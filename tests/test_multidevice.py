"""Multi-device semantics tests.

jax pins the device count at first init and the rest of the suite must see
ONE device, so these run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 and assert inside it.
They verify that every shard_map code path computes the SAME result as its
single-device oracle:

  * sharded posting-scan engine  == flat search
  * embedding_bag_sharded        == embedding_bag
  * MoE with EP over model=4     == MoE with tp=1
  * compressed/bucketed psum     == plain mean
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    assert len(jax.devices()) == 8

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    # ---- 1. sharded search == flat search --------------------------------
    from repro.build.kmeans import balanced_hierarchical_kmeans
    from repro.core.spann_rules import closure_assign
    from repro.core.ivf import IVFIndex, build_postings, search_flat
    from repro.core.search import SearchConfig, make_sharded_serve

    rng = np.random.default_rng(0)
    x = rng.normal(size=(2000, 16)).astype(np.float32)
    q = rng.normal(size=(32, 16)).astype(np.float32)
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=40, iters=6)
    ca = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents), eps=0.2))
    C = cents.shape[0]
    Cpad = -(-C // 4) * 4            # pad clusters to the model axis
    postings, pids = build_postings(x, ca, C, 48)
    postings = np.concatenate([postings, np.zeros((Cpad - C, 48, 16), np.float32)])
    pids = np.concatenate([pids, np.full((Cpad - C, 48), -1, np.int32)])
    cents_pad = np.concatenate([cents, np.full((Cpad - C, 16), 1e6, np.float32)])
    idx = IVFIndex(jnp.asarray(cents_pad), jnp.asarray(postings), jnp.asarray(pids))

    scfg = SearchConfig(k=10, nprobe_max=16, pruning="none", use_kernel=False)
    serve = make_sharded_serve(mesh, scfg)
    d_sh, i_sh, _ = serve(idx.centroids, idx.postings, idx.posting_ids,
                          None, jnp.asarray(q),
                          jnp.full((32,), 10, jnp.int32))
    d_flat, i_flat = search_flat(idx, jnp.asarray(q), 10, nprobe=16)
    np.testing.assert_allclose(np.asarray(d_sh), np.asarray(d_flat),
                               rtol=1e-4, atol=1e-4)
    # ids may differ only at equal distances; check recall-style equality
    for a, b in zip(np.asarray(i_sh), np.asarray(i_flat)):
        assert len(set(a.tolist()) ^ set(b.tolist())) <= 2, (a, b)
    print("sharded search OK")

    # ---- 2. embedding bag ---------------------------------------------------
    from repro.models.recsys.embedding import embedding_bag, embedding_bag_sharded
    table = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(-1, 64, size=(16, 5)).astype(np.int32))
    got = embedding_bag_sharded(table, ids, mesh)
    want = embedding_bag(table, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    print("embedding bag OK")

    # ---- 3. MoE EP == tp1 ---------------------------------------------------
    from repro.models.lm import LMConfig, MoEConfig, init_params
    from repro.models.lm.transformer import forward
    moe = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                    d_ff_shared=32, capacity_factor=4.0)
    cfg = LMConfig("t", n_layers=2, d_model=32, n_heads=4, n_kv=2, d_ff=0,
                   vocab=64, moe=moe, dtype=jnp.float32, q_chunk=16)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
    h1 = forward(params, toks, cfg, mesh=None)
    h2 = forward(params, toks, cfg, mesh=mesh)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3, atol=2e-3)
    print("moe EP OK")

    # ---- 4. compressed + bucketed psum --------------------------------------
    from repro.distributed.collectives import bucketed_psum, compressed_psum_tree
    grads = {"a": jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32)),
             "b": [jnp.asarray(rng.normal(size=(64,)).astype(np.float32))]}

    def cg(g):
        out, _ = compressed_psum_tree(g, "data")
        return out

    def bg(g):
        return bucketed_psum(g, "data", bucket_bytes=128)

    for fn, tol in ((cg, 3e-2), (bg, 1e-5)):
        got = jax.shard_map(fn, mesh=mesh, in_specs=(P(),), out_specs=P(),
                            check_vma=False)(grads)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(grads)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol)
    print("collectives OK")
    print("ALL MULTIDEVICE OK")
""")


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL MULTIDEVICE OK" in out.stdout
