"""Serving-runtime tests: queue-pair semantics, pipelined-vs-sequential
parity, deadline shedding determinism, multi-index fairness.

Engine tests drive ``ServeEngine.step`` with a VIRTUAL clock: every
admission / shedding / batching decision is a function of (policy, trace
times) only, so replaying a seeded trace must reproduce the decision
sequence bit-for-bit (``BatchPolicy(ewma=0)`` freezes the service-time
estimate — the one input that otherwise comes from wall-clock measurement).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.search import SearchConfig, serve_step
from repro.runtime import (
    BatchPolicy,
    BatchResult,
    DynamicBatcher,
    PrefetchPipeline,
    QueuePair,
    RoutePlan,
    SearchRequest,
    ServeEngine,
    StageTimes,
    bursty_trace,
    hot_cluster_trace,
    inflight_depth,
    locality_skewed_trace,
    multi_tenant_trace,
    overlap_efficiency,
    poisson_trace,
    shard_skewed_trace,
    TenantSpec,
)
from repro.storage import TieredPostings


CFG = SearchConfig(k=5, nprobe_max=8, pruning="none", use_kernel=False,
                   fused_topk=True)


@pytest.fixture(scope="module")
def queries(small_corpus):
    _, q, topk = small_corpus
    return q.astype(np.float32), topk


@pytest.fixture()
def streamed_pipeline(small_index):
    tier = TieredPostings(np.asarray(small_index.postings),
                          np.asarray(small_index.posting_ids))
    return PrefetchPipeline(small_index, None, CFG, tier=tier,
                            pad_batch=8, row_bucket=32)


def _mk_engine(small_index, n_indexes=2, policy=None, clock=None, depth=1):
    pipes = {}
    for i in range(n_indexes):
        tier = TieredPostings(np.asarray(small_index.postings),
                              np.asarray(small_index.posting_ids))
        pipes[f"idx{i}"] = PrefetchPipeline(small_index, None, CFG, tier=tier,
                                            pad_batch=8, row_bucket=32)
    policy = policy or BatchPolicy(max_batch=16, max_wait_s=0.001, pad=8)
    batcher = DynamicBatcher(policy, list(pipes))
    return ServeEngine(pipes, batcher, clock=clock or (lambda: 0.0),
                       depth=depth)


# -------------------------------------------------------------------------
# queue pair
# -------------------------------------------------------------------------
def test_queue_pair_fifo_and_backpressure():
    qp = QueuePair(sq_depth=4)

    def req(i):
        return SearchRequest(req_id=i, index="a", query=np.zeros(4),
                             topk=5, deadline=None)

    for i in range(4):
        assert qp.submit(req(i))
    # full SQ: non-blocking submit is back-pressure, blocking times out
    assert not qp.submit(req(99))
    assert not qp.submit(req(99), block=True, timeout=0.01)
    got = qp.pop_submissions(2)
    assert [r.req_id for r in got] == [0, 1]          # FIFO
    assert qp.submit(req(4))                          # drained -> admits
    got = qp.pop_submissions()
    assert [r.req_id for r in got] == [2, 3, 4]
    assert not qp.wait_submissions(timeout=0.01)


def test_queue_pair_completion_order():
    from repro.runtime import Completion
    qp = QueuePair()
    qp.complete([Completion(i, "a", "ok", None, None, 0, 0.0, 1.0)
                 for i in range(5)])
    assert [c.req_id for c in qp.poll(3)] == [0, 1, 2]
    assert [c.req_id for c in qp.poll()] == [3, 4]


# -------------------------------------------------------------------------
# pipeline parity
# -------------------------------------------------------------------------
def test_pipelined_matches_sequential(streamed_pipeline, queries):
    q, topk = queries
    batches = [(q[i * 16:(i + 1) * 16], topk[i * 16:(i + 1) * 16])
               for i in range(4)]
    seq = streamed_pipeline.run_sequential(batches)
    pip = streamed_pipeline.run_pipelined(batches)
    ref = streamed_pipeline.run_sequential(batches, reference=True)
    for s, p, r in zip(seq, pip, ref):
        np.testing.assert_array_equal(s.ids, p.ids)
        np.testing.assert_allclose(s.dists, p.dists)
        np.testing.assert_array_equal(s.ids, r.ids)
    # overlap is measured, not asserted: sequential mode must show none
    assert overlap_efficiency([r.times for r in seq]) == 0.0
    assert overlap_efficiency([r.times for r in pip]) > 0.0


def test_streamed_matches_serve_step(streamed_pipeline, small_index, queries):
    q, topk = queries
    out = streamed_pipeline.serve_batch(q[:32], topk[:32])
    ref = serve_step(small_index, None, jnp.asarray(q[:32]),
                     jnp.asarray(topk[:32]), CFG)
    np.testing.assert_array_equal(np.asarray(ref["ids"]), out.ids)
    np.testing.assert_array_equal(np.asarray(ref["nprobe"]), out.nprobe)


def test_resident_mode_matches_streamed(small_index, queries):
    q, topk = queries
    res = PrefetchPipeline(small_index, None, CFG, pad_batch=8)
    tier = TieredPostings(np.asarray(small_index.postings),
                          np.asarray(small_index.posting_ids))
    str_ = PrefetchPipeline(small_index, None, CFG, tier=tier, pad_batch=8)
    a = res.serve_batch(q[:24], topk[:24])
    b = str_.serve_batch(q[:24], topk[:24])
    np.testing.assert_array_equal(a.ids, b.ids)


def test_nprobe_cap_degrades(streamed_pipeline, queries):
    q, topk = queries
    cap = np.zeros(16, np.int32)
    cap[:8] = 2
    out = streamed_pipeline.serve_batch(q[:16], topk[:16], nprobe_cap=cap)
    assert (out.nprobe[:8] <= 2).all()
    assert (out.nprobe[8:] == CFG.nprobe_max).all()   # pruning="none"


# -------------------------------------------------------------------------
# dup_bound: oracle pre-selection must cover the build's realized replication
# -------------------------------------------------------------------------
def _high_replication_index(max_replicas=12, n=20, c=16, d=8, seed=3):
    """Index built at max_replicas=12: every vector lands in its 12 nearest
    clusters (eps wide open, RNG rule off), so every id has exactly 12
    posting slots — the regime the hardcoded dup_bound=8 silently broke."""
    import jax.numpy as jnp
    from repro.core.ivf import IVFIndex, build_postings
    from repro.core.spann_rules import closure_assign

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    cents = rng.normal(size=(c, d)).astype(np.float32)
    ca = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents),
                                   eps=1e6, max_replicas=max_replicas,
                                   rng_rule=False))
    assert (ca >= 0).all()                  # replication saturated the cap
    postings, pids = build_postings(x, ca, c, cluster_len=32)
    return x, IVFIndex(jnp.asarray(cents), jnp.asarray(postings),
                       jnp.asarray(pids))


def test_dup_bound_derived_from_build_replication():
    """Regression for the ROADMAP dup_bound=8 hazard: at max_replicas=12 the
    oracle's pre-selection must widen to the realized replication, or the
    k2 frontier fills with closure duplicates and real neighbors drop out."""
    from repro.runtime import max_id_replicas

    x, index = _high_replication_index()
    assert max_id_replicas(index.posting_ids) == 12
    rng = np.random.default_rng(7)
    q = rng.normal(size=(8, x.shape[1])).astype(np.float32)
    # n_cand=12 == replication: with dup_bound=8 the top-96 pre-selection is
    # exactly the 8 nearest ids' slots -> only 8 uniques survive for k=10
    cfg = SearchConfig(k=10, nprobe_max=16, pruning="none", n_cand=12,
                       use_kernel=False, fused_topk=True)
    outs = {}
    for use_kernel in (False, True):
        c = SearchConfig(**{**cfg.__dict__, "use_kernel": use_kernel})
        tier = TieredPostings(np.asarray(index.postings),
                              np.asarray(index.posting_ids))
        pipe = PrefetchPipeline(index, None, c, tier=tier,
                                pad_batch=8, row_bucket=32)
        assert pipe.dup_bound == 12          # derived, not hardcoded
        outs[use_kernel] = pipe.serve_batch(q, 10)
    # oracle == kernel, and every query fills all k slots with real ids
    np.testing.assert_array_equal(outs[False].ids, outs[True].ids)
    np.testing.assert_allclose(outs[False].dists, outs[True].dists,
                               rtol=1e-5, atol=1e-5)
    assert (outs[False].ids >= 0).all()
    # the pre-fix behavior is reproducible on demand: a forced dup_bound=8
    # pipeline starves the frontier (candidates lost to duplicates)
    tier = TieredPostings(np.asarray(index.postings),
                          np.asarray(index.posting_ids))
    stale = PrefetchPipeline(index, None, cfg, tier=tier,
                             pad_batch=8, row_bucket=32, dup_bound=8)
    out8 = stale.serve_batch(q, 10)
    assert (out8.ids < 0).any(), "dup_bound=8 should starve k=10 here"


# -------------------------------------------------------------------------
# batcher: deadline-estimate fixed point, shared due predicate, locality
# -------------------------------------------------------------------------
def _req(i, deadline, t=0.0, index="a", route=None):
    return SearchRequest(req_id=i, index=index,
                         query=np.zeros(4, np.float32), topk=5,
                         deadline=deadline, arrival=t, route=route)


def _routed_req(i, clusters, t=0.0, index="a"):
    cids = np.full(8, -1, np.int32)
    cids[: len(clusters)] = clusters
    return _req(i, None, t=t, index=index,
                route=RoutePlan(cids=cids, nprobe=len(clusters),
                                probe_set=frozenset(clusters), source=None))


def test_form_estimates_recomputed_on_kept_set():
    """Regression for the pre-shed estimate bug: ``form`` judged every
    request against ``est = overhead + est_query_s * len(reqs)`` computed
    BEFORE shedding, so a survivor was shed/degraded because of peers that
    were themselves just shed.  With overhead 1ms and 10ms/query: the
    pre-shed batch of 4 estimates 41ms full / 21ms degraded, which sheds
    S2 (13ms budget) and degrades S1 (25ms budget) — but once the two
    doomed 7ms requests are dropped, the kept batch of 2 runs in 21ms full
    / 11ms degraded, so S2 fits degraded and S1 fits at FULL quality."""
    policy = BatchPolicy(max_batch=8, max_wait_s=0.0, shed="degrade",
                         degrade_nprobe=2, degrade_speedup=2.0,
                         overhead_s=1e-3, init_query_s=10e-3, ewma=0.0)
    b = DynamicBatcher(policy, ["a"])
    for i, dl in enumerate((0.007, 0.007, 0.013, 0.025)):
        assert b.add(_req(i, dl), now=0.0) is None   # all pass admission
    mb, sheds = b.form(0.0)
    assert sorted(c.req_id for c in sheds) == [0, 1]   # truly doomed
    assert [r.req_id for r in mb.requests] == [2, 3]   # survivors KEPT
    assert mb.degraded.tolist() == [True, False]       # S1 at full quality
    assert mb.nprobe_cap.tolist() == [2, 0]
    assert b.stats.shed_deadline == 2 and b.stats.degraded == 1


def test_ready_and_form_share_due_predicate():
    policy = BatchPolicy(max_batch=4, max_wait_s=0.01, shed="none")
    b = DynamicBatcher(policy, ["a", "b"])
    b.add(_req(1, None, t=0.0), now=0.0)
    # young + underfull: not due — ready and form must agree (shared helper)
    assert not b.ready(0.005)
    assert b.form(0.005) == (None, [])
    # head-of-line aged: both flip together
    assert b.ready(0.011)
    mb, _ = b.form(0.011)
    assert mb is not None and len(mb.requests) == 1
    # fullness triggers regardless of age
    for i in range(4):
        b.add(_req(10 + i, None, t=0.02), now=0.02)
    assert b.ready(0.02)
    mb, _ = b.form(0.02)
    assert len(mb.requests) == 4
    # force drain: both queues drain, round-robin, deterministically
    b.add(_req(20, None, t=0.03), now=0.03)
    b.add(_req(21, None, t=0.03, index="b"), now=0.03)
    assert not b.ready(0.03)
    first, _ = b.form(0.03, force=True)
    second, _ = b.form(0.03, force=True)
    assert {first.index, second.index} == {"a", "b"}
    assert b.form(0.03, force=True) == (None, [])


def test_locality_grouping_packs_by_probe_overlap():
    import dataclasses as _dc
    policy = BatchPolicy(max_batch=4, max_wait_s=10.0, shed="none",
                         grouping="locality")
    ga, gb = (1, 2, 3), (7, 8, 9)
    b = DynamicBatcher(policy, ["a"])
    # interleaved arrivals from two disjoint probe neighborhoods
    for i in range(8):
        b.add(_routed_req(i, ga if i % 2 == 0 else gb), now=0.0)
    mb1, _ = b.form(0.0)
    mb2, _ = b.form(0.0)
    assert [r.req_id for r in mb1.requests] == [0, 2, 4, 6]   # unmixed,
    assert [r.req_id for r in mb2.requests] == [1, 3, 5, 7]   # FIFO inside
    assert mb1.probe_union == frozenset(ga)
    assert mb2.probe_union == frozenset(gb)
    assert b.stats.locality_batches == 2
    # FIFO mode on the same arrivals mixes both groups (the A/B baseline)
    bf = DynamicBatcher(_dc.replace(policy, grouping="fifo"), ["a"])
    for i in range(8):
        bf.add(_routed_req(i, ga if i % 2 == 0 else gb), now=0.0)
    mbf, _ = bf.form(0.0)
    assert [r.req_id for r in mbf.requests] == [0, 1, 2, 3]
    assert mbf.probe_union == frozenset(ga) | frozenset(gb)


def test_locality_aging_guard_seeds_skipped_requests():
    policy = BatchPolicy(max_batch=4, max_wait_s=0.01, shed="none")
    hot, cold = (1, 2, 3), (40, 41)
    b = DynamicBatcher(policy, ["a"])
    b.add(_routed_req(0, hot, t=0.0), now=0.0)
    b.add(_routed_req(1, hot, t=0.0), now=0.0)
    b.add(_routed_req(2, cold, t=0.0), now=0.0)       # the outlier
    for i in range(3, 9):
        b.add(_routed_req(i, hot, t=0.001), now=0.001)
    # due by fullness at t=1ms: nothing aged yet, locality skips the outlier
    mb, _ = b.form(0.001)
    assert 2 not in [r.req_id for r in mb.requests]
    assert mb.probe_union == frozenset(hot)
    # by t=11ms the outlier has aged past max_wait_s: it MUST seed the next
    # batch even though it shares no clusters with anyone
    for i in range(9, 12):
        b.add(_routed_req(i, hot, t=0.011), now=0.011)
    mb2, _ = b.form(0.011)
    assert 2 in [r.req_id for r in mb2.requests]
    assert frozenset(cold) <= mb2.probe_union
    assert b.stats.aged_seeds > 0


def test_union_growth_cap_releases_tight_partial_batches():
    policy = BatchPolicy(max_batch=4, max_wait_s=10.0, shed="none",
                         union_growth_cap=1)
    b = DynamicBatcher(policy, ["a"])
    b.add(_routed_req(0, (1, 2, 3)), now=0.0)
    b.add(_routed_req(1, (1, 2, 3)), now=0.0)
    b.add(_routed_req(2, (50, 51, 52)), now=0.0)      # would add 3 clusters
    b.add(_routed_req(3, (1, 2, 4)), now=0.0)         # adds just 1
    mb, _ = b.form(0.0)
    assert [r.req_id for r in mb.requests] == [0, 1, 3]   # outlier deferred
    mb2, _ = b.form(10.5)                              # ages, then releases
    assert [r.req_id for r in mb2.requests] == [2]


# -------------------------------------------------------------------------
# engine: ordering, shedding determinism, fairness
# -------------------------------------------------------------------------
def test_engine_per_index_fifo(small_index, queries):
    q, _ = queries
    eng = _mk_engine(small_index)
    with pytest.raises(KeyError):
        eng.submit(q[0], 5, index="no-such-index")   # client-thread error,
    for i in range(40):                              # never the poller's
        assert eng.submit(q[i % 64], 5, index=f"idx{i % 2}") >= 0
    while eng.step(now=1.0):
        pass
    comps = eng.qp.poll()
    assert len(comps) == 40
    for name in ("idx0", "idx1"):
        seq = [c.req_id for c in comps if c.index == name]
        assert seq == sorted(seq)
    assert {c.status for c in comps} == {"ok"}


def _run_trace(small_index, q, trace, policy):
    vt = [0.0]
    eng = _mk_engine(small_index, policy=policy, clock=lambda: vt[0])
    log = []
    for arr in trace:
        vt[0] = arr.t
        eng.submit(q[arr.qrow % 64], 5, index="idx0",
                   deadline_s=arr.deadline_s)
        eng.step(now=arr.t, force=False)
        log += [(c.req_id, c.status, c.nprobe) for c in eng.qp.poll()]
    vt[0] = trace[-1].t + 1.0
    while eng.step(now=vt[0], force=True):
        pass
    log += [(c.req_id, c.status, c.nprobe) for c in eng.qp.poll()]
    return log, eng.stats


def test_deadline_shedding_deterministic(small_index, queries):
    q, _ = queries
    # saturating arrivals with deadlines tighter than a full batch: some
    # shed, some degraded.  ewma=0 freezes the service estimate so the
    # decision sequence is a pure function of the seeded trace.
    policy = BatchPolicy(max_batch=16, max_wait_s=0.005, pad=8,
                         shed="degrade", degrade_nprobe=2,
                         init_query_s=2e-3, ewma=0.0, overhead_s=1e-3)
    trace = poisson_trace(2000.0, 0.25, seed=11, deadline_s=0.012)
    assert len(trace) > 100
    log1, st1 = _run_trace(small_index, q, trace, policy)
    log2, st2 = _run_trace(small_index, q, trace, policy)
    assert log1 == log2                       # decision-for-decision replay
    statuses = {s for _, s, _ in log1}
    assert "shed" in statuses and "degraded" in statuses
    assert st1.shed == st2.shed and st1.degraded == st2.degraded
    # degraded requests really ran at the capped level
    for _, s, nprobe in log1:
        if s == "degraded":
            assert 0 < nprobe <= 2


def test_multi_index_fairness(small_index, queries):
    q, _ = queries
    eng = _mk_engine(small_index, n_indexes=3)
    # saturate all three tenants equally, then let the batcher release
    served = []
    for i in range(96):
        eng.submit(q[i % 64], 5, index=f"idx{i % 3}")
    orig = eng._complete_batch

    def spy(mb, result, done, epoch=None):
        served.append(mb.index)
        orig(mb, result, done, epoch=epoch)

    eng._complete_batch = spy
    while eng.step(now=1.0):
        pass
    counts = {n: served.count(n) for n in ("idx0", "idx1", "idx2")}
    assert max(counts.values()) - min(counts.values()) <= 1
    # round-robin: no tenant served twice before the others under backlog
    assert served[:3] in ([
        ["idx0", "idx1", "idx2"], ["idx1", "idx2", "idx0"],
        ["idx2", "idx0", "idx1"]])


def test_engine_threaded_drain(small_index, queries):
    q, _ = queries
    import time as _time
    eng = _mk_engine(small_index, clock=None)
    eng.clock = _time.monotonic
    eng.start()
    n = 0
    for i in range(50):
        n += eng.submit(q[i % 64], 5, index=f"idx{i % 2}") >= 0
    eng.stop(drain=True)
    comps = eng.qp.poll()
    assert len(comps) == n == eng.stats.completed
    assert all(c.status == "ok" for c in comps)


def test_engine_deep_window_threaded_drain(small_index, queries):
    """depth=3: the poller keeps several batches in flight; every admitted
    request still completes exactly once, per-index FIFO preserved (fifo
    grouping — locality may legitimately reorder across batches, so the
    order assert would race the wall clock under it)."""
    q, _ = queries
    import time as _time
    policy = BatchPolicy(max_batch=16, max_wait_s=0.001, pad=8,
                         grouping="fifo")
    eng = _mk_engine(small_index, policy=policy, clock=None, depth=3)
    eng.clock = _time.monotonic
    eng.start()
    n = 0
    for i in range(60):
        n += eng.submit(q[i % 64], 5, index=f"idx{i % 2}") >= 0
    eng.stop(drain=True)
    comps = eng.qp.poll()
    assert len(comps) == n == eng.stats.completed
    assert all(c.status == "ok" for c in comps)
    for name in ("idx0", "idx1"):
        seq = [c.req_id for c in comps if c.index == name]
        assert seq == sorted(seq)


def test_engine_routes_at_admission(small_index, queries):
    """Requests carry a RoutePlan whose probe signature is exactly what the
    pipeline's plan stage would compute: bursts are routed eagerly at SQ
    drain (group >= pad amortizes the call), trickles in one pooled call
    at formation — either way, at most once per request."""
    q, _ = queries
    eng = _mk_engine(small_index, n_indexes=1)
    pipe = eng.pipelines["idx0"]
    # burst path: drained group of 8 >= pad=8 -> routed at admission
    for i in range(8):
        eng.submit(q[i], 5, index="idx0")
    eng._drain_sq(0.0)
    reqs = list(eng.batcher._pending["idx0"])
    assert len(reqs) == 8
    assert all(r.route is not None for r in reqs)
    cids, npb = pipe.route(q[:8], np.full(8, 5, np.int32))
    for i, r in enumerate(reqs):
        want = frozenset(int(c) for c in cids[i, : int(npb[i])] if c >= 0)
        assert r.route.probe_set == want and len(want) > 0
        assert r.route.source is pipe
    while eng.step(now=1.0):
        pass
    comps = eng.qp.poll()
    assert len(comps) == 8 and all(c.status == "ok" for c in comps)
    # trickle path: below-pad drains stay unrouted until formation pools
    # them into one routing call
    for i in range(3):
        eng.submit(q[i], 5, index="idx0")
        eng._drain_sq(0.0)
    reqs = list(eng.batcher._pending["idx0"])
    assert all(r.route is None for r in reqs)
    mb, _ = eng.batcher.form(1.0, force=False)     # head aged -> due
    assert mb is not None and len(mb.requests) == 3
    assert all(r.route is not None and r.route.source is pipe
               for r in mb.requests)


def test_route_reuse_matches_replan(streamed_pipeline, queries):
    """plan(routed=...) must be bit-identical to plan() recomputing the
    centroid scan — the admission-time routing is moved, not approximated."""
    q, topk = queries
    cids, nprobe = streamed_pipeline.route(q[:16], topk[:16])
    plan_r = streamed_pipeline.plan(q[:16], topk[:16],
                                    routed=(cids, nprobe))
    assert plan_r.times.routed
    out_r = streamed_pipeline.harvest(streamed_pipeline.dispatch(
        streamed_pipeline.prefetch(plan_r)))
    out = streamed_pipeline.serve_batch(q[:16], topk[:16])
    np.testing.assert_array_equal(out.ids, out_r.ids)
    np.testing.assert_allclose(out.dists, out_r.dists)
    np.testing.assert_array_equal(out.nprobe, out_r.nprobe)


def test_run_pipelined_depth(streamed_pipeline, queries):
    q, topk = queries
    batches = [(q[i * 8:(i + 1) * 8], topk[i * 8:(i + 1) * 8])
               for i in range(6)]
    base = streamed_pipeline.run_sequential(batches)
    deep = streamed_pipeline.run_pipelined(batches, depth=3)
    for s, p in zip(base, deep):
        np.testing.assert_array_equal(s.ids, p.ids)
    # stamp evidence: >= 2 scans in flight at once with a deep window,
    # never more than 1 in the sequential and 1-deep drivers
    assert inflight_depth([r.times for r in deep]) >= 2
    assert inflight_depth([r.times for r in base]) == 1
    shallow = streamed_pipeline.run_pipelined(batches, depth=1)
    assert inflight_depth([r.times for r in shallow]) == 1
    for s, p in zip(base, shallow):
        np.testing.assert_array_equal(s.ids, p.ids)


def test_multi_tenant_starvation_guard_under_locality(small_index, queries):
    """A hot-cluster tenant must not delay a cold tenant's head-of-line
    request past max_wait_s under locality grouping (seeded trace, virtual
    clock — the decision sequence replays bit-for-bit)."""
    from repro.runtime import merge_timelines
    q, _ = queries
    policy = BatchPolicy(max_batch=8, max_wait_s=0.002, pad=8, shed="none",
                         grouping="locality")
    hot = poisson_trace(3000.0, 0.1, seed=5, index="idx0")
    cold = poisson_trace(80.0, 0.1, seed=6, index="idx1")
    trace = merge_timelines(hot, cold)
    assert any(a.index == "idx1" for a in trace)
    logs = []
    for _ in range(2):
        vt = [0.0]
        eng = _mk_engine(small_index, policy=policy, clock=lambda: vt[0])
        log = []
        for arr in trace:
            vt[0] = arr.t
            eng.submit(q[arr.qrow % 64], 5, index=arr.index)
            eng.step(now=arr.t, force=False)   # drain SQ, form if due
            while eng.batcher.ready(arr.t):    # both tenants due: form all
                eng.step(now=arr.t, force=False)
            log += [(c.req_id, c.index) for c in eng.qp.poll()]
        vt[0] = trace[-1].t + policy.max_wait_s + 1e-4
        while eng.step(now=vt[0], force=False):
            pass
        log += [(c.req_id, c.index) for c in eng.qp.poll()]
        assert eng.batcher.pending() == 0
        # the aging bound: formation opportunities in this replay exist
        # only at arrival times, so no request (either tenant) may wait
        # past max_wait_s plus the largest inter-arrival gap
        slack = max(y.t - x.t for x, y in zip(trace, trace[1:])) + 2e-4
        assert eng.batcher.stats.max_queue_wait_s \
            <= policy.max_wait_s + slack
        assert len(log) == len(trace)
        logs.append(log)
    assert logs[0] == logs[1]                 # deterministic replay


# -------------------------------------------------------------------------
# loadgen: locality-skewed + hot-cluster traces
# -------------------------------------------------------------------------
def test_locality_traces_deterministic_and_skewed():
    kw = dict(n_queries=640, n_groups=8, concurrency=4, seed=2)
    a = locality_skewed_trace(500, 1.0, **kw)
    assert a == locality_skewed_trace(500, 1.0, **kw)
    assert all(x.t <= y.t for x, y in zip(a, a[1:]))
    gs = 640 // 8
    assert len({arr.qrow // gs for arr in a}) > 1   # interleaved groups
    # within a stream, group persistence: consecutive same-group arrivals
    # dominate (switch_p is small), so short windows are locality-skewed
    h = hot_cluster_trace(500, 1.0, n_queries=640, hot_frac=0.05,
                          hot_weight=0.9, seed=3)
    assert h == hot_cluster_trace(500, 1.0, n_queries=640, hot_frac=0.05,
                                  hot_weight=0.9, seed=3)
    n_hot = sum(1 for arr in h if arr.qrow < 32)
    assert n_hot > 0.7 * len(h)               # hot slice carries the mass


# -------------------------------------------------------------------------
# load generator
# -------------------------------------------------------------------------
def test_loadgen_deterministic_and_sorted():
    a = poisson_trace(500, 1.0, seed=3, deadline_s=0.05)
    b = poisson_trace(500, 1.0, seed=3, deadline_s=0.05)
    assert a == b
    assert all(x.t <= y.t for x, y in zip(a, a[1:]))
    assert abs(len(a) - 500) < 120            # ~Poisson(500)
    c = poisson_trace(500, 1.0, seed=4)
    assert c != a

    m = multi_tenant_trace([TenantSpec("x", 300), TenantSpec("y", 100)],
                           1.0, seed=0)
    assert all(p.t <= q.t for p, q in zip(m, m[1:]))
    nx = sum(1 for arr in m if arr.index == "x")
    ny = len(m) - nx
    assert nx > 2 * ny                        # rate mix respected

    bt = bursty_trace(50, 2000, period_s=0.2, duty=0.25, duration_s=1.0,
                      seed=5)
    in_burst = sum(1 for arr in bt if (arr.t % 0.2) < 0.05)
    assert in_burst > len(bt) * 0.6           # bursts carry the mass


# -------------------------------------------------------------------------
# shutdown / crash drain: no admitted request is ever abandoned
# -------------------------------------------------------------------------
class _HarvestBomb:
    """Delegating pipeline wrapper whose harvest raises for chosen batch
    ordinals — the poller-killing fault the engine's drain guards absorb."""

    def __init__(self, inner, fail_batches):
        self._inner = inner
        self._fail = set(fail_batches)
        self._n = 0

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def harvest(self, handle):
        i = self._n
        self._n += 1
        if i in self._fail:
            raise RuntimeError("injected harvest fault")
        return self._inner.harvest(handle)


def test_harvest_fault_completes_batch_as_failed(small_index, queries):
    """Regression (pre-fix behavior FAILS this): a harvest exception used
    to unwind the poller thread with the depth-N window still holding
    batches — those and every later submission were abandoned, clients
    blocked on CQ entries that never came.  Now the faulted batch
    completes as "failed" and the poller keeps serving the rest."""
    q, _ = queries
    import time as _time
    tier = TieredPostings(np.asarray(small_index.postings),
                          np.asarray(small_index.posting_ids))
    pipe = _HarvestBomb(
        PrefetchPipeline(small_index, None, CFG, tier=tier,
                         pad_batch=8, row_bucket=32),
        fail_batches={1})
    policy = BatchPolicy(max_batch=8, max_wait_s=0.001, pad=8,
                         grouping="fifo")
    eng = ServeEngine({"a": pipe}, DynamicBatcher(policy, ["a"]),
                      clock=_time.monotonic, depth=2)
    eng.start()
    n = 0
    for i in range(48):
        n += eng.submit(q[i % 64], 5, index="a") >= 0
    eng.stop(drain=True)
    comps = eng.qp.poll()
    assert len(comps) == n == eng.stats.completed     # nothing abandoned
    n_failed = sum(1 for c in comps if c.status == "failed")
    assert n_failed >= 1                              # the bombed batch
    assert eng.stats.failed == n_failed
    assert all(c.ids is None for c in comps if c.status == "failed")
    assert sum(1 for c in comps if c.status == "ok") == n - n_failed


def test_stop_without_drain_sheds_instead_of_abandoning(small_index,
                                                        queries):
    """Regression: ``stop(drain=False)`` used to abandon requests pooled
    in the batcher (and SQ residents) — no CQ entry, blocked clients.
    Now every admitted-but-unformed request completes as "shed"."""
    q, _ = queries
    import time as _time
    # max_wait long enough that the batch cannot become due before stop
    policy = BatchPolicy(max_batch=64, max_wait_s=0.2, pad=8)
    eng = _mk_engine(small_index, policy=policy)
    eng.clock = _time.monotonic
    eng.start()
    n = 0
    for i in range(5):
        n += eng.submit(q[i], 5, index="idx0") >= 0
    _time.sleep(0.05)
    eng.stop(drain=False)
    comps = eng.qp.poll()
    assert len(comps) == n == eng.stats.completed
    assert {c.status for c in comps} == {"shed"}
    assert eng.batcher.pending() == 0


def test_batcher_drain_pending_fifo():
    policy = BatchPolicy(max_batch=64, max_wait_s=10.0, pad=8)
    b = DynamicBatcher(policy, ["a", "b"])

    def req(i, idx):
        return SearchRequest(req_id=i, index=idx, query=np.zeros(4),
                             topk=5, deadline=None)

    for i in range(6):
        assert b.add(req(i, "a" if i % 2 == 0 else "b"), 0.0) is None
    out = b.drain_pending()
    # FIFO within each index, indexes in registration order
    assert [r.req_id for r in out] == [0, 2, 4, 1, 3, 5]
    assert b.pending() == 0
    mb, sheds = b.form(100.0, force=True)
    assert mb is None and sheds == []


class _PartialPipe:
    """Minimal stage-protocol pipeline: stamps row 0 of every batch as
    partial (the fabric's degraded-mode contract) and records the batch
    deadline the engine hands to deadline-aware pipelines."""
    pad_batch = 8
    accepts_deadline = True

    def __init__(self):
        self.saw_deadline = "unset"

    def plan(self, queries, topk, nprobe_cap=None, routed=None,
             deadline=None):
        self.saw_deadline = deadline
        return queries.shape[0]

    def prefetch(self, b):
        return b

    def dispatch(self, b):
        return b

    def harvest(self, b):
        partial = np.zeros(b, bool)
        partial[0] = True
        return BatchResult(
            ids=np.zeros((b, 5), np.int32),
            dists=np.zeros((b, 5), np.float32),
            nprobe=np.full(b, 1, np.int32),
            times=StageTimes(size=b), partial=partial)


def test_engine_stamps_partial_and_plumbs_deadline():
    eng = ServeEngine({"a": _PartialPipe()},
                      DynamicBatcher(BatchPolicy(max_batch=4,
                                                 max_wait_s=0.001, pad=4),
                                     ["a"]),
                      clock=lambda: 0.0)
    for i in range(3):
        assert eng.submit(np.zeros(4), 5, index="a",
                          deadline_s=1.0 + i) >= 0
    eng.step(now=0.0)
    comps = eng.qp.poll()
    assert [c.status for c in comps] == ["partial", "ok", "ok"]
    assert eng.stats.partial == 1
    # the batch deadline is the tightest request deadline
    assert eng.pipelines["a"].saw_deadline == 1.0


def test_shard_skewed_trace_deterministic_and_skewed():
    hot = [3, 7, 11]
    a = shard_skewed_trace(400, 1.0, 64, hot, seed=9)
    assert a == shard_skewed_trace(400, 1.0, 64, hot, seed=9)
    assert all(x.t <= y.t for x, y in zip(a, a[1:]))
    n_hot = sum(1 for arr in a if arr.qrow in set(hot))
    assert n_hot > 0.7 * len(a)               # hot shard carries the mass
    assert all(0 <= arr.qrow < 64 for arr in a)
    assert shard_skewed_trace(400, 1.0, 64, hot, seed=10) != a
    with pytest.raises(ValueError):
        shard_skewed_trace(400, 1.0, 64, [], seed=0)
