"""Construction pipeline: 3 stages, checkpoint/resume, LLSP integration."""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.build.pipeline import BuildConfig, build_index
from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.llsp import LLSPConfig
from repro.core.search import SearchConfig, serve_step


@pytest.fixture(scope="module")
def built(tmp_path_factory, small_corpus):
    x, q, topk = small_corpus
    wd = str(tmp_path_factory.mktemp("build"))
    cfg = BuildConfig(max_cluster_size=48, cluster_len=64, coarse_per_task=800,
                      n_workers=2,
                      llsp=LLSPConfig(levels=(4, 8, 16, 32), n_trees=20,
                                      max_depth=4, n_ratio_features=8))
    idx, llsp, report = build_index(x, cfg, wd, queries=q,
                                    query_topk=np.minimum(topk, 20))
    return wd, cfg, idx, llsp, report, small_corpus


def test_build_produces_searchable_index(built):
    wd, cfg, idx, llsp, report, (x, q, topk) = built
    assert report.n_clusters > 10
    assert report.replication >= 1.0
    qj = jnp.asarray(q)
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    out = serve_step(idx, None, qj, jnp.full((q.shape[0],), 10, jnp.int32),
                     SearchConfig(k=10, nprobe_max=32, pruning="none",
                                  use_kernel=False))
    assert recall_at_k(out["ids"], np.asarray(ti)) > 0.85


def test_llsp_trained_in_pipeline_works(built):
    wd, cfg, idx, llsp, report, (x, q, topk) = built
    assert llsp is not None
    qj = jnp.asarray(q)
    out = serve_step(idx, llsp, qj, jnp.full((q.shape[0],), 10, jnp.int32),
                     SearchConfig(k=10, nprobe_max=32, pruning="llsp",
                                  n_ratio=8, use_kernel=False))
    assert float(np.asarray(out["nprobe"]).mean()) <= 32


def test_resume_skips_finished_stages(built, small_corpus):
    wd, cfg, idx, llsp, report, _ = built
    x, q, topk = small_corpus
    idx2, _, report2 = build_index(x, cfg, wd, queries=q,
                                   query_topk=np.minimum(topk, 20))
    assert "stage1" in report2.resumed_stages
    assert "stage2" in report2.resumed_stages
    np.testing.assert_array_equal(np.asarray(idx.posting_ids),
                                  np.asarray(idx2.posting_ids))


def test_stage2_task_files_exist(built):
    wd = built[0]
    shards = os.listdir(os.path.join(wd, "shards"))
    assert len(shards) >= 2          # the pipeline actually split the work


# -------------------------------------------------------------------------
# PR 3: fused assign + streamed stage 2
# -------------------------------------------------------------------------
def test_fused_assign_step_bit_identical(small_corpus):
    """The fused E+M pass must produce bit-identical assignments and counts
    to the legacy path on the same inputs — off-TPU both argmin over the
    same oracle distances, so parity is structural.  (On TPU the two Pallas
    kernels may flip ULP ties; the bench's tolerant check covers that.)"""
    import jax
    from repro.build.kmeans import kmeans_assign_step

    if jax.default_backend() == "tpu":
        pytest.skip("bit-exact parity is an off-TPU structural property")
    x, _, _ = small_corpus
    cents = x[:37].copy()
    a_f, m_f, s_f, c_f = kmeans_assign_step(x, cents, fused=True)
    a_u, m_u, s_u, c_u = kmeans_assign_step(x, cents, fused=False)
    np.testing.assert_array_equal(a_f, a_u)
    np.testing.assert_array_equal(c_f, c_u)
    np.testing.assert_allclose(m_f, m_u, rtol=1e-5, atol=1e-5)
    # fused sums are f32 device accumulations vs the f64 host scatter-add
    np.testing.assert_allclose(s_f, s_u, rtol=1e-4, atol=1e-4)


def test_stage2_stream_stamps_show_overlap(built):
    """The streamed stage-2 pipeline stamps every shard and the stamps must
    show shard i+1's load interval intersecting shard i's assign window for
    at least one pair (lenient: a contended CI box can deschedule the
    loader thread, so the gate is 'overlap happened somewhere')."""
    from repro.build.stream import pair_overlaps

    report = built[4]
    stamps = report.shard_stamps
    assert len(stamps) >= 2
    live = [t for t in stamps if not t["resumed"]]
    assert len(live) >= 2
    for t in live:      # stage ordering invariants hold per shard
        assert t["load_start"] <= t["load_end"] <= t["stream_end"]
        assert t["stream_end"] <= t["assign_dispatch"] <= t["assign_done"]
    overlaps = pair_overlaps(stamps)
    assert max(overlaps) > 0.0, f"no load-under-assign overlap: {overlaps}"
    assert 0.0 <= report.shard_overlap <= 1.0


def test_resume_mid_stage2_identical_hash(built, small_corpus, tmp_path):
    """Kill-and-resume mid-stage-2: delete one finished shard checkpoint,
    rebuild, and the final index must hash identically (the resumability
    contract of the streamed shard pipeline)."""
    from repro.build.pipeline import index_content_hash

    wd, cfg, idx, llsp, report, _ = built
    x, q, topk = small_corpus
    h0 = index_content_hash(idx)
    shards = sorted(os.listdir(os.path.join(wd, "shards")))
    os.remove(os.path.join(wd, "shards", shards[1]))
    idx2, _, report2 = build_index(x, cfg, wd, queries=q,
                                   query_topk=np.minimum(topk, 20))
    assert "stage2:partial" in report2.resumed_stages
    n_resumed = sum(1 for t in report2.shard_stamps if t["resumed"])
    assert n_resumed == len(shards) - 1      # only the deleted shard re-ran
    assert index_content_hash(idx2) == h0


def test_streamed_stage2_matches_elastic_path(small_corpus, tmp_path):
    """Schedule change, not artifact change: the double-buffered shard
    pipeline and the legacy elastic task pool build byte-identical stage-2
    output from the same stage-1 centroids."""
    import shutil
    from repro.build.pipeline import index_content_hash

    x, _, _ = small_corpus
    cfg_s = BuildConfig(max_cluster_size=48, cluster_len=64,
                        coarse_per_task=800, n_workers=2, stream_stage2=True)
    cfg_e = BuildConfig(max_cluster_size=48, cluster_len=64,
                        coarse_per_task=800, n_workers=2, stream_stage2=False)
    wd_s, wd_e = str(tmp_path / "s"), str(tmp_path / "e")
    idx_s, _, _ = build_index(x, cfg_s, wd_s)
    # reuse stage 1 so only the stage-2 scheduler differs
    os.makedirs(wd_e, exist_ok=True)
    shutil.copy(os.path.join(wd_s, "stage1_centroids.npy"),
                os.path.join(wd_e, "stage1_centroids.npy"))
    idx_e, _, rep_e = build_index(x, cfg_e, wd_e)
    assert "stage1" in rep_e.resumed_stages
    assert index_content_hash(idx_s) == index_content_hash(idx_e)
