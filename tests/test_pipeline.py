"""Construction pipeline: 3 stages, checkpoint/resume, LLSP integration."""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.build.pipeline import BuildConfig, build_index
from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.llsp import LLSPConfig
from repro.core.search import SearchConfig, serve_step


@pytest.fixture(scope="module")
def built(tmp_path_factory, small_corpus):
    x, q, topk = small_corpus
    wd = str(tmp_path_factory.mktemp("build"))
    cfg = BuildConfig(max_cluster_size=48, cluster_len=64, coarse_per_task=800,
                      n_workers=2,
                      llsp=LLSPConfig(levels=(4, 8, 16, 32), n_trees=20,
                                      max_depth=4, n_ratio_features=8))
    idx, llsp, report = build_index(x, cfg, wd, queries=q,
                                    query_topk=np.minimum(topk, 20))
    return wd, cfg, idx, llsp, report, small_corpus


def test_build_produces_searchable_index(built):
    wd, cfg, idx, llsp, report, (x, q, topk) = built
    assert report.n_clusters > 10
    assert report.replication >= 1.0
    qj = jnp.asarray(q)
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    out = serve_step(idx, None, qj, jnp.full((q.shape[0],), 10, jnp.int32),
                     SearchConfig(k=10, nprobe_max=32, pruning="none",
                                  use_kernel=False))
    assert recall_at_k(out["ids"], np.asarray(ti)) > 0.85


def test_llsp_trained_in_pipeline_works(built):
    wd, cfg, idx, llsp, report, (x, q, topk) = built
    assert llsp is not None
    qj = jnp.asarray(q)
    out = serve_step(idx, llsp, qj, jnp.full((q.shape[0],), 10, jnp.int32),
                     SearchConfig(k=10, nprobe_max=32, pruning="llsp",
                                  n_ratio=8, use_kernel=False))
    assert float(np.asarray(out["nprobe"]).mean()) <= 32


def test_resume_skips_finished_stages(built, small_corpus):
    wd, cfg, idx, llsp, report, _ = built
    x, q, topk = small_corpus
    idx2, _, report2 = build_index(x, cfg, wd, queries=q,
                                   query_topk=np.minimum(topk, 20))
    assert "stage1" in report2.resumed_stages
    assert "stage2" in report2.resumed_stages
    np.testing.assert_array_equal(np.asarray(idx.posting_ids),
                                  np.asarray(idx2.posting_ids))


def test_stage2_task_files_exist(built):
    wd = built[0]
    shards = os.listdir(os.path.join(wd, "shards"))
    assert len(shards) >= 2          # elastic pool actually split the work
