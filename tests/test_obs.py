"""Observability-layer tests (PR 7): streaming-histogram accuracy vs
np.percentile, trace-recorder ring/sampling/export semantics, the reason
taxonomy on every non-"ok" completion path, and end-to-end trace integrity
(well-nested spans, exactly one terminal per admitted request, trace_ids
surviving fabric requeue across a seeded kill drill)."""
import json
import time
import types

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.search import SearchConfig
from repro.distributed import FaultInjector, ShardedFabric
from repro.obs import (
    Counter, Gauge, Histogram, MetricsRegistry, Observability,
    TraceRecorder, check_well_nested,
)
from repro.runtime import (
    BatchPolicy, BatchResult, DynamicBatcher, ServeEngine, StageTimes,
    shard_skewed_trace,
)
from repro.storage import TieredPostings
from repro.storage.host_tier import FetchEvent, TierStats

CFG = SearchConfig(k=5, nprobe_max=8, pruning="none", use_kernel=False,
                   fused_topk=True)


# -------------------------------------------------------------------------
# metrics primitives
# -------------------------------------------------------------------------
def test_counter_labels_and_total():
    c = Counter("x")
    c.inc()
    c.inc(2, "deadline")
    c.inc(1, "drain")
    assert c.value() == 4
    assert c.value("deadline") == 2
    assert c.labels() == {"deadline": 2, "drain": 1}


def test_gauge_last_write_wins():
    g = Gauge("x")
    g.set(3)
    g.set(7)
    g.set(1, "shard0")
    assert g.value() == 7 and g.value("shard0") == 1


def test_histogram_accuracy_within_2pct_of_numpy():
    """ISSUE acceptance: streaming p50/p99 within 2% of np.percentile on a
    realistic latency-shaped (lognormal, ms-scale) stream."""
    rng = np.random.default_rng(42)
    xs = np.exp(rng.normal(np.log(0.020), 0.6, size=20_000))   # ~20ms median
    h = Histogram("lat")
    h.observe_many(xs)
    for q in (0.50, 0.90, 0.99):
        ref = float(np.percentile(xs, q * 100))
        got = h.quantile(q)
        assert abs(got - ref) / ref <= 0.02, (q, got, ref)
    assert abs(h.mean - xs.mean()) / xs.mean() < 1e-9


def test_histogram_single_sample_exact_and_bounded_memory():
    h = Histogram("x")
    h.observe(0.0123)
    assert h.quantile(0.5) == pytest.approx(0.0123)
    assert h.quantile(0.99) == pytest.approx(0.0123)
    n_cells = h.counts.size
    for v in np.linspace(1e-7, 2e4, 5000):     # incl. under/overflow
        h.observe(float(v))
    assert h.counts.size == n_cells            # O(1) memory, any stream
    assert h.n == 5001


def test_histogram_merge_equals_union():
    rng = np.random.default_rng(7)
    a, b = rng.exponential(0.05, 3000), rng.exponential(0.2, 2000)
    ha, hb, hu = Histogram("a"), Histogram("b"), Histogram("u")
    ha.observe_many(a)
    hb.observe_many(b)
    hu.observe_many(np.concatenate([a, b]))
    ha.merge(hb)
    assert ha.n == hu.n
    for q in (0.5, 0.99):
        assert ha.quantile(q) == pytest.approx(hu.quantile(q))


def test_registry_get_or_create_and_snapshot():
    m = MetricsRegistry()
    assert m.counter("a") is m.counter("a")
    with pytest.raises(AssertionError):
        m.gauge("a")                           # name/type collision
    m.counter("a").inc(3, "why")
    m.histogram("h").observe(0.5)
    snap = m.snapshot()
    assert snap["a"]["total"] == 3 and snap["a"]["why"] == 3
    assert snap["h"]["n"] == 1
    assert any("h:" in ln for ln in m.render())


# -------------------------------------------------------------------------
# trace recorder
# -------------------------------------------------------------------------
def test_mint_sampling_deterministic_and_off_is_free():
    tr = TraceRecorder(sample_rate=0.5)
    ids = [tr.mint() for _ in range(400)]
    tr2 = TraceRecorder(sample_rate=0.5)
    assert ids == [tr2.mint() for _ in range(400)]   # replayable
    sampled = [i for i in ids if i]
    assert 0 < len(sampled) < 400                    # rate actually applies
    off = TraceRecorder(enabled=False)
    assert off.mint() == 0
    off.span("x", 0.0, 1.0, trace_id=1)
    assert off.snapshot() == []


def test_ring_bound_drops_oldest_and_counts():
    tr = TraceRecorder(max_events_per_thread=64)
    for i in range(200):
        tr.instant(f"e{i}", t=float(i))
    assert tr.dropped_events > 0
    names = [e[1] for e in tr.snapshot()]
    assert len(names) <= 64
    assert "e199" in names and "e0" not in names     # recent kept


def test_export_perfetto_shape(tmp_path):
    tr = TraceRecorder()
    tr.span("stage", 1.0, 2.0, trace_id=5, track="batch-0", args={"n": 4})
    tr.instant("done:ok", t=2.0, trace_id=5, track="requests")
    tr.abegin("task", "task-1", t=1.1, trace_id=5, track="shard-0")
    tr.aend("task", "task-1", t=1.9, track="shard-0")
    path = str(tmp_path / "t.json")
    doc = tr.export(path)
    assert json.load(open(path)) == json.loads(json.dumps(doc))
    te = doc["traceEvents"]
    by_ph = {}
    for e in te:
        by_ph.setdefault(e["ph"], []).append(e)
    assert len(by_ph["X"]) == 1 and by_ph["X"][0]["dur"] == \
        pytest.approx(1e6)
    assert by_ph["X"][0]["args"]["trace_id"] == 5
    assert by_ph["i"][0]["s"] == "t"
    assert by_ph["b"][0]["id"] == by_ph["e"][0]["id"] == "task-1"
    tracks = {e["args"]["name"] for e in by_ph["M"]}
    assert {"batch-0", "requests", "shard-0"} <= tracks
    assert min(e["ts"] for e in te if e["ph"] != "M") == 0.0  # rebased
    assert check_well_nested(te) == []


def test_check_well_nested_catches_crossing_and_unmatched():
    cross = [
        {"ph": "X", "name": "a", "pid": 1, "tid": 1, "ts": 0, "dur": 10},
        {"ph": "X", "name": "b", "pid": 1, "tid": 1, "ts": 5, "dur": 10},
    ]
    assert any("crosses" in v for v in check_well_nested(cross))
    # same intervals on DIFFERENT tracks: fine
    cross[1]["tid"] = 2
    assert check_well_nested(cross) == []
    dangling = [{"ph": "b", "name": "t", "pid": 1, "tid": 1, "ts": 0,
                 "cat": "task", "id": "task-9"}]
    assert any("without end" in v for v in check_well_nested(dangling))
    orphan = [{"ph": "e", "name": "t", "pid": 1, "tid": 1, "ts": 0,
               "cat": "task", "id": "task-9"}]
    assert any("without begin" in v for v in check_well_nested(orphan))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 60), st.integers(1, 40),
                          st.integers(0, 2)), min_size=1, max_size=24))
def test_well_nested_property_on_constructed_trees(spans):
    """Spans built nested-by-construction (children strictly inside their
    parent) always validate; shifting any span to straddle its parent's
    end always trips the checker."""
    events = []
    for i, (start, width, depth) in enumerate(spans):
        # nest by shrinking: each deeper level sits strictly inside
        ts = start * 1000.0 + depth * 10.0
        dur = width * 1000.0 / (depth + 1)
        events.append({"ph": "X", "name": f"s{i}", "pid": 1, "tid": 1,
                       "ts": ts, "dur": dur})
    # sort and keep only spans that nest (drop crossers) -> must validate
    kept = []
    for ev in sorted(events, key=lambda e: (e["ts"], -e["dur"])):
        end = ev["ts"] + ev["dur"]
        ok = True
        for k in kept:
            kend = k["ts"] + k["dur"]
            if ev["ts"] < kend < end and k["ts"] <= ev["ts"]:
                ok = False                     # would straddle k's end
        if ok:
            kept.append(ev)
    assert check_well_nested(kept) == []
    # now force a genuine crossing pair and expect a violation
    bad = kept + [{"ph": "X", "name": "crosser", "pid": 1, "tid": 1,
                   "ts": kept[0]["ts"] + kept[0]["dur"] / 2,
                   "dur": kept[0]["dur"]}]
    if kept[0]["dur"] > 0:
        assert any("crosses" in v for v in check_well_nested(bad))


# -------------------------------------------------------------------------
# reason taxonomy: every non-"ok" path stamps a non-empty reason
# -------------------------------------------------------------------------
class _StubPipe:
    """Minimal stage-protocol pipeline that errors at one chosen stage."""
    pad_batch = 8
    accepts_deadline = False

    def __init__(self, fail_stage=""):
        self.fail = fail_stage

    def plan(self, queries, topk, nprobe_cap=None, routed=None):
        if self.fail == "plan":
            raise RuntimeError("boom")
        b = len(queries)
        return types.SimpleNamespace(times=StageTimes(size=b),
                                     nprobe=np.full(b, 1, np.int32))

    def prefetch(self, plan):
        if self.fail == "prefetch":
            raise RuntimeError("boom")
        return plan

    def dispatch(self, h):
        if self.fail == "dispatch":
            raise RuntimeError("boom")
        return h

    def harvest(self, h):
        if self.fail == "harvest":
            raise RuntimeError("boom")
        b = h.times.size
        return BatchResult(ids=np.zeros((b, CFG.k), np.int32),
                           dists=np.zeros((b, CFG.k), np.float32),
                           nprobe=h.nprobe, times=h.times)


def _stub_engine(fail_stage, clock=None):
    eng = ServeEngine({"s": _StubPipe(fail_stage)},
                      DynamicBatcher(BatchPolicy(max_batch=8,
                                                 max_wait_s=0.001),
                                     ["s"]),
                      clock=clock or (lambda: 0.0),
                      obs=Observability(sample_rate=1.0))
    return eng


@pytest.mark.parametrize("stage,reason", [
    ("plan", "plan_error"), ("prefetch", "prefetch_error"),
    ("dispatch", "dispatch_error"), ("harvest", "harvest_error"),
])
def test_failed_paths_stamp_stage_reason(stage, reason):
    eng = _stub_engine(stage, clock=time.monotonic)
    eng.start()
    try:
        for _ in range(3):
            assert eng.submit(np.zeros(4, np.float32), CFG.k, index="s",
                              block=True) >= 0
        assert eng.qp.wait_completions(3, timeout=10.0)
    finally:
        eng.stop(drain=True)
    comps = eng.qp.poll()
    assert len(comps) == 3
    assert {c.status for c in comps} == {"failed"}
    assert {c.reason for c in comps} == {reason}
    assert eng.obs.metrics.counter("engine.not_ok").value(reason) == 3


def test_shed_paths_stamp_deadline_and_drain_reasons():
    vt = [0.0]
    eng = _stub_engine("", clock=lambda: vt[0])
    # dead on arrival: deadline already unmeetable -> admission shed
    eng.submit(np.zeros(4, np.float32), CFG.k, index="s", deadline_s=-1.0)
    eng.step(now=0.0)
    shed = [c for c in eng.qp.poll() if c.status == "shed"]
    assert shed and all(c.reason == "deadline" for c in shed)
    # admitted but flushed at shutdown -> drain
    eng.submit(np.zeros(4, np.float32), CFG.k, index="s")
    eng._flush_pending()
    comps = eng.qp.poll()
    assert comps and all(c.status == "shed" and c.reason == "drain"
                         for c in comps)


def test_degraded_and_partial_reasons():
    vt = [0.0]
    eng = _stub_engine("", clock=lambda: vt[0])
    req = types.SimpleNamespace(req_id=1, index="s", arrival=0.0,
                                trace_id=0, deadline=None)
    mb = types.SimpleNamespace(requests=[req],
                               degraded=np.array([True]), index="s")
    times = StageTimes(size=1)
    res = BatchResult(ids=np.zeros((1, CFG.k), np.int32),
                      dists=np.zeros((1, CFG.k), np.float32),
                      nprobe=np.ones(1, np.int32), times=times)
    eng._complete_batch(mb, res, done=1.0)
    c = eng.qp.poll()[0]
    assert c.status == "degraded" and c.reason == "deadline"
    # fabric partial outranks degrade, and carries the fabric's reason
    res2 = BatchResult(ids=np.zeros((1, CFG.k), np.int32),
                       dists=np.zeros((1, CFG.k), np.float32),
                       nprobe=np.ones(1, np.int32), times=StageTimes(size=1),
                       partial=np.array([True]), partial_reason="timeout")
    eng._complete_batch(mb, res2, done=2.0)
    c = eng.qp.poll()[0]
    assert c.status == "partial" and c.reason == "timeout"


# -------------------------------------------------------------------------
# bounded accounting satellites
# -------------------------------------------------------------------------
def test_tier_stats_ring_drop_is_counted():
    st_ = TierStats(max_events=8)
    ev = FetchEvent(gather_start=0.0, gather_end=1.0, stream_end=2.0,
                    rows=1, bytes=64)
    for _ in range(20):
        st_.record(ev)
    assert len(st_.events) <= 8
    assert st_.dropped_events == 12            # 3 evictions x 4 events
    st_.reset()
    assert st_.dropped_events == 0 and not st_.events


def test_update_lane_visibility_streams_into_histograms(small_corpus):
    from repro.lifecycle import LiveFreshState, UpdateLane
    x, _, _ = small_corpus
    vt = [0.0]
    st_ = LiveFreshState(dim=x.shape[1], capacity=4096, n_main=x.shape[0])
    lane = UpdateLane(st_, clock=lambda: vt[0])
    lane._raw_cap = 32                         # tiny raw ring for the test
    for i in range(100):
        lane.submit_insert(np.ones((1, x.shape[1]), np.float32))
    lane.pump(vt[0], budget=0)
    vt[0] = 2.0
    lane.mark_visible(lane.state.seq, vt[0])
    vis = lane.visibility_stats()
    assert vis["n_visible"] == 100 and vis["n_pending"] == 0
    # raw window is bounded; the HISTOGRAM saw every sample
    assert len(lane.visible_log) <= 32
    assert lane._h_vis["insert"].n == 100
    assert vis["insert_to_visible"]["p50_ms"] == pytest.approx(2000.0)
    assert vis["insert_to_visible"]["mean_ms"] == pytest.approx(2000.0)


# -------------------------------------------------------------------------
# trace integrity through the real engine + fabric (seeded kill drill)
# -------------------------------------------------------------------------
def test_kill_drill_trace_integrity(small_index, small_corpus):
    """The satellite's end-to-end property: run the seeded kill-a-shard
    drill at sample_rate=1.0 and assert on the EXPORTED trace —
    (1) well-nested per track, (2) every admitted request has exactly one
    terminal event, (3) trace_ids survive the fabric's requeue path (the
    killed shard's task ids reappear on survivor tasks and reach merge)."""
    _, q, _ = small_corpus
    q = q.astype(np.float32)
    obs = Observability(sample_rate=1.0)
    probe = ShardedFabric(small_index, None, CFG, n_shards=4)
    hot = np.nonzero(probe.rmap0.replicas[:, 0] == 1)[0]
    inj = FaultInjector(seed=7).kill(0.2, shard=1)
    fab = ShardedFabric(small_index, None, CFG, n_shards=4,
                        hot_clusters=hot, injector=inj,
                        hedge_after_s=0.05, tick_s=0.02, obs=obs)
    fab.warmup()
    fab.start()
    eng = ServeEngine({"default": fab},
                      DynamicBatcher(BatchPolicy(max_batch=16,
                                                 max_wait_s=0.004),
                                     ["default"]),
                      obs=obs)
    eng.start()
    try:
        hot_rows = np.nonzero(fab.query_shards(q) == 1)[0]
        trace = shard_skewed_trace(150, 0.8, q.shape[0], hot_rows, seed=3)
        inj.arm(time.monotonic())
        t0 = time.monotonic()
        for a in trace:
            while time.monotonic() - t0 < a.t:
                time.sleep(0.0005)
            assert eng.submit(q[a.qrow], CFG.k) >= 0
    finally:
        eng.stop(drain=True)
        fab.stop()
    assert eng.stats.completed == len(trace)   # the drill itself held up
    assert fab.stats.requeued_tasks >= 1
    doc = obs.trace.export()
    te = doc["traceEvents"]
    # (1) structural validity
    assert check_well_nested(te) == []
    # (2) exactly one terminal per admitted request
    begun, terms = set(), {}
    requeued_tids, merged_tids = set(), set()
    for e in te:
        args = e.get("args") or {}
        if e["ph"] == "b" and e["name"] == "request":
            begun.add(args["trace_id"])
        elif e["ph"] == "i" and e["name"].startswith("done:"):
            t = args["trace_id"]
            terms[t] = terms.get(t, 0) + 1
        elif e["ph"] == "b" and e["name"] == "task" \
                and args.get("kind") == "requeue":
            requeued_tids.update(args["trace_ids"])
        elif e["ph"] == "X" and e["name"] == "merge":
            merged_tids.update(args["trace_ids"])
    assert len(begun) == len(trace)
    assert set(terms) == begun
    assert all(n == 1 for n in terms.values())
    # (3) requeued task trace_ids are real requests that reached merge and
    # terminated ok — identity survived kill -> requeue -> merge
    assert requeued_tids
    assert requeued_tids <= begun
    assert requeued_tids <= merged_tids
    # zero-drop drill => requeued requests still completed ok
    done_ok = {args["trace_id"] for e in te
               if e["ph"] == "i" and e["name"] == "done:ok"
               for args in [e.get("args") or {}]}
    assert requeued_tids <= done_ok
    # per-shard fan-out really traced: scan spans on >= 2 shard tracks
    track_names = {e["tid"]: e["args"]["name"] for e in te
                   if e["ph"] == "M"}
    scan_tracks = {track_names[e["tid"]] for e in te
                   if e["ph"] == "X" and e["name"] == "scan"}
    assert len([t for t in scan_tracks if t.startswith("shard-")]) >= 2


def test_tracing_off_records_nothing_but_metrics_stay_live(small_index):
    tier = TieredPostings(np.asarray(small_index.postings),
                          np.asarray(small_index.posting_ids))
    from repro.runtime import PrefetchPipeline
    pipe = PrefetchPipeline(small_index, None, CFG, tier=tier, pad_batch=8,
                            row_bucket=32)
    eng = ServeEngine({"idx": pipe},
                      DynamicBatcher(BatchPolicy(max_batch=8,
                                                 max_wait_s=0.001),
                                     ["idx"]),
                      clock=lambda: 0.0)      # default obs = off
    q = np.asarray(small_index.centroids)[0].astype(np.float32)
    eng.submit(q, CFG.k, index="idx")
    eng.step(now=0.0)
    comps = eng.qp.poll()
    assert comps and comps[0].trace_id == 0
    assert eng.obs.trace.snapshot() == []
    assert eng.obs.metrics.counter("engine.completions").value("ok") >= 1
