"""Property tests (PR 3 satellite): numpy-oracle parity + invariances for
the two merge/assign primitives every engine path leans on.

* ``merge_candidate_topk`` — checked against a slow per-row numpy dedup
  oracle on random shapes/ids/masks, plus permutation invariance of the
  candidate axis (the merge must not care how shards interleave candidates).
* ``kmeans_assign_update_ref`` — the fused assign kernel's reference oracle,
  checked against a pure-numpy Lloyd step on random shapes/dtypes, plus
  permutation equivariance over points (sums/counts are a set reduction).

Runs through tests/_hypothesis_compat.py, so the whole module skips cleanly
when hypothesis isn't installed.  ``derandomize=True`` keeps the generated
cases a pure function of the test code — no flaky CI from a fresh random
seed finding a tie the assertions don't model.
"""
import numpy as np
import jax.numpy as jnp

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

if HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck

    COMMON = dict(
        max_examples=25, deadline=None, derandomize=True,
        suppress_health_check=[HealthCheck.too_slow],
    )
else:  # the shim's settings() ignores kwargs; keep the call sites uniform
    COMMON = {}


# -------------------------------------------------------------------------
# numpy oracles
# -------------------------------------------------------------------------
def _np_dedup_topk(dists, ids, k):
    """Slow per-row reference: ascending unique-by-id top-k, (inf, -1) pad."""
    b, _ = dists.shape
    out_d = np.full((b, k), np.inf, np.float32)
    out_i = np.full((b, k), -1, np.int64)
    for r in range(b):
        order = np.argsort(dists[r], kind="stable")
        seen = set()
        slot = 0
        for j in order:
            i = int(ids[r, j])
            dv = float(dists[r, j])
            if i < 0 or not np.isfinite(dv) or i in seen:
                continue
            seen.add(i)
            out_d[r, slot] = dv
            out_i[r, slot] = i
            slot += 1
            if slot == k:
                break
    return out_d, out_i


def _np_assign_update(x, cents):
    """Pure-numpy Lloyd E+M step in float64."""
    x64 = x.astype(np.float64)
    c64 = cents.astype(np.float64)
    d = ((x64[:, None, :] - c64[None, :, :]) ** 2).sum(-1)   # (N, K)
    a = np.argmin(d, axis=1)
    md = d[np.arange(x.shape[0]), a]
    k = cents.shape[0]
    sums = np.zeros((k, x.shape[1]), np.float64)
    np.add.at(sums, a, x64)
    counts = np.bincount(a, minlength=k).astype(np.float64)
    return a, md, sums, counts


def _mk_candidates(seed, b, n, id_range, mask_frac):
    """Random candidate rows with UNIQUE finite distances (tie-free, so the
    oracle comparison is exact), random ids incl. duplicates and -1 pads,
    and a masked (inf) fraction."""
    rng = np.random.default_rng(seed)
    base = rng.permutation(b * n).astype(np.float32)         # all distinct
    dists = (base.reshape(b, n) + 1.0) * 0.125
    ids = rng.integers(-1, id_range, size=(b, n)).astype(np.int32)
    masked = rng.random((b, n)) < mask_frac
    dists = np.where(masked, np.inf, dists).astype(np.float32)
    return dists, ids


# -------------------------------------------------------------------------
# merge_candidate_topk
# -------------------------------------------------------------------------
@settings(**COMMON)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 6),
    n=st.integers(1, 40),
    k=st.integers(1, 24),
    id_range=st.integers(1, 30),
    mask_frac=st.floats(0.0, 0.9),
)
def test_merge_candidate_topk_matches_numpy_oracle(seed, b, n, k, id_range,
                                                   mask_frac):
    from repro.core.distance import merge_candidate_topk

    dists, ids = _mk_candidates(seed, b, n, id_range, mask_frac)
    vd, vi = merge_candidate_topk(jnp.asarray(dists), jnp.asarray(ids), k)
    wd, wi = _np_dedup_topk(dists, ids, k)
    np.testing.assert_allclose(np.asarray(vd), wd, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(vi), wi)


@settings(**COMMON)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 5),
    n=st.integers(2, 32),
    k=st.integers(1, 16),
    id_range=st.integers(1, 20),
)
def test_merge_candidate_topk_permutation_invariant(seed, b, n, k, id_range):
    """Shuffling the candidate axis (how shards/probes interleave) must not
    change the merged top-k — distances are unique, so exactly invariant."""
    from repro.core.distance import merge_candidate_topk

    dists, ids = _mk_candidates(seed, b, n, id_range, mask_frac=0.2)
    perm = np.random.default_rng(seed ^ 0x5EED).permutation(n)
    vd0, vi0 = merge_candidate_topk(jnp.asarray(dists), jnp.asarray(ids), k)
    vd1, vi1 = merge_candidate_topk(jnp.asarray(dists[:, perm]),
                                    jnp.asarray(ids[:, perm]), k)
    np.testing.assert_array_equal(np.asarray(vi0), np.asarray(vi1))
    np.testing.assert_allclose(np.asarray(vd0), np.asarray(vd1))


# -------------------------------------------------------------------------
# fused assign/update oracle
# -------------------------------------------------------------------------
@settings(**COMMON)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 120),
    d=st.integers(1, 48),
    k=st.integers(1, 33),
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_assign_oracle_matches_numpy(seed, n, d, k, dtype):
    from repro.kernels.ref import kmeans_assign_update_ref

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    cents = rng.normal(size=(k, d)).astype(np.float32)
    xj = jnp.asarray(x).astype(dtype)
    cj = jnp.asarray(cents).astype(dtype)
    a, md, sums, counts = kmeans_assign_update_ref(xj, cj)
    a = np.asarray(a)
    wa, wmd, wsums, wcounts = _np_assign_update(
        np.asarray(xj, np.float32), np.asarray(cj, np.float32))
    tol = 1e-4 if dtype == "float32" else 5e-2
    # argmin may legitimately differ only where two centroids are
    # numerically tied for a point — both picks must then realize ~the min
    np.testing.assert_allclose(np.asarray(md), wmd, rtol=tol, atol=tol * 10)
    flip = a != wa
    if flip.any():
        from repro.kernels.ref import assign_distances_f64
        gap = np.abs(wmd[flip] - assign_distances_f64(
            np.asarray(xj, np.float32)[flip], np.asarray(cj, np.float32),
            a[flip]))
        assert (gap <= tol * 10 * (1.0 + np.abs(wmd[flip]))).all()
    else:
        np.testing.assert_allclose(np.asarray(sums), wsums,
                                   rtol=tol, atol=tol * 10)
        np.testing.assert_array_equal(
            np.round(np.asarray(counts)).astype(np.int64),
            wcounts.astype(np.int64))
    assert float(np.asarray(counts).sum()) == n     # every point lands once


@settings(**COMMON)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(2, 100),
    d=st.integers(1, 32),
    k=st.integers(1, 20),
)
def test_assign_oracle_permutation_equivariant(seed, n, d, k):
    """Permuting the points permutes the assignments and leaves the set
    reductions (sums, counts) unchanged — chunk/shard order can never change
    a Lloyd step.  The gemm may re-block under a different row order
    (ULP-level distance noise), so the checks are tie-tolerant: an argmin
    flip is accepted only where the two picks realize ~the same min."""
    from repro.kernels.ref import kmeans_assign_update_ref

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    cents = rng.normal(size=(k, d)).astype(np.float32)
    perm = rng.permutation(n)
    a0, md0, s0, c0 = kmeans_assign_update_ref(jnp.asarray(x),
                                               jnp.asarray(cents))
    a1, md1, s1, c1 = kmeans_assign_update_ref(jnp.asarray(x[perm]),
                                               jnp.asarray(cents))
    a0p = np.asarray(a0)[perm]
    a1 = np.asarray(a1)
    np.testing.assert_allclose(np.asarray(md0)[perm], np.asarray(md1),
                               rtol=1e-5, atol=1e-5)
    flip = a0p != a1
    if flip.any():
        # both picks must be numerically tied for those points
        from repro.kernels.ref import assign_distances_f64
        np.testing.assert_allclose(
            assign_distances_f64(x[perm][flip], cents, a0p[flip]),
            assign_distances_f64(x[perm][flip], cents, a1[flip]),
            rtol=1e-4, atol=1e-4)
    else:
        np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                                   rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------------------
# sharded merge (PR 6 fabric): shard-count and shard-order invariance
# -------------------------------------------------------------------------
@settings(**COMMON)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 5),
    n=st.integers(8, 40),
    k=st.integers(1, 12),
    id_range=st.integers(1, 25),
    s=st.integers(1, 8),
)
def test_sharded_merge_invariant_to_shard_count_and_order(seed, b, n, k,
                                                          id_range, s):
    """The fabric's cross-shard reduction: partition the candidate pool
    over S shards, let each shard cut its slice to a local dedup top-k,
    and merge the per-shard sets through merge_candidate_topk — the result
    equals the single-shard (S=1) merge for EVERY shard count and every
    ordering of the shard replies (an id's best instance surviving the
    local cut is exactly the per-shard top-m guarantee the fabric relies
    on)."""
    from repro.core.distance import merge_candidate_topk

    dists, ids = _mk_candidates(seed, b, n, id_range, mask_frac=0.2)
    ref_d, ref_i = merge_candidate_topk(jnp.asarray(dists),
                                        jnp.asarray(ids), k)
    rng = np.random.default_rng(seed ^ 0xFAB)
    owner = rng.integers(0, s, size=n)
    parts = []
    for shard in range(s):
        cols = np.nonzero(owner == shard)[0]
        if cols.size == 0:
            continue          # a shard that owns no probed cluster replies
        pd, pi = _np_dedup_topk(dists[:, cols], ids[:, cols], k)
        parts.append((pd, pi.astype(np.int32)))
    orders = [list(range(len(parts))),
              list(rng.permutation(len(parts)))]
    for order in orders:
        cd = np.concatenate([parts[i][0] for i in order], axis=1)
        ci = np.concatenate([parts[i][1] for i in order], axis=1)
        vd, vi = merge_candidate_topk(jnp.asarray(cd), jnp.asarray(ci), k)
        np.testing.assert_array_equal(np.asarray(vi), np.asarray(ref_i))
        np.testing.assert_allclose(np.asarray(vd), np.asarray(ref_d))


# -------------------------------------------------------------------------
# q8 serving path (PR 8): fused vs legacy vs f32, dead-slot masking
# -------------------------------------------------------------------------
def _mk_q8_corpus(seed, c, l, d, dead_frac):
    """Random quantization-EXACT index: postings = centroid + s * code with
    per-cluster power-of-two s and a pinned |code|=127 slot, so
    quantize_postings recovers (s, codes) bit-exactly and the q8 distance
    equals the f32 distance up to float association.  Dead slots (-1 ids)
    carry adversarial far-away payload — the bugfix under test."""
    rng = np.random.default_rng(seed)
    cents = rng.normal(size=(c, d)).astype(np.float32)
    codes = rng.integers(-127, 128, size=(c, l, d)).astype(np.int32)
    codes[:, 0, 0] = 127                      # pin amax -> scale == s
    s = (2.0 ** rng.integers(-6, -3, size=(c, 1, 1))).astype(np.float32)
    postings = cents[:, None, :] + s * codes.astype(np.float32)
    pids = rng.permutation(c * l).astype(np.int32).reshape(c, l)
    dead = rng.random((c, l)) < dead_frac
    dead[:, 0] = False                        # keep the pinned slot live
    pids[dead] = -1
    postings[dead] = rng.normal(loc=40.0, size=(int(dead.sum()), d)) \
        .astype(np.float32)                   # garbage where ids say "dead"
    queries = rng.normal(size=(3, d)).astype(np.float32)
    p = min(c, 3)
    cids = rng.integers(0, c, size=(3, p)).astype(np.int32)
    mask = rng.random((3, p)) > 0.2
    return cents, postings, pids, dead, queries, cids, mask


@settings(**COMMON)
@given(
    seed=st.integers(0, 2**31 - 1),
    c=st.integers(2, 8),
    l=st.integers(2, 12),
    d=st.integers(2, 10),
    dead_frac=st.floats(0.1, 0.6),
    k=st.integers(1, 8),
)
def test_q8_fused_matches_legacy_and_f32(seed, c, l, d, dead_frac, k):
    """The fused q8 candidate path == the legacy full-materialization path
    == (on quantization-exact data) the f32 scan, through the numpy dedup
    oracle.  One property pins all three serving routes together."""
    from repro.core.distance import merge_candidate_topk
    from repro.core.quantize import ivf_scan_quantized, quantize_postings
    from repro.core.search import _auto_ncand
    from repro.kernels.ref import ivf_scan_q8_topk_ref

    cents, postings, pids, dead, q, cids, mask = _mk_q8_corpus(
        seed, c, l, d, dead_frac)
    qp = quantize_postings(jnp.asarray(postings), jnp.asarray(cents),
                           jnp.asarray(pids))
    # fused candidate path
    cd, ci = ivf_scan_q8_topk_ref(
        qp.q8, qp.scale, qp.norm2, jnp.asarray(cents), jnp.asarray(pids),
        jnp.asarray(cids), jnp.asarray(mask), jnp.asarray(q),
        _auto_ncand(k))
    fd, fi = merge_candidate_topk(cd, ci, k)
    # legacy full-materialization path -> numpy oracle top-k
    full = np.asarray(ivf_scan_quantized(
        qp, jnp.asarray(cents), jnp.asarray(cids), jnp.asarray(mask),
        jnp.asarray(q)))
    gids = pids[cids]                                    # (B, P, L)
    full = np.where(gids < 0, np.inf, full)
    ld, li = _np_dedup_topk(full.reshape(3, -1), gids.reshape(3, -1), k)
    np.testing.assert_array_equal(np.asarray(fi), li)
    np.testing.assert_allclose(np.asarray(fd), ld, rtol=1e-5, atol=1e-5)
    # f32 ground truth on the same probes (quantization-exact corpus):
    # garbage payload sits only in dead slots, which the id mask drops
    f32 = np.full_like(full, np.inf)
    live_probe = mask[:, :, None] & (gids >= 0)
    diff = q[:, None, None, :] - postings[cids]          # (B, P, L, D)
    f32 = np.where(live_probe, (diff ** 2).sum(-1), np.inf)
    wd, wi = _np_dedup_topk(f32.reshape(3, -1), gids.reshape(3, -1), k)
    np.testing.assert_array_equal(np.asarray(fi), wi)
    np.testing.assert_allclose(np.asarray(fd), wd, rtol=1e-3, atol=1e-3)


@settings(**COMMON)
@given(
    seed=st.integers(0, 2**31 - 1),
    c=st.integers(2, 8),
    l=st.integers(2, 12),
    d=st.integers(2, 10),
    dead_frac=st.floats(0.1, 0.6),
)
def test_q8_dead_slot_payload_cannot_leak(seed, c, l, d, dead_frac):
    """Exact invariance: ANY payload in dead slots produces bit-identical
    quantized tensors when the id mask is passed — the scale, codes, and
    norms of a poisoned index equal those of the zeroed-padding index."""
    from repro.core.quantize import quantize_postings

    cents, postings, pids, dead, *_ = _mk_q8_corpus(seed, c, l, d, dead_frac)
    clean = postings.copy()
    clean[dead] = 0.0
    qa = quantize_postings(jnp.asarray(postings), jnp.asarray(cents),
                           jnp.asarray(pids))
    qb = quantize_postings(jnp.asarray(clean), jnp.asarray(cents),
                           jnp.asarray(pids))
    np.testing.assert_array_equal(np.asarray(qa.scale), np.asarray(qb.scale))
    np.testing.assert_array_equal(np.asarray(qa.q8), np.asarray(qb.q8))
    np.testing.assert_array_equal(np.asarray(qa.norm2), np.asarray(qb.norm2))


@settings(**COMMON)
@given(
    seed=st.integers(0, 2**31 - 1),
    c=st.integers(3, 8),
    l=st.integers(2, 12),
    d=st.integers(2, 10),
    k=st.integers(1, 6),
)
def test_q8_fused_probe_permutation_invariant(seed, c, l, d, k):
    """Permuting the probe axis (cids and mask together) must not change
    the fused q8 top-k — shard/probe interleaving cannot alter results."""
    from repro.core.distance import merge_candidate_topk
    from repro.core.quantize import quantize_postings
    from repro.core.search import _auto_ncand
    from repro.kernels.ref import ivf_scan_q8_topk_ref

    cents, postings, pids, _, q, cids, mask = _mk_q8_corpus(
        seed, c, l, d, 0.3)
    qp = quantize_postings(jnp.asarray(postings), jnp.asarray(cents),
                           jnp.asarray(pids))

    def fused(cp, mp):
        cd, ci = ivf_scan_q8_topk_ref(
            qp.q8, qp.scale, qp.norm2, jnp.asarray(cents),
            jnp.asarray(pids), jnp.asarray(cp), jnp.asarray(mp),
            jnp.asarray(q), _auto_ncand(k))
        return merge_candidate_topk(cd, ci, k)

    perm = np.random.default_rng(seed ^ 0xBEEF).permutation(cids.shape[1])
    d0, i0 = fused(cids, mask)
    d1, i1 = fused(cids[:, perm], mask[:, perm])
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1))
