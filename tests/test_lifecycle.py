"""Index lifecycle runtime tests: update lane, freshness merge in the
serving pipeline, epoch swap protocol, delta-aware rebuilds.

Engine tests drive ``ServeEngine.step`` synchronously (virtual clock) so
every pump/route/merge decision is deterministic; the one threaded test
pins the live rebuild+swap contract end to end.
"""
import dataclasses as dc
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.search import SearchConfig
from repro.lifecycle import (
    CorpusStore,
    LiveFreshState,
    RebuildPolicy,
    RebuildScheduler,
    UpdateLane,
    VersionManager,
    delta_build,
    load_manifest,
)
from repro.runtime import BatchPolicy, DynamicBatcher, PrefetchPipeline, ServeEngine
from repro.storage import TieredPostings

CFG = SearchConfig(k=5, nprobe_max=8, pruning="none", use_kernel=False,
                   fused_topk=True)


def _mk_state(small_corpus, capacity=64):
    x, _, _ = small_corpus
    return LiveFreshState(dim=x.shape[1], capacity=capacity,
                          n_main=x.shape[0]), x


def _mk_pipe(small_index, state, **kw):
    tier = TieredPostings(np.asarray(small_index.postings),
                          np.asarray(small_index.posting_ids))
    return PrefetchPipeline(small_index, None, CFG, tier=tier, pad_batch=8,
                            row_bucket=32, fresh_source=state.snapshot, **kw)


def _mk_engine(pipe, state, clock=None):
    lane = UpdateLane(state, clock=clock or (lambda: 0.0))
    policy = BatchPolicy(max_batch=16, max_wait_s=0.001, pad=8,
                         update_quantum=4)
    batcher = DynamicBatcher(policy, ["idx"])
    eng = ServeEngine({"idx": pipe}, batcher, clock=clock or (lambda: 0.0),
                      update_lanes={"idx": lane})
    return eng, lane


# -------------------------------------------------------------------------
# LiveFreshState
# -------------------------------------------------------------------------
def test_state_mints_sequential_global_ids(small_corpus):
    st, x = _mk_state(small_corpus)
    n = x.shape[0]
    ids = st.insert(np.zeros((3, x.shape[1])))
    assert ids.tolist() == [n, n + 1, n + 2]
    with pytest.raises(BufferError):
        st.insert(np.zeros((st.capacity, x.shape[1])))
    assert st.fill == 3 and st.next_id == n + 3


def test_state_publish_is_monotonic_and_immutable(small_corpus):
    st, x = _mk_state(small_corpus)
    s0 = st.snapshot()
    st.insert(np.ones((1, x.shape[1])))
    assert st.snapshot() is s0            # not visible until publish
    st.publish()
    s1 = st.snapshot()
    assert s1.seq > s0.seq and s1.fill == 1
    assert int(np.asarray(s0.delta_ids[0])) == -1   # old snapshot frozen


def test_state_delete_ignores_unminted_ids(small_corpus):
    st, x = _mk_state(small_corpus)
    n = x.shape[0]
    assert st.delete(np.asarray([0, 5, n + 50])) == 2   # future id ignored
    assert st.delete(np.asarray([5])) == 0              # already dead
    assert st.n_tombstoned == 2
    assert 0 < st.tombstone_frac < 1


# -------------------------------------------------------------------------
# update lane through the engine: interleave, visibility, backpressure
# -------------------------------------------------------------------------
def test_inserts_become_visible_through_search(small_corpus, small_index):
    st, x = _mk_state(small_corpus)
    pipe = _mk_pipe(small_index, st)
    eng, lane = _mk_engine(pipe, st)
    far = np.full((2, x.shape[1]), 7.5, np.float32)     # away from the data
    rid = lane.submit_insert(far)
    assert rid > 0
    for i in range(4):
        eng.submit(far[0], 5, index="idx")
    eng.step(now=1.0)
    comps = eng.qp.poll()
    assert len(comps) == 4
    n = x.shape[0]
    for c in comps:
        assert c.ids[0] == n                  # nearest = the inserted vector
    vis = lane.visibility_stats()
    assert vis["n_visible"] == 1 and vis["n_pending"] == 0
    # stamped, not inferred: the interval is harvest_time - submit_time
    _, op, dt = lane.visible_log[0]
    assert op == "insert" and dt == 1.0 - 0.0


def test_tombstoned_main_and_delta_ids_filtered(small_corpus, small_index):
    st, x = _mk_state(small_corpus)
    pipe = _mk_pipe(small_index, st)
    eng, lane = _mk_engine(pipe, st)
    n = x.shape[0]
    far = np.full((2, x.shape[1]), 7.5, np.float32)
    lane.submit_insert(far)                   # ids n, n+1
    eng.submit(far[0], 5, index="idx")
    eng.step(now=0.5)
    (c0,) = eng.qp.poll()
    assert c0.ids[0] == n and n + 1 in c0.ids.tolist()
    victim_main = int(c0.ids[2])              # best main-index hit
    lane.submit_delete(np.asarray([n, victim_main]))
    eng.submit(far[0], 5, index="idx")
    eng.step(now=1.0)
    (c1,) = eng.qp.poll()
    ids1 = c1.ids.tolist()
    assert n not in ids1                      # tombstoned DELTA id filtered
    assert victim_main not in ids1            # tombstoned MAIN id filtered
    assert n + 1 == ids1[0]                   # surviving delta id promoted


def test_update_storm_cannot_starve_search(small_corpus, small_index):
    """update_quantum bounds per-cycle update work: with a storm of queued
    ops, each step still serves its search batch while the storm drains a
    quantum at a time."""
    st, x = _mk_state(small_corpus, capacity=512)
    pipe = _mk_pipe(small_index, st)
    eng, lane = _mk_engine(pipe, st)
    for _ in range(40):                       # 40 single-vector inserts
        lane.submit_insert(np.zeros((1, x.shape[1])))
    served = 0
    for i in range(5):
        eng.submit(x[i], 5, index="idx")
        served += eng.step(now=float(i))
    assert served == 5                        # search never starved
    q = lane.stats
    assert q.applied_inserts == 4 * 5         # quantum=4 per step, 5 steps
    assert lane.qp.sq_len() == 20             # storm still draining


def test_full_buffer_rejects_with_rebuild_due(small_corpus, small_index):
    st, x = _mk_state(small_corpus, capacity=4)
    pipe = _mk_pipe(small_index, st)
    eng, lane = _mk_engine(pipe, st)
    lane.submit_insert(np.zeros((3, x.shape[1])))
    lane.submit_insert(np.zeros((2, x.shape[1])))     # overflows capacity 4
    eng.step(now=0.0)
    comps = lane.qp.poll()
    assert [c.status for c in comps] == ["ok", "rebuild_due"]
    assert lane.stats.rejected_full == 1
    assert st.fill == 3                       # partial batch never applied


def test_update_lane_deadline_admission_and_covered_deletes(small_corpus):
    """The update lane mirrors the search lane's admission control: ops the
    poller reaches past their deadline are shed (not applied stale), and a
    delete whose ids are all already tombstoned is dropped as covered."""
    st, x = _mk_state(small_corpus)
    d = x.shape[1]
    vt = [0.0]
    lane = UpdateLane(st, clock=lambda: vt[0])
    # 1) expired at pump time -> shed, nothing applied, nothing published
    lane.submit_insert(np.ones((1, d)), deadline_s=0.005)
    vt[0] = 0.02
    assert lane.pump(vt[0]) == 0
    c = lane.qp.poll()[0]
    assert c.status == "shed" and lane.stats.shed_deadline == 1
    assert st.fill == 0 and lane.stats.publishes == 0
    # 2) in-deadline op applies normally
    lane.submit_insert(np.ones((2, d)), deadline_s=0.05)
    assert lane.pump(vt[0]) == 1
    ok = lane.qp.poll()[0]
    assert ok.status == "ok" and st.fill == 2
    # 3) first delete applies; an identical one is covered by the newer
    #    tombstone: dropped without a publish
    lane.submit_delete(ok.ids)
    lane.pump(vt[0])
    assert lane.qp.poll()[0].status == "ok"
    pubs = lane.stats.publishes
    lane.submit_delete(ok.ids)
    lane.pump(vt[0])
    c2 = lane.qp.poll()[0]
    assert c2.status == "covered"
    assert lane.stats.covered_deletes == 1
    assert lane.stats.publishes == pubs       # no-op saved the device_put
    # 4) a PARTIALLY covered delete still applies (one id newly dead)
    ids2 = st.insert(np.ones((1, d)))
    st.publish()
    lane.submit_delete(np.concatenate([ok.ids[:1], ids2]))
    lane.pump(vt[0])
    assert lane.qp.poll()[0].status == "ok"
    assert st.n_tombstoned == 3


def test_update_deadline_shed_counted_under_storm(small_corpus, small_index):
    """Deadline admission composes with the quantum drain: ops that expire
    while queued behind a storm are shed when the poller reaches them,
    and the shed shows up in stats, not as a late apply."""
    st, x = _mk_state(small_corpus, capacity=512)
    vt = [0.0]
    pipe = _mk_pipe(small_index, st)
    lane = UpdateLane(st, clock=lambda: vt[0])
    policy = BatchPolicy(max_batch=16, max_wait_s=0.001, pad=8,
                         update_quantum=4)
    batcher = DynamicBatcher(policy, ["idx"])
    eng = ServeEngine({"idx": pipe}, batcher, clock=lambda: vt[0],
                      update_lanes={"idx": lane})
    for _ in range(12):
        lane.submit_insert(np.zeros((1, x.shape[1])), deadline_s=0.5)
    # first quantum lands in time; then the clock jumps past the deadline
    eng.submit(x[0], 5, index="idx")
    eng.step(now=0.0)
    vt[0] = 1.0
    eng.submit(x[1], 5, index="idx")
    eng.step(now=1.0)
    eng.submit(x[2], 5, index="idx")
    eng.step(now=1.0)
    assert lane.stats.applied_inserts == 4    # the in-deadline quantum
    assert lane.stats.shed_deadline == 8      # the stale remainder
    assert st.fill == 4


# -------------------------------------------------------------------------
# epoch swap protocol
# -------------------------------------------------------------------------
def test_epoch_retires_only_after_last_inflight_harvest(small_corpus,
                                                        small_index):
    st, _ = _mk_state(small_corpus)
    pipe_a = _mk_pipe(small_index, st)
    pipe_b = _mk_pipe(small_index, st)
    vm = VersionManager(clock=lambda: 0.0)
    ep_a = vm.deploy("idx", pipe_a, fresh=st)
    held = vm.route("idx")                    # an in-flight batch
    assert held is ep_a and ep_a.inflight == 1
    old, new = vm.swap("idx", pipe_b, fresh=st)
    assert old is ep_a and old.retired
    assert not old.finalized.is_set()         # batch still in flight
    assert not pipe_a.tier.released
    assert vm.route("idx") is new             # new batches -> new epoch
    new.release()
    vm.harvested(held)                        # last old batch harvests
    assert old.finalized.is_set()
    assert pipe_a.tier.released               # tier freed at retirement
    with pytest.raises(RuntimeError):
        pipe_a.tier.fetch(np.zeros((1, 2), np.int32))
    assert not pipe_b.tier.released


def test_engine_routes_through_version_manager(small_corpus, small_index):
    st, x = _mk_state(small_corpus)
    pipe = _mk_pipe(small_index, st)
    eng, lane = _mk_engine(pipe, st)
    vm = VersionManager(clock=lambda: 0.0)
    vm.deploy("idx", pipe, fresh=st)
    vm.bind(eng)
    for i in range(3):
        eng.submit(x[i], 5, index="idx")
    assert eng.step(now=0.0) == 3
    ep = vm.current("idx")
    assert ep.record.batches == 1 and ep.inflight == 0


# -------------------------------------------------------------------------
# delta-aware rebuild
# -------------------------------------------------------------------------
@pytest.fixture(scope="module")
def built(small_corpus, tmp_path_factory):
    from repro.build.kmeans import balanced_hierarchical_kmeans

    x, _, _ = small_corpus
    wd = str(tmp_path_factory.mktemp("lifecycle_build"))
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=48, iters=8)
    corpus = CorpusStore(x)
    index, stats = delta_build(corpus.view(), cents, wd, cluster_len=64,
                               eps=0.2, max_replicas=4, per_task=1000)
    return corpus, cents, wd, index, stats


def test_delta_build_reuses_clean_shards(built, small_corpus, rng):
    corpus, cents, wd, index0, stats0 = built
    x, _, _ = small_corpus
    assert stats0["shards_reused"] == 0       # cold build streams everything
    assert stats0["bytes_streamed"] == stats0["full_stream_bytes"]
    assert load_manifest(wd) is not None
    # append one shard's worth of new rows; old shards must hold
    new = rng.normal(size=(120, x.shape[1])).astype(np.float32)
    corpus.append(new)
    index1, stats1 = delta_build(corpus.view(), cents, wd, cluster_len=64,
                                 eps=0.2, max_replicas=4, per_task=1000)
    assert stats1["shards_streamed"] == 1     # only the new trailing shard
    assert stats1["shards_reused"] == stats0["shards_total"]
    assert stats1["shards_total"] == stats0["shards_total"] + 1
    assert stats1["bytes_streamed"] * 2 <= stats1["full_stream_bytes"]
    # the reuse is exact: a forced full restream builds the same index
    from repro.build.pipeline import index_content_hash

    index_full, stats_full = delta_build(
        corpus.view(), cents, wd, cluster_len=64, eps=0.2, max_replicas=4,
        per_task=1000, use_manifest=False)
    assert stats_full["shards_reused"] == 0
    assert index_content_hash(index1) == index_content_hash(index_full)


def test_delta_build_folds_tombstones(built):
    corpus, cents, wd, index0, _ = built
    tomb = np.zeros((corpus.n,), bool)
    dead = np.asarray([0, 1, 2, 50, 51])
    tomb[dead] = True
    index, stats = delta_build(corpus.view(), cents, wd, cluster_len=64,
                               eps=0.2, max_replicas=4, per_task=1000,
                               tombstone=tomb)
    assert stats["folded_deletes"] == len(dead)
    pids = np.asarray(index.posting_ids)
    assert not np.isin(pids[pids >= 0], dead).any()
    # masking at the posting build does NOT dirty the shards
    assert stats["shards_streamed"] == 0


def test_live_rebuild_swap_zero_dropped(small_corpus, small_index,
                                        tmp_path, rng):
    """The acceptance flow in miniature, threaded: searches + updates live,
    a delta rebuild triggers on fill, swaps atomically; every admitted
    request completes, inserted ids stay findable across the swap."""
    import time

    from repro.build.kmeans import balanced_hierarchical_kmeans

    x, q, _ = small_corpus
    wd = str(tmp_path)
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=48, iters=8)
    corpus = CorpusStore(x)
    index, _ = delta_build(corpus.view(), cents, wd, cluster_len=64,
                           eps=0.2, max_replicas=4, per_task=1000)
    st = LiveFreshState(dim=x.shape[1], capacity=64, n_main=corpus.n)
    lane = UpdateLane(st)

    def mk(index, state):
        tier = TieredPostings(np.asarray(index.postings),
                              np.asarray(index.posting_ids))
        p = PrefetchPipeline(index, None, CFG, tier=tier, pad_batch=8,
                             row_bucket=32, fresh_source=state.snapshot)
        p.warmup(batch_sizes=(8,))
        return p

    pipe = mk(index, st)
    vm = VersionManager()
    ep0 = vm.deploy("idx", pipe, fresh=st)
    batcher = DynamicBatcher(
        BatchPolicy(max_batch=16, max_wait_s=0.002, pad=8), ["idx"])
    eng = ServeEngine({"idx": pipe}, batcher, update_lanes={"idx": lane})
    vm.bind(eng)
    sched = RebuildScheduler(
        name="idx", corpus=corpus, centroids=cents, workdir=wd, lane=lane,
        versions=vm, make_pipeline=mk, cluster_len=64,
        policy=RebuildPolicy(delta_fill_frac=0.5, per_task=1000))
    eng.start()
    try:
        far = rng.normal(loc=6.0, size=(40, x.shape[1])).astype(np.float32)
        lane.submit_insert(far)               # 40/64 -> over the threshold
        for i in range(32):
            eng.submit(q[i], 5, index="idx")
        deadline = time.monotonic() + 10
        while sched.due() is None and time.monotonic() < deadline:
            time.sleep(0.01)
        assert sched.due() == "delta_fill"
        rep = sched.rebuild_and_swap(trigger="test")
        assert rep.folded_inserts == 40 and rep.shards_reused >= 4
        assert rep.bytes_streamed * 2 <= rep.full_stream_bytes
        # inserted ids survive the swap (now in the main index)
        want = {}
        for i in range(8):
            rid = eng.submit(far[i], 5, index="idx")
            want[rid] = x.shape[0] + i
    finally:
        eng.stop(drain=True)
    assert ep0.finalized.wait(5)              # old epoch fully drained
    comps = eng.qp.poll()
    hits = [c for c in comps
            if c.req_id in want and want[c.req_id] in c.ids.tolist()]
    assert len(hits) == 8
    st_e = eng.stats
    assert st_e.completed == st_e.submitted   # zero dropped across the swap
    assert vm.history[0].finalized_at > 0
