"""Per-arch LM smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (deliverable f),
plus decode==forward consistency for the hybrid family."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get
from repro.launch.train import scaled_lm_config
from repro.models.lm import (
    init_cache, init_params, decode_step, make_train_step, prefill_step,
)
from repro.models.lm.transformer import forward, param_shapes, param_specs
from repro.optim import adamw

LM_ARCHS = ["gemma3_12b", "phi4_mini", "gemma3_27b", "llama4_scout", "qwen2_moe"]


@pytest.fixture(scope="module", params=LM_ARCHS)
def reduced(request):
    arch = get(request.param)
    cfg = scaled_lm_config(arch.config, 0.02)
    cfg = dataclasses.replace(cfg, q_chunk=16)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return request.param, cfg, params


def test_train_step_shapes_and_finite(reduced):
    name, cfg, params = reduced
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0, cfg.vocab)
    step = jax.jit(make_train_step(cfg))
    opt = adamw.init(params)
    p2, opt2, m = step(params, opt, toks)
    assert np.isfinite(float(m["loss"])), name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert bool(jnp.isfinite(b.astype(jnp.float32)).all()), name


def test_loss_decreases(reduced):
    name, cfg, params = reduced
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 33), 0, cfg.vocab)
    step = jax.jit(make_train_step(cfg))
    opt = adamw.init(params)
    first = None
    for _ in range(4):
        params, opt, m = step(params, opt, toks)
        first = first or float(m["loss"])
    assert float(m["loss"]) < first, name


def test_decode_matches_forward(reduced):
    name, cfg, params = reduced
    if cfg.moe is not None:
        # capacity drops are batch-size-dependent by design; give both paths
        # ample capacity so routing matches exactly
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
        params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    cache = init_cache(cfg, b, s)
    outs = []
    dec = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg))
    for t in range(s):
        logits, cache = dec(params, cache, toks[:, t], jnp.int32(t))
        outs.append(logits)
    h = forward(params, toks, cfg)
    oracle = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    err = max(float(jnp.abs(outs[t] - oracle[:, t].astype(jnp.float32)).max())
              for t in range(s))
    scale = float(jnp.abs(oracle).max()) + 1e-6
    tol = 2e-3 if cfg.dtype == jnp.float32 else 5e-2
    assert err / scale < tol, (name, err, scale)


def test_prefill_matches_forward(reduced):
    name, cfg, params = reduced
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg.vocab)
    logits, cache = prefill_step(params, toks, cfg)
    h = forward(params, toks, cfg)
    oracle = jnp.einsum("bd,vd->bv", h[:, -1], params["embed"].astype(h.dtype))
    rel = float(jnp.abs(logits - oracle.astype(jnp.float32)).max()) / (
        float(jnp.abs(oracle).max()) + 1e-6)
    assert rel < 2e-3, (name, rel)
    assert logits.shape == (2, cfg.vocab)


def test_param_specs_cover_shapes():
    """Every arch's param tree and spec tree are congruent, and sharded dims
    divide on the production model axis (16)."""
    for name in LM_ARCHS:
        cfg = get(name).config
        shapes = param_shapes(cfg)
        specs = param_specs(cfg, tp=16)
        flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
        flat_p = {tuple(str(k) for k in path): sp
                  for path, sp in jax.tree_util.tree_flatten_with_path(
                      specs, is_leaf=lambda x: isinstance(
                          x, jax.sharding.PartitionSpec))[0]}
        for path, leaf in flat_s:
            key = tuple(str(k) for k in path)
            assert key in flat_p, (name, key)
            sp = tuple(flat_p[key])
            for i, ax in enumerate(sp):
                if ax is None:
                    continue
                n = 16 if ax == "model" else 16
                assert leaf.shape[i] % n == 0, (name, key, leaf.shape, sp)


def test_moe_capacity_drop_keeps_residual():
    """Tokens dropped by capacity must still flow through residual+shared."""
    from repro.models.lm import LMConfig, MoEConfig
    moe = MoEConfig(n_experts=4, top_k=1, d_ff_expert=16, n_shared=1,
                    d_ff_shared=16, capacity_factor=0.26)  # tiny capacity
    cfg = LMConfig("t", n_layers=1, d_model=16, n_heads=2, n_kv=1, d_ff=0,
                   vocab=32, moe=moe, dtype=jnp.float32, q_chunk=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, 32)
    h = forward(params, toks, cfg)
    assert bool(jnp.isfinite(h).all())
