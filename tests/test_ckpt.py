"""Checkpoint store: roundtrip, atomicity, GC, bit-exact resume."""
import json
import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 3)),
            "opt": [jnp.arange(5), {"m": jnp.ones((2, 2), jnp.bfloat16)}]}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, 3, str(tmp_path), extra={"cursor": 7})
    t2, step, extra = ckpt.restore(t, str(tmp_path))
    assert step == 3 and extra["cursor"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_gc_keeps_last_k(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(t, s, str(tmp_path), keep=3)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 3 and dirs[-1] == "step_00000005"


def test_restore_ignores_stale_tmp(tmp_path):
    t = _tree()
    ckpt.save(t, 1, str(tmp_path))
    # a crashed writer leaves a .tmp dir and a half-written dir w/o manifest
    os.makedirs(tmp_path / "step_00000002.tmp")
    os.makedirs(tmp_path / "step_00000003")
    assert ckpt.latest_step(str(tmp_path)) == 1
    _, step, _ = ckpt.restore(t, str(tmp_path))
    assert step == 1


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(_tree(), 1, str(tmp_path))
    bad = {"w": jnp.zeros((5, 3)),
           "opt": [jnp.arange(5), {"m": jnp.ones((2, 2), jnp.bfloat16)}]}
    with pytest.raises(ValueError):
        ckpt.restore(bad, str(tmp_path))


def test_train_resume_bit_exact(tmp_path):
    """Crash at step 6, resume from the step-5 checkpoint: identical final
    params to an uninterrupted run."""
    from repro.models.lm import LMConfig, init_params, make_train_step
    from repro.optim import adamw

    cfg = LMConfig("t", n_layers=2, d_model=16, n_heads=2, n_kv=1, d_ff=32,
                   vocab=64, dtype=jnp.float32, q_chunk=8)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, 64)
    step_fn = jax.jit(make_train_step(cfg))

    def run(n_steps, params, opt, start=0, save_at=None, root=None):
        for s in range(start, n_steps):
            params, opt, _ = step_fn(params, opt, toks)
            if save_at is not None and s + 1 == save_at:
                ckpt.save((params, opt), s + 1, root)
        return params, opt

    p0 = init_params(cfg, jax.random.PRNGKey(0))
    o0 = adamw.init(p0)
    ref_p, _ = run(10, p0, o0)

    root = str(tmp_path / "ck")
    p1, o1 = run(5, p0, o0, save_at=5, root=root)
    # "crash": throw away state, restore, continue
    (p2, o2), step, _ = ckpt.restore((p0, o0), root)
    assert step == 5
    p2, _ = run(10, p2, o2, start=5)
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
