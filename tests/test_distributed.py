"""Distributed utilities: compression, fault machinery, hlo analysis."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.distributed import HeartbeatMonitor
from repro.distributed.collectives import dequantize_int8, quantize_int8


def test_quantize_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_compressed_psum_single_participant_with_error_feedback():
    """On a 1-axis mesh of size 1, compressed_psum must reproduce the value
    up to quantization, and the EF buffer must carry the residual."""
    from repro.distributed.collectives import compressed_psum
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.asarray(np.linspace(-1, 1, 64, dtype=np.float32))

    def f(x):
        out, err = compressed_psum(x, "data")
        out2, err2 = compressed_psum(x, "data", err)
        return out, err, out2

    out, err, out2 = jax.shard_map(
        f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec(), check_vma=False)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-2)
    # EF: two applications reconstruct the value better on average
    e1 = np.abs(np.asarray(out) - np.asarray(x)).mean()
    e2 = np.abs((np.asarray(out) + np.asarray(out2)) / 2 - np.asarray(x)).mean()
    assert e2 <= e1 + 1e-6


def test_heartbeat_failure_and_stragglers():
    hb = HeartbeatMonitor(4, miss_threshold=2, slow_factor=2.0)
    for t in range(4):
        hb.tick()
        for n in range(3):  # node 3 never beats
            hb.beat(n, latency=10.0 if n == 2 else 1.0)
    assert 3 in hb.failed()
    assert 2 in hb.stragglers()
    assert 0 not in hb.failed() and 1 not in hb.stragglers()


def test_hlo_analysis_scan_matches_unroll():
    from repro.launch.hlo_analysis import analyze

    def make(unroll):
        def f(x, w):
            def body(x, wi):
                return jnp.tanh(x @ wi), None
            x, _ = jax.lax.scan(body, x, w, unroll=8 if unroll else 1)
            return x
        return f

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    tots = []
    for unroll in (False, True):
        c = jax.jit(make(unroll)).lower(x, w).compile()
        tots.append(analyze(c.as_text()))
    assert tots[0].n_while == 1 and tots[1].n_while == 0
    assert abs(tots[0].flops - tots[1].flops) / tots[1].flops < 0.02
    want = 8 * 2 * 64 * 128 * 128
    assert abs(tots[1].flops - want) / want < 0.05


def test_hlo_analysis_counts_collectives_in_loops():
    from repro.launch.hlo_analysis import analyze
    mesh = jax.make_mesh((1,), ("data",))

    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "data") * 0.5, None
        out, _ = jax.lax.scan(body, x, None, length=6)
        return out

    from jax.sharding import PartitionSpec as P
    g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                              check_vma=False))
    c = g.lower(jax.ShapeDtypeStruct((32,), jnp.float32)).compile()
    t = analyze(c.as_text())
    # 6 iterations x 32 floats x 4 bytes x 2 (ring factor) — if the backend
    # didn't elide the trivial 1-party reduce
    total = sum(t.coll.values())
    ops = sum(t.coll_ops.values())
    if ops:
        assert total >= 6 * 32 * 4
