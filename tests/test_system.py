"""End-to-end behaviour of the paper's system (replaces the scaffold stub).

Covers the full Helmsman story at container scale: build a clustered index
over a realistic (clustered) corpus, train LLSP from logged queries, serve
with all three pruning modes, and check the paper's qualitative claims:

  * clustering-based search reaches the recall target with small nprobe
    (the premise of §3.3);
  * LLSP spends fewer probes than no-pruning at comparable recall (§5.4);
  * per-query recall is more stable than fixed-eps (§5.4, Fig. 20);
  * serving survives a posting-shard failure via replicas (§6.2).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.search import SearchConfig, serve_step


@pytest.fixture(scope="module")
def system(tmp_path_factory, small_corpus):
    from repro.build.pipeline import BuildConfig, build_index
    from repro.core.llsp import LLSPConfig
    x, q, topk = small_corpus
    wd = str(tmp_path_factory.mktemp("sys"))
    cfg = BuildConfig(max_cluster_size=48, cluster_len=64,
                      coarse_per_task=1000, n_workers=2,
                      llsp=LLSPConfig(levels=(4, 8, 16, 32), n_trees=25,
                                      max_depth=4, n_ratio_features=8))
    idx, llsp, _ = build_index(x, cfg, wd, queries=q,
                               query_topk=np.minimum(topk, 20).astype(np.int32))
    qj = jnp.asarray(q)
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    return idx, llsp, qj, np.asarray(ti)


def _run(idx, llsp, qj, mode, **kw):
    cfg = SearchConfig(k=10, nprobe_max=32, pruning=mode, use_kernel=False,
                       n_ratio=8, **kw)
    return serve_step(idx, llsp, qj, jnp.full((qj.shape[0],), 10, jnp.int32), cfg)


def test_clustering_premise(system):
    idx, llsp, qj, ti = system
    out = _run(idx, None, qj, "none")
    r = recall_at_k(out["ids"], ti)
    assert r >= 0.9, f"non-pruned recall {r}"


def test_llsp_probe_savings(system):
    idx, llsp, qj, ti = system
    out_all = _run(idx, None, qj, "none")
    out_llsp = _run(idx, llsp, qj, "llsp")
    r_all = recall_at_k(out_all["ids"], ti)
    r_llsp = recall_at_k(out_llsp["ids"], ti)
    mean_probe = float(np.asarray(out_llsp["nprobe"]).mean())
    assert mean_probe < 32
    assert r_llsp >= r_all - 0.08, (r_llsp, r_all, mean_probe)


def test_llsp_stability_vs_fixed(system):
    idx, llsp, qj, ti = system
    out_llsp = _run(idx, llsp, qj, "llsp")
    probes_llsp = float(np.asarray(out_llsp["nprobe"]).mean())

    def frac_ok(out):
        ids = np.asarray(out["ids"])
        per = [(len(set(ids[i].tolist()) & set(ti[i].tolist())) / 10)
               for i in range(ids.shape[0])]
        return float(np.mean(np.asarray(per) >= 0.9))

    best_fixed = 0.0
    for eps in (0.05, 0.1, 0.2, 0.4):
        out_f = _run(idx, None, qj, "fixed", eps=eps)
        if float(np.asarray(out_f["nprobe"]).mean()) <= probes_llsp + 1:
            best_fixed = max(best_fixed, frac_ok(out_f))
    assert frac_ok(out_llsp) >= best_fixed - 0.05


def test_shard_failure_failover(system):
    """Losing one posting shard only loses that shard's un-replicated
    clusters; replicated (hot) clusters keep serving."""
    import numpy as np
    from repro.storage import make_replica_map, plan_striping
    from repro.distributed import ownership_mask, plan_failover

    idx = system[0]
    C = idx.n_clusters
    n_shards = 8
    st = plan_striping(C, n_shards)
    hot = np.arange(C)[::2]          # replicate every other cluster
    rm = make_replica_map(C, n_shards, st, hot_clusters=hot, n_replicas=2)
    plan = plan_failover(rm, [2])
    mask = ownership_mask(plan.owner, n_shards)
    # every non-lost cluster has exactly one live owner, none on shard 2
    assert mask[2].sum() == 0
    alive = np.setdiff1d(np.arange(C), plan.lost)
    assert (mask[:, alive].sum(axis=0) == 1).all()
    # hot clusters all survive
    assert not set(hot.tolist()) & set(plan.lost.tolist())
    # coverage loss is bounded by the failed shard's cold share
    assert plan.n_lost <= C // n_shards + 1
