"""Tier-1 recall regression gate (PR 3 satellite).

One cached small-corpus build, served END TO END through ``serve_leveled``
on the candidate-compressed fused path — the exact production route:
GBDT level routing -> per-level compiled centroid scan + LLSP pruning ->
fused-topk candidate scan -> merge.  The gate asserts recall@10 >= 0.96 so
a future kernel / merge / planner edit cannot silently trade recall for
speed: any such regression fails tier-1, not a nightly bench.

The build is module-cached (one build per test session) and seeded, so the
gate is deterministic.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.build.pipeline import BuildConfig, build_index
from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.llsp import LLSPConfig
from repro.core.search import SearchConfig, serve_leveled

RECALL_FLOOR = 0.96
Q8_RECALL_FLOOR = 0.95      # int8-residual first pass, no flash re-rank


@pytest.fixture(scope="module")
def gate_build(tmp_path_factory, small_corpus):
    x, q, topk = small_corpus
    wd = str(tmp_path_factory.mktemp("recall_gate"))
    cfg = BuildConfig(
        max_cluster_size=48, cluster_len=64, coarse_per_task=1000,
        n_workers=2,
        llsp=LLSPConfig(levels=(8, 16, 32, 48), recall_target=0.97,
                        n_ratio_features=8, n_trees=30, max_depth=4),
    )
    idx, llsp, report = build_index(x, cfg, wd, queries=q,
                                    query_topk=np.minimum(topk, 20))
    _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    return idx, llsp, report, x, q, np.asarray(t10)


def test_recall_gate_serve_leveled_fused(gate_build):
    idx, llsp, _, x, q, true10 = gate_build
    assert llsp is not None
    cfg = SearchConfig(k=10, nprobe_max=48, pruning="llsp", n_ratio=8,
                       use_kernel=False, fused_topk=True)
    out = serve_leveled(idx, llsp, q, np.full((q.shape[0],), 10, np.int32),
                        cfg, pad=32)
    r = recall_at_k(out["ids"], true10)
    assert r >= RECALL_FLOOR, (
        f"recall@10={r:.4f} fell below the {RECALL_FLOOR} gate on the fused "
        f"serve_leveled path (levels used: {np.bincount(out['levels']).tolist()})")


def test_recall_gate_serve_leveled_q8(gate_build):
    """PR 8 gate: the quantized serving default, END TO END through
    ``serve_leveled`` — GBDT routing -> LLSP pruning -> fused q8 candidate
    scan (dead slots masked out of the scale) -> merge.  Floors the raw
    first-pass recall at 0.95; the flash re-rank on top (runtime tests)
    only tightens it."""
    from repro.core.quantize import attach_quantized

    idx, llsp, _, x, q, true10 = gate_build
    qidx = attach_quantized(idx)
    cfg = SearchConfig(k=10, nprobe_max=48, pruning="llsp", n_ratio=8,
                       use_kernel=False, fused_topk=True, tier="q8")
    out = serve_leveled(qidx, llsp, q, np.full((q.shape[0],), 10, np.int32),
                        cfg, pad=32)
    r = recall_at_k(out["ids"], true10)
    assert r >= Q8_RECALL_FLOOR, (
        f"quantized recall@10={r:.4f} fell below the {Q8_RECALL_FLOOR} gate "
        f"on the fused q8 serve_leveled path")


def test_recall_gate_fused_build_is_searchable(gate_build):
    # the gate corpus was built on the DEFAULT (fused_assign + streamed
    # stage 2) pipeline — sanity-pin that and the replication contract
    idx, _, report, x, q, _ = gate_build
    assert report.n_clusters > 10
    assert report.replication >= 1.0
    assert 0.0 <= report.shard_overlap <= 1.0
    assert len(report.shard_stamps) >= 2    # streamed stage 2 actually ran
