"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs the pure-jnp
oracle in kernels/ref.py."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.ivf_scan import ivf_scan, ivf_scan_clustermajor
from repro.kernels.pairwise_l2 import pairwise_l2


@pytest.mark.parametrize("n,m,d", [(8, 16, 8), (128, 128, 128),
                                   (100, 257, 96), (33, 64, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_sweep(n, m, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * m + d))
    a = jax.random.normal(k1, (n, d), dtype)
    b = jax.random.normal(k2, (m, d), dtype)
    got = pairwise_l2(a, b, bn=32, bm=64, bd=64, interpret=True)
    want = ref.pairwise_l2_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("c,l,d,b,p", [(16, 8, 16, 4, 4), (64, 32, 64, 8, 16),
                                       (10, 16, 24, 3, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ivf_scan_sweep(c, l, d, b, p, dtype):
    key = jax.random.PRNGKey(c + l + d)
    k1, k2, k3 = jax.random.split(key, 3)
    postings = jax.random.normal(k1, (c, l, d), dtype)
    queries = jax.random.normal(k2, (b, d), dtype)
    cids = jax.random.randint(k3, (b, p), 0, c)
    mask = jax.random.bernoulli(k3, 0.7, (b, p))
    got = ivf_scan(postings, cids, mask, queries, interpret=True)
    want = ref.ivf_scan_ref(postings, cids, mask, queries)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)
    # masked probes are +inf in both
    assert np.all(np.isinf(np.asarray(got)[~np.asarray(mask)]))


@pytest.mark.parametrize("c,l,d,b,a_n", [(16, 8, 16, 4, 6), (32, 16, 32, 8, 12)])
def test_ivf_scan_clustermajor_sweep(c, l, d, b, a_n):
    key = jax.random.PRNGKey(a_n)
    k1, k2, k3 = jax.random.split(key, 3)
    postings = jax.random.normal(k1, (c, l, d))
    queries = jax.random.normal(k2, (b, d))
    active = jax.random.randint(k3, (a_n,), 0, c)
    qsel = jax.random.bernoulli(k3, 0.5, (a_n, b))
    got = ivf_scan_clustermajor(postings, active, qsel, queries, interpret=True)
    want = ref.ivf_scan_clustermajor_ref(postings, active, qsel, queries)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n,d,k,bn", [(300, 32, 17, 64), (1000, 24, 33, 128),
                                      (37, 130, 5, 8), (8, 8, 8, 512),
                                      (257, 48, 129, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_assign_update_sweep(n, d, k, bn, dtype):
    """Fused assign/update kernel (interpret) vs the jnp oracle: exact
    assignments and counts, tolerance on the float accumulations."""
    from repro.kernels.kmeans_assign import kmeans_assign_update

    k1, k2 = jax.random.split(jax.random.PRNGKey(n + d + k))
    x = jax.random.normal(k1, (n, d), dtype)
    c = jax.random.normal(k2, (k, d), dtype)
    a, md, s, cnt = kmeans_assign_update(x, c, bn=bn, interpret=True)
    ar, mr, sr, cr = ref.kmeans_assign_update_ref(x, c)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ar))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(cr))
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(md), np.asarray(mr),
                               rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=tol, atol=tol * 10)
    # the set reduction is closed: every point lands in exactly one centroid
    assert float(np.asarray(cnt).sum()) == n
    np.testing.assert_allclose(np.asarray(s).sum(0),
                               np.asarray(x, np.float32).sum(0),
                               rtol=tol * 10, atol=tol * 100)


def test_kmeans_assign_update_accumulates_across_blocks():
    """Multi-block grids must fold partial sums into the SAME revisited
    VMEM block — catch any init/flush bug by making every block contribute
    to every centroid."""
    from repro.kernels.kmeans_assign import kmeans_assign_update

    n, d, k = 64, 16, 4
    rng = np.random.default_rng(0)
    c = rng.normal(size=(k, d)).astype(np.float32)
    x = np.repeat(c, n // k, axis=0) + 1e-3 * rng.normal(
        size=(n, d)).astype(np.float32)
    order = rng.permutation(n)            # interleave: all blocks hit all k
    x = x[order]
    a, _, s, cnt = kmeans_assign_update(
        jnp.asarray(x), jnp.asarray(c), bn=8, interpret=True)
    assert np.asarray(cnt).tolist() == [n // k] * k
    want = np.stack([x[np.asarray(a) == j].sum(0) for j in range(k)])
    np.testing.assert_allclose(np.asarray(s), want, rtol=1e-5, atol=1e-5)


def test_kmeans_assign_update_chunked_wrapper_matches_single():
    """ops.kmeans_assign_update chunking over N is invisible in the result."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, (500, 20))
    c = jax.random.normal(k2, (13, 20))
    a0, m0, s0, c0 = ops.kmeans_assign_update(x, c, chunk=10_000)
    a1, m1, s1, c1 = ops.kmeans_assign_update(x, c, chunk=64)
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))
    np.testing.assert_allclose(np.asarray(m0), np.asarray(m1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))


def test_kmeans_assign_matches_argmin():
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(k1, (300, 32))
    c = jax.random.normal(k2, (17, 32))
    assign, mind = ops.kmeans_assign(x, c, chunk=128)
    d = ref.pairwise_l2_ref(x, c)
    np.testing.assert_array_equal(np.asarray(assign), np.argmin(np.asarray(d), 1))
    # fused-vs-unfused float noise near zero: atol-dominated comparison
    np.testing.assert_allclose(np.asarray(mind), np.min(np.asarray(d), 1),
                               rtol=1e-4, atol=1e-4)


def test_ops_wrappers_dispatch():
    """ops.* must run without explicit interpret flags on this backend."""
    a = jnp.ones((16, 8))
    b = jnp.zeros((4, 8))
    out = ops.pairwise_l2(a, b)
    np.testing.assert_allclose(np.asarray(out), np.full((16, 4), 8.0), rtol=1e-6)


@pytest.mark.parametrize("k,d", [(5, 8), (37, 19), (129, 130), (128, 128)])
@pytest.mark.parametrize("n_empty", [0, 1, 4])
def test_kmeans_mstep_kernel_matches_ref(k, d, n_empty):
    """Fused M-step kernel (interpret) vs jnp oracle vs the host formula:
    exact division for live clusters, exact rank-ordered reseed for empties
    (the e-th empty cluster takes the e-th worst-served candidate)."""
    from repro.kernels.kmeans_mstep import kmeans_mstep

    rng = np.random.default_rng(k * 1000 + d + n_empty)
    sums = (rng.normal(size=(k, d)) * 10).astype(np.float32)
    counts = rng.integers(1, 5, size=k).astype(np.float32)
    empties = rng.choice(k, size=min(n_empty, k), replace=False)
    counts[empties] = 0.0
    reseed = rng.normal(size=(k, d)).astype(np.float32)
    out = np.asarray(kmeans_mstep(jnp.asarray(sums), jnp.asarray(counts),
                                  jnp.asarray(reseed), interpret=True))
    out_ref = np.asarray(ref.kmeans_mstep_ref(
        jnp.asarray(sums), jnp.asarray(counts), jnp.asarray(reseed)))
    np.testing.assert_array_equal(out, out_ref)
    empty = counts <= 0
    want = sums / np.maximum(counts, 1.0)[:, None]
    want[empty] = reseed[(np.cumsum(empty) - empty)[empty]]
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-6)


def test_kmeans_device_mstep_matches_host_path():
    """Whole-Lloyd-iteration parity: the device-resident loop (fused assign
    kernel + top-k worst-served gather + M-step kernel) reproduces the host
    M-step path — same assignments, same centroids, same inertia."""
    from repro.build.kmeans import kmeans

    rng = np.random.default_rng(11)
    # two tight blobs + k larger than the natural cluster count so empty
    # clusters actually occur and the reseed path is exercised
    x = np.concatenate([
        rng.normal(loc=0.0, scale=0.05, size=(200, 8)),
        rng.normal(loc=9.0, scale=0.05, size=(200, 8)),
    ]).astype(np.float32)
    cd, ad, inertia_d = kmeans(x, 12, iters=5, seed=2, fused=True,
                               device_mstep=True)
    ch, ah, inertia_h = kmeans(x, 12, iters=5, seed=2, fused=True,
                               device_mstep=False)
    np.testing.assert_array_equal(ad, ah)
    np.testing.assert_allclose(cd, ch, rtol=2e-6, atol=2e-6)
    assert abs(inertia_d - inertia_h) <= 1e-3 * max(abs(inertia_h), 1.0)


def test_kmeans_mstep_ops_dispatch():
    sums = jnp.asarray(np.eye(4, 8, dtype=np.float32) * 6.0)
    counts = jnp.asarray(np.array([2.0, 0.0, 3.0, 0.0], np.float32))
    reseed = jnp.asarray(np.arange(32, dtype=np.float32).reshape(4, 8))
    out = np.asarray(ops.kmeans_mstep(sums, counts, reseed))
    np.testing.assert_allclose(out[0], np.eye(4, 8)[0] * 3.0)
    np.testing.assert_allclose(out[2], np.eye(4, 8)[2] * 2.0)
    np.testing.assert_allclose(out[1], reseed[0])    # 1st empty -> 1st worst
    np.testing.assert_allclose(out[3], reseed[1])    # 2nd empty -> 2nd worst
