"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs the pure-jnp
oracle in kernels/ref.py."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.ivf_scan import ivf_scan, ivf_scan_clustermajor
from repro.kernels.pairwise_l2 import pairwise_l2


@pytest.mark.parametrize("n,m,d", [(8, 16, 8), (128, 128, 128),
                                   (100, 257, 96), (33, 64, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2_sweep(n, m, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * m + d))
    a = jax.random.normal(k1, (n, d), dtype)
    b = jax.random.normal(k2, (m, d), dtype)
    got = pairwise_l2(a, b, bn=32, bm=64, bd=64, interpret=True)
    want = ref.pairwise_l2_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("c,l,d,b,p", [(16, 8, 16, 4, 4), (64, 32, 64, 8, 16),
                                       (10, 16, 24, 3, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ivf_scan_sweep(c, l, d, b, p, dtype):
    key = jax.random.PRNGKey(c + l + d)
    k1, k2, k3 = jax.random.split(key, 3)
    postings = jax.random.normal(k1, (c, l, d), dtype)
    queries = jax.random.normal(k2, (b, d), dtype)
    cids = jax.random.randint(k3, (b, p), 0, c)
    mask = jax.random.bernoulli(k3, 0.7, (b, p))
    got = ivf_scan(postings, cids, mask, queries, interpret=True)
    want = ref.ivf_scan_ref(postings, cids, mask, queries)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)
    # masked probes are +inf in both
    assert np.all(np.isinf(np.asarray(got)[~np.asarray(mask)]))


@pytest.mark.parametrize("c,l,d,b,a_n", [(16, 8, 16, 4, 6), (32, 16, 32, 8, 12)])
def test_ivf_scan_clustermajor_sweep(c, l, d, b, a_n):
    key = jax.random.PRNGKey(a_n)
    k1, k2, k3 = jax.random.split(key, 3)
    postings = jax.random.normal(k1, (c, l, d))
    queries = jax.random.normal(k2, (b, d))
    active = jax.random.randint(k3, (a_n,), 0, c)
    qsel = jax.random.bernoulli(k3, 0.5, (a_n, b))
    got = ivf_scan_clustermajor(postings, active, qsel, queries, interpret=True)
    want = ref.ivf_scan_clustermajor_ref(postings, active, qsel, queries)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_kmeans_assign_matches_argmin():
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(k1, (300, 32))
    c = jax.random.normal(k2, (17, 32))
    assign, mind = ops.kmeans_assign(x, c, chunk=128)
    d = ref.pairwise_l2_ref(x, c)
    np.testing.assert_array_equal(np.asarray(assign), np.argmin(np.asarray(d), 1))
    # fused-vs-unfused float noise near zero: atol-dominated comparison
    np.testing.assert_allclose(np.asarray(mind), np.min(np.asarray(d), 1),
                               rtol=1e-4, atol=1e-4)


def test_ops_wrappers_dispatch():
    """ops.* must run without explicit interpret flags on this backend."""
    a = jnp.ones((16, 8))
    b = jnp.zeros((4, 8))
    out = ops.pairwise_l2(a, b)
    np.testing.assert_allclose(np.asarray(out), np.full((16, 4), 8.0), rtol=1e-6)
