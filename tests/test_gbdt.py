"""GBDT trainer + JAX inference."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.gbdt import (
    GBDTRegressor, predict_jax, predict_stacked_jax, stack_params,
)


def _toy(n=2000, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, size=(n, 4)).astype(np.float32)
    y = X[:, 0] ** 2 + 2.0 * (X[:, 1] > 0.5) + 0.3 * X[:, 2] + rng.normal(0, 0.1, n)
    return X, y


def test_gbdt_beats_mean_baseline():
    X, y = _toy()
    m = GBDTRegressor(n_trees=60, max_depth=4, lr=0.2).fit(X, y)
    pred = m.predict(X)
    mse = float(np.mean((pred - y) ** 2))
    base = float(np.var(y))
    assert mse < 0.2 * base, (mse, base)


def test_gbdt_generalizes():
    X, y = _toy(seed=1)
    Xt, yt = _toy(seed=2)
    m = GBDTRegressor(n_trees=60, max_depth=4, lr=0.2).fit(X, y)
    mse = float(np.mean((m.predict(Xt) - yt) ** 2))
    assert mse < 0.3 * float(np.var(yt))


def test_stacked_inference_matches_individual():
    X, y = _toy(n=500)
    models = []
    for i in range(3):
        models.append(
            GBDTRegressor(n_trees=10, max_depth=3, lr=0.3, seed=i).fit(X, y + i).params
        )
    stacked = stack_params(models)
    Xj = jnp.asarray(X[:32])
    for lvl in range(3):
        want = predict_jax(models[lvl], Xj)
        got = predict_stacked_jax(stacked, jnp.full((32,), lvl, jnp.int32), Xj)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_constant_target():
    X, _ = _toy(n=200)
    y = np.full(200, 3.25)
    m = GBDTRegressor(n_trees=5, max_depth=3).fit(X, y)
    np.testing.assert_allclose(m.predict(X), y, atol=1e-3)
