"""RecSys smoke tests: reduced configs per assigned arch + EmbeddingBag."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get
from repro.data import recsys_batch
from repro.models.recsys import (
    RecSysConfig, bce_loss, embedding_bag, embedding_lookup, forward,
    init_params, make_train_step, retrieval_scores,
)
from repro.optim import adamw

RS_ARCHS = ["xdeepfm", "wide_deep", "mind", "din"]


def reduced_cfg(name):
    return dataclasses.replace(get(name).config, table_rows=2048)


@pytest.fixture(scope="module", params=RS_ARCHS)
def model(request):
    cfg = reduced_cfg(request.param)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = recsys_batch(32, cfg.n_sparse, cfg.table_rows,
                         seq_len=cfg.seq_len, seed=1)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    return request.param, cfg, params, batch


def test_forward_shapes_finite(model):
    name, cfg, params, batch = model
    logits = forward(params, batch, cfg)
    assert logits.shape == (32,)
    assert bool(jnp.isfinite(logits).all()), name


def test_train_step_improves(model):
    name, cfg, params, batch = model
    step = jax.jit(make_train_step(cfg))
    opt = adamw.init(params)
    losses = []
    for _ in range(6):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (name, losses)


def test_embedding_bag_matches_manual(rng):
    table = rng.normal(size=(50, 8)).astype(np.float32)
    ids = rng.integers(-1, 50, size=(6, 5)).astype(np.int32)
    out = np.asarray(embedding_bag(jnp.asarray(table), jnp.asarray(ids)))
    for b in range(6):
        want = table[ids[b][ids[b] >= 0]].sum(0) if (ids[b] >= 0).any() else 0
        np.testing.assert_allclose(out[b], want, rtol=1e-5, atol=1e-6)


def test_embedding_bag_weights(rng):
    table = rng.normal(size=(20, 4)).astype(np.float32)
    ids = np.array([[0, 1, -1]], dtype=np.int32)
    w = np.array([[2.0, 0.5, 9.9]], dtype=np.float32)
    out = np.asarray(embedding_bag(jnp.asarray(table), jnp.asarray(ids),
                                   jnp.asarray(w)))
    np.testing.assert_allclose(out[0], 2 * table[0] + 0.5 * table[1], rtol=1e-5)


def test_embedding_lookup_masks_negatives(rng):
    table = rng.normal(size=(10, 3)).astype(np.float32)
    ids = np.array([[1, -1], [0, 2]], dtype=np.int32)
    out = np.asarray(embedding_lookup(jnp.asarray(table), jnp.asarray(ids)))
    assert (out[0, 1] == 0).all()
    np.testing.assert_array_equal(out[1, 1], table[2])


def test_retrieval_scores_single_and_multi_interest(rng):
    cand = rng.normal(size=(100, 8)).astype(np.float32)
    user = rng.normal(size=(2, 8)).astype(np.float32)
    vals, ids = retrieval_scores(jnp.asarray(user), jnp.asarray(cand), k=5)
    want = (user @ cand.T)
    for b in range(2):
        np.testing.assert_array_equal(np.asarray(ids)[b],
                                      np.argsort(-want[b])[:5])
    multi = rng.normal(size=(2, 3, 8)).astype(np.float32)
    vals, ids = retrieval_scores(jnp.asarray(multi), jnp.asarray(cand), k=5)
    want = np.einsum("bid,nd->bin", multi, cand).max(1)
    for b in range(2):
        np.testing.assert_array_equal(np.asarray(ids)[b],
                                      np.argsort(-want[b])[:5])


def test_capsule_routing_output_norms():
    """Squash keeps interest capsule norms in (0, 1)."""
    from repro.models.recsys.models import capsule_routing
    cfg = reduced_cfg("mind")
    rng = np.random.default_rng(0)
    hist = jnp.asarray(rng.normal(size=(4, cfg.seq_len, cfg.embed_dim)).astype(np.float32))
    mask = jnp.ones((4, cfg.seq_len), bool)
    bil = jnp.asarray(rng.normal(size=(cfg.embed_dim, cfg.embed_dim)).astype(np.float32) * 0.1)
    v = capsule_routing(hist, mask, bil, cfg)
    assert v.shape == (4, cfg.n_interests, cfg.embed_dim)
    norms = np.linalg.norm(np.asarray(v), axis=-1)
    assert (norms < 1.0 + 1e-5).all() and (norms > 0).all()
