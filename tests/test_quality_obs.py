"""Quality-observability tests (PR 9): recall-proxy correctness and live
calibration against shadow audits on the real q8 serving path, deterministic
non-blocking shadow sampling, multi-window burn-rate alert fire/clear with
hysteresis under a virtual clock, telemetry-harvest persistence round-trips,
the centroid-drift rebuild advisory, rerank auto-round parity, fabric
coverage stamping, and Perfetto flow-arrow export integrity."""
import json
import time
import types

import numpy as np
import pytest

from repro.core.search import SearchConfig
from repro.obs import (
    BurnRule, HarvestRing, MetricsRegistry, Observability, QualityMonitor,
    SLOTracker, TraceRecorder, check_well_nested, default_rules,
    health_snapshot, load_npz, recall_proxy, shadow_sampled, write_health,
)
from repro.obs.quality import overlap_frac
from repro.runtime import (
    BatchPolicy, DynamicBatcher, RerankConfig, ServeEngine, drifting_trace,
    make_quantized_pipeline,
)


# -------------------------------------------------------------------------
# proxy primitives
# -------------------------------------------------------------------------
def test_recall_proxy_rowwise_overlap_and_padding():
    pre = np.array([[1, 2, 3, 4], [5, 6, 7, 8], [-1, -1, 2, 3]])
    post = np.array([[1, 2, 9, 9], [5, 6, 7, 8], [2, 3, -1, -1]])
    p = recall_proxy(pre, post, k=4)
    assert p.dtype == np.float32
    assert p[0] == pytest.approx(0.5)          # {1,2} of 4
    assert p[1] == pytest.approx(1.0)
    assert p[2] == pytest.approx(0.5)          # padding (-1) never matches
    # k slices both sides
    assert recall_proxy(pre, post, k=2)[0] == pytest.approx(1.0)


def test_overlap_frac_scalar_against_truth():
    assert overlap_frac(np.array([3, 1, 2]), np.array([1, 2, 3]), 3) == 1.0
    assert overlap_frac(np.array([3, -1, 9]), np.array([1, 2, 3]), 3) \
        == pytest.approx(1 / 3)


def test_shadow_sampling_deterministic_and_rate_shaped():
    ids = range(4000)
    assert not any(shadow_sampled(i, 0.0) for i in ids)
    assert all(shadow_sampled(i, 1.0) for i in ids)
    picked = [i for i in ids if shadow_sampled(i, 0.05)]
    again = [i for i in ids if shadow_sampled(i, 0.05)]
    assert picked == again                     # replayable
    assert 0.02 <= len(picked) / 4000 <= 0.09  # rate actually applies
    # monotone: raising the rate only ADDS audited ids
    more = {i for i in ids if shadow_sampled(i, 0.2)}
    assert set(picked) <= more


# -------------------------------------------------------------------------
# monitor streams on a stubbed completion funnel
# -------------------------------------------------------------------------
def _comp(q=1.0, status="ok", nprobe=4):
    return types.SimpleNamespace(status=status, quality=q, nprobe=nprobe,
                                 submitted=0.5, completed=1.0, reason="",
                                 ids=np.arange(5))


def _req(i, route=None):
    return types.SimpleNamespace(req_id=i, index="s", trace_id=0,
                                 route=route, query=np.zeros(4, np.float32),
                                 topk=5)


def test_observe_batch_streams_labels_low_counter_and_harvest():
    m = MetricsRegistry()
    h = HarvestRing()
    qm = QualityMonitor(m, harvest=h, low_threshold=0.9)
    reqs = [_req(0), _req(1), _req(2)]
    comps = [_comp(1.0), _comp(0.5, status="partial"), _comp(-1.0)]
    qm.observe_batch(reqs, comps, shards=np.array([0, 1, 0]),
                     rerank_rounds=2)
    assert qm.proxy_hist.n == 2                # -1 = no proxy, skipped
    assert qm.low_proxy.value() == 1           # 0.5 < 0.9
    assert m.histogram("quality.recall_proxy.shard:1").n == 1
    assert m.histogram("quality.recall_proxy.status:partial").n == 1
    assert m.counter("quality.not_ok").value("partial") == 1
    recs = h.records()
    assert len(recs) == 3 and h.appended == 3
    assert recs[2]["quality"] == -1.0          # sentinel persisted verbatim
    assert recs[1]["shard"] == 1
    assert all(r["rerank_rounds"] == 2 for r in recs)
    s = qm.summary()
    assert s["queries"] == 3 and s["low_proxy"] == 1
    assert s["proxy"]["n"] == 2


def test_route_clusters_land_in_harvest():
    m = MetricsRegistry()
    h = HarvestRing()
    qm = QualityMonitor(m, harvest=h)
    route = types.SimpleNamespace(cids=np.array([7, 3, -1, -1]))
    qm.observe_batch([_req(0, route=route)], [_comp(0.9)])
    assert h.records()[0]["clusters"] == (7, 3)
    assert h.records()[0]["route"] == "routed"


# -------------------------------------------------------------------------
# harvest ring persistence
# -------------------------------------------------------------------------
def _fill(h, n, base=0):
    for i in range(n):
        h.append(req_id=base + i, index="sift", trace_id=i * 7, t=1.5 + i,
                 route="direct", nprobe=8, status="ok" if i % 3 else
                 "partial", reason="" if i % 3 else "no_replica",
                 latency_s=0.004 * i, rerank_rounds=i % 4,
                 quality=float(np.float32(i / max(n - 1, 1))), shard=i % 3,
                 clusters=tuple(range(i % 10)))


def test_harvest_npz_roundtrip_is_exact(tmp_path):
    h = HarvestRing()
    _fill(h, 50)
    p = str(tmp_path / "shard.npz")
    h.flush_npz(p)
    assert load_npz(p) == h.records()          # field-by-field identical


def test_harvest_jsonl_roundtrip(tmp_path):
    h = HarvestRing()
    _fill(h, 20)
    p = str(tmp_path / "shard.jsonl")
    assert h.flush_jsonl(p) == 20
    rows = [json.loads(ln) for ln in open(p)]
    want = h.records()
    assert len(rows) == 20
    for got, exp in zip(rows, want):
        exp = dict(exp)
        exp["clusters"] = list(exp["clusters"])
        assert got == exp


def test_harvest_ring_bound_drops_oldest_and_counts():
    h = HarvestRing(capacity=8)
    _fill(h, 20)
    assert len(h) == 8 and h.appended == 20 and h.dropped == 12
    assert h.records()[0]["req_id"] == 12      # oldest evicted


# -------------------------------------------------------------------------
# live calibration: proxy vs shadow audit through the real q8 path
# -------------------------------------------------------------------------
def test_q8_proxy_calibrated_against_shadow_audits(small_index,
                                                   small_corpus, tmp_path):
    """ISSUE acceptance: on the quantized serving default every completion
    carries a proxy in [0, 1], a 100% shadow-audit pass measures true
    recall on the same answers, and |proxy - true| stays tiny at high
    nprobe (both should sit at ~1.0 — miscalibration here means the proxy
    is reading the wrong candidates)."""
    x, q, _ = small_corpus
    cfg = SearchConfig(k=10, nprobe_max=32, pruning="none",
                       use_kernel=False, fused_topk=True)
    pipe = make_quantized_pipeline(small_index, None, cfg, vectors=x,
                                   name="q8",
                                   flash_path=str(tmp_path / "flash.f32"))
    obs = Observability.off()
    harvest = HarvestRing()
    qm = QualityMonitor(obs.metrics, vectors=x, shadow_rate=1.0,
                        harvest=harvest)
    eng = ServeEngine({"q8": pipe},
                      DynamicBatcher(BatchPolicy(max_batch=16,
                                                 max_wait_s=0.001),
                                     ["q8"]),
                      clock=lambda: 0.0, obs=obs, quality=qm)
    n = 32
    try:
        for i in range(n):
            eng.submit(q[i].astype(np.float32), cfg.k, index="q8")
        comps = []
        for _ in range(8):
            eng.step(now=0.0)
            comps += eng.qp.poll()
            if len(comps) >= n:
                break
        assert len(comps) == n
        # every q8 completion carries a live proxy
        assert all(0.0 <= c.quality <= 1.0 for c in comps)
        qm.drain(timeout_s=30.0)
        qm.close()
    finally:
        pipe.flash.release()
    s = qm.summary()
    assert s["proxy"]["n"] == n
    assert s["audits_done"] == n and s["audits_dropped"] == 0
    assert s["calibration_err"]["mean"] <= 0.05, s["calibration_err"]
    assert harvest.appended == n
    assert all(r["quality"] >= 0.0 for r in harvest.records())


def test_shadow_queue_bound_drops_audits_not_requests():
    m = MetricsRegistry()
    qm = QualityMonitor(m, vectors=np.zeros((64, 4), np.float32),
                        shadow_rate=1.0, max_pending=0)
    qm.observe_batch([_req(0)], [_comp(1.0)])
    assert qm.audits.value("dropped") == 1     # bounded lane, counted
    assert qm.proxy_hist.n == 1                # proxy stream unaffected
    qm.close()


# -------------------------------------------------------------------------
# burn-rate alerting (virtual clock — fully deterministic)
# -------------------------------------------------------------------------
def _tracker():
    vt = [0.0]
    tot, bad = [0], [0]
    slo = SLOTracker(metrics=MetricsRegistry(), clock=lambda: vt[0])
    slo.add_rule(BurnRule(name="r", total_fn=lambda: tot[0],
                          bad_fn=lambda: bad[0], budget=0.01,
                          fast_s=10.0, slow_s=60.0))
    return vt, tot, bad, slo


def _run(slo, vt, tot, bad, seconds, per_tick_total, per_tick_bad,
         step=5.0):
    for _ in range(int(seconds / step)):
        vt[0] += step
        tot[0] += per_tick_total
        bad[0] += per_tick_bad
        slo.tick()


def test_burn_alert_fires_on_burst_and_clears_with_hysteresis():
    vt, tot, bad, slo = _tracker()
    st = slo.alerts["r"]
    _run(slo, vt, tot, bad, 60, 100, 0)        # healthy hour: quiet
    assert st.state == "ok" and st.fires == 0
    _run(slo, vt, tot, bad, 30, 100, 10)       # 10% bad >> 2x the 1% budget
    assert st.state == "firing" and st.fires == 1
    assert st.fast_burn >= 2.0 and st.slow_burn >= 2.0
    # hovering between clear (1x) and fire (2x): NO flapping
    _run(slo, vt, tot, bad, 60, 1000, 15)      # 1.5% bad -> burn 1.5
    assert st.state == "firing" and st.fires == 1 and st.clears == 0
    _run(slo, vt, tot, bad, 120, 100, 0)       # recovery
    assert st.state == "ok" and st.clears == 1 and st.fires == 1
    _run(slo, vt, tot, bad, 60, 100, 0)        # stays quiet
    assert st.fires == 1 and st.clears == 1
    assert slo.metrics.counter("slo.alerts").value("r:fire") == 1
    assert slo.metrics.counter("slo.alerts").value("r:clear") == 1


def test_burn_ignores_windows_below_min_events():
    vt, tot, bad, slo = _tracker()
    _run(slo, vt, tot, bad, 60, 0, 0)          # no traffic at all
    st = slo.alerts["r"]
    assert st.state == "ok" and st.fast_burn == 0.0 and st.slow_burn == 0.0


def test_alert_transitions_emit_slo_trace_instants():
    vt, tot, bad = [0.0], [0], [0]
    tr = TraceRecorder()
    slo = SLOTracker(trace=tr, clock=lambda: vt[0])
    slo.add_rule(BurnRule(name="q", total_fn=lambda: tot[0],
                          bad_fn=lambda: bad[0], budget=0.01,
                          fast_s=10.0, slow_s=30.0))
    _run(slo, vt, tot, bad, 30, 100, 50)
    _run(slo, vt, tot, bad, 90, 1000, 0)
    names = [e[1] for e in tr.snapshot()]
    assert "alert_fire:q" in names and "alert_clear:q" in names


def test_default_rules_wire_engine_and_quality_streams():
    m = MetricsRegistry()
    qm = QualityMonitor(m)
    vt = [0.0]
    slo = SLOTracker(metrics=m, clock=lambda: vt[0])
    default_rules(slo, m, quality=qm, fast_s=5.0, slow_s=20.0)
    assert set(slo.alerts) == {"deadline", "partial", "failed", "shed",
                               "quality"}
    comp = m.counter("engine.completions")
    for t in range(8):
        vt[0] += 5.0
        comp.inc(10.0)
        comp.inc(5.0, "partial")               # 33% partial, 1% budget
        slo.tick()
    assert slo.alerts["partial"].state == "firing"
    assert slo.alerts["failed"].state == "ok"


def test_health_snapshot_document_and_atomic_write(tmp_path):
    m = MetricsRegistry()
    qm = QualityMonitor(m)
    qm.observe_batch([_req(0)], [_comp(0.7)])
    vt = [0.0]
    slo = SLOTracker(metrics=m, clock=lambda: vt[0])
    default_rules(slo, m, quality=qm)
    slo.tick()
    doc = health_snapshot(slo=slo, quality=qm, registry=m,
                          extra={"drill": {"victim": 1}}, t=123.0)
    p = str(tmp_path / "health.json")
    write_health(p, doc)
    back = json.load(open(p))
    assert back["t"] == 123.0
    assert back["alerts"]["partial"]["state"] == "ok"
    assert back["quality"]["proxy"]["n"] == 1
    assert back["drill"]["victim"] == 1
    assert "engine.completions" in back["metrics"] or back["metrics"]


# -------------------------------------------------------------------------
# centroid-drift rebuild advisory
# -------------------------------------------------------------------------
def _drift_monitor(trace=None, **kw):
    from repro.lifecycle import DriftMonitor
    cents = np.array([[0.0, 0.0], [10.0, 10.0]], np.float32)
    return DriftMonitor(cents, metrics=MetricsRegistry(), trace=trace,
                        shift_threshold=0.6, min_inserts=32, **kw)


def test_isotropic_inserts_do_not_advise():
    dm = _drift_monitor()
    rng = np.random.default_rng(0)
    v = rng.normal(0.0, 1.0, (200, 2)).astype(np.float32)
    dm.observe(np.concatenate([v, -v]))        # symmetric around c0
    assert dm.advisory() is None
    assert dm.shifts().max() < 0.2
    assert dm.summary()["clusters_drifted"] == 0


def test_one_sided_pileup_advises_once_and_resets():
    tr = TraceRecorder()
    dm = _drift_monitor(trace=tr)
    rng = np.random.default_rng(1)
    # all inserts land on ONE side of centroid 0: shift -> ~1
    v = (np.array([2.0, 0.0]) +
         rng.normal(0, 0.05, (64, 2))).astype(np.float32)
    dm.observe(v)
    assert dm.shifts()[0] > 0.9
    reason = dm.advisory()
    assert reason is not None and reason.startswith("drift:")
    dm.advisory()                              # latched: still advising...
    names = [e[1] for e in tr.snapshot()]
    assert names.count("rebuild_advisory") == 1   # ...but ONE instant
    assert dm.advisories == 1
    assert dm.summary()["top"][0]["cluster"] == 0
    dm.reset()
    assert dm.advisory() is None               # re-armed, no stale signal


def test_nearest_centroid_fallback_matches_explicit_cids():
    dm1, dm2 = _drift_monitor(), _drift_monitor()
    v = (np.array([10.0, 10.0]) +
         np.array([[1.0, 0.0]] * 40)).astype(np.float32)
    dm1.observe(v)                             # assigns nearest (cluster 1)
    dm2.observe(v, cids=np.ones(40, np.int64))
    np.testing.assert_allclose(dm1.shifts(), dm2.shifts())
    assert dm1.shifts()[1] > 0.9 and dm1.shifts()[0] == 0.0


def test_drift_severity_weighs_shift_by_assign_mass():
    """Advisory ranking: a fully-shifted cluster absorbing 3x the insert
    mass outranks an equally-shifted low-mass one, deterministically."""
    dm = _drift_monitor()
    v0 = (np.array([2.0, 0.0]) + np.zeros((40, 2))).astype(np.float32)
    v1 = (np.array([10.0, 12.0]) + np.zeros((120, 2))).astype(np.float32)
    dm.observe(v0)
    dm.observe(v1)
    s, sev = dm.shifts(), dm.severity()
    assert s[0] > 0.9 and s[1] > 0.9           # both fully one-sided...
    np.testing.assert_allclose(sev, s * np.array([40, 120]) / 160.0)
    assert sev[1] > sev[0]                     # ...mass breaks the tie
    top = dm.summary()["top"]
    assert top[0]["cluster"] == 1
    assert top[0]["severity"] == pytest.approx(float(sev[1]))
    assert [t["cluster"] for t in top] == [1, 0]
    # identical streams rank identically (lexsort, not bare argsort)
    dm2 = _drift_monitor()
    dm2.observe(v0)
    dm2.observe(v1)
    assert [t["cluster"] for t in dm2.summary()["top"]] == [1, 0]
    # exact severity tie: ascending cluster id decides
    dm3 = _drift_monitor()
    dm3.observe((np.array([2.0, 0.0]) + np.zeros((64, 2))).astype(np.float32))
    dm3.observe((np.array([10.0, 12.0]) +
                 np.zeros((64, 2))).astype(np.float32))
    sev3 = dm3.severity()
    assert sev3[0] == sev3[1]
    assert [t["cluster"] for t in dm3.summary()["top"]] == [0, 1]


def test_scheduler_due_surfaces_drift_advisory():
    from repro.lifecycle import RebuildScheduler
    from repro.lifecycle.rebuild import RebuildPolicy
    dm = _drift_monitor()
    lane = types.SimpleNamespace(
        state=types.SimpleNamespace(fill_frac=0.0, tombstone_frac=0.0),
        stats=types.SimpleNamespace(rejected_full=0))
    sched = RebuildScheduler(
        name="t", corpus=None, centroids=dm.centroids, workdir="",
        lane=lane, versions=None, make_pipeline=None, cluster_len=8,
        policy=RebuildPolicy(min_interval_s=0.0), clock=lambda: 100.0,
        drift=dm)
    assert sched.due() is None                 # stationary stream
    dm.observe((np.array([2.0, 0.0]) +
                np.zeros((64, 2))).astype(np.float32))
    assert sched.due() == "drift:1"
    # capacity triggers still outrank the advisory
    lane.state.fill_frac = 1.0
    assert sched.due() == "delta_fill"


def test_drifting_trace_window_migrates_and_validates():
    tr = drifting_trace(200.0, 10.0, 1000, window_frac=0.2, seed=3)
    assert len(tr) > 100
    rows = np.array([a.qrow for a in tr])
    assert rows.min() >= 0 and rows.max() < 1000
    n10 = len(tr) // 10
    assert rows[:n10].mean() + 300 < rows[-n10:].mean()  # window moved
    assert rows[:n10].max() < 1000 * 0.2 + 80            # starts low
    assert tr == drifting_trace(200.0, 10.0, 1000, window_frac=0.2, seed=3)
    with pytest.raises(ValueError):
        drifting_trace(10.0, 1.0, 100, window_frac=0.0)
    with pytest.raises(ValueError):
        drifting_trace(10.0, 1.0, 100, window_frac=1.5)


# -------------------------------------------------------------------------
# rerank auto-round: parity at off, adaptation at on
# -------------------------------------------------------------------------
def test_auto_round_first_batch_parity_and_adaptation(small_index,
                                                      small_corpus,
                                                      tmp_path):
    x, q, _ = small_corpus
    cfg = SearchConfig(k=10, nprobe_max=16, pruning="none",
                       use_kernel=False, fused_topk=True)

    def run(pipe, batch):
        h = pipe.prefetch(pipe.plan(batch, cfg.k))
        return pipe.harvest(pipe.dispatch(h))

    off = make_quantized_pipeline(
        small_index, None, cfg, vectors=x, name="off",
        flash_path=str(tmp_path / "off.f32"),
        rerank=RerankConfig(round_size=8, auto_round=False))
    on = make_quantized_pipeline(
        small_index, None, cfg, vectors=x, name="on",
        flash_path=str(tmp_path / "on.f32"),
        rerank=RerankConfig(round_size=8, auto_round=True))
    try:
        b = q[:16].astype(np.float32)
        r_off, r_on = run(off, b), run(on, b)
        # before any I/O stamps exist, auto mode runs the configured width
        # verbatim — results bit-equal to the static config
        assert r_on.times.rerank_round_size == 8
        assert r_off.times.rerank_round_size == 8
        np.testing.assert_array_equal(r_off.ids, r_on.ids)
        np.testing.assert_array_equal(r_off.dists, r_on.dists)
        # the stamped cost retargets the NEXT batch's round width
        learned = on._auto_round
        assert learned is not None and learned >= 16
        assert off._auto_round is None         # off never adapts
        r2 = run(on, b)
        assert r2.times.rerank_round_size == learned != 8
        r2_off = run(off, b)
        assert r2_off.times.rerank_round_size == 8
    finally:
        off.flash.release()
        on.flash.release()


# -------------------------------------------------------------------------
# fabric coverage proxy + flow-arrow export
# -------------------------------------------------------------------------
def test_fabric_coverage_and_primary_shard_stamps(small_index):
    from repro.distributed import ShardedFabric
    cfg = SearchConfig(k=5, nprobe_max=8, pruning="none", use_kernel=False,
                       fused_topk=True)
    fab = ShardedFabric(small_index, None, cfg, n_shards=4)
    # plan.cids rows are RANK-ORDERED probe lists (-1 = padding)
    pcids = np.array([[0, 1, 2, 3],
                      [0, 2, -1, -1],
                      [2, 3, -1, -1]], np.int64)
    state = types.SimpleNamespace(
        plan=types.SimpleNamespace(cids=pcids), lost=set())
    # no losses: full coverage regardless of probe shape
    np.testing.assert_allclose(fab._coverage(state, 3), [1.0, 1.0, 1.0])
    # clusters 1 and 3 lost: coverage drops by the RANK weight 1/(1+j) of
    # each lost probe — losing the rank-1 probe (row 0: cluster 1) costs
    # more than losing the rank-3 probe (cluster 3), and a row that never
    # probed a lost cluster (row 1) stays at 1.0
    state.lost = {1, 3}
    w = 1.0 / (1.0 + np.arange(4, dtype=np.float64))
    exp0 = 1.0 - (w[1] + w[3]) / w.sum()            # lost ranks 1 and 3
    exp2 = 1.0 - w[1] / (w[0] + w[1])               # lost rank 1 of 2
    np.testing.assert_allclose(fab._coverage(state, 3),
                               [exp0, 1.0, exp2], rtol=1e-6)
    # the victim-vs-bystander separation the kill drill gates on: losing
    # a query's rank-0 probe must cost more than losing its last probe
    state_home = types.SimpleNamespace(
        plan=types.SimpleNamespace(cids=pcids[:1]), lost={0})
    state_tail = types.SimpleNamespace(
        plan=types.SimpleNamespace(cids=pcids[:1]), lost={3})
    assert fab._coverage(state_home, 1)[0] < fab._coverage(state_tail, 1)[0]
    cids = np.array([[0, 1], [2, -1], [3, 0]], np.int64)
    shards = fab._primary_shards(
        types.SimpleNamespace(plan=types.SimpleNamespace(cids=cids)), 3)
    np.testing.assert_array_equal(
        shards, fab.striping.shard_of(np.array([0, 2, 3])))


def test_flow_arrow_export_and_dangling_detection():
    tr = TraceRecorder()
    tr.span("request", 1.0, 2.0, trace_id=9, track="requests")
    tr.flow_start("fanout", "flow-1", t=1.2, trace_id=9, track="requests",
                  args={"shard": 2})
    tr.flow_finish("fanout", "flow-1", t=1.2, trace_id=9, track="shard-2")
    doc = tr.export()
    te = doc["traceEvents"]
    s = [e for e in te if e["ph"] == "s"]
    f = [e for e in te if e["ph"] == "f"]
    assert len(s) == len(f) == 1
    assert s[0]["cat"] == f[0]["cat"] == "flow"
    assert s[0]["id"] == f[0]["id"]
    assert f[0]["bp"] == "e"                   # bind to enclosing slice
    assert s[0]["args"]["shard"] == 2
    assert check_well_nested(te) == []
    # dangling endpoints are structural violations
    tr2 = TraceRecorder()
    tr2.flow_start("fanout", "flow-7", t=0.5, trace_id=1, track="requests")
    v = check_well_nested(tr2.export()["traceEvents"])
    assert any("without finish" in x for x in v)
    tr3 = TraceRecorder()
    tr3.flow_finish("fanout", "flow-8", t=0.5, track="shard-0")
    v = check_well_nested(tr3.export()["traceEvents"])
    assert any("without start" in x for x in v)


def test_lifecycle_rebuild_trace_track(small_corpus):
    """The scheduler's rebuild emits snapshot/build/swap spans plus the
    epoch_swap instant on the 'lifecycle' track (satellite: rebuilds are
    visible in the same flamegraph as the serving spans)."""
    from repro.lifecycle import RebuildScheduler
    tr = TraceRecorder()
    obs = types.SimpleNamespace(trace=tr, tracing=True)
    rep = types.SimpleNamespace(
        trigger="drift:1", folded_inserts=4, mode="delta", eid_old=0,
        eid_new=1, t_snapshot=1.0, t_built=2.0, t_swapped=3.0,
        carried_ops=0, shards_streamed=2, shards_reused=6, io_cut_x=4.0,
        tier="q8")
    bstats = {"shard_stamps": [
        {"shard": 0, "rows": 10, "bytes": 640, "load_start": 1.1,
         "assign_done": 1.4, "resumed": False},
        {"shard": 1, "rows": 10, "bytes": 640, "load_start": 1.2,
         "assign_done": 1.5, "resumed": False},
        {"shard": 2, "rows": 0, "bytes": 0, "load_start": 0.0,
         "assign_done": 0.0, "resumed": True}]}
    sched = object.__new__(RebuildScheduler)
    sched.obs = obs
    sched.name = "t"
    sched._emit_rebuild_trace(rep, bstats, 0.5)
    te = tr.export()["traceEvents"]
    assert check_well_nested(te) == []
    tracks = {e["tid"]: e["args"]["name"] for e in te if e["ph"] == "M"}
    xs = {e["name"] for e in te if e["ph"] == "X"}
    assert {"snapshot", "build", "swap"} <= xs
    assert all(tracks[e["tid"]] == "lifecycle"
               for e in te if e["ph"] == "X")
    swaps = [e for e in te if e["ph"] == "i" and e["name"] == "epoch_swap"]
    assert len(swaps) == 1 and swaps[0]["args"]["eid_new"] == 1
    streams = [e for e in te if e["ph"] in ("b", "e")
               and e["name"] == "shard_stream"]
    assert len(streams) == 4                   # 2 streamed shards x (b, e)
    assert not any("shard2" in str(e.get("id")) for e in streams)
