"""Leveled serving engine, sharded-centroid scan, graph baseline."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.search import SearchConfig, make_sharded_serve, serve_leveled, serve_step


def test_serve_leveled_matches_masked_engine(small_corpus, small_index):
    """The leveled engine must match the single-program LLSP path in quality
    while never exceeding each level's probe bound."""
    from repro.build.pipeline import train_llsp_for_index
    from repro.core.llsp import LLSPConfig

    x, q, topk = small_corpus
    llsp = train_llsp_for_index(
        LLSPConfig(levels=(4, 8, 16, 32), n_trees=20, max_depth=4,
                   n_ratio_features=8),
        small_index, x, q, np.minimum(topk, 20), seed=0)
    cfg = SearchConfig(k=10, nprobe_max=32, pruning="llsp", n_ratio=8,
                       use_kernel=False)
    tk = np.full((q.shape[0],), 10, np.int32)
    out_l = serve_leveled(small_index, llsp, q, tk, cfg, pad=16)
    out_m = serve_step(small_index, llsp, jnp.asarray(q), jnp.asarray(tk), cfg)
    _, ti = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    r_l = recall_at_k(out_l["ids"], np.asarray(ti))
    r_m = recall_at_k(np.asarray(out_m["ids"]), np.asarray(ti))
    assert r_l >= r_m - 0.05, (r_l, r_m)
    bounds = np.asarray(llsp.levels)[out_l["levels"]]
    assert (out_l["nprobe"] <= bounds).all()


def test_shard_centroids_matches_replicated(small_corpus, small_index):
    """cfg.shard_centroids (1-shard degenerate mesh) == replicated scan."""
    x, q, _ = small_corpus
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tk = jnp.full((q.shape[0],), 10, jnp.int32)
    outs = []
    for sc in (False, True):
        cfg = SearchConfig(k=10, nprobe_max=16, pruning="none",
                           use_kernel=False, shard_centroids=sc)
        serve = make_sharded_serve(mesh, cfg)
        d, i, _ = serve(small_index.centroids, small_index.postings,
                        small_index.posting_ids, None, jnp.asarray(q), tk)
        outs.append((np.asarray(d), np.asarray(i)))
    np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=1e-5, atol=1e-5)


def test_graph_baseline_recall_and_hops(small_corpus):
    from repro.core.graph_baseline import batch_search, build_nsw_graph

    x, q, _ = small_corpus
    g = build_nsw_graph(x, degree=24)
    deg = (g.neighbors >= 0).sum(1)
    assert deg.min() >= 2, "random long links keep the graph connected"
    _, ti = brute_force_topk(jnp.asarray(x), jnp.asarray(q[:32]), 10)
    ids, st = batch_search(g, q[:32], 10, beam=64)
    r = recall_at_k(ids, np.asarray(ti))
    assert r > 0.7, r
    assert st.hops > 10, "hop counting (the serialized-I/O chain) must work"


def test_head_padding_preserves_train_and_decode():
    """pad_heads_to only ADDS zero-capacity heads: forward values at init
    differ (extra random heads) but shapes/updates stay sane, and decode
    still matches forward."""
    import dataclasses
    from repro.models.lm import LMConfig, init_params, init_cache, decode_step
    from repro.models.lm.transformer import forward, param_shapes

    cfg = LMConfig("t", n_layers=2, d_model=32, n_heads=3, n_kv=1, d_ff=64,
                   vocab=64, dtype=jnp.float32, q_chunk=8, pad_heads_to=4)
    shapes = param_shapes(cfg)
    assert shapes["layers"]["wq"].shape[3] == 4
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, 64)
    h = forward(p, toks, cfg)
    assert bool(jnp.isfinite(h).all())
    cache = init_cache(cfg, 2, 10)
    outs = []
    for t in range(10):
        logits, cache = decode_step(p, cache, toks[:, t], jnp.int32(t), cfg)
        outs.append(logits)
    oracle = jnp.einsum("bsd,vd->bsv", h, p["embed"])
    err = float(jnp.abs(outs[-1] - oracle[:, -1]).max())
    assert err < 1e-3, err
