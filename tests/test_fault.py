"""Unit coverage for the dormant-since-seed fault machinery
(distributed/fault.py): heartbeat table, failover planning, ownership
masks, and the seeded fault injector the fabric drills replay."""
import numpy as np

from repro.distributed import (
    FaultInjector, HeartbeatMonitor, ownership_mask, plan_failover,
)
from repro.storage.layout import make_replica_map, plan_striping


# -------------------------------------------------------------------------
# HeartbeatMonitor
# -------------------------------------------------------------------------
def test_heartbeat_all_alive_when_beating():
    hb = HeartbeatMonitor(8, miss_threshold=3)
    for _ in range(10):
        hb.tick()
        for n in range(8):
            hb.beat(n)
    assert hb.failed().size == 0
    assert hb.stragglers().size == 0


def test_heartbeat_miss_threshold_boundary():
    hb = HeartbeatMonitor(2, miss_threshold=3)
    hb.beat(0)
    hb.beat(1)
    hb.tick()
    hb.tick()
    hb.beat(1)
    assert hb.failed().size == 0          # node 0 at 2 misses: not yet
    hb.tick()
    assert hb.failed().tolist() == [0]    # exactly miss_threshold misses
    hb.beat(0)                            # a beat resurrects it
    assert hb.failed().size == 0


def test_heartbeat_latency_ema_flags_stragglers():
    hb = HeartbeatMonitor(4, miss_threshold=100, slow_factor=3.0)
    for _ in range(30):
        hb.tick()
        for n in range(4):
            hb.beat(n, latency=20.0 if n == 3 else 1.0)
    assert hb.stragglers().tolist() == [3]
    # a failed node is never also reported straggling
    hb2 = HeartbeatMonitor(4, miss_threshold=2, slow_factor=3.0)
    for _ in range(5):
        hb2.tick()
        for n in range(3):
            hb2.beat(n, latency=1.0)
    assert 3 in hb2.failed()
    assert 3 not in hb2.stragglers()


# -------------------------------------------------------------------------
# plan_failover / ownership_mask
# -------------------------------------------------------------------------
def _rmap(n_clusters=24, n_shards=4, hot=None, n_replicas=2):
    striping = plan_striping(n_clusters, n_shards)
    return make_replica_map(n_clusters, n_shards, striping,
                            hot_clusters=hot, n_replicas=n_replicas)


def test_plan_failover_replicated_loses_nothing():
    """R=2 over every cluster: any single shard death moves its primaries
    to the replica and loses zero clusters."""
    rm = _rmap(hot=np.arange(24))
    for dead in range(4):
        fo = plan_failover(rm, [dead])
        assert fo.n_lost == 0
        assert (fo.owner >= 0).all()
        assert not np.isin(fo.owner, [dead]).any()
        # exactly the dead shard's primaries moved
        moved_expected = np.nonzero(rm.replicas[:, 0] == dead)[0]
        np.testing.assert_array_equal(fo.moved, moved_expected)


def test_plan_failover_unreplicated_clusters_are_lost():
    rm = _rmap(hot=None)                  # R slot 1 all -1
    fo = plan_failover(rm, [2])
    lost_expected = np.nonzero(rm.replicas[:, 0] == 2)[0]
    np.testing.assert_array_equal(fo.lost, lost_expected)
    assert fo.moved.size == 0             # nowhere to move to
    # surviving clusters keep their original owner
    keep = np.setdiff1d(np.arange(24), lost_expected)
    np.testing.assert_array_equal(fo.owner[keep], rm.replicas[keep, 0])


def test_plan_failover_cumulative_failures():
    rm = _rmap(hot=np.arange(24))
    fo1 = plan_failover(rm, [0])
    fo2 = plan_failover(rm, [0, 1])
    assert fo2.n_lost >= fo1.n_lost
    assert not np.isin(fo2.owner, [0, 1]).any()


def test_ownership_mask_round_trips():
    rm = _rmap(hot=np.arange(24))
    for failed in ([], [1], [0, 3]):
        fo = plan_failover(rm, failed)
        mask = ownership_mask(fo.owner, 4)
        assert mask.shape == (4, 24)
        # each non-lost cluster owned exactly once; lost ones by nobody
        counts = mask.sum(axis=0)
        np.testing.assert_array_equal(counts, (fo.owner >= 0).astype(int))
        # round trip: argmax over the shard axis recovers the owner array
        rec = np.where(counts > 0, mask.argmax(axis=0), -1)
        np.testing.assert_array_equal(rec, fo.owner)
        for s in failed:
            assert not mask[s].any()


# -------------------------------------------------------------------------
# FaultInjector
# -------------------------------------------------------------------------
class _FakeFabric:
    def __init__(self, n=4):
        self.n = n
        self.dead = set()
        self.injected = []

    def alive_shards(self):
        return [s for s in range(self.n) if s not in self.dead]

    def inject(self, ev, shard):
        self.injected.append((ev.kind, shard))
        if ev.kind == "kill":
            self.dead.add(shard)


def _run_schedule(seed):
    inj = (FaultInjector(seed=seed)
           .kill(0.1)                     # seeded victim
           .stall(0.2, shard=2, duration_s=0.5, stall_s=0.1)
           .kill(0.3))                    # seeded victim among survivors
    fab = _FakeFabric()
    inj.arm(0.0)
    for t in (0.05, 0.15, 0.25, 0.35):
        inj.poll(t, fab)
    return inj, fab


def test_fault_injector_schedule_is_seeded_and_replayable():
    inj_a, fab_a = _run_schedule(seed=5)
    inj_b, fab_b = _run_schedule(seed=5)
    assert fab_a.injected == fab_b.injected           # bit-for-bit replay
    assert len(fab_a.injected) == 3
    # log carries (relative time, kind, shard) in fire order
    assert [(k, s) for _, k, s in inj_a.log] == fab_a.injected
    # a different seed may pick different victims but fires the same kinds
    inj_c, fab_c = _run_schedule(seed=6)
    assert [k for k, _ in fab_c.injected] == [k for k, _ in fab_a.injected]


def test_fault_injector_victim_excludes_dead_shards():
    inj = FaultInjector(seed=0)
    for _ in range(4):
        inj.kill(0.0)
    fab = _FakeFabric(n=4)
    inj.arm(0.0)
    inj.poll(1.0, fab)
    # all four seeded kills land on distinct shards: victims are drawn from
    # the alive set, which shrinks after each kill
    assert sorted(s for _, s in fab.injected) == [0, 1, 2, 3]
    # nothing left to kill: further events no-op instead of erroring
    inj.kill(2.0)
    assert inj.poll(3.0, fab) == []


def test_fault_injector_events_fire_once_and_in_order():
    inj = FaultInjector(seed=1).kill(0.5, shard=1).corrupt(
        0.1, shard=0, duration_s=0.2)
    fab = _FakeFabric()
    inj.arm(10.0)
    assert inj.poll(10.05, fab) == []                 # nothing due yet
    assert inj.poll(10.6, fab) == [("corrupt", 0), ("kill", 1)]
    assert inj.poll(11.0, fab) == []                  # fired=True latches
    assert len(fab.injected) == 2
