"""Quantized serving tier (PR 8): q8 hot tier + flash f32 re-rank.

Covers the tentpole's layers end to end:
  * FlashTier — mmap read/dedup semantics, stamped ReadEvents, arena
    extent accounting, idempotent release;
  * QuantizedTieredPostings — union/sentinel/remap fetch contract parity
    with the f32 tier, hot-bytes ratio;
  * PrefetchPipeline in q8 mode — recall parity with the f32 pipeline,
    re-rank exactness vs brute force, adaptive-stop behavior, and the
    stamp-measured rerank/scan overlap on pipelined runs;
  * lifecycle — a delta rebuild through ``make_quantized_pipeline``
    reports (and preserves) the q8 tier across the epoch swap.
"""
import dataclasses as dc
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.quantize import ivf_scan_quantized, quantize_postings
from repro.core.search import SearchConfig
from repro.runtime import (
    PrefetchPipeline,
    RerankConfig,
    make_quantized_pipeline,
    overlap_efficiency,
    rerank_overlap_efficiency,
)
from repro.storage import (
    ChunkArena,
    FlashTier,
    QuantizedTieredPostings,
    TieredPostings,
)

CFG = SearchConfig(k=10, nprobe_max=16, pruning="none", use_kernel=False,
                   fused_topk=True)


# -------------------------------------------------------------------------
# FlashTier
# -------------------------------------------------------------------------
def test_flash_tier_read_dedup_and_stats(tmp_path, rng):
    x = rng.normal(size=(100, 8)).astype(np.float32)
    ft = FlashTier(x, str(tmp_path / "t.f32"))
    assert ft.nbytes == 100 * 8 * 4
    ids = np.array([[5, 3, 5, -1], [3, 7, -1, -1]])
    uids, rows = ft.read(ids)
    np.testing.assert_array_equal(uids, [3, 5, 7])   # sorted unique, no -1
    np.testing.assert_allclose(rows, x[[3, 5, 7]])
    ev = ft.stats.events[-1]
    assert ev.rows == 3 and ev.requested == 5        # dedup is visible
    assert ev.bytes == rows.nbytes and ev.end >= ev.start
    assert ft.stats.reads == 1 and ft.stats.rows_read == 3
    ft.release()
    ft.release()                                     # idempotent
    assert not os.path.exists(ft.path)
    with pytest.raises(RuntimeError):
        ft.read(np.array([0]))


def test_flash_tier_arena_accounting(tmp_path, rng):
    x = rng.normal(size=(5000, 8)).astype(np.float32)   # 2 extents @ 4096
    arena = ChunkArena(1, 64 << 20, chunk_bytes=1 << 20)
    free0 = arena.free_bytes
    ft = FlashTier(x, str(tmp_path / "a.f32"), arena=arena, name="fx",
                   epoch=3)
    assert len(ft.extents) == 2
    assert arena.free_bytes < free0
    ft.release()
    assert arena.free_bytes == free0                 # extents recycled


# -------------------------------------------------------------------------
# QuantizedTieredPostings
# -------------------------------------------------------------------------
@pytest.fixture()
def q8_tier(small_index):
    qp = quantize_postings(small_index.postings, small_index.centroids,
                           small_index.posting_ids)
    return QuantizedTieredPostings(
        np.asarray(qp.q8), np.asarray(qp.scale), np.asarray(qp.norm2),
        np.asarray(small_index.centroids),
        np.asarray(small_index.posting_ids)), qp


def test_q8_tier_fetch_matches_quantized_scan(small_index, q8_tier, rng):
    """Scoring the packed fetch output reproduces the resident quantized
    scan — the streamed path serves the same distances the flat path does."""
    tier, qp = q8_tier
    b, p = 4, 5
    cids = rng.integers(0, small_index.n_clusters, (b, p)).astype(np.int32)
    mask = rng.random((b, p)) > 0.3
    g8, scale, norm2, cents, ids, remap = tier.fetch(cids, mask)
    q = rng.normal(size=(b, small_index.dim)).astype(np.float32)
    qc = q[:, None, :] - np.asarray(cents)[None]            # (B, R, D)
    cross = np.einsum("brd,rld->brl", qc,
                      np.asarray(g8, np.float32))
    d_rows = ((qc ** 2).sum(-1)[:, :, None]
              - 2.0 * np.asarray(scale).reshape(1, -1, 1) * cross
              + np.asarray(norm2)[None])                    # (B, R, L)
    rm = np.asarray(remap)
    got = np.take_along_axis(d_rows, rm[:, :, None], axis=1)
    want = np.asarray(ivf_scan_quantized(
        qp, small_index.centroids, jnp.asarray(cids), jnp.asarray(mask),
        jnp.asarray(q)))
    live = np.asarray(ids)[rm] >= 0                     # (B, P, L)
    np.testing.assert_allclose(got[live], want[live], rtol=1e-4, atol=1e-3)
    # masked probes land on the sentinel: ids -1, norm2 0 (no live slot)
    assert (rm[~mask] >= 0).all()
    assert (np.asarray(ids)[rm[~mask]] == -1).all()


def test_q8_tier_hot_bytes_ratio(small_index, q8_tier):
    tier, _ = q8_tier
    f32 = TieredPostings(np.asarray(small_index.postings),
                         np.asarray(small_index.posting_ids))
    f32_bytes = (f32.postings.nbytes + f32.posting_ids.nbytes
                 + np.asarray(small_index.centroids).nbytes)
    assert tier.nbytes() <= 0.35 * f32_bytes


def test_q8_tier_release_fails_loudly(small_index, q8_tier):
    tier, _ = q8_tier
    tier.release()
    with pytest.raises(RuntimeError):
        tier.fetch(np.zeros((1, 1), np.int32))


# -------------------------------------------------------------------------
# q8 pipeline + flash re-rank
# -------------------------------------------------------------------------
def _batches(q, topk, batch=16, n=4):
    return [(q[i * batch:(i + 1) * batch], topk[i * batch:(i + 1) * batch])
            for i in range(n)]


@pytest.fixture()
def q8_pipeline(small_index, small_corpus, tmp_path):
    x, _, _ = small_corpus
    return make_quantized_pipeline(
        small_index, None, CFG, vectors=x,
        flash_path=str(tmp_path / "pipe.f32"), pad_batch=8, row_bucket=32)


def test_q8_pipeline_recall_matches_f32(small_index, small_corpus,
                                        q8_pipeline):
    x, q, topk = small_corpus
    f32 = PrefetchPipeline(
        small_index, None, CFG,
        TieredPostings(np.asarray(small_index.postings),
                       np.asarray(small_index.posting_ids)),
        pad_batch=8, row_bucket=32)
    bs = _batches(q, topk)
    out_q8 = q8_pipeline.run_pipelined(bs, depth=2)
    out_f32 = f32.run_pipelined(bs, depth=2)
    _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    n = sum(b[0].shape[0] for b in bs)
    r_q8 = recall_at_k(np.concatenate([r.ids for r in out_q8])[:, :10],
                       np.asarray(t10)[:n])
    r_f32 = recall_at_k(np.concatenate([r.ids for r in out_f32])[:, :10],
                        np.asarray(t10)[:n])
    assert r_q8 >= r_f32 - 0.01, (r_q8, r_f32)


def test_q8_rerank_distances_are_exact(small_corpus, q8_pipeline):
    """Every returned id inside the flash corpus carries its TRUE f32
    distance after re-rank — not the quantized approximation."""
    x, q, topk = small_corpus
    res = q8_pipeline.serve_batch(q[:16], topk[:16])
    want = ((q[:16, None, :] - x[None]) ** 2).sum(-1)
    live = res.ids >= 0
    got = res.dists[live]
    true = want[np.nonzero(live)[0], res.ids[live]]
    np.testing.assert_allclose(got, true, rtol=1e-4, atol=1e-3)
    t = res.times
    assert t.rerank_end > t.rerank_start > 0
    assert t.rerank_rounds >= 1 and t.rerank_cands > 0


def test_q8_rerank_overlap_measured_from_stamps(small_corpus, q8_pipeline):
    x, q, topk = small_corpus
    out = q8_pipeline.run_pipelined(_batches(q, topk), depth=2)
    times = [r.times for r in out]
    assert all(t.rerank_end > t.rerank_start for t in times)
    # batch i's rerank must overlap batch i+1's scan window (the poller
    # dispatches ahead) — measured, not asserted by construction
    assert rerank_overlap_efficiency(times) > 0.0
    assert overlap_efficiency(times) > 0.0           # gather overlap intact


def test_q8_adaptive_stop_stable_topk(small_index, small_corpus, tmp_path):
    """With a tiny round size the re-ranker should stop before exhausting
    the candidate list once the top-k is stable — and the answer must match
    the exhaustive re-rank exactly."""
    x, q, topk = small_corpus
    full = make_quantized_pipeline(
        small_index, None, CFG, vectors=x,
        flash_path=str(tmp_path / "full.f32"), pad_batch=8, row_bucket=32,
        rerank=RerankConfig(round_size=10_000))
    adaptive = make_quantized_pipeline(
        small_index, None, CFG, vectors=x,
        flash_path=str(tmp_path / "adap.f32"), pad_batch=8, row_bucket=32,
        rerank=RerankConfig(round_size=16, stable_rounds=2))
    rf = full.serve_batch(q[:16], topk[:16])
    ra = adaptive.serve_batch(q[:16], topk[:16])
    assert rf.times.rerank_rounds == 1
    assert ra.times.rerank_rounds >= 2
    if ra.times.rerank_stable_stop:
        assert ra.times.rerank_cands < rf.times.rerank_cands
    # adaptive stop may only cut candidates that cannot enter the top-k:
    # identical ids, identical exact distances
    np.testing.assert_array_equal(ra.ids, rf.ids)
    np.testing.assert_allclose(ra.dists, rf.dists, rtol=1e-5, atol=1e-5)


def test_no_rerank_arm_serves_quantized_distances(small_index, small_corpus):
    """``with_flash=False`` (--no-rerank) serves raw q8 first-pass results:
    no rerank stamps, tier still quantized."""
    x, q, topk = small_corpus
    pipe = make_quantized_pipeline(small_index, None, CFG, vectors=x,
                                   with_flash=False, pad_batch=8,
                                   row_bucket=32)
    assert pipe.flash is None and pipe.quantized
    assert pipe.tier_kind == "q8"
    res = pipe.serve_batch(q[:16], topk[:16])
    assert res.times.rerank_end == 0.0
    _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q[:16]), 10)
    assert recall_at_k(res.ids[:, :10], np.asarray(t10)) >= 0.9


def test_q8_pipeline_warmup_compiles(q8_pipeline):
    assert q8_pipeline.warmup(batch_sizes=(8,)) >= 1


# -------------------------------------------------------------------------
# lifecycle: rebuilds preserve the serving tier
# -------------------------------------------------------------------------
def test_rebuild_preserves_q8_tier(small_corpus, tmp_path):
    from repro.build.kmeans import balanced_hierarchical_kmeans
    from repro.lifecycle import (
        CorpusStore, LiveFreshState, RebuildPolicy, RebuildScheduler,
        UpdateLane, VersionManager, delta_build,
    )
    from repro.runtime import BatchPolicy, DynamicBatcher, ServeEngine

    x, q, _ = small_corpus
    wd = str(tmp_path)
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=48, iters=8)
    corpus = CorpusStore(x)
    index, _ = delta_build(corpus.view(), cents, wd, cluster_len=64,
                           eps=0.2, max_replicas=4, per_task=1000)
    st = LiveFreshState(dim=x.shape[1], capacity=64, n_main=corpus.n)
    lane = UpdateLane(st)

    def mk(index, state):
        p = make_quantized_pipeline(
            index, None, CFG, with_flash=True, pad_batch=8, row_bucket=32,
            fresh_source=state.snapshot,
            flash_path=os.path.join(wd, f"reb-{id(state)}.f32"))
        p.warmup(batch_sizes=(8,))
        return p

    pipe = mk(index, st)
    assert pipe.tier_kind == "q8"
    vm = VersionManager()
    vm.deploy("idx", pipe, fresh=st)
    batcher = DynamicBatcher(
        BatchPolicy(max_batch=16, max_wait_s=0.002, pad=8), ["idx"])
    eng = ServeEngine({"idx": pipe}, batcher, update_lanes={"idx": lane})
    vm.bind(eng)
    sched = RebuildScheduler(
        name="idx", corpus=corpus, centroids=cents, workdir=wd, lane=lane,
        versions=vm, make_pipeline=mk, cluster_len=64,
        policy=RebuildPolicy(delta_fill_frac=0.5, per_task=1000))
    eng.start()
    try:
        lane.submit_insert(
            np.random.default_rng(1).normal(
                loc=6.0, size=(40, x.shape[1])).astype(np.float32))
        rep = sched.rebuild_and_swap(trigger="test")
        # the report pins the serving tier the rebuilt epoch came up on
        assert rep.tier == "q8"
        # inserts reach the new epoch either folded (pumped before the
        # snapshot) or carried (raced the snapshot) — both preserve them
        assert rep.folded_inserts + rep.carried_ops == 40
        rid = eng.submit(q[0], 5, index="idx")
        assert rid >= 0
    finally:
        eng.stop(drain=True)
    comps = eng.qp.poll()
    assert any(c.req_id == rid and c.status == "ok" for c in comps)
