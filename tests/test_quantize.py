"""int8 posting quantization: exactness of the expansion + recall bound."""
import numpy as np
import jax.numpy as jnp

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk, search_flat
from repro.core.quantize import (
    ivf_scan_quantized, quantize_postings, search_flat_quantized,
)
from repro.kernels import ref


def test_quantized_distance_matches_dequantized(small_index, rng):
    qp = quantize_postings(small_index.postings, small_index.centroids)
    q = jnp.asarray(rng.normal(size=(8, small_index.dim)).astype(np.float32))
    cids = jnp.asarray(rng.integers(0, small_index.n_clusters, (8, 5)).astype(np.int32))
    mask = jnp.ones((8, 5), bool)
    got = ivf_scan_quantized(qp, small_index.centroids, cids, mask, q)
    # oracle: dequantize (residual + centroid) then the f32 reference scan
    deq = qp.q8.astype(jnp.float32) * qp.scale \
        + np.asarray(small_index.centroids)[:, None, :]
    want = ref.ivf_scan_ref(jnp.asarray(deq), cids, mask, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_quantized_recall_within_1pct(small_corpus, small_index):
    x, q, _ = small_corpus
    qj = jnp.asarray(q)
    qp = quantize_postings(small_index.postings, small_index.centroids)
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    _, i_f32 = search_flat(small_index, qj, 10, nprobe=16)
    _, i_int8 = search_flat_quantized(small_index, qp, qj, 10, nprobe=16)
    r_f32 = recall_at_k(np.asarray(i_f32), np.asarray(ti))
    r_int8 = recall_at_k(np.asarray(i_int8), np.asarray(ti))
    assert r_int8 >= r_f32 - 0.01, (r_int8, r_f32)
    # 4x smaller payload (int8 vs f32) modulo the tiny norm/scale sidecar
    f32_bytes = small_index.postings.size * 4
    assert qp.nbytes() < 0.3 * f32_bytes


def test_q8_pallas_kernel_matches_jnp(small_index, rng):
    """The int8-residual Pallas kernel vs the pure-jnp quantized scan."""
    from repro.kernels.ivf_scan_q8 import ivf_scan_q8

    qp = quantize_postings(small_index.postings, small_index.centroids)
    q = jnp.asarray(rng.normal(size=(4, small_index.dim)).astype(np.float32))
    cids = jnp.asarray(rng.integers(0, small_index.n_clusters, (4, 6)).astype(np.int32))
    mask = jnp.asarray(rng.random((4, 6)) > 0.3)
    got = ivf_scan_q8(qp.q8, qp.scale, qp.norm2, small_index.centroids,
                      cids, mask, q, interpret=True)
    want = ivf_scan_quantized(qp, small_index.centroids, cids, mask, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_q8_sharded_engine_matches_flat(small_corpus, small_index):
    """Quantized sharded engine (1x1 degenerate mesh) == flat quantized."""
    import jax
    from repro.core.search import SearchConfig, make_sharded_serve_quantized

    x, q, _ = small_corpus
    qp = quantize_postings(small_index.postings, small_index.centroids)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = SearchConfig(k=10, nprobe_max=16, pruning="none", use_kernel=False)
    serve = make_sharded_serve_quantized(mesh, cfg)
    tk = jnp.full((q.shape[0],), 10, jnp.int32)
    d_sh, i_sh, _ = serve(small_index.centroids, qp.q8, qp.scale, qp.norm2,
                          small_index.posting_ids, None, jnp.asarray(q), tk)
    d_fl, i_fl = search_flat_quantized(small_index, qp, jnp.asarray(q), 10, 16)
    np.testing.assert_allclose(np.asarray(d_sh), np.asarray(d_fl),
                               rtol=1e-4, atol=1e-4)
