"""int8 posting quantization: exactness of the expansion + recall bound."""
import numpy as np
import jax.numpy as jnp

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk, search_flat
from repro.core.quantize import (
    ivf_scan_quantized, quantize_postings, search_flat_quantized,
)
from repro.kernels import ref


def test_quantized_distance_matches_dequantized(small_index, rng):
    qp = quantize_postings(small_index.postings, small_index.centroids)
    q = jnp.asarray(rng.normal(size=(8, small_index.dim)).astype(np.float32))
    cids = jnp.asarray(rng.integers(0, small_index.n_clusters, (8, 5)).astype(np.int32))
    mask = jnp.ones((8, 5), bool)
    got = ivf_scan_quantized(qp, small_index.centroids, cids, mask, q)
    # oracle: dequantize (residual + centroid) then the f32 reference scan
    deq = qp.q8.astype(jnp.float32) * qp.scale \
        + np.asarray(small_index.centroids)[:, None, :]
    want = ref.ivf_scan_ref(jnp.asarray(deq), cids, mask, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_quantized_recall_within_1pct(small_corpus, small_index):
    x, q, _ = small_corpus
    qj = jnp.asarray(q)
    qp = quantize_postings(small_index.postings, small_index.centroids)
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    _, i_f32 = search_flat(small_index, qj, 10, nprobe=16)
    _, i_int8 = search_flat_quantized(small_index, qp, qj, 10, nprobe=16)
    r_f32 = recall_at_k(np.asarray(i_f32), np.asarray(ti))
    r_int8 = recall_at_k(np.asarray(i_int8), np.asarray(ti))
    assert r_int8 >= r_f32 - 0.01, (r_int8, r_f32)
    # 4x smaller payload (int8 vs f32) modulo the tiny norm/scale sidecar
    f32_bytes = small_index.postings.size * 4
    assert qp.nbytes() < 0.3 * f32_bytes


def test_q8_pallas_kernel_matches_jnp(small_index, rng):
    """The int8-residual Pallas kernel vs the pure-jnp quantized scan."""
    from repro.kernels.ivf_scan_q8 import ivf_scan_q8

    qp = quantize_postings(small_index.postings, small_index.centroids)
    q = jnp.asarray(rng.normal(size=(4, small_index.dim)).astype(np.float32))
    cids = jnp.asarray(rng.integers(0, small_index.n_clusters, (4, 6)).astype(np.int32))
    mask = jnp.asarray(rng.random((4, 6)) > 0.3)
    got = ivf_scan_q8(qp.q8, qp.scale, qp.norm2, small_index.centroids,
                      cids, mask, q, interpret=True)
    want = ivf_scan_quantized(qp, small_index.centroids, cids, mask, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def _poison_padding(index, rng, magnitude=50.0):
    """Adversarial pad payload: dead slots (id < 0) filled with far-away
    garbage, the way tombstoned/stale rows drift in a live index.  Live
    slots untouched, so any behavior change is the padding's doing."""
    import dataclasses
    pids = np.asarray(index.posting_ids)
    postings = np.array(np.asarray(index.postings))
    dead = pids < 0
    assert dead.any(), "fixture must have padded clusters"
    postings[dead] = rng.normal(
        loc=magnitude, size=(int(dead.sum()), postings.shape[-1])
    ).astype(np.float32)
    return dataclasses.replace(index, postings=jnp.asarray(postings))


def test_dead_slots_excluded_from_scale(small_index, rng):
    """THE PR 8 bugfix: the per-cluster scale must come from LIVE residuals
    only.  With garbage in the padding, the masked quantization is
    bit-identical to quantizing a clean index — the unmasked one inflates
    the scale and coarsens every live code."""
    poisoned = _poison_padding(small_index, rng)
    qp_clean = quantize_postings(small_index.postings, small_index.centroids,
                                 small_index.posting_ids)
    qp_masked = quantize_postings(poisoned.postings, poisoned.centroids,
                                  poisoned.posting_ids)
    np.testing.assert_array_equal(np.asarray(qp_masked.scale),
                                  np.asarray(qp_clean.scale))
    np.testing.assert_array_equal(np.asarray(qp_masked.q8),
                                  np.asarray(qp_clean.q8))
    np.testing.assert_array_equal(np.asarray(qp_masked.norm2),
                                  np.asarray(qp_clean.norm2))
    # dead slots carry zero codes and zero norms — nothing to leak
    dead = np.asarray(small_index.posting_ids) < 0
    assert (np.asarray(qp_masked.q8)[dead] == 0).all()
    assert (np.asarray(qp_masked.norm2)[dead] == 0).all()
    # and the old (unmasked) behavior measurably degrades the grid
    qp_leaky = quantize_postings(poisoned.postings, poisoned.centroids)
    padded = dead.any(axis=1)
    assert (np.asarray(qp_leaky.scale)[padded] >
            np.asarray(qp_masked.scale)[padded]).all()


def test_dead_slot_leak_costs_recall(small_corpus, small_index, rng):
    """End-to-end regression: on the poisoned index the masked quantization
    holds the f32 recall bound; the pre-fix unmasked path loses recall."""
    x, q, _ = small_corpus
    qj = jnp.asarray(q)
    poisoned = _poison_padding(small_index, rng)
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    qp_masked = quantize_postings(poisoned.postings, poisoned.centroids,
                                  poisoned.posting_ids)
    qp_leaky = quantize_postings(poisoned.postings, poisoned.centroids)
    _, i_m = search_flat_quantized(poisoned, qp_masked, qj, 10, nprobe=16)
    _, i_l = search_flat_quantized(poisoned, qp_leaky, qj, 10, nprobe=16)
    r_m = recall_at_k(np.asarray(i_m), np.asarray(ti))
    r_l = recall_at_k(np.asarray(i_l), np.asarray(ti))
    _, i_f32 = search_flat(poisoned, qj, 10, nprobe=16)
    r_f32 = recall_at_k(np.asarray(i_f32), np.asarray(ti))
    assert r_m >= r_f32 - 0.01, (r_m, r_f32)
    assert r_l < r_m - 0.01, (
        f"expected the unmasked scale to cost recall: leaky={r_l:.4f} "
        f"masked={r_m:.4f}")


def test_search_flat_quantized_kernel_dispatch_parity(small_corpus,
                                                      small_index):
    """THE PR 8 dispatch fix: fused=True must actually route to the Pallas
    kernel when asked — and agree with the reference to float tolerance."""
    x, q, _ = small_corpus
    qj = jnp.asarray(q[:16])
    qp = quantize_postings(small_index.postings, small_index.centroids,
                           small_index.posting_ids)
    d_ref, i_ref = search_flat_quantized(small_index, qp, qj, 10, nprobe=8,
                                         fused=True, use_kernel=False)
    d_ker, i_ker = search_flat_quantized(small_index, qp, qj, 10, nprobe=8,
                                         fused=True, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(i_ker), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(d_ker), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-3)


def test_attach_quantized_serves_tier_q8(small_corpus, small_index):
    """attach_quantized + SearchConfig(tier='q8') — the resident serving
    path the engine uses — matches the flat quantized search."""
    from repro.core.quantize import attach_quantized
    from repro.core.search import SearchConfig, serve_step

    x, q, _ = small_corpus
    qj = jnp.asarray(q)
    idx = attach_quantized(small_index)
    assert idx.q8 is not None and idx.qscale is not None
    cfg = SearchConfig(k=10, nprobe_max=16, pruning="none",
                       use_kernel=False, fused_topk=True, tier="q8")
    out = serve_step(idx, None, qj,
                     jnp.full((q.shape[0],), 10, jnp.int32), cfg)
    qp = quantize_postings(small_index.postings, small_index.centroids,
                           small_index.posting_ids)
    d_fl, i_fl = search_flat_quantized(small_index, qp, qj, 10, 16)
    np.testing.assert_array_equal(np.asarray(out["ids"]), np.asarray(i_fl))
    # tier=q8 without an attached payload must fail loudly, not fall back
    import pytest
    with pytest.raises(ValueError):
        serve_step(small_index, None, qj[:4],
                   jnp.full((4,), 10, jnp.int32), cfg)


def test_q8_sharded_engine_matches_flat(small_corpus, small_index):
    """Quantized sharded engine (1x1 degenerate mesh) == flat quantized."""
    import jax
    from repro.core.search import SearchConfig, make_sharded_serve_quantized

    x, q, _ = small_corpus
    qp = quantize_postings(small_index.postings, small_index.centroids)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = SearchConfig(k=10, nprobe_max=16, pruning="none", use_kernel=False)
    serve = make_sharded_serve_quantized(mesh, cfg)
    tk = jnp.full((q.shape[0],), 10, jnp.int32)
    d_sh, i_sh, _ = serve(small_index.centroids, qp.q8, qp.scale, qp.norm2,
                          small_index.posting_ids, None, jnp.asarray(q), tk)
    d_fl, i_fl = search_flat_quantized(small_index, qp, jnp.asarray(q), 10, 16)
    np.testing.assert_allclose(np.asarray(d_sh), np.asarray(d_fl),
                               rtol=1e-4, atol=1e-4)
