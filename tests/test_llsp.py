"""Leveling-learned search pruning: labels, training, end-to-end gains."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.llsp import (
    LLSPConfig, first_hit_ranks, min_nprobe_labels, train_llsp,
)
from repro.core.distance import recall_at_k, squared_l2_chunked, topk_smallest
from repro.core.ivf import brute_force_topk, search_flat
from repro.core.search import SearchConfig, serve_step


def test_min_nprobe_labels_closed_form_matches_sweep():
    rng = np.random.default_rng(0)
    B, k, nmax = 16, 10, 32
    ranks = rng.integers(0, nmax + 1, size=(B, k)).astype(np.int32)
    ranks = np.minimum(ranks, nmax)
    labels = min_nprobe_labels(ranks, 0.9, nmax)
    # brute-force: smallest nprobe whose recall >= target
    for b in range(B):
        for nprobe in range(1, nmax + 1):
            rec = float((ranks[b] < nprobe).mean())
            if rec >= 0.9:
                assert labels[b] == nprobe, (b, labels[b], nprobe)
                break
        else:
            assert labels[b] == nmax


def test_min_nprobe_labels_per_query_topk():
    nmax = 16
    ranks = np.full((2, 8), nmax, np.int32)
    ranks[0, :4] = [0, 1, 2, 3]     # query0: top-4 only (rest padded)
    ranks[1, :8] = 1
    topk = np.array([4, 8])
    labels = min_nprobe_labels(ranks, 1.0, nmax, topk=topk)
    assert labels[0] == 4            # needs rank<4 -> nprobe 4
    assert labels[1] == 2


def test_first_hit_ranks(small_index):
    pids = np.asarray(small_index.posting_ids)
    C = pids.shape[0]
    # true ids: first valid vector of clusters 0 and 1
    v0 = pids[0][pids[0] >= 0][0]
    v1 = pids[1][pids[1] >= 0][0]
    true_ids = np.array([[v0, v1]])
    cid_order = np.arange(C, dtype=np.int64)[None, :]
    n_vec = int(pids.max()) + 1
    ranks = first_hit_ranks(true_ids, cid_order, pids, n_vec, C)
    assert ranks[0, 0] == 0
    # v1 might also live in cluster 0 via closure; rank is <= 1
    assert ranks[0, 1] <= 1


@pytest.fixture(scope="module")
def trained(small_corpus, small_index):
    x, q, topk = small_corpus
    cfg = LLSPConfig(levels=(4, 8, 16, 32), recall_target=0.9,
                     n_ratio_features=8, n_trees=30, max_depth=4)
    qj = jnp.asarray(q)
    cd = squared_l2_chunked(qj, small_index.centroids)
    cdists, cid_order = topk_smallest(cd, 32)
    kmax = int(topk.max())
    _, true_ids = search_flat(small_index, qj, kmax, nprobe=32)
    true_ids = np.asarray(true_ids)
    col = np.arange(kmax)[None, :]
    true_ids = np.where(col < topk[:, None], true_ids, -1)
    params = train_llsp(cfg, q, topk, np.asarray(cid_order), np.asarray(cdists),
                        true_ids, np.asarray(small_index.posting_ids), x.shape[0])
    return cfg, params


def test_llsp_reduces_probes_vs_none(small_corpus, small_index, trained):
    x, q, topk = small_corpus
    cfg, params = trained
    qj = jnp.asarray(q)
    tj = jnp.asarray(np.minimum(topk, 10).astype(np.int32))
    out_llsp = serve_step(small_index, params, qj, tj,
                          SearchConfig(k=10, nprobe_max=32, pruning="llsp",
                                       n_ratio=8, use_kernel=False))
    nprobe = np.asarray(out_llsp["nprobe"])
    assert nprobe.mean() < 32, "LLSP should prune below nmax on average"
    # recall near the non-pruned search at 32 probes
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    out_none = serve_step(small_index, None, qj, tj,
                          SearchConfig(k=10, nprobe_max=32, pruning="none",
                                       use_kernel=False))
    r_llsp = recall_at_k(out_llsp["ids"], np.asarray(ti))
    r_none = recall_at_k(out_none["ids"], np.asarray(ti))
    assert r_llsp >= r_none - 0.1, (r_llsp, r_none)


def test_llsp_per_query_recall_stability(small_corpus, small_index, trained):
    """Paper Fig. 20: under comparable mean probes, LLSP keeps more queries
    above the target than the fixed rule."""
    x, q, topk = small_corpus
    cfg, params = trained
    qj = jnp.asarray(q)
    tj = jnp.full((q.shape[0],), 10, jnp.int32)
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    ti = np.asarray(ti)

    def frac_meeting(out, target=0.9):
        ids = np.asarray(out["ids"])
        per_q = [(len(set(ids[i].tolist()) & set(ti[i].tolist())) / 10)
                 for i in range(ids.shape[0])]
        return float(np.mean(np.asarray(per_q) >= target)), \
            float(np.asarray(out["nprobe"]).mean())

    f_llsp, np_llsp = frac_meeting(serve_step(
        small_index, params, qj, tj,
        SearchConfig(k=10, nprobe_max=32, pruning="llsp", n_ratio=8,
                     use_kernel=False)))
    # fixed rule tuned to spend a similar probe budget
    f_fixed, np_fixed = None, None
    for eps in (0.05, 0.1, 0.2, 0.4, 0.8):
        f, npm = frac_meeting(serve_step(
            small_index, None, qj, tj,
            SearchConfig(k=10, nprobe_max=32, pruning="fixed", eps=eps,
                         use_kernel=False)))
        if npm >= np_llsp or f_fixed is None:
            f_fixed, np_fixed = f, npm
            if npm >= np_llsp:
                break
    assert f_llsp >= f_fixed - 0.05, (
        f"LLSP {f_llsp:.2f}@{np_llsp:.1f} probes vs fixed "
        f"{f_fixed:.2f}@{np_fixed:.1f}")
