"""Elastic construction pool: policies under failure injection."""
import numpy as np
import pytest

from repro.build.elastic import (
    PoolPolicy, SimNode, SimPool, SimTask, TaskFailed, run_tasks,
)


def _tasks(n, work=10.0):
    return [SimTask(i, work) for i in range(n)]


def test_sim_pool_finishes_under_preemption():
    nodes = [SimNode(i, preempt_rate=0.4 if i < 3 else 0.0) for i in range(8)]
    rep = SimPool(nodes, PoolPolicy(seed=1)).run(_tasks(50))
    assert len(rep.task_node) == 50
    assert rep.n_preemptions > 0


def test_sim_pool_evicts_flaky_nodes():
    nodes = [SimNode(0, preempt_rate=1.0)] + [SimNode(i) for i in range(1, 4)]
    rep = SimPool(nodes, PoolPolicy(evict_after=2, seed=2)).run(_tasks(20))
    assert rep.n_evictions >= 1
    # the always-preempting node must not own any finished task
    assert 0 not in set(rep.task_node.values())


def test_sim_pool_scaling_reduces_makespan():
    """Fig. 21b analogue: makespan shrinks as workers grow."""
    makespans = []
    for n_nodes in (1, 4, 16, 64):
        nodes = [SimNode(i) for i in range(n_nodes)]
        rep = SimPool(nodes, PoolPolicy(seed=0)).run(_tasks(128, work=5.0))
        makespans.append(rep.makespan)
    assert makespans == sorted(makespans, reverse=True)
    assert makespans[0] / makespans[-1] > 16  # near-linear region


def test_sim_pool_straggler_backup():
    nodes = [SimNode(0, speed=0.02)] + [SimNode(i) for i in range(1, 6)]
    rep = SimPool(nodes, PoolPolicy(straggler_factor=2.0, seed=3)).run(
        _tasks(24, work=8.0))
    # the slow node's task gets duplicated; makespan must stay near the
    # fast-node serial bound, far below the slow node's 400 time units
    assert rep.makespan < 100
    assert rep.n_backups >= 1


def test_run_tasks_retries_transient_failures():
    attempts = {}

    def mk(i):
        def f():
            attempts[i] = attempts.get(i, 0) + 1
            if i % 3 == 0 and attempts[i] < 3:
                raise RuntimeError("preempted")
            return i * i
        return f

    out = run_tasks([mk(i) for i in range(9)], n_workers=3)
    assert out == [i * i for i in range(9)]
    assert attempts[0] == 3


def test_run_tasks_gives_up_eventually():
    def always_fail():
        raise RuntimeError("dead node")

    with pytest.raises(TaskFailed):
        run_tasks([always_fail], n_workers=1, max_attempts=3)
