"""IVF index construction + search properties."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.build.kmeans import balanced_hierarchical_kmeans, kmeans
from repro.core.distance import recall_at_k
from repro.core.ivf import IVFIndex, brute_force_topk, build_postings, search_flat
from repro.core.search import SearchConfig, serve_step
from repro.core.spann_rules import closure_assign, fixed_eps_nprobe


def test_kmeans_decreases_inertia(rng):
    x = rng.normal(size=(1000, 8)).astype(np.float32)
    _, _, inertia1 = kmeans(x, 10, iters=1)
    _, _, inertia10 = kmeans(x, 10, iters=10)
    assert inertia10 <= inertia1


def test_balanced_kmeans_respects_bound(rng):
    x = rng.normal(size=(3000, 8)).astype(np.float32)
    cents, assign = balanced_hierarchical_kmeans(x, max_cluster_size=50, iters=6)
    sizes = np.bincount(assign, minlength=cents.shape[0])
    assert sizes.max() <= 50
    assert assign.min() >= 0 and assign.max() < cents.shape[0]


def test_closure_assign_invariants(rng):
    x = rng.normal(size=(500, 8)).astype(np.float32)
    cents, _, _ = kmeans(x, 20, iters=5)
    ca = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents),
                                   eps=0.3, max_replicas=4))
    # column 0 is the nearest centroid
    d = ((x[:, None] - cents[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(ca[:, 0], d.argmin(1))
    # no duplicate assignment per row; -1 padding only after valid entries
    for row in ca:
        valid = row[row >= 0]
        assert len(set(valid.tolist())) == len(valid)


def test_closure_rng_rule_prunes(rng):
    x = rng.normal(size=(500, 8)).astype(np.float32)
    cents, _, _ = kmeans(x, 20, iters=5)
    with_rng = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents),
                                         eps=0.5, max_replicas=4, rng_rule=True))
    without = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents),
                                        eps=0.5, max_replicas=4, rng_rule=False))
    assert (with_rng >= 0).sum() <= (without >= 0).sum()


def test_build_postings_fixed_size_and_ids(rng):
    x = rng.normal(size=(300, 8)).astype(np.float32)
    assign = np.stack([rng.integers(0, 10, 300),
                       rng.integers(-1, 10, 300)], axis=1).astype(np.int32)
    postings, ids = build_postings(x, assign, 10, 40)
    assert postings.shape == (10, 40, 8) and ids.shape == (10, 40)
    for c in range(10):
        valid = ids[c][ids[c] >= 0]
        for slot, vid in enumerate(ids[c]):
            if vid >= 0:
                np.testing.assert_array_equal(postings[c, slot], x[vid])


def test_recall_monotonic_in_nprobe(small_corpus, small_index):
    x, q, _ = small_corpus
    qj = jnp.asarray(q)
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    recalls = []
    for nprobe in (2, 8, 32):
        _, ids = search_flat(small_index, qj, 10, nprobe=nprobe)
        recalls.append(recall_at_k(ids, ti))
    assert recalls[0] <= recalls[1] <= recalls[2] + 1e-9
    assert recalls[-1] > 0.8, recalls  # clustered corpus: 32 probes suffice


def test_serve_step_kernel_matches_flat(small_corpus, small_index):
    x, q, _ = small_corpus
    qj = jnp.asarray(q)
    topk_req = jnp.full((q.shape[0],), 10, jnp.int32)
    d0, i0 = search_flat(small_index, qj, 10, nprobe=16)
    for use_kernel in (False, True):
        out = serve_step(small_index, None, qj, topk_req,
                         SearchConfig(k=10, nprobe_max=16, pruning="none",
                                      use_kernel=use_kernel))
        np.testing.assert_allclose(np.asarray(out["dists"]), np.asarray(d0),
                                   rtol=1e-4, atol=1e-4)


def test_fixed_eps_pruning_counts():
    cd = jnp.asarray([[1.0, 1.1, 1.2, 4.0], [1.0, 2.0, 3.0, 4.0]])
    np_ = np.asarray(fixed_eps_nprobe(cd, eps=0.12, nmax=4))
    # (1+eps)^2*1.0 = 1.2544 -> first row keeps 3, second keeps 1
    np.testing.assert_array_equal(np_, [3, 1])


def test_two_level_quantizer_path(small_corpus, small_index):
    from repro.core.ivf import make_group_quantizer
    x, q, _ = small_corpus
    gc, gm = make_group_quantizer(np.asarray(small_index.centroids), 8)
    idx = IVFIndex(small_index.centroids, small_index.postings,
                   small_index.posting_ids,
                   group_centroids=jnp.asarray(gc), group_members=jnp.asarray(gm))
    qj = jnp.asarray(q)
    topk_req = jnp.full((q.shape[0],), 10, jnp.int32)
    out = serve_step(idx, None, qj, topk_req,
                     SearchConfig(k=10, nprobe_max=16, pruning="none",
                                  use_kernel=False, two_level=True,
                                  n_groups_probe=4))
    _, ti = brute_force_topk(jnp.asarray(x), qj, 10)
    r = recall_at_k(out["ids"], ti)
    assert r > 0.5, r   # coarse quantizer trades some recall
