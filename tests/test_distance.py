"""Distance / top-k utilities, with hypothesis property tests.

hypothesis is an OPTIONAL dev dependency (requirements-dev.txt): without it
the property tests are skipped but the rest of this module still runs, so a
lean install never loses the whole tier-1 suite to an ImportError.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.core.distance import (
    dedup_topk, recall_at_k, squared_l2, squared_l2_chunked, topk_smallest,
)


def test_squared_l2_matches_numpy(rng):
    a = rng.normal(size=(20, 7)).astype(np.float32)
    b = rng.normal(size=(31, 7)).astype(np.float32)
    want = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(np.asarray(squared_l2(jnp.asarray(a), jnp.asarray(b))),
                               want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 7, 31, 100])
def test_squared_l2_chunked_invariant_to_chunk(rng, chunk):
    a = rng.normal(size=(9, 5)).astype(np.float32)
    b = rng.normal(size=(23, 5)).astype(np.float32)
    full = squared_l2(jnp.asarray(a), jnp.asarray(b))
    ch = squared_l2_chunked(jnp.asarray(a), jnp.asarray(b), chunk=chunk)
    np.testing.assert_allclose(np.asarray(ch), np.asarray(full), rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_dedup_topk_properties(data):
    n = data.draw(st.integers(4, 40))
    k = data.draw(st.integers(1, 8))
    n_ids = data.draw(st.integers(2, 12))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    dists = rng.uniform(0, 10, size=(2, n)).astype(np.float32)
    ids = rng.integers(-1, n_ids, size=(2, n)).astype(np.int32)
    vals, out_ids = dedup_topk(jnp.asarray(dists), jnp.asarray(ids), k)
    vals, out_ids = np.asarray(vals), np.asarray(out_ids)
    for row in range(2):
        seen = set()
        # valid prefix: no dup ids, ascending distances, each is the MIN
        # distance for that id
        for j in range(k):
            if out_ids[row, j] < 0:
                continue
            i = int(out_ids[row, j])
            assert i not in seen, "duplicate id in top-k"
            seen.add(i)
            mind = dists[row][ids[row] == i].min()
            assert vals[row, j] == pytest.approx(mind, rel=1e-6)
        finite = vals[row][~np.isinf(vals[row])]
        assert np.all(np.diff(finite) >= -1e-6), "not sorted"
        # count of unique valid ids caps the number of finite results
        n_unique = len(set(ids[row][ids[row] >= 0].tolist()))
        assert (out_ids[row] >= 0).sum() == min(k, n_unique)


def test_recall_at_k():
    pred = np.array([[1, 2, 3], [4, 5, 6]])
    true = np.array([[1, 2, 9], [4, 5, 6]])
    assert recall_at_k(pred, true) == pytest.approx(5 / 6)
