"""Static-analysis suite + runtime lock-order checker tests.

Three layers, mirroring the suite's own trust chain:

* rule units — each checker exercised on inline snippets (flag the bad
  shape, stay quiet on the legal twin);
* the repo gate — ``src/`` and ``tests/`` must lint clean against the
  checked-in baseline, the fixture corpus must self-test exactly, and
  the static lock graph must stay acyclic while still seeing the one
  real cross-module edge;
* static/runtime agreement — the PR 9 ``add_done_callback``-under-lock
  deadlock class is flagged by the AST checker AND caught by the
  instrumented ``LockCheck`` on the same fixture in the same run, and
  a seeded multithreaded stress drill (live traffic, update lane,
  mid-flight rebuild+swap, ``stop(drain=True)``) verifies acyclic.
"""
import textwrap
import threading
import types
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import check_locks
from repro.analysis import lint as lint_mod
from repro.analysis.core import FileModel, load_baseline
from repro.analysis.lockcheck import LockCheck
from repro.analysis.project import Project

ROOT = Path(__file__).resolve().parents[1]


def _findings(source, relpath="src/repro/runtime/snippet.py"):
    fm = FileModel(relpath, relpath, textwrap.dedent(source))
    return lint_mod.run_checkers([fm]), fm


def _rules(findings):
    return sorted(f.rule for f in findings)


# -------------------------------------------------------------------------
# the repo gate: lint-clean, self-test, lock graph
# -------------------------------------------------------------------------
def test_repo_lints_clean_against_baseline():
    findings, models = lint_mod.scan(["src", "tests"], root=str(ROOT))
    baseline = load_baseline(lint_mod.DEFAULT_BASELINE)
    new, _, _ = lint_mod.split_findings(findings, models, baseline)
    assert not new, "new lint findings:\n" + "\n".join(
        f.render() for f in new)


def test_fixture_corpus_self_test_matches_exactly(capsys):
    assert lint_mod.self_test() == 0
    out = capsys.readouterr().out
    assert "self-test OK" in out


def test_lint_cli_gates_on_fixtures_and_reports_json(tmp_path):
    # the known-bad corpus must FAIL the gate when scanned explicitly...
    bad = str(ROOT / "src" / "repro" / "analysis" / "fixtures" /
              "bad_unbounded.py")
    out = tmp_path / "findings.json"
    rc = lint_mod.main([bad, "--fixtures", "--no-baseline",
                        "--json", str(out)])
    assert rc == 1
    import json
    payload = json.loads(out.read_text())
    assert payload["counts"]["new"] == 1
    assert any(f["rule"] == "unbounded-growth" for f in payload["new"])
    # ...and the clean twin must pass
    good = str(ROOT / "src" / "repro" / "analysis" / "fixtures" /
               "good_clean.py")
    assert lint_mod.main([good, "--fixtures", "--no-baseline"]) == 0


def test_static_lock_graph_acyclic_with_real_cross_module_edge():
    """The rebuild swap path (LiveFreshState.lock -> VersionManager._lock)
    is the one real cross-module edge; the graph must see it and must
    stay acyclic."""
    _, models = lint_mod.scan(["src"], root=str(ROOT))
    project = Project(models)
    lock_findings, checker = check_locks.check(project)
    assert not [f for f in lock_findings if f.rule == "lock-order-cycle"], \
        [f.render() for f in lock_findings]
    assert ("LiveFreshState.lock", "VersionManager._lock") in checker.edges


# -------------------------------------------------------------------------
# rule units: lock discipline
# -------------------------------------------------------------------------
def test_lock_rule_flags_sleep_under_lock_not_after():
    findings, _ = _findings("""
        import threading
        import time


        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def bad(self):
                with self._lock:
                    time.sleep(0.1)

            def good(self):
                with self._lock:
                    x = 1
                time.sleep(0.1)
                return x
    """)
    assert _rules(findings) == ["lock-blocking-call"]
    assert findings[0].scope.endswith("C.bad")


def test_lock_rule_flags_callback_registration_under_lock():
    findings, _ = _findings("""
        import threading
        from concurrent.futures import ThreadPoolExecutor


        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._exec = ThreadPoolExecutor(1)

            def bad(self, fn, cb):
                with self._lock:
                    fut = self._exec.submit(fn)
                    fut.add_done_callback(cb)
                return fut

            def good(self, fn, cb):
                with self._lock:
                    fut = self._exec.submit(fn)
                fut.add_done_callback(cb)
                return fut
    """)
    assert _rules(findings) == ["lock-callback-under-lock"]
    assert "add_done_callback" in findings[0].message


def test_lock_rule_allows_condition_wait_on_backing_lock_only():
    findings, _ = _findings("""
        import threading


        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._cv = threading.Condition(self._lock)
                self._evt = threading.Event()

            def ok(self):
                with self._cv:
                    self._cv.wait_for(lambda: True, 0.1)

            def bad(self):
                with self._lock:
                    self._evt.wait(0.1)
    """)
    assert _rules(findings) == ["lock-blocking-call"]
    assert findings[0].scope.endswith("C.bad")


def test_lock_rule_detects_order_cycle_and_reentry():
    findings, _ = _findings("""
        import threading


        class C:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()

            def fwd(self):
                with self._a:
                    with self._b:
                        pass

            def bwd(self):
                with self._b:
                    with self._a:
                        pass

            def again(self):
                with self._a:
                    with self._a:
                        pass
    """)
    assert _rules(findings) == ["lock-order-cycle", "lock-order-cycle"]


def test_lock_rule_consistent_order_and_rlock_reentry_are_clean():
    findings, _ = _findings("""
        import threading


        class C:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()
                self._r = threading.RLock()

            def fwd(self):
                with self._a:
                    with self._b:
                        pass

            def fwd2(self):
                with self._a:
                    with self._b:
                        pass

            def again(self):
                with self._r:
                    with self._r:
                        pass
    """)
    assert findings == []


# -------------------------------------------------------------------------
# rule units: bounded memory
# -------------------------------------------------------------------------
BOUNDED_TMPL = """
    import collections


    class Hot:
        def __init__(self):
            self.buf = {init}

        def step(self, item):
            {grow}
"""


def test_bounded_rule_flags_hot_path_append():
    findings, _ = _findings(BOUNDED_TMPL.format(
        init="[]", grow="self.buf.append(item)"))
    assert _rules(findings) == ["unbounded-growth"]


def test_bounded_rule_accepts_deque_maxlen_and_trims():
    findings, _ = _findings(BOUNDED_TMPL.format(
        init="collections.deque(maxlen=64)",
        grow="self.buf.append(item)"))
    assert findings == []
    findings, _ = _findings("""
        class Hot:
            def __init__(self):
                self.buf = []

            def step(self, item):
                self.buf.append(item)
                del self.buf[:-64]
    """)
    assert findings == []


def test_bounded_rule_honors_bounded_by_annotation():
    findings, _ = _findings("""
        class Hot:
            def __init__(self):
                # lint: bounded-by(one entry per shard, fixed at deploy)
                self.buf = []

            def step(self, item):
                self.buf.append(item)
    """)
    assert findings == []


def test_bounded_rule_ignores_cold_paths():
    findings, _ = _findings(
        BOUNDED_TMPL.format(init="[]", grow="self.buf.append(item)"),
        relpath="src/repro/build/snippet.py")
    assert findings == []


# -------------------------------------------------------------------------
# rule units: determinism
# -------------------------------------------------------------------------
def test_determinism_rules_flag_global_unseeded_and_clock_rngs():
    findings, _ = _findings("""
        import random
        import time

        import numpy as np


        def noisy():
            a = np.random.normal(size=3)
            g = np.random.default_rng()
            h = np.random.default_rng(time.time_ns())
            b = random.random()
            return a, g, h, b
    """)
    assert _rules(findings) == ["clock-seed", "global-rng", "global-rng",
                                "unseeded-rng"]


def test_determinism_rules_accept_seeded_generators():
    findings, _ = _findings("""
        import numpy as np


        def clean(seed):
            g = np.random.default_rng(seed)
            h = np.random.default_rng(np.random.SeedSequence(7))
            return g.normal(size=3) + h.normal(size=3)
    """)
    assert findings == []


# -------------------------------------------------------------------------
# rule units: jit hazards
# -------------------------------------------------------------------------
def test_jit_rules_flag_host_sync_and_traced_branch():
    findings, _ = _findings("""
        import jax
        import numpy as np


        @jax.jit
        def bad(x):
            if x > 0:
                return float(x)
            return np.asarray(x)


        @jax.jit
        def shape_ok(x):
            if x.ndim > 2:
                return x.sum()
            return x
    """)
    assert _rules(findings) == ["jit-host-sync", "jit-host-sync",
                                "jit-python-branch"]


def test_jit_rules_treat_static_argnames_as_python_values():
    findings, _ = _findings("""
        import functools

        import jax


        @functools.partial(jax.jit, static_argnames=("k",))
        def topk_pad(x, k):
            if k > 8:
                k = 8
            return x[:k]
    """)
    assert findings == []


# -------------------------------------------------------------------------
# waivers and baseline
# -------------------------------------------------------------------------
def test_inline_waiver_moves_finding_out_of_new():
    findings, fm = _findings("""
        import threading
        import time


        class C:
            def __init__(self):
                self._lock = threading.Lock()

            def bad(self):
                with self._lock:
                    # lint: waive[lock-blocking-call] test-only pause
                    time.sleep(0.001)
    """)
    assert _rules(findings) == ["lock-blocking-call"]
    new, waived, baselined = lint_mod.split_findings(findings, [fm], set())
    assert new == [] and len(waived) == 1 and baselined == []


def test_baseline_key_survives_line_drift():
    findings, fm = _findings(BOUNDED_TMPL.format(
        init="[]", grow="self.buf.append(item)"))
    baseline = {f.key for f in findings}
    # same file with a comment pushed in above: line numbers move, the
    # (rule, path, scope, normalized source) key does not
    shifted, fm2 = _findings(
        "# a leading comment\n# another\n" + textwrap.dedent(
            BOUNDED_TMPL.format(init="[]", grow="self.buf.append(item)")))
    assert [f.line for f in shifted] != [f.line for f in findings]
    new, _, baselined = lint_mod.split_findings(shifted, [fm2], baseline)
    assert new == [] and len(baselined) == 1


# -------------------------------------------------------------------------
# runtime lockcheck: the instrumented companion
# -------------------------------------------------------------------------
def test_lockcheck_records_runtime_lock_order_cycle():
    from repro.analysis.fixtures.bad_lock_cycle import LockCycle
    with LockCheck() as lc:
        c = LockCycle()
        c.forward()
        c.backward()     # single-threaded, so no deadlock — but the
    #                      conflicting order is recorded either way
    assert lc.wrapped >= 2
    cyc = lc.find_cycle()
    assert cyc is not None and len(cyc) == 2
    with pytest.raises(AssertionError, match="lock-order cycle"):
        lc.assert_acyclic()


def test_lockcheck_passes_the_clean_twin():
    from repro.analysis.fixtures.good_clean import CleanAuditor
    with LockCheck() as lc:
        aud = CleanAuditor()
        fut = aud.submit_audit(lambda: 41)
        assert fut.result(timeout=10.0) == 41
        aud.wait_done(timeout=0.05)    # Condition.wait_for on CheckedLock
        aud._exec.shutdown(wait=True)
    assert lc.acquisitions > 0
    assert lc.submits_under_lock()        # evidence recorded...
    assert not lc.callbacks_under_lock()  # ...but no PR 9 event
    lc.verify()                           # default policy: clean


def test_pr9_deadlock_class_static_and_runtime_agree():
    """ISSUE acceptance: the PR 9 fixture is flagged by the static
    checker AND caught by the runtime lockcheck in the same test run."""
    fixtures = str(ROOT / "src" / "repro" / "analysis" / "fixtures")
    findings, _ = lint_mod.scan([fixtures], include_fixtures=True)
    static_hits = [f for f in findings
                   if f.rule == "lock-callback-under-lock"
                   and f.path.endswith("bad_callback_under_lock.py")]
    assert static_hits and static_hits[0].scope.endswith("submit_audit")

    from repro.analysis.fixtures.bad_callback_under_lock import ShadowAuditor
    gate = threading.Event()
    with LockCheck() as lc:
        aud = ShadowAuditor()
        # the audit fn blocks on the gate, so the future is still pending
        # when add_done_callback registers — the registration is recorded
        # without actually tripping the inline-callback deadlock
        fut = aud.submit_audit(gate.wait, 30)
    gate.set()
    assert fut.result(timeout=10.0)
    aud._exec.shutdown(wait=True)

    events = lc.callbacks_under_lock()
    assert events, "runtime checker missed the registration-under-lock"
    kind, held, site, _ = events[0]
    assert kind == "add_done_callback" and held
    assert "bad_callback_under_lock" in site
    with pytest.raises(AssertionError, match="PR 9 deadlock class"):
        lc.verify()


# -------------------------------------------------------------------------
# satellite (b): fabric mode rejects an explicit q8 tier
# -------------------------------------------------------------------------
def test_fabric_rejects_explicit_q8_tier():
    from repro.launch import serve
    args = types.SimpleNamespace(tier="q8", shards=4)
    with pytest.raises(ValueError) as ei:
        serve.run_fabric(args)
    msg = str(ei.value)
    assert msg == serve.FABRIC_TIER_ERROR
    assert "--tier q8 is not supported in fabric mode" in msg
    assert "--shards 0" in msg


# -------------------------------------------------------------------------
# satellite (c): seeded multithreaded stress drill under lockcheck
# -------------------------------------------------------------------------
def test_stress_drain_races_updates_and_swap_under_lockcheck(
        lockcheck, small_corpus, tmp_path):
    """Live searchers + an update lane + a mid-flight rebuild/swap, then
    ``stop(drain=True)`` racing a just-queued update batch — all with
    every repro-constructed lock instrumented.  The ``lockcheck``
    fixture re-verifies at teardown; the strict contract (acyclic, no
    callback-under-lock, no submit-under-lock) is asserted here too so
    a violation prints its evidence."""
    import time

    from repro.build.kmeans import balanced_hierarchical_kmeans
    from repro.core.search import SearchConfig
    from repro.lifecycle import (CorpusStore, LiveFreshState, RebuildPolicy,
                                 RebuildScheduler, UpdateLane, VersionManager,
                                 delta_build)
    from repro.runtime import (BatchPolicy, DynamicBatcher, PrefetchPipeline,
                               ServeEngine)
    from repro.storage import TieredPostings

    x, q, _ = small_corpus
    cfg = SearchConfig(k=5, nprobe_max=8, pruning="none", use_kernel=False,
                       fused_topk=True)
    wd = str(tmp_path)
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=48, iters=8)
    corpus = CorpusStore(x)
    index, _ = delta_build(corpus.view(), cents, wd, cluster_len=64,
                           eps=0.2, max_replicas=4, per_task=1000)
    # everything lock-bearing is constructed HERE, inside the
    # instrumented window (see the lockcheck fixture docstring)
    st = LiveFreshState(dim=x.shape[1], capacity=64, n_main=corpus.n)
    lane = UpdateLane(st)

    def mk(index, state):
        tier = TieredPostings(np.asarray(index.postings),
                              np.asarray(index.posting_ids))
        p = PrefetchPipeline(index, None, cfg, tier=tier, pad_batch=8,
                             row_bucket=32, fresh_source=state.snapshot)
        p.warmup(batch_sizes=(8,))
        return p

    pipe = mk(index, st)
    vm = VersionManager()
    ep0 = vm.deploy("idx", pipe, fresh=st)
    batcher = DynamicBatcher(
        BatchPolicy(max_batch=16, max_wait_s=0.002, pad=8,
                    update_quantum=4), ["idx"])
    eng = ServeEngine({"idx": pipe}, batcher, update_lanes={"idx": lane})
    vm.bind(eng)
    sched = RebuildScheduler(
        name="idx", corpus=corpus, centroids=cents, workdir=wd, lane=lane,
        versions=vm, make_pipeline=mk, cluster_len=64,
        policy=RebuildPolicy(delta_fill_frac=0.9, per_task=1000))

    stop_updates = threading.Event()
    errs = []

    def searcher(seed):
        r = np.random.default_rng(seed)
        try:
            for _ in range(48):
                eng.submit(q[int(r.integers(0, q.shape[0]))], 5, index="idx")
                time.sleep(float(r.uniform(0.0, 0.002)))
        except Exception as e:                      # pragma: no cover
            errs.append(repr(e))

    def updater(seed):
        r = np.random.default_rng(seed)
        try:
            for _ in range(8):
                if stop_updates.is_set():
                    break
                vecs = r.normal(loc=6.0,
                                size=(3, x.shape[1])).astype(np.float32)
                lane.submit_insert(vecs, block=False)
                lane.submit_delete(
                    np.asarray([int(r.integers(0, x.shape[0]))]),
                    block=False)
                time.sleep(float(r.uniform(0.0, 0.003)))
        except Exception as e:                      # pragma: no cover
            errs.append(repr(e))

    threads = [threading.Thread(target=searcher, args=(101,), name="s0"),
               threading.Thread(target=searcher, args=(202,), name="s1"),
               threading.Thread(target=updater, args=(303,), name="u0")]
    eng.start()
    try:
        for t in threads:
            t.start()
        time.sleep(0.02)          # traffic + updates genuinely in the air
        rep = sched.rebuild_and_swap(trigger="stress")
        assert rep is not None
        stop_updates.set()
        for t in threads:
            t.join(timeout=30)
        assert not any(t.is_alive() for t in threads)
        # one final update batch queued right before the drain: stop()
        # must race the lane and still retire everything admitted
        lane.submit_insert(np.full((2, x.shape[1]), 5.5, np.float32),
                           block=False)
    finally:
        stop_updates.set()
        eng.stop(drain=True)
    assert ep0.finalized.wait(5)
    assert not errs, errs
    s = eng.stats
    assert s.completed == s.submitted       # zero dropped across the drill
    assert lockcheck.wrapped > 0 and lockcheck.acquisitions > 0
    assert lockcheck.find_cycle() is None, sorted(lockcheck.edges)
    assert lockcheck.callbacks_under_lock() == []
    assert lockcheck.submits_under_lock() == []
