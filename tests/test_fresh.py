"""Freshness layer (§6.2): insert/delete/search-merge/rebuild-fold."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.fresh import FreshIndex, rebuild
from repro.core.ivf import brute_force_topk
from repro.core.distance import recall_at_k


@pytest.fixture()
def fresh(small_corpus, small_index):
    x, _, _ = small_corpus
    return FreshIndex(main=small_index, capacity=256, n_total=x.shape[0]), x


def test_inserted_vectors_are_findable(fresh, rng):
    fi, x = fresh
    new = rng.normal(loc=5.0, size=(8, x.shape[1])).astype(np.float32)
    ids = fi.insert(new)
    d, i = fi.search(jnp.asarray(new), k=3, nprobe=8)
    for row, want in zip(np.asarray(i), ids):
        assert want in row.tolist()
        # exact self-match at distance ~0
    assert float(np.asarray(d)[:, 0].max()) < 1e-3


def test_deletes_are_filtered(fresh, small_corpus):
    fi, x = fresh
    q = jnp.asarray(x[:4])                  # query = existing vectors
    _, i0 = fi.search(q, k=1, nprobe=8)
    victims = np.asarray(i0)[:, 0]
    fi.delete(victims)
    _, i1 = fi.search(q, k=3, nprobe=8)
    for row, dead in zip(np.asarray(i1), victims):
        assert dead not in row.tolist()


def test_delete_of_delta_insert(fresh, rng):
    fi, x = fresh
    new = rng.normal(loc=7.0, size=(2, x.shape[1])).astype(np.float32)
    ids = fi.insert(new)
    fi.delete(ids[:1])
    _, i = fi.search(jnp.asarray(new), k=2, nprobe=8)
    assert ids[0] not in np.asarray(i).ravel().tolist()
    assert ids[1] in np.asarray(i)[1].tolist()


def test_buffer_full_signals_rebuild(fresh, rng):
    fi, x = fresh
    with pytest.raises(BufferError):
        fi.insert(rng.normal(size=(fi.capacity + 1, x.shape[1])).astype(np.float32))


def test_rebuild_folds_delta_and_drops_tombstones(fresh, rng, tmp_path):
    from repro.build.pipeline import BuildConfig
    fi, x = fresh
    new = rng.normal(loc=5.0, size=(16, x.shape[1])).astype(np.float32)
    ids = fi.insert(new)
    fi.delete(np.arange(10))          # kill 10 old vectors
    fi.delete(ids[:4])                # and 4 fresh ones
    cfg = BuildConfig(max_cluster_size=48, cluster_len=64,
                      coarse_per_task=1500, n_workers=2)
    new_fi, old_ids, vecs = rebuild(fi, x, cfg, str(tmp_path))
    assert vecs.shape[0] == x.shape[0] - 10 + 16 - 4
    assert not set(range(10)) & set(old_ids.tolist())
    assert not set(ids[:4].tolist()) & set(old_ids.tolist())
    # the folded index still answers well
    q = jnp.asarray(vecs[:32])
    _, ti = brute_force_topk(jnp.asarray(vecs), q, 5)
    _, i = new_fi.search(q, k=5, nprobe=16)
    assert recall_at_k(np.asarray(i), np.asarray(ti)) > 0.8
