"""Freshness layer (§6.2): insert/delete/search-merge/rebuild-fold."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.fresh import FreshIndex, rebuild
from repro.core.ivf import brute_force_topk
from repro.core.distance import recall_at_k


@pytest.fixture()
def fresh(small_corpus, small_index):
    x, _, _ = small_corpus
    return FreshIndex(main=small_index, capacity=256, n_total=x.shape[0]), x


def test_inserted_vectors_are_findable(fresh, rng):
    fi, x = fresh
    new = rng.normal(loc=5.0, size=(8, x.shape[1])).astype(np.float32)
    ids = fi.insert(new)
    d, i = fi.search(jnp.asarray(new), k=3, nprobe=8)
    for row, want in zip(np.asarray(i), ids):
        assert want in row.tolist()
        # exact self-match at distance ~0
    assert float(np.asarray(d)[:, 0].max()) < 1e-3


def test_deletes_are_filtered(fresh, small_corpus):
    fi, x = fresh
    q = jnp.asarray(x[:4])                  # query = existing vectors
    _, i0 = fi.search(q, k=1, nprobe=8)
    victims = np.asarray(i0)[:, 0]
    fi.delete(victims)
    _, i1 = fi.search(q, k=3, nprobe=8)
    for row, dead in zip(np.asarray(i1), victims):
        assert dead not in row.tolist()


def test_delete_of_delta_insert(fresh, rng):
    fi, x = fresh
    new = rng.normal(loc=7.0, size=(2, x.shape[1])).astype(np.float32)
    ids = fi.insert(new)
    fi.delete(ids[:1])
    _, i = fi.search(jnp.asarray(new), k=2, nprobe=8)
    assert ids[0] not in np.asarray(i).ravel().tolist()
    assert ids[1] in np.asarray(i)[1].tolist()


def test_buffer_full_signals_rebuild(fresh, rng):
    fi, x = fresh
    with pytest.raises(BufferError):
        fi.insert(rng.normal(size=(fi.capacity + 1, x.shape[1])).astype(np.float32))


def test_rebuild_folds_delta_and_drops_tombstones(fresh, rng, tmp_path):
    from repro.build.pipeline import BuildConfig
    fi, x = fresh
    new = rng.normal(loc=5.0, size=(16, x.shape[1])).astype(np.float32)
    ids = fi.insert(new)
    fi.delete(np.arange(10))          # kill 10 old vectors
    fi.delete(ids[:4])                # and 4 fresh ones
    cfg = BuildConfig(max_cluster_size=48, cluster_len=64,
                      coarse_per_task=1500, n_workers=2)
    new_fi, old_ids, vecs = rebuild(fi, x, cfg, str(tmp_path))
    assert vecs.shape[0] == x.shape[0] - 10 + 16 - 4
    assert not set(range(10)) & set(old_ids.tolist())
    assert not set(ids[:4].tolist()) & set(old_ids.tolist())
    # the folded index still answers well
    q = jnp.asarray(vecs[:32])
    _, ti = brute_force_topk(jnp.asarray(vecs), q, 5)
    _, i = new_fi.search(q, k=5, nprobe=16)
    assert recall_at_k(np.asarray(i), np.asarray(ti)) > 0.8


# -------------------------------------------------------------------------
# edge cases (PR 4 satellite)
# -------------------------------------------------------------------------
def test_insert_exactly_at_capacity(fresh, rng):
    """An exact-fit insert must succeed (the boundary is > capacity, not
    >=); only the NEXT insert signals rebuild-due, and the rejected batch
    must not partially land."""
    fi, x = fresh
    vecs = rng.normal(size=(fi.capacity, x.shape[1])).astype(np.float32)
    ids = fi.insert(vecs)                    # fills the buffer exactly
    assert fi.fill == fi.capacity
    assert len(ids) == fi.capacity
    with pytest.raises(BufferError):
        fi.insert(vecs[:1])
    assert fi.fill == fi.capacity            # rejected insert left no trace
    # every slot is live and findable
    _, i = fi.search(jnp.asarray(vecs[-2:]), k=1, nprobe=8)
    assert np.asarray(i)[:, 0].tolist() == ids[-2:].tolist()


def test_delete_then_reinsert_same_vector(fresh, rng):
    """Delete-then-reinsert: the reinserted vector gets a FRESH id (the id
    space is append-only — tombstones are never resurrected), the old id
    stays filtered, and the new copy is findable."""
    fi, x = fresh
    vec = rng.normal(loc=6.0, size=(1, x.shape[1])).astype(np.float32)
    (id0,) = fi.insert(vec)
    fi.delete(np.asarray([id0]))
    (id1,) = fi.insert(vec)                  # same payload, after the delete
    assert id1 != id0                        # never reuses a tombstoned id
    d, i = fi.search(jnp.asarray(vec), k=3, nprobe=8)
    row = np.asarray(i)[0].tolist()
    assert id1 in row and id0 not in row
    assert float(np.asarray(d)[0, 0]) < 1e-3   # exact self-match survives


def test_tombstoned_delta_ids_filtered_through_serve_leveled(
        small_corpus, small_index, rng):
    """The production merge path: main candidates via serve_leveled (GBDT
    routing + per-level compiled fused scan) merged with the delta buffer —
    tombstoned DELTA ids must be filtered at that merge, not just in the
    brute-force search_flat path."""
    from repro.core.llsp import LLSPConfig, train_llsp
    from repro.core.distance import squared_l2_chunked, topk_smallest
    from repro.core.ivf import search_flat
    from repro.core.search import SearchConfig

    x, q, topk = small_corpus
    fi = FreshIndex(main=small_index, capacity=64, n_total=x.shape[0])
    # tiny LLSP trained exactly like tests/test_llsp.py's fixture
    lcfg = LLSPConfig(levels=(4, 8, 16, 32), recall_target=0.9,
                      n_ratio_features=8, n_trees=30, max_depth=4)
    qj = jnp.asarray(q)
    cd = squared_l2_chunked(qj, small_index.centroids)
    cdists, cid_order = topk_smallest(cd, 32)
    kmax = int(topk.max())
    _, true_ids = search_flat(small_index, qj, kmax, nprobe=32)
    true_ids = np.asarray(true_ids)
    col = np.arange(kmax)[None, :]
    true_ids = np.where(col < topk[:, None], true_ids, -1)
    params = train_llsp(lcfg, q, topk, np.asarray(cid_order),
                        np.asarray(cdists), true_ids,
                        np.asarray(small_index.posting_ids), x.shape[0])

    probe = rng.normal(loc=8.0, size=(2, x.shape[1])).astype(np.float32)
    ids = fi.insert(probe)                   # two delta vectors by the probe
    fi.delete(ids[:1])                       # tombstone one of them
    cfg = SearchConfig(k=5, nprobe_max=32, pruning="llsp", n_ratio=8,
                       use_kernel=False, fused_topk=True)
    d, i = fi.search_leveled(params, probe, 5, cfg, pad=8)
    for row in i:
        assert ids[0] not in row.tolist()    # tombstoned delta id filtered
    assert i[1][0] == ids[1]                 # live delta id wins its query
    assert d[1][0] < 1e-3
    # and the merge agrees with the brute-force reference path
    _, i_ref = fi.search(jnp.asarray(probe), k=5, nprobe=32)
    assert i[1].tolist()[:3] == np.asarray(i_ref)[1].tolist()[:3]
