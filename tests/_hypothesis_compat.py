"""Optional-hypothesis shim: property tests skip cleanly when the optional
dev dependency (requirements-dev.txt) is absent, instead of killing the whole
tier-1 collection with a ModuleNotFoundError.

Usage in test modules:  ``from _hypothesis_compat import given, settings, st``
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _NullStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NullStrategies()
