"""Storage tier: arena allocator (hypothesis), layout/striping, host tier."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.storage import (
    ChunkArena, OutOfSpace, TieredPostings, apply_striping, make_replica_map,
    plan_striping,
)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_arena_alloc_release_invariants(data):
    n_dev = data.draw(st.integers(1, 8))
    arena = ChunkArena(n_devices=n_dev, device_bytes=1 << 24, chunk_bytes=1 << 20)
    live = []
    for i in range(data.draw(st.integers(1, 12))):
        action = data.draw(st.sampled_from(["alloc", "release"]))
        if action == "alloc" or not live:
            name = f"idx{i}"
            n_clusters = data.draw(st.integers(1, 300))
            cbytes = data.draw(st.integers(1, 96 * 1024))
            try:
                exts = arena.allocate_index(name, n_clusters, cbytes)
                live.append(name)
                assert len(exts) == n_clusters
                blocks = -(-cbytes // 4096)
                for e in exts:
                    assert e.n_blocks == blocks
                    assert 0 <= e.device < n_dev
                    # extent inside device capacity
                    assert (e.lba + e.n_blocks) * 4096 <= 1 << 24
                # no overlapping extents within the index
                spans = sorted((e.device, e.lba, e.lba + e.n_blocks) for e in exts)
                for (d1, s1, e1), (d2, s2, e2) in zip(spans, spans[1:]):
                    assert d1 != d2 or e1 <= s2
            except OutOfSpace:
                pass
        else:
            name = data.draw(st.sampled_from(live))
            live.remove(name)
            arena.release_index(name)
        arena.validate()
    # full cleanup returns every chunk
    for name in live:
        arena.release_index(name)
    arena.validate()
    assert arena.used_bytes == 0


def test_arena_cluster_bigger_than_chunk():
    arena = ChunkArena(2, 1 << 22, chunk_bytes=1 << 20)
    with pytest.raises(ValueError):
        arena.allocate_index("big", 1, (1 << 20) + 1)


def test_striping_bijective():
    st_ = plan_striping(100, 8)
    perm = st_.perm
    valid = perm[perm >= 0]
    assert sorted(valid.tolist()) == list(range(100))
    for c in range(100):
        assert perm[st_.cluster_to_row[c]] == c
    # shard loads balanced within 1
    shards = st_.shard_of(np.arange(100))
    counts = np.bincount(shards, minlength=8)
    assert counts.max() - counts.min() <= 1


def test_apply_striping_masks_pads():
    st_ = plan_striping(5, 4)
    postings = np.arange(5 * 2 * 3, dtype=np.float32).reshape(5, 2, 3)
    ids = np.arange(10, dtype=np.int32).reshape(5, 2)
    p, i = apply_striping(st_, postings, ids)
    assert p.shape[0] == 4 * st_.rows_per_shard
    assert (i[st_.perm < 0] == -1).all()


def test_replica_failover_and_loss():
    st_ = plan_striping(64, 8)
    rm = make_replica_map(64, 8, st_, hot_clusters=np.arange(16), n_replicas=2)
    from repro.distributed import plan_failover
    plan = plan_failover(rm, [0])
    owners = plan.owner
    # no owner is the failed shard
    assert not np.any(owners == 0)
    # hot clusters whose primary was shard 0 moved to their replica
    hot_on_0 = [c for c in range(16) if rm.replicas[c, 0] == 0]
    for c in hot_on_0:
        assert owners[c] == rm.replicas[c, 1]
    # cold clusters on shard 0 are lost
    cold_on_0 = [c for c in range(16, 64) if rm.replicas[c, 0] == 0]
    assert set(cold_on_0) <= set(plan.lost.tolist())


def test_tiered_postings_fetch_dedup(rng):
    postings = rng.normal(size=(20, 4, 8)).astype(np.float32)
    ids = rng.integers(0, 100, size=(20, 4)).astype(np.int32)
    tier = TieredPostings(postings, ids)
    cids = np.array([[0, 3, 3], [3, 5, 0]], dtype=np.int32)
    mask = np.array([[True, True, False], [True, True, True]])
    packed, packed_ids, remap = tier.fetch(cids, mask)
    assert tier.stats.clusters_deduped == 3      # {0, 3, 5}
    packed = np.asarray(packed)
    remap = np.asarray(remap)
    for b in range(2):
        for p_ in range(3):
            if mask[b, p_]:
                np.testing.assert_array_equal(packed[remap[b, p_]],
                                              postings[cids[b, p_]])


def test_tiered_postings_sentinel_and_lut_reuse(rng):
    postings = rng.normal(size=(20, 4, 8)).astype(np.float32)
    ids = rng.integers(0, 100, size=(20, 4)).astype(np.int32)
    tier = TieredPostings(postings, ids)
    cids = np.array([[2, 7, -1], [7, 9, 2]], dtype=np.int32)
    mask = np.array([[True, False, True], [True, True, True]])
    packed, packed_ids, remap = tier.fetch(cids, mask)
    remap = np.asarray(remap)
    packed_ids = np.asarray(packed_ids)
    # masked / negative probes land on the sentinel row, whose ids are all
    # -1 (NOT an arbitrary live row-0 alias)
    sentinel = remap[0, 1]
    assert remap[0, 2] == sentinel               # cid -1 while mask True
    assert (packed_ids[sentinel] == -1).all()
    assert sentinel == tier.stats.clusters_deduped  # first row past union
    # the hoisted LUT must not leak state between fetches: a second fetch
    # over a DIFFERENT union (overlapping the first) still remaps correctly
    cids2 = np.array([[9, 4, 2], [4, 4, 9]], dtype=np.int32)
    packed2, _, remap2 = tier.fetch(cids2, None)
    packed2, remap2 = np.asarray(packed2), np.asarray(remap2)
    for b in range(2):
        for p_ in range(3):
            np.testing.assert_array_equal(packed2[remap2[b, p_]],
                                          postings[cids2[b, p_]])


def test_tiered_postings_row_bucketing(rng):
    postings = rng.normal(size=(20, 4, 8)).astype(np.float32)
    ids = rng.integers(0, 100, size=(20, 4)).astype(np.int32)
    tier = TieredPostings(postings, ids)
    cids = np.array([[0, 1, 2]], dtype=np.int32)
    packed, packed_ids, _ = tier.fetch(cids, bucket=8)
    assert packed.shape[0] == 8                  # 3 + sentinel -> bucket
    assert (np.asarray(packed_ids)[3:] == -1).all()
    packed, _, _ = tier.fetch(cids, pad_rows=6, bucket=4)
    assert packed.shape[0] == 8                  # max(4, 6) -> next bucket
    ev = tier.stats.events[-1]
    assert ev.rows == 8 and ev.stream_end >= ev.gather_end >= ev.gather_start
