"""Candidate-compressed serving data path: fused-topk kernels vs the ref.py
oracles (interpret mode), merge edge cases, engine equivalence old vs new,
and the level-cache hygiene fixes."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.distance import dedup_topk, merge_candidate_topk
from repro.kernels import ref
from repro.kernels.ivf_scan import ivf_scan_topk, plan_tile_probes
from repro.kernels.ivf_scan_q8 import ivf_scan_q8_topk


def _assert_candidates_match(gd, gi, wd, wi, tol=1e-4):
    """Distances must match elementwise; ids must match except inside tied
    groups (equal distances), where only the id SET must agree."""
    gd, gi, wd, wi = map(np.asarray, (gd, gi, wd, wi))
    np.testing.assert_allclose(gd, wd, rtol=tol, atol=tol * 10)
    for r in range(gd.shape[0]):
        # compare ids where the distance is unique within the row
        for j in range(gd.shape[1]):
            if np.isinf(wd[r, j]):
                assert gi[r, j] == -1 and wi[r, j] == -1
                continue
            tied = np.isclose(wd[r], wd[r, j], rtol=tol, atol=tol * 10)
            if tied.sum() == 1:
                assert gi[r, j] == wi[r, j], (r, j, gi[r], wi[r])
            else:
                assert set(gi[r][tied].tolist()) == set(wi[r][tied].tolist())


@pytest.mark.parametrize("c,l,d,b,p,bq", [(16, 8, 16, 4, 4, 2),
                                          (64, 32, 64, 8, 16, 4),
                                          (10, 16, 24, 3, 5, 8),
                                          (32, 16, 32, 13, 7, 4)])
def test_ivf_scan_topk_matches_oracle(c, l, d, b, p, bq):
    key = jax.random.PRNGKey(c * l + d + b)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    postings = jax.random.normal(k1, (c, l, d))
    queries = jax.random.normal(k2, (b, d))
    cids = jax.random.randint(k3, (b, p), 0, c)
    mask = jax.random.bernoulli(k4, 0.7, (b, p))
    pids = jax.random.randint(k1, (c, l), -1, 4 * c * l)
    k2c = 12
    gd, gi = ivf_scan_topk(postings, pids, cids, mask, queries,
                           k2=k2c, bq=bq, interpret=True)
    wd, wi = ref.ivf_scan_topk_ref(postings, pids, cids, mask, queries, k2c)
    assert gd.shape == (b, k2c) and gi.shape == (b, k2c)
    _assert_candidates_match(gd, gi, wd, wi)


def test_ivf_scan_topk_all_masked_and_dup_probes():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    postings = jax.random.normal(k1, (8, 4, 8))
    queries = jax.random.normal(k2, (4, 8))
    # every query probes cluster 3 four times (duplicate probes must not
    # produce duplicate candidates), query 0 fully masked
    cids = jnp.full((4, 4), 3, jnp.int32)
    mask = jnp.ones((4, 4), bool).at[0].set(False)
    pids = jnp.arange(8 * 4, dtype=jnp.int32).reshape(8, 4)
    gd, gi = ivf_scan_topk(postings, pids, cids, mask, queries,
                           k2=8, bq=2, interpret=True)
    gd, gi = np.asarray(gd), np.asarray(gi)
    assert np.all(np.isinf(gd[0])) and np.all(gi[0] == -1)
    for r in range(1, 4):
        valid = gi[r][gi[r] >= 0]
        assert len(valid) == 4                      # L=4 slots, scanned once
        assert len(set(valid.tolist())) == len(valid)


def test_ivf_scan_q8_topk_matches_oracle():
    for (c, l, d, b, p, bq) in [(16, 8, 16, 4, 4, 2), (32, 16, 32, 6, 8, 4)]:
        key = jax.random.PRNGKey(c + l + d)
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        cents = jax.random.normal(k1, (c, d))
        post = cents[:, None, :] + 0.1 * jax.random.normal(k2, (c, l, d))
        r = post - cents[:, None, :]
        amax = jnp.max(jnp.abs(r), axis=(1, 2), keepdims=True)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q8 = jnp.clip(jnp.round(r / scale), -127, 127).astype(jnp.int8)
        norm2 = (scale ** 2)[:, :, 0] * jnp.sum(
            q8.astype(jnp.float32) ** 2, axis=-1)
        queries = jax.random.normal(k3, (b, d))
        cids = jax.random.randint(k4, (b, p), 0, c)
        mask = jax.random.bernoulli(k5, 0.8, (b, p))
        pids = jax.random.randint(k4, (c, l), 0, 10_000)
        gd, gi = ivf_scan_q8_topk(q8, scale, norm2, cents, pids, cids, mask,
                                  queries, k2=10, bq=bq, interpret=True)
        wd, wi = ref.ivf_scan_q8_topk_ref(q8, scale, norm2, cents, pids,
                                          cids, mask, queries, 10)
        _assert_candidates_match(gd, gi, wd, wi, tol=1e-3)


def test_plan_tile_probes_covers_union_once():
    cids = jnp.asarray([[1, 5, 1, 7], [5, 5, 2, 0]], jnp.int32)
    mask = jnp.asarray([[True, True, True, False], [True, False, True, True]])
    tc, qsel = plan_tile_probes(cids, mask, bq=2, n_clusters=8)
    tc, qsel = np.asarray(tc), np.asarray(qsel)
    live = qsel.any(axis=-1)[0]
    # union of live probes = {0, 1, 2, 5}; each exactly once
    assert sorted(tc[0][live].tolist()) == [0, 1, 2, 5]
    # cluster 5: probed (live) by BOTH queries -> one slot serves both
    s5 = int(np.nonzero((tc[0] == 5) & live)[0][0])
    assert qsel[0, s5].tolist() == [1, 1]
    # sorted block table => duplicate clusters adjacent (DMA revisit skip)
    assert (np.diff(tc[0]) >= 0).all()


def test_plan_tile_probes_chunked_parity():
    # tile-chunking only bounds the membership intermediate; the plan must
    # be bit-identical for any chunk size (incl. the degenerate chunk=1)
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    cids = jax.random.randint(k1, (48, 16), -1, 30)
    mask = jax.random.bernoulli(k2, 0.7, (48, 16))
    tc0, qs0 = plan_tile_probes(cids, mask, bq=8, n_clusters=30)
    for chunk in (1, 2, 5):
        tc, qs = plan_tile_probes(cids, mask, bq=8, n_clusters=30,
                                  tile_chunk=chunk)
        np.testing.assert_array_equal(np.asarray(tc0), np.asarray(tc))
        np.testing.assert_array_equal(np.asarray(qs0), np.asarray(qs))


# -------------------------------------------------------------------------
# merge edge cases
# -------------------------------------------------------------------------
def test_merge_candidate_topk_matches_dedup_topk(rng):
    for n, k, n_ids in [(8, 4, 3), (24, 10, 40), (16, 20, 6)]:
        dists = rng.uniform(0, 10, size=(5, n)).astype(np.float32)
        ids = rng.integers(-1, n_ids, size=(5, n)).astype(np.int32)
        vm, im = merge_candidate_topk(jnp.asarray(dists), jnp.asarray(ids), k)
        vd, id_ = dedup_topk(jnp.asarray(dists), jnp.asarray(ids), k)
        np.testing.assert_allclose(np.asarray(vm), np.asarray(vd),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(im), np.asarray(id_))


def test_merge_candidate_topk_all_duplicates():
    dists = jnp.asarray([[3.0, 1.0, 2.0, 5.0]])
    ids = jnp.asarray([[7, 7, 7, 7]], jnp.int32)
    vals, out = merge_candidate_topk(dists, ids, 3)
    assert out[0, 0] == 7 and vals[0, 0] == 1.0       # keeps the min
    assert np.all(np.asarray(out)[0, 1:] == -1)
    assert np.all(np.isinf(np.asarray(vals)[0, 1:]))


def test_merge_candidate_topk_all_masked():
    dists = jnp.full((2, 4), jnp.inf)
    ids = jnp.full((2, 4), -1, jnp.int32)
    vals, out = merge_candidate_topk(dists, ids, 3)
    assert np.all(np.asarray(out) == -1)
    assert np.all(np.isinf(np.asarray(vals)))


def test_merge_candidate_topk_k_exceeds_candidates():
    dists = jnp.asarray([[2.0, 1.0]])
    ids = jnp.asarray([[4, 9]], jnp.int32)
    vals, out = merge_candidate_topk(dists, ids, 6)
    assert out.shape == (1, 6)
    assert np.asarray(out)[0, :2].tolist() == [9, 4]
    assert np.all(np.asarray(out)[0, 2:] == -1)


# -------------------------------------------------------------------------
# engine equivalence: candidate-compressed path vs legacy full-distance path
# -------------------------------------------------------------------------
def _mk_cfg(**kw):
    from repro.core.search import SearchConfig
    return SearchConfig(**kw)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_serve_step_fused_matches_legacy(small_corpus, small_index, use_kernel):
    from repro.core.search import serve_step
    x, q, _ = small_corpus
    qj = jnp.asarray(q[:24] if use_kernel else q)
    tk = jnp.full((qj.shape[0],), 10, jnp.int32)
    outs = []
    for fused in (False, True):
        cfg = _mk_cfg(k=10, nprobe_max=16, pruning="none",
                      use_kernel=use_kernel, fused_topk=fused)
        outs.append(serve_step(small_index, None, qj, tk, cfg))
    np.testing.assert_allclose(np.asarray(outs[0]["dists"]),
                               np.asarray(outs[1]["dists"]),
                               rtol=1e-5, atol=1e-5)
    # identical recall by construction (same unique-id top-k)
    a, b = np.asarray(outs[0]["ids"]), np.asarray(outs[1]["ids"])
    for ra, rb in zip(a, b):
        assert set(ra.tolist()) == set(rb.tolist())


def test_sharded_engine_fused_matches_legacy(small_corpus, small_index):
    from repro.core.search import make_sharded_serve
    x, q, _ = small_corpus
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tk = jnp.full((q.shape[0],), 10, jnp.int32)
    outs = []
    for fused in (False, True):
        cfg = _mk_cfg(k=10, nprobe_max=16, pruning="none", use_kernel=False,
                      fused_topk=fused)
        serve = make_sharded_serve(mesh, cfg)
        d, i, _ = serve(small_index.centroids, small_index.postings,
                        small_index.posting_ids, None, jnp.asarray(q), tk)
        outs.append((np.asarray(d), np.asarray(i)))
    np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=1e-5, atol=1e-5)


def test_quantized_sharded_engine_fused_matches_legacy(small_corpus,
                                                       small_index):
    from repro.core.quantize import quantize_postings
    from repro.core.search import make_sharded_serve_quantized
    x, q, _ = small_corpus
    qp = quantize_postings(small_index.postings, small_index.centroids)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tk = jnp.full((q.shape[0],), 10, jnp.int32)
    outs = []
    for fused in (False, True):
        cfg = _mk_cfg(k=10, nprobe_max=16, pruning="none", use_kernel=False,
                      fused_topk=fused)
        serve = make_sharded_serve_quantized(mesh, cfg)
        d, i, _ = serve(small_index.centroids, qp.q8, qp.scale, qp.norm2,
                        small_index.posting_ids, None, jnp.asarray(q), tk)
        outs.append((np.asarray(d), np.asarray(i)))
    np.testing.assert_allclose(outs[0][0], outs[1][0], rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------------------
# level-cache hygiene
# -------------------------------------------------------------------------
def test_level_cache_is_lru_bounded():
    from repro.core import search as s
    s._LEVEL_CACHE.clear()
    for i in range(3 * s._LEVEL_CACHE_MAX):
        s._level_cache_lookup(("key", i), lambda: object())
    assert len(s._LEVEL_CACHE) == s._LEVEL_CACHE_MAX
    # most-recent keys survive
    assert ("key", 3 * s._LEVEL_CACHE_MAX - 1) in s._LEVEL_CACHE
    assert ("key", 0) not in s._LEVEL_CACHE
    s._LEVEL_CACHE.clear()


def test_index_token_stable_and_id_reuse_safe():
    from repro.core import search as s

    class Obj:  # weakref-able stand-in
        pass

    a = Obj()
    t1 = s._index_token(a)
    assert s._index_token(a) == t1          # stable for the live object
    b = Obj()
    assert s._index_token(b) != t1          # distinct objects never alias
    # simulate id() reuse: plant a's entry under another object's id, as if
    # the allocator reused the address — the weakref validation must mint a
    # fresh token instead of returning a's stale one
    c = Obj()
    s._INDEX_TOKENS[id(c)] = s._INDEX_TOKENS[id(a)]
    t3 = s._index_token(c)
    assert t3 != t1
