"""graphcast smoke tests: reduced configs over all four shape regimes."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get
from repro.data import neighbor_sample, random_graph
from repro.models.gnn import GNNConfig, forward, forward_batched, init_params, make_train_step
from repro.optim import adamw


@pytest.fixture(scope="module")
def cfg():
    base = get("graphcast").config
    return dataclasses.replace(base, n_layers=3, d_hidden=32, n_vars=7)


def test_full_graph_train(cfg, rng):
    src, dst, feats = random_graph(100, 400, 16, seed=0)
    batch = {
        "node_feats": jnp.asarray(feats),
        "src": jnp.asarray(src), "dst": jnp.asarray(dst),
        "targets": jnp.asarray(rng.normal(size=(100, 7)).astype(np.float32)),
    }
    params = init_params(cfg, 16, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    opt = adamw.init(params)
    losses = []
    for _ in range(5):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] and np.isfinite(losses[-1])


def test_edge_mask_equivalent_to_dropping_edges(cfg, rng):
    src, dst, feats = random_graph(50, 120, 8, seed=1)
    params = init_params(cfg, 8, jax.random.PRNGKey(0))
    keep = rng.random(120) > 0.3
    full = forward(params, jnp.asarray(feats), jnp.asarray(src),
                   jnp.asarray(dst), cfg, edge_mask=jnp.asarray(keep))
    sub = forward(params, jnp.asarray(feats), jnp.asarray(src[keep]),
                  jnp.asarray(dst[keep]), cfg)
    np.testing.assert_allclose(np.asarray(full), np.asarray(sub),
                               rtol=1e-4, atol=1e-4)


def test_batched_molecule(cfg, rng):
    b, n, e = 8, 12, 20
    feats = rng.normal(size=(b, n, 5)).astype(np.float32)
    src = rng.integers(0, n, size=(b, e)).astype(np.int32)
    dst = rng.integers(0, n, size=(b, e)).astype(np.int32)
    params = init_params(cfg, 5, jax.random.PRNGKey(0))
    out = forward_batched(params, jnp.asarray(feats), jnp.asarray(src),
                          jnp.asarray(dst), cfg)
    assert out.shape == (b, n, cfg.n_vars)
    assert bool(jnp.isfinite(out).all())


def test_neighbor_sampler_validity(rng):
    src, dst, _ = random_graph(200, 2000, 4, seed=2)
    edge_set = set(zip(src.tolist(), dst.tolist()))
    seeds = rng.choice(200, size=16, replace=False).astype(np.int32)
    layers, frontier = neighbor_sample(src, dst, seeds, fanouts=(5, 3))
    assert len(layers) == 2
    prev_frontier = set(np.unique(seeds).tolist())
    for (es, ed) in layers:
        assert es.shape == ed.shape
        # every sampled edge exists in the graph, destination in frontier
        for s_, d_ in zip(es.tolist(), ed.tolist()):
            assert (s_, d_) in edge_set
            assert d_ in prev_frontier
        # fanout bound per destination
        if len(ed):
            counts = np.bincount(ed)
            assert counts.max() <= 5
        prev_frontier |= set(es.tolist())
    assert set(frontier.tolist()) == prev_frontier


def test_sampled_subgraph_trains(cfg, rng):
    """minibatch_lg regime: padded sampled subgraph + node_mask loss."""
    src, dst, feats = random_graph(300, 3000, 16, seed=3)
    seeds = rng.choice(300, size=32, replace=False).astype(np.int32)
    layers, frontier = neighbor_sample(src, dst, seeds, fanouts=(5, 3))
    es = np.concatenate([l[0] for l in layers])
    ed = np.concatenate([l[1] for l in layers])
    target = -(-len(es) // 128) * 128
    pad = target - len(es)
    es = np.pad(es, (0, pad)); ed = np.pad(ed, (0, pad))
    emask = np.arange(target) < (target - pad)
    nmask = np.zeros(300, bool); nmask[seeds] = True
    batch = {
        "node_feats": jnp.asarray(feats),
        "src": jnp.asarray(es), "dst": jnp.asarray(ed),
        "edge_mask": jnp.asarray(emask),
        "targets": jnp.asarray(rng.normal(size=(300, 7)).astype(np.float32)),
        "node_mask": jnp.asarray(nmask),
    }
    params = init_params(cfg, 16, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    opt = adamw.init(params)
    for _ in range(3):
        params, opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))


def test_row_dp_matches_dense_forward(cfg, rng):
    """forward_rowdp (1x1 degenerate mesh, dst-sorted edges) == forward."""
    import jax
    from repro.models.gnn.graphcast import forward_rowdp

    rcfg = dataclasses.replace(cfg, row_dp=True)
    src, dst, feats = random_graph(64, 256, 8, seed=7)
    order = np.argsort(dst, kind="stable")      # the dst-sorted contract
    src, dst = src[order], dst[order]
    params = init_params(rcfg, 8, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    got = forward_rowdp(params, jnp.asarray(feats), jnp.asarray(src),
                        jnp.asarray(dst), rcfg, mesh)
    want = forward(params, jnp.asarray(feats), jnp.asarray(src),
                   jnp.asarray(dst), rcfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
