"""Sharded serving fabric tests: bit-equal cross-shard merge, the live
SQ/CQ fan-out path, p2c replica routing, and the fault drills — kill
(failover, zero-drop), stall (hedge), corrupt (checksum retry), and
both-replicas-down (graceful partial degrade)."""
import time

import numpy as np
import pytest

from repro.core.distance import recall_at_k
from repro.core.search import SearchConfig
from repro.distributed import FaultEvent, FaultInjector, ShardedFabric
from repro.runtime import (
    BatchPolicy, DynamicBatcher, ServeEngine, shard_skewed_trace,
)

CFG = SearchConfig(k=5, nprobe_max=8, pruning="none", use_kernel=False,
                   fused_topk=True)


@pytest.fixture(scope="module")
def queries(small_corpus):
    _, q, _ = small_corpus
    return q.astype(np.float32)


@pytest.fixture(scope="module")
def ref_result(small_index, queries):
    """Single-shard fabric scan — the bit-equality reference."""
    fab = ShardedFabric(small_index, None, CFG, n_shards=1)
    return fab.scan_sync(queries, CFG.k)


def _replicated(small_index, n_shards, **kw):
    """Fabric with EVERY cluster R=2-replicated (no cluster is lost when
    any single shard dies)."""
    n_clusters = int(np.asarray(small_index.postings).shape[0])
    return ShardedFabric(small_index, None, CFG, n_shards=n_shards,
                         hot_clusters=np.arange(n_clusters), **kw)


def _live_batch(fab, queries, deadline=None):
    """Drive one batch through the real stage protocol (worker threads,
    SQ/CQ, hedging, failure detection)."""
    plan = fab.plan(queries, CFG.k, deadline=deadline)
    state = fab.dispatch(fab.prefetch(plan))
    return fab.harvest(state)


# -------------------------------------------------------------------------
# cross-shard merge parity
# -------------------------------------------------------------------------
@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_scan_sync_bit_equal_across_shard_counts(small_index, queries,
                                                 ref_result, n_shards):
    """Partitioning the posting tier over S shards and merging through
    merge_candidate_topk returns the SAME BITS as the single-shard scan —
    the fabric's core correctness invariant (ISSUE acceptance: S=1 vs S=8
    bit-equal)."""
    fab = ShardedFabric(small_index, None, CFG, n_shards=n_shards)
    out = fab.scan_sync(queries, CFG.k)
    np.testing.assert_array_equal(out.ids, ref_result.ids)
    np.testing.assert_array_equal(out.dists, ref_result.dists)
    assert not out.partial.any()


def test_replication_does_not_change_results(small_index, queries,
                                             ref_result):
    fab = _replicated(small_index, 4)
    out = fab.scan_sync(queries, CFG.k)
    np.testing.assert_array_equal(out.ids, ref_result.ids)
    np.testing.assert_array_equal(out.dists, ref_result.dists)


def test_live_queue_path_matches_sync(small_index, queries, ref_result):
    """The threaded SQ/CQ path (p2c routing, worker scans, CQ merge) is
    bit-equal to the deterministic sync path."""
    fab = _replicated(small_index, 4)
    fab.start()
    try:
        out = _live_batch(fab, queries[:32])
    finally:
        fab.stop()
    np.testing.assert_array_equal(out.ids, ref_result.ids[:32])
    np.testing.assert_array_equal(out.dists, ref_result.dists[:32])
    assert not out.partial.any()
    assert fab.stats.replies > 0 and fab.stats.timeouts == 0


# -------------------------------------------------------------------------
# replica routing
# -------------------------------------------------------------------------
def test_p2c_routes_to_less_loaded_replica(small_index):
    """S=2 with full replication puts every cluster on both shards; p2c
    must send the whole union to the idle one when the other is loaded,
    and split near-evenly when loads are equal."""
    fab = _replicated(small_index, 2)
    wanted = np.arange(int(np.asarray(small_index.postings).shape[0]),
                       dtype=np.int64)
    fab._out_per_shard[0] = 1000
    by_shard, lost = fab._p2c_assign(wanted)
    assert not lost and list(by_shard) == [1]
    fab._out_per_shard[0] = 0
    by_shard, _ = fab._p2c_assign(wanted)
    sizes = {s: len(c) for s, c in by_shard.items()}
    assert set(sizes) == {0, 1}
    assert abs(sizes[0] - sizes[1]) <= 1       # load feedback alternates


# -------------------------------------------------------------------------
# fault drills (live workers)
# -------------------------------------------------------------------------
def test_kill_failover_is_zero_loss_when_replicated(small_index, queries,
                                                    ref_result):
    """Silently kill a shard between two live batches: the heartbeat
    monitor finds the corpse, plan_failover reroutes its clusters, its
    epoch retires (tier reclaimed), and the next batch is bit-equal with
    zero partial rows — nothing was lost.  Hedging is disabled so the
    batch can only complete through the failover path."""
    fab = _replicated(small_index, 4, hedge_after_s=30.0, tick_s=0.01)
    fab.start()
    try:
        _live_batch(fab, queries[:16])
        fab.inject(FaultEvent(0.0, "kill", 1, silent=True), 1)
        out = _live_batch(fab, queries[:32])
    finally:
        fab.stop()
    np.testing.assert_array_equal(out.ids, ref_result.ids[:32])
    np.testing.assert_array_equal(out.dists, ref_result.dists[:32])
    assert not out.partial.any()
    # failover bookkeeping: shard 1 declared, no clusters lost
    assert 1 in fab.failed and fab.alive_shards() == [0, 2, 3]
    assert [f["shard"] for f in fab.stats.failovers] == [1]
    assert fab.stats.failovers[0]["lost"] == 0
    assert not fab.owner_mask[1].any()
    # PR 4 safe retire: the dead shard's epoch finalized, tier reclaimed
    assert fab.epochs[1].retired
    assert fab.epochs[1].finalized.wait(timeout=2.0)
    assert fab.nodes[1].tier.released
    # survivors keep their payload
    assert not fab.nodes[0].tier.released


def test_unreplicated_kill_degrades_to_partial(small_index, queries):
    """No replicas (hot_clusters=None): killing a shard loses its
    clusters.  Queries probing them are stamped partial — served from the
    surviving shards, never dropped or hung — and untouched queries stay
    bit-equal to their pre-kill answers.  nprobe is capped so some rows
    miss the dead shard entirely."""
    fab = ShardedFabric(small_index, None, CFG, n_shards=4,
                        tick_s=0.01, harvest_timeout_s=2.0)
    fab.start()
    try:
        pre = fab.harvest(fab.dispatch(fab.prefetch(
            fab.plan(queries[:32], CFG.k, nprobe_cap=2))))
        fab.inject(FaultEvent(0.0, "kill", 1, silent=True), 1)
        out = fab.harvest(fab.dispatch(fab.prefetch(
            fab.plan(queries[:32], CFG.k, nprobe_cap=2))))
    finally:
        fab.stop()
    assert not pre.partial.any()
    assert fab.stats.failovers and fab.stats.failovers[0]["lost"] > 0
    assert fab.lost
    # the stamp matches the probe sets: a row is partial iff it probed a
    # lost cluster
    plan = fab.plan(queries[:32], CFG.k, nprobe_cap=2)
    cids = np.asarray(plan.cids)[:32]
    pmask = np.asarray(plan.pmask)[:32]
    lost = np.fromiter(fab.lost, np.int64, len(fab.lost))
    expect = (np.isin(cids, lost) & pmask & (cids >= 0)).any(axis=1)
    np.testing.assert_array_equal(out.partial, expect)
    assert expect.any()                        # drill actually lost probes
    full = ~expect
    assert full.any()                          # ...but not for every row
    np.testing.assert_array_equal(out.ids[full], pre.ids[full])
    assert fab.stats.partial_queries == int(expect.sum())


def test_stall_triggers_hedge_and_stays_correct(small_index, queries,
                                                ref_result):
    """A stalled (straggler) shard holds its tasks; the router hedges the
    unresolved clusters onto the other replica and the batch completes
    bit-equal without waiting out the stall."""
    fab = _replicated(small_index, 4, hedge_after_s=0.02)
    fab.start()
    try:
        fab.inject(FaultEvent(0.0, "stall", duration_s=3.0, stall_s=1.0), 2)
        t0 = time.monotonic()
        out = _live_batch(fab, queries[:32])
        elapsed = time.monotonic() - t0
    finally:
        fab.stop()
    np.testing.assert_array_equal(out.ids, ref_result.ids[:32])
    np.testing.assert_array_equal(out.dists, ref_result.dists[:32])
    assert not out.partial.any()
    assert fab.stats.hedges >= 1
    assert elapsed < 3.0                       # did not sit out the stall
    assert 2 not in fab.failed                 # straggler, not a corpse


def test_corrupt_payload_detected_and_retried(small_index, queries,
                                              ref_result):
    """A corrupt window flips candidate-id bits after the checksum was
    taken; the router's re-hash rejects the reply and retries until a
    clean copy lands — the merged result never sees the bad bits."""
    fab = _replicated(small_index, 4, retry_budget=500,
                      hedge_after_s=0.02)
    fab.start()
    try:
        fab.inject(FaultEvent(0.0, "corrupt", duration_s=0.15), 3)
        out = _live_batch(fab, queries[:32])
    finally:
        fab.stop()
    np.testing.assert_array_equal(out.ids, ref_result.ids[:32])
    np.testing.assert_array_equal(out.dists, ref_result.dists[:32])
    assert not out.partial.any()
    assert fab.stats.checksum_failures >= 1
    assert fab.stats.retries >= 1
    assert not fab.failed                      # corruption is not death


# -------------------------------------------------------------------------
# the kill-a-shard drill, end-to-end through the serving engine
# -------------------------------------------------------------------------
def test_engine_kill_drill_zero_drop(small_index, queries):
    """ISSUE acceptance drill in miniature: shard-skewed live traffic
    through ServeEngine, FaultInjector kills the hot shard mid-trace.
    Every submitted query completes "ok" (zero dropped, zero partial,
    zero failed), exactly one failover fires with nothing lost, and the
    post-failover fabric stays bit-equal to single-shard."""
    q = queries
    probe = ShardedFabric(small_index, None, CFG, n_shards=4)
    hot = np.nonzero(probe.rmap0.replicas[:, 0] == 1)[0]
    inj = FaultInjector(seed=7).kill(0.25, shard=1)
    fab = ShardedFabric(small_index, None, CFG, n_shards=4,
                        hot_clusters=hot, injector=inj,
                        hedge_after_s=0.05, tick_s=0.02)
    fab.warmup()
    fab.start()
    eng = ServeEngine({"default": fab},
                      DynamicBatcher(BatchPolicy(max_batch=16,
                                                 max_wait_s=0.004),
                                     ["default"]))
    eng.start()
    try:
        hot_rows = np.nonzero(fab.query_shards(q) == 1)[0]
        trace = shard_skewed_trace(300, 0.8, q.shape[0], hot_rows, seed=3)
        inj.arm(time.monotonic())
        t0 = time.monotonic()
        for a in trace:
            while time.monotonic() - t0 < a.t:
                time.sleep(0.0005)
            assert eng.submit(q[a.qrow], CFG.k) >= 0
    finally:
        eng.stop(drain=True)
        fab.stop()
    comps = eng.qp.poll()
    # zero-drop: every submission came back, all clean
    assert eng.stats.submitted == len(trace)
    assert len(comps) == len(trace)
    assert eng.stats.completed == len(trace)
    assert eng.stats.failed == 0 and eng.stats.shed == 0
    assert eng.stats.partial == 0
    assert set(c.status for c in comps) == {"ok"}
    assert all(c.ids is not None for c in comps)
    # the drill really fired and failed over with nothing lost
    assert [(k, s) for _, k, s in inj.log] == [("kill", 1)]
    assert [f["shard"] for f in fab.stats.failovers] == [1]
    assert fab.stats.failovers[0]["lost"] == 0
    assert fab.stats.dead_replies + fab.stats.requeued_tasks >= 1
    # recall parity after failover (acceptance: within 0.002; here exact)
    ref = ShardedFabric(small_index, None, CFG, n_shards=1)
    post = fab.scan_sync(q[:32], CFG.k)
    r = recall_at_k(post.ids, ref.scan_sync(q[:32], CFG.k).ids)
    assert r == 1.0
    assert not post.partial.any()
