"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see the real single CPU device (the 512-device flag belongs to
launch/dryrun.py ONLY, per the dry-run spec)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def lockcheck():
    """Runtime lock-order checker for the concurrency suites.

    Locks/RLocks constructed by ``repro.*`` code inside the test body
    are instrumented; at teardown the recorded acquisition-order graph
    must be acyclic and no ``add_done_callback`` may have been
    registered with a lock held (the PR 9 deadlock class) — a
    violation fails the test even if the run got lucky.  Construct the
    objects under test INSIDE the test: pre-existing locks (session
    fixtures) are not visible.
    """
    from repro.analysis.lockcheck import LockCheck
    lc = LockCheck()
    lc.install()
    try:
        yield lc
    finally:
        lc.uninstall()
    lc.verify()


@pytest.fixture(scope="session")
def small_corpus(rng):
    """Clustered vectors + queries shared by the ANNS tests."""
    from repro.data import PAPER_DATASETS, make_queries, make_vectors
    import dataclasses
    spec = dataclasses.replace(PAPER_DATASETS["sift"], n=4000, dim=24, n_modes=16)
    x = make_vectors(spec)
    q, topk = make_queries(spec, 64)
    return x, q, np.minimum(topk, 50).astype(np.int32)


@pytest.fixture(scope="session")
def small_index(small_corpus):
    import jax.numpy as jnp
    from repro.build.kmeans import balanced_hierarchical_kmeans
    from repro.core.spann_rules import closure_assign
    from repro.core.ivf import IVFIndex, build_postings

    x, _, _ = small_corpus
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=48, iters=8)
    ca = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents),
                                   eps=0.2, max_replicas=4))
    postings, pids = build_postings(x, ca, cents.shape[0], 64)
    return IVFIndex(jnp.asarray(cents), jnp.asarray(postings), jnp.asarray(pids))
