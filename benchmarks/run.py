"""Benchmark driver — one harness per paper table/figure.

  bench_search_topk     Fig 14a / 15 / 16
  bench_search_recall   Fig 14b
  bench_bandwidth       Fig 18
  bench_pruning         Fig 19 / 20 / Tab 3
  bench_construction    Fig 13 / 21
  bench_cost            Tab 4 / 5 / 6
  roofline              §Roofline table from results/dryrun

Prints ``name,us_per_call,derived`` CSV rows; JSON under results/bench/.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (
        bench_bandwidth,
        bench_construction,
        bench_cost,
        bench_pruning,
        bench_search_recall,
        bench_search_topk,
        roofline,
    )

    benches = [
        ("search_topk", bench_search_topk.run),
        ("search_recall", bench_search_recall.run),
        ("bandwidth", bench_bandwidth.run),
        ("pruning", bench_pruning.run),
        ("construction", bench_construction.run),
        ("cost", bench_cost.run),
        ("roofline", roofline.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches:
        if only and only != name:
            continue
        t0 = time.perf_counter()
        try:
            fn()
            print(f"# {name} done in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", flush=True)
    if failures:
        raise SystemExit(f"failed benches: {failures}")


if __name__ == "__main__":
    main()
