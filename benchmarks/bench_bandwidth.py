"""Fig. 18 — bandwidth utilization of the posting-scan path.

TPU adaptation (DESIGN.md §2): the "SSD array bandwidth" term becomes the
memory-bandwidth term of the scan.  We measure, on this container:

  * peak    — a STREAM-like triad over a matched-size buffer (the device
              limit the utilization is normalized by);
  * batched — Helmsman's layout: ONE fused gather+distance over the padded
              posting tensor (dependency-free batch);
  * serial  — SPANN-on-libaio analogue: per-probe python-loop gathers
              (dependency-chained dispatch, the per-command overhead regime).

Utilization = achieved scan bytes/s over peak.  The Gen4->Gen5 "upgrade gain"
analogue (Fig. 18b) is modeled from the paper's device table: systems whose
utilization is software-bound gain little from faster devices; we report
how far each path is from its bandwidth ceiling.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from .common import emit, get_bench_index, save_result, time_fn
from repro.kernels import ref


def _stream_peak(nbytes: int = 1 << 28) -> float:
    a = jnp.ones(nbytes // 4, jnp.float32)
    b = jnp.full(nbytes // 4, 0.5, jnp.float32)

    @jax.jit
    def triad(a, b):
        return a + 2.0 * b

    secs = time_fn(triad, a, b)
    return 3 * nbytes / secs          # read a + read b + write out


def run() -> dict:
    bi = get_bench_index()
    idx = bi.index
    B, P = 256, 32
    rng = np.random.default_rng(0)
    C = idx.n_clusters
    cids = jnp.asarray(rng.integers(0, C, size=(B, P)).astype(np.int32))
    mask = jnp.ones((B, P), bool)
    qj = jnp.asarray(bi.q[:B])
    bytes_scanned = B * P * idx.cluster_len * idx.dim * 4

    peak = _stream_peak()

    fused = jax.jit(lambda c, m, q: ref.ivf_scan_ref(idx.postings, c, m, q))
    t_batched = time_fn(fused, cids, mask, qj)
    bw_batched = bytes_scanned / t_batched

    # serialized per-probe dispatch (the software-overhead regime)
    one = jax.jit(lambda c, q: ref.ivf_scan_ref(
        idx.postings, c, jnp.ones((1, 1), bool), q))
    cids_np = np.asarray(cids)
    one(cids[:1, :1], qj[:1])         # compile
    t0 = time.perf_counter()
    n_serial = 512                    # subsample; per-op cost is constant
    for i in range(n_serial):
        b_, p_ = divmod(i, P)
        jax.block_until_ready(one(cids[b_:b_+1, p_:p_+1], qj[b_:b_+1]))
    t_serial_per = (time.perf_counter() - t0) / n_serial
    bw_serial = (idx.cluster_len * idx.dim * 4) / t_serial_per

    util_batched = bw_batched / peak
    util_serial = bw_serial / peak
    # Fig. 18b analogue: a device 2x faster helps only the non-software-bound
    # path; software-bound utilization stays flat
    payload = {
        "peak_bw_gbs": peak / 1e9,
        "batched_bw_gbs": bw_batched / 1e9,
        "serial_bw_gbs": bw_serial / 1e9,
        "util_batched": util_batched,
        "util_serial": util_serial,
        "util_ratio": util_batched / max(util_serial, 1e-12),
        "paper_claim": "1.6-7.5x utilization vs serialized stacks (Fig 18a)",
    }
    save_result("bandwidth", payload)
    emit("bandwidth.batched", t_batched * 1e6,
         f"util={util_batched:.2f};peak={peak/1e9:.1f}GB/s")
    emit("bandwidth.serial", t_serial_per * 1e6,
         f"util={util_serial:.3f};ratio={payload['util_ratio']:.1f}x")
    return payload


if __name__ == "__main__":
    run()
