"""§Roofline — build the per-(arch x shape x mesh) roofline table from the
dry-run JSON records (results/dryrun/*.json).

Terms (per spec, TPU v5e):
  compute    = HLO_FLOPs(per-chip, trip-corrected) / 197e12
  memory     = HLO_bytes(per-chip)                 / 819e9
  collective = collective_bytes(per-chip)          / 50e9
plus MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and the dominant term.
Emits markdown (for EXPERIMENTS.md) and a machine-readable summary.
"""
from __future__ import annotations

import glob
import json
import os

from .common import ROOT, save_result, emit

DRYRUN = os.path.join(ROOT, "results", "dryrun")


def load_records():
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def table(recs, mesh="single") -> str:
    lines = [
        "| arch | shape | variant | compute | memory | collective | dominant | "
        "useful% | MODEL_FLOPS | status |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        var = r.get("variant", "base")
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                f"SKIP: {r['skipped'][:60]}… |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {var} | — | — | — "
                         f"| — | — | — | FAIL |")
            continue
        rt = r["roofline"]
        useful = r.get("useful_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {var} | {fmt_s(rt['compute_s'])} | "
            f"{fmt_s(rt['memory_s'])} | {fmt_s(rt['collective_s'])} | "
            f"{rt['dominant']} | "
            f"{'' if useful is None else f'{min(useful,9.99)*100:.0f}%'} | "
            f"{r['model_flops']:.2e} | ok |")
    return "\n".join(lines)


def run() -> dict:
    recs = load_records()
    ok = [r for r in recs if r.get("ok")]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    md = "## Single-pod (16x16)\n\n" + table(recs, "single") + \
         "\n\n## Multi-pod (2x16x16)\n\n" + table(recs, "multi")
    out_md = os.path.join(ROOT, "results", "roofline_table.md")
    with open(out_md, "w") as f:
        f.write(md)
    payload = {
        "n_cells": len(recs), "n_ok": len(ok),
        "n_skip": sum(1 for r in recs if r.get("skipped")),
        "n_fail": sum(1 for r in recs if r.get("ok") is False),
        "dominant_counts": doms,
        "table_md": out_md,
    }
    save_result("roofline", payload)
    emit("roofline.cells", 0.0,
         f"ok={payload['n_ok']};skip={payload['n_skip']};"
         f"fail={payload['n_fail']};dom={doms}")
    return payload


if __name__ == "__main__":
    run()
