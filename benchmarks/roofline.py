"""§Roofline — build the per-(arch x shape x mesh) roofline table from the
dry-run JSON records (results/dryrun/*.json).

Terms (per spec, TPU v5e):
  compute    = HLO_FLOPs(per-chip, trip-corrected) / 197e12
  memory     = HLO_bytes(per-chip)                 / 819e9
  collective = collective_bytes(per-chip)          / 50e9
plus MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and the dominant term.
Emits markdown (for EXPERIMENTS.md) and a machine-readable summary.
"""
from __future__ import annotations

import glob
import json
import os

from .common import ROOT, save_result, emit

DRYRUN = os.path.join(ROOT, "results", "dryrun")


def load_records():
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def table(recs, mesh="single") -> str:
    lines = [
        "| arch | shape | variant | compute | memory | collective | dominant | "
        "useful% | MODEL_FLOPS | status |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        var = r.get("variant", "base")
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                f"SKIP: {r['skipped'][:60]}… |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {var} | — | — | — "
                         f"| — | — | — | FAIL |")
            continue
        rt = r["roofline"]
        useful = r.get("useful_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {var} | {fmt_s(rt['compute_s'])} | "
            f"{fmt_s(rt['memory_s'])} | {fmt_s(rt['collective_s'])} | "
            f"{rt['dominant']} | "
            f"{'' if useful is None else f'{min(useful,9.99)*100:.0f}%'} | "
            f"{r['model_flops']:.2e} | ok |")
    return "\n".join(lines)


def scan_writeback_table(
    shapes=((16, 128, 10), (64, 128, 10), (64, 256, 10), (64, 128, 100)),
) -> tuple[str, list]:
    """Analytic HBM-writeback table for the posting-scan stage.

    Per query: legacy writes the full (P, L) f32 distance tile plus the
    (P, L) i32 id gather; the fused-topk kernel writes n_cand (dist, id)
    pairs.  At 819 GB/s (v5e) the legacy writeback alone is a hard roofline
    term the fused path removes — the candidate compression is what makes
    per-query nprobe pruning bandwidth-proportional instead of just
    compute-masked.
    """
    from repro.core.search import _auto_ncand

    rows = []
    lines = [
        "| P | L | k | n_cand | legacy B/query | fused B/query | reduction |",
        "|---|---|---|---|---|---|---|",
    ]
    for p, l, k in shapes:
        k2 = _auto_ncand(k)
        legacy = p * l * (4 + 4)
        fused = k2 * (4 + 4)
        rows.append(dict(P=p, L=l, k=k, n_cand=k2,
                         legacy_bytes=legacy, fused_bytes=fused,
                         reduction_x=legacy / fused))
        lines.append(f"| {p} | {l} | {k} | {k2} | {legacy} | {fused} | "
                     f"{legacy / fused:.0f}x |")
    return "\n".join(lines), rows


def run() -> dict:
    recs = load_records()
    ok = [r for r in recs if r.get("ok")]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    wb_md, wb_rows = scan_writeback_table()
    md = "## Single-pod (16x16)\n\n" + table(recs, "single") + \
         "\n\n## Multi-pod (2x16x16)\n\n" + table(recs, "multi") + \
         "\n\n## Serving data path: posting-scan HBM writeback\n\n" + wb_md
    out_md = os.path.join(ROOT, "results", "roofline_table.md")
    os.makedirs(os.path.dirname(out_md), exist_ok=True)
    with open(out_md, "w") as f:
        f.write(md)
    payload = {
        "n_cells": len(recs), "n_ok": len(ok),
        "n_skip": sum(1 for r in recs if r.get("skipped")),
        "n_fail": sum(1 for r in recs if r.get("ok") is False),
        "dominant_counts": doms,
        "scan_writeback": wb_rows,
        "table_md": out_md,
    }
    save_result("roofline", payload)
    emit("roofline.cells", 0.0,
         f"ok={payload['n_ok']};skip={payload['n_skip']};"
         f"fail={payload['n_fail']};dom={doms}")
    emit("roofline.scan_writeback.P64_L128_k10", 0.0,
         f"{next(r['reduction_x'] for r in wb_rows if r['P'] == 64 and r['L'] == 128 and r['k'] == 10):.0f}x")
    return payload


if __name__ == "__main__":
    run()
