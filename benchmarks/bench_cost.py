"""Tables 4 / 5 / 6 — cost-efficiency, now MEASURED for the serving tiers.

Prices from the paper's Table 1 (Dec 2025): DRAM 8 $/GB, Gen5 SSD 0.2 $/GB.

Two kinds of rows:

  measured — the two Helmsman serving arms, run through the actual
    PrefetchPipeline on this container and priced from the bytes the tier
    objects really hold:
      helmsman_f32  — f32 postings host-resident (TieredPostings streamed):
                      DRAM = centroids + f32 payload + ids, SSD = 0.
      helmsman_q8   — the PR 8 default: q8 hot tier
                      (QuantizedTieredPostings.nbytes() at the DRAM rate) +
                      the f32 corpus demoted to the flash tier
                      (FlashTier.nbytes at the SSD rate), adaptive f32
                      re-rank on.
    The old table priced helmsman from the f32 ``index.postings`` bytes at
    the SSD rate regardless of which tier was actually serving — wrong in
    both directions (the resident arm pays DRAM, the quantized arm holds a
    quarter of those bytes hot).

  modeled — the paper-baseline capacity models (full run only), unchanged:
      HNSW    — vectors + graph edges (~1.5x raw) all in DRAM;
      PipeANN — DRAM budget 25% of raw + full raw on SSD;
      SPANN   — centroids in DRAM, replicated f32 postings on SSD.
    Their throughput comes from the search bench (QPS/core, measured
    compute + modeled SSD term), scaled to the paper's 96-core node.

``--smoke`` builds a tiny fresh index and runs only the two measured arms
with hard gates (hot-bytes ratio, recall parity, re-rank overlap stamps) —
wired into CI so the quantized tier's cost claim is executed, not assumed.
Writes results/bench/bench_cost.json.
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import json
import os
import time

import numpy as np
import jax.numpy as jnp

try:                                   # package mode (benchmarks/run.py)
    from .common import RESULTS, emit, get_bench_index, save_result
except ImportError:                    # standalone mode (CI smoke)
    from common import RESULTS, emit, get_bench_index, save_result

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.search import SearchConfig
from repro.runtime import (
    PrefetchPipeline,
    make_quantized_pipeline,
    overlap_efficiency,
    rerank_overlap_efficiency,
)
from repro.storage import TieredPostings

DRAM_PER_GB = 8.0
SSD_PER_GB = 0.2
CORES_PER_NODE = 96

# CI gate: the quantized hot tier must hold at most this fraction of the
# f32-resident hot bytes (D=32 layout lands ~0.30-0.32x; see ISSUE/ROADMAP).
HOT_RATIO_GATE = 0.35
# CI gate: q8 + flash re-rank recall@10 may trail the f32 arm by at most 1%.
RECALL_SLACK = 0.01


def _build_smoke_index(n=4000, dim=24):
    """Tiny fresh index, no LLSP — seconds, not minutes (the
    bench_serving_pipeline smoke recipe)."""
    from repro.build.kmeans import balanced_hierarchical_kmeans
    from repro.core.ivf import IVFIndex, build_postings
    from repro.core.spann_rules import closure_assign
    from repro.data import PAPER_DATASETS, make_queries, make_vectors

    spec = dc.replace(PAPER_DATASETS["sift"], n=n, dim=dim, n_modes=16)
    x = make_vectors(spec)
    q, topk = make_queries(spec, 256)
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=48, iters=8)
    ca = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents),
                                   eps=0.2, max_replicas=4))
    postings, pids = build_postings(x, ca, cents.shape[0], 64)
    index = IVFIndex(jnp.asarray(cents), jnp.asarray(postings),
                     jnp.asarray(pids))
    return index, None, x, q, np.minimum(topk, 50).astype(np.int32)


def _measure_arm(pipe, q, topk, true10, *, batch: int, repeats: int) -> dict:
    """Run the query set through ``run_pipelined(depth=2)`` and report
    measured throughput + recall + the stamp-derived overlap evidence."""
    nb = len(q) // batch
    batches = [(q[i * batch:(i + 1) * batch], topk[i * batch:(i + 1) * batch])
               for i in range(nb)]
    pipe.warmup((batch,))
    pipe.run_pipelined(batches, depth=2)      # warm every program + allocator
    ts, res = [], None
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = pipe.run_pipelined(batches, depth=2)
        ts.append(time.perf_counter() - t0)
    nq = batch * nb
    times = [r.times for r in res]
    rec = recall_at_k(np.concatenate([r.ids for r in res])[:, :10],
                      true10[:nq])
    row = {
        "tier": pipe.tier_kind,
        "qps": nq / float(np.median(ts)),
        "recall10": float(rec),
        "gather_overlap": overlap_efficiency(times),
        "rerank_overlap": rerank_overlap_efficiency(times),
        "rerank_rounds_mean": float(np.mean([t.rerank_rounds for t in times])),
        "rerank_cands_mean": float(np.mean([t.rerank_cands for t in times])),
        "rerank_stable_stops": int(sum(t.rerank_stable_stop for t in times)),
        "rerank_io_ms_mean": float(
            np.mean([t.rerank_io_s for t in times])) * 1e3,
    }
    return row


def _measured_rows(index, llsp, x, q, topk, true10, *, cfg, batch, repeats,
                   workdir) -> dict:
    """The two serving arms, priced from the tier objects' real bytes."""
    centroids_b = int(np.asarray(index.centroids).nbytes)

    # -- arm 1: f32 host-resident (the pre-PR-8 streamed default) ----------
    f32_tier = TieredPostings(np.asarray(index.postings),
                              np.asarray(index.posting_ids))
    pipe_f32 = PrefetchPipeline(index, llsp, cfg, f32_tier)
    f32_hot_b = (f32_tier.postings.nbytes + f32_tier.posting_ids.nbytes
                 + centroids_b)
    row_f32 = _measure_arm(pipe_f32, q, topk, true10,
                           batch=batch, repeats=repeats)
    row_f32.update(dram_gb=f32_hot_b / 1e9, ssd_gb=0.0, hot_bytes=f32_hot_b)

    # -- arm 2: q8 hot tier + flash-resident f32 + adaptive re-rank --------
    pipe_q8 = make_quantized_pipeline(
        index, llsp, cfg, vectors=x,
        flash_path=os.path.join(workdir, "bench_cost.flash.f32"))
    q8_hot_b = pipe_q8.tier.nbytes()
    flash_b = pipe_q8.flash.nbytes
    row_q8 = _measure_arm(pipe_q8, q, topk, true10,
                          batch=batch, repeats=repeats)
    row_q8.update(dram_gb=q8_hot_b / 1e9, ssd_gb=flash_b / 1e9,
                  hot_bytes=q8_hot_b)
    pipe_q8.flash.release()

    return {"helmsman_f32": row_f32, "helmsman_q8": row_q8,
            "hot_ratio": q8_hot_b / f32_hot_b}


def _price(rows: dict) -> None:
    for r in rows.values():
        r["cost"] = r["dram_gb"] * DRAM_PER_GB + r["ssd_gb"] * SSD_PER_GB
        r["qps_per_dollar"] = r["qps"] / max(r["cost"], 1e-9)


def run(smoke: bool = False) -> dict:
    workdir = RESULTS
    os.makedirs(workdir, exist_ok=True)
    if smoke:
        index, llsp, x, q, topk = _build_smoke_index()
        cfg = SearchConfig(k=10, nprobe_max=16, pruning="none",
                           use_kernel=False, fused_topk=True)
        _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
        true10 = np.asarray(t10)
        batch, repeats = 32, 2
    else:
        bi = get_bench_index()
        index, llsp, x, q, topk, true10 = (bi.index, bi.llsp, bi.x, bi.q,
                                           bi.topk, bi.true10)
        cfg = SearchConfig(k=10, nprobe_max=64, pruning="llsp",
                           use_kernel=False, fused_topk=True)
        batch, repeats = 64, 3

    measured = _measured_rows(index, llsp, x, q, topk, true10, cfg=cfg,
                              batch=batch, repeats=repeats, workdir=workdir)
    rows = {k: v for k, v in measured.items() if k != "hot_ratio"}

    if not smoke:
        # modeled baseline rows need the search bench's QPS/core table
        path = os.path.join(RESULTS, "search_topk.json")
        if not os.path.exists(path):
            try:
                from . import bench_search_topk
            except ImportError:
                # standalone mode: bench_search_topk uses package-relative
                # imports, so load it through the namespace package
                import importlib
                import sys
                root = os.path.dirname(os.path.dirname(
                    os.path.abspath(__file__)))
                if root not in sys.path:
                    sys.path.insert(0, root)
                bench_search_topk = importlib.import_module(
                    "benchmarks.bench_search_topk")
            bench_search_topk.run()
        with open(path) as f:
            search = json.load(f)
        by = {(r["system"], r["topk"]): r for r in search["rows"] if r}
        k = 100 if ("helmsman", 100) in by else max(t for (_, t) in by)
        raw_gb = x.nbytes / 1e9
        graph = by[("graph", k)]
        rows["hnsw"] = dict(
            dram_gb=1.5 * raw_gb, ssd_gb=0.0,
            qps=1.0 / (graph["compute_us"] * 1e-6) * CORES_PER_NODE)
        rows["pipeann"] = dict(
            dram_gb=0.25 * raw_gb, ssd_gb=raw_gb,
            qps=by[("graph", k)]["qps_per_core"] * CORES_PER_NODE)
        rows["spann"] = dict(
            dram_gb=np.asarray(index.centroids).nbytes / 1e9,
            ssd_gb=np.asarray(index.postings).nbytes / 1e9,
            qps=by[("spann", k)]["qps_per_core"] * CORES_PER_NODE)
        # the measured arms ran on this one core; scale to the node like
        # the modeled rows so the $/QPS column compares like with like
        for m in ("helmsman_f32", "helmsman_q8"):
            rows[m]["qps"] *= CORES_PER_NODE

    _price(rows)

    f32, q8 = rows["helmsman_f32"], rows["helmsman_q8"]
    payload = {
        "smoke": smoke,
        "prices": {"dram_per_gb": DRAM_PER_GB, "ssd_per_gb": SSD_PER_GB,
                   "cores_per_node": CORES_PER_NODE},
        "corpus": {"n": int(x.shape[0]), "dim": int(x.shape[1]),
                   "raw_gb": x.nbytes / 1e9},
        "hot_ratio": measured["hot_ratio"],
        "hot_ratio_gate": HOT_RATIO_GATE,
        "recall_slack": RECALL_SLACK,
        "rows": rows,
        "q8_over_f32_qps_per_dollar":
            q8["qps_per_dollar"] / max(f32["qps_per_dollar"], 1e-9),
        "dram_saving_q8_vs_f32": 1 - q8["dram_gb"] / f32["dram_gb"],
        "paper_claims": "250 QPS/$ = 5.4x HNSW, 2.9x SPANN (Tab 4); "
                        ">90% DRAM saving (Tab 5)",
    }
    save_result("bench_cost", payload)
    for m, r in rows.items():
        emit(f"cost.{m}", 0.0,
             f"qps/$={r['qps_per_dollar']:.1f};dram={r['dram_gb']:.4f}GB;"
             f"ssd={r['ssd_gb']:.4f}GB"
             + (f";recall10={r['recall10']:.3f}" if "recall10" in r else ""))

    if smoke:
        hr = payload["hot_ratio"]
        assert hr <= HOT_RATIO_GATE, (
            f"quantized hot tier holds {hr:.3f}x the f32-resident bytes "
            f"(gate {HOT_RATIO_GATE})")
        assert q8["recall10"] >= f32["recall10"] - RECALL_SLACK, (
            f"q8+rerank recall {q8['recall10']:.4f} trails f32 "
            f"{f32['recall10']:.4f} by more than {RECALL_SLACK}")
        assert q8["rerank_rounds_mean"] > 0, "no re-rank rounds stamped"
        assert q8["rerank_overlap"] > 0, (
            "re-rank never overlapped the next batch's scan — the stamps "
            "show no hidden I/O")
        print(f"[smoke] cost bench OK: hot_ratio={hr:.3f} "
              f"(gate {HOT_RATIO_GATE}), recall f32={f32['recall10']:.4f} "
              f"q8={q8['recall10']:.4f}, "
              f"rerank_overlap={q8['rerank_overlap']:.2f}, "
              f"q8 $/QPS advantage="
              f"{payload['q8_over_f32_qps_per_dollar']:.2f}x")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI run with assertions")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
