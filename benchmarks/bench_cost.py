"""Tables 4 / 5 / 6 — cost-efficiency model.

Prices from the paper's Table 1 (Dec 2025): DRAM 8 $/GB, Gen5 SSD 0.2 $/GB.
Capacity model per system (paper §5.1 setup):
  HNSW      — everything in DRAM (vectors + graph edges ~ 1.5x raw).
  PipeANN   — DRAM budget 25% of raw + full raw on SSD.
  SPANN/us  — centroids (8%) in DRAM, postings x replication on SSD
              (DRAM:SSD ~ 1:20).
Throughput ratios come from the measured/modeled search bench (QPS/core),
scaled to the paper's 96-core node.
"""
from __future__ import annotations

import json
import os

import numpy as np

from .common import RESULTS, emit, get_bench_index, save_result

DRAM_PER_GB = 8.0
SSD_PER_GB = 0.2
CORES_PER_NODE = 96


def run() -> dict:
    bi = get_bench_index()
    # throughput rows measured by bench_search_topk (run it if missing)
    path = os.path.join(RESULTS, "search_topk.json")
    if not os.path.exists(path):
        from . import bench_search_topk
        bench_search_topk.run()
    with open(path) as f:
        search = json.load(f)
    by = {(r["system"], r["topk"]): r for r in search["rows"] if r}
    k = 100 if ("helmsman", 100) in by else max(t for (_, t) in by)

    raw_gb = bi.x.nbytes / 1e9
    replication = float((np.asarray(bi.index.posting_ids) >= 0).sum()
                        / bi.x.shape[0])
    centroids_gb = np.asarray(bi.index.centroids).nbytes / 1e9
    postings_gb = np.asarray(bi.index.postings).nbytes / 1e9

    def node_qps(system):
        return by[(system, k)]["qps_per_core"] * CORES_PER_NODE

    rows = {}
    # HNSW: vectors+edges in DRAM; per-core compute ~ graph baseline w/o I/O
    graph = by[("graph", k)]
    hnsw_qps = 1.0 / (graph["compute_us"] * 1e-6) * CORES_PER_NODE
    rows["hnsw"] = dict(dram_gb=1.5 * raw_gb, ssd_gb=0.0, qps=hnsw_qps)
    rows["pipeann"] = dict(dram_gb=0.25 * raw_gb, ssd_gb=raw_gb,
                           qps=node_qps("graph"))
    rows["spann"] = dict(dram_gb=centroids_gb, ssd_gb=postings_gb,
                         qps=node_qps("spann"))
    rows["helmsman"] = dict(dram_gb=centroids_gb, ssd_gb=postings_gb,
                            qps=node_qps("helmsman"))
    for r in rows.values():
        r["cost"] = r["dram_gb"] * DRAM_PER_GB + r["ssd_gb"] * SSD_PER_GB
        r["qps_per_dollar"] = r["qps"] / max(r["cost"], 1e-9)

    eff = {m: r["qps_per_dollar"] for m, r in rows.items()}
    payload = {
        "topk": k,
        "replication": replication,
        "rows": rows,
        "helmsman_over_hnsw": eff["helmsman"] / eff["hnsw"],
        "helmsman_over_spann": eff["helmsman"] / eff["spann"],
        "dram_saving_vs_hnsw": 1 - rows["helmsman"]["dram_gb"] / rows["hnsw"]["dram_gb"],
        "paper_claims": "250 QPS/$ = 5.4x HNSW, 2.9x SPANN (Tab 4); "
                        ">90% DRAM saving (Tab 5)",
    }
    save_result("cost", payload)
    for m, r in rows.items():
        emit(f"cost.{m}", 0.0,
             f"qps/$={r['qps_per_dollar']:.1f};dram={r['dram_gb']:.3f}GB")
    return payload


if __name__ == "__main__":
    run()
