"""Fig. 14a / 15 / 16 — throughput + mean/P99.9 latency across top-k, at a
90% recall target, for Helmsman vs SPANN(fixed-eps) vs the graph baseline.

Compute latencies are measured on this container; the SSD term is modeled
per benchmarks/common.IO_MODEL and reported separately so the measured and
modeled parts are never conflated.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.search import SearchConfig, serve_step

from .common import (
    emit, get_bench_index, io_time_clustered, io_time_graph, recall10,
    save_result, time_fn,
)

TOPKS = (10, 50, 100)
RECALL_TARGET = 0.9


def serving_datapath_compare(bi, k: int = 10, nprobe_max: int = 64) -> dict:
    """Legacy (B, P, L) writeback vs the candidate-compressed fused-topk path.

    Measures both paths end-to-end (serve_step) and reports the per-query
    HBM writeback of the scan stage: the legacy path writes the full (P, L)
    f32 distance tile AND materializes the (P, L) i32 id gather regardless of
    nprobe; the fused path writes n_cand (distance, id) pairs.  The modeled
    bytes are analytic (shape-derived); recalls are measured.  NOTE on the
    latencies: on this CPU container both rows run the jnp oracle
    (use_kernel=False — the interpret-mode Pallas grid is a correctness
    harness, not a fast path), so compute_us shows result PARITY overhead
    only; the writeback win itself is a TPU HBM effect the bytes model
    captures.
    """
    import dataclasses as dc

    from repro.core.search import _auto_ncand

    L = bi.index.cluster_len
    k2 = _auto_ncand(k)
    bytes_legacy = nprobe_max * L * (4 + 4)
    bytes_fused = k2 * (4 + 4)
    b = bi.q.shape[0]
    rows = {}
    base = SearchConfig(k=k, nprobe_max=nprobe_max, pruning="none",
                        n_ratio=16, use_kernel=False)
    for name, cfg in (("legacy", dc.replace(base, fused_topk=False)),
                      ("fused_topk", dc.replace(base, fused_topk=True))):
        qj = jnp.asarray(bi.q)
        tj = jnp.full((b,), k, jnp.int32)
        fn = jax.jit(lambda q, t, cfg=cfg: serve_step(bi.index, None, q, t, cfg))
        out = fn(qj, tj)
        secs = time_fn(fn, qj, tj)
        rows[name] = dict(
            recall10=recall_at_k(np.asarray(out["ids"])[:, :10], bi.true10),
            compute_us=secs / b * 1e6,
            hbm_bytes_written_per_query=(bytes_legacy if name == "legacy"
                                         else bytes_fused),
        )
    rows["writeback_reduction_x"] = bytes_legacy / bytes_fused
    rows["shapes"] = dict(P=nprobe_max, L=L, k=k, n_cand=k2)
    rows["measured_path"] = ("jnp oracle (use_kernel=False); bytes are the "
                             "analytic TPU writeback model")
    return rows


def _clustered(bi, k, pruning, llsp, nprobe_max, eps=0.12, use_kernel=False):
    cfg = SearchConfig(k=k, nprobe_max=nprobe_max, pruning=pruning, eps=eps,
                       n_ratio=16, use_kernel=use_kernel)
    qj = jnp.asarray(bi.q)
    tj = jnp.full((bi.q.shape[0],), k, jnp.int32)
    fn = jax.jit(lambda q, t: serve_step(bi.index, llsp, q, t, cfg))
    out = fn(qj, tj)
    secs = time_fn(fn, qj, tj)
    return out, secs


def run() -> dict:
    bi = get_bench_index()
    xj = jnp.asarray(bi.x)
    qj = jnp.asarray(bi.q)
    b = bi.q.shape[0]
    rows = []
    # graph baseline built once
    from repro.core.graph_baseline import batch_search, build_nsw_graph
    g = build_nsw_graph(bi.x[:10_000], degree=24)   # graph build is O(N^2/chunk)
    _, tg_small = brute_force_topk(jnp.asarray(bi.x[:10_000]), qj, 100)
    tg_small = np.asarray(tg_small)

    from repro.core.search import serve_leveled
    for k in TOPKS:
        _, true_k = brute_force_topk(xj, qj, k)
        true_k = np.asarray(true_k)

        # ---- Helmsman: LLSP (leveled engine) + SPDK stack -----------------
        scfg = SearchConfig(k=k, nprobe_max=64, pruning="llsp", n_ratio=16,
                            use_kernel=False)
        tj = np.full((b,), k, np.int32)
        fn = lambda _=None: serve_leveled(bi.index, bi.llsp, bi.q, tj, scfg)
        out = fn()
        secs = time_fn(fn, None)
        r_helms = recall_at_k(np.asarray(out["ids"]), true_k)
        probes = float(np.asarray(out["nprobe"]).mean())
        t_io = io_time_clustered(probes, "spdk")
        rows.append(dict(system="helmsman", topk=k, recall=r_helms,
                         compute_us=secs / b * 1e6, probes=probes,
                         io_us=t_io * 1e6,
                         qps_io_bound=170e3 / probes,
                         qps_per_core=1.0 / (secs / b + t_io)))

        # ---- SPANN: fixed-eps + libaio stack (matched recall) -------------
        best = None
        for eps in (0.05, 0.1, 0.2, 0.4, 0.8):
            out, secs = _clustered(bi, k, "fixed", None, 64, eps=eps)
            r = recall_at_k(np.asarray(out["ids"]), true_k)
            probes = float(np.asarray(out["nprobe"]).mean())
            t_io = io_time_clustered(probes, "libaio")
            best = dict(system="spann", topk=k, recall=r,
                        compute_us=secs / b * 1e6, probes=probes,
                        io_us=t_io * 1e6,
                        qps_io_bound=35e3 / probes,
                        qps_per_core=1.0 / (secs / b + t_io))
            if r >= min(RECALL_TARGET, r_helms):  # match Helmsman's quality
                break
        rows.append(best)

        # ---- graph baseline (DiskANN-style beam; 10k subset) --------------
        # beam swept until the recall target (greedy walks lengthen with
        # top-k — the paper's Fig. 14a observation)
        import time as _t
        kq = min(k, 100)
        n_eval = 64
        for beam in (max(2 * kq, 32), max(4 * kq, 64), max(8 * kq, 128)):
            lat, hops_all, hits = [], [], 0
            for i in range(n_eval):
                t0 = _t.perf_counter()
                ids, st = batch_search(g, bi.q[i:i + 1], kq, beam=beam)
                lat.append(_t.perf_counter() - t0)
                hops_all.append(st.hops)
                hits += len(set(ids[0].tolist()) & set(tg_small[i, :kq].tolist()))
            if hits / (n_eval * kq) >= RECALL_TARGET:
                break
        lat = np.asarray(lat)
        hops = float(np.mean(hops_all))
        t_io = io_time_graph(int(hops), 0)
        rows.append(dict(system="graph", topk=k, recall=hits / (n_eval * kq),
                         compute_us=float(lat.mean() * 1e6), probes=hops,
                         io_us=t_io * 1e6,
                         compute_p999_us=float(np.quantile(lat, 0.999) * 1e6),
                         qps_io_bound=1.0 / t_io,   # latency-chained reads
                         qps_per_core=1.0 / (float(lat.mean()) + t_io)))

    # headline ratios (paper: 2-16x over DRAM-SSD baselines); the io_bound
    # ratio is the SSD-saturated regime of the paper's 96-core/12-SSD node
    by = {(r["system"], r["topk"]): r for r in rows if r}
    ratios = {}
    for k in TOPKS:
        h, s, gq = (by[("helmsman", k)], by[("spann", k)], by[("graph", k)])
        ratios[k] = {
            "vs_spann": h["qps_per_core"] / s["qps_per_core"],
            "vs_graph": h["qps_per_core"] / gq["qps_per_core"],
            "io_bound_vs_spann": h["qps_io_bound"] / s["qps_io_bound"],
            "io_bound_vs_graph": h["qps_io_bound"] / gq["qps_io_bound"],
        }
    # ---- serving data path: legacy (B,P,L) writeback vs fused top-k -------
    datapath = serving_datapath_compare(bi)
    payload = {"rows": rows, "ratios": ratios, "recall_target": RECALL_TARGET,
               "serving_datapath": datapath}
    save_result("search_topk", payload)
    for r in rows:
        if r:
            emit(f"search.{r['system']}.top{r['topk']}",
                 r["compute_us"] + r["io_us"],
                 f"recall={r['recall']:.3f};qps/core={r['qps_per_core']:.0f}")
    emit("search.datapath.fused_topk", datapath["fused_topk"]["compute_us"],
         f"recall={datapath['fused_topk']['recall10']:.3f};"
         f"writeback_reduction={datapath['writeback_reduction_x']:.0f}x")
    return payload


if __name__ == "__main__":
    run()
