"""Fig. 14b — performance across recall targets at top-10.

Sweeps nprobe to trace the recall/QPS frontier for the batched clustered
scan (Helmsman path) and the fixed-eps baseline; graph baseline evaluated at
matched beams.  Compute measured; I/O modeled (common.IO_MODEL).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distance import recall_at_k
from repro.core.search import SearchConfig, serve_step

from .common import (
    emit, get_bench_index, io_time_clustered, save_result, time_fn,
)


def run() -> dict:
    bi = get_bench_index()
    qj = jnp.asarray(bi.q)
    tj = jnp.full((bi.q.shape[0],), 10, jnp.int32)
    b = bi.q.shape[0]
    frontier = []
    for nprobe in (2, 4, 8, 16, 32, 64):
        cfg = SearchConfig(k=10, nprobe_max=nprobe, pruning="none",
                           use_kernel=False)
        fn = jax.jit(lambda q, t: serve_step(bi.index, None, q, t, cfg))
        out = fn(qj, tj)
        secs = time_fn(fn, qj, tj)
        r = recall_at_k(np.asarray(out["ids"]), bi.true10)
        t_io = io_time_clustered(nprobe, "spdk")
        frontier.append(dict(nprobe=nprobe, recall=r,
                             compute_us=secs / b * 1e6, io_us=t_io * 1e6,
                             qps_per_core=1 / (secs / b + t_io)))
    payload = {"frontier": frontier}
    save_result("search_recall", payload)
    for row in frontier:
        emit(f"recall_frontier.np{row['nprobe']}",
             row["compute_us"] + row["io_us"],
             f"recall={row['recall']:.3f};qps/core={row['qps_per_core']:.0f}")
    return payload


if __name__ == "__main__":
    run()
