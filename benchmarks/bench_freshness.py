"""Freshness bench — mixed search+update load with a LIVE delta rebuild
(paper §6.2/§6.3: the index as a living object under traffic).

One open-loop experiment, three claims, all counter-asserted:

1. **Mixed load** — a seeded Poisson search stream and a seeded Poisson
   insert/delete stream replay together against the lifecycle engine
   (search lane + update lane on the same poller).  Reported: achieved
   q/s AND update ops/s, plus insert-to-visible p50/p99 **from stamps**
   (submit -> first harvested search batch whose captured snapshot covers
   the op — measured by the lane, not inferred from queue depths).
2. **Live delta rebuild + atomic swap** — the scheduler triggers on
   delta-fill mid-trace, rebuilds stage 2 in delta mode on a background
   thread while the engine serves, and swaps epochs atomically.  Recall@10
   is probed through the engine BEFORE the rebuild, DURING it (engine
   serving from the old epoch + delta), and AFTER the swap, each against
   fresh brute-force ground truth over the then-live vector set; the swap
   drops zero batches (engine completed == submitted - rejected, old epoch
   finalized with its batch count intact).
3. **Delta-mode I/O cut** — stage 2 streams only dirty/new shards
   (content-hash manifest); the ShardAssignPipeline byte counter must show
   >= 2x less streaming than the full restream of the same corpus.

``--smoke`` runs the scaled-down copy with the assertions on — wired into
CI next to the serving and construction smokes.
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import os
import time

import numpy as np
import jax.numpy as jnp

from common import emit, save_result

from repro.build.kmeans import balanced_hierarchical_kmeans
from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.search import SearchConfig
from repro.data import PAPER_DATASETS, make_queries, make_vectors
from repro.lifecycle import (
    CorpusStore,
    LiveFreshState,
    RebuildPolicy,
    RebuildScheduler,
    UpdateLane,
    VersionManager,
    delta_build,
)
from repro.runtime import (
    BatchPolicy,
    DynamicBatcher,
    PrefetchPipeline,
    ServeEngine,
    latency_percentiles,
    merge_timelines,
    poisson_trace,
    update_trace,
)
from repro.storage import TieredPostings


def live_truth(corpus: CorpusStore, state: LiveFreshState,
               probe_q: np.ndarray, k: int = 10) -> np.ndarray:
    """Brute-force ground truth over the CURRENT live set: corpus rows +
    live delta rows, tombstones dropped, deduped by global id (during a
    rebuild the folded delta prefix exists in both corpus and delta — same
    id, same payload)."""
    with state.lock:
        n = corpus.n
        tomb = state.tombstone_bits()
        dvecs, dids = state.delta_rows(0, state.fill)
    x = corpus.view()
    live_main = np.nonzero(~tomb[:n])[0]
    keep = ~tomb[dids] if len(dids) else np.zeros((0,), bool)
    vecs = np.concatenate([x[live_main], dvecs[keep]])
    ids = np.concatenate([live_main, dids[keep]]).astype(np.int64)
    uniq, first = np.unique(ids, return_index=True)
    vecs, ids = vecs[first], uniq
    _, pos = brute_force_topk(jnp.asarray(vecs), jnp.asarray(probe_q), k)
    return ids[np.asarray(pos)]


def probe_recall(engine: ServeEngine, lane: UpdateLane, corpus, state,
                 probe_q: np.ndarray, index: str, k: int = 10,
                 timeout: float = 30.0) -> dict:
    """Recall@k measured THROUGH the engine against truth over the live
    set.  Waits for the update SQ to drain first so the truth snapshot and
    the engine's published view agree."""
    deadline = time.monotonic() + timeout
    while lane.qp.sq_len() > 0 and time.monotonic() < deadline:
        time.sleep(0.002)
    t0 = time.monotonic()
    true = live_truth(corpus, state, probe_q, k)
    want = {}
    for i in range(len(probe_q)):
        rid = engine.submit(probe_q[i], k, index=index, block=True)
        if rid >= 0:
            want[rid] = i
    got: dict[int, np.ndarray] = {}
    others = []
    while len(got) < len(want) and time.monotonic() < deadline:
        for c in engine.qp.poll():
            if c.req_id in want and c.ids is not None:
                got[c.req_id] = c.ids
            elif c.req_id in want:
                want.pop(c.req_id)
            else:
                others.append(c)
        time.sleep(0.005)
    if not got:                            # engine dead / all probes lost —
        return {                           # surface it as recall 0, not a
            "recall": 0.0,                 # stack crash masking the cause
            "n_probes": 0,
            "window_s": time.monotonic() - t0,
            "stray_completions": others,
        }
    rows = [want[r] for r in got]
    ids = np.stack([got[r][:k] for r in got])
    return {
        "recall": float(recall_at_k(ids, true[rows])),
        "n_probes": len(got),
        "window_s": time.monotonic() - t0,
        "stray_completions": others,       # fed back into latency stats
    }


def run(args) -> dict:
    if args.smoke:
        n, dim, n_modes = 4000, 24, 16
        per_task, max_cluster, cluster_len = 800, 48, 64
        nprobe, duration = 16, 4.0
        search_qps, ins_ops, del_ops = 150.0, 30.0, 10.0
        capacity, fill_frac = 1024, 0.15
    else:
        n, dim, n_modes = 20_000, 32, 32
        per_task, max_cluster, cluster_len = 2500, 96, 128
        nprobe, duration = 24, 10.0
        search_qps, ins_ops, del_ops = 300.0, 60.0, 20.0
        capacity, fill_frac = 4096, 0.15
    ins_batch, del_batch = 4, 2
    k = 10
    name = "live"

    spec = dc.replace(PAPER_DATASETS["sift"], n=n, dim=dim, n_modes=n_modes)
    x = make_vectors(spec)
    q, _ = make_queries(spec, 512)
    probe_q = q[:48]
    reserve = make_vectors(dc.replace(spec, seed=spec.seed + 9))

    import tempfile
    workdir = tempfile.mkdtemp(prefix="bench_freshness_")
    cents, _ = balanced_hierarchical_kmeans(
        x, max_cluster_size=max_cluster, iters=8, fused=True)
    corpus = CorpusStore(x)
    t0 = time.perf_counter()
    index, cold_stats = delta_build(
        corpus.view(), cents, workdir, cluster_len=cluster_len, eps=0.2,
        max_replicas=4, per_task=per_task)
    cold_s = time.perf_counter() - t0

    cfg = SearchConfig(k=k, nprobe_max=nprobe, pruning="none",
                       use_kernel=False, fused_topk=True)
    state = LiveFreshState(dim=dim, capacity=capacity, n_main=corpus.n)
    lane = UpdateLane(state)

    def make_pipeline(idx, st):
        tier = TieredPostings(np.asarray(idx.postings),
                              np.asarray(idx.posting_ids))
        p = PrefetchPipeline(idx, None, cfg, tier=tier,
                             fresh_source=st.snapshot)
        p.warmup(batch_sizes=(16, 32))
        return p

    pipe = make_pipeline(index, state)
    vm = VersionManager()
    vm.deploy(name, pipe, fresh=state)
    policy = BatchPolicy(max_batch=32, max_wait_s=0.004, update_quantum=64)
    batcher = DynamicBatcher(policy, [name])
    engine = ServeEngine({name: pipe}, batcher, update_lanes={name: lane})
    vm.bind(engine)
    sched = RebuildScheduler(
        name=name, corpus=corpus, centroids=cents, workdir=workdir,
        lane=lane, versions=vm, make_pipeline=make_pipeline,
        cluster_len=cluster_len, policy=RebuildPolicy(
            delta_fill_frac=fill_frac, tombstone_frac=0.9,
            min_interval_s=10 * duration, per_task=per_task))

    searches = poisson_trace(search_qps, duration, seed=args.seed,
                             index=name, topk=(k, k), n_queries=len(q))
    updates = update_trace(ins_ops, del_ops, duration,
                           seed=args.seed, index=name)
    # insert batch sizing rides the op count
    timeline = merge_timelines(searches, updates)

    engine.start()
    lat: list[float] = []
    probes: dict[str, dict] = {}
    next_reserve = 0
    n_del = 0
    rng = np.random.default_rng(args.seed + 1)
    wall0 = time.monotonic()
    before_at = 0.25 * duration
    try:
        for arr in timeline:
            lag = wall0 + arr.t - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            if "before" not in probes and arr.t >= before_at:
                # the pre-swap probe gates the scheduler start, so the
                # before/during/after ordering is deterministic even when
                # the fill threshold is crossed early
                probes["before"] = probe_recall(engine, lane, corpus, state,
                                                probe_q, name, k)
                sched.start(poll_s=0.02)
            if "during" not in probes and sched.rebuilding.is_set():
                probes["during"] = probe_recall(engine, lane, corpus, state,
                                                probe_q, name, k)
            if hasattr(arr, "qrow"):                       # search arrival
                engine.submit(q[arr.qrow], k, index=name,
                              deadline_s=arr.deadline_s)
            elif arr.op == "insert":
                lo = next_reserve
                next_reserve += ins_batch
                if next_reserve <= len(reserve):
                    lane.submit_insert(reserve[lo:next_reserve])
            else:
                dead = rng.integers(0, x.shape[0], size=del_batch)
                lane.submit_delete(dead)
                n_del += del_batch
            lat += [c.latency for c in engine.qp.poll()
                    if c.status != "shed"]
        # let any in-flight rebuild land, then the post-swap probe
        deadline = time.monotonic() + 60
        while (sched.rebuilding.is_set() or not sched.reports) \
                and time.monotonic() < deadline:
            time.sleep(0.01)
        probes["after"] = probe_recall(engine, lane, corpus, state,
                                       probe_q, name, k)
    finally:
        sched.stop()
        engine.stop(drain=True)
    wall = time.monotonic() - wall0
    for pr in probes.values():
        lat += [c.latency for c in pr.pop("stray_completions", [])
                if c.status != "shed"]
    lat += [c.latency for c in engine.qp.poll() if c.status != "shed"]

    st = engine.stats
    ls = lane.stats
    vis = lane.visibility_stats()
    reports = [dc.asdict(r) for r in sched.reports]
    epochs = [dc.asdict(r) for r in vm.history]
    result = {
        "mode": "smoke" if args.smoke else "full",
        "corpus": {"n0": n, "dim": dim, "clusters": int(index.n_clusters),
                   "cluster_len": cluster_len, "capacity": capacity},
        "config": {"k": k, "nprobe_max": nprobe,
                   "search_qps": search_qps, "insert_ops_s": ins_ops,
                   "delete_ops_s": del_ops, "insert_batch": ins_batch,
                   "delete_batch": del_batch, "duration_s": duration},
        "cold_build": {"seconds": cold_s,
                       "bytes_streamed": cold_stats["bytes_streamed"]},
        "mixed_load": {
            "wall_s": wall,
            "achieved_qps": (st.completed - st.shed) / wall,
            "update_ops_s": (ls.applied_inserts + ls.applied_deletes) / wall,
            "applied_inserts": ls.applied_inserts,
            "applied_deletes": ls.applied_deletes,
            "search_latency": latency_percentiles(lat),
            "insert_to_visible": vis["insert_to_visible"],
            "delete_to_visible": vis["delete_to_visible"],
            "n_visible": vis["n_visible"],
        },
        "recall_across_swap": {ph: {kk: v for kk, v in pr.items()}
                               for ph, pr in probes.items()},
        "rebuilds": reports,
        "epochs": epochs,
        "dropped_batches": st.submitted - st.rejected - st.completed,
        "engine": {"submitted": st.submitted, "completed": st.completed,
                   "rejected": st.rejected, "shed": st.shed,
                   "batches": st.batches},
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI run with assertions")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    result = run(args)
    save_result("bench_freshness", result)

    ml = result["mixed_load"]
    rec = result["recall_across_swap"]
    reps = result["rebuilds"]
    emit("freshness_mixed_load", 1e6 / max(ml["achieved_qps"], 1e-9),
         f"qps={ml['achieved_qps']:.0f} "
         f"update_ops={ml['update_ops_s']:.0f}/s "
         f"vis_p50={ml['insert_to_visible']['p50_ms']:.0f}ms "
         f"vis_p99={ml['insert_to_visible']['p99_ms']:.0f}ms")
    for ph in ("before", "during", "after"):
        if ph in rec:
            print(f"[freshness] recall@10 {ph:>6} swap: "
                  f"{rec[ph]['recall']:.3f} ({rec[ph]['n_probes']} probes)")
    for r in reps:
        print(f"[freshness] rebuild({r['trigger']}): "
              f"{r['shards_streamed']}/{r['shards_total']} shards streamed, "
              f"{r['bytes_streamed']}/{r['full_stream_bytes']} bytes "
              f"({r['full_stream_bytes'] / max(r['bytes_streamed'], 1):.1f}x "
              f"cut), folded +{r['folded_inserts']}/-{r['folded_deletes']}, "
              f"carried {r['carried_ops']} ops, "
              f"build {r['t_built'] - r['t_snapshot']:.2f}s")

    # acceptance gates (ISSUE 4): live swap, zero drops, recall held,
    # measured visibility, counter-asserted I/O cut
    assert len(reps) >= 1, "no rebuild triggered during the trace"
    assert result["dropped_batches"] == 0, "engine dropped admitted requests"
    assert all(r["bytes_streamed"] * 2 <= r["full_stream_bytes"]
               for r in reps), "delta rebuild saved < 2x stage-2 bytes"
    assert ml["n_visible"] > 0 and ml["insert_to_visible"]["p99_ms"] > 0, \
        "no stamped visibility measurements"
    finalized = [e for e in result["epochs"] if e["retired_at"] > 0]
    assert all(e["finalized_at"] > 0 for e in finalized), \
        "a retired epoch never finalized (in-flight batch leaked)"
    for ph in ("before", "during", "after"):
        assert ph in rec, f"missing {ph}-swap recall probe"
        assert rec[ph]["recall"] >= 0.96, \
            f"recall@10 {ph} swap = {rec[ph]['recall']:.3f} < 0.96"
    print(f"[{'smoke' if args.smoke else 'full'}] freshness OK: "
          f"recall held {rec['before']['recall']:.3f}/"
          f"{rec['during']['recall']:.3f}/{rec['after']['recall']:.3f} "
          f"across a live swap, io_cut="
          f"{reps[0]['full_stream_bytes'] / max(reps[0]['bytes_streamed'], 1):.1f}x, "
          f"0 dropped")


if __name__ == "__main__":
    main()
