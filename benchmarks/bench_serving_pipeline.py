"""Serving-runtime A/B: sequential loop vs SQ/CQ prefetch pipeline (§4.1).

Two experiments, both on STREAMED-mode serving (postings host-resident,
probed-cluster unions streamed per batch — the TPU analogue of the paper's
SSD tier):

1. **Pipeline A/B** — the same micro-batch stream through
   ``PrefetchPipeline.run_sequential`` (gather -> stream -> scan -> readback,
   strictly serialized: the pre-PR-2 serve loop) and ``run_pipelined``
   (batch i+1 planned + gathered + streamed while batch i's scan is in
   flight).  Both run the identical SearchConfig (same k, nprobe, LLSP
   config) and the results are asserted bit-identical, so recall is equal by
   construction (and spot-checked against brute force).  Reported per batch
   size: throughput, speedup, per-stage medians, overlap efficiency, and the
   per-stage timestamps of the first pipelined batches as direct evidence
   that gather/stream of batch i+1 lands inside scan of batch i.

2. **Engine under load** — the full SQ -> batcher -> pipeline -> CQ runtime
   serving a seeded open-loop Poisson trace over two co-resident logical
   indexes (hot/cold tenants) with deadlines: throughput, p50/p99 latency,
   deadline-miss rate, shed/degraded counts, per-tenant batch fairness.

``--smoke`` runs a scaled-down copy of both (fresh tiny index, no LLSP) and
asserts the parity + overlap invariants — wired into CI so the pipelined
path is *executed*, not just unit-tested, on every push.
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import os
import time

import numpy as np
import jax.numpy as jnp

from common import CACHE, emit, save_result

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.search import SearchConfig
from repro.data import PAPER_DATASETS, make_queries, make_vectors
from repro.runtime import (
    BatchPolicy,
    DynamicBatcher,
    PrefetchPipeline,
    ServeEngine,
    TenantSpec,
    latency_percentiles,
    multi_tenant_trace,
    overlap_efficiency,
)
from repro.storage import TieredPostings


def build_smoke_index(n=4000, dim=24):
    """Tiny fresh index, no LLSP (pruning='none') — seconds, not minutes."""
    from repro.build.kmeans import balanced_hierarchical_kmeans
    from repro.core.ivf import IVFIndex, build_postings
    from repro.core.spann_rules import closure_assign

    spec = dc.replace(PAPER_DATASETS["sift"], n=n, dim=dim, n_modes=16)
    x = make_vectors(spec)
    q, topk = make_queries(spec, 256)
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=48, iters=8)
    ca = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents),
                                   eps=0.2, max_replicas=4))
    postings, pids = build_postings(x, ca, cents.shape[0], 64)
    index = IVFIndex(jnp.asarray(cents), jnp.asarray(postings),
                     jnp.asarray(pids))
    return index, None, x, q, np.minimum(topk, 50).astype(np.int32)


def build_full_index(n=60_000, dim=64):
    """The serving corpus (redsrch-shaped), built once and checkpoint-cached
    under results/bench_cache/serving_index."""
    from repro.build.pipeline import BuildConfig, build_index
    from repro.core.llsp import LLSPConfig

    spec = dc.replace(PAPER_DATASETS["redsrch"], n=n, dim=dim, n_modes=64)
    x = make_vectors(spec)
    q, topk = make_queries(spec, 1024)
    topk = np.minimum(topk, 100).astype(np.int32)
    cfg = BuildConfig(
        max_cluster_size=96, cluster_len=128, coarse_per_task=8000,
        n_workers=2, closure_eps=0.2,
        llsp=LLSPConfig(levels=(8, 16, 32, 64), recall_target=0.9,
                        n_ratio_features=16, n_trees=50, max_depth=5),
    )
    os.makedirs(CACHE, exist_ok=True)
    index, llsp, _ = build_index(x, cfg, os.path.join(CACHE, "serving_index"),
                                 queries=q, query_topk=topk)
    return index, llsp, x, q, topk


def stage_ms(times, field0, field1):
    return float(np.median([
        (getattr(t, field1) - getattr(t, field0)) * 1e3 for t in times
    ]))


def run_ab(pipe, q, topk, true10, batch_sizes, repeats) -> list[dict]:
    """Three-way A/B per batch size, trials interleaved + paired so machine
    drift cancels in the ratios:

      ref  — the pre-runtime sequential loop (fetch + PR 1 reference scan,
             every stage blocking): what streamed serving looked like
             before this subsystem;
      seq  — the runtime's stages run strictly serialized (identical scan
             program as pipe): isolates the overlap effect alone;
      pipe — the double-buffered prefetch pipeline.
    """
    rows = []
    for b in batch_sizes:
        nb = len(q) // b
        batches = [(q[i * b:(i + 1) * b], topk[i * b:(i + 1) * b])
                   for i in range(nb)]
        # warm every program + allocator before any timed trial
        pipe.run_sequential(batches, reference=True)
        pipe.run_sequential(batches)
        pipe.run_pipelined(batches)
        t_ref, t_seq, t_pip = [], [], []
        ref = seq = pip = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            ref = pipe.run_sequential(batches, reference=True)
            t_ref.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            seq = pipe.run_sequential(batches)
            t_seq.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            pip = pipe.run_pipelined(batches)
            t_pip.append(time.perf_counter() - t0)
        for r, s, p in zip(ref, seq, pip):
            assert np.array_equal(s.ids, p.ids), "pipelined != sequential"
            assert np.array_equal(r.ids, p.ids), "pipelined != reference"
        nq = b * nb
        cover = min(len(q), nq)       # queries actually served this sweep
        rec = recall_at_k(
            np.concatenate([r.ids for r in seq])[:cover, :10],
            true10[:cover])
        st = [r.times for r in seq]
        pt = [r.times for r in pip]
        med_ref, med_seq, med_pip = (float(np.median(t))
                                     for t in (t_ref, t_seq, t_pip))
        row = {
            "batch": b,
            "qps_ref": nq / med_ref,
            "qps_seq": nq / med_seq,
            "qps_pipe": nq / med_pip,
            # paired per-trial ratios -> median, robust to drift between
            # trials (the criterion numbers)
            "speedup_vs_ref": float(np.median(
                [r / p for r, p in zip(t_ref, t_pip)])),
            "speedup_overlap_only": float(np.median(
                [s / p for s, p in zip(t_seq, t_pip)])),
            "recall10": float(rec),
            "nprobe_mean": float(np.mean([r.nprobe.mean() for r in seq])),
            "overlap_eff_seq": overlap_efficiency(st),
            "overlap_eff_pipe": overlap_efficiency(pt),
            "plan_ms": stage_ms(st, "plan_start", "plan_end"),
            "gather_ms": stage_ms(st, "gather_start", "gather_end"),
            "stream_ms": stage_ms(st, "gather_end", "stream_end"),
            "scan_ms": stage_ms(st, "scan_dispatch", "scan_done"),
            "rows_median": int(np.median([t.rows for t in st])),
            # direct evidence of overlap: first pipelined stage stamps,
            # rebased to the run start so intervals are easy to eyeball
            "pipe_timeline": [
                {
                    "batch": i,
                    "gather": [t.gather_start - pt[0].plan_start,
                               t.stream_end - pt[0].plan_start],
                    "scan": [t.scan_dispatch - pt[0].plan_start,
                             t.scan_done - pt[0].plan_start],
                }
                for i, t in enumerate(pt[:4])
            ],
        }
        rows.append(row)
        emit(f"serving_pipeline_b{b}", 1e6 * med_pip / nq,
             f"speedup_vs_ref={row['speedup_vs_ref']:.2f}x "
             f"overlap_only={row['speedup_overlap_only']:.2f}x "
             f"qps={row['qps_pipe']:.0f} "
             f"ovl={row['overlap_eff_pipe']:.2f} recall={rec:.3f}")
    return rows


def run_engine_load(index, llsp, pipes_cfg, q, duration_s, rate_qps,
                    deadline_s, seed) -> dict:
    """Open-loop Poisson over two logical tenants on one node."""
    cfg, tier_arrays = pipes_cfg
    postings, pids = tier_arrays
    pipes = {
        name: PrefetchPipeline(index, llsp, cfg,
                               tier=TieredPostings(postings, pids))
        for name in ("hot", "cold")
    }
    policy = BatchPolicy(max_batch=32, max_wait_s=0.004, shed="degrade",
                        degrade_nprobe=8)
    batcher = DynamicBatcher(policy, list(pipes))
    engine = ServeEngine(pipes, batcher)
    for p in pipes.values():        # pre-compile every hot shape off-clock
        p.warmup(batch_sizes=(policy.pad, policy.max_batch))
        p.serve_batch(q[: policy.max_batch], 10)
    trace = multi_tenant_trace(
        [TenantSpec("hot", rate_qps * 0.7, deadline_s=deadline_s,
                    n_queries=len(q)),
         TenantSpec("cold", rate_qps * 0.3, deadline_s=deadline_s,
                    n_queries=len(q))],
        duration_s, seed=seed)
    engine.start()
    t0 = time.perf_counter()
    for arr in trace:
        lag = t0 + arr.t - time.perf_counter()
        if lag > 0:
            time.sleep(lag)
        engine.submit(q[arr.qrow], 10, index=arr.index,
                      deadline_s=arr.deadline_s)
    engine.stop(drain=True)
    wall = time.perf_counter() - t0
    comps = engine.qp.poll()
    ok = [c for c in comps if c.status != "shed"]
    lat = [c.latency for c in ok]
    missed = [c for c in ok
              if deadline_s is not None and c.latency > deadline_s]
    per_tenant = {
        name: latency_percentiles(
            [c.latency for c in ok if c.index == name])
        for name in pipes
    }
    n = max(len(comps), 1)
    return {
        "offered_qps": rate_qps,
        "achieved_qps": len(ok) / wall,
        "wall_s": wall,
        "submitted": engine.stats.submitted,
        "rejected": engine.stats.rejected,
        "completed": len(comps),
        "shed": engine.stats.shed,
        "degraded": engine.stats.degraded,
        "batches": engine.stats.batches,
        "deadline_miss_rate": (len(missed) + engine.stats.shed) / n,
        "latency": latency_percentiles(lat),
        "per_tenant": per_tenant,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI run with assertions")
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=None)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None, help="open-loop qps")
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.smoke:
        index, llsp, x, q, topk = build_smoke_index()
        cfg = SearchConfig(k=10, nprobe_max=16, pruning="none",
                           use_kernel=False, fused_topk=True)
        batch_sizes = args.batch_sizes or [32]
        repeats = args.repeats or 2
        rate = args.rate or 400.0
        duration = args.duration or 1.0
        deadline_s = None if args.deadline_ms is None \
            else args.deadline_ms * 1e-3
    else:
        index, llsp, x, q, topk = build_full_index()
        cfg = SearchConfig(k=10, nprobe_max=64, pruning="llsp", n_ratio=16,
                           use_kernel=False, fused_topk=True)
        batch_sizes = args.batch_sizes or [16, 32, 64]
        repeats = args.repeats or 5
        rate = args.rate or 500.0
        duration = args.duration or 6.0
        deadline_s = (args.deadline_ms or 80.0) * 1e-3

    postings = np.asarray(index.postings)
    pids = np.asarray(index.posting_ids)
    _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    true10 = np.asarray(t10)

    tier = TieredPostings(postings, pids)
    pipe = PrefetchPipeline(index, llsp, cfg, tier=tier)
    ab = run_ab(pipe, q, topk, true10, batch_sizes, repeats)

    load = run_engine_load(index, llsp, (cfg, (postings, pids)), q,
                           duration, rate, deadline_s, args.seed)
    emit("serving_engine_load", 1e6 / max(load["achieved_qps"], 1e-9),
         f"qps={load['achieved_qps']:.0f} p99={load['latency']['p99_ms']:.1f}ms "
         f"miss={load['deadline_miss_rate']:.3f} shed={load['shed']}")

    payload = {
        "mode": "smoke" if args.smoke else "full",
        "corpus": {"n": int(x.shape[0]), "dim": int(x.shape[1]),
                   "clusters": int(index.n_clusters),
                   "cluster_len": int(index.cluster_len),
                   "payload_mib": int(postings.nbytes >> 20)},
        "config": {"k": cfg.k, "nprobe_max": cfg.nprobe_max,
                   "pruning": cfg.pruning, "use_kernel": cfg.use_kernel},
        "ab": ab,
        "engine_load": load,
        "tier_totals": {
            "bytes_streamed": tier.stats.bytes_streamed,
            "batches": tier.stats.batches,
            "gather_s": tier.stats.gather_s,
            "stream_s": tier.stats.stream_s,
        },
    }
    save_result("bench_serving_pipeline", payload)

    if args.smoke:
        # CI invariants: parity already asserted in run_ab; check overlap
        # actually happened and the engine completed every admitted request.
        # lenient threshold: overlap efficiency is a wall-clock property and
        # a contended CI runner can deschedule the gather thread; the gate
        # is "overlap happened", not "overlap was perfect"
        assert all(r["overlap_eff_pipe"] > 0.1 for r in ab), \
            f"no overlap measured: {[r['overlap_eff_pipe'] for r in ab]}"
        assert all(r["overlap_eff_seq"] == 0.0 for r in ab)
        assert load["completed"] == load["submitted"] - load["rejected"], \
            "engine lost requests"
        print("[smoke] serving pipeline OK: "
              f"speedup_vs_ref={ab[0]['speedup_vs_ref']:.2f}x "
              f"overlap={ab[0]['overlap_eff_pipe']:.2f} "
              f"engine_qps={load['achieved_qps']:.0f}")


if __name__ == "__main__":
    main()
