"""Serving-runtime A/B: sequential loop vs SQ/CQ prefetch pipeline (§4.1).

Two experiments, both on STREAMED-mode serving (postings host-resident,
probed-cluster unions streamed per batch — the TPU analogue of the paper's
SSD tier):

1. **Pipeline A/B** — the same micro-batch stream through
   ``PrefetchPipeline.run_sequential`` (gather -> stream -> scan -> readback,
   strictly serialized: the pre-PR-2 serve loop) and ``run_pipelined``
   (batch i+1 planned + gathered + streamed while batch i's scan is in
   flight).  Both run the identical SearchConfig (same k, nprobe, LLSP
   config) and the results are asserted bit-identical, so recall is equal by
   construction (and spot-checked against brute force).  Reported per batch
   size: throughput, speedup, per-stage medians, overlap efficiency, and the
   per-stage timestamps of the first pipelined batches as direct evidence
   that gather/stream of batch i+1 lands inside scan of batch i.

2. **Engine under load** — the full SQ -> batcher -> pipeline -> CQ runtime
   serving a seeded open-loop Poisson trace over two co-resident logical
   indexes (hot/cold tenants) with deadlines: throughput, p50/p99 latency,
   deadline-miss rate, shed/degraded counts, per-tenant batch fairness.

3. **FIFO-vs-locality formation A/B** — the same seeded locality-skewed
   trace (concurrent user streams, each pinned to a probe neighborhood of a
   centroid-sorted query pool) replayed against a busy-server virtual clock
   through two batchers that differ ONLY in ``BatchPolicy.grouping``; every
   formed micro-batch is then served through the identical pipeline, so the
   per-batch gather-union bytes come from the tier's own fetch counters
   (measured, not inferred) and the per-query results are asserted
   bit-identical (recall is equal by construction).  The aging guard is
   asserted per formation: no aged request is ever skipped for a locality
   pick.

4. **N-deep in-flight window** — the locality-formed batches through
   ``run_pipelined(depth=N)`` vs the 1-deep double buffer, with the
   ``inflight_depth`` stamp evidence that >= 2 scans were actually in
   flight at once.

``--smoke`` runs a scaled-down copy of all of it (fresh tiny index, no
LLSP) and asserts the parity + overlap + union-reduction invariants —
wired into CI so the locality path is *executed*, not just unit-tested, on
every push.
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import os
import time

import numpy as np
import jax.numpy as jnp

from common import CACHE, emit, save_result

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.search import SearchConfig
from repro.data import PAPER_DATASETS, make_queries, make_vectors
from repro.runtime import (
    BatchPolicy,
    DynamicBatcher,
    PrefetchPipeline,
    SearchRequest,
    ServeEngine,
    TenantSpec,
    inflight_depth,
    latency_percentiles,
    locality_skewed_trace,
    make_quantized_pipeline,
    multi_tenant_trace,
    overlap_efficiency,
)
from repro.obs import HarvestRing, Histogram, Observability, QualityMonitor
from repro.storage import TieredPostings


def build_smoke_index(n=4000, dim=24):
    """Tiny fresh index, no LLSP (pruning='none') — seconds, not minutes."""
    from repro.build.kmeans import balanced_hierarchical_kmeans
    from repro.core.ivf import IVFIndex, build_postings
    from repro.core.spann_rules import closure_assign

    spec = dc.replace(PAPER_DATASETS["sift"], n=n, dim=dim, n_modes=16)
    x = make_vectors(spec)
    q, topk = make_queries(spec, 256)
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=48, iters=8)
    ca = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents),
                                   eps=0.2, max_replicas=4))
    postings, pids = build_postings(x, ca, cents.shape[0], 64)
    index = IVFIndex(jnp.asarray(cents), jnp.asarray(postings),
                     jnp.asarray(pids))
    return index, None, x, q, np.minimum(topk, 50).astype(np.int32)


def build_full_index(n=60_000, dim=64):
    """The serving corpus (redsrch-shaped), built once and checkpoint-cached
    under results/bench_cache/serving_index."""
    from repro.build.pipeline import BuildConfig, build_index
    from repro.core.llsp import LLSPConfig

    spec = dc.replace(PAPER_DATASETS["redsrch"], n=n, dim=dim, n_modes=64)
    x = make_vectors(spec)
    q, topk = make_queries(spec, 1024)
    topk = np.minimum(topk, 100).astype(np.int32)
    cfg = BuildConfig(
        max_cluster_size=96, cluster_len=128, coarse_per_task=8000,
        n_workers=2, closure_eps=0.2,
        llsp=LLSPConfig(levels=(8, 16, 32, 64), recall_target=0.9,
                        n_ratio_features=16, n_trees=50, max_depth=5),
    )
    os.makedirs(CACHE, exist_ok=True)
    index, llsp, _ = build_index(x, cfg, os.path.join(CACHE, "serving_index"),
                                 queries=q, query_topk=topk)
    return index, llsp, x, q, topk


def stage_ms(times, field0, field1):
    return float(np.median([
        (getattr(t, field1) - getattr(t, field0)) * 1e3 for t in times
    ]))


def run_ab(pipe, q, topk, true10, batch_sizes, repeats) -> list[dict]:
    """Three-way A/B per batch size, trials interleaved + paired so machine
    drift cancels in the ratios:

      ref  — the pre-runtime sequential loop (fetch + PR 1 reference scan,
             every stage blocking): what streamed serving looked like
             before this subsystem;
      seq  — the runtime's stages run strictly serialized (identical scan
             program as pipe): isolates the overlap effect alone;
      pipe — the double-buffered prefetch pipeline.
    """
    rows = []
    for b in batch_sizes:
        nb = len(q) // b
        batches = [(q[i * b:(i + 1) * b], topk[i * b:(i + 1) * b])
                   for i in range(nb)]
        # warm every program + allocator before any timed trial
        pipe.run_sequential(batches, reference=True)
        pipe.run_sequential(batches)
        pipe.run_pipelined(batches)
        t_ref, t_seq, t_pip = [], [], []
        ref = seq = pip = None
        for _ in range(repeats):
            t0 = time.perf_counter()
            ref = pipe.run_sequential(batches, reference=True)
            t_ref.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            seq = pipe.run_sequential(batches)
            t_seq.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            pip = pipe.run_pipelined(batches)
            t_pip.append(time.perf_counter() - t0)
        for r, s, p in zip(ref, seq, pip):
            assert np.array_equal(s.ids, p.ids), "pipelined != sequential"
            assert np.array_equal(r.ids, p.ids), "pipelined != reference"
        nq = b * nb
        cover = min(len(q), nq)       # queries actually served this sweep
        rec = recall_at_k(
            np.concatenate([r.ids for r in seq])[:cover, :10],
            true10[:cover])
        st = [r.times for r in seq]
        pt = [r.times for r in pip]
        med_ref, med_seq, med_pip = (float(np.median(t))
                                     for t in (t_ref, t_seq, t_pip))
        row = {
            "batch": b,
            "qps_ref": nq / med_ref,
            "qps_seq": nq / med_seq,
            "qps_pipe": nq / med_pip,
            # paired per-trial ratios -> median, robust to drift between
            # trials (the criterion numbers)
            "speedup_vs_ref": float(np.median(
                [r / p for r, p in zip(t_ref, t_pip)])),
            "speedup_overlap_only": float(np.median(
                [s / p for s, p in zip(t_seq, t_pip)])),
            "recall10": float(rec),
            "nprobe_mean": float(np.mean([r.nprobe.mean() for r in seq])),
            "overlap_eff_seq": overlap_efficiency(st),
            "overlap_eff_pipe": overlap_efficiency(pt),
            "plan_ms": stage_ms(st, "plan_start", "plan_end"),
            "gather_ms": stage_ms(st, "gather_start", "gather_end"),
            "stream_ms": stage_ms(st, "gather_end", "stream_end"),
            "scan_ms": stage_ms(st, "scan_dispatch", "scan_done"),
            "rows_median": int(np.median([t.rows for t in st])),
            # direct evidence of overlap: first pipelined stage stamps,
            # rebased to the run start so intervals are easy to eyeball
            "pipe_timeline": [
                {
                    "batch": i,
                    "gather": [t.gather_start - pt[0].plan_start,
                               t.stream_end - pt[0].plan_start],
                    "scan": [t.scan_dispatch - pt[0].plan_start,
                             t.scan_done - pt[0].plan_start],
                }
                for i, t in enumerate(pt[:4])
            ],
        }
        rows.append(row)
        emit(f"serving_pipeline_b{b}", 1e6 * med_pip / nq,
             f"speedup_vs_ref={row['speedup_vs_ref']:.2f}x "
             f"overlap_only={row['speedup_overlap_only']:.2f}x "
             f"qps={row['qps_pipe']:.0f} "
             f"ovl={row['overlap_eff_pipe']:.2f} recall={rec:.3f}")
    return rows


def topic_pool(q, true10, n_groups, seed=0):
    """Cluster the query pool into ``n_groups`` topics (tiny seeded Lloyd)
    and lay it out topic-contiguous, so the loadgen's contiguous qrow
    groups are real probe neighborhoods.  Sorting by nearest-centroid *id*
    is NOT enough — centroid ids carry no spatial order, so id-adjacent
    queries can probe disjoint cluster sets."""
    rng = np.random.default_rng(seed)
    c = q[rng.choice(len(q), n_groups, replace=False)].astype(np.float64)
    a = np.zeros(len(q), np.int64)
    for _ in range(10):
        d = ((q[:, None, :].astype(np.float64) - c[None]) ** 2).sum(-1)
        a = d.argmin(1)
        for g in range(n_groups):
            m = a == g
            if m.any():
                c[g] = q[m].mean(0)
    order = np.argsort(a, kind="stable")
    return q[order], true10[order]


def _serve_formed(pipe, mb):
    """Serve one formed MicroBatch through the pipeline, reusing the
    admission-time routes exactly as the engine does."""
    queries = np.stack([r.query for r in mb.requests])
    topk = np.asarray([r.topk for r in mb.requests], np.int32)
    routed = (np.stack([r.route.cids for r in mb.requests]),
              np.asarray([r.route.nprobe for r in mb.requests], np.int32))
    plan = pipe.plan(queries, topk, nprobe_cap=mb.nprobe_cap, routed=routed)
    return pipe.harvest(pipe.dispatch(pipe.prefetch(plan)))


def run_locality_ab(index, llsp, cfg, tier_arrays, q, true10, *,
                    rate_qps, duration_s, seed, max_batch, n_groups=16,
                    concurrency=8, utilization=0.95,
                    max_wait_s=0.2, pool_batches=None) -> dict:
    """Paired FIFO-vs-locality micro-batch formation on one seeded
    locality-skewed trace.

    The replay drives the batcher with a busy-server virtual clock (one
    batch per ``service_s``) and holds formation until the pending pool is
    ``pool_batches`` batches deep (default: one batch per concurrent
    stream) or a head-of-line request ages — the steady state of a loaded
    server with batching delay, reached without a long queueing warmup.  A
    pool of exactly max_batch gives ANY grouping no choice; a pool with
    ~max_batch requests per active stream is the regime locality formation
    exists for.  Both modes replay the identical gating, so the comparison
    stays paired; formation decisions are a pure function of (trace,
    policy).  Every formed batch is then served off-clock through the
    identical pipeline and the per-batch union bytes are read from the
    tier's fetch events."""
    postings, pids = tier_arrays
    qs, t10 = topic_pool(q, true10, n_groups, seed=seed)
    trace = locality_skewed_trace(
        rate_qps, duration_s, n_queries=len(qs), n_groups=n_groups,
        concurrency=concurrency, seed=seed)
    service_s = max_batch / rate_qps * utilization
    pool_batches = pool_batches or concurrency
    out = {}
    for mode in ("fifo", "locality"):
        tier = TieredPostings(postings, pids)
        pipe = PrefetchPipeline(index, llsp, cfg, tier=tier)
        # high utilization + a generous batching-delay bound: the pending
        # pool stays several batches deep (each topic has ~max_batch
        # members pending), which is the regime locality selection exists
        # for — a pool of exactly max_batch gives any grouping no choice
        policy = BatchPolicy(max_batch=max_batch, max_wait_s=max_wait_s,
                             shed="none", grouping=mode)
        batcher = DynamicBatcher(policy, ["default"])
        # pool-level admission routing: ONE batched centroid+LLSP call;
        # RoutePlans come from the engine's own constructor so the
        # formation input measured here is byte-for-byte what a live
        # engine would feed form()
        from repro.runtime.engine import make_route_plan

        cids_all, nprobe_all = pipe.route(qs, 10)
        plans = [make_route_plan(cids_all[i], nprobe_all[i], pipe)
                 for i in range(len(qs))]

        def mk_req(rid, arr):
            return SearchRequest(
                req_id=rid, index="default", query=qs[arr.qrow], topk=10,
                deadline=None, arrival=arr.t, route=plans[arr.qrow])

        def aged_guard_form(now):
            """form() + the aging-bound assertion: every request older than
            max_wait_s must be in this batch (up to max_batch, FIFO)."""
            pending = list(batcher._pending["default"])
            aged = [r.req_id for r in pending
                    if now - r.arrival >= policy.max_wait_s][:max_batch]
            mb, sheds = batcher.form(now)
            assert not sheds
            if mb is not None:
                got_ids = {r.req_id for r in mb.requests}
                missed = [i for i in aged if i not in got_ids]
                assert not missed, \
                    f"aging guard violated: {missed} skipped at t={now:.4f}"
            return mb

        def pool_ready(now):
            pend = batcher._pending["default"]
            if len(pend) >= pool_batches * max_batch:
                return True
            return bool(pend) and now - pend[0].arrival >= policy.max_wait_s

        formed, rows = [], {}
        busy_until = 0.0
        for rid, arr in enumerate(trace):
            rows[rid] = arr.qrow
            batcher.add(mk_req(rid, arr), now=arr.t)
            while arr.t >= busy_until and pool_ready(arr.t):
                mb = aged_guard_form(arr.t)
                if mb is None:
                    break
                formed.append(mb)
                busy_until = max(busy_until, arr.t) + service_s
        # tail drain: the server keeps its cadence past the last arrival
        t = max(trace[-1].t, busy_until)
        while batcher.pending():
            mb = aged_guard_form(t)
            if mb is None:
                t += policy.max_wait_s / 4    # let heads age
                continue
            formed.append(mb)
            t += service_s
        # serve every formed batch through the identical pipeline
        got = {}
        union_bytes, union_clusters, requested = [], [], []
        for mb in formed:
            res = _serve_formed(pipe, mb)
            for r, ids_row in zip(mb.requests, res.ids):
                got[r.req_id] = ids_row
            union_bytes.append(res.times.union_bytes)
            union_clusters.append(res.times.union_clusters)
            requested.append(res.times.clusters_requested)
        assert len(got) == len(trace), "requests lost in formation"
        order = sorted(got)
        ids = np.stack([got[r] for r in order])
        rec = recall_at_k(ids[:, :10],
                          t10[[rows[r] for r in order]])
        waits = np.concatenate([mb.waits for mb in formed])
        out[mode] = {
            "batches": len(formed),
            "batch_size_mean": float(np.mean([len(mb.requests)
                                              for mb in formed])),
            "union_bytes_total": int(tier.stats.union_bytes_streamed),
            "union_bytes_per_batch": float(np.mean(union_bytes)),
            "union_clusters_per_batch": float(np.mean(union_clusters)),
            "requested_clusters_per_batch": float(np.mean(requested)),
            "bytes_streamed_total": int(tier.stats.bytes_streamed),
            "recall10": float(rec),
            "wait_ms": {
                "p50": float(np.percentile(waits, 50) * 1e3),
                "p99": float(np.percentile(waits, 99) * 1e3),
                "max": float(waits.max() * 1e3),
            },
            "aged_seeds": batcher.stats.aged_seeds,
            "_ids": ids,
            "_order": order,
        }
    f, l = out["fifo"], out["locality"]
    # identical per-query results regardless of batch composition: recall
    # is bit-equal by construction, and we assert it, not assume it
    assert f["_order"] == l["_order"]
    assert np.array_equal(f["_ids"], l["_ids"]), "locality changed results"
    assert f["recall10"] == l["recall10"]
    # the aging bound, relative to the FIFO baseline under the identical
    # replay: locality reordering may cost a skipped request at most one
    # max_wait_s window on top of whatever queueing delay FIFO also pays
    # (the per-formation aged-seed assert above is the mechanism; this is
    # the end-to-end consequence)
    assert l["wait_ms"]["max"] <= f["wait_ms"]["max"] + max_wait_s * 1e3, \
        f"locality starved someone: {l['wait_ms']} vs fifo {f['wait_ms']}"
    for m in out.values():
        m.pop("_ids"), m.pop("_order")
    ratio = f["union_bytes_total"] / max(l["union_bytes_total"], 1)
    summary = {
        "trace": {"rate_qps": rate_qps, "duration_s": duration_s,
                  "arrivals": len(trace), "n_groups": n_groups,
                  "concurrency": concurrency, "seed": seed,
                  "service_s": service_s, "max_batch": max_batch,
                  "pool_batches": pool_batches},
        "fifo": f, "locality": l,
        "union_bytes_reduction": float(ratio),
        "union_clusters_reduction": float(
            f["union_clusters_per_batch"] / max(
                l["union_clusters_per_batch"], 1e-9)),
    }
    emit("serving_locality_ab",
         1e6 * l["union_bytes_per_batch"] / max(f["union_bytes_per_batch"], 1),
         f"union_bytes {ratio:.2f}x smaller "
         f"({f['union_bytes_per_batch'] / 2**20:.2f} -> "
         f"{l['union_bytes_per_batch'] / 2**20:.2f} MiB/batch), "
         f"recall {l['recall10']:.3f} (bit-equal), "
         f"wait_p99 {l['wait_ms']['p99']:.1f}ms")
    return summary


def run_depth_evidence(pipe, q, topk, batch: int, depth: int,
                       n_batches: int = 16) -> dict:
    """Stage-stamp evidence for the N-deep in-flight window: the same
    batches through run_pipelined at depth 1 and depth N; ``inflight_depth``
    counts scans whose dispatch->harvest intervals overlap."""
    nb = min(n_batches, len(q) // batch)
    batches = [(q[i * batch:(i + 1) * batch], topk[i * batch:(i + 1) * batch])
               for i in range(nb)]
    pipe.run_pipelined(batches, depth=depth)      # warm
    t0 = time.perf_counter()
    one = pipe.run_pipelined(batches, depth=1)
    t1 = time.perf_counter()
    deep = pipe.run_pipelined(batches, depth=depth)
    t2 = time.perf_counter()
    for a, b in zip(one, deep):
        assert np.array_equal(a.ids, b.ids), "depth changed results"
    d1 = inflight_depth([r.times for r in one])
    dn = inflight_depth([r.times for r in deep])
    nq = nb * batch
    return {
        "batch": batch, "depth": depth, "n_batches": nb,
        "inflight_depth_1": d1, "inflight_depth_n": dn,
        "qps_depth_1": nq / (t1 - t0), "qps_depth_n": nq / (t2 - t1),
        # first few stamps, rebased, as direct evidence
        "timeline": [
            {"batch": i,
             "scan": [t.scan_dispatch - deep[0].times.plan_start,
                      t.scan_done - deep[0].times.plan_start]}
            for i, t in enumerate([r.times for r in deep[:4]])
        ],
    }


def run_engine_load(index, llsp, pipes_cfg, q, duration_s, rate_qps,
                    deadline_s, seed, depth=1,
                    grouping="locality") -> dict:
    """Open-loop Poisson over two logical tenants on one node.  The trace
    is locality-FREE (uniform qrows), so ``grouping="locality"`` here prices
    the formation machinery's pure overhead on this CPU — the win side is
    the locality A/B, whose trace actually has structure to exploit."""
    cfg, tier_arrays = pipes_cfg
    postings, pids = tier_arrays
    pipes = {
        name: PrefetchPipeline(index, llsp, cfg,
                               tier=TieredPostings(postings, pids))
        for name in ("hot", "cold")
    }
    policy = BatchPolicy(max_batch=32, max_wait_s=0.004, shed="degrade",
                        degrade_nprobe=8, grouping=grouping)
    batcher = DynamicBatcher(policy, list(pipes))
    engine = ServeEngine(pipes, batcher, depth=depth)
    for p in pipes.values():        # pre-compile every hot shape off-clock
        p.warmup(batch_sizes=(policy.pad, policy.max_batch))
        p.serve_batch(q[: policy.max_batch], 10)
    trace = multi_tenant_trace(
        [TenantSpec("hot", rate_qps * 0.7, deadline_s=deadline_s,
                    n_queries=len(q)),
         TenantSpec("cold", rate_qps * 0.3, deadline_s=deadline_s,
                    n_queries=len(q))],
        duration_s, seed=seed)
    engine.start()
    t0 = time.perf_counter()
    for arr in trace:
        lag = t0 + arr.t - time.perf_counter()
        if lag > 0:
            time.sleep(lag)
        engine.submit(q[arr.qrow], 10, index=arr.index,
                      deadline_s=arr.deadline_s)
    engine.stop(drain=True)
    wall = time.perf_counter() - t0
    comps = engine.qp.poll()
    ok = [c for c in comps if c.status != "shed"]
    lat = [c.latency for c in ok]
    missed = [c for c in ok
              if deadline_s is not None and c.latency > deadline_s]
    per_tenant = {
        name: latency_percentiles(
            [c.latency for c in ok if c.index == name])
        for name in pipes
    }
    n = max(len(comps), 1)
    return {
        "offered_qps": rate_qps,
        "achieved_qps": len(ok) / wall,
        "wall_s": wall,
        "submitted": engine.stats.submitted,
        "rejected": engine.stats.rejected,
        "completed": len(comps),
        "shed": engine.stats.shed,
        "degraded": engine.stats.degraded,
        "batches": engine.stats.batches,
        "deadline_miss_rate": (len(missed) + engine.stats.shed) / n,
        "latency": latency_percentiles(lat),
        "per_tenant": per_tenant,
    }


def run_tracing_overhead(index, llsp, pipes_cfg, q, *, n_queries=400,
                         trials=5) -> dict:
    """Paired tracing-on/off A/B (PR 7 acceptance: <= 5% q/s overhead at
    ``sample_rate=1.0``).  Two identical engines — one with the default
    no-tracing observability, one tracing EVERY request — each serve the
    same closed-loop query stream; trials are interleaved (off/on order
    alternates) so thermal / scheduler drift cancels, and the gate is the
    MEDIAN of the per-trial paired q/s ratios.  Also hard-gates the
    streaming histogram's p50/p99 against np.percentile (<= 2%) on a
    seeded latency-shaped draw — the numbers serving reports must match
    what a post-hoc numpy analysis of the raw stream would say."""
    cfg, (postings, pids) = pipes_cfg
    engines = {}
    for mode in ("off", "on"):
        pipe = PrefetchPipeline(index, llsp, cfg,
                                tier=TieredPostings(postings, pids))
        policy = BatchPolicy(max_batch=32, max_wait_s=0.002)
        pipe.warmup(batch_sizes=(policy.pad, policy.max_batch))
        pipe.serve_batch(q[: policy.max_batch], 10)
        obs = Observability(sample_rate=1.0) if mode == "on" else None
        eng = ServeEngine({"default": pipe},
                          DynamicBatcher(policy, ["default"]), obs=obs)
        eng.start()
        engines[mode] = eng

    def one_trial(eng) -> float:
        rows = np.arange(n_queries) % q.shape[0]
        t0 = time.perf_counter()
        for r in rows:
            eng.submit(q[r], 10, index="default", block=True)
        assert eng.qp.wait_completions(n_queries, timeout=120.0)
        wall = time.perf_counter() - t0
        comps = eng.qp.poll()
        assert len(comps) == n_queries
        return n_queries / wall

    try:
        for eng in engines.values():    # untimed warm pass through the loop
            one_trial(eng)
        ratios, qps = [], {"off": [], "on": []}
        for t in range(trials):
            order = ("off", "on") if t % 2 == 0 else ("on", "off")
            got = {}
            for mode in order:
                got[mode] = one_trial(engines[mode])
                qps[mode].append(got[mode])
            ratios.append(got["on"] / got["off"])
            engines["on"].obs.trace.clear()   # bound trial-to-trial memory
    finally:
        for eng in engines.values():
            eng.stop(drain=True)

    # histogram accuracy gate: streaming quantiles vs exact numpy on the
    # same seeded ms-scale lognormal stream
    rng = np.random.default_rng(0)
    xs = np.exp(rng.normal(np.log(0.02), 0.7, size=20_000))
    h = Histogram("gate")
    h.observe_many(xs)
    hist_err = {
        f"p{int(p * 100)}": abs(h.quantile(p) - np.percentile(xs, p * 100))
        / np.percentile(xs, p * 100)
        for p in (0.5, 0.99)
    }
    assert max(hist_err.values()) <= 0.02, \
        f"streaming histogram off by >2%: {hist_err}"

    med = float(np.median(ratios))
    return {
        "n_queries": n_queries,
        "trials": trials,
        "qps_off": [round(v, 1) for v in qps["off"]],
        "qps_on": [round(v, 1) for v in qps["on"]],
        "qps_ratio_median": med,
        "overhead_pct": round((1.0 - med) * 100.0, 2),
        "hist_quantile_err": {k: round(v, 5) for k, v in hist_err.items()},
    }


def run_quality_overhead(index, llsp, cfg, x, q, *, n_queries=300,
                         trials=3, shadow_rate=0.02) -> dict:
    """Paired quality-on/off A/B (PR 9 acceptance: the full quality layer
    — per-query recall proxy, labeled histograms, harvest records, and a
    live shadow-audit lane — may cost at most 5% q/s on the q8 serving
    default).  Two identical q8 engines differ ONLY in the quality layer:
    "off" runs ``quality_proxy=False`` with no monitor (the ``serve
    --no-quality`` configuration), "on" computes the proxy per batch and
    feeds a QualityMonitor with shadow audits against the true corpus at
    2x the production default rate (0.02 vs 0.01 — extra audit volume for
    calibration statistics while still bounding the gate honestly).
    Trials are interleaved so drift cancels; the gate is the median of the
    paired per-trial q/s ratios.  The same run calibrates the proxy: every
    completed audit's |proxy - true| must average <= 0.05 (hard-asserted
    here, at both scales — the proxy is only useful if it tracks truth)."""
    import tempfile

    tmp = tempfile.mkdtemp(prefix="bench_quality_")
    engines, monitors = {}, {}
    for mode in ("off", "on"):
        obs = Observability.off()
        pipe = make_quantized_pipeline(
            index, llsp, cfg, vectors=x, name=f"quality_{mode}",
            flash_path=os.path.join(tmp, f"flash_{mode}.f32"),
            quality_proxy=(mode == "on"))
        policy = BatchPolicy(max_batch=32, max_wait_s=0.002)
        pipe.warmup(batch_sizes=(policy.pad, policy.max_batch))
        pipe.serve_batch(q[: policy.max_batch], 10)
        quality = None
        if mode == "on":
            quality = QualityMonitor(obs.metrics, vectors=x,
                                     shadow_rate=shadow_rate,
                                     harvest=HarvestRing())
        monitors[mode] = quality
        eng = ServeEngine({"default": pipe},
                          DynamicBatcher(policy, ["default"]),
                          obs=obs, quality=quality)
        eng.start()
        engines[mode] = eng

    def one_trial(eng) -> float:
        rows = np.arange(n_queries) % q.shape[0]
        t0 = time.perf_counter()
        for r in rows:
            eng.submit(q[r], 10, index="default", block=True)
        assert eng.qp.wait_completions(n_queries, timeout=120.0)
        wall = time.perf_counter() - t0
        comps = eng.qp.poll()
        assert len(comps) == n_queries
        return n_queries / wall

    try:
        for eng in engines.values():    # untimed warm pass through the loop
            one_trial(eng)
        ratios, qps = [], {"off": [], "on": []}
        for t in range(trials):
            order = ("off", "on") if t % 2 == 0 else ("on", "off")
            got = {}
            for mode in order:
                got[mode] = one_trial(engines[mode])
                qps[mode].append(got[mode])
            ratios.append(got["on"] / got["off"])
    finally:
        for eng in engines.values():
            eng.stop(drain=True)
        for mode in ("off", "on"):
            engines[mode].pipelines["default"].flash.release()

    qm = monitors["on"]
    qm.drain(timeout_s=30.0)
    s = qm.summary()
    served = (trials + 1) * n_queries
    # the proxy must be LIVE on the q8 default path: one proxy observation
    # per served query, not a sampled subset
    assert s["proxy"]["n"] == served, \
        f"proxy missing: {s['proxy']['n']} != {served}"
    assert s["audits_done"] > 0, "shadow lane never completed an audit"
    calib = s["calibration_err"]
    assert calib["mean"] <= 0.05, \
        f"proxy calibration off: mean |proxy-true| = {calib['mean']:.4f}"
    harvest = qm.harvest
    assert harvest.appended == served, "harvest lost records"
    qm.close()
    med = float(np.median(ratios))
    return {
        "n_queries": n_queries,
        "trials": trials,
        "shadow_rate": shadow_rate,
        "qps_off": [round(v, 1) for v in qps["off"]],
        "qps_on": [round(v, 1) for v in qps["on"]],
        "qps_ratio_median": med,
        "overhead_pct": round((1.0 - med) * 100.0, 2),
        "proxy_p50": round(s["proxy"]["p50"], 4),
        "proxy_mean": round(s["proxy"]["mean"], 4),
        "true_mean": round(s["true"]["mean"], 4),
        "audits_done": s["audits_done"],
        "audits_dropped": s["audits_dropped"],
        "calibration_err_mean": round(calib["mean"], 5),
        "calibration_err_p99": round(calib["p99"], 5),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI run with assertions")
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=None)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--rate", type=float, default=None, help="open-loop qps")
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--depth", type=int, default=2,
                    help="in-flight window for the engine + depth evidence")
    args = ap.parse_args()

    if args.smoke:
        index, llsp, x, q, topk = build_smoke_index()
        cfg = SearchConfig(k=10, nprobe_max=16, pruning="none",
                           use_kernel=False, fused_topk=True)
        batch_sizes = args.batch_sizes or [32]
        repeats = args.repeats or 2
        rate = args.rate or 400.0
        duration = args.duration or 1.0
        deadline_s = None if args.deadline_ms is None \
            else args.deadline_ms * 1e-3
    else:
        index, llsp, x, q, topk = build_full_index()
        cfg = SearchConfig(k=10, nprobe_max=64, pruning="llsp", n_ratio=16,
                           use_kernel=False, fused_topk=True)
        batch_sizes = args.batch_sizes or [16, 32, 64]
        repeats = args.repeats or 5
        rate = args.rate or 500.0
        duration = args.duration or 6.0
        deadline_s = (args.deadline_ms or 80.0) * 1e-3

    postings = np.asarray(index.postings)
    pids = np.asarray(index.posting_ids)
    _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    true10 = np.asarray(t10)

    tier = TieredPostings(postings, pids)
    pipe = PrefetchPipeline(index, llsp, cfg, tier=tier)
    ab = run_ab(pipe, q, topk, true10, batch_sizes, repeats)

    # FIFO-vs-locality formation A/B on the seeded locality-skewed trace.
    # smoke: the 130-cluster toy index saturates at the shared nprobe_max
    # (any 16 queries' probe sets blanket most of the index), so the A/B
    # runs at nprobe_max=8 / one-topic batches — both modes share the
    # config, so recall stays bit-equal and the comparison paired
    if args.smoke:
        cfg_loc = dc.replace(cfg, nprobe_max=8)
        loc_batch = 16
    else:
        cfg_loc = cfg
        loc_batch = 32
    loc_rate = rate * (4 if args.smoke else 8)   # formation-pool pressure
    locality = run_locality_ab(
        index, llsp, cfg_loc, (postings, pids), q, true10,
        rate_qps=loc_rate, duration_s=min(duration, 2.0),
        seed=args.seed, max_batch=loc_batch)

    # N-deep in-flight window evidence on a topic-sorted batch stream
    qs_sorted, _ = topic_pool(q, true10, 16, seed=args.seed)
    dtier = TieredPostings(postings, pids)
    dpipe = PrefetchPipeline(index, llsp, cfg, tier=dtier)
    depth_ev = run_depth_evidence(
        dpipe, qs_sorted, np.full(len(qs_sorted), 10, np.int32),
        batch=32, depth=max(args.depth, 2))
    emit("serving_depth_window", 1e6 / max(depth_ev["qps_depth_n"], 1e-9),
         f"inflight {depth_ev['inflight_depth_1']} -> "
         f"{depth_ev['inflight_depth_n']} at depth={depth_ev['depth']}, "
         f"qps {depth_ev['qps_depth_1']:.0f} -> "
         f"{depth_ev['qps_depth_n']:.0f}")

    # the load experiment measures the latency-bound deadline regime: on
    # this CPU the scan is the long pole, so a deeper window only queues
    # batches behind it (depth pays off when scan << gather — TPU); the
    # depth evidence above shows the mechanism, the load run stays 1-deep.
    # full mode also prices the locality machinery on a locality-free
    # uniform trace (paired fifo row) — overhead transparency, not a win
    loads = {}
    for g in (("locality",) if args.smoke else ("fifo", "locality")):
        loads[g] = run_engine_load(index, llsp, (cfg, (postings, pids)), q,
                                   duration, rate, deadline_s, args.seed,
                                   depth=1, grouping=g)
        emit(f"serving_engine_load_{g}",
             1e6 / max(loads[g]["achieved_qps"], 1e-9),
             f"qps={loads[g]['achieved_qps']:.0f} "
             f"p99={loads[g]['latency']['p99_ms']:.1f}ms "
             f"miss={loads[g]['deadline_miss_rate']:.3f} "
             f"shed={loads[g]['shed']}")
    load = loads["locality"]

    # PR 7: tracing-on/off paired overhead + histogram accuracy (CI gate)
    overhead = run_tracing_overhead(
        index, llsp, (cfg, (postings, pids)), q,
        n_queries=300 if args.smoke else 800,
        trials=5)
    emit("serving_tracing_overhead",
         max(overhead["overhead_pct"], 0.0) * 1e3,
         f"q/s ratio on/off={overhead['qps_ratio_median']:.3f} "
         f"({overhead['overhead_pct']:+.1f}% at sample_rate=1.0), "
         f"hist p99 err={overhead['hist_quantile_err']['p99']:.4f}")

    # PR 9: quality-layer on/off paired overhead + proxy calibration on
    # the q8 serving default (calibration hard-asserted inside)
    quality_ab = run_quality_overhead(
        index, llsp, cfg, x, q,
        n_queries=300 if args.smoke else 800,
        trials=5)
    emit("serving_quality_overhead",
         max(quality_ab["overhead_pct"], 0.0) * 1e3,
         f"q/s ratio on/off={quality_ab['qps_ratio_median']:.3f} "
         f"({quality_ab['overhead_pct']:+.1f}%), "
         f"proxy mean={quality_ab['proxy_mean']:.3f} "
         f"true mean={quality_ab['true_mean']:.3f} "
         f"|calib|={quality_ab['calibration_err_mean']:.4f} "
         f"over {quality_ab['audits_done']:.0f} audits")

    payload = {
        "mode": "smoke" if args.smoke else "full",
        "corpus": {"n": int(x.shape[0]), "dim": int(x.shape[1]),
                   "clusters": int(index.n_clusters),
                   "cluster_len": int(index.cluster_len),
                   "payload_mib": int(postings.nbytes >> 20)},
        "config": {"k": cfg.k, "nprobe_max": cfg.nprobe_max,
                   "pruning": cfg.pruning, "use_kernel": cfg.use_kernel},
        "ab": ab,
        "locality_ab": locality,
        "depth_window": depth_ev,
        "engine_load": loads,
        "tracing_overhead": overhead,
        "quality_overhead": quality_ab,
        "tier_totals": {
            "bytes_streamed": tier.stats.bytes_streamed,
            "union_bytes_streamed": tier.stats.union_bytes_streamed,
            "batches": tier.stats.batches,
            "gather_s": tier.stats.gather_s,
            "stream_s": tier.stats.stream_s,
        },
    }
    save_result("bench_serving_pipeline", payload)

    # locality + depth invariants hold at BOTH scales (virtual-clock
    # formation decisions and structural stamp properties — not wall-clock
    # sensitive, so they gate the full run too):
    #   * grouped formation must cut the measured per-batch gather union
    #     (>= 1.2x smoke CI gate on the tiny index; the full corpus clears
    #     1.5x — see ROADMAP) at bit-equal recall (asserted inside the A/B);
    #   * the N-deep window must actually keep >= 2 scans in flight.
    min_cut = 1.2 if args.smoke else 1.5
    assert locality["union_bytes_reduction"] >= min_cut, \
        f"locality union cut {locality['union_bytes_reduction']:.2f}x < {min_cut}x"
    assert depth_ev["inflight_depth_n"] >= 2, \
        f"deep window never had 2 scans in flight: {depth_ev}"
    assert depth_ev["inflight_depth_1"] == 1

    if args.smoke:
        # CI invariants: parity already asserted in run_ab; check overlap
        # actually happened and the engine completed every admitted request.
        # lenient threshold: overlap efficiency is a wall-clock property and
        # a contended CI runner can deschedule the gather thread; the gate
        # is "overlap happened", not "overlap was perfect"
        assert all(r["overlap_eff_pipe"] > 0.1 for r in ab), \
            f"no overlap measured: {[r['overlap_eff_pipe'] for r in ab]}"
        assert all(r["overlap_eff_seq"] == 0.0 for r in ab)
        assert load["completed"] == load["submitted"] - load["rejected"], \
            "engine lost requests"
        # observability must be close to free: tracing every request may
        # cost at most 5% q/s vs the identical engine with tracing off
        assert overhead["qps_ratio_median"] >= 0.95, \
            f"tracing overhead gate: {overhead}"
        # and so must the quality layer (proxy + audits + harvest); the
        # calibration bound is hard-asserted inside run_quality_overhead
        assert quality_ab["qps_ratio_median"] >= 0.95, \
            f"quality overhead gate: {quality_ab}"
        print("[smoke] serving pipeline OK: "
              f"speedup_vs_ref={ab[0]['speedup_vs_ref']:.2f}x "
              f"overlap={ab[0]['overlap_eff_pipe']:.2f} "
              f"locality_cut={locality['union_bytes_reduction']:.2f}x "
              f"inflight_depth={depth_ev['inflight_depth_n']} "
              f"engine_qps={load['achieved_qps']:.0f}")


if __name__ == "__main__":
    main()
