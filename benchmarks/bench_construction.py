"""Fig. 13 / Fig. 21 — construction acceleration + elastic scaling.

* Fig 13 analogue: accelerated (jitted, batched, MXU-shaped) k-means vs a
  naive per-point host loop, across dataset scales — the dispatch-threshold
  curve (device_worth_it).
* Fig 21a analogue: end-to-end 3-stage build, accelerated vs loop-based
  stage-1, measured.
* Fig 21b: elastic-scaling makespan from the SimPool discrete-event model,
  1 -> 10^4 workers with the paper's preemption/retry/eviction policies on.
"""
from __future__ import annotations

import shutil
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.build.elastic import PoolPolicy, SimNode, SimPool, SimTask
from repro.build.kmeans import kmeans
from repro.data import PAPER_DATASETS, make_vectors

from .common import CACHE, emit, save_result


def _naive_kmeans_step(x: np.ndarray, cents: np.ndarray) -> np.ndarray:
    """Per-point host loop (the 'CPU-only single node' regime)."""
    assign = np.empty(x.shape[0], dtype=np.int64)
    for i in range(x.shape[0]):
        assign[i] = np.argmin(((cents - x[i]) ** 2).sum(1))
    return assign


def run() -> dict:
    import dataclasses as dc
    rng = np.random.default_rng(0)

    # ---- Fig 13: accelerated vs naive across scales -----------------------
    speedups = {}
    for n in (1_000, 10_000, 50_000):
        x = rng.normal(size=(n, 64)).astype(np.float32)
        k = max(8, n // 500)
        cents = x[:k].copy()
        t0 = time.perf_counter()
        _naive_kmeans_step(x[: min(n, 2_000)], cents)
        t_naive = (time.perf_counter() - t0) / min(n, 2_000) * n

        from repro.kernels import ops as kops
        xj, cj = jnp.asarray(x), jnp.asarray(cents)
        kops.kmeans_assign(xj, cj)       # compile
        t0 = time.perf_counter()
        jax.block_until_ready(kops.kmeans_assign(xj, cj))
        t_acc = time.perf_counter() - t0
        speedups[n] = t_naive / t_acc

    # ---- Fig 21a: end-to-end build, accelerated stage 1 -------------------
    from repro.build.pipeline import BuildConfig, build_index
    spec = dc.replace(PAPER_DATASETS["sift"], n=20_000, dim=32, n_modes=32)
    x = make_vectors(spec)
    wd = CACHE + "/construct_bench"
    shutil.rmtree(wd, ignore_errors=True)
    t0 = time.perf_counter()
    _, _, report = build_index(
        x, BuildConfig(max_cluster_size=96, cluster_len=128,
                       coarse_per_task=5000, n_workers=2), wd)
    t_build = time.perf_counter() - t0

    # ---- Fig 21b: elastic scaling makespan --------------------------------
    tasks = [SimTask(i, work=10.0) for i in range(4096)]
    scaling = {}
    for workers in (1, 16, 256, 1024, 10_000):
        nodes = [SimNode(i, preempt_rate=0.05 if i % 7 == 0 else 0.0)
                 for i in range(workers)]
        rep = SimPool(nodes, PoolPolicy(seed=1)).run(list(tasks))
        scaling[workers] = dict(makespan=rep.makespan,
                                preemptions=rep.n_preemptions,
                                reassigned=rep.n_reassignments,
                                evicted=rep.n_evictions,
                                backups=rep.n_backups)

    payload = {
        "fig13_speedup_by_scale": speedups,
        "fig21a_build": {"seconds": t_build,
                         "stage_seconds": report.stage_seconds,
                         "n_clusters": report.n_clusters,
                         "replication": report.replication},
        "fig21b_elastic_scaling": scaling,
        "paper_claims": "~10x from acceleration (Fig 21a); 16h -> 4-7h from "
                        "1024 -> 1e4 workers (Fig 21b)",
    }
    save_result("construction", payload)
    for n, s in speedups.items():
        emit(f"construct.assign_speedup.n{n}", 0.0, f"{s:.1f}x")
    emit("construct.e2e_build", t_build * 1e6,
         f"clusters={report.n_clusters}")
    emit("construct.elastic_1k_to_10k", 0.0,
         f"{scaling[1024]['makespan']/scaling[10_000]['makespan']:.2f}x")
    return payload


if __name__ == "__main__":
    run()
