"""Fig. 13 / Fig. 21 — construction acceleration + elastic scaling, PR 3.

Four experiments (``--smoke`` runs the CI-sized copy with assertions):

* **Assign-kernel A/B** (Fig 13 analogue): one paired Lloyd E+M step on the
  SAME (x, centroids) through the legacy path (materialized distance tile,
  argmin readback, host float64 scatter-add) and the fused path
  (kernels/kmeans_assign: in-VMEM distances, device-accumulated sums/counts).
  Assignments are asserted BIT-IDENTICAL; timing is paired-interleaved.
* **Writeback table**: analytic HBM bytes per Lloyd iteration — legacy
  materializes the (N, K) f32 distance matrix; fused emits only
  (K, D) sums + (K,) counts + (N,) assign + (N,) min-dists.  The smoke
  asserts the >= 50x reduction at K=1024, D=64 the issue calls for.
* **Streamed stage-2 build** (Fig 21a analogue): end-to-end ``build_index``
  on the fused+streamed defaults, reporting per-shard stage stamps
  (load/stream/dispatch/done) and the measured load-under-assign overlap,
  then a kill-and-resume mid-stage-2 that must reproduce the exact index
  hash.
* **Fig 21b**: elastic-scaling makespan from the SimPool discrete-event
  model (full mode only).

JSON lands in results/bench/bench_construction.json (CI artifact for the
build-side perf trajectory).
"""
from __future__ import annotations

import argparse
import os
import shutil
import time

import numpy as np
import jax
import jax.numpy as jnp

try:  # package import (benchmarks.run) or direct script execution
    from .common import CACHE, emit, save_result, time_fn
except ImportError:  # pragma: no cover - script mode
    from common import CACHE, emit, save_result, time_fn


def _naive_kmeans_step(x: np.ndarray, cents: np.ndarray) -> np.ndarray:
    """Per-point host loop (the 'CPU-only single node' regime)."""
    assign = np.empty(x.shape[0], dtype=np.int64)
    for i in range(x.shape[0]):
        assign[i] = np.argmin(((cents - x[i]) ** 2).sum(1))
    return assign


# --------------------------------------------------------------------------
# assign-step writeback accounting (the tentpole's bytes claim)
# --------------------------------------------------------------------------
def assign_writeback_table(
    shapes=((50_000, 1024, 64), (50_000, 256, 64), (20_000, 1024, 128),
            (100_000, 4096, 64)),
) -> tuple[str, list]:
    """Analytic HBM writeback per Lloyd iteration.

    Legacy: the (N, K) f32 distance matrix round-trips HBM (written by the
    pairwise kernel, re-read for argmin) and the M-step re-reads x on host.
    Fused: only the ANSWER crosses the pallas boundary — (K, D) f32 sums,
    (K,) f32 counts, (N,) i32 assignments, (N,) f32 min-dists.
    """
    rows = []
    lines = [
        "| N | K | D | legacy bytes/iter | fused bytes/iter | reduction |",
        "|---|---|---|---|---|---|",
    ]
    for n, k, d in shapes:
        legacy = n * k * 4
        fused = (k * d + k) * 4 + n * (4 + 4)
        rows.append(dict(N=n, K=k, D=d, legacy_bytes=legacy,
                         fused_bytes=fused, reduction_x=legacy / fused))
        lines.append(
            f"| {n} | {k} | {d} | {legacy / 2**20:.1f} MiB | "
            f"{fused / 2**20:.2f} MiB | {legacy / fused:.0f}x |")
    return "\n".join(lines), rows


# --------------------------------------------------------------------------
# paired A/B: fused vs legacy Lloyd step
# --------------------------------------------------------------------------
def assert_assign_parity(a_f, a_u, x, cents) -> bool:
    """Fused-vs-legacy assignment parity.  Off-TPU the two paths argmin over
    the SAME oracle distances, so parity is structural and asserted
    bit-exact.  On TPU they are two different Pallas kernels with different
    f32 reduction orders, so an argmin flip is tolerated ONLY where the two
    picks are numerically tied for that point.  Returns bit_identical."""
    a_f, a_u = np.asarray(a_f), np.asarray(a_u)
    bit_identical = bool((a_f == a_u).all())
    if jax.default_backend() != "tpu":
        assert bit_identical, "fused assign diverged from the jnp reference"
        return True
    flip = a_f != a_u
    if flip.any():
        from repro.kernels.ref import assign_distances_f64
        np.testing.assert_allclose(
            assign_distances_f64(x[flip], cents, a_f[flip]),
            assign_distances_f64(x[flip], cents, a_u[flip]),
            rtol=1e-4, atol=1e-4, err_msg="non-tie argmin divergence")
    return bit_identical


def run_assign_ab(n: int, k: int, d: int, repeats: int = 3,
                  seed: int = 0) -> dict:
    from repro.build.kmeans import kmeans_assign_step

    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    cents = x[rng.choice(n, size=k, replace=False)].copy()

    a_f, _, s_f, c_f = kmeans_assign_step(x, cents, fused=True)
    a_u, _, s_u, c_u = kmeans_assign_step(x, cents, fused=False)
    bit_identical = assert_assign_parity(a_f, a_u, x, cents)
    sums_err = float(np.abs(s_f - s_u).max())

    # paired-interleaved timing (same inputs, alternating paths)
    t_f, t_u = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        kmeans_assign_step(x, cents, fused=False)
        t_u.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        kmeans_assign_step(x, cents, fused=True)
        t_f.append(time.perf_counter() - t0)
    return {
        "n": n, "k": k, "d": d,
        "assign_bit_identical": bit_identical,
        "sums_max_abs_err": sums_err,
        "legacy_s": float(np.median(t_u)),
        "fused_s": float(np.median(t_f)),
        "speedup_x": float(np.median(t_u) / max(np.median(t_f), 1e-12)),
    }


# --------------------------------------------------------------------------
# streamed stage-2 build: overlap stamps + mid-stage-2 resume hash
# --------------------------------------------------------------------------
def run_streamed_build(n: int, dim: int, per_task: int, workdir: str) -> dict:
    import dataclasses as dc

    from repro.build.pipeline import (
        BuildConfig, _chunks, build_index, index_content_hash)
    from repro.build.stream import (
        ShardAssignPipeline, pair_overlaps, shard_overlap_efficiency)
    from repro.data import PAPER_DATASETS, make_vectors

    spec = dc.replace(PAPER_DATASETS["sift"], n=n, dim=dim, n_modes=32)
    x = make_vectors(spec)
    cfg = BuildConfig(max_cluster_size=96, cluster_len=128,
                      coarse_per_task=per_task, n_workers=2)
    shutil.rmtree(workdir, ignore_errors=True)
    t0 = time.perf_counter()
    index, _, report = build_index(x, cfg, workdir)
    t_build = time.perf_counter() - t0
    h0 = index_content_hash(index)
    overlaps = pair_overlaps(report.shard_stamps)

    # paired shard-pipeline A/B on the same spans/centroids: pipelined vs
    # strictly-sequential stage chain (fresh checkpoint dirs so both run);
    # spans come from the pipeline's own chunker so the A/B and the resume
    # victim always match the real build's shard layout
    spans = _chunks(n, per_task)
    cents = np.load(os.path.join(workdir, "stage1_centroids.npy"))
    ab = {}
    for mode in ("sequential", "pipelined"):
        sdir = os.path.join(workdir, f"ab_{mode}")
        os.makedirs(sdir, exist_ok=True)
        paths = [os.path.join(sdir, f"assign_{i:05d}.npz")
                 for i in range(len(spans))]
        pipe = ShardAssignPipeline(x, cents, spans, paths,
                                   eps=cfg.closure_eps,
                                   max_replicas=cfg.max_replicas)
        try:
            t0 = time.perf_counter()
            st = pipe.run_sequential() if mode == "sequential" else pipe.run()
            ab[mode] = {"stage2_s": time.perf_counter() - t0,
                        "overlap_eff": shard_overlap_efficiency(st)}
        finally:
            pipe.close()
    assert ab["sequential"]["overlap_eff"] == 0.0
    for i in range(len(spans)):       # same artifact either way
        a_s = np.load(os.path.join(workdir, "ab_sequential",
                                   f"assign_{i:05d}.npz"))["assign"]
        a_p = np.load(os.path.join(workdir, "ab_pipelined",
                                   f"assign_{i:05d}.npz"))["assign"]
        np.testing.assert_array_equal(a_s, a_p)

    # kill-and-resume mid-stage-2: drop one shard checkpoint, rebuild
    shards_dir = os.path.join(workdir, "shards")
    victim = sorted(p for p in os.listdir(shards_dir)
                    if p.endswith(".npz"))[len(spans) // 2]
    os.remove(os.path.join(shards_dir, victim))
    t0 = time.perf_counter()
    index2, _, report2 = build_index(x, cfg, workdir)
    t_resume = time.perf_counter() - t0
    h1 = index_content_hash(index2)

    return {
        "n": n, "dim": dim, "shards": len(report.shard_stamps),
        "build_s": t_build, "stage_seconds": report.stage_seconds,
        "n_clusters": report.n_clusters, "replication": report.replication,
        "shard_overlap_eff": report.shard_overlap,
        "pair_overlap_s": overlaps,
        "stage2_ab": ab,
        "shard_stamps": report.shard_stamps,
        "resume": {
            "victim": victim, "resume_s": t_resume,
            "resumed_stages": report2.resumed_stages,
            "hash_before": h0, "hash_after": h1,
            "hash_identical": h0 == h1,
        },
    }


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
def run(smoke: bool = False) -> dict:
    rng = np.random.default_rng(0)
    payload: dict = {"mode": "smoke" if smoke else "full"}

    # ---- writeback accounting (analytic, both modes) ----------------------
    wb_md, wb_rows = assign_writeback_table()
    payload["assign_writeback"] = wb_rows
    payload["assign_writeback_md"] = wb_md
    gate = next(r for r in wb_rows if r["K"] == 1024 and r["D"] == 64)
    assert gate["reduction_x"] >= 50.0, \
        f"writeback reduction {gate['reduction_x']:.0f}x < 50x at K=1024,D=64"

    # ---- paired fused-vs-legacy Lloyd step --------------------------------
    ab_shapes = ([(6_000, 256, 32)] if smoke
                 else [(20_000, 512, 32), (50_000, 1024, 64)])
    payload["assign_ab"] = [run_assign_ab(*s) for s in ab_shapes]
    for r in payload["assign_ab"]:
        emit(f"construct.assign_ab.n{r['n']}_k{r['k']}",
             r["fused_s"] * 1e6,
             f"legacy={r['legacy_s']*1e6:.0f}us;x{r['speedup_x']:.2f};"
             f"bit_identical={r['assign_bit_identical']}")

    # ---- streamed stage-2 build + resume ----------------------------------
    wd = os.path.join(CACHE, "construct_bench")
    build = (run_streamed_build(6_000, 24, 1_000, wd) if smoke
             else run_streamed_build(20_000, 32, 4_000, wd))
    payload["streamed_build"] = build
    emit("construct.e2e_build", build["build_s"] * 1e6,
         f"clusters={build['n_clusters']};"
         f"overlap={build['shard_overlap_eff']:.2f}")
    emit("construct.resume_hash", build["resume"]["resume_s"] * 1e6,
         f"identical={build['resume']['hash_identical']}")

    if smoke:
        assert build["resume"]["hash_identical"], \
            "mid-stage-2 resume changed the index hash"
        assert "stage2:partial" in build["resume"]["resumed_stages"]
        # lenient like the serving smoke: the gate is "overlap happened",
        # not "overlap was perfect" — CI boxes deschedule threads freely
        assert build["pair_overlap_s"] and max(build["pair_overlap_s"]) > 0, \
            f"no shard load hidden under an assign: {build['pair_overlap_s']}"
        save_result("bench_construction", payload)
        print("[smoke] construction pipeline OK: "
              f"assign_speedup={payload['assign_ab'][0]['speedup_x']:.2f}x "
              f"writeback={gate['reduction_x']:.0f}x "
              f"shard_overlap={build['shard_overlap_eff']:.2f} "
              f"resume_hash=identical")
        return payload

    # ---- Fig 13: accelerated vs naive across scales (full only) -----------
    from repro.kernels import ops as kops
    speedups = {}
    for n in (1_000, 10_000, 50_000):
        x = rng.normal(size=(n, 64)).astype(np.float32)
        k = max(8, n // 500)
        cents = x[:k].copy()
        t0 = time.perf_counter()
        _naive_kmeans_step(x[: min(n, 2_000)], cents)
        t_naive = (time.perf_counter() - t0) / min(n, 2_000) * n
        xj, cj = jnp.asarray(x), jnp.asarray(cents)
        t_acc = time_fn(lambda: kops.kmeans_assign_update(xj, cj))
        speedups[n] = t_naive / t_acc
    payload["fig13_speedup_by_scale"] = speedups
    for n, s in speedups.items():
        emit(f"construct.assign_speedup.n{n}", 0.0, f"{s:.1f}x")

    # ---- Fig 21b: elastic scaling makespan --------------------------------
    from repro.build.elastic import PoolPolicy, SimNode, SimPool, SimTask
    tasks = [SimTask(i, work=10.0) for i in range(4096)]
    scaling = {}
    for workers in (1, 16, 256, 1024, 10_000):
        nodes = [SimNode(i, preempt_rate=0.05 if i % 7 == 0 else 0.0)
                 for i in range(workers)]
        rep = SimPool(nodes, PoolPolicy(seed=1)).run(list(tasks))
        scaling[workers] = dict(makespan=rep.makespan,
                                preemptions=rep.n_preemptions,
                                reassigned=rep.n_reassignments,
                                evicted=rep.n_evictions,
                                backups=rep.n_backups)
    payload["fig21b_elastic_scaling"] = scaling
    payload["paper_claims"] = (
        "~10x from acceleration (Fig 21a); 16h -> 4-7h from 1024 -> 1e4 "
        "workers (Fig 21b)")
    emit("construct.elastic_1k_to_10k", 0.0,
         f"{scaling[1024]['makespan']/scaling[10_000]['makespan']:.2f}x")
    save_result("bench_construction", payload)
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI run with assertions")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
