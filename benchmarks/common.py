"""Shared benchmark infrastructure.

Scale note: the container is one CPU core, so the corpus is scaled to
30k x 32-d (SIFT100M-shaped: clustered, same cluster_len/replication as the
paper's setup) and ALL compute-side numbers are real measurements.  The SSD
term cannot be measured here; it is modeled with the PAPER'S OWN measured
service rates (Fig. 9b) and device specs (Table 1), clearly split out in
every result row:

  I/O model (per search thread / core):
    libaio   ~35 KIOPS   (SPANN's stack, Fig. 9a/9b)
    io_uring ~60 KIOPS
    spdk    ~170 KIOPS   (Helmsman's stack; meets the 120-170 KIOPS need)
    read latency (Gen5, 12 KB) ~ 100 us  — multiplies the HOP count of
    graph traversal (dependency-chained reads, §3.2); clustering reads are
    dependency-free so they are throughput- not latency-bound.

Every bench writes JSON under results/bench/ and prints a CSV row
``name,us_per_call,derived`` (benchmarks/run.py aggregates them).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "results", "bench")
CACHE = os.path.join(ROOT, "results", "bench_cache")

IO_MODEL = {
    "libaio_kiops": 35e3,
    "io_uring_kiops": 60e3,
    "spdk_kiops": 170e3,
    "read_latency_s": 100e-6,      # dependency-chained read (graph hop)
    "cluster_pages": 3,            # 12 KB cluster list = 3 x 4 KB LBAs
    "gen4_over_gen5_bw": 6.5 / 12.0,
}


@dataclasses.dataclass
class BenchIndex:
    index: object
    llsp: object
    x: np.ndarray
    q: np.ndarray
    topk: np.ndarray
    true10: np.ndarray
    true100: np.ndarray


_CACHED: Optional[BenchIndex] = None


def get_bench_index(n: int = 30_000, dim: int = 32, n_queries: int = 512) -> BenchIndex:
    """Build (or resume from results/bench_cache) the benchmark index."""
    global _CACHED
    if _CACHED is not None:
        return _CACHED
    import dataclasses as dc
    from repro.build.pipeline import BuildConfig, build_index
    from repro.core.ivf import brute_force_topk
    from repro.core.llsp import LLSPConfig
    from repro.data import PAPER_DATASETS, make_queries, make_vectors

    os.makedirs(CACHE, exist_ok=True)
    spec = dc.replace(PAPER_DATASETS["sift"], n=n, dim=dim, n_modes=48)
    x = make_vectors(spec)
    q, topk = make_queries(spec, n_queries)
    topk = np.minimum(topk, 100).astype(np.int32)
    cfg = BuildConfig(
        max_cluster_size=96, cluster_len=128, coarse_per_task=6000,
        n_workers=2, closure_eps=0.2,
        llsp=LLSPConfig(levels=(8, 16, 32, 64), recall_target=0.9,
                        n_ratio_features=16, n_trees=50, max_depth=5),
    )
    idx, llsp, _ = build_index(x, cfg, os.path.join(CACHE, "bench_index"),
                               queries=q, query_topk=topk)
    _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    _, t100 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 100)
    _CACHED = BenchIndex(idx, llsp, x, q, topk,
                         np.asarray(t10), np.asarray(t100))
    return _CACHED


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds of fn(*args) (jax results block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def save_result(name: str, payload: dict) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def recall10(ids: np.ndarray, true10: np.ndarray) -> float:
    from repro.core.distance import recall_at_k
    return recall_at_k(ids[:, :10], true10)


def io_time_clustered(n_probes: float, stack: str) -> float:
    """Batched dependency-free reads: service-rate bound (per core)."""
    return n_probes / IO_MODEL[f"{stack}_kiops"]


def io_time_graph(hops: int, beam_reads: int) -> float:
    """Dependency-chained rounds x read latency (beam reads within a round
    are parallel, so rounds dominate)."""
    return hops * IO_MODEL["read_latency_s"]
