"""Sharded-fabric bench — scaling sweep + the kill-a-shard drill (PR 6).

Two experiments against the replicated serving fabric
(``repro.distributed.ShardedFabric``), both seeded and replayable:

1. **Scaling sweep** — the same query stream through S = 1/2/4/8 simulated
   shards.  The container is ONE core, so S worker threads time-share it
   and wall q/s cannot scale; what IS measured per shard is scan-busy
   seconds from per-task service stamps, and the bottleneck-shard model
   ``virtual_qps = n_queries / max_s(busy_s[s])`` gives the throughput an
   S-host deployment would see (each host runs its shard's measured work
   in parallel; the fan-out is embarrassingly parallel and the merge is
   on the router).  Wall q/s is reported alongside, unmodeled.  The gate:
   merged top-k BIT-EQUAL to S=1 at every S (equal recall by construction),
   and near-linear virtual scaling to S=8.
2. **Kill-a-shard drill** — shard-skewed live traffic through ServeEngine,
   a seeded FaultInjector kills the hot shard mid-trace.  Gates: ZERO
   dropped queries (every submission completes "ok" — the hot shard's
   primaries are R=2-replicated, so failover loses nothing), recall@10
   parity within 0.002 before/after failover, and a bounded p99 over the
   failover cohort (queries in flight around the kill), reported as the
   failover gap.
3. **Quality drill (PR 9)** — the same kill with replication DISABLED
   (R=1), so the victim's clusters are genuinely lost and the quality
   observability stack must catch it: the victim shard's coverage-proxy
   histogram dips below the survivors', the ``partial`` burn-rate alert
   fires during the outage and clears with hysteresis once traffic
   drains, and every completion lands in the telemetry harvest, whose
   npz shard replays back into the exact per-query records.  Artifacts:
   ``results/bench/health_snapshot.json`` (final health doc + the
   per-tick snapshot series) and ``results/bench/harvest_drill.npz``.

``--smoke`` is the scaled-down CI copy with every gate asserted.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

import os

from common import RESULTS, emit, save_result

from repro.build.kmeans import balanced_hierarchical_kmeans
from repro.core.distance import recall_at_k
from repro.core.ivf import IVFIndex, brute_force_topk, build_postings
from repro.core.search import SearchConfig
from repro.core.spann_rules import closure_assign
from repro.data import PAPER_DATASETS, make_queries, make_vectors
from repro.distributed import FaultInjector, ShardedFabric
from repro.obs import (HarvestRing, Observability, QualityMonitor,
                       SLOTracker, check_well_nested, default_rules,
                       health_snapshot, load_npz, write_health)
from repro.runtime import (
    BatchPolicy,
    DynamicBatcher,
    ServeEngine,
    latency_percentiles,
    shard_skewed_trace,
)

import dataclasses as dc


def build_corpus(smoke: bool):
    if smoke:
        n, dim, n_modes, max_cluster, clen, nq = 4000, 24, 16, 48, 64, 256
    else:
        n, dim, n_modes, max_cluster, clen, nq = 20_000, 32, 32, 96, 128, 512
    spec = dc.replace(PAPER_DATASETS["sift"], n=n, dim=dim, n_modes=n_modes)
    x = make_vectors(spec)
    q, _ = make_queries(spec, nq)
    cents, _ = balanced_hierarchical_kmeans(x, max_cluster_size=max_cluster,
                                            iters=8, fused=True)
    ca = np.asarray(closure_assign(jnp.asarray(x), jnp.asarray(cents),
                                   eps=0.2, max_replicas=4))
    postings, pids = build_postings(x, ca, cents.shape[0], clen)
    index = IVFIndex(jnp.asarray(cents), jnp.asarray(postings),
                     jnp.asarray(pids))
    _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    return index, q.astype(np.float32), np.asarray(t10)


def run_batches(fab: ShardedFabric, q: np.ndarray, k: int,
                batch: int = 32, passes: int = 2):
    """Drive the live stage protocol batch by batch; returns (ids, wall)."""
    out = []
    t0 = time.perf_counter()
    for _ in range(passes):
        for lo in range(0, len(q), batch):
            plan = fab.plan(q[lo:lo + batch], k)
            res = fab.harvest(fab.dispatch(fab.prefetch(plan)))
            out.append(res.ids)
    wall = time.perf_counter() - t0
    return np.concatenate(out[:len(out) // passes]), wall


def scaling_sweep(index, q, true10, shard_counts, k: int = 10,
                  reps: int = 3) -> list[dict]:
    """Virtual bottleneck-shard scaling, best of ``reps`` sweeps.

    The per-shard busy stamps that define virtual q/s are taken inside
    worker threads; on an oversubscribed host (CI runners, the 1-core dev
    box) a worker descheduled mid-task keeps its busy window open, which
    can only INFLATE busy time and understate scaling — the noise is
    one-sided.  Max-over-repetitions is therefore the consistent
    estimator of what the fabric can actually do; single-sweep numbers
    here flap by >2x run to run at S=8.
    """
    n_clusters = int(np.asarray(index.postings).shape[0])
    cfg = SearchConfig(k=k, nprobe_max=16, pruning="none",
                      use_kernel=False, fused_topk=True)
    passes = 2
    best: dict[int, dict] = {}
    ref_ids = None
    for rep in range(reps):
        for s in shard_counts:
            fab = ShardedFabric(index, None, cfg, n_shards=s,
                                hot_clusters=np.arange(n_clusters))
            fab.warmup()
            fab.start()
            try:
                ids, wall = run_batches(fab, q, k, passes=passes)
            finally:
                fab.stop()
            n_served = len(q) * passes
            busy = fab.stats.busy_s
            virtual_qps = n_served / float(busy.max())
            if ref_ids is None:
                ref_ids = ids
            row = {
                "shards": s,
                "wall_qps": n_served / wall,
                "virtual_qps": virtual_qps,
                "busy_s_per_shard": busy.tolist(),
                "busy_imbalance": float(busy.max() / max(busy.mean(),
                                                         1e-12)),
                "tasks_per_shard": fab.stats.tasks_per_shard.tolist(),
                "bit_equal_vs_s1": bool(np.array_equal(ids, ref_ids)),
                "recall_at_10": float(recall_at_k(ids[:, :10], true10)),
            }
            # bit-equality must hold on EVERY sweep, not just the kept one
            assert row["bit_equal_vs_s1"], f"S={s} rep={rep} ids diverged"
            if s not in best or virtual_qps > best[s]["virtual_qps"]:
                best[s] = row
            print(f"[fabric] rep{rep} S={s}: virtual {virtual_qps:7.0f} "
                  f"q/s, wall {row['wall_qps']:5.0f} q/s, imbalance "
                  f"{row['busy_imbalance']:.2f}, "
                  f"bit_equal={row['bit_equal_vs_s1']}", flush=True)
    base_vqps = best[shard_counts[0]]["virtual_qps"]
    rows = []
    for s in shard_counts:
        row = best[s]
        row["speedup_vs_s1"] = row["virtual_qps"] / base_vqps
        rows.append(row)
        print(f"[fabric] best S={s}: virtual {row['virtual_qps']:7.0f} q/s "
              f"(x{row['speedup_vs_s1']:.2f}) over {reps} sweeps", flush=True)
    return rows


def _export_drill_trace(obs: Observability, n_completed: int) -> dict:
    """Export the drill's Perfetto trace to results/bench/ (uploaded as a
    CI artifact) and validate it structurally: well-nested per track, one
    terminal per admitted request, per-shard fan-out spans on >= 2 shard
    tracks, and — when the kill produced requeues — the requeued tasks'
    trace_ids reaching a merge span (identity survives failover)."""
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, "trace_fabric_drill.json")
    doc = obs.trace.export(path)
    te = doc["traceEvents"]
    violations = check_well_nested(te)
    assert not violations, f"drill trace malformed: {violations[:3]}"
    begun, terms = set(), {}
    requeued_tids, merged_tids = set(), set()
    for e in te:
        a = e.get("args") or {}
        if e["ph"] == "b" and e["name"] == "request":
            begun.add(a["trace_id"])
        elif e["ph"] == "i" and e["name"].startswith("done:"):
            terms[a["trace_id"]] = terms.get(a["trace_id"], 0) + 1
        elif e["ph"] == "b" and e["name"] == "task" \
                and a.get("kind") == "requeue":
            requeued_tids.update(a["trace_ids"])
        elif e["ph"] == "X" and e["name"] == "merge":
            merged_tids.update(a["trace_ids"])
    assert len(begun) == n_completed and set(terms) == begun \
        and all(n == 1 for n in terms.values()), \
        f"terminal mismatch: {len(begun)} begun, {len(terms)} terminated"
    assert requeued_tids <= merged_tids, \
        "requeued trace_ids never reached a merge span"
    track_names = {e["tid"]: e["args"]["name"] for e in te if e["ph"] == "M"}
    scan_tracks = {track_names[e["tid"]] for e in te
                   if e["ph"] == "X" and e["name"] == "scan"}
    n_shard_tracks = len([t for t in scan_tracks if t.startswith("shard-")])
    assert n_shard_tracks >= 2, f"fan-out not traced: {scan_tracks}"
    failover_instants = sum(1 for e in te
                            if e["ph"] == "i" and e["name"] == "failover")
    print(f"[drill] trace: {len(te)} events -> {path} "
          f"(requests={len(begun)}, requeued_tids={len(requeued_tids)}, "
          f"shard_tracks={n_shard_tracks}, failover_instants="
          f"{failover_instants}, dropped={doc['otherData']['dropped_events']})",
          flush=True)
    return {
        "path": os.path.relpath(path, os.path.dirname(RESULTS)),
        "events": len(te),
        "requests_traced": len(begun),
        "requeued_trace_ids": len(requeued_tids),
        "shard_tracks_with_scans": n_shard_tracks,
        "failover_instants": failover_instants,
        "dropped_events": doc["otherData"]["dropped_events"],
    }


def kill_drill(index, q, true10, n_shards: int, smoke: bool,
               seed: int, k: int = 10) -> dict:
    cfg = SearchConfig(k=k, nprobe_max=16, pruning="none",
                      use_kernel=False, fused_topk=True)
    victim = 1
    rate, duration, kill_at = (300.0, 1.0, 0.3) if smoke \
        else (500.0, 2.0, 0.8)
    probe = ShardedFabric(index, None, cfg, n_shards=n_shards)
    hot = np.nonzero(probe.rmap0.replicas[:, 0] == victim)[0]
    inj = FaultInjector(seed=seed).kill(kill_at, shard=victim)
    # PR 7: drills run with full tracing ON — the exported trace is a CI
    # artifact (the failover flamegraph) and is structurally validated below
    obs = Observability(sample_rate=1.0)
    fab = ShardedFabric(index, None, cfg, n_shards=n_shards,
                        hot_clusters=hot, injector=inj,
                        hedge_after_s=0.05, tick_s=0.02, obs=obs)
    fab.warmup()
    rec_before = float(recall_at_k(
        fab.scan_sync(q, k).ids[:, :10], true10))
    fab.start()
    eng = ServeEngine({"default": fab},
                      DynamicBatcher(BatchPolicy(max_batch=16,
                                                 max_wait_s=0.004),
                                     ["default"]),
                      obs=obs)
    eng.start()
    hot_rows = np.nonzero(fab.query_shards(q) == victim)[0]
    trace = shard_skewed_trace(rate, duration, len(q), hot_rows, seed=seed)
    t0 = time.monotonic()
    inj.arm(t0)
    try:
        for a in trace:
            lag = t0 + a.t - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            eng.submit(q[a.qrow], k)
    finally:
        eng.stop(drain=True)
        fab.stop()
    comps = eng.qp.poll()
    rec_after = float(recall_at_k(
        fab.scan_sync(q, k).ids[:, :10], true10))
    lat = [c.latency for c in comps]
    kill_t = t0 + inj.log[0][0] if inj.log else None
    # failover cohort: queries in flight around the kill — their p99 is
    # the client-visible failover gap
    gap = [c.latency for c in comps
           if kill_t is not None
           and kill_t - 0.1 <= c.submitted <= kill_t + 0.5]
    st = eng.stats
    drill = {
        "shards": n_shards, "victim": victim, "kill_at_s": kill_at,
        "offered_qps": rate, "duration_s": duration,
        "hot_query_rows": int(hot_rows.size),
        "replicated_clusters": int(hot.size),
        "submitted": st.submitted, "completed": st.completed,
        "dropped": st.submitted - st.rejected - st.completed,
        "rejected": st.rejected, "shed": st.shed,
        "failed": st.failed, "partial": st.partial,
        "statuses": sorted(set(c.status for c in comps)),
        "failovers": fab.stats.failovers,
        "dead_replies": fab.stats.dead_replies,
        "requeued_tasks": fab.stats.requeued_tasks,
        "hedges": fab.stats.hedges,
        "timeouts": fab.stats.timeouts,
        "recall10_before": rec_before,
        "recall10_after": rec_after,
        "latency": latency_percentiles(lat),
        "failover_gap": latency_percentiles(gap) if gap else None,
        "fault_log": [{"t_s": t, "kind": kk, "shard": s}
                      for t, kk, s in inj.log],
        "trace": _export_drill_trace(obs, st.completed),
    }
    print(f"[drill] S={n_shards} kill shard {victim} @ {kill_at}s: "
          f"{st.completed}/{st.submitted} completed, dropped="
          f"{drill['dropped']}, statuses={drill['statuses']}, "
          f"failovers={[(f['shard'], f['lost']) for f in drill['failovers']]}, "
          f"recall {rec_before:.3f} -> {rec_after:.3f}", flush=True)
    if gap:
        print(f"[drill] failover gap p99 "
              f"{drill['failover_gap']['p99_ms']:.0f}ms over {len(gap)} "
              f"in-flight queries (steady-state p99 "
              f"{drill['latency']['p99_ms']:.0f}ms)", flush=True)
    return drill


def quality_drill(index, q, n_shards: int, smoke: bool,
                  seed: int, k: int = 10) -> dict:
    """Kill a shard with NO replica (R=1) and gate that the PR 9 quality
    stack detects, alerts, and records the outage (see module doc)."""
    cfg = SearchConfig(k=k, nprobe_max=16, pruning="none",
                      use_kernel=False, fused_topk=True)
    victim = 1
    rate, duration, kill_at = (300.0, 1.0, 0.3) if smoke \
        else (500.0, 2.0, 0.8)
    fast_s, slow_s = (0.25, 1.0) if smoke else (0.5, 2.0)
    inj = FaultInjector(seed=seed).kill(kill_at, shard=victim)
    obs = Observability.off()      # metrics-only: the flamegraph artifact
    # is kill_drill's job; this drill exercises the quality streams
    fab = ShardedFabric(index, None, cfg, n_shards=n_shards,
                        n_replicas=1, injector=inj,
                        hedge_after_s=0.05, tick_s=0.02, obs=obs)
    fab.warmup()
    fab.start()
    harvest = HarvestRing()
    quality = QualityMonitor(obs.metrics, shadow_rate=0.0, harvest=harvest)
    slo = SLOTracker(metrics=obs.metrics)
    default_rules(slo, obs.metrics, quality=quality,
                  fast_s=fast_s, slow_s=slow_s)
    eng = ServeEngine({"default": fab},
                      DynamicBatcher(BatchPolicy(max_batch=16,
                                                 max_wait_s=0.004),
                                     ["default"]),
                      obs=obs, quality=quality)
    eng.start()
    hot_rows = np.nonzero(fab.query_shards(q) == victim)[0]
    trace = shard_skewed_trace(rate, duration, len(q), hot_rows, seed=seed)
    vic_hist = quality._labeled_hist(f"shard:{victim}")

    def snap(t_rel: float) -> dict:
        states = slo.tick()
        st = slo.alerts["partial"]
        return {"t_s": round(t_rel, 3), "alerts": states,
                "partial_fast_burn": round(st.fast_burn, 3),
                "partial_slow_burn": round(st.slow_burn, 3),
                "victim_proxy_n": vic_hist.n,
                "victim_proxy_mean": vic_hist.to_dict()["mean"]}

    snaps = []
    t0 = time.monotonic()
    inj.arm(t0)
    next_tick = 0.05
    try:
        for a in trace:
            lag = t0 + a.t - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            now = time.monotonic() - t0
            if now >= next_tick:
                snaps.append(snap(now))
                next_tick = now + 0.05
            eng.submit(q[a.qrow], k)
    finally:
        eng.stop(drain=True)
        fab.stop()
    comps = eng.qp.poll()
    # keep ticking after traffic ends: the windowed burn decays to zero
    # once the outage leaves both windows, and hysteresis clears the alert
    t_end = time.monotonic() - t0 + 3.0 * slow_s
    while time.monotonic() - t0 < t_end:
        snaps.append(snap(time.monotonic() - t0))
        st = slo.alerts["partial"]
        if st.fires and st.state == "ok":
            break
        time.sleep(0.05)
    quality.close()

    # per-shard coverage-proxy rollup: the victim must dip below survivors
    shard_proxy = {}
    for s in range(n_shards):
        h = quality._labeled_hist(f"shard:{s}")
        if h.n:
            shard_proxy[s] = h.to_dict()
    survivors = [d["mean"] for s, d in shard_proxy.items() if s != victim]
    st = eng.stats
    # harvest shard: flush and replay — the records must round-trip exactly
    os.makedirs(RESULTS, exist_ok=True)
    hpath = os.path.join(RESULTS, "harvest_drill.npz")
    harvest.flush_npz(hpath)
    replayed = load_npz(hpath)
    orig = harvest.records()
    assert replayed == orig, "harvest npz shard did not replay exactly"
    assert harvest.appended == st.completed, \
        f"harvest missed completions: {harvest.appended}/{st.completed}"
    health_path = os.path.join(RESULTS, "health_snapshot.json")
    doc = health_snapshot(
        slo=slo, quality=quality, registry=obs.metrics,
        extra={"snapshots": snaps,
               "harvest": {"records": len(harvest), "path": "harvest_drill.npz"},
               "drill": {"shards": n_shards, "victim": victim,
                         "replicas": 1, "kill_at_s": kill_at}})
    write_health(health_path, doc)
    alert = slo.alerts["partial"]
    drill = {
        "shards": n_shards, "victim": victim, "kill_at_s": kill_at,
        "offered_qps": rate, "duration_s": duration,
        "submitted": st.submitted, "completed": st.completed,
        "dropped": st.submitted - st.rejected - st.completed,
        "partial": st.partial,
        "victim_proxy": shard_proxy.get(victim),
        "survivor_proxy_mean": (float(np.mean(survivors))
                                if survivors else None),
        "partial_alert": alert.asdict(),
        "quality_alert": slo.alerts["quality"].asdict(),
        "snapshots": len(snaps),
        "harvest_records": len(harvest),
        "health_path": os.path.relpath(health_path,
                                       os.path.dirname(RESULTS)),
        "harvest_path": os.path.relpath(hpath, os.path.dirname(RESULTS)),
    }
    vic = drill["victim_proxy"] or {}
    print(f"[quality-drill] S={n_shards} R=1 kill shard {victim}: "
          f"{st.completed}/{st.submitted} completed, partial={st.partial}, "
          f"victim proxy mean {vic.get('mean', float('nan')):.3f} vs "
          f"survivors {drill['survivor_proxy_mean'] or float('nan'):.3f}, "
          f"partial alert fires={alert.fires} clears={alert.clears} "
          f"state={alert.state}", flush=True)
    return drill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI run with assertions")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    index, q, true10 = build_corpus(args.smoke)
    shard_counts = [1, 2, 4, 8]
    drill_shards = 4 if args.smoke else 8

    scaling = scaling_sweep(index, q, true10, shard_counts)
    drill = kill_drill(index, q, true10, drill_shards, args.smoke,
                       args.seed)
    qdrill = quality_drill(index, q, drill_shards, args.smoke, args.seed)

    result = {
        "mode": "smoke" if args.smoke else "full",
        "corpus": {"n": int(np.asarray(index.postings).shape[0])
                        * int(index.cluster_len),
                   "clusters": int(np.asarray(index.postings).shape[0]),
                   "n_queries": len(q)},
        "scaling": scaling,
        "kill_drill": drill,
        "quality_drill": qdrill,
    }
    save_result("bench_fabric", result)

    top = scaling[-1]
    emit("fabric_scaling", 1e6 / top["virtual_qps"],
         f"S={top['shards']} virtual={top['virtual_qps']:.0f}q/s "
         f"x{top['speedup_vs_s1']:.2f} bit_equal={top['bit_equal_vs_s1']}")
    emit("fabric_kill_drill", 1e6 / max(drill["completed"]
                                        / drill["duration_s"], 1e-9),
         f"S={drill['shards']} dropped={drill['dropped']} "
         f"recall {drill['recall10_before']:.3f}->"
         f"{drill['recall10_after']:.3f}")
    vic = qdrill["victim_proxy"] or {}
    emit("fabric_quality_drill",
         1e6 * max(1.0 - vic.get("mean", 1.0), 1e-9),
         f"victim proxy {vic.get('mean', float('nan')):.3f} vs survivors "
         f"{qdrill['survivor_proxy_mean'] or float('nan'):.3f}, partial "
         f"alert fires={qdrill['partial_alert']['fires']} "
         f"state={qdrill['partial_alert']['state']}")

    # acceptance gates (ISSUE 6)
    assert all(r["bit_equal_vs_s1"] for r in scaling), \
        "cross-shard merge is not bit-equal to single-shard"
    s8 = scaling[-1]
    assert s8["speedup_vs_s1"] >= 0.5 * s8["shards"], \
        f"virtual scaling fell below 0.5x linear: {s8['speedup_vs_s1']:.2f}"
    assert drill["dropped"] == 0, "kill drill dropped queries"
    assert drill["failed"] == 0 and drill["partial"] == 0, \
        "kill drill degraded queries despite full replication of the victim"
    assert drill["failovers"] and drill["failovers"][0]["shard"] == 1 \
        and drill["failovers"][0]["lost"] == 0, "failover lost clusters"
    assert abs(drill["recall10_before"] - drill["recall10_after"]) <= 0.002, \
        "recall parity broken across failover"
    assert drill["failover_gap"] is None or \
        drill["failover_gap"]["p99_ms"] <= 5000.0, \
        "failover gap unbounded (exceeded the harvest timeout)"
    # quality-drill gates (PR 9): the outage must be detected, alerted,
    # and recorded — not silently absorbed
    assert qdrill["dropped"] == 0, "quality drill dropped queries"
    assert qdrill["partial"] > 0, \
        "R=1 kill produced no partial completions — drill is vacuous"
    assert qdrill["victim_proxy"] is not None \
        and qdrill["survivor_proxy_mean"] is not None, \
        "per-shard proxy streams missing"
    assert qdrill["victim_proxy"]["min"] < 0.999, \
        "victim coverage proxy never dipped despite lost clusters"
    assert qdrill["victim_proxy"]["mean"] < qdrill["survivor_proxy_mean"], \
        "victim shard proxy did not dip below survivors"
    assert qdrill["partial_alert"]["fires"] >= 1, \
        "partial burn-rate alert never fired during the outage"
    assert qdrill["partial_alert"]["state"] == "ok" \
        and qdrill["partial_alert"]["clears"] >= 1, \
        "partial alert did not clear after traffic drained"
    mode = "smoke" if args.smoke else "full"
    print(f"[{mode}] fabric OK: S={s8['shards']} "
          f"x{s8['speedup_vs_s1']:.2f} virtual scaling, zero-drop kill "
          f"drill, recall parity "
          f"{abs(drill['recall10_before'] - drill['recall10_after']):.4f}")


if __name__ == "__main__":
    main()
