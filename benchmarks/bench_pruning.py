"""Fig. 19 / Fig. 20 / Table 3 — pruning-module efficiency.

* Fig 19: throughput speedup of LLSP vs fixed-eps vs no pruning at the same
  recall target (probes saved -> time saved, both measured).
* Fig 20: per-query recall stability — fraction of queries individually
  meeting the target, under matched mean probe budgets.
* Tab 3: feature importance of the router and pruning models via group
  permutation (query coords / top-k / centroid-distance stats).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.llsp import pruner_features, router_features
from repro.core.gbdt import predict_jax, predict_stacked_jax
from repro.core.search import SearchConfig, serve_step

from .common import emit, get_bench_index, save_result, time_fn


def _run_mode(bi, mode, llsp, k=10, nmax=64, eps=0.12):
    cfg = SearchConfig(k=k, nprobe_max=nmax, pruning=mode, eps=eps,
                       n_ratio=16, use_kernel=False)
    qj = jnp.asarray(bi.q)
    tj = jnp.full((bi.q.shape[0],), k, jnp.int32)
    fn = jax.jit(lambda q, t: serve_step(bi.index, llsp, q, t, cfg))
    out = fn(qj, tj)
    secs = time_fn(fn, qj, tj)
    return out, secs


def _per_query_recall(ids, true10):
    ids = np.asarray(ids)
    return np.asarray([
        len(set(ids[i, :10].tolist()) & set(true10[i].tolist())) / 10
        for i in range(ids.shape[0])
    ])


def _perm_importance(predict, X, groups, seed=0):
    rng = np.random.default_rng(seed)
    base = np.asarray(predict(jnp.asarray(X)))
    out = {}
    for name, cols in groups.items():
        Xp = X.copy()
        Xp[:, cols] = Xp[rng.permutation(X.shape[0])][:, cols]
        pred = np.asarray(predict(jnp.asarray(Xp)))
        out[name] = float(np.mean((pred - base) ** 2))
    tot = sum(out.values()) or 1.0
    return {k: v / tot for k, v in out.items()}


def _run_leveled(bi, k=10, nmax=64):
    """LLSP through the leveled engine: per-level compiled shapes, so pruned
    probes save real compute (the TPU-native leveling payoff)."""
    from repro.core.search import serve_leveled
    cfg = SearchConfig(k=k, nprobe_max=nmax, pruning="llsp", n_ratio=16,
                       use_kernel=False)
    q = bi.q
    tj = np.full((q.shape[0],), k, np.int32)
    fn = lambda: serve_leveled(bi.index, bi.llsp, q, tj, cfg)
    out = fn()
    secs = time_fn(lambda _=None: fn(), None)
    return out, secs


def run() -> dict:
    bi = get_bench_index()
    out_none, t_none = _run_mode(bi, "none", None)
    out_fixed, t_fixed = _run_mode(bi, "fixed", None)
    out_llsp, t_llsp = _run_leveled(bi)

    r = {m: recall_at_k(np.asarray(o["ids"])[:, :10], bi.true10)
         for m, o in (("none", out_none), ("fixed", out_fixed),
                      ("llsp", out_llsp))}
    probes = {m: float(np.asarray(o["nprobe"]).mean())
              for m, o in (("none", out_none), ("fixed", out_fixed),
                           ("llsp", out_llsp))}
    qps = {"none": 1 / t_none, "fixed": 1 / t_fixed, "llsp": 1 / t_llsp}

    pq = {m: _per_query_recall(o["ids"], bi.true10)
          for m, o in (("fixed", out_fixed), ("llsp", out_llsp))}
    stability = {m: float((v >= 0.9).mean()) for m, v in pq.items()}

    # Table 3: permutation importance
    D = bi.q.shape[1]
    rf = np.asarray(router_features(jnp.asarray(bi.q),
                                    jnp.asarray(bi.topk)))
    router_imp = _perm_importance(
        lambda X: predict_jax(bi.llsp.router, X), rf,
        {"query": list(range(D)), "k": [D]})
    from repro.core.distance import squared_l2_chunked, topk_smallest
    cd = squared_l2_chunked(jnp.asarray(bi.q), bi.index.centroids)
    cdists, _ = topk_smallest(cd, 64)
    pf = np.asarray(pruner_features(jnp.asarray(bi.q), jnp.asarray(bi.topk),
                                    cdists, 16))
    lvl = jnp.zeros((pf.shape[0],), jnp.int32)
    pruner_imp = _perm_importance(
        lambda X: predict_stacked_jax(bi.llsp.pruners, lvl, X), pf,
        {"query": list(range(D)), "k": [D],
         "centroids": list(range(D + 1, pf.shape[1]))})

    payload = {
        "recall": r, "mean_probes": probes,
        "qps_speedup_vs_none": qps["llsp"] / qps["none"],
        "qps_speedup_vs_fixed": qps["llsp"] / qps["fixed"],
        "probe_savings_vs_none": probes["none"] / probes["llsp"],
        "stability_frac_meeting_0.9": stability,
        "feature_importance": {"router": router_imp, "pruner": pruner_imp},
        "paper_claims": "1.1-1.6x vs none, 5-25% vs fixed (Fig 19); "
                        ">80% vs ~60% queries meeting target (Fig 20)",
    }
    save_result("pruning", payload)
    emit("pruning.llsp", t_llsp * 1e6,
         f"recall={r['llsp']:.3f};probes={probes['llsp']:.1f};"
         f"stab={stability['llsp']:.2f}")
    emit("pruning.fixed", t_fixed * 1e6,
         f"recall={r['fixed']:.3f};probes={probes['fixed']:.1f};"
         f"stab={stability['fixed']:.2f}")
    emit("pruning.none", t_none * 1e6, f"recall={r['none']:.3f}")
    return payload


if __name__ == "__main__":
    run()
