"""The four assigned recsys architectures.

* xDeepFM  [1803.05170] — linear + CIN (compressed interaction network,
  200-200-200) + DNN (400-400) over 39 sparse-feature embeddings (dim 10).
* Wide&Deep [1606.07792] — wide linear over sparse ids + deep MLP
  (1024-512-256) over 40 embeddings (dim 32).
* MIND     [1904.08030] — multi-interest network: behaviour sequence ->
  dynamic-routing capsules (4 interests, 3 iterations), label-aware attention
  at train, interest-vs-candidate max-dot at serve (retrieval model).
* DIN      [1706.06978] — target attention (att MLP 80-40) over a length-100
  behaviour sequence, then MLP 200-80.

Common substrate: row-sharded embedding tables via models/recsys/embedding.
Every model exposes param_shapes/param_specs/init_params/forward(+loss).
Tables default to 2**20 rows per sparse field group (production tables are
1e6-1e9 rows; the row count is a config knob — the dry run uses the full
config, smoke tests shrink it).

``retrieval_cand`` (score 1 query against 1M candidates) is served by
``retrieval_scores`` — a sharded batched dot over a candidate matrix — and,
for the paper integration, by the Helmsman IVF engine over the same item
embedding table (examples/train_retrieval.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .embedding import (
    embedding_bag,
    embedding_bag_sharded,
    embedding_lookup,
    embedding_lookup_sharded,
)


@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str                 # xdeepfm | wide_deep | mind | din
    n_sparse: int             # sparse fields (ids per sample)
    embed_dim: int
    table_rows: int = 1 << 20
    mlp: tuple = ()
    cin_layers: tuple = ()    # xdeepfm
    attn_mlp: tuple = ()      # din
    seq_len: int = 0          # din/mind behaviour length
    n_interests: int = 0      # mind
    capsule_iters: int = 3    # mind
    dtype: Any = jnp.float32


def _mlp_shapes(dims: tuple, dtype) -> dict:
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"w{i}"] = jax.ShapeDtypeStruct((a, b), dtype)
        out[f"b{i}"] = jax.ShapeDtypeStruct((b,), dtype)
    return out


def _mlp_specs(dims: tuple) -> dict:
    out = {}
    for i in range(len(dims) - 1):
        out[f"w{i}"] = P()
        out[f"b{i}"] = P()
    return out


def _mlp_apply(x, mp, n, act=jax.nn.relu, last_act=False):
    for i in range(n):
        x = x @ mp[f"w{i}"] + mp[f"b{i}"]
        if i < n - 1 or last_act:
            x = act(x)
    return x


def param_shapes(cfg: RecSysConfig) -> dict:
    dt = cfg.dtype
    d = cfg.embed_dim
    sd = lambda s: jax.ShapeDtypeStruct(s, dt)
    p: dict = {"table": sd((cfg.table_rows, d))}
    if cfg.kind == "xdeepfm":
        f = cfg.n_sparse
        p["linear"] = sd((cfg.table_rows, 1))
        cin = {}
        prev = f
        for i, hk in enumerate(cfg.cin_layers):
            cin[f"w{i}"] = sd((prev * f, hk))
            prev = hk
        p["cin"] = cin
        p["cin_out"] = sd((sum(cfg.cin_layers), 1))
        dnn_dims = (f * d,) + tuple(cfg.mlp) + (1,)
        p["dnn"] = _mlp_shapes(dnn_dims, dt)
    elif cfg.kind == "wide_deep":
        p["wide"] = sd((cfg.table_rows, 1))
        deep_dims = (cfg.n_sparse * d,) + tuple(cfg.mlp) + (1,)
        p["deep"] = _mlp_shapes(deep_dims, dt)
    elif cfg.kind == "din":
        att_dims = (4 * d,) + tuple(cfg.attn_mlp) + (1,)
        p["attn"] = _mlp_shapes(att_dims, dt)
        mlp_dims = ((cfg.n_sparse + 2) * d,) + tuple(cfg.mlp) + (1,)
        p["mlp"] = _mlp_shapes(mlp_dims, dt)
    elif cfg.kind == "mind":
        p["bilinear"] = sd((d, d))              # capsule routing bilinear map
        p["label_proj"] = sd((d, d))
    else:
        raise ValueError(cfg.kind)
    return p


def param_specs(cfg: RecSysConfig) -> dict:
    p: dict = {"table": P("model", None)}
    if cfg.kind == "xdeepfm":
        p["linear"] = P("model", None)
        p["cin"] = {f"w{i}": P() for i in range(len(cfg.cin_layers))}
        p["cin_out"] = P()
        p["dnn"] = _mlp_specs((cfg.n_sparse * cfg.embed_dim,) + tuple(cfg.mlp) + (1,))
    elif cfg.kind == "wide_deep":
        p["wide"] = P("model", None)
        p["deep"] = _mlp_specs((cfg.n_sparse * cfg.embed_dim,) + tuple(cfg.mlp) + (1,))
    elif cfg.kind == "din":
        p["attn"] = _mlp_specs((4 * cfg.embed_dim,) + tuple(cfg.attn_mlp) + (1,))
        p["mlp"] = _mlp_specs(((cfg.n_sparse + 2) * cfg.embed_dim,) + tuple(cfg.mlp) + (1,))
    elif cfg.kind == "mind":
        p["bilinear"] = P()
        p["label_proj"] = P()
    return p


def init_params(cfg: RecSysConfig, key: jax.Array) -> dict:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten(shapes)
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, s in zip(keys, flat):
        scale = 0.05 if len(s.shape) < 2 else 1.0 / np.sqrt(s.shape[-2] if len(s.shape) >= 2 else 1)
        leaves.append(jax.random.normal(k, s.shape, s.dtype) * scale)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# forwards (mesh=None -> single device; mesh -> sharded tables)
# ---------------------------------------------------------------------------
def _lookup(table, ids, mesh, batch_axes):
    if mesh is None:
        return embedding_lookup(table, ids)
    return embedding_lookup_sharded(table, ids, mesh, batch_axes)


def _bag(table, ids, mesh, batch_axes, weights=None):
    if mesh is None:
        return embedding_bag(table, ids, weights)
    return embedding_bag_sharded(table, ids, mesh, weights, batch_axes)


def _cin(x0: jax.Array, params: dict, cfg: RecSysConfig) -> jax.Array:
    """Compressed Interaction Network.  x0: (B, F, D)."""
    b, f, d = x0.shape
    xk = x0
    outs = []
    for i, hk in enumerate(cfg.cin_layers):
        # outer interaction: (B, Hk-1, F, D)
        z = jnp.einsum("bhd,bfd->bhfd", xk, x0)
        z = z.reshape(b, xk.shape[1] * f, d)
        xk = jnp.einsum("bzd,zh->bhd", z, params["cin"][f"w{i}"])  # (B, Hk, D)
        xk = jax.nn.relu(xk)
        outs.append(xk.sum(axis=2))                                # (B, Hk)
    return jnp.concatenate(outs, axis=1)                           # (B, sum Hk)


def forward(
    params: dict,
    batch: dict,
    cfg: RecSysConfig,
    mesh=None,
    batch_axes: tuple = ("data",),
) -> jax.Array:
    """Returns logits (B,)."""
    ids = batch["sparse_ids"]                       # (B, F)
    b = ids.shape[0]
    if cfg.kind == "xdeepfm":
        emb = _lookup(params["table"], ids, mesh, batch_axes)      # (B, F, D)
        lin = _bag(params["linear"], ids, mesh, batch_axes)[:, 0]  # (B,)
        cin_feats = _cin(emb, params, cfg)
        cin_term = (cin_feats @ params["cin_out"])[:, 0]
        dnn_in = emb.reshape(b, -1)
        n_mlp = len(cfg.mlp) + 1
        dnn_term = _mlp_apply(dnn_in, params["dnn"], n_mlp)[:, 0]
        return lin + cin_term + dnn_term
    if cfg.kind == "wide_deep":
        wide = _bag(params["wide"], ids, mesh, batch_axes)[:, 0]
        emb = _lookup(params["table"], ids, mesh, batch_axes)
        deep = _mlp_apply(emb.reshape(b, -1), params["deep"], len(cfg.mlp) + 1)[:, 0]
        return wide + deep
    if cfg.kind == "din":
        emb = _lookup(params["table"], ids, mesh, batch_axes)       # (B, F, D)
        target = emb[:, 0]                                          # target item
        hist = _lookup(params["table"], batch["hist_ids"], mesh, batch_axes)  # (B, S, D)
        hmask = jnp.arange(cfg.seq_len)[None, :] < batch["hist_len"][:, None]
        t = jnp.broadcast_to(target[:, None, :], hist.shape)
        att_in = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
        score = _mlp_apply(att_in, params["attn"], len(cfg.attn_mlp) + 1,
                           act=jax.nn.sigmoid)[..., 0]              # (B, S)
        score = jnp.where(hmask, score, 0.0)
        interest = jnp.einsum("bs,bsd->bd", score, hist)
        x = jnp.concatenate([emb.reshape(b, -1), interest, interest * target], axis=-1)
        return _mlp_apply(x, params["mlp"], len(cfg.mlp) + 1)[:, 0]
    if cfg.kind == "mind":
        hist = _lookup(params["table"], batch["hist_ids"], mesh, batch_axes)
        hmask = jnp.arange(cfg.seq_len)[None, :] < batch["hist_len"][:, None]
        interests = capsule_routing(hist, hmask, params["bilinear"], cfg)  # (B, I, D)
        target = _lookup(params["table"], batch["sparse_ids"][:, :1], mesh, batch_axes)[:, 0]
        lbl = target @ params["label_proj"]
        att = jax.nn.softmax(
            jnp.einsum("bid,bd->bi", interests, lbl) * jnp.sqrt(1.0 * cfg.embed_dim),
            axis=-1,
        )
        user = jnp.einsum("bi,bid->bd", att, interests)
        return jnp.einsum("bd,bd->b", user, target)
    raise ValueError(cfg.kind)


def capsule_routing(
    hist: jax.Array,       # (B, S, D)
    mask: jax.Array,       # (B, S)
    bilinear: jax.Array,   # (D, D)
    cfg: RecSysConfig,
) -> jax.Array:
    """B2I dynamic routing (MIND §4.2): behaviour capsules -> interest capsules."""
    b, s, d = hist.shape
    i_n = cfg.n_interests
    u = hist @ bilinear                                    # (B, S, D)
    logits = jnp.zeros((b, i_n, s), jnp.float32)

    def squash(v):
        n2 = jnp.sum(v * v, axis=-1, keepdims=True)
        return (n2 / (1 + n2)) * v * jax.lax.rsqrt(n2 + 1e-9)

    def body(logits, _):
        w = jax.nn.softmax(logits, axis=1)                 # over interests
        w = jnp.where(mask[:, None, :], w, 0.0)
        z = jnp.einsum("bis,bsd->bid", w, u)
        v = squash(z)
        delta = jnp.einsum("bid,bsd->bis", v, u)
        return logits + delta, v

    logits, vs = jax.lax.scan(body, logits, None, length=cfg.capsule_iters)
    return vs[-1]                                          # (B, I, D)


def bce_loss(params, batch, cfg, mesh=None, batch_axes=("data",)) -> jax.Array:
    logits = forward(params, batch, cfg, mesh, batch_axes)
    y = batch["labels"]
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    return -jnp.mean(y * logp + (1 - y) * lognp)


def make_train_step(cfg: RecSysConfig, opt_cfg=None, mesh=None, batch_axes=("data",)):
    from repro.optim import adamw

    opt_cfg = opt_cfg or adamw.AdamWConfig(weight_decay=0.0)

    def train_step(params, opt_state, batch):
        l, grads = jax.value_and_grad(
            lambda p: bce_loss(p, batch, cfg, mesh, batch_axes)
        )(params)
        params, opt_state, metrics = adamw.apply(params, grads, opt_state, opt_cfg)
        metrics["loss"] = l
        return params, opt_state, metrics

    return train_step


def retrieval_scores(
    user: jax.Array,         # (B, D) or (B, I, D) multi-interest
    candidates: jax.Array,   # (N, D) — sharded over `model` at scale
    k: int = 100,
) -> tuple[jax.Array, jax.Array]:
    """Score every candidate; return top-k (scores, ids).

    Multi-interest users take the max over interests per candidate (MIND
    serving).  At the 1M-candidate `retrieval_cand` shape this is one batched
    matmul — never a loop; candidates sharded over `model` let GSPMD
    merge only per-shard top-k, and the Helmsman IVF path
    (examples) replaces the exhaustive scan entirely.
    """
    if user.ndim == 2:
        scores = user @ candidates.T                    # (B, N)
    else:
        scores = jnp.einsum("bid,nd->bin", user, candidates).max(axis=1)
    vals, ids = jax.lax.top_k(scores, k)
    return vals, ids
