"""EmbeddingBag over model-sharded tables — the recsys hot path.

JAX has no native EmbeddingBag and no CSR/CSC sparse (BCOO only), so lookup +
pooling is built from ``jnp.take`` + ``jax.ops.segment_sum`` — this IS part of
the system, per the assignment.

Layout: ids come as a fixed-shape matrix (B, S) (S = multi-hot slots per bag;
id < 0 = empty slot).  The bag is the row.  Internally the lookup flattens to
(B*S,) and pools with segment_sum over the row index — the canonical
take+segment_sum EmbeddingBag.

Implementations:

* ``embedding_bag``          — single-device.
* ``embedding_bag_sharded``  — production path: the table is ROW-sharded over
  the ``model`` axis.  A naive jnp.take would make GSPMD all-gather the whole
  table (GBs).  Instead a shard_map masks ids to the local row range, does a
  LOCAL take (out-of-range ids contribute zero), pools locally, and psums the
  pooled (B, D) output over ``model`` — communication is the tiny pooled
  output, not the table.  Same "move compute to the data" insight as
  Helmsman's posting-shard scan + top-k merge, applied to embedding tables.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def embedding_bag(
    table: jax.Array,      # (R, D)
    ids: jax.Array,        # (B, S) int32; id < 0 = empty slot
    weights: Optional[jax.Array] = None,   # (B, S)
) -> jax.Array:
    """Pooled (B, D) embeddings: take + segment_sum over row bags."""
    b, s = ids.shape
    flat = ids.reshape(-1)
    vecs = jnp.take(table, jnp.clip(flat, 0, table.shape[0] - 1), axis=0)
    if weights is not None:
        vecs = vecs * weights.reshape(-1, 1)
    vecs = jnp.where((flat >= 0)[:, None], vecs, 0.0)
    bags = jnp.repeat(jnp.arange(b), s)
    return jax.ops.segment_sum(vecs, bags, num_segments=b)


def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Unpooled (B, S, D) lookup (DIN/MIND need per-position vectors)."""
    vecs = jnp.take(table, jnp.clip(ids, 0, table.shape[0] - 1), axis=0)
    return jnp.where((ids >= 0)[..., None], vecs, 0.0)


def embedding_bag_sharded(
    table: jax.Array,      # (R, D), rows sharded P('model', None)
    ids: jax.Array,        # (B, S), batch-sharded
    mesh,
    weights: Optional[jax.Array] = None,
    batch_axes: tuple = ("data",),
) -> jax.Array:
    """Row-sharded EmbeddingBag: local take+segment_sum, psum over `model`."""
    rows = table.shape[0]
    tp = mesh.shape["model"]
    r_loc = rows // tp
    assert rows % tp == 0, (rows, tp)
    ba = (batch_axes if len(batch_axes) > 1
          else (batch_axes[0] if batch_axes else None))

    def local(table_l, ids_l, w_l):
        shard = jax.lax.axis_index("model")
        lo = (shard * r_loc).astype(ids_l.dtype)
        b, s = ids_l.shape
        flat = ids_l.reshape(-1)
        rel = flat - lo
        mine = (rel >= 0) & (rel < r_loc) & (flat >= 0)
        vecs = jnp.take(table_l, jnp.clip(rel, 0, r_loc - 1), axis=0)
        if w_l is not None:
            vecs = vecs * w_l.reshape(-1, 1)
        vecs = jnp.where(mine[:, None], vecs, 0.0)
        bags = jnp.repeat(jnp.arange(b), s)
        pooled = jax.ops.segment_sum(vecs, bags, num_segments=b)
        return jax.lax.psum(pooled, "model")

    if weights is None:
        fn = lambda t, i: local(t, i, None)
        return jax.shard_map(
            fn, mesh=mesh,
            in_specs=(P("model", None), P(ba, None)),
            out_specs=P(ba, None),
            check_vma=False,
        )(table, ids)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P("model", None), P(ba, None), P(ba, None)),
        out_specs=P(ba, None),
        check_vma=False,
    )(table, ids, weights)


def embedding_lookup_sharded(
    table: jax.Array,
    ids: jax.Array,        # (B, S)
    mesh,
    batch_axes: tuple = ("data",),
) -> jax.Array:
    """Unpooled sharded lookup: (B, S, D).  psum combines one-hot row hits."""
    rows = table.shape[0]
    tp = mesh.shape["model"]
    r_loc = rows // tp
    assert rows % tp == 0, (rows, tp)
    ba = (batch_axes if len(batch_axes) > 1
          else (batch_axes[0] if batch_axes else None))

    def local(table_l, ids_l):
        shard = jax.lax.axis_index("model")
        lo = (shard * r_loc).astype(ids_l.dtype)
        rel = ids_l - lo
        mine = (rel >= 0) & (rel < r_loc) & (ids_l >= 0)
        vecs = jnp.take(table_l, jnp.clip(rel, 0, r_loc - 1), axis=0)
        vecs = jnp.where(mine[..., None], vecs, 0.0)
        return jax.lax.psum(vecs, "model")

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P("model", None), P(ba, None)),
        out_specs=P(ba, None, None),
        check_vma=False,
    )(table, ids)
