from .embedding import embedding_bag, embedding_bag_sharded, embedding_lookup, embedding_lookup_sharded
from .models import RecSysConfig, bce_loss, forward, init_params, make_train_step, param_shapes, param_specs, retrieval_scores
