"""Decoder-only transformer family covering the five assigned LM archs.

Features (per the assigned configs):
* GQA attention (separate n_kv), RoPE, RMSNorm, SwiGLU MLP.
* gemma3-style hybrid attention: blocks of ``period`` layers where the last
  layer is global and the rest use a sliding window (5:1 local:global).
* MoE layers (llama4-scout top-1 x16; qwen2-moe 4 shared + 60 routed top-4)
  via shard_map expert parallelism (models/lm/moe.py).
* scan-over-blocks for compile time; jax.checkpoint (remat) per block.
* chunked attention + chunked loss so 32k-token prefill never materializes
  an (S, S) score matrix or a full (B, S, V) logit tensor.
* decode path with stacked KV caches: global layers cache the full context,
  local layers cache only their window (ring buffer) — this is what makes
  ``long_500k`` sub-quadratic-memory for the hybrid archs.

Everything is shape-polymorphic over batch/sequence and built for pjit:
``param_specs``/``input_specs`` give the PartitionSpecs used by launch/.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .moe import MoEConfig, moe_ffn, moe_param_shapes, moe_param_specs


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    window: int = 0                 # sliding window size for local layers
    period: int = 1                 # layers per block; last layer of a block
                                    # is global, the rest local (gemma3: 6)
    moe: Optional[MoEConfig] = None
    dtype: Any = jnp.bfloat16
    q_chunk: int = 1024             # query-chunk for attention & loss
    fsdp: bool = False              # ZeRO-3 weight sharding over `data`
    tail_local: int = 0             # extra local-only layers after the blocks
                                    # (gemma3-27b: 62 = 10x6 + 2 local)
    remat: bool = True
    pad_heads_to: int = 0           # perf: pad H up so heads shard over TP=16
                                    # (avoids Dh-sharding's O(S^2) score psum)
    pure_dp: bool = False           # perf: no TP — ZeRO-3 over data x model
                                    # (O(params) gathers replace O(activation)
                                    # all-reduces; right call for <=13B @ 4k)
    seq_parallel: bool = False      # perf: Megatron-SP — keep activations
                                    # sequence-sharded over `model` between
                                    # blocks (AR -> RS+AG, halves TP traffic)

    @property
    def heads_padded(self) -> int:
        return max(self.pad_heads_to, self.n_heads)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def n_blocks(self) -> int:
        main = self.n_layers - self.tail_local
        assert main % self.period == 0, (self.n_layers, self.period)
        return main // self.period

    @property
    def n_params(self) -> int:
        """Total parameter count (for 6ND roofline accounting)."""
        d, h, kv, dh, f = (
            self.d_model, self.n_heads, self.n_kv, self.head_dim, self.d_ff,
        )
        attn = d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
        if self.moe is None:
            ffn = 3 * d * f
        else:
            m = self.moe
            ffn = m.n_experts * 3 * d * m.d_ff_expert + m.n_shared * 3 * d * m.d_ff_shared
            ffn += d * m.n_experts  # router
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + self.vocab * d + d

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k experts count)."""
        if self.moe is None:
            return self.n_params
        d, h, kv, dh, f = (
            self.d_model, self.n_heads, self.n_kv, self.head_dim, self.d_ff,
        )
        m = self.moe
        attn = d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
        ffn = m.top_k * 3 * d * m.d_ff_expert + m.n_shared * 3 * d * m.d_ff_shared
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + self.vocab * d + d


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def _group_shapes(cfg: LMConfig, lead: tuple) -> dict:
    d, h, kv, dh, f = (
        cfg.d_model, cfg.heads_padded, cfg.n_kv, cfg.head_dim, cfg.d_ff,
    )
    sd = lambda shape: jax.ShapeDtypeStruct(lead + shape, cfg.dtype)
    layers = {
        "wq": sd((d, h, dh)),
        "wk": sd((d, kv, dh)),
        "wv": sd((d, kv, dh)),
        "wo": sd((h, dh, d)),
        "rms1": sd((d,)),
        "rms2": sd((d,)),
    }
    if cfg.moe is None:
        layers.update({
            "w_gate": sd((d, f)),
            "w_up": sd((d, f)),
            "w_down": sd((f, d)),
        })
    else:
        layers.update(moe_param_shapes(cfg.moe, d, lead, cfg.dtype))
    return layers


def param_shapes(cfg: LMConfig) -> dict:
    """Abstract parameter pytree (ShapeDtypeStruct leaves)."""
    sd = lambda shape: jax.ShapeDtypeStruct(shape, cfg.dtype)
    out = {
        "embed": sd((cfg.vocab, cfg.d_model)),
        "final_norm": sd((cfg.d_model,)),
        "layers": _group_shapes(cfg, (cfg.n_blocks, cfg.period)),
    }
    if cfg.tail_local:
        out["tail"] = _group_shapes(cfg, (cfg.tail_local,))
    return out


def param_specs(cfg: LMConfig, tp: int = 16, fsdp: Optional[bool] = None) -> dict:
    """PartitionSpecs matching param_shapes (Megatron TP over `model`).

    Head sharding is adaptive to the arch's divisibility on the fixed
    production mesh (model=16):
      * H % tp == 0  -> shard the head dim of wq/wo (and wk/wv if KV % tp == 0,
        else replicate KV — standard GQA TP with KV < tp);
      * else if Dh % tp == 0 -> shard head_dim on all four projections
        (phi4: H=24, llama4: H=40; psum after the contractions);
      * else replicate attention weights.

    fsdp=True additionally shards the big FFN/expert weights over `data`
    (ZeRO-3 style) — used by the largest archs so params+moments fit HBM.
    """
    if fsdp is None:
        fsdp = cfg.fsdp
    if cfg.pure_dp:
        return _pure_dp_specs(cfg, tp)
    dp = "data" if fsdp else None
    h, kv, dh = cfg.heads_padded, cfg.n_kv, cfg.head_dim

    def group(n_lead: int) -> dict:
        lead = (None,) * n_lead
        if h % tp == 0:
            wq = P(*lead, None, "model", None)
            wo = P(*lead, "model", None, None)
            if kv % tp == 0:
                wk = wv = P(*lead, None, "model", None)
            else:
                wk = wv = P(*lead, None, None, None)
        elif dh % tp == 0:
            wq = wk = wv = P(*lead, None, None, "model")
            wo = P(*lead, None, "model", None)
        else:
            wq = wk = wv = P(*lead, None, None, None)
            wo = P(*lead, None, None, None)
        layers = {
            "wq": wq, "wk": wk, "wv": wv, "wo": wo,
            "rms1": P(), "rms2": P(),
        }
        if cfg.moe is None:
            layers.update({
                "w_gate": P(*lead, dp, "model"),
                "w_up": P(*lead, dp, "model"),
                "w_down": P(*lead, "model", dp),
            })
        else:
            layers.update(moe_param_specs(cfg.moe, fsdp, n_lead))
        return layers

    out = {
        "embed": P("model", None),
        "final_norm": P(),
        "layers": group(2),
    }
    if cfg.tail_local:
        out["tail"] = group(1)
    return out


def _pure_dp_specs(cfg: LMConfig, tp: int, dsize: int = 16) -> dict:
    """ZeRO-3 layout: every weight sharded on its first divisible dim over
    the combined (data, model) axes; activations are pure data-parallel
    (batch over both axes), so there are NO TP collectives — per-step
    traffic is O(params) weight gathers + gradient reduce-scatters."""
    shapes = param_shapes(cfg)
    both = dsize * tp

    def spec_of(sds) -> P:
        shp = sds.shape
        for i, d in enumerate(shp):
            if d % both == 0:
                return P(*([None] * i), ("data", "model"),
                         *([None] * (len(shp) - i - 1)))
        for i, d in enumerate(shp):
            if d % tp == 0:
                return P(*([None] * i), "model",
                         *([None] * (len(shp) - i - 1)))
        return P()

    return jax.tree.map(spec_of, shapes)


def init_params(cfg: LMConfig, key: jax.Array) -> dict:
    """Real initialization (small configs / smoke tests)."""
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten(shapes)
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, s in zip(keys, flat):
        if s.shape and s.shape[-1] > 1 and len(s.shape) >= 2:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            leaves.append(
                (jax.random.normal(k, s.shape, jnp.float32) / np.sqrt(max(fan_in, 1))).astype(s.dtype)
            )
        else:
            leaves.append(jnp.ones(s.shape, s.dtype))
    p = jax.tree_util.tree_unflatten(treedef, leaves)
    p["final_norm"] = jnp.ones_like(p["final_norm"])
    p["layers"]["rms1"] = jnp.ones_like(p["layers"]["rms1"])
    p["layers"]["rms2"] = jnp.ones_like(p["layers"]["rms2"])
    if cfg.tail_local:
        p["tail"]["rms1"] = jnp.ones_like(p["tail"]["rms1"])
        p["tail"]["rms2"] = jnp.ones_like(p["tail"]["rms2"])
    return p


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (n * w.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, Dh), pos: (..., T) int32 absolute positions."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs            # (..., T, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _attend(
    q: jax.Array,        # (B, Tq, H, Dh) rotated
    k: jax.Array,        # (B, Tk, KV, Dh) rotated
    v: jax.Array,        # (B, Tk, KV, Dh)
    qpos: jax.Array,     # (Tq,)
    kpos: jax.Array,     # (Tk,) (or (B, Tk) for ring buffers)
    kvalid: jax.Array,   # (Tk,) or (B, Tk) bool
    window: int,         # 0 = global
) -> jax.Array:
    b, tq, h, dh = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, tq, kvh, rep, dh)
    scores = jnp.einsum(
        "bqkrd,bskd->bkrqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / np.sqrt(dh)
    if kpos.ndim == 1:
        kp = kpos[None, :]
        kv_ok = kvalid[None, :]
    else:
        kp, kv_ok = kpos, kvalid
    causal = qpos[None, :, None] >= kp[:, None, :]               # (B, Tq, Tk)
    mask = causal & kv_ok[:, None, :]
    if window > 0:
        mask &= (qpos[None, :, None] - kp[:, None, :]) < window
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v.astype(jnp.float32))
    return out.reshape(b, tq, h, dh).astype(q.dtype)


def attention_full(
    x: jax.Array, lp: dict, pos0: int, window: int, cfg: LMConfig,
    *, return_kv: bool = False,
):
    """Training/prefill attention, scanned over query chunks."""
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, lp["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["wv"])
    pos = pos0 + jnp.arange(s)
    q = rope(q, jnp.broadcast_to(pos, (b, s)), cfg.rope_theta)
    k = rope(k, jnp.broadcast_to(pos, (b, s)), cfg.rope_theta)
    qc = min(cfg.q_chunk, s)
    if s % qc:
        qc = s  # fall back to unchunked for ragged small shapes
    n_chunks = s // qc
    kvalid = jnp.ones((s,), bool)

    def chunk(i):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * qc, qc, axis=1)
        return _attend(sl(q), k, v, pos0 + i * qc + jnp.arange(qc), pos, kvalid, window)

    if n_chunks == 1:
        o = chunk(0)
    else:
        o = jax.lax.map(chunk, jnp.arange(n_chunks))             # (n, B, qc, H, Dh)
        o = jnp.moveaxis(o, 0, 1).reshape(b, s, cfg.heads_padded, cfg.head_dim)
    out = jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
    if return_kv:
        return out, k, v
    return out


def swiglu(x: jax.Array, lp: dict) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, lp["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, lp["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, lp["w_down"])


def _sp_constraint(x: jax.Array, cfg: LMConfig, mesh) -> jax.Array:
    """Megatron-SP: keep activations sequence-sharded over `model` at the
    residual boundaries so GSPMD lowers the TP all-reduce into
    reduce-scatter (+ all-gather at the next consumer) — half the traffic,
    and norms compute on 1/TP of the sequence."""
    if not cfg.seq_parallel or mesh is None:
        return x
    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return jax.lax.with_sharding_constraint(x, P(ba, "model", None))


def group_forward(
    x: jax.Array, gp: dict, cfg: LMConfig, pos0: int, mesh=None,
    *, n_in_group: int, all_local: bool = False,
) -> jax.Array:
    """Run ``n_in_group`` stacked layers.  Unless ``all_local``, the last
    layer of the group is global and the rest use the sliding window."""
    for li in range(n_in_group):
        lp = jax.tree.map(lambda a: a[li], gp)
        if cfg.pure_dp:
            # ZeRO-3: force ONE weight all-gather per layer (otherwise GSPMD
            # keeps weights sharded and all-reduces 256-way partial products
            # of the activations instead — measured 869 GB/step vs ~70 GB)
            lp = jax.tree.map(
                lambda w: jax.lax.with_sharding_constraint(w, P()), lp)
        is_global = (li == n_in_group - 1) and not all_local
        window = 0 if (is_global or cfg.window == 0) else cfg.window
        h = rms_norm(x, lp["rms1"])
        x = _sp_constraint(x + attention_full(h, lp, pos0, window, cfg), cfg, mesh)
        h = rms_norm(x, lp["rms2"])
        if cfg.moe is None:
            x = _sp_constraint(x + swiglu(h, lp), cfg, mesh)
        else:
            x = x + moe_ffn(h, lp, cfg.moe, mesh, cfg.fsdp)
    return x


def block_forward(
    x: jax.Array, bp: dict, cfg: LMConfig, pos0: int, mesh=None
) -> jax.Array:
    """One block = ``period`` layers; layers [0..period-2] local, last global."""
    return group_forward(x, bp, cfg, pos0, mesh, n_in_group=cfg.period)


def forward(
    params: dict, tokens: jax.Array, cfg: LMConfig, mesh=None
) -> jax.Array:
    """Token ids (B, S) -> final hidden states (B, S, D)."""
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = x * float(np.sqrt(cfg.d_model))

    def body(x, bp):
        if cfg.remat:
            fn = jax.checkpoint(
                functools.partial(block_forward, cfg=cfg, pos0=0, mesh=mesh)
            )
        else:
            fn = functools.partial(block_forward, cfg=cfg, pos0=0, mesh=mesh)
        x = fn(x, bp)
        if cfg.seq_parallel and mesh is not None:
            ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
            x = jax.lax.with_sharding_constraint(x, P(ba, "model", None))
        return x, None

    x, _ = jax.lax.scan(body, x, params["layers"])
    if cfg.tail_local:
        tail_fn = functools.partial(
            group_forward, cfg=cfg, pos0=0, mesh=mesh,
            n_in_group=cfg.tail_local, all_local=True,
        )
        if cfg.remat:
            tail_fn = jax.checkpoint(tail_fn)
        x = tail_fn(x, params["tail"])
    return rms_norm(x, params["final_norm"])


def chunked_ce_loss(
    h: jax.Array, embed: jax.Array, targets: jax.Array, cfg: LMConfig
) -> jax.Array:
    """Cross-entropy without materializing (B, S, V): scan over S-chunks."""
    b, s, d = h.shape
    qc = min(cfg.q_chunk, s)
    if s % qc:
        qc = s
    n = s // qc
    w = embed.astype(cfg.dtype)

    def chunk_loss(i):
        hc = jax.lax.dynamic_slice_in_dim(h, i * qc, qc, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, i * qc, qc, axis=1)
        logits = jnp.einsum("bsd,vd->bsv", hc, w).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via mask+sum: gather-free (take_along_axis grads are
        # broken in this jax build; flat gather overflows int32 at 262k
        # vocab), local under vocab sharding, and fused by XLA
        vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(
            jnp.where(vocab_ids == tc[..., None], logits, 0.0), axis=-1
        )
        return jnp.sum(logz - gold)

    if n == 1:
        tot = chunk_loss(0)
    else:
        tot = jnp.sum(jax.lax.map(chunk_loss, jnp.arange(n)))
    return tot / (b * s)


# ---------------------------------------------------------------------------
# train / prefill / decode steps
# ---------------------------------------------------------------------------
def loss_fn(params: dict, tokens: jax.Array, cfg: LMConfig, mesh=None) -> jax.Array:
    h = forward(params, tokens[:, :-1], cfg, mesh)
    return chunked_ce_loss(h, params["embed"], tokens[:, 1:], cfg)


def make_train_step(cfg: LMConfig, opt_cfg=None, mesh=None):
    from repro.optim import adamw

    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def train_step(params, opt_state, tokens):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, tokens, cfg, mesh)
        )(params)
        params, opt_state, metrics = adamw.apply(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def cache_shapes(cfg: LMConfig, batch: int, seq: int) -> dict:
    """Abstract KV cache: global layers cache ``seq``; local layers cache
    min(window, seq) (ring buffer); tail-local layers get their own rings."""
    nb, pe, kv, dh = cfg.n_blocks, cfg.period, cfg.n_kv, cfg.head_dim
    w = min(cfg.window, seq) if cfg.window else seq
    sd = lambda shape: jax.ShapeDtypeStruct(shape, cfg.dtype)
    cache = {
        "k_g": sd((nb, batch, seq, kv, dh)),
        "v_g": sd((nb, batch, seq, kv, dh)),
    }
    if pe > 1:
        cache.update({
            "k_l": sd((nb, pe - 1, batch, w, kv, dh)),
            "v_l": sd((nb, pe - 1, batch, w, kv, dh)),
        })
    if cfg.tail_local:
        cache.update({
            "k_t": sd((cfg.tail_local, batch, w, kv, dh)),
            "v_t": sd((cfg.tail_local, batch, w, kv, dh)),
        })
    return cache


def cache_specs(cfg: LMConfig, mesh, *, seq_shard: bool = True) -> dict:
    """Global caches shard the sequence dim over `model` (split-KV decode);
    local ring buffers shard batch only (their window is small)."""
    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    g = P(None, ba, "model", None, None) if seq_shard else P(None, ba, None, None, None)
    out = {"k_g": g, "v_g": g}
    if cfg.period > 1:
        l = P(None, None, ba, None, None, None)
        out.update({"k_l": l, "v_l": l})
    if cfg.tail_local:
        t = P(None, ba, None, None, None)
        out.update({"k_t": t, "v_t": t})
    return out


def init_cache(cfg: LMConfig, batch: int, seq: int) -> dict:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shapes(cfg, batch, seq)
    )


def decode_step(
    params: dict,
    cache: dict,
    token: jax.Array,     # (B,) int32 current token
    pos: jax.Array,       # () int32 its position
    cfg: LMConfig,
    mesh=None,
) -> tuple[jax.Array, dict]:
    """One decode step: returns (logits (B, V), updated cache)."""
    b = token.shape[0]
    x = params["embed"].astype(cfg.dtype)[token][:, None, :] * float(np.sqrt(cfg.d_model))
    if cfg.period > 1:
        w = cache["k_l"].shape[3]
    elif cfg.tail_local:
        w = cache["k_t"].shape[2]
    else:
        w = 0

    def layer(x, lp, kc, vc, *, is_global):
        """One decode layer against its cache (full context or ring)."""
        h = rms_norm(x, lp["rms1"])
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        ppos = jnp.broadcast_to(pos, (b, 1))
        q = rope(q, ppos, cfg.rope_theta)
        k = rope(k, ppos, cfg.rope_theta)
        if is_global or cfg.window == 0:
            kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
            s = kc.shape[1]
            kpos = jnp.arange(s)
            kvalid = kpos <= pos
            o = _attend(q, kc, vc, pos[None], kpos, kvalid, 0)
        else:
            slot = pos % w
            kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
            ring = jnp.arange(w)
            # absolute position stored in each ring slot
            kpos = pos - ((slot - ring) % w)
            kvalid = kpos >= 0
            o = _attend(q, kc, vc, pos[None], kpos, kvalid, cfg.window)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["wo"])
        h = rms_norm(x, lp["rms2"])
        if cfg.moe is None:
            x = x + swiglu(h, lp)
        else:
            x = x + moe_ffn(h, lp, cfg.moe, mesh, cfg.fsdp)
        return x, kc, vc

    def block(carry, inputs):
        x = carry
        bp, kg, vg, kl, vl = inputs
        new_kl, new_vl = [], []
        for li in range(cfg.period):
            lp = jax.tree.map(lambda a: a[li], bp)
            is_global = li == cfg.period - 1
            if is_global or cfg.window == 0:
                x, kg, vg = layer(x, lp, kg, vg, is_global=True)
            else:
                x, kc, vc = layer(x, lp, kl[li], vl[li], is_global=False)
                new_kl.append(kc)
                new_vl.append(vc)
        if cfg.period > 1:
            kl = jnp.stack(new_kl)
            vl = jnp.stack(new_vl)
        return x, (kg, vg, kl, vl)

    if cfg.period > 1:
        xs = (params["layers"], cache["k_g"], cache["v_g"], cache["k_l"], cache["v_l"])
    else:
        dummy = jnp.zeros((cfg.n_blocks, 0), cfg.dtype)
        xs = (params["layers"], cache["k_g"], cache["v_g"], dummy, dummy)
    x, (kg, vg, kl, vl) = jax.lax.scan(block, x, xs)

    new_cache = {"k_g": kg, "v_g": vg}
    if cfg.period > 1:
        new_cache.update({"k_l": kl, "v_l": vl})
    if cfg.tail_local:  # trailing local-only layers (gemma3-27b: 62 = 60 + 2)
        kts, vts = [], []
        for li in range(cfg.tail_local):
            lp = jax.tree.map(lambda a: a[li], params["tail"])
            x, kc, vc = layer(x, lp, cache["k_t"][li], cache["v_t"][li], is_global=False)
            kts.append(kc)
            vts.append(vc)
        new_cache.update({"k_t": jnp.stack(kts), "v_t": jnp.stack(vts)})

    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.dtype))[:, 0]
    return logits.astype(jnp.float32), new_cache


def prefill_step(
    params: dict, tokens: jax.Array, cfg: LMConfig, mesh=None
) -> tuple[jax.Array, dict]:
    """Prefill: full forward that also materializes the KV caches.

    Returns (last-token logits (B, V), cache).  Cache extraction re-runs the
    projections per block (cheap relative to attention).
    """
    b, s = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens] * float(np.sqrt(cfg.d_model))
    w = min(cfg.window, s) if cfg.window else s

    def body(x, bp):
        kg = vg = None
        kls, vls = [], []
        for li in range(cfg.period):
            lp = jax.tree.map(lambda a: a[li], bp)
            is_global = li == cfg.period - 1
            window = 0 if (is_global or cfg.window == 0) else cfg.window
            h = rms_norm(x, lp["rms1"])
            attn, k, v = attention_full(h, lp, 0, window, cfg, return_kv=True)
            x = x + attn
            if is_global or cfg.window == 0:
                kg, vg = k, v
            else:
                # ring-buffer layout: position p lives at slot p % w, so the
                # last-w slice must be rolled to line up with decode_step
                kls.append(jnp.roll(k[:, -w:], s % w, axis=1))
                vls.append(jnp.roll(v[:, -w:], s % w, axis=1))
            h2 = rms_norm(x, lp["rms2"])
            if cfg.moe is None:
                x = x + swiglu(h2, lp)
            else:
                x = x + moe_ffn(h2, lp, cfg.moe, mesh, cfg.fsdp)
        out = (kg, vg)
        if cfg.period > 1:
            out = (kg, vg, jnp.stack(kls), jnp.stack(vls))
        return x, out

    x, caches = jax.lax.scan(body, x, params["layers"])
    cache = {"k_g": caches[0], "v_g": caches[1]}
    if cfg.period > 1:
        cache.update({"k_l": caches[2], "v_l": caches[3]})
    if cfg.tail_local:  # trailing local-only layers
        kts, vts = [], []
        for li in range(cfg.tail_local):
            lp = jax.tree.map(lambda a: a[li], params["tail"])
            h = rms_norm(x, lp["rms1"])
            attn, k, v = attention_full(h, lp, 0, cfg.window, cfg, return_kv=True)
            x = x + attn
            kts.append(jnp.roll(k[:, -w:], s % w, axis=1))
            vts.append(jnp.roll(v[:, -w:], s % w, axis=1))
            h2 = rms_norm(x, lp["rms2"])
            if cfg.moe is None:
                x = x + swiglu(h2, lp)
            else:
                x = x + moe_ffn(h2, lp, cfg.moe, mesh, cfg.fsdp)
        cache.update({"k_t": jnp.stack(kts), "v_t": jnp.stack(vts)})
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(cfg.dtype))
    return logits.astype(jnp.float32), cache
