"""Mixture-of-Experts FFN with shard_map expert parallelism.

Covers the two assigned MoE archs:
* llama4-scout: 16 routed experts, top-1, plus 1 shared expert.
* qwen2-moe:    60 routed experts (padded to 64 for even EP), top-4,
                plus 4 shared experts.

Design (TP-style activations, EP weights):
activations are replicated across the ``model`` axis (as in Megatron TP), and
each model shard owns E/TP experts.  Per shard: mask the router assignment to
local experts, select up to ``capacity`` tokens per local expert with a
static-shape argsort gather, run the expert FFN as one batched einsum, scatter
the weighted outputs back, and psum over ``model``.  Communication is a single
(B, S, D) psum — identical to the dense TP FFN — so EP costs no extra
collective volume; the price is capacity-overflow token drops (standard).

Shared experts are plain SwiGLU with d_ff sharded over ``model`` (TP), fused
into the same psum.

Router uses float32 logits + load-balancing auxiliary loss (recorded in the
forward as a side value via ``aux_loss_accum`` — the train loss adds it).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts (logical)
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0      # per shared expert
    e_pad: int = 0            # padded expert count for even EP (0 = n_experts)
    capacity_factor: float = 1.25
    aux_coef: float = 0.01

    @property
    def e(self) -> int:
        return self.e_pad or self.n_experts


def moe_param_shapes(moe: MoEConfig, d: int, lead: tuple, dtype) -> dict:
    sd = lambda shape: jax.ShapeDtypeStruct(lead + shape, dtype)
    e, fe = moe.e, moe.d_ff_expert
    out = {
        "moe_router": jax.ShapeDtypeStruct(lead + (d, e), jnp.float32),
        "moe_gate": sd((e, d, fe)),
        "moe_up": sd((e, d, fe)),
        "moe_down": sd((e, fe, d)),
    }
    if moe.n_shared:
        fs = moe.n_shared * moe.d_ff_shared
        out.update({
            "w_gate": sd((d, fs)),
            "w_up": sd((d, fs)),
            "w_down": sd((fs, d)),
        })
    return out


def moe_param_specs(moe: MoEConfig, fsdp: bool = False, n_lead: int = 2) -> dict:
    dp = "data" if fsdp else None
    lead = (None,) * n_lead
    out = {
        "moe_router": P(),
        "moe_gate": P(*lead, "model", None, dp),
        "moe_up": P(*lead, "model", None, dp),
        "moe_down": P(*lead, "model", dp, None),
    }
    if moe.n_shared:
        out.update({
            "w_gate": P(*lead, dp, "model"),
            "w_up": P(*lead, dp, "model"),
            "w_down": P(*lead, "model", dp),
        })
    return out


def _local_expert_ffn(
    x2d: jax.Array,        # (T, D) local tokens (replicated over model)
    probs: jax.Array,      # (T, K) router probs of the top-k choices
    choice: jax.Array,     # (T, K) expert ids of the top-k choices
    gate: jax.Array,       # (Eloc, D, Fe)
    up: jax.Array,
    down: jax.Array,       # (Eloc, Fe, D)
    e0: jax.Array,         # first expert id owned by this shard
    capacity: int,
) -> jax.Array:
    t, k = choice.shape
    e_loc = gate.shape[0]
    flat_choice = choice.reshape(-1)                   # (T*K,)
    flat_prob = probs.reshape(-1)
    local_eid = flat_choice - e0
    mine = (local_eid >= 0) & (local_eid < e_loc)
    # rank slots per local expert: sort (expert, -prob) so each expert's
    # highest-prob tokens win the capacity race
    sort_key = jnp.where(mine, local_eid, e_loc).astype(jnp.float32) * 2.0 - flat_prob * 1e-6
    # selection is non-differentiable (grads flow via the prob weights at
    # combine); stop_gradient also dodges the broken sort JVP in this build
    order = jnp.argsort(jax.lax.stop_gradient(sort_key))
    sorted_eid = jnp.where(mine, local_eid, e_loc)[order]
    # position within its expert group
    same = sorted_eid[:, None] == jnp.arange(e_loc + 1)[None, :]
    rank_in_e = jnp.cumsum(same, axis=0) - 1
    # flat 1-D gather (take_along_axis grads are broken in this jax build)
    n_cols = e_loc + 1
    slot_rank = rank_in_e.reshape(-1)[jnp.arange(t * k) * n_cols + sorted_eid]
    keep = (sorted_eid < e_loc) & (slot_rank < capacity)
    slot = jnp.where(keep, sorted_eid * capacity + slot_rank, e_loc * capacity)
    # scatter token rows into (Eloc*capacity + 1 overflow, D)
    token_of = order // k
    buf = jnp.zeros((e_loc * capacity + 1, x2d.shape[1]), x2d.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], x2d[token_of], 0))
    xe = buf[:-1].reshape(e_loc, capacity, -1)         # (Eloc, C, D)
    g = jnp.einsum("ecd,edf->ecf", xe, gate)
    u = jnp.einsum("ecd,edf->ecf", xe, up)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u, down)
    y = y.reshape(e_loc * capacity, -1)
    y = jnp.concatenate([y, jnp.zeros((1, y.shape[1]), y.dtype)], axis=0)
    # gather back, weight by router prob, sum over the K choices
    contrib = y[slot] * jnp.where(keep, flat_prob[order], 0.0)[:, None].astype(y.dtype)
    out = jnp.zeros_like(x2d)
    out = out.at[token_of].add(contrib)
    return out


def moe_ffn(
    x: jax.Array,          # (B, S, D); B sharded over the batch axes
    lp: dict,              # block-layer params incl. moe_* (already sliced)
    moe: MoEConfig,
    mesh,
    fsdp: bool = False,
) -> jax.Array:
    b, s, d = x.shape
    router = lp["moe_router"].astype(jnp.float32)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router)
    if moe.e != moe.n_experts:  # mask padded experts off
        pad_mask = jnp.arange(moe.e) >= moe.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs_full, moe.top_k)        # (B, S, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    if mesh is not None and "model" in mesh.axis_names:
        tp = mesh.shape["model"]
    else:
        tp = 1
    e_loc = moe.e // tp
    fe = moe.d_ff_expert

    x2d = x.reshape(b * s, d)
    probs2 = top_p.reshape(b * s, moe.top_k).astype(jnp.float32)
    choice2 = top_e.reshape(b * s, moe.top_k)

    if tp == 1:
        capacity = max(1, int(np.ceil(b * s * moe.top_k / moe.e * moe.capacity_factor)))
        routed = _local_expert_ffn(
            x2d, probs2, choice2,
            lp["moe_gate"], lp["moe_up"], lp["moe_down"],
            jnp.int32(0), capacity,
        )
    else:
        ba = tuple(a for a in mesh.axis_names if a != "model")

        def shard_fn(x2d, probs2, choice2, gate, up, down):
            shard = jax.lax.axis_index("model")
            e0 = (shard * e_loc).astype(jnp.int32)
            if fsdp:  # ZeRO-3: gather the weight shard over `data` per use
                gate = jax.lax.all_gather(gate, "data", axis=3, tiled=True)
                up = jax.lax.all_gather(up, "data", axis=3, tiled=True)
                down = jax.lax.all_gather(down, "data", axis=2, tiled=True)
            t_loc = x2d.shape[0]
            cap = max(1, int(np.ceil(t_loc * moe.top_k / moe.e * moe.capacity_factor)))
            y = _local_expert_ffn(
                x2d, probs2, choice2, gate[0], up[0], down[0], e0, cap
            )
            return jax.lax.psum(y, "model")

        wdp = "data" if fsdp else None
        routed = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(
                P(ba), P(ba), P(ba),
                P("model", None, None, wdp),
                P("model", None, None, wdp),
                P("model", None, wdp, None),
            ),
            out_specs=P(ba),
            check_vma=False,
        )(
            x2d, probs2, choice2,
            lp["moe_gate"].reshape(tp, e_loc, d, fe),
            lp["moe_up"].reshape(tp, e_loc, d, fe),
            lp["moe_down"].reshape(tp, e_loc, fe, d),
        )
    out = routed.reshape(b, s, d)

    if moe.n_shared:
        g = jnp.einsum("bsd,df->bsf", x, lp["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, lp["w_up"])
        out = out + jnp.einsum(
            "bsf,fd->bsd",
            jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
            lp["w_down"],
        )
    return out.astype(x.dtype)


def load_balance_loss(logits: jax.Array, top_e: jax.Array, moe: MoEConfig) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(logits, axis=-1)
    p_mean = probs.mean(axis=(0, 1))
    onehot = jax.nn.one_hot(top_e[..., 0], moe.e)
    f = onehot.mean(axis=(0, 1))
    return moe.e * jnp.sum(f * p_mean) * moe.aux_coef
