from .transformer import (
    LMConfig,
    cache_shapes,
    cache_specs,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    make_train_step,
    param_shapes,
    param_specs,
    prefill_step,
)
from .moe import MoEConfig
