"""GraphCast-style encoder-processor-decoder mesh GNN (arXiv:2212.12794).

Adapted to the assigned generic graph shapes: the model runs on any
(n_nodes, n_edges, d_feat) graph given as an edge index, in three regimes:

* ``full graph``  — one big graph; nodes/edges as flat arrays.
* ``sampled``     — layered neighbor-sampled subgraph (minibatch_lg): fixed
  padded edge lists per layer from data/synthetic.neighbor_sample.
* ``batched``     — (batch, nodes, ...) small molecule graphs, vmapped.

Message passing is segment_sum over an edge index -> node scatter (JAX sparse
is BCOO-only; this gather/scatter IS the SpMM kernel regime for this family).

Sharding (distributed/sharding.gnn_specs): edges sharded over the batch axes,
node tensors sharded on the FEATURE dim over `model` — so the edge gather
(indexes dim 0) and the segment_sum scatter (writes dim 0) are local per
GSPMD (operands sharded only on non-indexed dims), and the per-node MLPs are
TP-sharded.  This avoids replicating the 5 GB node tensor of ogb_products.

Structure per GraphCast: encoder MLP lifts input features to d_hidden;
``n_layers`` processor blocks of (edge MLP -> aggregate -> node MLP) with
residuals + LayerNorm; decoder MLP emits n_vars outputs per node.
``mesh_refinement`` controls the simulated multi-scale edge set in the
paper's own config (the icosahedral hierarchy); for assigned graphs the edge
set is the data's own.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 16
    d_hidden: int = 512
    n_vars: int = 227
    mesh_refinement: int = 6
    aggregator: str = "sum"
    dtype: Any = jnp.float32
    sharded_mp: bool = False   # perf it.1 (refuted): shard_map gather/scatter
                               # under feature-TP — boundary reshards cost more
    row_dp: bool = False       # perf it.2: weights REPLICATED (34 MB total),
                               # nodes+edges row-sharded over every mesh axis,
                               # edges dst-sorted (data-pipeline contract) so
                               # the scatter is local; communication = ONE
                               # node-tensor all-gather per layer


def _mlp_shapes(d_in: int, d_hidden: int, d_out: int, dtype) -> dict:
    sd = lambda s: jax.ShapeDtypeStruct(s, dtype)
    return {
        "w1": sd((d_in, d_hidden)), "b1": sd((d_hidden,)),
        "w2": sd((d_hidden, d_out)), "b2": sd((d_out,)),
    }


def param_shapes(cfg: GNNConfig, d_feat: int) -> dict:
    dh = cfg.d_hidden
    dt = cfg.dtype
    sd = lambda s: jax.ShapeDtypeStruct(s, dt)
    L = cfg.n_layers
    return {
        "encoder": _mlp_shapes(d_feat, dh, dh, dt),
        "proc": {
            # stacked over layers for scan; edge MLP eats [src, dst] concat
            "edge_w1": sd((L, 2 * dh, dh)), "edge_b1": sd((L, dh)),
            "edge_w2": sd((L, dh, dh)), "edge_b2": sd((L, dh)),
            "node_w1": sd((L, 2 * dh, dh)), "node_b1": sd((L, dh)),
            "node_w2": sd((L, dh, dh)), "node_b2": sd((L, dh)),
            "ln_node": sd((L, dh)), "ln_edge": sd((L, dh)),
        },
        "decoder": _mlp_shapes(dh, dh, cfg.n_vars, dt),
    }


def param_specs(cfg: GNNConfig) -> dict:
    """Hidden dim over `model` (TP); GSPMD resolves the 2*dh contractions.
    With cfg.row_dp every weight is replicated instead (34 MB total: the
    right call — see EXPERIMENTS §Perf cell 4)."""
    if cfg.row_dp:
        return jax.tree.map(lambda _: P(), param_shapes(cfg, 1))
    mlp = lambda: {"w1": P(None, "model"), "b1": P("model"),
                   "w2": P("model", None), "b2": P()}
    return {
        "encoder": {"w1": P(None, "model"), "b1": P("model"),
                    "w2": P("model", None), "b2": P()},
        "proc": {
            "edge_w1": P(None, None, "model"), "edge_b1": P(None, "model"),
            "edge_w2": P(None, "model", None), "edge_b2": P(),
            "node_w1": P(None, None, "model"), "node_b1": P(None, "model"),
            "node_w2": P(None, "model", None), "node_b2": P(),
            "ln_node": P(), "ln_edge": P(),
        },
        "decoder": {"w1": P(None, "model"), "b1": P("model"),
                    "w2": P("model", None), "b2": P()},
    }


def init_params(cfg: GNNConfig, d_feat: int, key: jax.Array) -> dict:
    shapes = param_shapes(cfg, d_feat)
    flat, treedef = jax.tree_util.tree_flatten(shapes)
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, s in zip(keys, flat):
        if len(s.shape) >= 2:
            fan_in = s.shape[-2]
            leaves.append(jax.random.normal(k, s.shape, s.dtype) / np.sqrt(fan_in))
        else:
            leaves.append(jnp.zeros(s.shape, s.dtype))
    p = jax.tree_util.tree_unflatten(treedef, leaves)
    p["proc"]["ln_node"] = jnp.ones_like(p["proc"]["ln_node"])
    p["proc"]["ln_edge"] = jnp.ones_like(p["proc"]["ln_edge"])
    return p


def _mlp(x, mp):
    h = jax.nn.silu(x @ mp["w1"] + mp["b1"])
    return h @ mp["w2"] + mp["b2"]


def _gather_sharded(h, idx, mesh):
    """h (N, F) sharded P(None, model); idx (E,) sharded over the batch axes.
    A plain h[idx] lets GSPMD all-gather h over `model` (measured ~1 TB/step
    on ogb_products); inside shard_map the gather is provably local."""
    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def f(h_l, idx_l):
        return h_l[idx_l]

    return jax.shard_map(
        f, mesh=mesh,
        in_specs=(P(None, "model"), P(ba)),
        out_specs=P(ba, "model"),
        check_vma=False,
    )(h, idx)


def _scatter_sum_sharded(m, dst, n, mesh):
    """Edge messages (E, F) [batch x model sharded] scatter-added into node
    rows: local segment_sum per data shard + one psum over the batch axes."""
    ba = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def f(m_l, dst_l):
        part = jax.ops.segment_sum(m_l, dst_l, num_segments=n)
        for ax in ba:
            part = jax.lax.psum(part, ax)
        return part

    return jax.shard_map(
        f, mesh=mesh,
        in_specs=(P(ba, "model"), P(ba)),
        out_specs=P(None, "model"),
        check_vma=False,
    )(m, dst)


def _layer_norm(x, g, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * g).astype(x.dtype)


def forward(
    params: dict,
    node_feats: jax.Array,   # (N, d_feat)
    src: jax.Array,          # (E,) int32
    dst: jax.Array,          # (E,) int32
    cfg: GNNConfig,
    edge_mask: Optional[jax.Array] = None,   # (E,) bool for padded edges
    mesh=None,
) -> jax.Array:
    """Returns per-node predictions (N, n_vars)."""
    n = node_feats.shape[0]
    h = _mlp(node_feats.astype(cfg.dtype), params["encoder"])
    sharded = cfg.sharded_mp and mesh is not None

    def block(h, lp):
        if sharded:
            e_in = jnp.concatenate(
                [_gather_sharded(h, src, mesh),
                 _gather_sharded(h, dst, mesh)], axis=-1)          # (E, 2dh)
        else:
            e_in = jnp.concatenate([h[src], h[dst]], axis=-1)      # (E, 2dh)
        m = jax.nn.silu(e_in @ lp["edge_w1"] + lp["edge_b1"])
        m = m @ lp["edge_w2"] + lp["edge_b2"]
        m = _layer_norm(m, lp["ln_edge"])
        if edge_mask is not None:
            m = jnp.where(edge_mask[:, None], m, 0.0)
        if sharded and cfg.aggregator == "sum":
            agg = _scatter_sum_sharded(m, dst, n, mesh)
        elif cfg.aggregator == "sum":
            agg = jax.ops.segment_sum(m, dst, num_segments=n)
        elif cfg.aggregator == "max":
            agg = jax.ops.segment_max(m, dst, num_segments=n)
        else:
            raise ValueError(cfg.aggregator)
        u = jnp.concatenate([h, agg], axis=-1)
        upd = jax.nn.silu(u @ lp["node_w1"] + lp["node_b1"])
        upd = upd @ lp["node_w2"] + lp["node_b2"]
        h2 = _layer_norm(h + upd, lp["ln_node"])
        return h2, None

    h, _ = jax.lax.scan(jax.checkpoint(block), h, params["proc"])
    return _mlp(h, params["decoder"])


def forward_batched(params, node_feats, src, dst, cfg, edge_mask=None):
    """(B, N, F) graphs with per-graph edge lists (B, E)."""
    fn = lambda nf, s, d, em: forward(params, nf, s, d, cfg, em)
    if edge_mask is None:
        edge_mask = jnp.ones(src.shape, bool)
    return jax.vmap(fn)(node_feats, src, dst, edge_mask)


def forward_rowdp(params, node_feats, src, dst, cfg, mesh,
                  edge_mask=None):
    """Row-DP message passing: shard_map over ALL mesh axes flattened.

    Contracts (enforced by the data pipeline / input_specs):
      * node rows sharded evenly over the flattened mesh axes;
      * edges sharded so shard i's edges all have dst in i's row range
        (sort edges by dst once at load — free) -> the scatter is local;
      * src is arbitrary -> one tiled all-gather of h per layer (the ONLY
        collective; weights are replicated).
    """
    axes = tuple(mesh.axis_names)
    n = node_feats.shape[0]
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    rows = n // n_shards

    def local(nf_l, src_l, dst_l, em_l, params):
        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        lo = idx * rows
        h_l = _mlp(nf_l.astype(cfg.dtype), params["encoder"])   # (rows, dh)

        def block(h_l, lp):
            h_full = jax.lax.all_gather(h_l, axes, axis=0, tiled=True)
            e_in = jnp.concatenate(
                [h_full[src_l], h_full[dst_l]], axis=-1)
            m = jax.nn.silu(e_in @ lp["edge_w1"] + lp["edge_b1"])
            m = m @ lp["edge_w2"] + lp["edge_b2"]
            m = _layer_norm(m, lp["ln_edge"])
            if em_l is not None:
                m = jnp.where(em_l[:, None], m, 0.0)
            # dst-sorted contract: every dst_l is in [lo, lo+rows)
            agg = jax.ops.segment_sum(m, dst_l - lo, num_segments=rows)
            u = jnp.concatenate([h_l, agg], axis=-1)
            upd = jax.nn.silu(u @ lp["node_w1"] + lp["node_b1"])
            upd = upd @ lp["node_w2"] + lp["node_b2"]
            return _layer_norm(h_l + upd, lp["ln_node"]), None

        h_l, _ = jax.lax.scan(jax.checkpoint(block), h_l, params["proc"])
        return _mlp(h_l, params["decoder"])

    spec_rows = P(axes, None)
    spec_e = P(axes)
    em = edge_mask if edge_mask is not None else jnp.ones(src.shape, bool)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(spec_rows, spec_e, spec_e, spec_e, P()),
        out_specs=spec_rows,
        check_vma=False,
    )(node_feats, src, dst, em, params)


def mse_loss(params, node_feats, src, dst, targets, cfg,
             edge_mask=None, node_mask=None, mesh=None) -> jax.Array:
    if cfg.row_dp and mesh is not None:
        pred = forward_rowdp(params, node_feats, src, dst, cfg, mesh, edge_mask)
    else:
        pred = forward(params, node_feats, src, dst, cfg, edge_mask, mesh)
    err = (pred.astype(jnp.float32) - targets.astype(jnp.float32)) ** 2
    if node_mask is not None:
        err = jnp.where(node_mask[:, None], err, 0.0)
        denom = jnp.maximum(node_mask.sum() * err.shape[1], 1)
    else:
        denom = err.size
    return err.sum() / denom


def make_train_step(cfg: GNNConfig, opt_cfg=None, batched: bool = False,
                    mesh=None):
    from repro.optim import adamw

    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def train_step(params, opt_state, batch):
        def loss(p):
            if batched:
                pred = forward_batched(
                    p, batch["node_feats"], batch["src"], batch["dst"], cfg,
                    batch.get("edge_mask"),
                )
                return jnp.mean(
                    (pred.astype(jnp.float32) - batch["targets"].astype(jnp.float32)) ** 2
                )
            return mse_loss(
                p, batch["node_feats"], batch["src"], batch["dst"],
                batch["targets"], cfg, batch.get("edge_mask"),
                batch.get("node_mask"), mesh,
            )

        l, grads = jax.value_and_grad(loss)(params)
        params, opt_state, metrics = adamw.apply(params, grads, opt_state, opt_cfg)
        metrics["loss"] = l
        return params, opt_state, metrics

    return train_step
