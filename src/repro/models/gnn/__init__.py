from .graphcast import GNNConfig, forward, forward_batched, init_params, make_train_step, mse_loss, param_shapes, param_specs
