"""Delta-aware rebuild scheduler — the background half of the lifecycle
runtime (paper §6.3: periodic rebuilds fold the delta + drop tombstones,
*while serving*).

The paper's billion-scale "(re)build within hours" claim only pays off when
a rebuild (a) runs concurrently with traffic and (b) redoes only what
changed.  Both live here:

* :class:`CorpusStore` — the append-only global-id row store (row index ==
  vector id).  Inserts land in the delta buffer first and are appended at
  rebuild-snapshot time, so corpus rows never move: posting ids stay valid
  across every rebuild and clients' ids survive swaps.  Deletes never
  compact rows (that would shift every later shard's content); they are
  masked out of the posting build instead, and a ``full`` rebuild remains
  the compaction point — exactly the paper's delta/main split.
* :func:`delta_build` — stage 2 through ``build/stream.ShardAssignPipeline``
  in **delta mode**: ``plan_delta_shards`` diffs the corpus against the
  previous build's content-hash manifest, only dirty/new shards stream +
  assign, untouched shards reuse their checkpoints byte-for-byte.  The
  pipeline's byte counter and the plan's reuse counter together prove the
  I/O cut (the bench asserts the ratio, it does not infer it).
* :class:`RebuildScheduler` — watches the live freshness state
  (delta-fill / tombstone-ratio thresholds), runs the delta build on a
  background thread, and performs the atomic swap: snapshot the delta under
  the lane's lock, build, then (again under the lock) carry the ops that
  arrived *during* the build into the new epoch's state and swap epochs via
  the :class:`~repro.lifecycle.version.VersionManager` — in-flight batches
  finish on the old epoch, zero batches dropped.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Callable, Optional

import numpy as np
import jax.numpy as jnp

from repro.build.stream import (
    ShardAssignPipeline, array_content_hash, plan_delta_shards,
)
from repro.core.ivf import IVFIndex, build_postings

from .ingest import LiveFreshState, UpdateLane
from .version import VersionManager


@dataclasses.dataclass(frozen=True)
class RebuildPolicy:
    delta_fill_frac: float = 0.5       # trigger: delta buffer this full
    tombstone_frac: float = 0.25       # trigger: this share of ids dead
    min_interval_s: float = 0.0        # rebuild rate limit
    per_task: int = 5000               # stage-2 shard rows (span quantum)
    capacity: Optional[int] = None     # next epoch's delta capacity
                                       # (None = keep current)


@dataclasses.dataclass
class RebuildReport:
    trigger: str
    mode: str                          # "delta" | "full"
    eid_old: int = -1
    eid_new: int = -1
    n_corpus: int = 0
    n_clusters: int = 0
    folded_inserts: int = 0
    folded_deletes: int = 0
    shards_total: int = 0
    shards_streamed: int = 0
    shards_reused: int = 0
    bytes_streamed: int = 0            # stage-2 slice bytes actually moved
    bytes_reused: int = 0              # slice bytes checkpoint reuse avoided
    full_stream_bytes: int = 0         # what a full restream would move
    t_snapshot: float = 0.0
    t_built: float = 0.0
    t_swapped: float = 0.0
    carried_ops: int = 0               # delta rows applied during the build
    tier: str = "f32"                  # first-pass payload the new epoch's
                                       # pipeline serves ("q8" = quantized
                                       # shards + flash re-rank tier) — the
                                       # rebuild must preserve the serving
                                       # tier choice across swaps

    @property
    def io_cut_x(self) -> float:
        return self.full_stream_bytes / max(self.bytes_streamed, 1)


class CorpusStore:
    """Append-only host corpus with stable global row ids.

    Growth is amortized (capacity doubling); ``view()`` is a zero-copy
    window of the live rows, safe to hand to the shard pipeline."""

    def __init__(self, x0: np.ndarray):
        x0 = np.ascontiguousarray(x0, dtype=np.float32)
        self._n = x0.shape[0]
        self._buf = x0
        self.dim = x0.shape[1]

    @property
    def n(self) -> int:
        return self._n

    def view(self) -> np.ndarray:
        return self._buf[: self._n]

    def append(self, vecs: np.ndarray) -> tuple[int, int]:
        vecs = np.asarray(vecs, np.float32).reshape(-1, self.dim)
        lo = self._n
        hi = lo + vecs.shape[0]
        if hi > self._buf.shape[0]:
            cap = max(hi, 2 * self._buf.shape[0])
            grown = np.empty((cap, self.dim), np.float32)
            grown[: self._n] = self._buf[: self._n]
            self._buf = grown
        self._buf[lo:hi] = vecs
        self._n = hi
        return lo, hi


def _chunks(n: int, per_task: int) -> list[tuple[int, int]]:
    return [(s, min(s + per_task, n)) for s in range(0, n, per_task)]


def _manifest_path(workdir: str) -> str:
    return os.path.join(workdir, "shard_manifest.json")


def load_manifest(workdir: str) -> Optional[dict]:
    p = _manifest_path(workdir)
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def save_manifest(workdir: str, manifest: dict) -> None:
    p = _manifest_path(workdir)
    tmp = p + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, p)


def delta_build(
    x: np.ndarray,
    centroids: np.ndarray,
    workdir: str,
    *,
    cluster_len: int,
    eps: float,
    max_replicas: int,
    per_task: int = 5000,
    tombstone: Optional[np.ndarray] = None,
    use_manifest: bool = True,
) -> tuple[IVFIndex, dict]:
    """Stage 2 + posting build with content-hash shard reuse.

    Returns (index, stats).  ``use_manifest=False`` forces a full restream
    (the A/B baseline for the I/O-cut counters).  Tombstoned rows are
    masked out of the posting build — the fold that drops deletes — but the
    corpus keeps its rows so shard hashes stay stable.
    """
    os.makedirs(workdir, exist_ok=True)
    shards_dir = os.path.join(workdir, "shards")
    os.makedirs(shards_dir, exist_ok=True)
    n = x.shape[0]
    spans = _chunks(n, per_task)
    paths = [os.path.join(shards_dir, f"assign_{i:05d}.npz")
             for i in range(len(spans))]
    prev = load_manifest(workdir) if use_manifest else None
    plan = plan_delta_shards(x, spans, paths, centroids, prev)
    pipe = ShardAssignPipeline(
        x, centroids, [spans[i] for i in plan.dirty],
        [paths[i] for i in plan.dirty],
        eps=eps, max_replicas=max_replicas)
    try:
        stamps = pipe.run()
    finally:
        pipe.close()
    assign = np.concatenate([np.load(p)["assign"] for p in paths], axis=0) \
        if paths else np.zeros((0, max_replicas), np.int32)
    folded_deletes = 0
    if tombstone is not None:
        dead = np.asarray(tombstone[:n], bool)
        folded_deletes = int(dead.sum())
        assign[dead] = -1              # the fold: tombstones leave postings
    n_clusters = centroids.shape[0]
    postings, posting_ids = build_postings(x, assign, n_clusters, cluster_len)
    index = IVFIndex(jnp.asarray(np.asarray(centroids, np.float32)),
                     jnp.asarray(postings), jnp.asarray(posting_ids))
    save_manifest(workdir, plan.manifest)
    stats = {
        "shards_total": len(spans),
        "shards_streamed": len(plan.dirty),
        "shards_reused": len(plan.reused),
        "bytes_streamed": int(pipe.bytes_streamed),
        "bytes_reused": int(plan.bytes_reused),
        "full_stream_bytes": int(x[:n].nbytes),
        "folded_deletes": folded_deletes,
        "shard_stamps": [t.asdict() for t in stamps],
    }
    return index, stats


class RebuildScheduler:
    """Threshold-triggered live rebuild + atomic epoch swap.

    ``make_pipeline(index, fresh_state)`` builds (and warms) the serving
    pipeline for a freshly built index — the deployment-specific part
    (tier construction, SearchConfig, warmup shapes) stays with the
    caller.  The scheduler owns *when* to rebuild, the snapshot/carry
    protocol, and the swap ordering.
    """

    # retained report/failure windows: the scheduler is a long-lived
    # daemon (one rebuild per nightly fold adds up); the full record
    # lands on the lifecycle trace track, these are the recent window
    MAX_REPORTS = 64
    MAX_FAILURES = 64

    def __init__(
        self,
        *,
        name: str,
        corpus: CorpusStore,
        centroids: np.ndarray,
        workdir: str,
        lane: UpdateLane,
        versions: "VersionManager",
        make_pipeline: Callable,
        cluster_len: int,
        closure_eps: float = 0.2,
        max_replicas: int = 4,
        policy: RebuildPolicy = RebuildPolicy(),
        clock=time.monotonic,
        drift=None,
        obs=None,
    ):
        self.name = name
        self.corpus = corpus
        self.centroids = np.asarray(centroids, np.float32)
        self.workdir = workdir
        self.lane = lane
        self.versions = versions
        self.make_pipeline = make_pipeline
        self.cluster_len = int(cluster_len)
        self.closure_eps = float(closure_eps)
        self.max_replicas = int(max_replicas)
        self.policy = policy
        self.clock = clock
        self.drift = drift                 # DriftMonitor advisory source
        self.obs = obs                     # lifecycle trace track target
        self.reports: list[RebuildReport] = []
        self.failures: list[str] = []
        self.rebuilding = threading.Event()
        self._last_rebuild = -1e30
        self._seen_rejected = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- trigger -----------------------------------------------------------
    def due(self, now: Optional[float] = None) -> Optional[str]:
        """Rebuild trigger reason, or None."""
        now = self.clock() if now is None else now
        if self.rebuilding.is_set():
            return None
        if now - self._last_rebuild < self.policy.min_interval_s:
            return None
        st = self.lane.state
        if st.fill_frac >= self.policy.delta_fill_frac:
            return "delta_fill"
        if st.tombstone_frac >= self.policy.tombstone_frac:
            return "tombstones"
        if self.lane.stats.rejected_full > self._seen_rejected:
            return "insert_rejected"
        if self.drift is not None:
            # quality trigger: the insert stream drifted away from the
            # epoch's centroids — rebuild before the capacity thresholds
            # would have noticed anything
            reason = self.drift.advisory()
            if reason is not None:
                return reason
        return None

    # -- the rebuild + swap flow ------------------------------------------
    def rebuild_and_swap(self, trigger: str = "manual",
                         mode: str = "delta") -> RebuildReport:
        """Fold the delta, rebuild stage 2 (delta mode), swap epochs.

        Runs on the caller's thread (the background poller uses
        ``start``).  The engine keeps serving throughout: only the two
        snapshot/carry critical sections take the lane's state lock, and
        the swap itself is the VersionManager's atomic publish."""
        rep = RebuildReport(trigger=trigger, mode=mode)
        self.rebuilding.set()
        try:
            return self._rebuild(rep)
        finally:
            self.rebuilding.clear()
            self._last_rebuild = self.clock()
            self._seen_rejected = self.lane.stats.rejected_full

    def _rebuild(self, rep: RebuildReport) -> RebuildReport:
        t_start = self.clock()
        st = self.lane.state
        # -- snapshot: fold the delta prefix into the corpus ---------------
        with st.lock:
            f0 = st.fill
            vecs0, ids0 = st.delta_rows(0, f0)
            tomb0 = st.tombstone_bits()
            rep.t_snapshot = self.clock()
        if f0:
            # global-id invariant: delta ids were minted sequentially from
            # corpus.n, so folding the prefix in order lands each vector at
            # the row its id already names.  Idempotent against a prior
            # FAILED rebuild attempt that already appended part (or all) of
            # this prefix — fold only the rows the corpus doesn't have yet.
            already = self.corpus.n - int(ids0[0])
            if not 0 <= already <= f0:
                raise RuntimeError(
                    f"delta ids out of step with corpus rows "
                    f"(corpus n={self.corpus.n}, delta ids "
                    f"[{ids0[0]}, {ids0[-1]}])")
            if already < f0:
                self.corpus.append(vecs0[already:])
            assert self.corpus.n == int(ids0[-1]) + 1
        rep.folded_inserts = int(f0)
        x = self.corpus.view()
        index, bstats = delta_build(
            x, self.centroids, self.workdir,
            cluster_len=self.cluster_len, eps=self.closure_eps,
            max_replicas=self.max_replicas, per_task=self.policy.per_task,
            tombstone=tomb0, use_manifest=(rep.mode == "delta"))
        rep.n_corpus = int(x.shape[0])
        rep.n_clusters = int(index.n_clusters)
        rep.folded_deletes = bstats["folded_deletes"]
        for key in ("shards_total", "shards_streamed", "shards_reused",
                    "bytes_streamed", "bytes_reused", "full_stream_bytes"):
            setattr(rep, key, bstats[key])
        rep.t_built = self.clock()

        # -- next epoch's freshness state ----------------------------------
        capacity = self.policy.capacity or st.capacity
        new_state = LiveFreshState(
            dim=self.corpus.dim, capacity=capacity, n_main=self.corpus.n,
            next_id=None, seq0=st.seq)     # seq stays globally monotonic
        pipeline = self.make_pipeline(index, new_state)
        # delta rebuilds must emit the same serving tier they replace: a
        # make_pipeline hook that silently falls back to f32 would undo the
        # quantized default at the first nightly rebuild
        rep.tier = getattr(pipeline, "tier_kind", "f32")

        # -- atomic swap: carry the ops applied during the build -----------
        with st.lock:
            f1 = st.fill
            carry_v, carry_i = st.delta_rows(f0, f1)
            new_state.adopt(carry_v, carry_i, st.tombstone_bits())
            # next_id continuity: ids minted during the build stay minted
            new_state.next_id = st.next_id
            # seq continuity must be re-synced HERE, not at construction:
            # the old state kept publishing during the (slow) build, and a
            # new epoch re-issuing already-used seqs would corrupt the
            # visibility stamps (ops marked visible by batches whose
            # snapshot never contained them)
            new_state.seq = st.seq
            new_state.publish()
            self.lane.retarget(new_state)
            old_ep, new_ep = self.versions.swap(self.name, pipeline,
                                               fresh=new_state)
        rep.carried_ops = int(f1 - f0)
        rep.eid_old, rep.eid_new = old_ep.eid, new_ep.eid
        rep.t_swapped = self.clock()
        self._emit_rebuild_trace(rep, bstats, t_start)
        if self.drift is not None:
            # the advisory's evidence was just folded into the new epoch
            self.drift.reset()
        self.reports.append(rep)
        del self.reports[: -self.MAX_REPORTS]
        return rep

    def _emit_rebuild_trace(self, rep: RebuildReport, bstats: dict,
                            t_start: float) -> None:
        """Rebuild/swap on its own ``lifecycle`` trace track: sequential
        snapshot / build / swap "X" spans, per-shard stage-2 stream
        lifetimes as async pairs (double-buffered shards OVERLAP, so they
        must not be "X" spans), and the epoch-swap instant tagged with the
        serving tier the new epoch inherits."""
        if self.obs is None or not self.obs.tracing:
            return
        tr = self.obs.trace
        tr.span("snapshot", t_start, rep.t_snapshot, track="lifecycle",
                args={"trigger": rep.trigger,
                      "folded_inserts": rep.folded_inserts})
        tr.span("build", rep.t_snapshot, rep.t_built, track="lifecycle",
                args={"mode": rep.mode,
                      "shards_streamed": rep.shards_streamed,
                      "shards_reused": rep.shards_reused,
                      "io_cut_x": round(rep.io_cut_x, 2)})
        for stamp in bstats.get("shard_stamps", ()):
            if stamp.get("resumed"):
                continue            # checkpoint hit: nothing streamed
            aid = f"rebuild{rep.eid_new}-shard{stamp['shard']}"
            tr.abegin("shard_stream", aid, t=stamp["load_start"],
                      track="lifecycle-shards",
                      args={"shard": stamp["shard"],
                            "rows": stamp["rows"],
                            "bytes": stamp["bytes"]})
            tr.aend("shard_stream", aid, t=stamp["assign_done"],
                    track="lifecycle-shards")
        tr.span("swap", rep.t_built, rep.t_swapped, track="lifecycle",
                args={"carried_ops": rep.carried_ops})
        tr.instant("epoch_swap", t=rep.t_swapped, track="lifecycle",
                   args={"eid_old": rep.eid_old, "eid_new": rep.eid_new,
                         "tier": rep.tier})

    # -- background poller -------------------------------------------------
    def start(self, poll_s: float = 0.05) -> None:
        assert self._thread is None, "scheduler already started"
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                reason = self.due()
                if reason is not None:
                    try:
                        self.rebuild_and_swap(trigger=reason)
                    except Exception as e:   # noqa: BLE001 — daemon must
                        # survive a failed attempt: the fold is idempotent
                        # (partial appends are detected and skipped on
                        # retry) and a retry re-snapshots a LARGER prefix,
                        # so e.g. a capacity overrun self-heals; dying here
                        # would silently stop all future rebuilds while the
                        # delta fills and inserts start bouncing
                        self.failures.append(repr(e))
                        del self.failures[: -self.MAX_FAILURES]
                        print(f"[rebuild-sched] attempt failed, will retry: "
                              f"{e!r}")
                self._stop.wait(poll_s)

        self._thread = threading.Thread(target=loop, name="rebuild-sched",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
