"""Update lane — the ingest half of the index lifecycle runtime (§6.2/§6.3).

The paper's deployment takes 25-30 KOPS of updates *concurrently with
search*: recent insertions land in an in-memory auxiliary structure,
deletions set tombstone bits, and queries merge both against the main index.
PR 2 built the search lane (SQ/CQ queue pairs -> batcher -> prefetch
pipeline); this module adds the symmetric **update lane** on the same
engine:

* :class:`LiveFreshState` — the mutable serving-side freshness state: a
  host-authoritative delta buffer + tombstone bitmap over the GLOBAL id
  space (ids are stable across rebuilds — the rebuild folds the delta but
  never renumbers, so clients' ids survive swaps), published to the device
  as an immutable :class:`FreshSnapshot` that search batches capture at
  dispatch.
* :class:`UpdateLane` — a second bounded SQ/CQ queue pair carrying
  insert/delete ops.  The engine's poller drains it **between** search
  batches with a per-cycle budget (``BatchPolicy.update_quantum``), so an
  update storm back-pressures its own SQ instead of starving search — the
  same fail-fast posture the search lane's admission control takes.

Visibility is **measured, not inferred**: every applied op records the
publish sequence number that first contains it; when a search batch whose
captured snapshot covers that sequence *harvests* (results returned), the
op is stamped visible.  ``insert-to-visible`` is therefore the real
client-observable interval — submit to first search response that could
have returned the vector — not a queue-depth estimate.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs import Observability
from repro.runtime.engine import QueuePair


@dataclasses.dataclass(frozen=True)
class FreshSnapshot:
    """Immutable device view of the freshness state at one publish point."""
    seq: int
    fill: int
    delta_vecs: jax.Array          # (capacity, D) f32
    delta_ids: jax.Array           # (capacity,) int32, -1 = empty
    tombstone: jax.Array           # (id_capacity,) bool


class LiveFreshState:
    """Host-authoritative delta buffer + tombstones with device publishing.

    ``n_main`` is the number of ids already owned by the main index (the
    corpus rows at epoch start); inserts mint ids ``next_id, next_id+1, …``
    so the id space stays append-only and globally stable.  ``capacity``
    bounds the delta buffer — a full buffer rejects inserts, which is the
    rebuild-due signal (the paper's hourly/daily cadence trigger).

    Thread contract: mutators take ``lock``; ``snapshot()`` is a lock-free
    read of the last published immutable snapshot (atomic reference load).
    The rebuild scheduler takes ``lock`` to snapshot/carry state at swap.
    """

    def __init__(self, dim: int, capacity: int, n_main: int,
                 next_id: Optional[int] = None, seq0: int = 0):
        self.dim = int(dim)
        self.capacity = int(capacity)
        self.n_main = int(n_main)
        self.next_id = int(n_main if next_id is None else next_id)
        self.id_capacity = self.next_id + self.capacity
        self.lock = threading.RLock()
        self.fill = 0
        self.seq = int(seq0)               # global-monotonic across epochs
        self.n_tombstoned = 0
        self._delta_vecs = np.zeros((self.capacity, self.dim), np.float32)
        self._delta_ids = np.full((self.capacity,), -1, np.int32)
        self._tombstone = np.zeros((self.id_capacity,), bool)
        self._snapshot: Optional[FreshSnapshot] = None
        self.publish()

    # -- mutators (call under self.lock via UpdateLane / scheduler) --------
    def insert(self, vecs: np.ndarray) -> np.ndarray:
        """Append to the delta buffer; returns minted global ids.  Raises
        BufferError when full — the rebuild-due signal."""
        vecs = np.asarray(vecs, np.float32).reshape(-1, self.dim)
        n = vecs.shape[0]
        with self.lock:
            if self.fill + n > self.capacity:
                raise BufferError(
                    f"delta buffer full ({self.fill}+{n}>{self.capacity}): "
                    f"rebuild due")
            ids = np.arange(self.next_id, self.next_id + n, dtype=np.int32)
            self._delta_vecs[self.fill:self.fill + n] = vecs
            self._delta_ids[self.fill:self.fill + n] = ids
            self.fill += n
            self.next_id += n
            return ids

    def delete(self, ids: np.ndarray) -> int:
        """Tombstone ids; unminted ids are ignored.  Returns # newly dead."""
        ids = np.asarray(ids, np.int64).ravel()
        with self.lock:
            ids = ids[(ids >= 0) & (ids < self.next_id)]
            fresh_kills = int((~self._tombstone[ids]).sum())
            self._tombstone[ids] = True
            self.n_tombstoned += fresh_kills
            return fresh_kills

    def publish(self) -> int:
        """Stream the current host state to device as a new immutable
        snapshot; returns its sequence number.  One device_put per pump
        cycle, not per op — the batching is part of the measured
        insert-to-visible latency, not hidden from it."""
        with self.lock:
            self.seq += 1
            # jnp.array, NOT jnp.asarray: on CPU asarray may zero-copy
            # ALIAS the host buffer (alignment-dependent), and an aliased
            # "snapshot" would mutate under in-flight batches on the next
            # insert — the copy is the immutability contract
            self._snapshot = FreshSnapshot(
                seq=self.seq, fill=self.fill,
                delta_vecs=jnp.array(self._delta_vecs),
                delta_ids=jnp.array(self._delta_ids),
                tombstone=jnp.array(self._tombstone),
            )
            return self.seq

    def already_covered(self, ids: np.ndarray) -> bool:
        """True when a delete of ``ids`` is fully covered by existing
        tombstones: it names at least one minted id and every minted id in
        it is already dead (a newer tombstone covers it — the update lane
        drops such deletes instead of re-applying them).  A delete naming
        no minted ids at all is NOT covered: it takes the normal apply
        path (a no-op there) so its completion stays "ok", as before."""
        ids = np.asarray(ids, np.int64).ravel()
        with self.lock:
            ids = ids[(ids >= 0) & (ids < self.next_id)]
            return ids.size > 0 and bool(self._tombstone[ids].all())

    # -- readers -----------------------------------------------------------
    def snapshot(self) -> FreshSnapshot:
        return self._snapshot

    @property
    def fill_frac(self) -> float:
        return self.fill / max(self.capacity, 1)

    @property
    def tombstone_frac(self) -> float:
        return self.n_tombstoned / max(self.next_id, 1)

    # -- swap-time accessors (scheduler holds self.lock) -------------------
    def delta_rows(self, lo: int, hi: int) -> tuple[np.ndarray, np.ndarray]:
        return (self._delta_vecs[lo:hi].copy(), self._delta_ids[lo:hi].copy())

    def tombstone_bits(self) -> np.ndarray:
        return self._tombstone.copy()

    def adopt(self, vecs: np.ndarray, ids: np.ndarray,
              tombstone: np.ndarray) -> None:
        """Carry post-snapshot state into this (fresh) epoch state: the ops
        applied while the rebuild ran.  Called under both states' locks at
        swap time."""
        n = vecs.shape[0]
        if n > self.capacity:
            # recoverable: the scheduler retries, and the retry snapshots
            # the (larger) current fill into the fold, shrinking the carry
            raise RuntimeError(
                f"rebuild outran the new delta capacity ({n} carried ops "
                f"> {self.capacity}): retry folds them instead")
        with self.lock:
            self._delta_vecs[:n] = vecs
            self._delta_ids[:n] = ids
            self.fill = n
            m = min(tombstone.shape[0], self.id_capacity)
            self._tombstone[:m] = tombstone[:m]
            assert not tombstone[m:].any(), "tombstoned id beyond new epoch"
            self.n_tombstoned = int(self._tombstone.sum())


@dataclasses.dataclass
class UpdateRequest:
    """One update op submitted to the lane's SQ.  ``deadline`` (absolute
    clock time, None = best-effort) mirrors the search lane's admission
    control: an op the poller reaches past its deadline is shed, not
    applied late — freshness SLOs fail fast under storms instead of
    silently applying minutes-stale ops."""
    req_id: int
    op: str                            # "insert" | "delete"
    vecs: Optional[np.ndarray]         # (n, D) for insert
    ids: Optional[np.ndarray]          # (n,) for delete
    arrival: float = 0.0
    deadline: Optional[float] = None   # absolute; None = best-effort


@dataclasses.dataclass
class UpdateCompletion:
    """CQ entry.  status: "ok" | "rebuild_due" (insert rejected, buffer
    full — resubmit after the swap) | "shed" (deadline passed before the
    poller reached the op) | "covered" (delete dropped: every id was
    already tombstoned by a newer delete)."""
    req_id: int
    op: str
    status: str
    ids: Optional[np.ndarray]          # minted (insert) / affected (delete)
    seq: int                           # publish seq that first contains it
    submitted: float
    applied: float


@dataclasses.dataclass
class UpdateLaneStats:
    submitted: int = 0
    rejected: int = 0                  # SQ-full back-pressure
    applied_inserts: int = 0           # vectors, not requests
    applied_deletes: int = 0
    rejected_full: int = 0             # delta buffer full (rebuild due)
    shed_deadline: int = 0             # ops past deadline at pump time
    covered_deletes: int = 0           # deletes dropped (already tombstoned)
    pumps: int = 0
    publishes: int = 0
    visible: int = 0                   # ops stamped visible by a harvest
    visibility_dropped: int = 0        # pending stamps evicted (no search
                                       # traffic drained them)


class UpdateLane:
    """Bounded SQ/CQ pair for insert/delete ops, drained by the engine.

    ``pump`` applies up to ``budget`` ops against the CURRENT state (the
    lane retargets to the new epoch's state at swap), publishes once, and
    parks the applied ops until a search-batch harvest covers their publish
    seq — at which point ``mark_visible`` stamps the measured
    insert-to-visible interval into ``visible_log``.
    """

    def __init__(self, state: LiveFreshState, sq_depth: int = 4096,
                 clock=time.monotonic, obs: Optional[Observability] = None):
        self.state = state
        self.qp = QueuePair(sq_depth=sq_depth)
        self.clock = clock
        self.stats = UpdateLaneStats()
        self.obs = obs if obs is not None else Observability.off()
        # visibility intervals stream into bounded histograms (the daemon
        # runs for days); visible_log keeps only a RECENT raw window for
        # tests and spot checks — stats come from the histograms
        self._h_vis = {
            "insert": self.obs.metrics.histogram("ingest.insert_to_visible_s"),
            "delete": self.obs.metrics.histogram("ingest.delete_to_visible_s"),
        }
        self._req_ids = itertools.count(1)
        self._pending_vis: list = []           # applied, awaiting coverage
        self.visible_log: list = []            # (req_id, op, visible_s)
        self._vis_cap = 1 << 16                # pending-ledger bound
        self._raw_cap = 1024                   # recent raw visibility samples

    # -- client side -------------------------------------------------------
    def submit_insert(self, vecs: np.ndarray, block: bool = False,
                      deadline_s: Optional[float] = None) -> int:
        now = self.clock()
        req = UpdateRequest(req_id=next(self._req_ids), op="insert",
                            vecs=np.asarray(vecs, np.float32), ids=None,
                            arrival=now,
                            deadline=None if deadline_s is None
                            else now + deadline_s)
        return self._submit(req, block)

    def submit_delete(self, ids: np.ndarray, block: bool = False,
                      deadline_s: Optional[float] = None) -> int:
        now = self.clock()
        req = UpdateRequest(req_id=next(self._req_ids), op="delete",
                            vecs=None, ids=np.asarray(ids, np.int64),
                            arrival=now,
                            deadline=None if deadline_s is None
                            else now + deadline_s)
        return self._submit(req, block)

    def _submit(self, req: UpdateRequest, block: bool) -> int:
        if not self.qp.submit(req, block=block):
            self.stats.rejected += 1
            return -1
        self.stats.submitted += 1
        return req.req_id

    # -- poller side -------------------------------------------------------
    def pump(self, now: float, budget: int = 0) -> int:
        """Apply up to ``budget`` ops (0 = all pending) and publish once.
        Returns the number of ops applied.  Runs on the engine poller
        thread — one publish per pump keeps the device_put cost per cycle
        bounded no matter the storm size."""
        ops = self.qp.pop_submissions(budget)
        if not ops:
            return 0
        comps: list[UpdateCompletion] = []
        applied = []
        # lock-then-recheck: a concurrent epoch swap retargets the lane
        # UNDER the old state's lock, so acquiring a state's lock and then
        # finding it still current guarantees no swap lands mid-apply —
        # without the recheck, ops could be applied to a retired state
        # (lost inserts, duplicate global ids)
        while True:
            st = self.state
            st.lock.acquire()
            if st is self.state:
                break
            st.lock.release()
        try:
            seq_next = st.seq + 1              # the publish these ops join
            for req in ops:
                if req.deadline is not None and now > req.deadline:
                    # deadline admission, mirroring the search lane: an op
                    # the poller reached too late is failed fast — the
                    # client learns its freshness SLO broke instead of the
                    # op applying arbitrarily late
                    self.stats.shed_deadline += 1
                    comps.append(UpdateCompletion(
                        req_id=req.req_id, op=req.op, status="shed",
                        ids=None, seq=-1,
                        submitted=req.arrival, applied=now))
                    continue
                if req.op == "delete" and st.already_covered(req.ids):
                    # a newer tombstone already covers every id: dropping
                    # the delete is semantically free and saves a publish
                    self.stats.covered_deletes += 1
                    comps.append(UpdateCompletion(
                        req_id=req.req_id, op=req.op, status="covered",
                        ids=req.ids, seq=st.seq,
                        submitted=req.arrival, applied=now))
                    continue
                if req.op == "insert":
                    try:
                        ids = st.insert(req.vecs)
                    except BufferError:
                        self.stats.rejected_full += 1
                        comps.append(UpdateCompletion(
                            req_id=req.req_id, op=req.op,
                            status="rebuild_due", ids=None, seq=-1,
                            submitted=req.arrival, applied=now))
                        continue
                    self.stats.applied_inserts += len(ids)
                else:
                    st.delete(req.ids)
                    ids = req.ids
                    self.stats.applied_deletes += len(ids)
                c = UpdateCompletion(
                    req_id=req.req_id, op=req.op, status="ok", ids=ids,
                    seq=seq_next, submitted=req.arrival, applied=now)
                comps.append(c)
                applied.append(c)
            if applied:
                st.publish()
                self.stats.publishes += 1
        finally:
            st.lock.release()
        self.stats.pumps += 1
        self._pending_vis.extend(applied)
        if len(self._pending_vis) > self._vis_cap:
            # an ingest-only lane (no search traffic harvesting batches)
            # must not grow the visibility ledger without bound; dropped
            # entries are counted, not silently forgotten
            drop = len(self._pending_vis) - self._vis_cap
            self.stats.visibility_dropped += drop
            del self._pending_vis[:drop]
        self.qp.complete(comps)
        return len(applied)

    def mark_visible(self, covered_seq: int, at: float) -> int:
        """A search batch that captured snapshot ``covered_seq`` harvested
        at ``at``: every applied op with seq <= covered_seq is now
        client-visible.  Poller-thread only (same thread as pump)."""
        if not self._pending_vis:
            return 0
        still, done = [], 0
        for c in self._pending_vis:
            if c.seq <= covered_seq:
                dt = at - c.submitted
                self.visible_log.append((c.req_id, c.op, dt))
                self._h_vis[c.op].observe(dt)
                done += 1
            else:
                still.append(c)
        self._pending_vis = still
        self.stats.visible += done
        if len(self.visible_log) > self._raw_cap:
            del self.visible_log[: len(self.visible_log) - self._raw_cap // 2]
        return done

    def retarget(self, new_state: LiveFreshState) -> None:
        """Point the lane at the new epoch's state (swap time; the caller
        holds both states' locks via the scheduler)."""
        self.state = new_state

    def visibility_stats(self) -> dict:
        # percentiles come from the STREAMING histograms (full run, bounded
        # memory), not the truncated raw window — same keys as the old
        # latency_percentiles dict
        return {
            "insert_to_visible": self._h_vis["insert"].summary_ms(),
            "delete_to_visible": self._h_vis["delete"].summary_ms(),
            "n_visible": self.stats.visible,
            "n_pending": len(self._pending_vis),
        }
