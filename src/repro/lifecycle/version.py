"""Epoch-tagged index versions + the atomic swap protocol (§6.2/§6.3).

A serving index that rebuilds under live traffic needs version management
(the distributed-storage ANNS line in PAPERS.md): queries must never observe
a half-swapped index, and the old version's resources must not be freed
while a batch still scans it.  The protocol here:

* every deployed index version is an :class:`Epoch` with a monotonically
  increasing id and its own pipeline + freshness state;
* the engine *routes* each micro-batch to the current epoch at batch
  formation, taking an in-flight reference — the batch carries its epoch to
  harvest, so a swap mid-flight cannot re-route it;
* ``swap`` publishes the new epoch atomically (under the engine's swap
  lock): new batches route to the new epoch, in-flight batches finish on
  the old one;
* the old epoch **retires** when its last in-flight batch harvests — only
  then is its host posting tier released (storage/host_tier.py ``release``)
  — so "zero dropped batches across a swap" is structural, and the epoch
  record counts every batch to prove it.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Optional


@dataclasses.dataclass
class EpochRecord:
    """Audit row kept in VersionManager.history for every epoch."""
    name: str
    eid: int
    created_at: float
    activated_at: float = 0.0
    retired_at: float = 0.0            # swap called: no new batches route here
    finalized_at: float = 0.0          # last in-flight batch harvested
    batches: int = 0                   # micro-batches served by this epoch


class Epoch:
    """One deployed index version: pipeline + freshness state + refcount."""

    def __init__(self, name: str, eid: int, pipeline, fresh=None,
                 clock=time.monotonic):
        self.name = name
        self.eid = eid
        self.pipeline = pipeline
        self.fresh = fresh             # LiveFreshState (None = static index)
        self.clock = clock
        self.record = EpochRecord(name=name, eid=eid, created_at=clock())
        self.retired = False
        self.finalized = threading.Event()
        self._inflight = 0
        self._lock = threading.Lock()
        if fresh is not None and getattr(pipeline, "fresh_source", None) is None:
            # bind the pipeline's per-batch snapshot capture to THIS epoch's
            # state: in-flight batches on a retired epoch keep reading the
            # frozen old state, never the new epoch's (swap semantics)
            pipeline.fresh_source = fresh.snapshot

    def acquire(self) -> "Epoch":
        with self._lock:
            self._inflight += 1
        return self

    def release(self) -> None:
        with self._lock:
            self._inflight -= 1
            self.record.batches += 1
            done = self.retired and self._inflight == 0
        if done:
            self._finalize()

    def retire(self) -> None:
        with self._lock:
            self.retired = True
            self.record.retired_at = self.clock()
            done = self._inflight == 0
        if done:
            self._finalize()

    def _finalize(self) -> None:
        if self.finalized.is_set():
            return
        self.record.finalized_at = self.clock()
        tier = getattr(self.pipeline, "tier", None)
        if tier is not None and hasattr(tier, "release"):
            tier.release()
        self.finalized.set()

    @property
    def inflight(self) -> int:
        with self._lock:
            return self._inflight


class VersionManager:
    """Named epochs + atomic swap, bound to a ServeEngine.

    The engine consults ``route`` at micro-batch formation (taking the
    in-flight reference) and calls ``harvested`` at completion; everything
    else — deploy, swap, retirement — happens here.
    """

    # retained epoch records: a serving daemon swaps epochs for as long
    # as it lives, so the history is a recent window, not the full run
    # (the epoch_swap trace instants are the durable record)
    MAX_HISTORY = 256

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self._epochs: dict[str, Epoch] = {}
        self._lock = threading.Lock()
        self._eids = itertools.count(1)
        self.history: list[EpochRecord] = []
        self.engine = None

    def _remember(self, record: EpochRecord) -> None:
        # call with self._lock held
        self.history.append(record)
        del self.history[: -self.MAX_HISTORY]

    def bind(self, engine) -> "VersionManager":
        """Attach to a ServeEngine: its poller routes through this manager
        for every deployed name."""
        self.engine = engine
        engine.versions = self
        return self

    def names(self) -> list[str]:
        with self._lock:
            return list(self._epochs)

    def current(self, name: str) -> Epoch:
        with self._lock:
            return self._epochs[name]

    def deploy(self, name: str, pipeline, fresh=None) -> Epoch:
        """Install the first epoch of ``name`` (no predecessor to retire)."""
        ep = Epoch(name, next(self._eids), pipeline, fresh, clock=self.clock)
        ep.record.activated_at = self.clock()
        with self._lock:
            assert name not in self._epochs, f"{name!r} already deployed"
            self._epochs[name] = ep
            self._remember(ep.record)
        if self.engine is not None:
            self.engine.swap_pipeline(name, pipeline)
        return ep

    def route(self, name: str) -> Optional[Epoch]:
        """Engine side: current epoch with an in-flight ref taken, or None
        for names this manager does not own."""
        with self._lock:
            ep = self._epochs.get(name)
            return None if ep is None else ep.acquire()

    def harvested(self, epoch: Epoch) -> None:
        epoch.release()

    def swap(self, name: str, pipeline, fresh=None) -> tuple[Epoch, Epoch]:
        """Atomic swap: publish the new epoch, retire the old.

        Returns (old, new).  In-flight batches hold their epoch reference,
        so the old epoch finalizes (tier released, record stamped) only
        after its last batch harvests — callers that must block on that use
        ``old.finalized.wait()``."""
        new = Epoch(name, next(self._eids), pipeline, fresh, clock=self.clock)
        with self._lock:
            old = self._epochs[name]
            self._epochs[name] = new
            new.record.activated_at = self.clock()
            self._remember(new.record)
        if self.engine is not None:
            self.engine.swap_pipeline(name, pipeline)
        old.retire()
        return old, new
