"""Centroid-drift monitor — the quality half of the rebuild trigger.

The fill/tombstone thresholds in :class:`~repro.lifecycle.rebuild.
RebuildPolicy` are CAPACITY triggers: they fire when the delta buffer is
mechanically full, regardless of whether the partition still fits the
data.  But a drifting insert stream degrades recall long before the
buffer fills — new vectors land in clusters whose centroid no longer
describes them, the closure assignment spreads them across more replicas,
and nprobe has to grow to hold recall.  This module watches for exactly
that: per-cluster **mean-residual shift** of the delta inserts against
the owning centroid, normalized by the cluster's observed residual scale.

For each insert batch the monitor accumulates, per owning cluster,
``sum(x - c)``, ``sum(||x - c||)`` and a count; a cluster's *shift* is
``||mean residual|| / mean residual norm`` — 0 when inserts scatter
isotropically around the centroid (the stationary case), → 1 when they
pile up on one side (the centroid is no longer where its data is).  When
enough clusters drift past the threshold, :meth:`advisory` returns a
reason string that :meth:`RebuildScheduler.due` treats as a rebuild
trigger, and the transition lands as a ``rebuild_advisory`` instant on
the ``lifecycle`` trace track (hysteresis: one instant per excursion,
not one per poll).

Gauges (bounded label sets): ``drift.max_shift``,
``drift.clusters_drifted``, ``drift.observed``, plus the live freshness
ratios ``lifecycle.fill_frac`` / ``lifecycle.tombstone_frac`` when
:meth:`observe_state` is fed the lane state.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np


class DriftMonitor:
    """Streaming per-cluster insert-drift detector (see module doc).

    ``centroids`` is the (C, D) array the CURRENT epoch was built
    against; :meth:`reset` re-arms the accumulators after a rebuild
    (same centroids, fresh delta).  ``observe`` takes the insert batch
    plus optional owning cluster ids — when omitted, vectors are
    assigned to their nearest centroid here (exact argmin; insert
    batches are small and off the search path).
    """

    def __init__(self, centroids: np.ndarray, *, metrics=None, trace=None,
                 shift_threshold: float = 0.6, min_inserts: int = 32,
                 max_drifted: int = 1):
        self.centroids = np.ascontiguousarray(centroids, np.float32)
        self.metrics = metrics
        self.trace = trace
        self.shift_threshold = float(shift_threshold)
        self.min_inserts = int(min_inserts)
        self.max_drifted = int(max_drifted)
        c = self.centroids.shape[0]
        self._lock = threading.Lock()
        self._resid_sum = np.zeros_like(self.centroids)       # (C, D)
        self._resid_norm = np.zeros(c, np.float64)            # sum ||x-c||
        self._count = np.zeros(c, np.int64)
        self._advisory_live = False       # hysteresis latch for the instant
        self.advisories = 0

    # -- ingestion ---------------------------------------------------------
    def observe(self, vecs: np.ndarray,
                cids: Optional[np.ndarray] = None) -> None:
        """Fold one insert batch into the per-cluster residual stats."""
        x = np.asarray(vecs, np.float32).reshape(-1, self.centroids.shape[1])
        if x.shape[0] == 0:
            return
        if cids is None:
            d = (np.einsum("bd,bd->b", x, x)[:, None]
                 - 2.0 * (x @ self.centroids.T)
                 + np.einsum("cd,cd->c", self.centroids, self.centroids))
            cids = np.argmin(d, axis=1)
        cids = np.asarray(cids, np.int64).ravel()
        resid = x - self.centroids[cids]
        norms = np.linalg.norm(resid, axis=1)
        with self._lock:
            np.add.at(self._resid_sum, cids, resid)
            np.add.at(self._resid_norm, cids, norms)
            np.add.at(self._count, cids, 1)

    def observe_state(self, state) -> None:
        """Mirror the lane's capacity ratios into gauges (the operator's
        'how close is the NEXT capacity-triggered rebuild?' view)."""
        if self.metrics is None:
            return
        self.metrics.gauge("lifecycle.fill_frac").set(state.fill_frac)
        self.metrics.gauge("lifecycle.tombstone_frac").set(
            state.tombstone_frac)

    # -- readout -----------------------------------------------------------
    def shifts(self) -> np.ndarray:
        """(C,) per-cluster shift in [0, 1]; 0 for clusters with fewer
        than ``min_inserts`` observations (no evidence, no signal)."""
        with self._lock:
            cnt = self._count.copy()
            rs = self._resid_sum.copy()
            rn = self._resid_norm.copy()
        out = np.zeros(cnt.shape[0], np.float64)
        live = cnt >= self.min_inserts
        if live.any():
            mean_norm = np.linalg.norm(
                rs[live] / cnt[live, None], axis=1)
            scale = rn[live] / cnt[live]
            out[live] = mean_norm / np.maximum(scale, 1e-12)
        return out

    def severity(self) -> np.ndarray:
        """(C,) rebuild-priority score: assign-mass x shift.

        Shift alone mis-ranks: a 0.9 shift on a cluster absorbing 2%
        of the insert stream matters less than a 0.7 shift on one
        absorbing half of it.  Weighting by the cluster's share of
        observed inserts makes the ranking reflect how much of the
        delta a rebuild would actually re-home — and makes the order
        deterministic for equal shifts (mass breaks the tie; cluster
        id breaks exact severity ties, see :meth:`_rank`)."""
        s = self.shifts()
        with self._lock:
            cnt = self._count.copy()
        total = max(int(cnt.sum()), 1)
        return s * (cnt.astype(np.float64) / total)

    @staticmethod
    def _rank(sev: np.ndarray, top: int = 8) -> np.ndarray:
        """Descending severity; ascending cluster id on exact ties —
        the same inputs always rank the same way (np.argsort alone is
        not stable across tied float scores)."""
        order = np.lexsort((np.arange(sev.shape[0]), -sev))
        return order[:top]

    def advisory(self) -> Optional[str]:
        """Rebuild-advisory reason when drifted clusters exceed the
        policy, else None.  Emits one ``rebuild_advisory`` trace instant
        per excursion (latched until the signal clears or :meth:`reset`
        re-arms it)."""
        s = self.shifts()
        drifted = int((s >= self.shift_threshold).sum())
        mx = float(s.max()) if s.size else 0.0
        if self.metrics is not None:
            self.metrics.gauge("drift.max_shift").set(mx)
            self.metrics.gauge("drift.clusters_drifted").set(drifted)
            self.metrics.gauge("drift.observed").set(int(self._count.sum()))
        if drifted >= self.max_drifted:
            if not self._advisory_live:
                self._advisory_live = True
                self.advisories += 1
                if self.trace is not None:
                    sev = self.severity()
                    top = int(self._rank(sev, top=1)[0])
                    self.trace.instant(
                        "rebuild_advisory", track="lifecycle",
                        args={"clusters_drifted": drifted,
                              "max_shift": round(mx, 4),
                              "top_cluster": top,
                              "top_severity": round(float(sev[top]), 4)})
            return f"drift:{drifted}"
        self._advisory_live = False
        return None

    def summary(self) -> dict:
        """JSON-able rollup for health snapshots; ``top`` is ranked by
        severity (assign-mass x shift), deterministically."""
        s = self.shifts()
        sev = self.severity()
        order = self._rank(sev, top=8)
        with self._lock:
            total = int(self._count.sum())
        return {
            "observed": total,
            "max_shift": float(s.max()) if s.size else 0.0,
            "clusters_drifted":
                int((s >= self.shift_threshold).sum()),
            "threshold": self.shift_threshold,
            "advisories": self.advisories,
            "top": [{"cluster": int(c), "shift": float(s[c]),
                     "severity": float(sev[c]),
                     "inserts": int(self._count[c])}
                    for c in order if s[c] > 0.0],
        }

    def reset(self, centroids: Optional[np.ndarray] = None) -> None:
        """Re-arm after a rebuild folded the observed delta (optionally
        against the new epoch's centroids)."""
        with self._lock:
            if centroids is not None:
                self.centroids = np.ascontiguousarray(centroids,
                                                      np.float32)
                self._resid_sum = np.zeros_like(self.centroids)
                self._resid_norm = np.zeros(self.centroids.shape[0],
                                            np.float64)
                self._count = np.zeros(self.centroids.shape[0], np.int64)
            else:
                self._resid_sum[:] = 0.0
                self._resid_norm[:] = 0.0
                self._count[:] = 0
            self._advisory_live = False
