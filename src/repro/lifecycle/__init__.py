"""Index lifecycle runtime — the index as a *living* object under traffic.

The paper's deployment (§6.2–§6.3) serves from a periodically-rebuilt main
index plus an in-memory delta and a tombstone bitmap.  This package wires
that contract into the PR 2 serving runtime:

=====================  ====================================================
paper §6.2/§6.3 piece  lifecycle module
=====================  ====================================================
update stream beside   :mod:`repro.lifecycle.ingest` — a second bounded
search traffic         SQ/CQ queue pair drained between search batches
                       (budgeted, so storms can't starve search), applied
                       to the live delta/tombstone state, with *measured*
                       insert-to-visible stamps
periodic delta-folding :mod:`repro.lifecycle.rebuild` — threshold-triggered
rebuilds               background rebuilds that restream only changed/new
                       shards (content-hash manifest) and fold tombstones
                       at the posting build
atomic version swap    :mod:`repro.lifecycle.version` — epoch-tagged index
                       versions; in-flight batches finish on the old epoch,
                       which retires (and frees its posting tier) when its
                       last batch harvests
=====================  ====================================================
"""
from .drift import DriftMonitor
from .ingest import (
    FreshSnapshot,
    LiveFreshState,
    UpdateCompletion,
    UpdateLane,
    UpdateLaneStats,
    UpdateRequest,
)
from .rebuild import (
    CorpusStore,
    RebuildPolicy,
    RebuildReport,
    RebuildScheduler,
    delta_build,
    load_manifest,
    save_manifest,
)
from .version import Epoch, EpochRecord, VersionManager
