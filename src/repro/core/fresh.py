"""Freshness layer — the paper's §6.2 deployment design for updates.

The paper rejects in-place updates (SPFresh/OdinANN-class systems cannot
sustain 25-30 KOPS of updates concurrent with search) and instead deploys:

  * the main SSD-resident clustered index, periodically REBUILT;
  * recent insertions in an auxiliary in-memory index;
  * deletions tracked by a tombstone bitmap;
  * queries search both, merge candidates, filter tombstones;
  * the rebuild folds the delta + drops tombstones, then swaps atomically.

``FreshIndex`` implements exactly that contract.  The auxiliary index here
is a brute-force buffer (at production delta sizes — minutes of inserts —
brute force on-device IS the right auxiliary structure for a TPU: one
matmul; the paper's HNSW/IVF choice is a CPU-ism).  All search paths are
jit-compatible at fixed buffer capacity; host-side state (fill counters)
lives outside jit like any serving system's.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from .distance import merge_candidate_topk, squared_l2, topk_smallest
from .ivf import IVFIndex, search_flat


@functools.partial(jax.jit, static_argnames=("k",))
def merge_fresh(
    main_d: jax.Array,      # (B, >=k) main-path candidate distances
    main_i: jax.Array,      # (B, >=k) main-path candidate ids
    queries: jax.Array,     # (B, D)
    delta_vecs: jax.Array,  # (capacity, D) delta buffer payload
    delta_ids: jax.Array,   # (capacity,) int32, -1 = empty slot
    tombstone: jax.Array,   # (id_capacity,) bool
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """THE freshness merge (§6.2): main candidates + delta brute force,
    tombstones filtered at the merge.  Single definition shared by
    ``FreshIndex.search``, the serve_leveled merged path, and the serving
    pipeline's delta-aware harvest — the three consumers cannot drift.

    Over-fetches k from the delta side so tombstoned results cannot starve
    the merge; ids outside the tombstone bitmap are clipped (guard, not a
    path — every live id fits the epoch's id_capacity by construction)."""
    d_delta = squared_l2(queries, delta_vecs)               # (B, cap)
    live_slot = delta_ids >= 0
    d_delta = jnp.where(live_slot[None, :], d_delta, jnp.inf)
    dd, pos = topk_smallest(d_delta, min(k, delta_vecs.shape[0]))
    di = delta_ids[pos]
    alld = jnp.concatenate([main_d, dd], axis=1)
    alli = jnp.concatenate([main_i, di], axis=1)
    dead = tombstone[jnp.clip(alli, 0, tombstone.shape[0] - 1)] | (alli < 0)
    alld = jnp.where(dead, jnp.inf, alld)
    return merge_candidate_topk(alld, alli, k)


@dataclasses.dataclass
class FreshIndex:
    main: IVFIndex
    capacity: int                    # delta-buffer slots
    n_total: int                     # id space size of the main index
    delta_vecs: jax.Array = None     # (capacity, D) f32
    delta_ids: jax.Array = None      # (capacity,) int32, -1 = empty
    tombstone: jax.Array = None      # (n_total + capacity,) bool
    fill: int = 0
    next_id: int = 0

    def __post_init__(self):
        d = self.main.dim
        if self.delta_vecs is None:
            self.delta_vecs = jnp.zeros((self.capacity, d), jnp.float32)
        if self.delta_ids is None:
            self.delta_ids = jnp.full((self.capacity,), -1, jnp.int32)
        if self.tombstone is None:
            self.tombstone = jnp.zeros((self.n_total + self.capacity,), bool)
        self.next_id = max(self.next_id, self.n_total)

    # -- updates (host-side bookkeeping + functional array updates) ----------
    def insert(self, vecs: np.ndarray) -> np.ndarray:
        """Append vectors to the delta buffer; returns their new ids.
        Raises when the buffer is full — the signal to trigger a rebuild
        (the paper's hourly/daily cadence)."""
        n = vecs.shape[0]
        if self.fill + n > self.capacity:
            raise BufferError(
                f"delta buffer full ({self.fill}+{n}>{self.capacity}): rebuild due")
        ids = np.arange(self.next_id, self.next_id + n, dtype=np.int32)
        self.delta_vecs = jax.lax.dynamic_update_slice(
            self.delta_vecs, jnp.asarray(vecs, jnp.float32), (self.fill, 0))
        self.delta_ids = jax.lax.dynamic_update_slice(
            self.delta_ids, jnp.asarray(ids), (self.fill,))
        self.fill += n
        self.next_id += n
        return ids

    def delete(self, ids: np.ndarray) -> None:
        self.tombstone = self.tombstone.at[jnp.asarray(ids)].set(True)

    # -- search ---------------------------------------------------------------
    def search(self, queries: jax.Array, k: int, nprobe: int):
        """Merged search: main IVF + delta brute force, tombstones filtered.

        Returns (dists (B,k), ids (B,k)).  Over-fetches k from each side so
        tombstoned results cannot starve the merge."""
        d_main, i_main = search_flat(self.main, queries, k, nprobe)
        return merge_fresh(d_main, i_main, queries,
                           self.delta_vecs, self.delta_ids, self.tombstone, k)

    def search_leveled(self, llsp_params, queries, k: int, cfg, pad: int = 64):
        """The production merged path: main candidates through
        ``serve_leveled`` (GBDT routing + per-level compiled fused-topk
        scan), then the same freshness merge as :meth:`search` — delta
        results folded in, tombstoned main AND delta ids filtered at the
        merge.  Returns (dists (B, k), ids (B, k)) numpy arrays."""
        from .search import serve_leveled

        out = serve_leveled(self.main, llsp_params, queries,
                            np.full((len(queries),), k, np.int32), cfg,
                            pad=pad)
        d, i = merge_fresh(
            jnp.asarray(out["dists"]), jnp.asarray(out["ids"]),
            jnp.asarray(np.asarray(queries, np.float32)),
            self.delta_vecs, self.delta_ids, self.tombstone, k)
        return np.asarray(d), np.asarray(i)

    # -- rebuild (fold delta + drop tombstones, atomically swap) -------------
    def fold_corpus(self, x_main: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Materialize the rebuild corpus: live main vectors + live delta.
        Returns (vectors, their ids in the old id space)."""
        tomb = np.asarray(self.tombstone)
        live_main = np.nonzero(~tomb[: self.n_total])[0]
        dv = np.asarray(self.delta_vecs)[: self.fill]
        di = np.asarray(self.delta_ids)[: self.fill]
        live_delta = ~tomb[di]
        vecs = np.concatenate([x_main[live_main], dv[live_delta]])
        ids = np.concatenate([live_main, di[live_delta]])
        return vecs.astype(np.float32), ids.astype(np.int32)


def rebuild(fresh: FreshIndex, x_main: np.ndarray, build_cfg, workdir: str):
    """Daily-rebuild flow: fold, rebuild with the 3-stage pipeline, swap.

    Returns (new FreshIndex over a compacted id space, id_map old->new)."""
    from repro.build.pipeline import build_index

    vecs, old_ids = fresh.fold_corpus(x_main)
    index, _, _ = build_index(vecs, build_cfg, workdir)
    new = FreshIndex(main=index, capacity=fresh.capacity, n_total=vecs.shape[0])
    return new, old_ids, vecs
