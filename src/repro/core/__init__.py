from .ivf import IVFIndex, brute_force_topk, build_postings, search_flat
from .search import (
    SearchConfig,
    make_sharded_serve,
    make_sharded_serve_quantized,
    serve_leveled,
    serve_step,
)
from .llsp import LLSPConfig, LLSPParams, train_llsp
from .gbdt import GBDTParams, GBDTRegressor
from .quantize import QuantizedPostings, quantize_postings
