"""Helmsman online search engine (paper Fig. 8 left / Fig. 11).

Per query batch:
  1. router GBDT picks the level (max nprobe)           [LLSP]
  2. centroid scan returns the nmax nearest centroids   [MXU brute force or
     two-level group quantizer — TPU stand-in for the centroid graph]
  3. level pruning GBDT refines nprobe                  [LLSP]
  4. one fused batched posting scan                     [ivf_scan Pallas kernel
     — the "single doorbell per batch" path]
  5. dedup + global top-k merge                         [closure duplicates]

Pruning modes: "llsp" (paper's contribution), "fixed" (SPANN Eq. 1 baseline),
"none" (scan all nmax).  The sharded engine stripes clusters over the
``model`` mesh axis and merges per-shard top-k via all_gather — the multi-SSD
array + frontend merge of Fig. 2a/10.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import itertools
import weakref
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import llsp as llsp_mod
from .distance import dedup_topk, merge_candidate_topk, squared_l2, topk_smallest
from .ivf import IVFIndex
from .spann_rules import fixed_eps_nprobe
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    k: int = 10
    nprobe_max: int = 64          # == LLSP nmax when pruning == "llsp"
    pruning: str = "none"         # "llsp" | "fixed" | "none"
    eps: float = 0.12             # fixed-eps baseline knob (Eq. 1)
    n_ratio: int = 32
    use_kernel: bool = True       # fused Pallas scan vs jnp gather
    two_level: bool = False       # group quantizer for the centroid scan
    n_groups_probe: int = 8
    shard_centroids: bool = False # perf: centroid scan sharded over `model`
                                  # (each shard scans C/TP centroids, then one
                                  # tiny (B, nmax) all-gather + re-rank) —
                                  # removes the TP-fold redundant scan
    fused_topk: bool = True       # candidate-compressed scan: the kernel (or
                                  # its oracle) emits (B, ~2k) candidates, not
                                  # (B, P, L) distances — O(P*L/k) less HBM
                                  # writeback.  False = legacy full-distance
                                  # path (kept for A/B benchmarking).
    n_cand: int = 0               # candidates per query the scan stage keeps
                                  # (0 = auto: ~2k rounded up to a lane
                                  # multiple).  Candidates are unique-by-id,
                                  # so n_cand >= k guarantees exact parity
                                  # with the legacy dedup-top-k.
    tier: str = "f32"             # first-pass payload: "f32" scans
                                  # index.postings; "q8" scans the attached
                                  # int8-residual payload (index.q8/qscale/
                                  # qnorm2, see core.quantize.attach_quantized)
                                  # at 1/4 the posting bytes.  Exact distances
                                  # come back via the flash-tier re-rank
                                  # (runtime/pipeline.py) when enabled.


def _auto_ncand(k: int) -> int:
    """Default candidate width: ~2k, padded to a multiple of 8 lanes."""
    return -(-max(2 * k, 16) // 8) * 8


def _fused_scan_candidates(cfg: "SearchConfig", kernel_call, ref_call):
    """Shared candidate-compressed dispatch: run the scan stage at width
    n_cand (kernel or jnp oracle per cfg.use_kernel), merge to cfg.k.

    ``kernel_call`` / ``ref_call``: callables taking the candidate width k2
    and returning ((B, k2) dists, (B, k2) ids).  Single definition so the
    f32 and quantized engines can't drift apart.
    """
    k2 = cfg.n_cand or _auto_ncand(cfg.k)
    cd, ci = kernel_call(k2) if cfg.use_kernel else ref_call(k2)
    return merge_candidate_topk(cd, ci, cfg.k)


def centroid_scan(
    index: IVFIndex, queries: jax.Array, nmax: int, cfg: SearchConfig
) -> tuple[jax.Array, jax.Array]:
    """Top-nmax centroids: (cdists (B, nmax) ascending, cids (B, nmax))."""
    if cfg.two_level and index.group_centroids is not None:
        gd = squared_l2(queries, index.group_centroids)            # (B, G)
        _, gsel = topk_smallest(gd, cfg.n_groups_probe)            # (B, g)
        cand = index.group_members[gsel]                           # (B, g, Cg)
        b = queries.shape[0]
        cand = cand.reshape(b, -1)                                 # (B, g*Cg)
        cvecs = index.centroids[jnp.maximum(cand, 0)]              # (B, M, D)
        d = jnp.sum((cvecs - queries[:, None, :]) ** 2, axis=-1)
        d = jnp.where(cand < 0, jnp.inf, d)
        vals, pos = topk_smallest(d, min(nmax, d.shape[1]))
        cids = jnp.take_along_axis(cand, pos, axis=1)
        if cids.shape[1] < nmax:  # pad (tiny-group configs)
            padn = nmax - cids.shape[1]
            cids = jnp.pad(cids, ((0, 0), (0, padn)), constant_values=-1)
            vals = jnp.pad(vals, ((0, 0), (0, padn)), constant_values=jnp.inf)
        return vals, cids
    d = squared_l2(queries, index.centroids)
    vals, cids = topk_smallest(d, nmax)
    return vals, cids


def decide_nprobe(
    cfg: SearchConfig,
    llsp_params: Optional[llsp_mod.LLSPParams],
    queries: jax.Array,
    topk_req: jax.Array,
    cdists: jax.Array,
) -> jax.Array:
    """Per-query nprobe (B,) int32 according to the pruning mode."""
    b = queries.shape[0]
    nmax = cdists.shape[1]
    if cfg.pruning == "none":
        return jnp.full((b,), nmax, dtype=jnp.int32)
    if cfg.pruning == "fixed":
        return fixed_eps_nprobe(cdists, cfg.eps, nmax)
    assert cfg.pruning == "llsp" and llsp_params is not None
    level = llsp_mod.route(llsp_params, queries, topk_req)
    return llsp_mod.prune(
        llsp_params, level, queries, topk_req, cdists, cfg.n_ratio
    )


def _scan_and_rank(
    index: IVFIndex,
    queries: jax.Array,
    cids: jax.Array,
    probe_mask: jax.Array,
    cfg: SearchConfig,
) -> tuple[jax.Array, jax.Array]:
    """Posting scan + top-k. Returns (dists (B,k), ids (B,k)).

    Default (cfg.fused_topk): the candidate-compressed path — the scan stage
    emits (B, n_cand) unique-by-id candidates (in-kernel top-k, in-kernel
    posting_ids resolution) and a cheap merge takes the final k.  Legacy: the
    scan writes (B, P, L) distances + a (B, P, L) id gather, then a global
    dedup-top-k double-argsorts over P*L elements.
    """
    b = queries.shape[0]
    k = cfg.k
    if cfg.tier == "q8":
        if index.q8 is None:
            raise ValueError(
                "SearchConfig(tier='q8') needs an index with the quantized "
                "payload attached — see core.quantize.attach_quantized")
        if cfg.fused_topk:
            from repro.kernels.ref import ivf_scan_q8_topk_ref

            return _fused_scan_candidates(
                cfg,
                lambda k2: kops.ivf_scan_q8_topk(
                    index.q8, index.qscale, index.qnorm2, index.centroids,
                    index.posting_ids, cids, probe_mask, queries, k2=k2),
                lambda k2: ivf_scan_q8_topk_ref(
                    index.q8, index.qscale, index.qnorm2, index.centroids,
                    index.posting_ids, cids, probe_mask, queries, k2),
            )
        from .quantize import QuantizedPostings, ivf_scan_quantized

        qp = QuantizedPostings(q8=index.q8, scale=index.qscale,
                               norm2=index.qnorm2)
        dists = ivf_scan_quantized(qp, index.centroids, cids, probe_mask,
                                   queries)
        ids = index.posting_ids[jnp.maximum(cids, 0)]
        dists = jnp.where(ids < 0, jnp.inf, dists)
        return dedup_topk(dists.reshape(b, -1), ids.reshape(b, -1), k)
    if cfg.fused_topk:
        from repro.kernels.ref import ivf_scan_topk_ref

        return _fused_scan_candidates(
            cfg,
            lambda k2: kops.ivf_scan_topk(
                index.postings, index.posting_ids, cids, probe_mask, queries,
                k2=k2),
            lambda k2: ivf_scan_topk_ref(
                index.postings, index.posting_ids, cids, probe_mask, queries,
                k2),
        )
    if cfg.use_kernel:
        dists = kops.ivf_scan(index.postings, cids, probe_mask, queries)
    else:
        from repro.kernels.ref import ivf_scan_ref

        dists = ivf_scan_ref(index.postings, cids, probe_mask, queries)
    ids = index.posting_ids[jnp.maximum(cids, 0)]                  # (B, P, L)
    dists = jnp.where(ids < 0, jnp.inf, dists)
    return dedup_topk(dists.reshape(b, -1), ids.reshape(b, -1), k)


def serve_step(
    index: IVFIndex,
    llsp_params: Optional[llsp_mod.LLSPParams],
    queries: jax.Array,
    topk_req: jax.Array,
    cfg: SearchConfig,
) -> dict:
    """Single-device search. Returns dict with ids, dists, nprobe."""
    nmax = cfg.nprobe_max
    cdists, cids = centroid_scan(index, queries, nmax, cfg)
    nprobe = decide_nprobe(cfg, llsp_params, queries, topk_req, cdists)
    probe_mask = (jnp.arange(nmax)[None, :] < nprobe[:, None]) & (cids >= 0)
    dists, ids = _scan_and_rank(index, queries, cids, probe_mask, cfg)
    return {"ids": ids, "dists": dists, "nprobe": nprobe}


# --------------------------------------------------------------------------
# leveled serving — the TPU-native payoff of the paper's LEVELING design
# --------------------------------------------------------------------------
# On CPUs the paper's per-query nprobe directly saves I/O; on TPUs shapes are
# static, so a masked scan still pays full compute for pruned probes.  The
# LLSP *levels* fix exactly this: each level is one compiled program with
# nprobe_max = that level's bound, the tiny GBDT router runs first, queries
# are bucketed by level (padded to `pad`), and each bucket runs its level's
# program.  Compute now scales with the routed level — leveling is not just
# a model-granularity choice, it is the static-shape mechanism.
# Cache keying: ``id(index)`` alone is unsafe — a freed-and-reallocated index
# can reuse the address and alias a stale compiled fn (stale shapes or, worse,
# silently-wrong donated buffers).  Each index object instead gets a monotonic
# token, validated through a weakref so an id() reuse mints a fresh token.
# The cache itself is LRU-bounded so long-lived serving processes that churn
# through indexes/configs don't grow it without bound.
_LEVEL_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_LEVEL_CACHE_MAX = 64
_INDEX_TOKENS: dict = {}          # id(index) -> (weakref, token)
_TOKEN_COUNTER = itertools.count()


def _index_token(index) -> int:
    """Monotonic identity token for an index object (id()-reuse safe)."""
    key = id(index)
    ent = _INDEX_TOKENS.get(key)
    if ent is not None and ent[0]() is index:
        return ent[1]
    if len(_INDEX_TOKENS) > 4 * _LEVEL_CACHE_MAX:     # prune dead entries
        for dead in [kid for kid, (r, _) in _INDEX_TOKENS.items()
                     if r() is None]:
            del _INDEX_TOKENS[dead]
    tok = next(_TOKEN_COUNTER)
    _INDEX_TOKENS[key] = (weakref.ref(index), tok)
    return tok


def _level_cache_lookup(key, make_fn):
    """LRU get-or-build on _LEVEL_CACHE."""
    fn = _LEVEL_CACHE.get(key)
    if fn is None:
        fn = make_fn()
        _LEVEL_CACHE[key] = fn
    _LEVEL_CACHE.move_to_end(key)
    while len(_LEVEL_CACHE) > _LEVEL_CACHE_MAX:
        _LEVEL_CACHE.popitem(last=False)
    return fn


def _serve_at_level(index, llsp_params, queries, topk_req, level_idx, bound, cfg):
    nmax_feat = max(bound, cfg.n_ratio + 1)   # pruner features need n_ratio+1
    cdists, cids = centroid_scan(index, queries, nmax_feat, cfg)
    level = jnp.full((queries.shape[0],), level_idx, jnp.int32)
    nprobe = llsp_mod.prune(
        llsp_params, level, queries, topk_req, cdists, cfg.n_ratio)
    nprobe = jnp.minimum(nprobe, bound)
    cids = cids[:, :bound]
    probe_mask = (jnp.arange(bound)[None, :] < nprobe[:, None]) & (cids >= 0)
    dists, ids = _scan_and_rank(index, queries, cids, probe_mask, cfg)
    return {"ids": ids, "dists": dists, "nprobe": nprobe}


def serve_leveled(
    index: IVFIndex,
    llsp_params: llsp_mod.LLSPParams,
    queries,
    topk_req,
    cfg: SearchConfig,
    pad: int = 64,
) -> dict:
    """Route on host, then run one level-specific compiled scan per bucket.

    Returns the same dict as serve_step; ``nprobe`` reflects the per-query
    pruner output.  Buckets are padded to multiples of ``pad`` so the jit
    cache stays small (one entry per (level, padded-size))."""
    import numpy as np

    q = np.asarray(queries, dtype=np.float32)
    tk = np.asarray(topk_req, dtype=np.int32)
    b = q.shape[0]
    lv = np.asarray(llsp_mod.route(llsp_params, jnp.asarray(q), jnp.asarray(tk)))
    bounds = np.asarray(llsp_params.levels)
    out_d = np.full((b, cfg.k), np.inf, np.float32)
    out_i = np.full((b, cfg.k), -1, np.int32)
    out_np = np.zeros((b,), np.int32)
    n_levels = int(bounds.shape[0])
    for li in range(n_levels):
        sel = np.nonzero(lv == li)[0]
        if sel.size == 0:
            continue
        padded = -(-sel.size // pad) * pad
        rows = np.concatenate([sel, np.full(padded - sel.size, sel[0])])
        # key carries everything baked into the compiled fn: the index
        # identity, level index AND its bound (a retrained LLSP can move the
        # bounds for the same index), batch padding, and the static cfg.
        # llsp weights are a traced argument, so they need no key entry.
        key = (_index_token(index), li, int(bounds[li]), padded, cfg)
        fn = _level_cache_lookup(key, lambda: jax.jit(functools.partial(
            _serve_at_level, level_idx=li, bound=int(bounds[li]), cfg=cfg)))
        res = fn(index, llsp_params, jnp.asarray(q[rows]), jnp.asarray(tk[rows]))
        out_d[sel] = np.asarray(res["dists"])[: sel.size]
        out_i[sel] = np.asarray(res["ids"])[: sel.size]
        out_np[sel] = np.asarray(res["nprobe"])[: sel.size]
    return {"ids": out_i, "dists": out_d, "nprobe": out_np, "levels": lv}


# --------------------------------------------------------------------------
# sharded engine — clusters striped over `model`, queries over data axes
# --------------------------------------------------------------------------
def make_sharded_serve(
    mesh,
    cfg: SearchConfig,
    *,
    batch_axes: tuple[str, ...] = ("data",),
    shard_axis: str = "model",
):
    """Build the shard_map'd serve function for the production mesh.

    Posting arrays are sharded on the cluster dim over ``shard_axis`` (each
    cluster fully resident on one shard = one contiguous SSD extent in the
    paper's layout); queries are sharded over ``batch_axes``; centroids and
    GBDT weights are replicated (the in-DRAM tier).  Per-shard top-k results
    are merged with one all_gather of k candidates — the Fig. 2a frontend.
    """
    n_shards = mesh.shape[shard_axis]
    bspec = P(batch_axes)

    def local_search(centroids, postings, posting_ids, llsp_params, queries, topk_req):
        shard = jax.lax.axis_index(shard_axis)
        c_local = postings.shape[0]
        lo = shard * c_local
        nmax = cfg.nprobe_max
        local_index = IVFIndex(centroids, postings, posting_ids)

        if cfg.shard_centroids:
            # each shard scans its own C/TP centroid slice (no redundancy);
            # merge with one tiny (B, nmax) all-gather + re-rank
            c_slice = centroids.shape[0]          # already the local slice
            d_loc = squared_l2(queries, centroids)
            k_loc = min(nmax, c_slice)
            dv, di = topk_smallest(d_loc, k_loc)
            di = di + shard * c_slice             # global centroid ids
            dv_all = jax.lax.all_gather(dv, shard_axis)   # (S, B, k_loc)
            di_all = jax.lax.all_gather(di, shard_axis)
            bq = queries.shape[0]
            dv_all = jnp.moveaxis(dv_all, 0, 1).reshape(bq, -1)
            di_all = jnp.moveaxis(di_all, 0, 1).reshape(bq, -1)
            cdists, pos = topk_smallest(dv_all, nmax)
            cids = jnp.take_along_axis(di_all, pos, axis=1)
        else:
            d = squared_l2(queries, centroids)
            cdists, cids = topk_smallest(d, nmax)
        nprobe = decide_nprobe(cfg, llsp_params, queries, topk_req, cdists)
        probe_mask = jnp.arange(nmax)[None, :] < nprobe[:, None]
        # restrict to clusters striped on this shard
        local_cids = cids - lo
        on_shard = (local_cids >= 0) & (local_cids < c_local)
        probe_mask = probe_mask & on_shard
        local_cids = jnp.clip(local_cids, 0, c_local - 1)
        dists_k, ids_k = _scan_and_rank(
            local_index, queries, local_cids, probe_mask, cfg
        )
        # merge across shards: gather each shard's k candidates, re-rank.
        # The all-gather already speaks the k-candidate format, so the merge
        # is over S*k = O(k) elements — merge_candidate_topk, not the full
        # double-argsort.
        all_d = jax.lax.all_gather(dists_k, shard_axis)            # (S, B, k)
        all_i = jax.lax.all_gather(ids_k, shard_axis)
        b = queries.shape[0]
        all_d = jnp.moveaxis(all_d, 0, 1).reshape(b, n_shards * cfg.k)
        all_i = jnp.moveaxis(all_i, 0, 1).reshape(b, n_shards * cfg.k)
        fd, fi = merge_candidate_topk(all_d, all_i, cfg.k)
        return fd, fi, nprobe

    cent_spec = P(shard_axis) if cfg.shard_centroids else P()
    return jax.shard_map(
        local_search,
        mesh=mesh,
        in_specs=(
            cent_spec,                 # centroids: sharded scan or replicated
            P(shard_axis),             # postings striped on cluster dim
            P(shard_axis),             # posting ids striped
            P(),                       # LLSP weights replicated
            bspec,                     # queries over data axes
            bspec,                     # requested top-k
        ),
        out_specs=(bspec, bspec, bspec),
        check_vma=False,
    )


def make_sharded_serve_quantized(
    mesh,
    cfg: SearchConfig,
    *,
    batch_axes: tuple[str, ...] = ("data",),
    shard_axis: str = "model",
):
    """Sharded engine over int8 RESIDUAL postings (core/quantize.py) —
    hillclimb it.3 for the serving cell: posting-scan HBM bytes drop 4x at
    <1% recall cost (tests/test_quantize.py).  Signature takes the
    quantized payload arrays explicitly (q8, scale, norm2); the centroid
    scan is sharded as in the `shard_centroids` path."""
    from .quantize import QuantizedPostings, ivf_scan_quantized

    n_shards = mesh.shape[shard_axis]
    bspec = P(batch_axes)

    def local_search(centroids_l, q8, scale, norm2, posting_ids,
                     llsp_params, queries, topk_req):
        shard = jax.lax.axis_index(shard_axis)
        c_local = q8.shape[0]
        lo = shard * c_local
        nmax = cfg.nprobe_max
        # sharded centroid scan + tiny all-gather merge
        d_loc = squared_l2(queries, centroids_l)
        k_loc = min(nmax, centroids_l.shape[0])
        dv, di = topk_smallest(d_loc, k_loc)
        di = di + shard * centroids_l.shape[0]
        dv_all = jax.lax.all_gather(dv, shard_axis)
        di_all = jax.lax.all_gather(di, shard_axis)
        bq = queries.shape[0]
        dv_all = jnp.moveaxis(dv_all, 0, 1).reshape(bq, -1)
        di_all = jnp.moveaxis(di_all, 0, 1).reshape(bq, -1)
        cdists, pos = topk_smallest(dv_all, nmax)
        cids = jnp.take_along_axis(di_all, pos, axis=1)

        nprobe = decide_nprobe(cfg, llsp_params, queries, topk_req, cdists)
        probe_mask = jnp.arange(nmax)[None, :] < nprobe[:, None]
        local_cids = cids - lo
        on_shard = (local_cids >= 0) & (local_cids < c_local)
        probe_mask = probe_mask & on_shard
        local_cids = jnp.clip(local_cids, 0, c_local - 1)
        if cfg.fused_topk:
            from repro.kernels.ref import ivf_scan_q8_topk_ref

            dists_k, ids_k = _fused_scan_candidates(
                cfg,
                lambda k2: kops.ivf_scan_q8_topk(
                    q8, scale, norm2, centroids_l, posting_ids,
                    local_cids, probe_mask, queries, k2=k2),
                lambda k2: ivf_scan_q8_topk_ref(
                    q8, scale, norm2, centroids_l, posting_ids,
                    local_cids, probe_mask, queries, k2),
            )
        else:
            qp = QuantizedPostings(q8=q8, scale=scale, norm2=norm2)
            dists = ivf_scan_quantized(qp, centroids_l, local_cids, probe_mask,
                                       queries)
            ids = posting_ids[jnp.maximum(local_cids, 0)]
            dists = jnp.where(ids < 0, jnp.inf, dists)
            dists_k, ids_k = dedup_topk(
                dists.reshape(bq, -1), ids.reshape(bq, -1), cfg.k)
        all_d = jax.lax.all_gather(dists_k, shard_axis)
        all_i = jax.lax.all_gather(ids_k, shard_axis)
        all_d = jnp.moveaxis(all_d, 0, 1).reshape(bq, n_shards * cfg.k)
        all_i = jnp.moveaxis(all_i, 0, 1).reshape(bq, n_shards * cfg.k)
        fd, fi = merge_candidate_topk(all_d, all_i, cfg.k)
        return fd, fi, nprobe

    return jax.shard_map(
        local_search,
        mesh=mesh,
        in_specs=(
            P(shard_axis),             # centroid slice (scan + residuals)
            P(shard_axis),             # q8 striped on cluster dim
            P(shard_axis),             # scales striped
            P(shard_axis),             # norms striped
            P(shard_axis),             # posting ids striped
            P(),                       # LLSP replicated
            bspec, bspec,
        ),
        out_specs=(bspec, bspec, bspec),
        check_vma=False,
    )
