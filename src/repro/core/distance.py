"""Distance utilities shared by the ANNS core.

All distances are squared L2 (the paper's similarity metric is L2; squared L2
is order-preserving and cheaper — one fused matmul on the MXU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def squared_l2(a: Array, b: Array) -> Array:
    """Pairwise squared L2 distances.

    a: (N, D), b: (M, D) -> (N, M).  Uses the ||a||^2 - 2ab + ||b||^2 expansion
    so the inner term is a single MXU matmul.
    """
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)          # (N, 1)
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T        # (1, M)
    ab = a @ b.T                                         # (N, M) — MXU
    d = a2 - 2.0 * ab + b2
    return jnp.maximum(d, 0.0)


def squared_l2_chunked(a: Array, b: Array, chunk: int = 4096) -> Array:
    """Memory-bounded pairwise distances for large M (scan over b-chunks)."""
    m = b.shape[0]
    if m <= chunk:
        return squared_l2(a, b)
    pad = (-m) % chunk
    bp = jnp.pad(b, ((0, pad), (0, 0)), constant_values=0.0)
    nb = bp.shape[0] // chunk
    bc = bp.reshape(nb, chunk, b.shape[1])

    def body(_, bi):
        return None, squared_l2(a, bi)

    _, out = jax.lax.scan(body, None, bc)                # (nb, N, chunk)
    out = jnp.moveaxis(out, 0, 1).reshape(a.shape[0], nb * chunk)
    return out[:, :m]


def topk_smallest(d: Array, k: int) -> tuple[Array, Array]:
    """Top-k smallest along the last axis -> (values, indices)."""
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


def dedup_topk(dists: Array, ids: Array, k: int) -> tuple[Array, Array]:
    """Top-k smallest with duplicate-id suppression (closure assignment
    duplicates vectors across clusters; the frontend merge must dedupe).

    dists, ids: (..., n).  Sort by distance, then mask any id that already
    appeared at a smaller distance.  Fully jittable (fixed shapes).
    """
    order = jnp.argsort(dists, axis=-1)
    sd = jnp.take_along_axis(dists, order, axis=-1)
    si = jnp.take_along_axis(ids, order, axis=-1)
    # Mark duplicates: an element is a dup if the same id occurs earlier in the
    # sorted order.  Sort (id, rank) pairs: stable-sort by id, then any element
    # whose predecessor (in id order) shares its id AND has smaller rank is dup.
    id_order = jnp.argsort(si, axis=-1, stable=True)     # ranks grouped by id
    gid = jnp.take_along_axis(si, id_order, axis=-1)
    prev_same = jnp.concatenate(
        [jnp.zeros_like(gid[..., :1], dtype=bool), gid[..., 1:] == gid[..., :-1]],
        axis=-1,
    )
    dup_sorted = prev_same  # stable sort keeps distance order within equal ids
    dup = jnp.zeros_like(dup_sorted)
    dup = jnp.put_along_axis(dup, id_order, dup_sorted, axis=-1, inplace=False)
    sd = jnp.where(dup | (si < 0), jnp.inf, sd)
    k_eff = min(k, sd.shape[-1])
    vals, pos = topk_smallest(sd, k_eff)
    out_ids = jnp.take_along_axis(si, pos, axis=-1)
    out_ids = jnp.where(jnp.isinf(vals), -1, out_ids)
    if k_eff < k:  # fewer candidates than requested: pad (inf, -1)
        pad = k - k_eff
        vals = jnp.pad(vals, [(0, 0)] * (vals.ndim - 1) + [(0, pad)],
                       constant_values=jnp.inf)
        out_ids = jnp.pad(out_ids, [(0, 0)] * (out_ids.ndim - 1) + [(0, pad)],
                          constant_values=-1)
    return vals, out_ids


def merge_candidate_topk(dists: Array, ids: Array, k: int) -> tuple[Array, Array]:
    """Top-k merge over small candidate sets (n = O(k), the fused-kernel
    output or a cross-shard gather of per-shard top-k).

    Same contract as dedup_topk — ascending per-id min distances, invalid
    slots (+inf, -1) — but sized for candidate-compressed inputs: one argsort
    by distance plus an O(n^2) pairwise duplicate mask instead of the second
    full argsort-by-id.  For n ~ tens of candidates the (…, n, n) comparison
    tile is cheaper than sorting twice; past that the quadratic mask loses,
    so large inputs (e.g. many-shard x large-k gathers) fall back to the
    sort-based dedup with the identical contract.
    """
    n = dists.shape[-1]
    if n > 256:  # (…, n, n) bool mask no longer pays for itself
        return dedup_topk(dists, ids, k)
    order = jnp.argsort(dists, axis=-1)
    sd = jnp.take_along_axis(dists, order, axis=-1)
    si = jnp.take_along_axis(ids, order, axis=-1)
    # dup[i] = some j<i (strictly earlier in distance order) has the same id
    same = si[..., :, None] == si[..., None, :]          # (…, n, n)
    earlier = jnp.tril(jnp.ones((n, n), bool), k=-1)
    dup = jnp.any(same & earlier, axis=-1)
    sd = jnp.where(dup | (si < 0), jnp.inf, sd)
    k_eff = min(k, n)
    vals, pos = topk_smallest(sd, k_eff)
    out_ids = jnp.take_along_axis(si, pos, axis=-1)
    out_ids = jnp.where(jnp.isinf(vals), -1, out_ids)
    if k_eff < k:  # fewer candidates than requested: pad (inf, -1)
        pad = k - k_eff
        vals = jnp.pad(vals, [(0, 0)] * (vals.ndim - 1) + [(0, pad)],
                       constant_values=jnp.inf)
        out_ids = jnp.pad(out_ids, [(0, 0)] * (out_ids.ndim - 1) + [(0, pad)],
                          constant_values=-1)
    return vals, out_ids


def recall_at_k(pred_ids, true_ids) -> float:
    """Mean recall@k between (B, k) predicted ids and (B, k) ground truth."""
    import numpy as np

    pred_ids = np.asarray(pred_ids)
    true_ids = np.asarray(true_ids)
    hits = 0
    for p, t in zip(pred_ids, true_ids):
        hits += len(set(p.tolist()) & set(t.tolist()))
    return hits / true_ids.size
