"""Graph-based ANNS baseline (the HNSW / DiskANN family of §2.2).

The paper's argument is comparative: greedy best-first graph traversal issues
*serialized, dependency-chained* reads, so on SSDs it cannot use the array's
bandwidth, while clustering-based search issues one dependency-free batch.
To reproduce Figs 4/14/15/16 we need the baseline itself:

* ``build_nsw_graph``   — kNN graph + RNG-rule edge pruning (the Vamana/NSW
  construction both HNSW and DiskANN derive from), degree-bounded.
* ``beam_search``       — best-first search with a beam ("ef"/"L"), counting
  HOPS (= serialized read rounds) and DISTANCE EVALS.  The hop count is what
  the DRAM-SSD latency model multiplies by the per-read latency; the eval
  count is the in-DRAM compute cost.

Implemented in numpy (the traversal is pointer-chasing, exactly the part the
paper shows does NOT vectorize onto wide hardware — that observation IS the
result; see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from repro.core.distance import squared_l2
import jax.numpy as jnp


@dataclasses.dataclass
class NSWGraph:
    vectors: np.ndarray      # (N, D)
    neighbors: np.ndarray    # (N, R) int32, -1 padded
    entry: int

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]


def build_nsw_graph(x: np.ndarray, degree: int = 24, chunk: int = 2048,
                    rng_prune: bool = True, alpha: float = 1.2,
                    seed: int = 0) -> NSWGraph:
    """kNN graph (exact, chunked) + alpha-relaxed RNG pruning (Vamana-style)
    + NSW random long links for navigability, degree-bounded.

    The strict RNG rule on a strongly clustered corpus prunes the graph into
    per-cluster islands (no long edges in a nearest-neighbor candidate pool),
    so like Vamana we relax occlusion by ``alpha`` and like NSW we reserve a
    few slots per node for random long-range links — both are what the real
    HNSW/DiskANN constructions do to stay navigable."""
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    n_rand = max(2, degree // 6)
    n_near = degree - n_rand
    cand_k = min(degree * 2 + 1, n)
    nbrs = np.full((n, cand_k - 1), -1, dtype=np.int32)
    xj = jnp.asarray(x)
    a2 = alpha * alpha                   # squared-L2 domain
    for s in range(0, n, chunk):
        d = np.asarray(squared_l2(xj[s:s + chunk], xj))
        part = np.argpartition(d, cand_k - 1, axis=1)[:, :cand_k]
        # order candidates by distance, drop self
        for i in range(part.shape[0]):
            row = part[i]
            row = row[np.argsort(d[i, row])]
            row = row[row != s + i][:cand_k - 1]
            nbrs[s + i, :len(row)] = row
    out = np.full((n, degree), -1, dtype=np.int32)
    for i in range(n):
        if rng_prune:
            kept: list[int] = []
            for c in nbrs[i]:
                if c < 0 or len(kept) == n_near:
                    break
                dc = float(((x[i] - x[c]) ** 2).sum())
                ok = True
                for m in kept:
                    if a2 * float(((x[m] - x[c]) ** 2).sum()) < dc:
                        ok = False
                        break
                if ok:
                    kept.append(int(c))
        else:
            kept = [int(c) for c in nbrs[i, :n_near] if c >= 0]
        # NSW long links: random distinct nodes (connectivity/expander edges)
        extra = rng.choice(n, size=n_rand, replace=False)
        for e in extra:
            if e != i and e not in kept and len(kept) < degree:
                kept.append(int(e))
        out[i, :len(kept)] = kept
    # entry point: medoid-ish (closest to the mean)
    entry = int(np.argmin(((x - x.mean(0)) ** 2).sum(1)))
    return NSWGraph(vectors=np.ascontiguousarray(x), neighbors=out, entry=entry)


@dataclasses.dataclass
class SearchStats:
    hops: int                # serialized read rounds (I/O chain length)
    evals: int               # distance computations
    beam_reads: int          # node fetches (beam-batched I/O count)


def beam_search(g: NSWGraph, q: np.ndarray, k: int, beam: int,
                max_hops: int = 10_000) -> tuple[np.ndarray, SearchStats]:
    """Best-first beam search (DiskANN-style).  Returns (ids (k,), stats)."""
    x = g.vectors
    visited = {g.entry}
    d0 = float(((x[g.entry] - q) ** 2).sum())
    # candidate heap (min by dist), result heap (max by dist)
    cand = [(d0, g.entry)]
    results = [(-d0, g.entry)]
    hops = evals = reads = 0
    while cand and hops < max_hops:
        d, u = heapq.heappop(cand)
        worst = -results[0][0]
        if d > worst and len(results) >= beam:
            break
        hops += 1
        reads += 1
        nb = g.neighbors[u]
        nb = nb[nb >= 0]
        fresh = [v for v in nb.tolist() if v not in visited]
        visited.update(fresh)
        if fresh:
            dv = ((x[fresh] - q) ** 2).sum(1)
            evals += len(fresh)
            for v, dvv in zip(fresh, dv.tolist()):
                if len(results) < beam or dvv < -results[0][0]:
                    heapq.heappush(cand, (dvv, v))
                    heapq.heappush(results, (-dvv, v))
                    if len(results) > beam:
                        heapq.heappop(results)
    top = sorted(((-nd, v) for nd, v in results))[:k]
    ids = np.asarray([v for _, v in top], dtype=np.int32)
    if len(ids) < k:
        ids = np.pad(ids, (0, k - len(ids)), constant_values=-1)
    return ids, SearchStats(hops=hops, evals=evals, beam_reads=reads)


def batch_search(g: NSWGraph, queries: np.ndarray, k: int, beam: int):
    """Convenience loop; returns (ids (B,k), mean stats)."""
    ids = np.empty((queries.shape[0], k), dtype=np.int32)
    hops = evals = reads = 0
    for i, q in enumerate(queries):
        ids[i], st = beam_search(g, q, k, beam)
        hops += st.hops
        evals += st.evals
        reads += st.beam_reads
    b = queries.shape[0]
    return ids, SearchStats(hops // b, evals // b, reads // b)
