"""Clustering-based (IVF/SPANN-style) index structures.

Layout mirrors the paper's serving data layout (§4.2, Fig. 10):

* ``centroids`` — the in-DRAM part (replicated across devices at serving).
* ``postings`` / ``posting_ids`` — fixed-size padded cluster lists, the
  "raw-block" part (sharded over the ``model`` mesh axis at serving; each
  cluster occupies one contiguous extent on one shard).
* optional two-level centroid quantizer (``group_centroids``/``group_members``)
  — the TPU-native replacement for SPANN's in-memory centroid graph.

Every array is a plain jax.Array so the whole index is a pytree that can be
checkpointed, device_put with shardings, or passed to jitted functions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from .distance import squared_l2_chunked, topk_smallest, dedup_topk


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class IVFIndex:
    centroids: jax.Array            # (C, D) f32
    postings: jax.Array             # (C, L, D) vector payloads (pad: repeat)
    posting_ids: jax.Array          # (C, L) int32, -1 = padding slot
    group_centroids: Optional[jax.Array] = None  # (G, D)
    group_members: Optional[jax.Array] = None    # (G, Cg) int32, -1 pad
    # optional int8-residual payload (core/quantize.py) — when attached, the
    # serve paths can run their first pass over these instead of `postings`
    # (SearchConfig.tier == "q8"); `postings` stays the f32 re-rank truth.
    q8: Optional[jax.Array] = None               # (C, L, D) int8 residuals
    qscale: Optional[jax.Array] = None           # (C, 1, 1) f32 per-cluster
    qnorm2: Optional[jax.Array] = None           # (C, L) f32 s^2*||r8||^2

    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def cluster_len(self) -> int:
        return self.postings.shape[1]

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]

    def nbytes(self) -> int:
        tot = 0
        for leaf in jax.tree_util.tree_leaves(self):
            tot += leaf.size * leaf.dtype.itemsize
        return tot


def build_postings(
    x: np.ndarray,
    assign: np.ndarray,
    n_clusters: int,
    cluster_len: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Materialize fixed-size posting lists from a (N, R) closure assignment.

    Clusters larger than ``cluster_len`` keep their closest members (the
    overflow replicas are boundary duplicates by construction); smaller ones
    pad with the last valid vector and id=-1 (distance contributions of pads
    are masked at merge via the -1 id).
    """
    n, r = assign.shape
    d = x.shape[1]
    members: list[list[int]] = [[] for _ in range(n_clusters)]
    for col in range(r):
        col_assign = assign[:, col]
        valid = np.nonzero(col_assign >= 0)[0]
        for i in valid:
            members[col_assign[i]].append(i)

    postings = np.zeros((n_clusters, cluster_len, d), dtype=np.float32)
    ids = np.full((n_clusters, cluster_len), -1, dtype=np.int32)
    for c in range(n_clusters):
        mem = members[c]
        if not mem:
            continue
        mem = np.asarray(mem[:cluster_len])
        postings[c, : len(mem)] = x[mem]
        ids[c, : len(mem)] = mem
        if len(mem) < cluster_len:  # pad payload with last vector, id stays -1
            postings[c, len(mem):] = x[mem[-1]]
    return postings, ids


def make_group_quantizer(
    centroids: np.ndarray, n_groups: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Two-level centroid quantizer (TPU stand-in for the centroid graph)."""
    from repro.build.kmeans import kmeans

    gc, gassign, _ = kmeans(centroids, n_groups, iters=10, seed=seed)
    sizes = np.bincount(gassign, minlength=n_groups)
    cap = int(sizes.max())
    members = np.full((n_groups, cap), -1, dtype=np.int32)
    fill = np.zeros(n_groups, dtype=np.int64)
    for cid, g in enumerate(gassign):
        members[g, fill[g]] = cid
        fill[g] += 1
    return gc.astype(np.float32), members


def brute_force_topk(
    x: jax.Array, queries: jax.Array, k: int, chunk: int = 8192
) -> tuple[jax.Array, jax.Array]:
    """Exact ground truth: (B, k) distances + ids over the raw vectors."""
    d = squared_l2_chunked(queries, x, chunk=chunk)
    return topk_smallest(d, k)


def search_flat(
    index: IVFIndex,
    queries: jax.Array,
    k: int,
    nprobe: int,
) -> tuple[jax.Array, jax.Array]:
    """Reference (non-pruned, single-device, pure-jnp) IVF search.

    Used as the oracle for the sharded/fused engine in core/search.py.
    """
    cd = squared_l2_chunked(queries, index.centroids)
    _, cids = topk_smallest(cd, nprobe)                   # (B, nprobe)
    gathered = index.postings[cids]                       # (B, n, L, D)
    gids = index.posting_ids[cids]                        # (B, n, L)
    q = queries[:, None, None, :]
    dist = jnp.sum((gathered - q) ** 2, axis=-1)          # (B, n, L)
    b = queries.shape[0]
    dist = dist.reshape(b, -1)
    gids = gids.reshape(b, -1)
    dist = jnp.where(gids < 0, jnp.inf, dist)
    return dedup_topk(dist, gids, k)
