"""Leveling-Learned Search Pruning (LLSP) — paper §4.3.

Router GBDT: (query, top-k) -> level (a coarse max nprobe).
Per-level pruning GBDT: (query, top-k, centroid-distance distribution) ->
refined nprobe.  Only *pre-search* features are used so posting reads remain
one dependency-free batch (the paper's key compatibility constraint with
batched SSD/HBM I/O — no probe-compute-decide loop).

Offline training (paper's workflow, §4.3):
* labels approximated from a non-pruned large-nprobe search (not brute force),
* router label = smallest level whose range reaches target recall,
* pruning label = minimal nprobe within that level reaching target recall,
  derived by *decreasing* nprobe until recall drops — we compute it in closed
  form from the rank of the first cluster containing each true neighbor.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .gbdt import (
    GBDTParams,
    GBDTRegressor,
    predict_jax,
    predict_stacked_jax,
    stack_params,
)


@dataclasses.dataclass(frozen=True)
class LLSPConfig:
    levels: tuple[int, ...] = (16, 32, 64, 128, 256)  # nprobe upper bounds
    recall_target: float = 0.9
    n_ratio_features: int = 32       # centroid-distance ratios fed to pruner
    label_nprobe: int = 0            # 0 => use max level for label generation
    n_trees: int = 80
    max_depth: int = 5
    lr: float = 0.2

    @property
    def nmax(self) -> int:
        return self.levels[-1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LLSPParams:
    router: GBDTParams
    pruners: GBDTParams              # stacked, leading dim = n_levels
    levels: jax.Array                # (n_levels,) int32


# --------------------------------------------------------------------------
# feature builders (shared online/offline; jit-safe)
# --------------------------------------------------------------------------
def router_features(queries: jax.Array, topk: jax.Array) -> jax.Array:
    """(B, D) + (B,) -> (B, D+1)."""
    return jnp.concatenate([queries, jnp.log1p(topk.astype(jnp.float32))[:, None]], axis=1)


def pruner_features(
    queries: jax.Array, topk: jax.Array, cdists: jax.Array, n_ratio: int
) -> jax.Array:
    """(B, D), (B,), (B, nmax sorted centroid dists) -> (B, D+2+n_ratio).

    Features: query coords, log top-k, d1, ratios d_i/d1 for i=1..n_ratio
    (paper: "the nearest centroid-query distance and relative ratios of the
    following centroids' to the 1st centroid's").
    """
    d1 = jnp.maximum(cdists[:, :1], 1e-12)
    ratios = cdists[:, 1 : n_ratio + 1] / d1
    return jnp.concatenate(
        [
            queries,
            jnp.log1p(topk.astype(jnp.float32))[:, None],
            jnp.sqrt(d1),
            ratios,
        ],
        axis=1,
    )


# --------------------------------------------------------------------------
# online inference
# --------------------------------------------------------------------------
def route(params: LLSPParams, queries: jax.Array, topk: jax.Array) -> jax.Array:
    """Predict per-query level index (B,) int32."""
    n_levels = params.levels.shape[0]
    raw = predict_jax(params.router, router_features(queries, topk))
    return jnp.clip(jnp.round(raw), 0, n_levels - 1).astype(jnp.int32)


def prune(
    params: LLSPParams,
    level: jax.Array,
    queries: jax.Array,
    topk: jax.Array,
    cdists: jax.Array,
    n_ratio: int,
) -> jax.Array:
    """Predict per-query nprobe (B,) int32 within [1, level_max]."""
    feats = pruner_features(queries, topk, cdists, n_ratio)
    raw = predict_stacked_jax(params.pruners, level, feats)
    level_max = params.levels[level].astype(jnp.float32)
    # never probe fewer clusters than could hold top-k results
    return jnp.clip(jnp.ceil(raw), 1.0, level_max).astype(jnp.int32)


# --------------------------------------------------------------------------
# offline label generation + training
# --------------------------------------------------------------------------
def min_nprobe_labels(
    centroid_rank_of_hit: np.ndarray,   # (B, kmax) rank of first cluster holding
    recall_target: float,               #        each true neighbor (nmax = miss)
    nmax: int,
    topk: np.ndarray | None = None,     # (B,) per-query k (pad cols = nmax rank)
) -> np.ndarray:
    """Closed-form minimal nprobe reaching target recall per query.

    recall(nprobe) = fraction of the query's true top-k whose first-containing-
    cluster rank < nprobe, so the minimal nprobe is 1 + the ceil(target*k)-th
    smallest rank.  Equivalent to (and far cheaper than) the paper's
    "decrease nprobe until recall drops" sweep.  ``topk`` supports per-query k
    (padded columns must carry rank nmax and are sorted past the needed index).
    """
    b, kmax = centroid_rank_of_hit.shape
    if topk is None:
        topk = np.full(b, kmax)
    need = np.ceil(recall_target * np.asarray(topk)).astype(np.int64)
    need = np.clip(need, 1, kmax)
    ranks_sorted = np.sort(centroid_rank_of_hit, axis=1)
    min_np = ranks_sorted[np.arange(b), need - 1] + 1
    return np.clip(min_np, 1, nmax).astype(np.int32)


def first_hit_ranks(
    true_ids: np.ndarray,      # (B, k)
    cid_order: np.ndarray,     # (B, nmax) centroid ids sorted by distance
    posting_ids: np.ndarray,   # (C, L)
    n_vectors: int,
    nmax: int,
) -> np.ndarray:
    """Rank (position in the query's centroid ordering) of the first cluster
    containing each true neighbor; nmax if not reachable within nmax."""
    C, L = posting_ids.shape
    # vector id -> clusters containing it (closure => several)
    flat = posting_ids.ravel()
    valid = flat >= 0
    vec = flat[valid]
    clu = np.repeat(np.arange(C, dtype=np.int64), L)[valid]
    order = np.argsort(vec, kind="stable")
    vec_s, clu_s = vec[order], clu[order]
    starts = np.searchsorted(vec_s, np.arange(n_vectors))
    ends = np.searchsorted(vec_s, np.arange(n_vectors) + 1)

    B, k = true_ids.shape
    out = np.full((B, k), nmax, dtype=np.int32)
    for b in range(B):
        rank_of = {int(c): r for r, c in enumerate(cid_order[b])}
        for j in range(k):
            v = int(true_ids[b, j])
            if v < 0:
                continue
            best = nmax
            for c in clu_s[starts[v]:ends[v]]:
                r = rank_of.get(int(c), nmax)
                if r < best:
                    best = r
            out[b, j] = best
    return out


def train_llsp(
    cfg: LLSPConfig,
    queries: np.ndarray,        # (B, D) training queries (sampled log window)
    topk: np.ndarray,           # (B,) business top-k per query
    cid_order: np.ndarray,      # (B, nmax) centroid ids by distance
    cdists: np.ndarray,         # (B, nmax) sorted centroid distances
    true_ids: np.ndarray,       # (B, k) approx ground truth (large-nprobe run)
    posting_ids: np.ndarray,    # (C, L)
    n_vectors: int,
    seed: int = 0,
) -> LLSPParams:
    levels = np.asarray(cfg.levels, dtype=np.int32)
    nmax = int(levels[-1])
    ranks = first_hit_ranks(true_ids, cid_order, posting_ids, n_vectors, nmax)
    # padded (-1) truth columns must not count against recall
    ranks = np.where(true_ids < 0, nmax, ranks)
    min_np = min_nprobe_labels(ranks, cfg.recall_target, nmax, topk=topk)

    # router: label = smallest level index whose bound >= min_nprobe
    lvl_label = np.searchsorted(levels, min_np, side="left")
    lvl_label = np.clip(lvl_label, 0, len(levels) - 1)
    rf = np.asarray(router_features(jnp.asarray(queries), jnp.asarray(topk)))
    router = GBDTRegressor(
        n_trees=cfg.n_trees, max_depth=cfg.max_depth, lr=cfg.lr, seed=seed
    ).fit(rf, lvl_label.astype(np.float64))

    # per-level pruners on the queries routed to each level
    pf = np.asarray(
        pruner_features(
            jnp.asarray(queries), jnp.asarray(topk), jnp.asarray(cdists),
            cfg.n_ratio_features,
        )
    )
    pruners = []
    for li in range(len(levels)):
        sel = lvl_label == li
        if sel.sum() < 32:  # too few samples: fall back to all data clipped
            Xl, yl = pf, np.minimum(min_np, levels[li]).astype(np.float64)
        else:
            Xl, yl = pf[sel], min_np[sel].astype(np.float64)
        m = GBDTRegressor(
            n_trees=cfg.n_trees, max_depth=cfg.max_depth, lr=cfg.lr,
            seed=seed + 101 * li,
        ).fit(Xl, yl)
        pruners.append(m.params)
    return LLSPParams(
        router=router.params,
        pruners=stack_params(pruners),
        levels=jnp.asarray(levels),
    )
