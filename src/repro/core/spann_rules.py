"""SPANN-inherited rules: fixed-epsilon pruning (Eq. 1) and closure
multi-cluster assignment with the RNG rule (§4.4 "closure multi-cluster
assignment that duplicates boundary vectors, using RNG rules").

These are the paper's *baselines / building blocks*: the fixed-eps rule is the
pruning baseline Helmsman improves on with LLSP; closure assignment is reused
verbatim in Helmsman's construction stage 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fixed_eps_nprobe(cdists: jax.Array, eps: float, nmax: int) -> jax.Array:
    """Eq. 1: search cluster ij iff Dist(q, c_ij) <= (1+eps) * Dist(q, c_i1).

    cdists: (B, nmax) centroid distances sorted ascending (squared L2 — the
    (1+eps) factor is applied in the L2 domain, so squared threshold is
    (1+eps)^2).  Returns per-query nprobe counts (B,) int32.
    """
    d1 = cdists[:, :1]
    thr = (1.0 + eps) ** 2 * d1
    keep = cdists <= thr
    return jnp.minimum(jnp.sum(keep, axis=1), nmax).astype(jnp.int32)


def closure_assign(
    x: jax.Array,
    centroids: jax.Array,
    *,
    eps: float = 0.1,
    max_replicas: int = 4,
    rng_rule: bool = True,
    chunk: int = 8192,
) -> jax.Array:
    """Closure multi-cluster assignment.

    Each vector is assigned to up to ``max_replicas`` nearest clusters whose
    centroid distance is within (1+eps) of the nearest, filtered by the RNG
    (relative neighborhood graph) rule: candidate c_j is kept only if for
    every already-kept c_m,  Dist(x, c_j) <= Dist(c_m, c_j)  (otherwise c_m
    "occludes" c_j and the replica would be redundant).

    Returns assignment ids (N, max_replicas) int32 with -1 padding; column 0
    is always the nearest cluster.
    """
    from .distance import squared_l2

    R = max_replicas
    n = x.shape[0]

    def assign_chunk(xc):
        d = squared_l2(xc, centroids)                       # (n, C)
        negd, cand = jax.lax.top_k(-d, R)                   # nearest R
        cd = -negd                                          # (n, R) ascending
        thr = (1.0 + eps) ** 2 * cd[:, :1]
        in_window = cd <= thr                               # (n, R)
        if not rng_rule:
            keep = in_window
        else:
            cc = centroids[cand]                            # (n, R, D)
            # pairwise centroid distances among candidates
            ccd = jnp.sum((cc[:, :, None, :] - cc[:, None, :, :]) ** 2, axis=-1)
            keep = jnp.zeros(cd.shape, dtype=bool).at[:, 0].set(True)

            def body(j, keep):
                # c_j kept iff in window and for all kept m<j: d(x,c_j) <= d(c_m,c_j)
                cd_j = jax.lax.dynamic_index_in_dim(cd, j, axis=1)  # (n, 1)
                ccd_j = jax.lax.dynamic_index_in_dim(ccd, j, axis=2)[..., 0]
                occluded = jnp.any(keep & (ccd_j < cd_j), axis=1)
                kj = in_window[:, j] & ~occluded
                return keep.at[:, j].set(kj)

            keep = jax.lax.fori_loop(1, R, body, keep)
        return jnp.where(keep, cand, -1)

    if n <= chunk:
        return assign_chunk(x)
    outs = []
    for s in range(0, n, chunk):
        outs.append(assign_chunk(x[s:s + chunk]))
    return jnp.concatenate(outs, axis=0)
