"""int8 posting-list quantization (beyond-paper memory optimization).

The paper stores full-precision vectors in the cluster lists (§4.2); at TPU
serving the posting scan is HBM-bandwidth-bound (EXPERIMENTS §Roofline), so
halving/quartering posting bytes moves the dominant term directly.  We add
symmetric per-cluster int8 quantization:

    p8[c] = round(p[c] / s_c),  s_c = max|p[c]| / 127

Quantizing raw vectors costs ~3% recall on clustered corpora (first
iteration, refuted), so we quantize the RESIDUAL to the cluster centroid
(IVF-RQ): residuals are small, so the int8 grid is ~10x finer where it
matters.  Distance stays closed-form:

    p = c_j + s*r8
    ||q - p||^2 = ||q - c_j||^2 - 2 s (q - c_j).r8 + s^2 ||r8||^2

with per-slot ||r8||^2 precomputed, so the scan is one int8->f32 matmul plus
rank-1 corrections — same MXU shape as the f32 scan at 1/4 the HBM bytes,
and recall within 1% of f32 (tests/test_quantize.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from .ivf import IVFIndex


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantizedPostings:
    q8: jax.Array          # (C, L, D) int8
    scale: jax.Array       # (C, 1, 1) f32 per-cluster scale
    norm2: jax.Array       # (C, L) f32 precomputed s^2 * ||p8||^2

    def nbytes(self) -> int:
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(self))


def quantize_postings(postings: jax.Array,
                      centroids: jax.Array,
                      posting_ids: jax.Array | None = None
                      ) -> QuantizedPostings:
    """Quantize padded posting lists against their own centroids.

    ``posting_ids`` (C, L), when given, marks dead padding slots (id < 0):
    their residuals are excluded from the per-cluster ``max|r|`` and their
    codes/norms zeroed.  Without the mask a low-fill cluster whose padding
    payload drifted from the centroid (tombstoned rows, stale pad vectors)
    inflates the scale and coarsens the int8 grid for every LIVE vector in
    the cluster — dead slots are already dropped downstream by the id mask,
    so letting them set the scale buys nothing and costs recall.
    """
    p = jnp.asarray(postings, jnp.float32)
    r = p - centroids[:, None, :]                 # residual to own centroid
    if posting_ids is not None:
        live = (jnp.asarray(posting_ids) >= 0)[:, :, None]
        r = jnp.where(live, r, 0.0)
    amax = jnp.max(jnp.abs(r), axis=(1, 2), keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q8 = jnp.clip(jnp.round(r / scale), -127, 127).astype(jnp.int8)
    norm2 = (scale ** 2)[:, :, 0] * jnp.sum(
        q8.astype(jnp.float32) ** 2, axis=-1)
    return QuantizedPostings(q8=q8, scale=scale, norm2=norm2)


def attach_quantized(index: IVFIndex,
                     qp: QuantizedPostings | None = None) -> IVFIndex:
    """Return a copy of ``index`` carrying its int8-residual payload.

    When ``qp`` is omitted the postings are quantized here, with dead
    padding slots masked out of the scale (the only correct default).  The
    returned index serves with ``SearchConfig(tier="q8")``.
    """
    if qp is None:
        qp = quantize_postings(index.postings, index.centroids,
                               index.posting_ids)
    return dataclasses.replace(index, q8=qp.q8, qscale=qp.scale,
                               qnorm2=qp.norm2)


def ivf_scan_quantized(
    qp: QuantizedPostings,
    centroids: jax.Array,  # (C, D)
    cids: jax.Array,       # (B, P) int32
    mask: jax.Array,       # (B, P) bool
    queries: jax.Array,    # (B, D)
) -> jax.Array:
    """(B, P, L) f32 distances against int8 residual postings; masked +inf."""
    q = queries.astype(jnp.float32)
    safe = jnp.clip(cids, 0, qp.q8.shape[0] - 1)
    g8 = qp.q8[safe].astype(jnp.float32)                 # (B,P,L,D)
    s = qp.scale[safe][:, :, :, 0]                       # (B,P,1)
    qc = q[:, None, :] - centroids[safe]                 # (B,P,D)
    cross = jnp.einsum("bpd,bpld->bpl", qc, g8)
    d = (
        jnp.sum(qc * qc, axis=-1)[:, :, None]
        - 2.0 * s * cross
        + qp.norm2[safe]
    )
    d = jnp.maximum(d, 0.0)
    return jnp.where(mask[:, :, None], d, jnp.inf)


def search_flat_quantized(index: IVFIndex, qp: QuantizedPostings,
                          queries: jax.Array, k: int, nprobe: int,
                          fused: bool = True, use_kernel: bool = False):
    """Quantized counterpart of core.ivf.search_flat.

    ``fused`` (default) routes through the candidate-compressed data path:
    the scan stage keeps only (B, ~2k) unique-by-id candidates and a cheap
    merge takes the final k — the same contract as the fused-topk kernels.
    ``fused=False`` keeps the legacy full (B, P, L) distance materialization.
    ``use_kernel`` dispatches the fused scan to the Pallas kernel instead of
    the reference — the same switch as ``SearchConfig.use_kernel`` in the
    sharded serve path (interpret mode on CPU, so the default stays off for
    this debugging-oriented entry point).
    """
    from .distance import dedup_topk, merge_candidate_topk, squared_l2_chunked, \
        topk_smallest

    cd = squared_l2_chunked(queries, index.centroids)
    _, cids = topk_smallest(cd, nprobe)
    mask = jnp.ones(cids.shape, bool)
    if fused:
        from .search import _auto_ncand
        from repro.kernels.ref import ivf_scan_q8_topk_ref
        from repro.kernels import ops as kops

        k2 = _auto_ncand(k)
        if use_kernel:
            cand_d, cand_i = kops.ivf_scan_q8_topk(
                qp.q8, qp.scale, qp.norm2, index.centroids,
                index.posting_ids, cids, mask, queries, k2=k2)
        else:
            cand_d, cand_i = ivf_scan_q8_topk_ref(
                qp.q8, qp.scale, qp.norm2, index.centroids,
                index.posting_ids, cids, mask, queries, k2)
        return merge_candidate_topk(cand_d, cand_i, k)
    dist = ivf_scan_quantized(qp, index.centroids, cids, mask, queries)
    gids = index.posting_ids[cids]
    dist = jnp.where(gids < 0, jnp.inf, dist)
    b = queries.shape[0]
    return dedup_topk(dist.reshape(b, -1), gids.reshape(b, -1), k)
