"""Histogram gradient-boosted decision trees (the paper's LLSP model class).

The paper trains LightGBM-style GBDTs offline (minute-level training, ~10-30us
inference, hundreds of KB per model) for the router and per-level pruning
models.  We implement the same model class from scratch:

* ``GBDTRegressor.fit`` — numpy histogram gradient boosting (squared loss,
  depth-wise greedy growth, quantile feature binning).  Offline/CPU, matching
  the paper's offline training stage.
* ``GBDTParams`` / ``predict_jax`` — flat-array tree ensemble whose inference
  is pure JAX (gather-based descent, no control flow), so the router + pruning
  models run *inside* the jitted serve_step.  Ensembles of identical shape can
  be stacked (one ensemble per LLSP level) and indexed per query.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GBDTParams:
    """Flat array encoding of a boosted ensemble.

    Trees are stored as full implicit binary trees of depth ``max_depth``:
    node i has children 2i+1 / 2i+2; leaves carry values.  ``feature`` < 0
    marks a node that is already a leaf (its ``value`` is the prediction and
    descent parks there).
    """

    feature: jax.Array    # (T, n_nodes) int32, -1 => leaf
    threshold: jax.Array  # (T, n_nodes) f32
    value: jax.Array      # (T, n_nodes) f32 (valid at leaves / early stops)
    base: jax.Array       # () f32
    lr: jax.Array         # () f32

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def max_depth(self) -> int:
        n = self.feature.shape[1]
        return int(np.log2(n + 1)) - 1


def predict_jax(params: GBDTParams, x: jax.Array) -> jax.Array:
    """Vectorized ensemble inference.  x: (B, F) -> (B,)."""
    B = x.shape[0]
    T, n_nodes = params.feature.shape
    depth = int(np.log2(n_nodes + 1)) - 1
    node = jnp.zeros((B, T), dtype=jnp.int32)
    for _ in range(depth):
        feat = params.feature[jnp.arange(T)[None, :], node]      # (B, T)
        thr = params.threshold[jnp.arange(T)[None, :], node]
        is_leaf = feat < 0
        fv = jnp.take_along_axis(x, jnp.maximum(feat, 0), axis=1)  # (B, T)
        go_left = fv <= thr
        child = jnp.where(go_left, 2 * node + 1, 2 * node + 2)
        node = jnp.where(is_leaf, node, child)
    val = params.value[jnp.arange(T)[None, :], node]             # (B, T)
    return params.base + params.lr * jnp.sum(val, axis=1)


def predict_stacked_jax(stacked: GBDTParams, level: jax.Array, x: jax.Array) -> jax.Array:
    """Inference through a *stack* of ensembles (one per LLSP level).

    stacked arrays have a leading level dim: feature (L, T, n_nodes)...
    ``level``: (B,) int32 selects the ensemble per row.  Used so the per-level
    pruning models run as one fused gather program instead of lax.switch.
    """
    B = x.shape[0]
    L, T, n_nodes = stacked.feature.shape
    depth = int(np.log2(n_nodes + 1)) - 1
    t_idx = jnp.arange(T)[None, :]
    node = jnp.zeros((B, T), dtype=jnp.int32)
    lvl = level[:, None]                                         # (B, 1)
    for _ in range(depth):
        feat = stacked.feature[lvl, t_idx, node]                 # (B, T)
        thr = stacked.threshold[lvl, t_idx, node]
        is_leaf = feat < 0
        fv = jnp.take_along_axis(x, jnp.maximum(feat, 0), axis=1)
        go_left = fv <= thr
        child = jnp.where(go_left, 2 * node + 1, 2 * node + 2)
        node = jnp.where(is_leaf, node, child)
    val = stacked.value[lvl, t_idx, node]
    return stacked.base[level] + stacked.lr[level] * jnp.sum(val, axis=1)


def stack_params(models: list[GBDTParams]) -> GBDTParams:
    """Stack same-shaped ensembles along a new leading (level) axis."""
    return GBDTParams(
        feature=jnp.stack([m.feature for m in models]),
        threshold=jnp.stack([m.threshold for m in models]),
        value=jnp.stack([m.value for m in models]),
        base=jnp.stack([m.base for m in models]),
        lr=jnp.stack([m.lr for m in models]),
    )


class GBDTRegressor:
    """Histogram GBDT with squared loss (LightGBM-flavored, numpy)."""

    def __init__(
        self,
        n_trees: int = 100,
        max_depth: int = 5,
        lr: float = 0.2,
        n_bins: int = 64,
        min_samples_leaf: int = 8,
        lambda_l2: float = 1.0,
        seed: int = 0,
    ):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.lr = lr
        self.n_bins = n_bins
        self.min_samples_leaf = min_samples_leaf
        self.lambda_l2 = lambda_l2
        self.seed = seed
        self.params: Optional[GBDTParams] = None

    # ---- binning -----------------------------------------------------------
    def _make_bins(self, X: np.ndarray) -> np.ndarray:
        qs = np.linspace(0, 1, self.n_bins + 1)[1:-1]
        edges = np.quantile(X, qs, axis=0)                        # (B-1, F)
        return np.ascontiguousarray(edges.T)                      # (F, B-1)

    def _bin(self, X: np.ndarray) -> np.ndarray:
        F = X.shape[1]
        out = np.empty(X.shape, dtype=np.int32)
        for f in range(F):
            out[:, f] = np.searchsorted(self.bin_edges_[f], X[:, f], side="left")
        return out

    # ---- tree growth -------------------------------------------------------
    def _fit_tree(self, binned: np.ndarray, g: np.ndarray):
        """Depth-wise greedy growth on gradients g. Returns flat node arrays."""
        n, F = binned.shape
        B = self.n_bins
        n_nodes = 2 ** (self.max_depth + 1) - 1
        feature = np.full(n_nodes, -1, dtype=np.int32)
        threshold = np.zeros(n_nodes, dtype=np.float32)
        value = np.zeros(n_nodes, dtype=np.float32)

        node_of = np.zeros(n, dtype=np.int64)                     # sample -> node
        lam = self.lambda_l2
        value[0] = g.sum() / (n + lam)

        level_nodes = [0]
        for depth in range(self.max_depth):
            if not level_nodes:
                break
            # histograms for every active node x feature x bin in one pass
            # flat key = node_slot * F * B + f * B + bin
            slot = {nd: i for i, nd in enumerate(level_nodes)}
            slots = np.array([slot.get(nd, -1) for nd in range(n_nodes)])
            s = slots[node_of]                                    # (n,)
            act = s >= 0
            sa, ba, ga = s[act], binned[act], g[act]
            S = len(level_nodes)
            keys = (sa[:, None] * F + np.arange(F)[None, :]) * B + ba
            hist_g = np.bincount(keys.ravel(), weights=np.repeat(ga, F),
                                 minlength=S * F * B).reshape(S, F, B)
            hist_n = np.bincount(keys.ravel(), minlength=S * F * B).reshape(S, F, B)

            next_level: list[int] = []
            csum_g = np.cumsum(hist_g, axis=2)
            csum_n = np.cumsum(hist_n, axis=2)
            for nd in level_nodes:
                si = slot[nd]
                tot_g = csum_g[si, 0, -1]
                tot_n = csum_n[si, 0, -1]
                if tot_n < 2 * self.min_samples_leaf:
                    value[nd] = tot_g / (tot_n + lam) if tot_n else 0.0
                    continue
                gl = csum_g[si, :, :-1]                           # (F, B-1)
                nl = csum_n[si, :, :-1]
                gr = tot_g - gl
                nr = tot_n - nl
                valid = (nl >= self.min_samples_leaf) & (nr >= self.min_samples_leaf)
                gain = gl * gl / (nl + lam) + gr * gr / (nr + lam) - tot_g * tot_g / (tot_n + lam)
                gain = np.where(valid, gain, -np.inf)
                fi, bi = np.unravel_index(np.argmax(gain), gain.shape)
                if not np.isfinite(gain[fi, bi]) or gain[fi, bi] <= 1e-12:
                    value[nd] = tot_g / (tot_n + lam)
                    continue
                feature[nd] = fi
                edges = self.bin_edges_[fi]
                threshold[nd] = edges[min(bi, len(edges) - 1)]
                lc, rc = 2 * nd + 1, 2 * nd + 2
                mask = (node_of == nd)
                go_left = binned[mask, fi] <= bi
                idx = np.where(mask)[0]
                node_of[idx[go_left]] = lc
                node_of[idx[~go_left]] = rc
                value[lc] = gl[fi, bi] / (nl[fi, bi] + lam)
                value[rc] = gr[fi, bi] / (nr[fi, bi] + lam)
                next_level += [lc, rc]
            level_nodes = next_level
        return feature, threshold, value, node_of

    # ---- boosting ----------------------------------------------------------
    def fit(self, X: np.ndarray, y: np.ndarray) -> "GBDTRegressor":
        X = np.asarray(X, dtype=np.float32)
        y = np.asarray(y, dtype=np.float64)
        self.bin_edges_ = self._make_bins(X)
        binned = self._bin(X)
        base = float(y.mean())
        F_pred = np.full(y.shape, base)
        feats, thrs, vals = [], [], []
        for _ in range(self.n_trees):
            g = y - F_pred
            f, t, v, node_of = self._fit_tree(binned, g)
            feats.append(f)
            thrs.append(t)
            vals.append(v)
            F_pred = F_pred + self.lr * v[node_of]
        self.params = GBDTParams(
            feature=jnp.asarray(np.stack(feats)),
            threshold=jnp.asarray(np.stack(thrs)),
            value=jnp.asarray(np.stack(vals), dtype=jnp.float32),
            base=jnp.float32(base),
            lr=jnp.float32(self.lr),
        )
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        assert self.params is not None, "fit first"
        return np.asarray(predict_jax(self.params, jnp.asarray(X, dtype=jnp.float32)))
