from .adamw import AdamWConfig, AdamWState, apply, cosine_schedule, init
