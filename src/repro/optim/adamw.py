"""AdamW + schedules + gradient utilities (pure JAX, pytree-generic).

Built in-repo (no optax dependency in this container).  Supports the
distributed tricks used by launch/train.py: gradient clipping, microbatch
accumulation, and optional int8-compressed reduction (distributed/collectives).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object      # pytree like params
    nu: object


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params) -> AdamWState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=z, nu=jax.tree.map(jnp.zeros_like, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply(
    params,
    grads,
    state: AdamWState,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
):
    """One AdamW update. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_p = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        AdamWState(step=step, mu=new_mu, nu=new_nu),
        {"grad_norm": gnorm, "lr": jnp.asarray(lr)},
    )


def cosine_schedule(
    base_lr: float, warmup: int, total: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def f(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return f
