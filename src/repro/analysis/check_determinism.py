"""Rule group 3 — determinism.

Every stochastic component in this repo threads an explicit
``np.random.default_rng(seed)`` / ``SeedSequence`` (loadgen, fault
injection, synthetic corpora, k-means init): reproducing a reported
recall/latency number requires it, and the shadow-audit math in
``obs/quality.py`` assumes replayable sampling.  Three rules:

* ``global-rng`` — sampling through module-global state
  (``np.random.normal(...)``, ``np.random.seed``, bare
  ``random.random()``): invisible cross-module coupling, order-
  dependent results under threads.
* ``unseeded-rng`` — ``default_rng()`` / ``RandomState()`` /
  ``random.Random()`` with no seed: a fresh OS-entropy stream per
  call, unreproducible by construction.
* ``clock-seed`` — a seed derived from the clock
  (``default_rng(time.time_ns())``): reproducible only within the
  same nanosecond.  Allowed under ``benchmarks/`` (wall-clock runs
  that WANT varied streams), banned elsewhere.
"""
from __future__ import annotations

import ast
import os
from typing import Optional

from .core import FileModel, Finding
from .project import Project, attr_chain

RULE_GLOBAL = "global-rng"
RULE_UNSEEDED = "unseeded-rng"
RULE_CLOCK = "clock-seed"

NP_GLOBAL_FNS = {
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "normal", "standard_normal", "uniform", "choice", "shuffle",
    "permutation", "poisson", "exponential", "beta", "gamma", "binomial",
    "bytes", "sample", "get_state", "set_state", "randint", "laplace",
    "lognormal", "multivariate_normal", "geometric", "zipf",
}
PY_RANDOM_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "seed", "normalvariate", "betavariate",
    "expovariate", "getrandbits", "triangular", "vonmisesvariate",
}
CLOCK_CALLS = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "datetime.now", "datetime.utcnow",
}


def _scope_of(fm: FileModel, node: ast.AST) -> str:
    # cheap enclosing-scope lookup: nearest def/class whose span covers
    # the node
    best = "module"
    best_span = None
    for sub in ast.walk(fm.tree):
        if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            if sub.lineno <= node.lineno <= (sub.end_lineno or sub.lineno):
                span = (sub.end_lineno or sub.lineno) - sub.lineno
                if best_span is None or span < best_span:
                    best, best_span = sub.name, span
    return best


CLOCK_FNS = {"time", "time_ns", "perf_counter", "perf_counter_ns",
             "monotonic", "monotonic_ns", "now", "utcnow"}


def _has_clock(expr: ast.AST) -> bool:
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Call):
            ch = attr_chain(sub.func)
            if ch and (ch in CLOCK_CALLS
                       or (ch.split(".")[-1] in CLOCK_FNS
                           and ch.split(".")[0] in ("time", "datetime"))):
                return True
    return False


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for fm in project.files:
        in_bench = "benchmarks" in os.path.normpath(
            fm.relpath).split(os.sep)
        for node in ast.walk(fm.tree):
            if not isinstance(node, ast.Call):
                continue
            f = _check_call(fm, node, in_bench)
            if f is not None:
                findings.append(f)
    return findings


def _check_call(fm: FileModel, call: ast.Call,
                in_bench: bool) -> Optional[Finding]:
    ch = attr_chain(call.func)
    if ch is None:
        return None
    parts = ch.split(".")
    scope = None

    # np.random.<sampler>(...) via module-global state
    if len(parts) >= 2 and parts[-2] == "random" \
            and parts[0] in ("np", "numpy") and parts[-1] in NP_GLOBAL_FNS:
        scope = _scope_of(fm, call)
        return fm.finding(
            RULE_GLOBAL, call, scope,
            f"np.random.{parts[-1]} uses module-global RNG state; thread "
            f"an explicit np.random.default_rng(seed) Generator instead")

    # bare random.<fn>(...)
    if len(parts) == 2 and parts[0] == "random" \
            and parts[1] in PY_RANDOM_FNS:
        scope = _scope_of(fm, call)
        return fm.finding(
            RULE_GLOBAL, call, scope,
            f"random.{parts[1]} uses the process-global stdlib RNG; use a "
            f"seeded np.random.default_rng or random.Random(seed)")

    # default_rng() / RandomState() / Random() — seed policing
    ctor = parts[-1]
    if ctor in ("default_rng", "RandomState") or ch in ("random.Random",):
        if not call.args and not call.keywords:
            scope = _scope_of(fm, call)
            return fm.finding(
                RULE_UNSEEDED, call, scope,
                f"{ctor}() with no seed draws fresh OS entropy — "
                f"unreproducible; pass an explicit seed or SeedSequence")
        if not in_bench and call.args and _has_clock(call.args[0]):
            scope = _scope_of(fm, call)
            return fm.finding(
                RULE_CLOCK, call, scope,
                f"{ctor}(<clock>) derives the seed from wall time — "
                f"reproducible only within the same tick; thread a fixed "
                f"seed (clock seeds are allowed only under benchmarks/)")
    if ctor == "SeedSequence" and not in_bench and call.args \
            and _has_clock(call.args[0]):
        scope = _scope_of(fm, call)
        return fm.finding(
            RULE_CLOCK, call, scope,
            "SeedSequence(<clock>) derives entropy from wall time; pass a "
            "fixed seed outside benchmarks/")
    return None
