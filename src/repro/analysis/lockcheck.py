"""Runtime lock-order checker — the dynamic half of the lock rules.

The static pass (:mod:`check_locks`) over-approximates: it sees every
path the source spells.  This module under-approximates: it records
what actually happened.  The two must agree on the known-bad fixture
corpus (the PR 9 ``add_done_callback``-under-lock deadlock is flagged
statically AND caught here in the same test run), and the concurrency
suites run under it to certify the REAL interleavings stayed clean.

While installed (see the ``lockcheck`` conftest fixture):

* ``threading.Lock`` / ``threading.RLock`` constructed from ``repro.*``
  code return instrumented wrappers named after their creation site
  (``repro.runtime.engine:113``) — one node per construction site, so
  every instance of a class shares a node and the graph expresses
  class-level lock ORDER, which is what deadlock-freedom is about.
* each thread keeps a held-stack; acquiring B with A on top records
  the edge A -> B.  :meth:`LockCheck.assert_acyclic` (called at
  fixture teardown) fails the test if the recorded order graph has a
  cycle — two threads that each saw half of a conflicting order are
  enough, no actual deadlock required.
* ``ThreadPoolExecutor.submit`` and ``Future.add_done_callback``
  called from ``repro.*`` with any lock held are recorded as
  held-across events (``submit`` is risk evidence; ``add_done_callback``
  is the PR 9 self-deadlock class and fails teardown by default).

Locks created BEFORE ``install()`` are invisible — build the objects
under test inside the instrumented window.
"""
from __future__ import annotations

import concurrent.futures
import sys
import threading
from typing import Optional


def _caller_module(depth: int = 2) -> str:
    try:
        frame = sys._getframe(depth)
    except ValueError:
        return ""
    return frame.f_globals.get("__name__", "") or ""


def _caller_site(depth: int = 2) -> str:
    try:
        frame = sys._getframe(depth)
    except ValueError:
        return "?:0"
    mod = frame.f_globals.get("__name__", "?")
    return f"{mod}:{frame.f_lineno}"


class CheckedLock:
    """Wraps a real lock; reports acquisition order to the registry.

    Drop-in for Lock/RLock including use as a Condition's backing lock
    (Condition's ``_is_owned`` fallback of ``acquire(0)``/``release``
    round-trips through us consistently)."""

    def __init__(self, check: "LockCheck", name: str, inner,
                 reentrant: bool):
        self._check = check
        self._name = name
        self._inner = inner
        self._reentrant = reentrant

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._check._note_acquire(self._name, self._reentrant)
        return got

    def release(self):
        self._check._note_release(self._name)
        self._inner.release()

    def locked(self):
        return self._inner.locked()

    # Condition-backing compatibility: threading.Condition grabs these
    # off its lock when present; delegating keeps RLock recursion
    # counts correct across wait() while still reporting to the check.
    def _is_owned(self):
        if hasattr(self._inner, "_is_owned"):
            return self._inner._is_owned()
        if self._inner.acquire(False):
            self._inner.release()
            return False
        return True

    def _release_save(self):
        self._check._note_release(self._name)
        if hasattr(self._inner, "_release_save"):
            return self._inner._release_save()
        self._inner.release()
        return None

    def _acquire_restore(self, state):
        if hasattr(self._inner, "_acquire_restore"):
            self._inner._acquire_restore(state)
        else:
            self._inner.acquire()
        self._check._note_acquire(self._name, self._reentrant)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __repr__(self):
        return f"<CheckedLock {self._name} wrapping {self._inner!r}>"


class LockCheck:
    """Recorder + installer.  One instance per instrumented window."""

    def __init__(self):
        self._mu = threading.Lock()     # guards the shared records
        self._tls = threading.local()
        # (src_name, dst_name) -> first-sighting description
        self.edges: dict[tuple[str, str], str] = {}
        self.reentrant: set[str] = set()
        self.acquisitions = 0
        # (kind, held lock names, call site, thread name)
        self.held_across: list[tuple[str, tuple[str, ...], str, str]] = []
        self.wrapped = 0
        self._installed = False
        self._orig: dict = {}

    # -- per-thread stack --------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def held(self) -> tuple:
        return tuple(self._stack())

    def _note_acquire(self, name: str, reentrant: bool) -> None:
        stack = self._stack()
        top = stack[-1] if stack else None
        stack.append(name)
        with self._mu:
            self.acquisitions += 1
            if reentrant:
                self.reentrant.add(name)
            if top is not None and top != name:
                self.edges.setdefault(
                    (top, name),
                    f"{threading.current_thread().name}")

    def _note_release(self, name: str) -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == name:
                del stack[i]
                return

    def _note_event(self, kind: str) -> None:
        stack = self._stack()
        if not stack:
            return
        if not _caller_module(3).startswith(("repro.", "tests",
                                             "test_")):
            return
        with self._mu:
            self.held_across.append(
                (kind, tuple(stack), _caller_site(3),
                 threading.current_thread().name))

    # -- install / uninstall ----------------------------------------------
    def install(self) -> "LockCheck":
        assert not self._installed, "LockCheck already installed"
        self._installed = True
        check = self
        orig_lock = threading.Lock
        orig_rlock = threading.RLock
        orig_submit = concurrent.futures.ThreadPoolExecutor.submit
        orig_adc = concurrent.futures.Future.add_done_callback
        self._orig = {"Lock": orig_lock, "RLock": orig_rlock,
                      "submit": orig_submit, "add_done_callback": orig_adc}

        def make_lock(*a, **k):
            inner = orig_lock(*a, **k)
            if _caller_module(2).startswith("repro."):
                check.wrapped += 1
                return CheckedLock(check, _caller_site(2), inner,
                                   reentrant=False)
            return inner

        def make_rlock(*a, **k):
            inner = orig_rlock(*a, **k)
            if _caller_module(2).startswith("repro."):
                check.wrapped += 1
                return CheckedLock(check, _caller_site(2), inner,
                                   reentrant=True)
            return inner

        def submit(executor, fn, /, *a, **k):
            check._note_event("submit")
            return orig_submit(executor, fn, *a, **k)

        def add_done_callback(future, cb):
            check._note_event("add_done_callback")
            return orig_adc(future, cb)

        threading.Lock = make_lock
        threading.RLock = make_rlock
        concurrent.futures.ThreadPoolExecutor.submit = submit
        concurrent.futures.Future.add_done_callback = add_done_callback
        return self

    def uninstall(self) -> None:
        if not self._installed:
            return
        self._installed = False
        threading.Lock = self._orig["Lock"]
        threading.RLock = self._orig["RLock"]
        concurrent.futures.ThreadPoolExecutor.submit = self._orig["submit"]
        concurrent.futures.Future.add_done_callback = \
            self._orig["add_done_callback"]

    def __enter__(self):
        return self.install()

    def __exit__(self, *exc):
        self.uninstall()
        return False

    # -- verdicts ----------------------------------------------------------
    def find_cycle(self) -> Optional[list[str]]:
        """A lock-order cycle in the recorded graph, or None."""
        adj: dict[str, set[str]] = {}
        for (src, dst) in self.edges:
            adj.setdefault(src, set()).add(dst)
            adj.setdefault(dst, set())
        WHITE, GREY, BLACK = 0, 1, 2
        color = {v: WHITE for v in adj}
        parent: dict[str, Optional[str]] = {}

        for root in sorted(adj):
            if color[root] != WHITE:
                continue
            stack = [(root, iter(sorted(adj[root])))]
            color[root] = GREY
            parent[root] = None
            while stack:
                v, it = stack[-1]
                for w in it:
                    if color[w] == WHITE:
                        color[w] = GREY
                        parent[w] = v
                        stack.append((w, iter(sorted(adj[w]))))
                        break
                    if color[w] == GREY:
                        cyc = [w]
                        node = v
                        while node is not None and node != w:
                            cyc.append(node)
                            node = parent[node]
                        cyc.reverse()
                        return cyc
                else:
                    color[v] = BLACK
                    stack.pop()
        return None

    def callbacks_under_lock(self) -> list:
        return [e for e in self.held_across if e[0] == "add_done_callback"]

    def submits_under_lock(self) -> list:
        return [e for e in self.held_across if e[0] == "submit"]

    def assert_acyclic(self) -> None:
        cyc = self.find_cycle()
        assert cyc is None, (
            f"lock-order cycle observed at runtime: {' -> '.join(cyc)} -> "
            f"{cyc[0]} (edges: {sorted(self.edges)})")

    def verify(self, *, allow_submit_under_lock: bool = True) -> None:
        """Teardown verdict: acyclic order graph, and no callback
        registered with a lock held (the PR 9 class).  Submit-under-
        lock is risk evidence, not a deadlock by itself — opt in to
        strictness via ``allow_submit_under_lock=False``."""
        self.assert_acyclic()
        bad = self.callbacks_under_lock()
        assert not bad, (
            f"add_done_callback with lock(s) held — a finished future "
            f"runs the callback inline on the registering thread "
            f"(PR 9 deadlock class): {bad}")
        if not allow_submit_under_lock:
            subs = self.submits_under_lock()
            assert not subs, f"executor.submit with lock(s) held: {subs}"
