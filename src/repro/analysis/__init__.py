"""repro.analysis — concurrency- and invariant-aware static analysis.

The serving stack is a genuinely concurrent system: nine modules hold
locks, with poller threads, shard workers, and audit executors.  PR 9
paid for that the hard way (an ``add_done_callback``-inside-lock
deadlock wedged the poller).  This package turns the repo's
conventions — no blocking calls under locks, bounded buffers
everywhere, seeded determinism, no host syncs inside jit — into
machine-checked rules:

* ``python -m repro.analysis.lint src/ tests/`` — the AST lint pass
  (see :mod:`repro.analysis.lint`); exits non-zero on any finding not
  waived inline or recorded in ``baseline.json``.
* :mod:`repro.analysis.lockcheck` — the runtime companion: an
  instrumented ``Lock``/``RLock`` wrapper that records the *actual*
  acquisition order and held-across-submit events during tests and
  asserts the lock graph is acyclic at teardown (the ``lockcheck``
  conftest fixture).

Rules, rationale, and waiver syntax are documented in
``docs/invariants.md``.

This package deliberately imports nothing heavyweight: the linter
parses source, it never imports the code under analysis.
"""
from .core import Finding, collect_files, load_file  # noqa: F401
