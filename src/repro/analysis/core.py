"""Shared lint plumbing: findings, inline waivers, and the baseline.

A :class:`Finding` is keyed by ``rule | relpath | scope | normalized
source line`` — deliberately NOT by line number, so the baseline
survives unrelated edits above a finding.  Two suppression mechanisms:

* inline waiver — ``# lint: waive[rule-id] reason`` on the offending
  line (or alone on the line above); ``waive[*]`` waives every rule.
  ``# lint: bounded-by(reason)`` is the bounded-memory rule's waiver:
  it asserts the buffer is bounded by construction and says why.
* baseline — ``analysis/baseline.json`` holds keys of known findings;
  the CI gate is zero NEW findings, so the baseline ships empty or
  near-empty and anything in it is a documented debt, not a dumping
  ground.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Optional

WAIVE_RE = re.compile(r"#\s*lint:\s*waive\[([*\w\-, ]+)\]\s*(.*)")
BOUNDED_RE = re.compile(r"#\s*lint:\s*bounded-by\(([^)]*)\)")
EXPECT_RE = re.compile(r"#\s*expect:\s*([\w\-, ]+)")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str                 # repo-relative path
    line: int
    scope: str                # "Class.method" / "module" / function name
    message: str
    source: str = ""          # stripped offending source line

    @property
    def key(self) -> str:
        norm = " ".join(self.source.split())
        return f"{self.rule}|{self.path}|{self.scope}|{norm}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.message}"
                + (f"\n    {self.source.strip()}" if self.source else ""))

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


class FileModel:
    """One parsed source file plus its comment-level lint directives."""

    def __init__(self, path: str, relpath: str, source: str):
        self.path = path
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        # line -> set of waived rule ids ('*' = all)
        self.waivers: dict[int, set[str]] = {}
        # line -> bounded-by reason (bounded-memory waiver)
        self.bounded: dict[int, str] = {}
        # line -> expected rule ids (fixture corpus self-test)
        self.expects: dict[int, set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = WAIVE_RE.search(text)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                target = i
                if text.lstrip().startswith("#"):
                    target = i + 1      # comment-only line waives the next
                self.waivers.setdefault(target, set()).update(rules)
            m = BOUNDED_RE.search(text)
            if m:
                target = i
                if text.lstrip().startswith("#"):
                    target = i + 1
                self.bounded[target] = m.group(1).strip()
            m = EXPECT_RE.search(text)
            if m:
                self.expects[i] = {r.strip() for r in m.group(1).split(",")
                                   if r.strip()}

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def waived(self, finding: Finding) -> bool:
        rules = self.waivers.get(finding.line, ())
        return "*" in rules or finding.rule in rules

    def finding(self, rule: str, node: ast.AST, scope: str,
                message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=rule, path=self.relpath, line=line, scope=scope,
                       message=message, source=self.line_text(line))


def collect_files(paths: list[str], *, root: str = ".",
                  include_fixtures: bool = False) -> list[str]:
    """Expand files/dirs into a sorted list of ``.py`` paths.  The
    known-bad fixture corpus is excluded unless explicitly requested
    (``--self-test`` turns it back on)."""
    out: list[str] = []
    for p in paths:
        p = os.path.join(root, p) if not os.path.isabs(p) else p
        if os.path.isfile(p) and p.endswith(".py"):
            out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    if not include_fixtures:
        out = [p for p in out
               if "fixtures" not in os.path.normpath(p).split(os.sep)]
    seen: set[str] = set()
    uniq = []
    for p in out:
        rp = os.path.realpath(p)
        if rp not in seen:
            seen.add(rp)
            uniq.append(p)
    return uniq


def load_file(path: str, *, root: str = ".") -> Optional[FileModel]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    rel = os.path.relpath(path, root)
    try:
        return FileModel(path, rel, source)
    except SyntaxError:
        return None


def load_baseline(path: str) -> set[str]:
    if not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    return set(data.get("findings", []))


def save_baseline(path: str, findings: list[Finding]) -> None:
    keys = sorted({f.key for f in findings})
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"version": 1, "findings": keys}, f, indent=2)
        f.write("\n")
