"""CLI: ``python -m repro.analysis.lint src/ tests/``.

Exit status is the CI gate: 0 when every finding is either waived
inline or present in the checked-in baseline, 1 otherwise.  Modes:

* default — scan the given paths (fixtures excluded), print new
  findings, exit non-zero if any.
* ``--json PATH`` — also dump the full findings report (new, waived,
  and baselined, each tagged) for the CI artifact.
* ``--write-baseline`` — rewrite the baseline from the current scan
  (for intentional debt; keep it near-empty).
* ``--self-test`` — scan ONLY the known-bad fixture corpus and
  require the produced findings to match the ``# expect: rule-id``
  annotations exactly, both directions (a missed expectation or an
  unexpected finding fails).  This pins the analyzer's behavior: the
  fixtures are the regression corpus for the PR 9 deadlock class and
  friends.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from . import check_bounded, check_determinism, check_jit, check_locks
from .core import (
    Finding, collect_files, load_baseline, load_file, save_baseline,
)
from .project import Project

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")
FIXTURES_DIR = os.path.join(os.path.dirname(__file__), "fixtures")


def run_checkers(files: list) -> list[Finding]:
    project = Project(files)
    findings: list[Finding] = []
    lock_findings, _ = check_locks.check(project)
    findings.extend(lock_findings)
    findings.extend(check_bounded.check(project))
    findings.extend(check_determinism.check(project))
    findings.extend(check_jit.check(project))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def scan(paths: list[str], *, root: str = ".",
         include_fixtures: bool = False):
    """-> (all findings, file models) for ``paths``."""
    models = []
    for p in collect_files(paths, root=root,
                           include_fixtures=include_fixtures):
        fm = load_file(p, root=root)
        if fm is not None:
            models.append(fm)
    return run_checkers(models), models


def split_findings(findings: list[Finding], models: list,
                   baseline: set[str]):
    by_path = {fm.relpath: fm for fm in models}
    new, waived, baselined = [], [], []
    for f in findings:
        fm = by_path.get(f.path)
        if fm is not None and fm.waived(f):
            waived.append(f)
        elif f.key in baseline:
            baselined.append(f)
        else:
            new.append(f)
    return new, waived, baselined


def self_test() -> int:
    """Fixture-corpus agreement check (see module doc)."""
    findings, models = scan([FIXTURES_DIR], include_fixtures=True)
    got: dict[tuple[str, int], set[str]] = {}
    for f in findings:
        got.setdefault((f.path, f.line), set()).add(f.rule)
    want: dict[tuple[str, int], set[str]] = {}
    for fm in models:
        for line, rules in fm.expects.items():
            want[(fm.relpath, line)] = set(rules)
    ok = True
    for key in sorted(set(want) | set(got)):
        w, g = want.get(key, set()), got.get(key, set())
        if w != g:
            ok = False
            path, line = key
            print(f"SELF-TEST MISMATCH {path}:{line}: "
                  f"expected {sorted(w) or '[]'}, got {sorted(g) or '[]'}")
    n_expected = sum(len(v) for v in want.values())
    if ok:
        print(f"self-test OK: {len(models)} fixture files, "
              f"{n_expected} expected findings, all matched exactly")
        return 0
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repro static analysis: lock discipline, bounded "
                    "memory, determinism, jit hazards "
                    "(rules: docs/invariants.md)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files/dirs to scan (default: src tests)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON (default: analysis/baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report ALL unwaived)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from this scan's unwaived "
                         "findings")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the full findings report to this path")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the known-bad fixture corpus produces "
                         "exactly its annotated findings")
    ap.add_argument("--fixtures", action="store_true",
                    help="include the known-bad fixture corpus in the scan "
                         "(excluded by default)")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test()

    paths = args.paths or ["src", "tests"]
    findings, models = scan(paths, include_fixtures=args.fixtures)
    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    new, waived, baselined = split_findings(findings, models, baseline)

    if args.write_baseline:
        save_baseline(args.baseline, new + baselined)
        print(f"baseline written: {len(new) + len(baselined)} findings "
              f"-> {args.baseline}")
        return 0

    if args.json_out:
        report = {
            "paths": paths,
            "counts": {"new": len(new), "waived": len(waived),
                       "baselined": len(baselined)},
            "new": [f.asdict() for f in new],
            "waived": [f.asdict() for f in waived],
            "baselined": [f.asdict() for f in baselined],
        }
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")

    for f in new:
        print(f.render())
    n_files = len(models)
    print(f"[lint] {n_files} files: {len(new)} new, {len(waived)} waived, "
          f"{len(baselined)} baselined")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
