"""Cross-module project model for the concurrency checkers.

The lock checker needs more than one file's AST: ``RebuildScheduler``
holds ``LiveFreshState.lock`` while calling into ``VersionManager``,
and whether THAT is safe depends on what ``VersionManager.swap``
acquires.  This module builds a registry of every class in the scanned
fileset — which attributes are locks / conditions / events / queues /
executors / threads / unbounded lists, and (via ``__init__`` parameter
annotations and ``self.x = ClassName(...)`` assignments) which
attributes hold instances of which other classes — so checkers can
resolve ``st.lock`` through ``st = self.lane.state`` to
``LiveFreshState.lock`` and build the static lock graph across
modules.

Resolution is deliberately conservative: an attribute chain that does
not resolve becomes an opaque per-class node, which can only MISS
edges, never invent a false cycle between real locks.
"""
from __future__ import annotations

import ast
import re
from typing import Optional

from .core import FileModel

LOCKISH_ATTR = re.compile(r"^_?\w*lock$")
QUEUE_TYPES = {"Queue", "SimpleQueue", "LifoQueue", "PriorityQueue"}
EXECUTOR_TYPES = {"ThreadPoolExecutor", "ProcessPoolExecutor"}


def call_name(node: ast.AST) -> Optional[str]:
    """Terminal name of a Call's func: ``threading.RLock`` -> RLock."""
    if isinstance(node, ast.Call):
        node = node.func
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def attr_chain(node: ast.AST) -> Optional[str]:
    """``self._lane.state.lock`` -> "self._lane.state.lock" (dotted
    Name/Attribute chains only; anything else is None)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def annotation_name(node: Optional[ast.AST]) -> Optional[str]:
    """Terminal class name of a simple annotation (handles Optional[X]
    / "X" string forms shallowly)."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.split("[")[-1].rstrip("]").split(".")[-1] or None
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Subscript):  # Optional[X] -> X
        sl = node.slice
        if isinstance(sl, ast.Tuple) and sl.elts:
            return annotation_name(sl.elts[0])
        return annotation_name(sl)
    return None


class ClassInfo:
    def __init__(self, name: str, fm: FileModel, node: ast.ClassDef):
        self.name = name
        self.file = fm
        self.node = node
        self.lock_attrs: dict[str, str] = {}      # attr -> "lock"|"rlock"
        self.cond_attrs: dict[str, Optional[str]] = {}  # attr -> backing
        self.event_attrs: set[str] = set()
        self.queue_attrs: set[str] = set()
        self.executor_attrs: set[str] = set()
        self.thread_attrs: set[str] = set()
        self.attr_types: dict[str, str] = {}      # attr -> class name
        self.list_attrs: dict[str, int] = {}      # attr -> init lineno
        self.bounded_attrs: set[str] = set()
        self.trimmed_attrs: set[str] = set()
        self.methods: dict[str, ast.FunctionDef] = {}
        # method -> set of lock node ids it acquires directly
        self.direct_locks: dict[str, set[str]] = {}

    def lock_node(self, attr: str) -> str:
        return f"{self.name}.{attr}"


class Project:
    """Registry of every class across the scanned files."""

    def __init__(self, files: list[FileModel]):
        self.files = files
        self.classes: dict[str, ClassInfo] = {}   # by class name
        for fm in files:
            for node in ast.walk(fm.tree):
                if isinstance(node, ast.ClassDef):
                    self._collect(fm, node)
        for ci in self.classes.values():
            self._collect_direct_locks(ci)

    # -- class harvesting --------------------------------------------------
    def _collect(self, fm: FileModel, node: ast.ClassDef) -> None:
        ci = ClassInfo(node.name, fm, node)
        # first collector wins on name collisions (names are unique in
        # this repo; a collision would only blur cross-class resolution)
        self.classes.setdefault(node.name, ci)
        is_dataclass = any("dataclass" in (ast.unparse(d) if d else "")
                           for d in node.decorator_list)
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ci.methods[stmt.name] = stmt
            elif is_dataclass and isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name):
                self._classify_attr(ci, stmt.target.id, stmt.value,
                                    stmt.lineno, param_ann=None)
        for mname, fn in ci.methods.items():
            ann = {a.arg: annotation_name(a.annotation)
                   for a in fn.args.args + fn.args.kwonlyargs}
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Assign) and len(sub.targets) == 1:
                    tgt = sub.targets[0]
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        self._classify_attr(ci, tgt.attr, sub.value,
                                            sub.lineno, param_ann=ann)
                elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
                    tgt = sub.target
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        self._classify_attr(ci, tgt.attr, sub.value,
                                            sub.lineno, param_ann=ann)
            self._collect_trims(ci, fn)
        # an attr both init'd unbounded and visibly trimmed is bounded
        for attr in list(ci.list_attrs):
            if attr in ci.trimmed_attrs or attr in ci.bounded_attrs:
                ci.list_attrs.pop(attr, None)
                ci.bounded_attrs.add(attr)

    def _classify_attr(self, ci: ClassInfo, attr: str, value: ast.AST,
                       lineno: int, param_ann: Optional[dict]) -> None:
        if value is None:
            return
        bounded_here = lineno in ci.file.bounded
        if isinstance(value, ast.List) and not value.elts:
            if bounded_here:
                ci.bounded_attrs.add(attr)
            else:
                ci.list_attrs[attr] = lineno
            return
        if isinstance(value, ast.Name) and param_ann:
            t = param_ann.get(value.id)
            if t:
                ci.attr_types.setdefault(attr, t)
            return
        if not isinstance(value, ast.Call):
            return
        name = call_name(value)
        kwargs = {k.arg for k in value.keywords}
        if name in ("Lock", "RLock") and self._is_threading(value.func):
            ci.lock_attrs[attr] = "rlock" if name == "RLock" else "lock"
        elif name == "Condition":
            backing = None
            if value.args:
                ch = attr_chain(value.args[0])
                if ch and ch.startswith("self."):
                    backing = ch.split(".", 1)[1]
            ci.cond_attrs[attr] = backing
        elif name == "Event":
            ci.event_attrs.add(attr)
        elif name in QUEUE_TYPES:
            ci.queue_attrs.add(attr)
        elif name in EXECUTOR_TYPES:
            ci.executor_attrs.add(attr)
        elif name == "Thread":
            ci.thread_attrs.add(attr)
        elif name in ("list",) and not value.args:
            if bounded_here:
                ci.bounded_attrs.add(attr)
            else:
                ci.list_attrs[attr] = lineno
        elif name == "deque":
            if "maxlen" in kwargs or bounded_here:
                ci.bounded_attrs.add(attr)
            else:
                ci.list_attrs[attr] = lineno
        elif name == "field":
            factory = next((k.value for k in value.keywords
                            if k.arg == "default_factory"), None)
            fname = call_name(factory) if factory is not None else None
            if isinstance(factory, ast.Name):
                fname = factory.id
            if fname == "list":
                if bounded_here:
                    ci.bounded_attrs.add(attr)
                else:
                    ci.list_attrs[attr] = lineno
        elif name and name[0].isupper():
            ci.attr_types.setdefault(attr, name)

    @staticmethod
    def _is_threading(func: ast.AST) -> bool:
        ch = attr_chain(func)
        return ch in ("threading.Lock", "threading.RLock", "Lock", "RLock",
                      "_thread.allocate_lock")

    def _collect_trims(self, ci: ClassInfo, fn: ast.FunctionDef) -> None:
        """A class that visibly shrinks ``self.x`` anywhere bounds it:
        ``del self.x[...]``, ``.pop/.popleft/.clear/.remove``, slice
        reassignment (``self.x = self.x[-k:]`` / ``self.x[:] = ...``)."""
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Delete):
                for t in sub.targets:
                    if isinstance(t, ast.Subscript):
                        ch = attr_chain(t.value)
                        if ch and ch.startswith("self."):
                            ci.trimmed_attrs.add(ch.split(".", 1)[1])
            elif isinstance(sub, ast.Call):
                if isinstance(sub.func, ast.Attribute) and sub.func.attr in (
                        "pop", "popleft", "clear", "remove"):
                    ch = attr_chain(sub.func.value)
                    if ch and ch.startswith("self."):
                        ci.trimmed_attrs.add(ch.split(".", 1)[1])
            elif isinstance(sub, ast.Assign):
                for t in sub.targets:
                    ch = attr_chain(t) if not isinstance(t, ast.Subscript) \
                        else attr_chain(t.value)
                    if not (ch and ch.startswith("self.")):
                        continue
                    attr = ch.split(".", 1)[1]
                    if isinstance(t, ast.Subscript):
                        ci.trimmed_attrs.add(attr)     # self.x[:] = ...
                    elif isinstance(sub.value, ast.Subscript):
                        ci.trimmed_attrs.add(attr)     # self.x = self.x[-k:]

    # -- lock acquisition model -------------------------------------------
    def _collect_direct_locks(self, ci: ClassInfo) -> None:
        from .check_locks import direct_lock_ids  # circular-free late import
        for mname, fn in ci.methods.items():
            ci.direct_locks[mname] = direct_lock_ids(self, ci, fn)

    # -- type resolution ---------------------------------------------------
    def resolve_type(self, expr: ast.AST, ci: Optional[ClassInfo],
                     local_types: dict) -> Optional[str]:
        """Class name of ``expr``'s value, or None.  Handles ``self``,
        annotated locals, and attribute chains through the registry
        (``self.lane.state`` -> UpdateLane -> LiveFreshState)."""
        if isinstance(expr, ast.Name):
            if expr.id == "self" and ci is not None:
                return ci.name
            return local_types.get(expr.id)
        if isinstance(expr, ast.Attribute):
            base = self.resolve_type(expr.value, ci, local_types)
            if base and base in self.classes:
                return self.classes[base].attr_types.get(expr.attr)
            return None
        if isinstance(expr, ast.Call):
            name = call_name(expr)
            if name and name in self.classes:
                return name
        return None

    def local_types(self, ci: Optional[ClassInfo],
                    fn: ast.FunctionDef) -> dict:
        """Best-effort local-variable class map from parameter
        annotations and simple ``x = <resolvable>`` assignments."""
        out: dict[str, str] = {}
        for a in fn.args.args + fn.args.kwonlyargs:
            t = annotation_name(a.annotation)
            if t:
                out[a.arg] = t
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Assign) and len(sub.targets) == 1 \
                    and isinstance(sub.targets[0], ast.Name):
                t = self.resolve_type(sub.value, ci, out)
                if t:
                    out[sub.targets[0].id] = t
        return out
