"""Rule group 4 — jit hazards.

Inside a function handed to ``jax.jit`` (or a Pallas kernel via
``pl.pallas_call``), a traced value has no concrete contents: forcing
one to host (``.item()``, ``float(x)``, ``np.asarray(x)``) inserts a
device sync in the middle of the traced computation (the PR 5 hot-path
stall class), and Python ``if``/``while`` on one either fails to trace
or silently bakes in the warmup value.  Two rules:

* ``jit-host-sync`` — ``.item()`` anywhere in a jitted function;
  ``float()/int()/bool()`` or ``np.asarray/np.array`` applied to a
  traced parameter.
* ``jit-python-branch`` — an ``if``/``while`` test that references a
  traced parameter directly.  Shape-derived tests (``x.shape``,
  ``x.ndim``, ``x.dtype``, ``x.size``), ``is None`` checks, and
  ``isinstance`` are static under trace and exempt.

"Traced parameter" excludes names listed in ``static_argnames`` /
``static_argnums`` on the jit decorator and arguments pre-bound by a
``functools.partial`` (partial-bound values are Python constants at
trace time).  Jitted functions are found three ways: decorator form
(``@jax.jit`` / ``@functools.partial(jax.jit, ...)``), call-wrapping
(``jax.jit(fn)`` / ``jax.jit(functools.partial(fn, ...))``), and the
kernel argument of ``pl.pallas_call``.
"""
from __future__ import annotations

import ast
from typing import Optional

from .core import FileModel, Finding
from .project import Project, attr_chain

RULE_SYNC = "jit-host-sync"
RULE_BRANCH = "jit-python-branch"

STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}


def _is_jit_expr(node: ast.AST) -> bool:
    ch = attr_chain(node)
    return ch in ("jax.jit", "jit")


def _is_pallas_call(node: ast.AST) -> bool:
    ch = attr_chain(node)
    return ch is not None and ch.endswith("pallas_call")


def _const_str_tuple(node: ast.AST) -> set[str]:
    out: set[str] = set()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        out.add(node.value)
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.add(e.value)
    return out


def _jit_call_static(call: ast.Call) -> tuple[set[str], set[int]]:
    """static_argnames / static_argnums from a jax.jit(...) call or a
    functools.partial(jax.jit, ...) decorator."""
    names: set[str] = set()
    nums: set[int] = set()
    for k in call.keywords:
        if k.arg == "static_argnames":
            names |= _const_str_tuple(k.value)
        elif k.arg == "static_argnums":
            if isinstance(k.value, ast.Constant) \
                    and isinstance(k.value.value, int):
                nums.add(k.value.value)
            elif isinstance(k.value, (ast.Tuple, ast.List)):
                for e in k.value.elts:
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, int):
                        nums.add(e.value)
    return names, nums


class _JittedFn:
    def __init__(self, fn: ast.FunctionDef, static_names: set[str],
                 static_nums: set[int], bound_pos: int,
                 bound_kw: set[str], kind: str):
        self.fn = fn
        self.kind = kind
        params = [a.arg for a in fn.args.args]
        self.traced: set[str] = set()
        for i, p in enumerate(params):
            if p in static_names or i in static_nums:
                continue
            if i < bound_pos or p in bound_kw:
                continue            # partial-bound -> trace-time constant
            self.traced.add(p)
        for a in fn.args.kwonlyargs:
            if a.arg not in static_names and a.arg not in bound_kw:
                self.traced.add(a.arg)


def _find_jitted(fm: FileModel) -> list[_JittedFn]:
    fns: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(fm.tree):
        if isinstance(node, ast.FunctionDef):
            fns.setdefault(node.name, node)
    out: list[_JittedFn] = []
    seen: set[int] = set()

    def add(fn: ast.FunctionDef, names: set[str], nums: set[int],
            bound_pos: int = 0, bound_kw: Optional[set] = None,
            kind: str = "jit") -> None:
        if id(fn) in seen:
            return
        seen.add(id(fn))
        out.append(_JittedFn(fn, names, nums, bound_pos,
                             bound_kw or set(), kind))

    # decorator forms
    for node in ast.walk(fm.tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        for dec in node.decorator_list:
            if _is_jit_expr(dec):
                add(node, set(), set())
            elif isinstance(dec, ast.Call):
                if _is_jit_expr(dec.func):
                    names, nums = _jit_call_static(dec)
                    add(node, names, nums)
                elif attr_chain(dec.func) in ("functools.partial",
                                              "partial") \
                        and dec.args and _is_jit_expr(dec.args[0]):
                    names, nums = _jit_call_static(dec)
                    add(node, names, nums)

    # call-wrapping: jax.jit(fn) / jax.jit(functools.partial(fn, ...))
    # and pl.pallas_call(kernel, ...)
    for node in ast.walk(fm.tree):
        if not isinstance(node, ast.Call):
            continue
        if _is_jit_expr(node.func) and node.args:
            target = node.args[0]
            names, nums = _jit_call_static(node)
            if isinstance(target, ast.Name) and target.id in fns:
                add(fns[target.id], names, nums)
            elif isinstance(target, ast.Call) \
                    and attr_chain(target.func) in ("functools.partial",
                                                    "partial") \
                    and target.args \
                    and isinstance(target.args[0], ast.Name) \
                    and target.args[0].id in fns:
                bound_kw = {k.arg for k in target.keywords if k.arg}
                add(fns[target.args[0].id], names, nums,
                    bound_pos=len(target.args) - 1, bound_kw=bound_kw)
        elif _is_pallas_call(node.func) and node.args:
            target = node.args[0]
            if isinstance(target, ast.Name) and target.id in fns:
                add(fns[target.id], set(), set(), kind="pallas")
            elif isinstance(target, ast.Call) \
                    and attr_chain(target.func) in ("functools.partial",
                                                    "partial") \
                    and target.args \
                    and isinstance(target.args[0], ast.Name) \
                    and target.args[0].id in fns:
                bound_kw = {k.arg for k in target.keywords if k.arg}
                add(fns[target.args[0].id], set(), set(),
                    bound_pos=len(target.args) - 1, bound_kw=bound_kw,
                    kind="pallas")
    return out


def _refs_traced(expr: ast.AST, traced: set[str]) -> Optional[str]:
    """Name of a traced param referenced 'raw' in ``expr`` — ignoring
    static projections (.shape/.ndim/.dtype/.size), `is None` tests,
    and isinstance checks."""
    skip: set[int] = set()
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Attribute) and sub.attr in STATIC_ATTRS:
            for inner in ast.walk(sub.value):
                skip.add(id(inner))
        elif isinstance(sub, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot)) for op in sub.ops):
            for inner in ast.walk(sub):
                skip.add(id(inner))
        elif isinstance(sub, ast.Call):
            fname = attr_chain(sub.func)
            if fname in ("isinstance", "len", "getattr", "hasattr"):
                for inner in ast.walk(sub):
                    skip.add(id(inner))
    for sub in ast.walk(expr):
        if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load) \
                and sub.id in traced and id(sub) not in skip:
            return sub.id
    return None


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for fm in project.files:
        for jf in _find_jitted(fm):
            findings.extend(_check_jitted(fm, jf))
    return findings


def _check_jitted(fm: FileModel, jf: _JittedFn) -> list[Finding]:
    out: list[Finding] = []
    fn = jf.fn
    scope = fn.name
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "item":
                out.append(fm.finding(
                    RULE_SYNC, node, scope,
                    f".item() inside jitted `{fn.name}` forces a host "
                    f"sync mid-trace"))
                continue
            ch = attr_chain(node.func)
            if ch in ("float", "int", "bool") and node.args \
                    and _refs_traced(node.args[0], jf.traced):
                out.append(fm.finding(
                    RULE_SYNC, node, scope,
                    f"{ch}() on traced value "
                    f"`{_refs_traced(node.args[0], jf.traced)}` inside "
                    f"jitted `{fn.name}` forces a host sync"))
            elif ch in ("np.asarray", "np.array", "numpy.asarray",
                        "numpy.array", "np.ascontiguousarray") \
                    and node.args \
                    and _refs_traced(node.args[0], jf.traced):
                out.append(fm.finding(
                    RULE_SYNC, node, scope,
                    f"{ch} on traced value inside jitted `{fn.name}` "
                    f"pulls the array to host mid-trace; use jnp"))
        elif isinstance(node, (ast.If, ast.While)):
            name = _refs_traced(node.test, jf.traced)
            if name:
                out.append(fm.finding(
                    RULE_BRANCH, node, scope,
                    f"Python branch on traced value `{name}` inside "
                    f"jitted `{fn.name}`; use lax.cond/select or hoist "
                    f"the decision out of the traced function"))
    return out
