"""Determinism violations: module-global RNG state (np + stdlib), an
unseeded generator, and a clock-derived seed — each makes a reported
recall/latency number unreproducible."""
import random
import time

import numpy as np


def sample_noise(n):
    return np.random.normal(size=n)  # expect: global-rng


def fresh_stream():
    return np.random.default_rng()  # expect: unseeded-rng


def clock_stream():
    return np.random.default_rng(time.time_ns())  # expect: clock-seed


def pick(items):
    return random.choice(items)  # expect: global-rng
