"""The grow-forever buffer class PR 7 eradicated: a serving-daemon
object that accretes one entry per request with no ring trim, no
``deque(maxlen)``, and no ``bounded-by`` justification — memory scales
with uptime."""


class GrowForever:
    def __init__(self):
        self.log = []
        self.seen = 0

    def record(self, item):
        self.seen += 1
        self.log.append(item)  # expect: unbounded-growth
