"""The legal twins of every bad fixture — zero findings expected.
Submit-under-lock with the callback registered AFTER release (the PR 9
fix shape from ``obs/quality.py``), ``Condition.wait_for`` on the lock
it is backed by, a ``deque(maxlen)`` buffer, and a seeded generator.
"""
import collections
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np


class CleanAuditor:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._exec = ThreadPoolExecutor(max_workers=1)
        self._pending = collections.deque(maxlen=64)
        self._rng = np.random.default_rng(7)

    def submit_audit(self, fn):
        with self._lock:
            fut = self._exec.submit(fn)
            self._pending.append(fut)
        fut.add_done_callback(self._done)  # outside the lock: legal
        return fut

    def _done(self, fut):
        with self._lock:
            self._cv.notify_all()

    def wait_done(self, timeout=1.0):
        with self._cv:
            # waiting on the condition backed by the held lock: legal
            return self._cv.wait_for(lambda: not self._pending, timeout)

    def sample(self, n):
        return self._rng.normal(size=n)
