"""Conflicting acquisition orders: ``forward`` takes A then B,
``backward`` takes B then A.  Two threads each half-way through is a
deadlock; the static lock graph has the cycle A -> B -> A whether or
not any test ever hits the interleaving.  ``double`` re-enters a
non-reentrant Lock on the same thread — a guaranteed self-deadlock.
"""
import threading


class LockCycle:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()
        self.hits = 0

    def forward(self):
        with self._a:
            with self._b:
                self.hits += 1

    def backward(self):
        with self._b:
            with self._a:  # expect: lock-order-cycle
                self.hits -= 1

    def double(self):
        with self._a:
            with self._a:  # expect: lock-order-cycle
                return self.hits
