"""The PR 9 deadlock, reproduced: a future's completion callback is
registered while the submitting thread holds the lock the callback
itself needs.  ``concurrent.futures`` runs the callback INLINE on the
registering thread when the future is already finished — with a
non-reentrant Lock held, ``_done``'s ``with self._lock:`` never
returns and the poller wedges on one core.

The fix shape lives in ``obs/quality.py`` (and the ``good_clean``
fixture): submit and bookkeep under the lock, register the callback
after releasing.
"""
import threading
from concurrent.futures import ThreadPoolExecutor


class ShadowAuditor:
    def __init__(self):
        self._lock = threading.Lock()
        self._exec = ThreadPoolExecutor(max_workers=1)
        self._futures = set()

    def submit_audit(self, fn, *args):
        with self._lock:
            fut = self._exec.submit(fn, *args)
            self._futures.add(fut)
            fut.add_done_callback(self._done)  # expect: lock-callback-under-lock
        return fut

    def wait_all(self):
        with self._lock:
            for fut in list(self._futures):
                fut.result()  # expect: lock-blocking-call
            self._futures.clear()

    def _done(self, fut):
        with self._lock:
            self._futures.discard(fut)
