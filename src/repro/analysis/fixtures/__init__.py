"""Known-bad fixture corpus for the analyzer's self-test.

Each module reproduces one defect class the linter exists to catch —
``bad_callback_under_lock`` is the PR 9 poller deadlock, verbatim in
shape.  Offending lines carry ``expect: <rule-id>`` comment annotations;
``python -m repro.analysis.lint --self-test`` requires the produced
findings to match them exactly (both directions), and
``tests/test_analysis.py`` additionally runs the lock fixtures under
the runtime :mod:`~repro.analysis.lockcheck` to prove static findings
and runtime evidence agree.

These files are EXCLUDED from normal lint scans (any path containing
a ``fixtures`` component is skipped) and are never imported by
serving code.
"""
