"""jit hazards: host syncs and Python branches on traced values
inside a jitted function and a Pallas kernel.  Never imported — the
linter parses, it does not execute."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("k",))
def topk_host_sync(scores, k):
    if scores.ndim > 2:  # static shape projection: fine
        scores = jnp.reshape(scores, (-1, scores.shape[-1]))
    peak = jnp.max(scores).item()  # expect: jit-host-sync
    scale = float(scores[0, 0])  # expect: jit-host-sync
    host = np.asarray(scores)  # expect: jit-host-sync
    if scores > 0:  # expect: jit-python-branch
        host = host + scale
    return jnp.argsort(scores)[..., :k], peak, host


def _bad_kernel(x_ref, o_ref):
    if x_ref:  # expect: jit-python-branch
        o_ref[...] = x_ref[...] * 2.0


def launch(x):
    import jax.experimental.pallas as pl
    return pl.pallas_call(_bad_kernel, out_shape=x)(x)
