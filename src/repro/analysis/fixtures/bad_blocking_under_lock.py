"""Blocking calls inside a lock's critical section: the sleep stalls
every thread contending for the lock, the queue get can wait on a
producer that needs the same lock, and the event wait parks the
holder until a setter that may be behind the lock runs."""
import queue
import threading
import time


class BlockingUnderLock:
    def __init__(self):
        self._lock = threading.Lock()
        self._q = queue.Queue()
        self._ready = threading.Event()

    def drain_one(self):
        with self._lock:
            time.sleep(0.01)  # expect: lock-blocking-call
            item = self._q.get(timeout=1.0)  # expect: lock-blocking-call
        return item

    def sync(self):
        with self._lock:
            self._ready.wait(1.0)  # expect: lock-blocking-call
