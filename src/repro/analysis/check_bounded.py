"""Rule group 2 — bounded memory (``unbounded-growth``).

PR 7 eradicated the grow-forever buffer class from the serving
daemons (trace rings, metric reservoirs, harvest ring); this rule
keeps it dead.  In hot-path modules (runtime / distributed / obs /
lifecycle / storage), an ``.append`` / ``.extend`` / ``+=`` on an
instance-attribute list with no visible bound is a finding.  A bound
is visible when the attr is a ``deque(maxlen=...)``, the class trims
it somewhere (``del self.x[:k]``, ``.pop/.popleft/.clear``, slice
reassignment), or the growth site / init site carries a
``# lint: bounded-by(reason)`` waiver asserting why it cannot grow
without limit (e.g. "one entry per shard, shards are fixed at
deploy").

Chains one attribute deep are resolved through the class registry:
``self.stats.failovers.append(...)`` is checked against
``FabricStats.failovers`` when ``self.stats = FabricStats()``.
"""
from __future__ import annotations

import ast
import os
from typing import Optional

from .core import FileModel, Finding
from .project import ClassInfo, Project, attr_chain

RULE = "unbounded-growth"

HOT_PARTS = {"runtime", "distributed", "obs", "lifecycle", "storage",
             "fixtures"}


def _is_hot(relpath: str) -> bool:
    return bool(HOT_PARTS.intersection(
        os.path.normpath(relpath).split(os.sep)))


def _owner_attr(project: Project, ci: Optional[ClassInfo],
                target: ast.AST, local_types: dict
                ) -> Optional[tuple[ClassInfo, str]]:
    """Resolve ``self.x`` / ``self.stats.failovers`` / ``st.log`` to
    (owning ClassInfo, attr name)."""
    if not isinstance(target, ast.Attribute):
        return None
    t = project.resolve_type(target.value, ci, local_types)
    owner = project.classes.get(t) if t else None
    if owner is None:
        return None
    return owner, target.attr


def check(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for fm in project.files:
        if not _is_hot(fm.relpath):
            continue
        for cls_node in ast.walk(fm.tree):
            if not isinstance(cls_node, ast.ClassDef):
                continue
            ci = project.classes.get(cls_node.name)
            if ci is None or ci.node is not cls_node:
                continue
            for mname, fn in ci.methods.items():
                scope = f"{ci.name}.{mname}"
                local_types = project.local_types(ci, fn)
                for node in ast.walk(fn):
                    f = _check_node(project, fm, ci, scope, node,
                                    local_types)
                    if f is not None:
                        findings.append(f)
    return findings


def _check_node(project: Project, fm: FileModel, ci: ClassInfo, scope: str,
                node: ast.AST, local_types: dict) -> Optional[Finding]:
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr in ("append", "extend"):
        owner_attr = _owner_attr(project, ci, node.func.value, local_types)
    elif isinstance(node, ast.AugAssign) and isinstance(node.op, ast.Add):
        owner_attr = _owner_attr(project, ci, node.target, local_types)
    else:
        return None
    if owner_attr is None:
        return None
    owner, attr = owner_attr
    if attr not in owner.list_attrs:
        return None                      # bounded, trimmed, or not a list
    if not _is_hot(owner.file.relpath):
        return None
    if node.lineno in fm.bounded:
        return None                      # growth-site bounded-by(...)
    init_line = owner.list_attrs[attr]
    if init_line in owner.file.bounded:
        return None                      # init-site bounded-by(...)
    return fm.finding(
        RULE, node, scope,
        f"{owner.name}.{attr} grows without bound (init at "
        f"{owner.file.relpath}:{init_line}); use deque(maxlen=...), a "
        f"ring trim, or '# lint: bounded-by(reason)'")
