"""Rule group 1 — lock discipline.

Three rules, all rooted in the PR 9 incident (an ``add_done_callback``
registered inside ``QualityMonitor._lock`` ran inline on the
submitting thread when the future was already finished, re-entered
``_done``, and deadlocked the poller on a non-reentrant Lock):

* ``lock-blocking-call`` — a call that can block indefinitely made
  while a lock is held: ``time.sleep``, ``Future.result``,
  ``Thread.join``, ``Queue.get/put(block=True)``, blocking
  ``submit(block=True)``, ``executor.shutdown(wait=True)``, and
  ``Condition/Event.wait`` on anything OTHER than the lock being held
  (waiting on the condition backed by the held lock is the legal
  pattern — the wait releases it).
* ``lock-callback-under-lock`` — ``Future.add_done_callback`` while
  holding a lock.  An already-finished future runs the callback
  INLINE on the registering thread; if the callback needs the same
  lock, that is a self-deadlock (the exact PR 9 class).
* ``lock-order-cycle`` — the cross-module static lock graph (which
  locks are acquired while which are held, including one level of
  call resolution through the class registry) contains a cycle, or a
  non-reentrant lock is re-acquired while already held.

Held scopes come from ``with <lock>:`` blocks (including ``with
<condition>:``, which acquires the condition's backing lock) and from
linear ``.acquire()`` / ``.release()`` pairs — the raw-acquire region
spans from the first acquire to the last release in the function,
which over-approximates loops like ``UpdateLane.pump``'s
lock-then-recheck but never under-approximates.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Optional

from .core import FileModel, Finding
from .project import (
    ClassInfo, LOCKISH_ATTR, Project, attr_chain, call_name,
)

RULE_BLOCKING = "lock-blocking-call"
RULE_CALLBACK = "lock-callback-under-lock"
RULE_CYCLE = "lock-order-cycle"


@dataclasses.dataclass
class LockRef:
    node_id: str              # "Class.attr" or opaque "Class:chain"
    kind: str                 # "lock" | "rlock"
    resolved: bool


@dataclasses.dataclass
class Region:
    lock: LockRef
    start: int                # first line at which the lock is held
    end: int                  # last line at which it may still be held
    acq_line: int             # acquisition site (for graph edges)


def resolve_lock_expr(project: Project, ci: Optional[ClassInfo],
                      expr: ast.AST, local_types: dict
                      ) -> Optional[LockRef]:
    """Map a context/receiver expression to a lock identity.

    ``self._lock`` -> Class._lock; ``self._doorbell`` (a Condition
    built on ``self._lock``) -> Class._lock; ``st.lock`` resolves
    through the registry; otherwise any ``*lock``-named attribute
    becomes an opaque (conservatively reentrant) node."""
    if not isinstance(expr, ast.Attribute):
        return None
    attr = expr.attr
    base_t = project.resolve_type(expr.value, ci, local_types)
    owner = project.classes.get(base_t) if base_t else None
    if owner is not None:
        if attr in owner.lock_attrs:
            return LockRef(owner.lock_node(attr), owner.lock_attrs[attr],
                           True)
        if attr in owner.cond_attrs:
            backing = owner.cond_attrs[attr]
            if backing and backing in owner.lock_attrs:
                return LockRef(owner.lock_node(backing),
                               owner.lock_attrs[backing], True)
            # Condition() with its own hidden lock
            return LockRef(owner.lock_node(attr), "lock", True)
    if LOCKISH_ATTR.match(attr):
        chain = attr_chain(expr) or attr
        scope = ci.name if ci else "module"
        return LockRef(f"{scope}:{chain}", "rlock", False)
    return None


def _fn_regions(project: Project, ci: Optional[ClassInfo],
                fn: ast.FunctionDef, local_types: dict) -> list[Region]:
    regions: list[Region] = []
    acquires: dict[str, list[tuple[int, LockRef]]] = {}
    releases: dict[str, list[int]] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.With):
            for item in node.items:
                ref = resolve_lock_expr(project, ci, item.context_expr,
                                        local_types)
                if ref is not None:
                    regions.append(Region(ref, node.lineno,
                                          node.end_lineno or node.lineno,
                                          node.lineno))
        elif isinstance(node, ast.Call) and isinstance(node.func,
                                                       ast.Attribute):
            if node.func.attr == "acquire":
                ref = resolve_lock_expr(project, ci, node.func.value,
                                        local_types)
                if ref is not None:
                    acquires.setdefault(ref.node_id, []).append(
                        (node.lineno, ref))
            elif node.func.attr == "release":
                ref = resolve_lock_expr(project, ci, node.func.value,
                                        local_types)
                if ref is not None:
                    releases.setdefault(ref.node_id, []).append(node.lineno)
    for node_id, acqs in acquires.items():
        first_line, ref = min(acqs, key=lambda t: t[0])
        rels = releases.get(node_id, [])
        end = max(rels) if rels else (fn.end_lineno or first_line)
        regions.append(Region(ref, first_line, end, first_line))
    return regions


def direct_lock_ids(project: Project, ci: ClassInfo,
                    fn: ast.FunctionDef) -> set[str]:
    """Resolved lock node ids this function acquires directly (used
    for one-level call edges in the cross-class lock graph)."""
    local_types = project.local_types(ci, fn)
    return {r.lock.node_id
            for r in _fn_regions(project, ci, fn, local_types)
            if r.lock.resolved}


def _held_at(regions: list[Region], line: int,
             acq_line: Optional[int] = None) -> list[Region]:
    return [r for r in regions
            if r.start <= line <= r.end
            and (acq_line is None or r.acq_line != acq_line
                 or line != r.acq_line)]


def _kwarg(call: ast.Call, name: str):
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _is_false(node) -> bool:
    return isinstance(node, ast.Constant) and node.value is False


class LockChecker:
    """Runs the three lock rules over a Project; also exports the
    static lock graph (`edges`) for tests and for the runtime
    companion's agreement check."""

    def __init__(self, project: Project):
        self.project = project
        self.findings: list[Finding] = []
        # (src_node, dst_node) -> (relpath, line) of first sighting
        self.edges: dict[tuple[str, str], tuple[str, int]] = {}
        self.node_kinds: dict[str, str] = {}

    def run(self) -> list[Finding]:
        for fm in self.project.files:
            self._check_file(fm)
        self._check_cycles()
        return self.findings

    # -- per-function analysis --------------------------------------------
    def _check_file(self, fm: FileModel) -> None:
        for node in ast.walk(fm.tree):
            if isinstance(node, ast.ClassDef):
                ci = self.project.classes.get(node.name)
                for stmt in node.body:
                    if isinstance(stmt, ast.FunctionDef):
                        self._check_fn(fm, ci, stmt,
                                       f"{node.name}.{stmt.name}")
        # module-level functions (incl. nested defs inside them)
        for stmt in fm.tree.body:
            if isinstance(stmt, ast.FunctionDef):
                self._check_fn(fm, None, stmt, stmt.name)

    def _check_fn(self, fm: FileModel, ci: Optional[ClassInfo],
                  fn: ast.FunctionDef, scope: str) -> None:
        project = self.project
        local_types = project.local_types(ci, fn)
        regions = _fn_regions(project, ci, fn, local_types)
        for r in regions:
            self.node_kinds.setdefault(r.lock.node_id, r.lock.kind)
        if not regions:
            return
        # nested-acquisition edges (incl. same-lock re-entry)
        for r2 in regions:
            for r1 in _held_at(regions, r2.acq_line, acq_line=r2.acq_line):
                if r1 is r2:
                    continue
                if r1.lock.node_id == r2.lock.node_id:
                    if r1.lock.kind == "lock" and r1.lock.resolved:
                        self.findings.append(fm.finding(
                            RULE_CYCLE,
                            _at(r2.acq_line),
                            scope,
                            f"non-reentrant lock {r1.lock.node_id} "
                            f"re-acquired while already held "
                            f"(self-deadlock)"))
                    continue
                self.edges.setdefault(
                    (r1.lock.node_id, r2.lock.node_id),
                    (fm.relpath, r2.acq_line))
        # blocking / callback calls + one-level call edges
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            held = _held_at(regions, call.lineno)
            if not held:
                continue
            self._classify_call(fm, ci, scope, call, held, local_types)

    def _classify_call(self, fm: FileModel, ci: Optional[ClassInfo],
                       scope: str, call: ast.Call, held: list[Region],
                       local_types: dict) -> None:
        project = self.project
        held_ids = {r.lock.node_id for r in held}
        held_desc = ", ".join(sorted(held_ids))
        func = call.func
        if isinstance(func, ast.Name):
            if func.id == "sleep":
                self.findings.append(fm.finding(
                    RULE_BLOCKING, call, scope,
                    f"sleep() while holding {held_desc}"))
            return
        if not isinstance(func, ast.Attribute):
            return
        attr = func.attr
        recv = func.value
        recv_t = project.resolve_type(recv, ci, local_types)
        owner = project.classes.get(recv_t) if recv_t else None
        recv_attr = recv.attr if isinstance(recv, ast.Attribute) else None

        if attr == "sleep" and attr_chain(func) in ("time.sleep",):
            self.findings.append(fm.finding(
                RULE_BLOCKING, call, scope,
                f"time.sleep while holding {held_desc}"))
        elif attr == "result":
            self.findings.append(fm.finding(
                RULE_BLOCKING, call, scope,
                f"Future.result() while holding {held_desc} — the worker "
                f"that completes it may need the same lock"))
        elif attr == "add_done_callback":
            self.findings.append(fm.finding(
                RULE_CALLBACK, call, scope,
                f"add_done_callback while holding {held_desc}: a finished "
                f"future runs the callback inline on this thread (PR 9 "
                f"deadlock class) — register it after releasing"))
        elif attr in ("wait", "wait_for"):
            backing = None
            base_t = project.resolve_type(recv, ci, local_types)
            base_owner = project.classes.get(base_t) if base_t else None
            if base_owner is None and isinstance(recv, ast.Attribute):
                inner_t = project.resolve_type(recv.value, ci, local_types)
                base_owner = project.classes.get(inner_t) if inner_t else None
                if base_owner is not None \
                        and recv.attr in base_owner.cond_attrs:
                    b = base_owner.cond_attrs[recv.attr]
                    if b:
                        backing = base_owner.lock_node(b)
            if backing is not None and backing in held_ids:
                return          # Condition.wait on the held lock: legal
            what = ("a condition backed by a DIFFERENT lock" if backing
                    else "an event or foreign condition")
            self.findings.append(fm.finding(
                RULE_BLOCKING, call, scope,
                f".{attr}() on {what} while holding {held_desc}"))
        elif attr in ("get", "put"):
            is_queue = (owner is None and isinstance(recv, ast.Attribute)
                        and self._queue_attr(ci, recv, local_types))
            if is_queue and not _is_false(_kwarg(call, "block")):
                self.findings.append(fm.finding(
                    RULE_BLOCKING, call, scope,
                    f"blocking Queue.{attr} while holding {held_desc}"))
        elif attr == "join":
            if self._thread_recv(ci, recv, local_types):
                self.findings.append(fm.finding(
                    RULE_BLOCKING, call, scope,
                    f"Thread.join while holding {held_desc}"))
        elif attr == "shutdown":
            if self._executor_recv(ci, recv, local_types) \
                    and not _is_false(_kwarg(call, "wait")):
                self.findings.append(fm.finding(
                    RULE_BLOCKING, call, scope,
                    f"executor.shutdown(wait=True) while holding "
                    f"{held_desc}"))
        elif attr == "submit":
            blk = _kwarg(call, "block")
            if blk is not None and not _is_false(blk):
                self.findings.append(fm.finding(
                    RULE_BLOCKING, call, scope,
                    f"blocking submit while holding {held_desc} — "
                    f"backpressure waits for a consumer that may need "
                    f"the lock"))
        elif attr in ("acquire", "release"):
            return
        # one-level call edges into other classes' direct locks
        if owner is not None and attr in owner.direct_locks:
            for lid in owner.direct_locks[attr]:
                for r in held:
                    if lid != r.lock.node_id:
                        self.edges.setdefault(
                            (r.lock.node_id, lid),
                            (fm.relpath, call.lineno))

    def _queue_attr(self, ci, recv: ast.Attribute, local_types) -> bool:
        t = self.project.resolve_type(recv.value, ci, local_types)
        owner = self.project.classes.get(t) if t else None
        return owner is not None and recv.attr in owner.queue_attrs

    def _thread_recv(self, ci, recv, local_types) -> bool:
        if isinstance(recv, ast.Attribute):
            t = self.project.resolve_type(recv.value, ci, local_types)
            owner = self.project.classes.get(t) if t else None
            if owner is not None and recv.attr in owner.thread_attrs:
                return True
        t = self.project.resolve_type(recv, ci, local_types)
        return t == "Thread"

    def _executor_recv(self, ci, recv, local_types) -> bool:
        if isinstance(recv, ast.Attribute):
            t = self.project.resolve_type(recv.value, ci, local_types)
            owner = self.project.classes.get(t) if t else None
            if owner is not None and recv.attr in owner.executor_attrs:
                return True
        t = self.project.resolve_type(recv, ci, local_types)
        return t in ("ThreadPoolExecutor", "ProcessPoolExecutor")

    # -- cross-module cycle detection -------------------------------------
    def _check_cycles(self) -> None:
        adj: dict[str, set[str]] = {}
        for (src, dst) in self.edges:
            adj.setdefault(src, set()).add(dst)
            adj.setdefault(dst, set())
        for scc in _tarjan(adj):
            if len(scc) < 2:
                continue
            cyc = sorted(scc)
            # anchor at the lexicographically largest in-SCC edge — the
            # "back edge" closing the cycle — for a deterministic site
            in_scc = [(s, d) for (s, d) in self.edges
                      if s in scc and d in scc]
            anchor = max(in_scc)
            path, line = self.edges[anchor]
            self.findings.append(Finding(
                rule=RULE_CYCLE, path=path, line=line,
                scope="lock-graph",
                message=(f"lock-order cycle: {' -> '.join(cyc)} -> "
                         f"{cyc[0]} (acquisition orders conflict across "
                         f"call paths)"),
                source=self._line_at(path, line)))

    def _line_at(self, relpath: str, line: int) -> str:
        for fm in self.project.files:
            if fm.relpath == relpath:
                return fm.line_text(line)
        return ""


def _at(lineno: int):
    node = ast.Pass()
    node.lineno = lineno
    return node


def _tarjan(adj: dict[str, set[str]]) -> list[set[str]]:
    index: dict[str, int] = {}
    low: dict[str, int] = {}
    on_stack: set[str] = set()
    stack: list[str] = []
    sccs: list[set[str]] = []
    counter = [0]

    def strongconnect(v: str) -> None:
        work = [(v, iter(sorted(adj.get(v, ()))))]
        index[v] = low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack.add(v)
        while work:
            node, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(sorted(adj.get(w, ())))))
                    advanced = True
                    break
                if w in on_stack:
                    low[node] = min(low[node], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[node])
            if low[node] == index[node]:
                scc: set[str] = set()
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.add(w)
                    if w == node:
                        break
                sccs.append(scc)

    for v in sorted(adj):
        if v not in index:
            strongconnect(v)
    return sccs


def check(project: Project) -> tuple[list[Finding], LockChecker]:
    lc = LockChecker(project)
    return lc.run(), lc
