"""Streaming stage-2 shard assignment — the construction-side overlap
pipeline (the build analogue of runtime/pipeline.py's §4.1 stage protocol).

Stage 2 of ``build_index`` walks the corpus chunk by chunk and runs closure
multi-cluster assignment per chunk on device.  The pre-PR-3 path ran those
chunks as opaque thread-pool tasks: every task serialized its host slice,
its host->device stream, and its device assign.  This module re-expresses
stage 2 through the PR 2 stage protocol so the phases pipeline instead:

  ``load``     -> host materialization of the shard's vector slice +
                  ``device_put``, on a dedicated worker thread (the build
                  side's SQ/DMA engine);
  ``dispatch`` -> launch the jitted closure assignment (JAX async dispatch —
                  returns immediately, assign in flight);
  ``harvest``  -> block on the assignment, checkpoint the shard atomically
                  (``.npz`` via os.replace, same task-granular resume
                  contract as before).

``run`` double-buffers: shard i+1's load is submitted right after shard i's
assign is dispatched, so the next shard's slice/stream hides under the
in-flight device assign.  Every stage is wall-clock stamped
(:class:`ShardStageTimes`, mirroring runtime.pipeline.StageTimes) and
:func:`shard_overlap_efficiency` measures — not infers — how much of shard
i+1's load interval lands inside shard i's assign-in-flight window.

Resumability: a shard whose checkpoint already exists short-circuits the
whole chain (stamped ``resumed=True``), so a preempted build resumes at
shard granularity with a bit-identical final index (asserted by the
construction bench via index hash).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.spann_rules import closure_assign


@dataclasses.dataclass
class ShardStageTimes:
    """Wall-clock stamps of one shard through the stage-2 pipeline."""
    shard: int
    rows: int = 0                  # vectors in this shard
    bytes: int = 0                 # host slice bytes loaded + streamed
    resumed: bool = False          # checkpoint hit: no load/assign ran
    load_start: float = 0.0
    load_end: float = 0.0          # host slice materialized
    stream_end: float = 0.0        # shard on device (device_put done)
    assign_dispatch: float = 0.0
    assign_done: float = 0.0

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


@functools.partial(jax.jit, static_argnames=("eps", "max_replicas"))
def _closure_assign_jit(xc, cents, eps: float, max_replicas: int):
    return closure_assign(xc, cents, eps=eps, max_replicas=max_replicas)


@dataclasses.dataclass
class _Loaded:
    shard: int
    path: str
    dev: Optional[jax.Array]
    times: ShardStageTimes


class ShardAssignPipeline:
    """Double-buffered closure-assignment over corpus shards.

    ``x`` is the host-resident corpus (the paper's blob-store chunk source);
    ``spans``/``paths`` define each shard's slice and checkpoint file;
    centroids are streamed to device once and stay resident (the in-DRAM
    tier).  ``run`` returns the per-shard stage stamps; the assignments land
    in the checkpoint files, which ``build_index`` concatenates exactly as
    before — the pipeline changes the schedule, not the artifact.
    """

    def __init__(self, x: np.ndarray, centroids: np.ndarray,
                 spans: list, paths: list, *,
                 eps: float, max_replicas: int):
        self.x = x
        self.spans = list(spans)
        self.paths = list(paths)
        self.eps = float(eps)
        self.max_replicas = int(max_replicas)
        self.bytes_streamed = 0        # host slice bytes actually loaded —
                                       # the delta-rebuild I/O counter
                                       # (resumed/reused shards add nothing)
        self._cents_dev = jnp.asarray(np.asarray(centroids, np.float32))
        self._loader = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="shard-load")

    def close(self) -> None:
        """Release the loader thread (builds are episodic, unlike serving —
        don't leak one worker per rebuild in a long-lived daemon)."""
        self._loader.shutdown(wait=True)

    # -- stages ------------------------------------------------------------
    def _load(self, i: int) -> _Loaded:
        lo, hi = self.spans[i]
        path = self.paths[i]
        t = ShardStageTimes(shard=i, rows=hi - lo)
        if os.path.exists(path):           # task-granular resume
            t.resumed = True
            return _Loaded(i, path, None, t)
        t.load_start = time.perf_counter()
        host = np.ascontiguousarray(self.x[lo:hi])   # the host "chunk read"
        t.load_end = time.perf_counter()
        dev = jnp.asarray(host)                      # host->device stream
        t.stream_end = time.perf_counter()
        t.bytes = int(host.nbytes)
        self.bytes_streamed += t.bytes
        return _Loaded(i, path, dev, t)

    def _dispatch(self, prep: _Loaded):
        """Launch the closure assign (async — returns with assign in flight)."""
        if prep.times.resumed:
            return None
        prep.times.assign_dispatch = time.perf_counter()
        return _closure_assign_jit(prep.dev, self._cents_dev,
                                   self.eps, self.max_replicas)

    def _harvest(self, prep: _Loaded, infl) -> ShardStageTimes:
        """Block on the assign, checkpoint the shard atomically."""
        if prep.times.resumed:
            return prep.times
        a = np.asarray(infl)               # blocks until the assign lands
        prep.times.assign_done = time.perf_counter()
        tmp = prep.path + ".tmp.npz"       # .npz suffix: savez won't append
        np.savez(tmp, assign=a)
        os.replace(tmp, prep.path)
        return prep.times

    # -- driver ------------------------------------------------------------
    def run(self) -> list[ShardStageTimes]:
        """Pipelined pass over all shards: dispatch shard i, then submit
        shard i+1's load before harvesting i — load i+1 hides under the
        in-flight assign of i."""
        n = len(self.spans)
        if n == 0:
            return []
        stamps: list[ShardStageTimes] = []
        prep = self._loader.submit(self._load, 0).result()
        for i in range(n):
            infl = self._dispatch(prep)
            nxt = (self._loader.submit(self._load, i + 1)
                   if i + 1 < n else None)
            stamps.append(self._harvest(prep, infl))
            if nxt is not None:
                prep = nxt.result()
        return stamps

    def run_sequential(self) -> list[ShardStageTimes]:
        """Strictly serial chain (the A/B baseline: host idle during assign,
        device idle during load)."""
        stamps = []
        for i in range(len(self.spans)):
            prep = self._load(i)
            infl = self._dispatch(prep)
            if infl is not None:
                jax.block_until_ready(infl)
            stamps.append(self._harvest(prep, infl))
        return stamps


def _get(t, name):
    return t[name] if isinstance(t, dict) else getattr(t, name)


def pair_overlaps(stamps: list) -> list[float]:
    """Per consecutive live shard pair: seconds of shard i+1's load+stream
    interval that land inside shard i's assign-in-flight window (can be
    negative when the intervals are disjoint — the gap).  Accepts
    ShardStageTimes or their asdict() form; the single definition the
    efficiency metric, the bench, and the tests all share."""
    live = [t for t in stamps if not _get(t, "resumed")]
    return [
        min(_get(cur, "stream_end"), _get(prev, "assign_done"))
        - max(_get(cur, "load_start"), _get(prev, "assign_dispatch"))
        for prev, cur in zip(live, live[1:])
    ]


def shard_overlap_efficiency(stamps: list) -> float:
    """Fraction of load+stream seconds hidden under the previous shard's
    assign-in-flight window (0 = fully serial, ~1 = fully hidden).  Resumed
    shards contribute nothing (they never loaded)."""
    live = [t for t in stamps if not _get(t, "resumed")]
    tot = sum(max(0.0, _get(c, "stream_end") - _get(c, "load_start"))
              for c in live[1:])
    hidden = sum(max(0.0, o) for o in pair_overlaps(stamps))
    return hidden / tot if tot > 0 else 0.0


# --------------------------------------------------------------------------
# delta mode — content-addressed shard reuse (paper §6.3 freshness rebuilds)
# --------------------------------------------------------------------------
# A closure assignment is a pure function of (shard slice, centroids), so a
# rebuild only needs to restream the shards whose inputs changed: appended
# corpus rows land in new/trailing spans, everything untouched reuses its
# checkpoint byte-for-byte.  The manifest records each shard's slice hash +
# the centroid-set hash; ``plan_delta_shards`` diffs it against the current
# corpus and returns what must stream vs what is reusable — with byte
# counts, so the I/O cut is counter-asserted, not assumed.

def array_content_hash(a: np.ndarray) -> str:
    import hashlib

    a = np.ascontiguousarray(a)
    h = hashlib.sha256()
    h.update(str(a.shape).encode())
    h.update(str(a.dtype).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def make_shard_manifest(x: np.ndarray, spans: list, centroids: np.ndarray
                        ) -> dict:
    """Manifest of a completed stage 2: per-shard slice hashes + the
    centroid hash they were assigned against (JSON-serializable)."""
    return {
        "centroid_hash": array_content_hash(centroids),
        "shards": [
            {"lo": int(lo), "hi": int(hi),
             "hash": array_content_hash(x[lo:hi])}
            for lo, hi in spans
        ],
    }


@dataclasses.dataclass
class DeltaShardPlan:
    dirty: list                    # shard indices that must stream + assign
    reused: list                   # shard indices whose checkpoints hold
    bytes_dirty: int               # slice bytes the delta build will stream
    bytes_reused: int              # slice bytes reuse avoids streaming
    manifest: dict                 # manifest of the NEW build (all shards)


def plan_delta_shards(x: np.ndarray, spans: list, paths: list,
                      centroids: np.ndarray,
                      prev_manifest: Optional[dict],
                      trust_manifest: bool = True) -> DeltaShardPlan:
    """Diff the corpus against the previous build's manifest.

    A shard is reusable iff its span matches the manifest entry, the
    centroid set is unchanged, and its checkpoint file exists.  Stale
    checkpoints of dirty shards are REMOVED so the assign pipeline's
    resume short-circuit cannot serve outdated assignments.

    ``trust_manifest`` (default): a span-stable shard carries its STORED
    hash forward without re-reading the slice — correct under the
    lifecycle contract that the corpus is append-only and rows never move
    (CorpusStore), and essential at scale: re-hashing every reused shard
    would read the whole corpus per rebuild, which is exactly the I/O the
    delta build exists to avoid.  Pass False to force content
    verification (e.g. a corpus whose rows CAN mutate in place)."""
    cent_hash = array_content_hash(centroids)
    cents_ok = (prev_manifest is not None and
                prev_manifest.get("centroid_hash") == cent_hash)
    prev_shards = (prev_manifest or {}).get("shards", [])
    dirty, reused, shard_ents = [], [], []
    bytes_dirty = bytes_reused = 0
    for i, ((lo, hi), path) in enumerate(zip(spans, paths)):
        nbytes = int(x[lo:hi].nbytes)
        prev = prev_shards[i] if cents_ok and i < len(prev_shards) else None
        span_ok = (prev is not None and prev["lo"] == lo and prev["hi"] == hi
                   and os.path.exists(path))
        if span_ok and not trust_manifest:
            span_ok = prev["hash"] == array_content_hash(x[lo:hi])
        if span_ok:
            shard_ents.append(prev)    # stored hash carried forward
            reused.append(i)
            bytes_reused += nbytes
        else:
            if os.path.exists(path):
                os.remove(path)        # stale: resume must not pick it up
            shard_ents.append({"lo": int(lo), "hi": int(hi),
                               "hash": array_content_hash(x[lo:hi])})
            dirty.append(i)
            bytes_dirty += nbytes
    return DeltaShardPlan(dirty=dirty, reused=reused,
                          bytes_dirty=bytes_dirty, bytes_reused=bytes_reused,
                          manifest={"centroid_hash": cent_hash,
                                    "shards": shard_ents})
