"""Elastic construction pool (paper §5.2 / Fig. 21b).

Construction tasks are dependency-free and idempotent, so the paper runs them
on cheap preemptible workers with retry/evict/backup policies.  Two layers:

* ``run_tasks`` — the real executor: a thread pool with bounded retries for
  transient failures (preemptions surface as exceptions).
* ``SimPool``  — a discrete-event model of the same policies at 10^4-worker
  scale (preemption, flaky-node eviction, straggler backups), used to
  reproduce the Fig. 21b makespan-vs-workers curve without a cluster.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional

import numpy as np


class TaskFailed(RuntimeError):
    """A task exhausted its retry budget."""


def run_tasks(
    fns: list[Callable],
    n_workers: int = 2,
    max_attempts: int = 3,
) -> list:
    """Run callables on a thread pool; retry each up to ``max_attempts``.

    Returns results in input order; raises TaskFailed when a task keeps
    failing (construction is idempotent, so retries are safe).
    """

    def attempt(fn):
        last = None
        for _ in range(max_attempts):
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 — preemptions are generic
                last = e
        raise TaskFailed(f"task failed after {max_attempts} attempts") from last

    with ThreadPoolExecutor(max_workers=max(1, n_workers)) as pool:
        futs = [pool.submit(attempt, fn) for fn in fns]
        return [f.result() for f in futs]


# --------------------------------------------------------------------------
# discrete-event pool simulator
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SimTask:
    tid: int
    work: float = 1.0


@dataclasses.dataclass
class SimNode:
    nid: int
    preempt_rate: float = 0.0   # P(an execution on this node is preempted)
    speed: float = 1.0          # work units per time unit


@dataclasses.dataclass
class PoolPolicy:
    seed: int = 0
    evict_after: int = 8            # preemptions before a node is evicted
    straggler_factor: Optional[float] = 2.0  # backup when projected runtime
                                             # exceeds factor * task.work;
                                             # None = backups off
    requeue_front: bool = True      # preempted tasks go to the queue front


@dataclasses.dataclass
class PoolReport:
    makespan: float
    task_node: dict               # tid -> nid that FINISHED the task
    n_preemptions: int
    n_reassignments: int
    n_evictions: int
    n_backups: int


class SimPool:
    """Event-driven simulation of the elastic pool policies."""

    def __init__(self, nodes: list[SimNode], policy: PoolPolicy):
        self.nodes = list(nodes)
        self.policy = policy

    def run(self, tasks: list[SimTask]) -> PoolReport:
        rng = np.random.default_rng(self.policy.seed)
        queue: deque[SimTask] = deque(tasks)
        events: list = []          # (time, seq, kind, node, task)
        seq = 0
        done: dict[int, float] = {}
        task_node: dict[int, int] = {}
        running: dict[int, tuple[SimNode, float, float]] = {}  # primary copy
        backed_up: set[int] = set()
        preempts: dict[int, int] = {}
        evicted: set[int] = set()
        idle: set[int] = set()
        node_by_id = {n.nid: n for n in self.nodes}
        stats = dict(pre=0, reassign=0, evict=0, backup=0)
        makespan = 0.0

        def launch(task: SimTask, node: SimNode, now: float, primary: bool):
            nonlocal seq
            idle.discard(node.nid)
            dur = task.work / max(node.speed, 1e-9)
            if rng.random() < node.preempt_rate:
                t_end = now + dur * float(rng.uniform(0.1, 0.9))
                kind = "preempt"
            else:
                t_end = now + dur
                kind = "finish"
            seq += 1
            heapq.heappush(events, (t_end, seq, kind, node, task))
            if primary:
                running[task.tid] = (node, now, now + dur)

        def dispatch(node: SimNode, now: float):
            """Give an available node work: queued task, else a straggler
            backup, else park it idle."""
            if node.nid in evicted:
                return
            if queue:
                launch(queue.popleft(), node, now, primary=True)
                return
            sf = self.policy.straggler_factor
            if sf is not None:
                worst_task, worst_end = None, -1.0
                for tid, (pnode, start, proj) in running.items():
                    if tid in done or tid in backed_up or pnode is node:
                        continue
                    task = task_by_id[tid]
                    if (proj - start) > sf * task.work and proj > worst_end:
                        worst_task, worst_end = task, proj
                if worst_task is not None:
                    backed_up.add(worst_task.tid)
                    stats["backup"] += 1
                    launch(worst_task, node, now, primary=False)
                    return
            idle.add(node.nid)

        def drain_idle(now: float):
            while queue and idle:
                nid = idle.pop()
                launch(queue.popleft(), node_by_id[nid], now, primary=True)

        task_by_id = {t.tid: t for t in tasks}
        for node in self.nodes:
            if not queue:
                idle.add(node.nid)
                continue
            launch(queue.popleft(), node, 0.0, primary=True)

        while events:
            now, _, kind, node, task = heapq.heappop(events)
            if task.tid in done:        # backup race loser / stale preempt
                dispatch(node, now)
            elif kind == "finish":
                done[task.tid] = now
                task_node[task.tid] = node.nid
                running.pop(task.tid, None)
                makespan = max(makespan, now)
                dispatch(node, now)
            else:  # preempt
                stats["pre"] += 1
                preempts[node.nid] = preempts.get(node.nid, 0) + 1
                if running.get(task.tid, (node, 0, 0))[0] is node:
                    running.pop(task.tid, None)
                    stats["reassign"] += 1
                    if self.policy.requeue_front:
                        queue.appendleft(task)
                    else:
                        queue.append(task)
                if (self.policy.evict_after
                        and preempts[node.nid] >= self.policy.evict_after):
                    evicted.add(node.nid)
                    idle.discard(node.nid)
                    stats["evict"] += 1
                else:
                    dispatch(node, now)
                drain_idle(now)
            if not events and queue:
                # every node evicted with work left: the pool re-provisions
                # (paper: replacement preemptibles join); progress guaranteed
                evicted.clear()
                preempts.clear()
                for cand in self.nodes:
                    if queue:
                        launch(queue.popleft(), cand, now, primary=True)
                    else:
                        idle.add(cand.nid)
        return PoolReport(
            makespan=makespan,
            task_node=task_node,
            n_preemptions=stats["pre"],
            n_reassignments=stats["reassign"],
            n_evictions=stats["evict"],
            n_backups=stats["backup"],
        )
