"""Accelerated k-means for construction stage 1 (paper Fig. 13 / 21a).

Two E/M-step data paths, selected per call (``BuildConfig.fused_assign``
routes the whole pipeline):

* ``fused=True`` (default in the pipeline) — the Pallas fused
  assign-and-accumulate kernel (kernels/kmeans_assign.py on TPU, its jnp
  oracle elsewhere): one pass emits assignments + per-centroid sums/counts,
  the (N, K) distance matrix stays in VMEM, and the M-step is a device
  matmul instead of a host ``np.add.at`` scatter.
* ``fused=False`` — the legacy A/B reference: kernels/ops.kmeans_assign
  (argmin over the materialized distance tile) + host-side float64
  scatter-add.

Both paths share the empty-cluster reseeding rule (worst-served points), and
their per-step assignments are bit-identical on the same inputs (the fused
oracle argmins over the same pairwise_l2_ref distances).
``balanced_hierarchical_kmeans`` is the SPANN-style recursive splitter that
bounds every leaf cluster at ``max_cluster_size`` so posting lists stay
fixed-size (the serving layout's contract).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def kmeans_assign_step(
    x: np.ndarray, cents: np.ndarray, fused: bool = False
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """One Lloyd E+M data pass. Returns (assign (N,) i64, min_dist (N,) f32,
    sums (K, D), counts (K,) i64).

    Fused: a single device pass (kernel/oracle) returns everything; counts
    come back exact (integer cross-chunk fold).  Unfused: device argmin +
    host float64 scatter-add — the legacy reference the bench pairs against.
    """
    k, d = cents.shape
    if fused:
        a, md, sums, counts = kops.kmeans_assign_update(
            jnp.asarray(x), jnp.asarray(cents))
        return (np.asarray(a, np.int64), np.asarray(md),
                np.asarray(sums, np.float64),
                np.asarray(counts, np.int64))
    a, md = kops.kmeans_assign(jnp.asarray(x), jnp.asarray(cents))
    assign = np.asarray(a, np.int64)
    sums = np.zeros((k, d), np.float64)
    np.add.at(sums, assign, x)
    counts = np.bincount(assign, minlength=k)
    return assign, np.asarray(md), sums, counts


def kmeans(
    x: np.ndarray, k: int, iters: int = 10, seed: int = 0,
    fused: bool = False, device_mstep: Optional[bool] = None,
) -> tuple[np.ndarray, np.ndarray, float]:
    """Lloyd's algorithm. Returns (centroids (k, D), assign (N,), inertia).

    ``device_mstep`` (default: follows ``fused``) finishes each iteration
    with the fused M-step kernel — division + empty-cluster reseed stay on
    device (kernels/kmeans_mstep.py), so a whole Lloyd iteration runs without
    a host round trip: assign/accumulate kernel -> top-k worst-served gather
    -> M-step kernel, all async-dispatched.  ``device_mstep=False`` is the
    host reference path the parity tests pin the kernel against.
    """
    x = np.asarray(x, np.float32)
    n, d = x.shape
    k = max(1, min(int(k), n))
    if device_mstep is None:
        device_mstep = fused
    rng = np.random.default_rng(seed)
    cents = x[rng.choice(n, size=k, replace=False)].astype(np.float32).copy()
    if fused and device_mstep:
        xd = jnp.asarray(x)
        cd = jnp.asarray(cents)
        a = jnp.zeros((n,), jnp.int32)
        md = jnp.zeros((n,), jnp.float32)
        for _ in range(max(1, iters)):
            a, md, sums, counts = kops.kmeans_assign_update(xd, cd)
            # worst-served candidates for however many clusters come up
            # empty (ties resolve by lowest index — top_k order, the
            # canonical semantics kmeans_mstep documents)
            _, worst = jax.lax.top_k(md, k)
            cd = kops.kmeans_mstep(sums, counts, xd[worst])
        return (np.asarray(cd), np.asarray(a, np.int32),
                float(np.asarray(md).sum()))
    assign = np.zeros(n, np.int64)
    mind = np.zeros(n, np.float32)
    for _ in range(max(1, iters)):
        assign, mind, sums, counts = kmeans_assign_step(x, cents, fused=fused)
        nonz = counts > 0
        cents[nonz] = (sums[nonz] / counts[nonz, None]).astype(np.float32)
        if (~nonz).any():  # reseed empty clusters at the worst-served points
            # descending with lowest-index-first ties: the same order as the
            # device path's jax.lax.top_k, so the two M-steps stay parity
            far = np.argsort(-mind, kind="stable")[: int((~nonz).sum())]
            cents[~nonz] = x[far]
    return cents, assign.astype(np.int32), float(mind.sum())


def balanced_hierarchical_kmeans(
    x: np.ndarray,
    max_cluster_size: int,
    iters: int = 8,
    seed: int = 0,
    branch: int = 8,
    fused: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Recursive balanced clustering: split until every leaf fits the bound.

    Returns (centroids (C, D) f32 = leaf means, assign (N,) int32).  A
    degenerate split (k-means collapses everything into one cluster) falls
    back to a median split along the highest-variance axis, so termination is
    guaranteed.
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    stack = [np.arange(n)]
    leaves: list[np.ndarray] = []
    task_seed = seed
    while stack:
        idxs = stack.pop()
        if idxs.size <= max_cluster_size:
            leaves.append(idxs)
            continue
        k = int(min(branch, max(2, -(-idxs.size // max_cluster_size))))
        task_seed += 1
        _, a, _ = kmeans(x[idxs], k, iters=iters, seed=task_seed, fused=fused)
        sizes = np.bincount(a, minlength=k)
        if (sizes == idxs.size).any():  # degenerate: force a median split
            dim = int(np.argmax(x[idxs].var(axis=0)))
            order = idxs[np.argsort(x[idxs][:, dim], kind="stable")]
            half = idxs.size // 2
            stack.append(order[:half])
            stack.append(order[half:])
            continue
        for j in range(k):
            sub = idxs[a == j]
            if sub.size:
                stack.append(sub)
    leaves.sort(key=lambda l: int(l[0]))  # deterministic leaf order
    cents = np.stack([x[l].mean(axis=0) for l in leaves]).astype(np.float32)
    assign = np.empty(n, np.int32)
    for ci, l in enumerate(leaves):
        assign[l] = ci
    return cents, assign


def enforce_size_bound(
    x: np.ndarray,
    centroids: np.ndarray,
    bound: int,
    max_rounds: int = 20,
    seed: int = 0,
    fused: bool = False,
) -> np.ndarray:
    """Split Voronoi cells larger than ``bound`` until none remain.

    Chunk-local clustering (stage-1 elastic tasks) bounds leaf sizes per
    chunk, but the MERGED centroid set's global Voronoi cells can still
    exceed the posting-list capacity; any primary overflow would be silently
    truncated by the fixed-size posting build.  Each round reassigns all
    points and 2-way-splits every oversized cell.  The fused path reads the
    cell sizes straight off the kernel's in-VMEM counts — no (N, K) matrix,
    no host bincount.
    """
    x = np.asarray(x, np.float32)
    cents = np.asarray(centroids, np.float32).copy()
    for rnd in range(max_rounds):
        if fused:
            a, _, _, counts = kops.kmeans_assign_update(
                jnp.asarray(x), jnp.asarray(cents))
            a = np.asarray(a)
            counts = np.asarray(counts, np.int64)
        else:
            a, _ = kops.kmeans_assign(jnp.asarray(x), jnp.asarray(cents))
            a = np.asarray(a)
            counts = np.bincount(a, minlength=cents.shape[0])
        over = np.nonzero(counts > bound)[0]
        if over.size == 0:
            break
        new_rows = []
        for c in over:
            pts = x[a == c]
            sub, _, _ = kmeans(pts, 2, iters=4, seed=seed + 131 * rnd + int(c),
                               fused=fused)
            cents[c] = sub[0]
            if sub.shape[0] > 1:
                new_rows.append(sub[1])
        if new_rows:
            cents = np.concatenate([cents, np.stack(new_rows)], axis=0)
    return cents


def kmeans_sharded_step(mesh, x, cents, k: int, fused: bool = True):
    """One distributed Lloyd iteration (stage-1 build cell for dry-runs).

    x sharded over the data axes, centroids replicated; per-shard partial
    sums + counts are psum'd so every shard ends with the same new centroids.
    ``fused`` routes the per-shard pass through the fused assign/update tile
    (Pallas kernel on TPU) so the (N_local, K) distance matrix stays in VMEM;
    the unfused branch keeps the original inline one-hot as the reference.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.distance import squared_l2

    data_axes = tuple(n for n in mesh.axis_names if n != "model")

    def step(xl, c):
        if fused:
            _, _, sums, counts = kops.kmeans_assign_update_tile(xl, c)
        else:
            d = squared_l2(xl, c)
            a = jnp.argmin(d, axis=1)
            oh = jax.nn.one_hot(a, c.shape[0], dtype=jnp.float32)
            sums = oh.T @ xl
            counts = jnp.sum(oh, axis=0)
        for ax in data_axes:
            sums = jax.lax.psum(sums, ax)
            counts = jax.lax.psum(counts, ax)
        safe = jnp.maximum(counts[:, None], 1.0)
        return jnp.where(counts[:, None] > 0, sums / safe, c)

    return jax.shard_map(
        step, mesh=mesh, in_specs=(P(data_axes), P()), out_specs=P(),
        check_vma=False,
    )(x, cents)
