"""Offline construction pipeline (paper §5 / Fig. 21).

Three stages — coarse clustering, closure assignment + posting build, LLSP
training — executed as dependency-free tasks on an elastic worker pool with
checkpoint/resume at task granularity.
"""
