"""3-stage construction pipeline with checkpoint/resume (paper §5, Fig. 21a).

Stage 1 — coarse clustering: the corpus is split into ``coarse_per_task``
chunks; each task runs balanced hierarchical k-means and the per-task
centroid sets are concatenated.  With ``cfg.fused_assign`` (default) every
Lloyd E+M step goes through the fused Pallas assign-and-accumulate kernel
(kernels/kmeans_assign.py on TPU, its jnp oracle elsewhere): the (N, K)
distance matrix never reaches HBM and the M-step is a device matmul, not a
host scatter-add.  Stage 2 — closure multi-cluster assignment (SPANN RNG
rule) per shard.  With ``cfg.stream_stage2`` (default) the shards run
through the double-buffered :class:`repro.build.stream.ShardAssignPipeline`
— shard i+1's host load + device stream overlaps shard i's in-flight device
assign, each stage wall-clock stamped (``report.shard_stamps``) — then the
fixed-size posting build.  Stage 3 — LLSP training from logged queries.

Every stage checkpoints its output under ``workdir``; rebuilding with the
same config resumes instead of recomputing (report.resumed_stages), at
SHARD granularity inside stage 2: a build preempted mid-stage-2 resumes
from the finished shard files and produces a bit-identical index
(``index_content_hash`` — asserted by benchmarks/bench_construction.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import time
from typing import Optional

import numpy as np
import jax.numpy as jnp

from repro.core.ivf import IVFIndex, build_postings
from repro.core.llsp import LLSPConfig, LLSPParams, train_llsp
from repro.core.spann_rules import closure_assign

from .elastic import run_tasks
from .kmeans import balanced_hierarchical_kmeans, enforce_size_bound
from .stream import ShardAssignPipeline, shard_overlap_efficiency


@dataclasses.dataclass
class BuildConfig:
    max_cluster_size: int = 96
    cluster_len: int = 128
    coarse_per_task: int = 10_000
    n_workers: int = 2
    closure_eps: float = 0.2
    max_replicas: int = 4
    kmeans_iters: int = 8
    seed: int = 0
    llsp: Optional[LLSPConfig] = None
    fused_assign: bool = True     # fused Pallas assign/update for every
                                  # k-means E+M step; False = legacy A/B
                                  # reference (materialized distances + host
                                  # float64 scatter-add)
    stream_stage2: bool = True    # double-buffered shard-assign pipeline
                                  # with stage stamps; False = the opaque
                                  # elastic thread-pool tasks


@dataclasses.dataclass
class BuildReport:
    n_clusters: int
    replication: float            # mean posting slots per corpus vector
    stage_seconds: dict
    resumed_stages: list
    shard_stamps: list = dataclasses.field(default_factory=list)
    shard_overlap: float = 0.0    # measured load-under-assign fraction


def _chunks(n: int, per_task: int) -> list[tuple[int, int]]:
    return [(s, min(s + per_task, n)) for s in range(0, n, per_task)]


def index_content_hash(index: IVFIndex) -> str:
    """Deterministic content hash of the serving index (resume invariant)."""
    h = hashlib.sha256()
    for arr in (index.centroids, index.postings, index.posting_ids):
        a = np.ascontiguousarray(np.asarray(arr))
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def build_index(
    x: np.ndarray,
    cfg: BuildConfig,
    workdir: str,
    queries: Optional[np.ndarray] = None,
    query_topk: Optional[np.ndarray] = None,
) -> tuple[IVFIndex, Optional[LLSPParams], BuildReport]:
    """Build (or resume) the serving index. Returns (index, llsp, report)."""
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    os.makedirs(workdir, exist_ok=True)
    shards_dir = os.path.join(workdir, "shards")
    os.makedirs(shards_dir, exist_ok=True)
    spans = _chunks(n, cfg.coarse_per_task)
    stage_seconds: dict = {}
    resumed: list = []

    # ---- stage 1: coarse clustering (elastic tasks, per-chunk) -----------
    t0 = time.perf_counter()
    c_path = os.path.join(workdir, "stage1_centroids.npy")
    if os.path.exists(c_path):
        centroids = np.load(c_path)
        resumed.append("stage1")
    else:
        def mk_stage1(i, lo, hi):
            def task():
                cents, _ = balanced_hierarchical_kmeans(
                    x[lo:hi], cfg.max_cluster_size, iters=cfg.kmeans_iters,
                    seed=cfg.seed + 1000 * i, fused=cfg.fused_assign)
                return cents
            return task

        outs = run_tasks([mk_stage1(i, lo, hi)
                          for i, (lo, hi) in enumerate(spans)],
                         n_workers=cfg.n_workers)
        centroids = np.concatenate(outs, axis=0).astype(np.float32)
        # merged Voronoi cells must fit a posting list, else the fixed-size
        # build would truncate primary assignments (replication < 1)
        centroids = enforce_size_bound(
            x, centroids, min(cfg.max_cluster_size, cfg.cluster_len),
            seed=cfg.seed, fused=cfg.fused_assign)
        np.save(c_path, centroids)
    n_clusters = centroids.shape[0]
    stage_seconds["stage1"] = time.perf_counter() - t0

    # ---- stage 2: closure assignment per shard + posting build -----------
    t0 = time.perf_counter()
    shard_paths = [os.path.join(shards_dir, f"assign_{i:05d}.npz")
                   for i in range(len(spans))]
    shard_stamps: list = []
    shard_overlap = 0.0
    if all(os.path.exists(p) for p in shard_paths):
        resumed.append("stage2")
    elif cfg.stream_stage2:
        pipe = ShardAssignPipeline(
            x, centroids, spans, shard_paths,
            eps=cfg.closure_eps, max_replicas=cfg.max_replicas)
        try:
            stamps = pipe.run()
        finally:
            pipe.close()
        shard_overlap = shard_overlap_efficiency(stamps)
        shard_stamps = [t.asdict() for t in stamps]
        if any(t.resumed for t in stamps):
            resumed.append("stage2:partial")
    else:
        cj = jnp.asarray(centroids)

        def mk_stage2(i, lo, hi, path):
            def task():
                if os.path.exists(path):     # task-granular resume
                    return path
                a = np.asarray(closure_assign(
                    jnp.asarray(x[lo:hi]), cj, eps=cfg.closure_eps,
                    max_replicas=cfg.max_replicas))
                tmp = path + ".tmp.npz"   # .npz suffix: savez won't append
                np.savez(tmp, assign=a)
                os.replace(tmp, path)
                return path
            return task

        run_tasks([mk_stage2(i, lo, hi, p)
                   for (i, ((lo, hi), p)) in enumerate(zip(spans, shard_paths))],
                  n_workers=cfg.n_workers)
    assign = np.concatenate(
        [np.load(p)["assign"] for p in shard_paths], axis=0)
    postings, posting_ids = build_postings(x, assign, n_clusters,
                                           cfg.cluster_len)
    index = IVFIndex(jnp.asarray(centroids), jnp.asarray(postings),
                     jnp.asarray(posting_ids))
    stage_seconds["stage2"] = time.perf_counter() - t0

    # ---- stage 3: LLSP training from logged queries -----------------------
    t0 = time.perf_counter()
    llsp = None
    if cfg.llsp is not None and queries is not None and query_topk is not None:
        llsp = train_llsp_for_index(cfg.llsp, index, x, queries,
                                    np.asarray(query_topk), seed=cfg.seed)
    stage_seconds["stage3"] = time.perf_counter() - t0

    replication = float((posting_ids >= 0).sum()) / max(n, 1)
    report = BuildReport(n_clusters=n_clusters, replication=replication,
                         stage_seconds=stage_seconds, resumed_stages=resumed,
                         shard_stamps=shard_stamps,
                         shard_overlap=shard_overlap)
    return index, llsp, report


def train_llsp_for_index(
    llsp_cfg: LLSPConfig,
    index: IVFIndex,
    x: np.ndarray,
    queries: np.ndarray,
    query_topk: np.ndarray,
    seed: int = 0,
) -> LLSPParams:
    """Offline LLSP training: labels from a non-pruned large-nprobe search."""
    from repro.core.distance import squared_l2_chunked, topk_smallest
    from repro.core.ivf import search_flat

    q = jnp.asarray(np.asarray(queries, np.float32))
    topk = np.asarray(query_topk, np.int64)
    nmax = min(llsp_cfg.nmax, index.n_clusters)
    cd = squared_l2_chunked(q, index.centroids)
    cdists, cid_order = topk_smallest(cd, nmax)
    kmax = int(topk.max())
    _, true_ids = search_flat(index, q, kmax, nprobe=nmax)
    true = np.asarray(true_ids)
    cols = np.arange(kmax)[None, :]
    true = np.where(cols < topk[:, None], true, -1)   # per-query k padding
    return train_llsp(
        llsp_cfg, np.asarray(queries, np.float32), topk,
        np.asarray(cid_order), np.asarray(cdists), true,
        np.asarray(index.posting_ids), x.shape[0], seed=seed,
    )
