"""Sharded checkpoint store: pytree -> npz shards + JSON manifest.

Design goals (1000+-node posture):
* every host writes only its addressable shards (here: single-host, but the
  layout is per-leaf files so a multi-host writer maps 1:1);
* atomic publish — a checkpoint directory is staged under ``.tmp`` and
  renamed only after the manifest fsyncs, so a crashed writer never leaves a
  half-checkpoint that restore could pick up;
* generation GC — keep the last ``keep`` checkpoints;
* restore is lazy per-leaf and validates shapes/dtypes against the manifest.

Used by launch/train.py (params + opt state + data cursor + step) and by the
construction pipeline (stage outputs).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp


def _leaf_files(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        name = name.replace("/", "_").replace(".", "_") or "leaf"
        out.append((name, leaf))
    return out


def save(tree, step: int, root: str, keep: int = 3, extra: Optional[dict] = None) -> str:
    """Write checkpoint ``root/step_<N>`` atomically. Returns the final path."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, leaf) in enumerate(_leaf_files(tree)):
        arr = np.asarray(leaf)
        fn = f"{i:04d}_{name}.npy"
        dtype_name = str(arr.dtype)
        raw_view = arr.dtype.kind == "V" or dtype_name not in np.sctypeDict
        if raw_view:
            # ml_dtypes (bfloat16/f8...) round-trip as a raw byte view
            raw = arr.view(np.uint8 if arr.dtype.itemsize == 1 else np.uint16)
            np.save(os.path.join(tmp, fn), raw)
        else:
            np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append(
            {"file": fn, "shape": list(arr.shape), "dtype": dtype_name,
             "raw_view": raw_view}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(root, keep)
    return final


def _gc(root: str, keep: int) -> None:
    ckpts = sorted(
        d for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(root, d))


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    ckpts = sorted(
        d for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(root, d, "manifest.json"))
    )
    if not ckpts:
        return None
    return int(ckpts[-1].split("_")[1])


def restore(tree_like, root: str, step: Optional[int] = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``tree_like``. Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    path = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"leaf count mismatch: tree {len(leaves)} vs manifest {len(manifest['leaves'])}"
        )
    import ml_dtypes

    out = []
    for leaf, meta in zip(leaves, manifest["leaves"]):
        arr = np.load(os.path.join(path, meta["file"]))
        want_dtype = meta["dtype"]
        if meta.get("raw_view"):                 # stored as a raw byte view
            arr = arr.view(np.dtype(getattr(ml_dtypes, want_dtype)))
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {meta['file']}")
        out.append(jnp.asarray(arr, dtype=np.asarray(leaf).dtype))
    return treedef.unflatten(out), manifest["step"], manifest.get("extra", {})
