"""Online quality observability: per-query recall proxies + shadow audits.

Latency observability (PR 7) answers "where did this query spend its
time?"; this module answers the operator's harder question — "is recall
degrading RIGHT NOW, and where?" — without an offline bench run:

* **recall proxy** — on the q8 serving default (PR 8) every harvested
  batch already exact-rescores its fused-topk candidates at f32, so the
  overlap between the pre-rerank approximate top-k and the post-rerank
  exact top-k is a FREE per-query quality signal (FusionANNS 2409.16576
  uses the same agreement as its stopping rule).  The fabric stamps a
  coverage proxy instead: the fraction of a query's probed clusters that
  a live replica actually scanned (1.0 on complete rows, < 1.0 on
  ``partial`` rows — exactly the rows whose recall is at risk).
* **shadow audit lane** — proxies need calibration, and f32/no-rerank
  paths have no rerank to disagree with.  A deterministic Knuth-hash
  sample of queries (default ~1%) is brute-force rescanned against the
  live corpus snapshot on a dedicated single-lane executor, producing a
  measured true recall and a per-audit ``|proxy - true|`` calibration
  error.  Submission never blocks serving: the audit queue is bounded and
  overflow audits are dropped (counted, never silent).

Streams (all bounded-memory, via :mod:`repro.obs.metrics`):

=============================  ========================================
``quality.recall_proxy``        histogram of per-query proxies, plus
``quality.recall_proxy.<lab>``  labeled variants by route / nprobe
                                bucket / degrade status / shard
``quality.recall_true``         shadow-audited true recall
``quality.calibration_err``     per-audit ``|proxy - true|``
``quality.queries``             counter (labels: route, status)
``quality.low_proxy``           queries with proxy < ``low_threshold``
                                — the "bad event" stream the SLO burn
                                tracker consumes
``quality.audits``              counter (labels: done, dropped)
=============================  ========================================
"""
from __future__ import annotations

import math
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from .trace import _KNUTH, _MASK32

# histogram domain for recall-like values in [0, 1]: lo=1e-3 keeps the
# relative-error contract for small proxies, hi just above 1.0 so exact
# agreement (proxy == 1.0) lands in the top bucket instead of overflow
_Q_LO, _Q_HI = 1e-3, 1.0 + 1e-9


def shadow_sampled(req_id: int, rate: float) -> bool:
    """Deterministic shadow-audit decision: Knuth-hash the request id to
    [0, 1) — the same idiom trace sampling uses, so a given rate audits
    the same requests on every replay of a seeded trace.  Keyed on
    ``req_id`` (not trace_id, which is 0 for unsampled requests)."""
    if rate >= 1.0:
        return True
    if rate <= 0.0:
        return False
    return ((int(req_id) * _KNUTH) & _MASK32) / 4294967296.0 < rate


def recall_proxy(pre_ids: np.ndarray, post_ids: np.ndarray,
                 k: int) -> np.ndarray:
    """Row-wise overlap |pre ∩ post| / k between two (B, >=k) id arrays.
    Negative ids are padding and never match.  Returns (B,) float32."""
    pre = np.asarray(pre_ids)[:, :k]
    post = np.asarray(post_ids)[:, :k]
    hit = (pre[:, :, None] == post[:, None, :]) & (pre[:, :, None] >= 0)
    return hit.any(axis=2).sum(axis=1).astype(np.float32) / float(max(k, 1))


def overlap_frac(ids: np.ndarray, true_ids: np.ndarray, k: int) -> float:
    """Scalar recall of one answer row against brute-force ground truth."""
    a = np.asarray(ids).ravel()[:k]
    b = set(int(i) for i in np.asarray(true_ids).ravel()[:k])
    return sum(1 for i in a if int(i) >= 0 and int(i) in b) / max(k, 1)


_NP_CACHE: dict = {}


def _nprobe_bucket(nprobe: int) -> str:
    """Coarse power-of-two bucket so labels stay bounded."""
    n = max(int(nprobe), 1)
    lab = _NP_CACHE.get(n)
    if lab is None:
        b = 1
        while b < n:
            b <<= 1
        lab = _NP_CACHE[n] = f"np{b}"
    return lab


class QualityMonitor:
    """Per-query quality streams + the shadow audit lane (see module doc).

    One monitor per serving stack; the engine calls :meth:`observe_batch`
    once per harvested batch from the completion funnel.  ``vectors`` is
    the ground-truth corpus for shadow audits — an (N, D) float array or
    a zero-arg callable returning one (so lifecycle swaps can hand the
    auditor the LIVE snapshot); ``None`` disables auditing but keeps the
    proxy streams.
    """

    def __init__(self, metrics, *, vectors=None, shadow_rate: float = 0.01,
                 low_threshold: float = 0.9, harvest=None, trace=None,
                 max_pending: int = 256):
        self.metrics = metrics
        self._vec_fn = (vectors if callable(vectors)
                        else (lambda: vectors)) if vectors is not None \
            else None
        self.shadow_rate = float(shadow_rate)
        self.low_threshold = float(low_threshold)
        self.harvest = harvest
        self.trace = trace
        self.max_pending = int(max_pending)
        self._lock = threading.Lock()
        self._exec: Optional[ThreadPoolExecutor] = None
        self._futures: set = set()
        self._hcache: dict = {}       # label -> Histogram, hot-path lookup
        self._corpus_cache = None     # (vectors_obj, f32 view, |v|^2)
        self.proxy_hist = metrics.histogram(
            "quality.recall_proxy", lo=_Q_LO, hi=_Q_HI)
        self.true_hist = metrics.histogram(
            "quality.recall_true", lo=_Q_LO, hi=_Q_HI)
        self.calib_hist = metrics.histogram(
            "quality.calibration_err", lo=1e-4, hi=_Q_HI)
        self.queries = metrics.counter("quality.queries")
        self.low_proxy = metrics.counter("quality.low_proxy")
        self.audits = metrics.counter("quality.audits")
        self.not_ok = metrics.counter("quality.not_ok")

    # -- proxy streaming ---------------------------------------------------
    def _labeled_hist(self, label: str):
        h = self._hcache.get(label)
        if h is None:
            h = self.metrics.histogram(
                f"quality.recall_proxy.{label}", lo=_Q_LO, hi=_Q_HI)
            self._hcache[label] = h
        return h

    def observe_batch(self, requests, comps, *, shards=None,
                      rerank_rounds: int = 0) -> None:
        """Fold one harvested batch into the quality streams.

        ``requests[i]`` pairs with ``comps[i]``; each completion carries
        its per-query proxy in ``comp.quality`` (-1 = the serving path
        produced no proxy — pure f32, no rerank) and its nprobe;
        ``shards`` is the fabric's per-query primary shard array (or None
        single-node).  Never blocks: shadow audits go to the bounded
        executor queue.
        """
        n_low = 0
        n_routed = n_direct = 0
        proxies: list = []
        groups: dict = {}          # label -> proxy values, flushed batched
        recs: Optional[list] = [] if self.harvest is not None else None
        rr = int(rerank_rounds)
        low = self.low_threshold
        for i, (req, comp) in enumerate(zip(requests, comps)):
            if getattr(req, "route", None) is not None:
                rlab, route = "route:routed", "routed"
                n_routed += 1
            else:
                rlab, route = "route:direct", "direct"
                n_direct += 1
            status = comp.status
            if status != "ok":
                self.not_ok.inc(1.0, label=status)
            q = getattr(comp, "quality", None)
            proxy = None
            if q is not None:
                qv = float(q)
                if qv >= 0.0 and math.isfinite(qv):
                    proxy = qv
            if proxy is not None:
                proxies.append(proxy)
                groups.setdefault(rlab, []).append(proxy)
                groups.setdefault(
                    _nprobe_bucket(comp.nprobe), []).append(proxy)
                if status != "ok":
                    groups.setdefault(f"status:{status}", []).append(proxy)
                if shards is not None:
                    groups.setdefault(
                        f"shard:{int(shards[i])}", []).append(proxy)
                if proxy < low:
                    n_low += 1
            self._maybe_shadow(req, comp, proxy)
            if recs is not None:
                done, sub = float(comp.completed), float(comp.submitted)
                recs.append((
                    int(getattr(req, "req_id", -1)),
                    getattr(req, "index", "") or "",
                    int(getattr(req, "trace_id", 0)),
                    done, route, int(comp.nprobe),
                    status, comp.reason or "",
                    done - sub if done > sub else 0.0,
                    rr,
                    -1.0 if proxy is None else proxy,
                    -1 if shards is None else int(shards[i]),
                    self._clusters_of(req),
                ))
        if proxies:
            self.proxy_hist.observe_many(proxies)
            for lab, vals in groups.items():
                self._labeled_hist(lab).observe_many(vals)
        if recs:
            self.harvest.extend(recs)
        if n_routed:
            self.queries.inc(float(n_routed), label="route:routed")
        if n_direct:
            self.queries.inc(float(n_direct), label="route:direct")
        if n_low:
            self.low_proxy.inc(float(n_low))

    @staticmethod
    def _clusters_of(req):
        route = getattr(req, "route", None)
        cids = getattr(route, "cids", None) if route is not None else None
        if cids is None:
            return ()
        row = np.asarray(cids).ravel()
        return tuple(int(c) for c in row[row >= 0][:8])

    # -- shadow audit lane -------------------------------------------------
    def _maybe_shadow(self, req, comp, proxy) -> None:
        if self._vec_fn is None or self.shadow_rate <= 0.0:
            return
        if comp.status in ("shed", "failed") or comp.ids is None:
            return
        if not shadow_sampled(getattr(req, "req_id", 0), self.shadow_rate):
            return
        with self._lock:
            if len(self._futures) >= self.max_pending:
                self.audits.inc(1.0, label="dropped")
                return
            if self._exec is None:
                self._exec = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="shadow-audit")
            k = int(getattr(req, "topk", len(np.asarray(comp.ids).ravel())))
            fut = self._exec.submit(
                self._audit, np.array(req.query, np.float32, copy=True),
                np.array(comp.ids, copy=True), k, proxy)
            self._futures.add(fut)
        # registered OUTSIDE the lock: an already-finished future runs the
        # callback inline on THIS thread, and _done needs the lock
        fut.add_done_callback(self._done)

    def _done(self, fut) -> None:
        with self._lock:
            self._futures.discard(fut)

    def _corpus(self):
        """(vectors, |v|^2) with the norms cached across audits — keyed by
        object identity with the source pinned in the cache tuple, so a
        lifecycle swap (new snapshot object) recomputes and a static corpus
        pays the norm pass exactly once."""
        v = self._vec_fn()
        cached = self._corpus_cache
        if cached is None or cached[0] is not v:
            arr = np.asarray(v, np.float32)
            n2 = (arr.astype(np.float64) ** 2).sum(axis=1)
            self._corpus_cache = (v, arr, n2)
            return arr, n2
        return cached[1], cached[2]

    def _audit(self, query, ids, k, proxy) -> float:
        vectors, n2 = self._corpus()
        # |v - q|^2 = |v|^2 - 2 v.q + |q|^2; the constant |q|^2 term cannot
        # change the ranking, so one matvec replaces the (N, D) residual
        # materialization — the audit lane shares a single core with serving
        d = n2 - 2.0 * (vectors @ query).astype(np.float64)
        kk = min(k, d.shape[0])
        true_ids = np.argpartition(d, kk - 1)[:kk]
        true = overlap_frac(ids, true_ids, kk)
        self.true_hist.observe(true)
        if proxy is not None and np.isfinite(proxy):
            self.calib_hist.observe(abs(float(proxy) - true))
        self.audits.inc(1.0, label="done")
        return true

    @property
    def pending_audits(self) -> int:
        with self._lock:
            return len(self._futures)

    def drain(self, timeout_s: float = 10.0) -> None:
        """Block until in-flight audits complete (shutdown/bench only —
        the serving path never calls this)."""
        import time as _time
        t1 = _time.monotonic() + timeout_s
        while self.pending_audits and _time.monotonic() < t1:
            _time.sleep(0.002)

    def close(self) -> None:
        self.drain()
        with self._lock:
            ex, self._exec = self._exec, None
        if ex is not None:
            ex.shutdown(wait=True)

    # -- readout -----------------------------------------------------------
    def summary(self) -> dict:
        """JSON-able rollup for health snapshots."""
        n = self.proxy_hist.n
        return {
            "queries": self.queries.value(),
            "proxy": self.proxy_hist.to_dict(),
            "low_proxy": self.low_proxy.value(),
            "low_frac": self.low_proxy.value() / max(n, 1),
            "audits_done": self.audits.value("done"),
            "audits_dropped": self.audits.value("dropped"),
            "true": self.true_hist.to_dict(),
            "calibration_err": self.calib_hist.to_dict(),
        }
