"""Bounded-memory streaming metrics: counters, gauges, log-bucketed
histograms.

The repo's latency accounting used to be grow-forever python lists fed to
``np.percentile`` at shutdown — fine for a bench, fatal for a daemon (the
paper's deployment serves for days).  These primitives hold O(1) memory
regardless of stream length:

* :class:`Counter` / :class:`Gauge` — label-aware scalars (labels are the
  shed/degrade/partial *reasons* and per-shard identities the fabric
  reports through);
* :class:`Histogram` — log-bucketed streaming histogram.  Bucket edges grow
  geometrically by ``growth`` (default 1.03, i.e. <= ~1.5% quantization
  error — the sqrt of one bucket's ratio — against the <= 2% accuracy gate
  the bench asserts vs ``np.percentile``).  Quantiles interpolate
  GEOMETRICALLY inside the selected bucket and clamp to the observed
  min/max, so single-sample and short streams are exact.  Histograms with
  identical bucketing **merge** by adding count arrays — per-shard or
  per-trial histograms aggregate without raw samples.

Thread contract: every mutation takes the metric's own lock (~100 ns —
invisible next to a batch scan); reads snapshot under the same lock.
"""
from __future__ import annotations

import math
import threading
from typing import Optional

import numpy as np

_TOTAL = ""          # label key of the unlabeled total


class Counter:
    """Monotonic counter with optional per-label breakdown.  ``inc(n,
    label)`` bumps both the total and the label's cell, so dashboards read
    one total and drill into reasons."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._cells: dict[str, float] = {_TOTAL: 0.0}

    def inc(self, n: float = 1.0, label: Optional[str] = None) -> None:
        with self._lock:
            self._cells[_TOTAL] += n
            if label is not None:
                self._cells[label] = self._cells.get(label, 0.0) + n

    def value(self, label: Optional[str] = None) -> float:
        with self._lock:
            return self._cells.get(_TOTAL if label is None else label, 0.0)

    def labels(self) -> dict[str, float]:
        with self._lock:
            return {k: v for k, v in self._cells.items() if k != _TOTAL}


class Gauge:
    """Last-write-wins scalar with optional per-label cells (queue depths,
    outstanding tasks per shard)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._cells: dict[str, float] = {}

    def set(self, v: float, label: Optional[str] = None) -> None:
        with self._lock:
            self._cells[_TOTAL if label is None else label] = float(v)

    def value(self, label: Optional[str] = None) -> float:
        with self._lock:
            return self._cells.get(_TOTAL if label is None else label, 0.0)

    def labels(self) -> dict[str, float]:
        with self._lock:
            return {k: v for k, v in self._cells.items() if k != _TOTAL}


class Histogram:
    """Log-bucketed streaming histogram over (lo, hi) with under/overflow
    buckets (see module doc for the accuracy contract)."""

    def __init__(self, name: str, lo: float = 1e-6, hi: float = 1e4,
                 growth: float = 1.03):
        assert lo > 0 and hi > lo and growth > 1.0
        self.name = name
        self.lo, self.hi, self.growth = float(lo), float(hi), float(growth)
        self._lg = math.log(growth)
        self.n_buckets = int(math.ceil(math.log(hi / lo) / self._lg))
        # counts[0] = underflow (< lo), counts[-1] = overflow (>= hi)
        self.counts = np.zeros(self.n_buckets + 2, np.int64)
        self.n = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def _bucket(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return self.n_buckets + 1
        return 1 + min(int(math.log(v / self.lo) / self._lg),
                       self.n_buckets - 1)

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[self._bucket(v)] += 1
            self.n += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def observe_many(self, vs) -> None:
        """Vectorized :meth:`observe`: one bucket pass + one lock for the
        whole array (the per-batch quality streams fold 32 proxies per call
        — per-value locking would dominate the poller's budget)."""
        a = np.asarray(vs, np.float64).ravel()
        if a.size == 0:
            return
        idx = np.empty(a.shape, np.int64)
        under = a < self.lo
        over = a >= self.hi
        mid = ~(under | over)
        idx[under] = 0
        idx[over] = self.n_buckets + 1
        if mid.any():
            idx[mid] = 1 + np.minimum(
                (np.log(a[mid] / self.lo) / self._lg).astype(np.int64),
                self.n_buckets - 1)
        with self._lock:
            np.add.at(self.counts, idx, 1)
            self.n += a.size
            self.sum += float(a.sum())
            self.min = min(self.min, float(a.min()))
            self.max = max(self.max, float(a.max()))

    def merge(self, other: "Histogram") -> None:
        """Fold another histogram with IDENTICAL bucketing into this one."""
        assert (self.lo, self.hi, self.growth) == \
            (other.lo, other.hi, other.growth), "bucketing mismatch"
        with other._lock:
            oc, on, osum = other.counts.copy(), other.n, other.sum
            omin, omax = other.min, other.max
        with self._lock:
            self.counts += oc
            self.n += on
            self.sum += osum
            self.min = min(self.min, omin)
            self.max = max(self.max, omax)

    def quantile(self, q: float) -> float:
        """Value at quantile q in [0, 1]: locate the bucket by cumulative
        count, interpolate geometrically by rank fraction inside it, clamp
        to the observed [min, max]."""
        with self._lock:
            if self.n == 0:
                return 0.0
            rank = q * (self.n - 1)
            cum = 0
            for i, c in enumerate(self.counts):
                if c == 0:
                    continue
                if rank < cum + c:
                    frac = (rank - cum + 0.5) / c
                    if i == 0:
                        return self.min
                    if i == self.n_buckets + 1:
                        return self.max
                    v = self.lo * self.growth ** (i - 1 + frac)
                    return min(max(v, self.min), self.max)
                cum += c
            return self.max

    @property
    def mean(self) -> float:
        with self._lock:
            return self.sum / self.n if self.n else 0.0

    def summary_ms(self) -> dict:
        """p50/p99/mean in milliseconds — drop-in for the dict
        ``latency_percentiles`` returns from raw lists."""
        return {"p50_ms": self.quantile(0.50) * 1e3,
                "p99_ms": self.quantile(0.99) * 1e3,
                "mean_ms": self.mean * 1e3}

    def to_dict(self) -> dict:
        with self._lock:
            n, s, mn, mx = self.n, self.sum, self.min, self.max
        return {"n": n, "sum": s,
                "min": mn if n else 0.0, "max": mx if n else 0.0,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99),
                "mean": s / n if n else 0.0}


class MetricsRegistry:
    """Get-or-create registry keyed by metric name; one per Observability
    bundle (no process-global state — parallel tests and A/B trials each
    read their own registry)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
            return m

    def counter(self, name: str) -> Counter:
        m = self._get(name, lambda: Counter(name))
        assert isinstance(m, Counter), f"{name} is {type(m).__name__}"
        return m

    def gauge(self, name: str) -> Gauge:
        m = self._get(name, lambda: Gauge(name))
        assert isinstance(m, Gauge), f"{name} is {type(m).__name__}"
        return m

    def histogram(self, name: str, lo: float = 1e-6, hi: float = 1e4,
                  growth: float = 1.03) -> Histogram:
        m = self._get(name, lambda: Histogram(name, lo, hi, growth))
        assert isinstance(m, Histogram), f"{name} is {type(m).__name__}"
        return m

    def snapshot(self) -> dict:
        with self._lock:
            items = sorted(self._metrics.items())
        out: dict = {}
        for name, m in items:
            if isinstance(m, Counter):
                out[name] = {"total": m.value(), **m.labels()}
            elif isinstance(m, Gauge):
                lab = m.labels()
                out[name] = {"value": m.value(), **lab}
            else:
                out[name] = m.to_dict()
        return out

    def render(self) -> list[str]:
        """One human-readable line per metric (the --metrics-every print)."""
        lines = []
        for name, v in self.snapshot().items():
            if "p99" in v:                             # histogram
                lines.append(
                    f"{name}: n={v['n']} mean={v['mean']:.4g} "
                    f"p50={v['p50']:.4g} p99={v['p99']:.4g}")
            else:
                head = v.pop("total", v.pop("value", 0.0))
                lab = " ".join(f"{k}={val:g}" for k, val in v.items())
                lines.append(f"{name}: {head:g}" + (f" ({lab})" if lab
                                                    else ""))
        return lines
