"""Per-query distributed tracing with Chrome/Perfetto ``trace_event`` export.

The serving path is five subsystems deep (batcher -> engine -> pipeline ->
fabric -> shards) and until now each kept its own private wall-clock stamps
with no shared request identity — nobody could answer "where did this p99
query spend its 71 ms?".  :class:`TraceRecorder` fixes that with one
design constraint: the hot path must stay cheap enough to leave tracing ON
at ``sample_rate=1.0`` (the bench gates <= 5% q/s overhead).

How the budget is met:

* **per-thread ring buffers** — recording is an append to a plain list
  owned by the calling thread (``threading.local``); the only lock is taken
  ONCE per thread, at buffer registration.  Export snapshots every buffer.
* **ring-bounded** — a buffer past ``max_events_per_thread`` drops its
  oldest half and counts the drop (``dropped_events``), so a serving daemon
  never grows without bound and never silently loses history either;
* **no clock reads the caller didn't already pay for** — span recording
  takes EXPLICIT start/end stamps, so stage spans are emitted from the
  ``StageTimes`` stamps the pipeline already collects per batch (zero extra
  ``perf_counter`` calls on the hot path);
* **deterministic sampling** — :meth:`mint` draws the trace decision from a
  Knuth multiplicative hash of the id itself, so a given ``sample_rate``
  selects the same requests on every replay of a seeded trace.
  ``trace_id == 0`` means "not sampled": every recording call takes the
  id and the unsampled path costs one integer compare.

Event model -> ``trace_event`` mapping (https://perfetto.dev):

=========  ============================================================
``span``    "X" complete event (ts + dur, µs) — must be WELL-NESTED per
            track; used for pipeline stages, shard scans, merges
``instant`` "i" instant event (thread scope) — terminal outcomes,
            failovers, hedges, sheds
``abegin``/ "b"/"e" async pair matched by (cat, id) — task LIFETIMES
``aend``    (dispatch -> resolve), which overlap freely on a shard track
            while tasks queue, so they must not be "X" spans
``flow_start``/ "s"/"f" flow pair matched by (cat, id) — SPAN LINKS: the
``flow_finish`` Perfetto UI renders an arrow from the start event's
            enclosing slice to the finish event's slice, so a request
            span visually fans out to the shard tasks it spawned
=========  ============================================================

Tracks are logical (``"requests"``, ``"shard-3"``, ``"batch-5"``, …) and
mapped to synthetic tids with thread_name metadata at export, so the
flamegraph reads by subsystem rather than by python thread id.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Optional

_KNUTH = 2654435761          # Knuth's multiplicative hash constant
_MASK32 = 0xFFFFFFFF


def _sampled(trace_id: int, rate: float) -> bool:
    """Deterministic per-id sampling decision: hash the id to [0, 1)."""
    if rate >= 1.0:
        return True
    if rate <= 0.0:
        return False
    return ((trace_id * _KNUTH) & _MASK32) / 4294967296.0 < rate


class TraceRecorder:
    """Lock-cheap, ring-bounded span/instant recorder (see module doc)."""

    def __init__(self, sample_rate: float = 1.0, *, enabled: bool = True,
                 max_events_per_thread: int = 1 << 15,
                 clock=time.perf_counter):
        self.sample_rate = float(sample_rate)
        self.enabled = bool(enabled) and self.sample_rate > 0.0
        self.max_events_per_thread = int(max_events_per_thread)
        self.clock = clock
        self._tls = threading.local()
        self._lock = threading.Lock()       # buffer registry + id mint only
        # lint: bounded-by(one entry per thread; buffers are ring-trimmed)
        self._buffers: list[tuple[str, list, list]] = []  # (thread, buf, drops)
        self._next_id = 1

    # -- identity ----------------------------------------------------------
    def mint(self) -> int:
        """Mint a trace id at request admission.  Returns 0 when the request
        falls outside ``sample_rate`` (or tracing is off) — the untraced
        sentinel every recording call short-circuits on."""
        if not self.enabled:
            return 0
        with self._lock:
            tid = self._next_id
            self._next_id += 1
        return tid if _sampled(tid, self.sample_rate) else 0

    # -- recording ---------------------------------------------------------
    def _buf(self) -> list:
        buf = getattr(self._tls, "buf", None)
        if buf is None:
            buf = []
            drops = [0]
            self._tls.buf = buf
            self._tls.drops = drops
            with self._lock:
                self._buffers.append(
                    (threading.current_thread().name, buf, drops))
        elif len(buf) >= self.max_events_per_thread:
            # ring bound: drop the OLDEST half (recent history is what a
            # post-incident export wants) and count it — never silent
            half = self.max_events_per_thread // 2
            self._tls.drops[0] += half
            del buf[:half]
        return buf

    def span(self, name: str, t0: float, t1: float, *, trace_id: int = 0,
             track: Optional[str] = None, args: Optional[dict] = None
             ) -> None:
        """Complete ("X") event from stamps the caller ALREADY took.  Spans
        sharing a track must nest; overlapping lifetimes belong in
        :meth:`abegin`/:meth:`aend` instead."""
        if not self.enabled or t1 < t0:
            return
        self._buf().append(("X", name, trace_id, t0, t1, track, args))

    def instant(self, name: str, *, t: Optional[float] = None,
                trace_id: int = 0, track: Optional[str] = None,
                args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        t = self.clock() if t is None else t
        self._buf().append(("i", name, trace_id, t, t, track, args))

    def abegin(self, name: str, async_id: int, *, t: Optional[float] = None,
               trace_id: int = 0, track: Optional[str] = None,
               args: Optional[dict] = None) -> None:
        """Open an async ("b") span matched to :meth:`aend` by async_id —
        the representation for task lifetimes that overlap on one track."""
        if not self.enabled:
            return
        t = self.clock() if t is None else t
        self._buf().append(("b", name, trace_id, t, async_id, track, args))

    def aend(self, name: str, async_id: int, *, t: Optional[float] = None,
             track: Optional[str] = None,
             args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        t = self.clock() if t is None else t
        self._buf().append(("e", name, 0, t, async_id, track, args))

    def flow_start(self, name: str, flow_id, *, t: Optional[float] = None,
                   trace_id: int = 0, track: Optional[str] = None,
                   args: Optional[dict] = None) -> None:
        """Open a flow arrow ("s") bound to :meth:`flow_finish` by flow_id.
        Perfetto draws start -> finish as an arrow between the slices that
        enclose the two events, which is how a request span links to the
        shard tasks it fanned out to."""
        if not self.enabled:
            return
        t = self.clock() if t is None else t
        self._buf().append(("s", name, trace_id, t, flow_id, track, args))

    def flow_finish(self, name: str, flow_id, *, t: Optional[float] = None,
                    trace_id: int = 0, track: Optional[str] = None,
                    args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        t = self.clock() if t is None else t
        self._buf().append(("f", name, trace_id, t, flow_id, track, args))

    # -- readout -----------------------------------------------------------
    def snapshot(self) -> list[tuple]:
        """All recorded events (every thread's buffer, registration order).
        Safe to call while recording continues: buffers are only appended
        to by their owner threads and list snapshots are atomic enough for
        a post-run export (the daemon exports after stop())."""
        with self._lock:
            bufs = list(self._buffers)
        out: list[tuple] = []
        for _, buf, _ in bufs:
            out.extend(list(buf))
        return out

    @property
    def dropped_events(self) -> int:
        with self._lock:
            return sum(d[0] for _, _, d in self._buffers)

    def clear(self) -> None:
        with self._lock:
            for _, buf, drops in self._buffers:
                buf.clear()
                drops[0] = 0

    def export(self, path: Optional[str] = None) -> dict:
        """Chrome/Perfetto ``trace_event`` JSON (open in ui.perfetto.dev or
        chrome://tracing).  Timestamps are rebased to the earliest event so
        the UI opens at t=0; tracks become synthetic tids with thread_name
        metadata."""
        events = self.snapshot()
        with self._lock:
            bufs = list(self._buffers)
        t0 = min((e[3] for e in events), default=0.0)
        tracks: dict[str, int] = {}
        te: list[dict] = []

        def tid_of(track: Optional[str], fallback: str) -> int:
            key = track if track is not None else f"thread:{fallback}"
            if key not in tracks:
                tracks[key] = len(tracks) + 1
                te.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": tracks[key], "args": {"name": key}})
            return tracks[key]

        # events carry no thread tag; re-walk per buffer for the fallback
        for tname, buf, _ in bufs:
            for ev in list(buf):
                kind, name, trace_id, ta, tb, track, args = ev
                tid = tid_of(track, tname)
                a = dict(args) if args else {}
                if trace_id:
                    a.setdefault("trace_id", trace_id)
                row = {"ph": kind, "name": name, "pid": 1, "tid": tid,
                       "ts": (ta - t0) * 1e6}
                if a:
                    row["args"] = a
                if kind == "X":
                    row["dur"] = max((tb - ta) * 1e6, 0.0)
                elif kind == "i":
                    row["s"] = "t"
                elif kind in ("s", "f"):   # flow arrow matched by (cat, id)
                    row["cat"] = "flow"
                    row["id"] = tb
                    if kind == "f":
                        row["bp"] = "e"    # bind to enclosing slice
                else:                      # async b/e matched by (cat, id)
                    row["cat"] = "task"
                    row["id"] = tb
                te.append(row)
        doc = {"traceEvents": te, "displayTimeUnit": "ms",
               "otherData": {"dropped_events": self.dropped_events}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


def check_well_nested(trace_events: list[dict],
                      eps_us: float = 0.01) -> list[str]:
    """Structural validation of an exported trace: "X" spans sharing a
    (pid, tid) must be properly nested (a span either contains or is
    disjoint from every other span on its track), every async "b" must
    have a matching "e", and every flow arrow must have BOTH endpoints —
    an "s" with no "f" (or vice versa) sharing its (cat, id) renders as a
    dangling arrow in the Perfetto UI and is reported here.  Returns
    human-readable violations (empty = valid).
    Used by the trace-integrity tests AND the bench drill gate — the export
    is checked, not trusted.

    ``eps_us`` absorbs float round-off: a span's end is reconstructed as
    ts + dur (two separately-rounded µs values), so back-to-back stages
    sharing a stamp can disagree by sub-nanosecond amounts — tolerated up
    to 10 ns, far below anything a real overlap produces."""
    bad: list[str] = []
    by_track: dict[tuple, list] = {}
    opens: dict[tuple, int] = {}
    flow_s: dict[tuple, int] = {}
    flow_f: dict[tuple, int] = {}
    for ev in trace_events:
        ph = ev.get("ph")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "X":
            by_track.setdefault(key, []).append(
                (float(ev["ts"]), float(ev["ts"]) + float(ev["dur"]),
                 ev.get("name")))
        elif ph == "b":
            opens[(ev.get("cat"), ev.get("id"))] = \
                opens.get((ev.get("cat"), ev.get("id")), 0) + 1
        elif ph == "e":
            k = (ev.get("cat"), ev.get("id"))
            if opens.get(k, 0) <= 0:
                bad.append(f"async end without begin: {ev.get('name')} {k}")
            else:
                opens[k] -= 1
        elif ph == "s":
            k = (ev.get("cat"), ev.get("id"))
            flow_s[k] = flow_s.get(k, 0) + 1
        elif ph == "f":
            k = (ev.get("cat"), ev.get("id"))
            flow_f[k] = flow_f.get(k, 0) + 1
    for k, n in opens.items():
        if n > 0:
            bad.append(f"async begin without end: {k}")
    for k in flow_s:
        if k not in flow_f:
            bad.append(f"flow start without finish: {k}")
    for k in flow_f:
        if k not in flow_s:
            bad.append(f"flow finish without start: {k}")
    for key, spans in by_track.items():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack: list[tuple] = []
        for ts, end, name in spans:
            while stack and stack[-1][1] <= ts + eps_us:
                stack.pop()
            if stack and end > stack[-1][1] + eps_us:
                bad.append(
                    f"track {key}: span {name!r} [{ts:.1f},{end:.1f}] "
                    f"crosses {stack[-1][2]!r} "
                    f"[{stack[-1][0]:.1f},{stack[-1][1]:.1f}]")
            stack.append((ts, end, name))
    return bad
