"""Per-query telemetry harvest: the training substrate for online-learned
routing (ROADMAP open item 3).

Every completed query already carries the tuple the future online
retrainer needs — which route planned it, which clusters were probed,
whether admission degraded or shed it, what latency it achieved, how many
rerank rounds it took, and what its recall proxy measured.  Until now
those facts died with the batch.  :class:`HarvestRing` is a bounded ring
of structured per-query records appended from the engine's completion
funnel (O(1), lock-guarded tuple append — daemon-safe) and persisted as
**shards**:

* ``flush_npz(path)`` — columnar ``.npz`` (one array per field, probed
  clusters padded to a fixed width with -1) for bulk training loads;
* ``flush_jsonl(path)`` — one JSON object per record for ad-hoc
  ``jq``/pandas queries.

Both formats round-trip through :func:`load_npz` / plain ``json.loads``
back into the exact per-query tuples, which the tier-1 replay test
asserts field-by-field.
"""
from __future__ import annotations

import json
import threading
from collections import deque

import numpy as np

# record layout: one tuple per completed query, columnar at flush
FIELDS = ("req_id", "index", "trace_id", "t", "route", "nprobe", "status",
          "reason", "latency_s", "rerank_rounds", "quality", "shard",
          "clusters")

#: probed-cluster ids kept per record (padded with -1 in npz shards)
CLUSTER_SLOTS = 8


class HarvestRing:
    """Bounded ring of per-query telemetry records (see module doc)."""

    def __init__(self, capacity: int = 1 << 16):
        self.capacity = int(capacity)
        self._dq: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.appended = 0          # lifetime appends (>= len when wrapped)

    def append(self, *, req_id: int, index: str, trace_id: int, t: float,
               route: str, nprobe: int, status: str, reason: str,
               latency_s: float, rerank_rounds: int, quality: float,
               shard: int, clusters=()) -> None:
        rec = (int(req_id), str(index), int(trace_id), float(t), str(route),
               int(nprobe), str(status), str(reason), float(latency_s),
               int(rerank_rounds), float(quality), int(shard),
               tuple(int(c) for c in clusters)[:CLUSTER_SLOTS])
        with self._lock:
            self._dq.append(rec)
            self.appended += 1

    def extend(self, recs) -> None:
        """Batched append of pre-built record tuples (FIELDS order, types
        already coerced) — one lock for a whole harvested batch.  The
        QualityMonitor hot path uses this; :meth:`append` stays the safe
        kwargs front door."""
        recs = list(recs)
        with self._lock:
            self._dq.extend(recs)
            self.appended += len(recs)

    def __len__(self) -> int:
        with self._lock:
            return len(self._dq)

    @property
    def dropped(self) -> int:
        """Records evicted by the ring bound."""
        with self._lock:
            return self.appended - len(self._dq)

    def records(self) -> list[dict]:
        with self._lock:
            rows = list(self._dq)
        return [dict(zip(FIELDS, r)) for r in rows]

    # -- persistence -------------------------------------------------------
    def flush_npz(self, path) -> dict:
        """Write a columnar shard; returns the column dict written."""
        with self._lock:
            rows = list(self._dq)
        n = len(rows)
        cl = np.full((n, CLUSTER_SLOTS), -1, np.int32)
        for i, r in enumerate(rows):
            cs = r[-1]
            if cs:
                cl[i, :len(cs)] = cs
        cols = {
            "req_id": np.array([r[0] for r in rows], np.int64),
            "index": np.array([r[1] for r in rows], dtype="<U32"),
            "trace_id": np.array([r[2] for r in rows], np.int64),
            "t": np.array([r[3] for r in rows], np.float64),
            "route": np.array([r[4] for r in rows], dtype="<U16"),
            "nprobe": np.array([r[5] for r in rows], np.int32),
            "status": np.array([r[6] for r in rows], dtype="<U16"),
            "reason": np.array([r[7] for r in rows], dtype="<U24"),
            "latency_s": np.array([r[8] for r in rows], np.float64),
            "rerank_rounds": np.array([r[9] for r in rows], np.int32),
            "quality": np.array([r[10] for r in rows], np.float32),
            "shard": np.array([r[11] for r in rows], np.int32),
            "clusters": cl,
        }
        np.savez_compressed(path, **cols)
        return cols

    def flush_jsonl(self, path) -> int:
        recs = self.records()
        with open(path, "w") as f:
            for r in recs:
                r = dict(r)
                r["clusters"] = list(r["clusters"])
                f.write(json.dumps(r) + "\n")
        return len(recs)


def load_npz(path) -> list[dict]:
    """Replay a columnar shard back into per-query record dicts — the
    consumption path open item 3's retrainer will use."""
    with np.load(path, allow_pickle=False) as z:
        cols = {k: z[k] for k in z.files}
    n = len(cols["req_id"])
    out = []
    for i in range(n):
        row = {k: cols[k][i].item() if cols[k].ndim == 1 else None
               for k in FIELDS if k != "clusters"}
        cl = cols["clusters"][i]
        row["clusters"] = tuple(int(c) for c in cl[cl >= 0])
        out.append(row)
    return out
