"""Multi-window burn-rate SLO alerting + health snapshots.

Raw counters can't answer "are we burning the error budget *right now*?"
— a daemon that served a bad hour yesterday has elevated totals forever.
The standard fix (Google SRE workbook ch.5) is **multi-window burn-rate
alerting**: an alert fires only when the bad-event fraction exceeds
``fire_burn`` x budget over BOTH a fast window (catches the spike, sets
time-to-detect) and a slow window (suppresses blips); it clears with
**hysteresis** — both windows must fall below the lower ``clear_burn``
threshold — so a burn hovering at the boundary produces one transition,
not a flap storm.

:class:`SLOTracker` is pull-based: :meth:`tick` samples cumulative
(total, bad) pairs from registered rules — plain callables, typically
closures over :mod:`repro.obs.metrics` counters — into per-rule sample
deques bounded by the slow window, and runs the state machine.  No
background thread: the serve loop ticks it at the ``--health-every``
cadence, tests tick it with a virtual clock, so alert behaviour is
seeded-deterministic.

Alert transitions emit ``alert_fire:<name>`` / ``alert_clear:<name>``
instants on the ``slo`` trace track and bump the ``slo.alerts`` counter;
burn levels stream into ``slo.burn_fast`` / ``slo.burn_slow`` gauges.
:func:`health_snapshot` assembles the one JSON document an operator (or
the fabric drill's CI gate) polls: alert states, burn levels, quality
rollup, drift summary, and the full metrics snapshot.
"""
from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass(frozen=True)
class BurnRule:
    """One SLO stream: ``bad_fn()``/``total_fn()`` return CUMULATIVE
    counts; ``budget`` is the allowed bad fraction (the SLO's error
    budget); burn = (windowed bad fraction) / budget."""
    name: str
    total_fn: Callable[[], float]
    bad_fn: Callable[[], float]
    budget: float = 0.01
    fast_s: float = 60.0
    slow_s: float = 300.0
    fire_burn: float = 2.0
    clear_burn: float = 1.0
    min_events: int = 1          # windows with fewer totals read burn 0


@dataclass
class AlertState:
    state: str = "ok"            # "ok" | "firing"
    since: float = 0.0
    fires: int = 0
    clears: int = 0
    fast_burn: float = 0.0
    slow_burn: float = 0.0

    def asdict(self) -> dict:
        return {"state": self.state, "since": self.since,
                "fires": self.fires, "clears": self.clears,
                "fast_burn": self.fast_burn, "slow_burn": self.slow_burn}


class SLOTracker:
    """Pull-based multi-window burn-rate alerter (see module doc)."""

    def __init__(self, *, metrics=None, trace=None, clock=time.monotonic):
        self.metrics = metrics
        self.trace = trace
        self.clock = clock
        # lint: bounded-by(config-time rule registration, not a hot path)
        self._rules: list[BurnRule] = []
        self._samples: dict[str, deque] = {}
        self.alerts: dict[str, AlertState] = {}

    def add_rule(self, rule: BurnRule) -> None:
        assert rule.name not in self.alerts, f"duplicate rule {rule.name}"
        self._rules.append(rule)
        self._samples[rule.name] = deque()
        self.alerts[rule.name] = AlertState()

    def _burn(self, dq: deque, now: float, window: float,
              rule: BurnRule) -> float:
        if not dq:
            return 0.0
        t1, total1, bad1 = dq[-1]
        # baseline: newest sample at or before the window edge; if the
        # tracker is younger than the window, the oldest sample serves —
        # early burns must be visible, not masked by a half-full window
        base = dq[0]
        for s in dq:
            if s[0] <= now - window:
                base = s
            else:
                break
        dt_total = total1 - base[1]
        if dt_total < rule.min_events:
            return 0.0
        frac = max(bad1 - base[2], 0.0) / dt_total
        return frac / max(rule.budget, 1e-12)

    def tick(self, now: Optional[float] = None) -> dict:
        """Sample every rule and run the fire/clear state machine.
        Returns {rule: state} for convenience."""
        now = self.clock() if now is None else now
        out = {}
        for rule in self._rules:
            dq = self._samples[rule.name]
            dq.append((now, float(rule.total_fn()), float(rule.bad_fn())))
            # evict: keep exactly one sample older than the slow window
            while len(dq) >= 2 and dq[1][0] <= now - rule.slow_s:
                dq.popleft()
            st = self.alerts[rule.name]
            st.fast_burn = self._burn(dq, now, rule.fast_s, rule)
            st.slow_burn = self._burn(dq, now, rule.slow_s, rule)
            if st.state == "ok" and st.fast_burn >= rule.fire_burn \
                    and st.slow_burn >= rule.fire_burn:
                st.state, st.since, st.fires = "firing", now, st.fires + 1
                self._transition(rule.name, "fire", now, st)
            elif st.state == "firing" and st.fast_burn <= rule.clear_burn \
                    and st.slow_burn <= rule.clear_burn:
                st.state, st.since = "ok", now
                st.clears += 1
                self._transition(rule.name, "clear", now, st)
            if self.metrics is not None:
                self.metrics.gauge("slo.burn_fast").set(
                    st.fast_burn, label=rule.name)
                self.metrics.gauge("slo.burn_slow").set(
                    st.slow_burn, label=rule.name)
                self.metrics.gauge("slo.alert").set(
                    1.0 if st.state == "firing" else 0.0, label=rule.name)
            out[rule.name] = st.state
        return out

    def _transition(self, name: str, kind: str, now: float,
                    st: AlertState) -> None:
        if self.metrics is not None:
            self.metrics.counter("slo.alerts").inc(1.0, f"{name}:{kind}")
        if self.trace is not None:
            self.trace.instant(
                f"alert_{kind}:{name}", t=now, track="slo",
                args={"fast_burn": round(st.fast_burn, 3),
                      "slow_burn": round(st.slow_burn, 3)})

    def snapshot(self) -> dict:
        return {name: st.asdict() for name, st in self.alerts.items()}


def default_rules(tracker: SLOTracker, registry, *, quality=None,
                  fast_s: float = 60.0, slow_s: float = 300.0) -> None:
    """Wire the standard serving SLO streams onto a tracker:

    * ``deadline`` — degraded completions (admission traded quality for
      the deadline) against a 5% budget;
    * ``partial``/``failed`` — responses missing clusters or dropped,
      1% and 0.1% budgets;
    * ``shed`` — rejected at admission, 1%;
    * ``quality`` — recall proxy below the monitor's low threshold, 5%
      (only when a :class:`~repro.obs.quality.QualityMonitor` is given).
    """
    comp = registry.counter("engine.completions")

    def rule(name, bad_fn, budget, total_fn=comp.value):
        tracker.add_rule(BurnRule(
            name=name, total_fn=total_fn, bad_fn=bad_fn, budget=budget,
            fast_s=fast_s, slow_s=slow_s))

    rule("deadline", lambda: comp.value("degraded"), 0.05)
    rule("partial", lambda: comp.value("partial"), 0.01)
    rule("failed", lambda: comp.value("failed"), 0.001)
    rule("shed", lambda: comp.value("shed"), 0.01)
    if quality is not None:
        rule("quality", quality.low_proxy.value, 0.05,
             total_fn=quality.queries.value)


def health_snapshot(*, slo: Optional[SLOTracker] = None, quality=None,
                    drift=None, registry=None, extra: Optional[dict] = None,
                    t: Optional[float] = None) -> dict:
    """One JSON-able health document: alert states + burns, quality
    rollup, drift summary, full metrics snapshot.  What ``serve.py
    --health-out`` writes and the fabric drill gates on."""
    doc: dict = {"t": time.time() if t is None else t}
    if slo is not None:
        doc["alerts"] = slo.snapshot()
    if quality is not None:
        doc["quality"] = quality.summary()
    if drift is not None:
        doc["drift"] = drift.summary()
    if registry is not None:
        doc["metrics"] = registry.snapshot()
    if extra:
        doc.update(extra)
    return doc


def write_health(path, doc) -> None:
    """Atomic-enough single-file write for a polling operator."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, default=float)
    import os
    os.replace(tmp, path)
