"""Unified telemetry layer (PR 7): per-query distributed traces + streaming
metrics, threaded through engine, batcher, pipeline, fabric, and lifecycle.

One :class:`Observability` bundle per serving stack:

* ``obs.trace`` — :class:`~repro.obs.trace.TraceRecorder`: ring-bounded
  per-thread span/instant buffers carrying a ``trace_id`` minted at request
  admission, exportable as Chrome/Perfetto ``trace_event`` JSON
  (``obs.trace.export(path)`` -> open in https://ui.perfetto.dev);
* ``obs.metrics`` — :class:`~repro.obs.metrics.MetricsRegistry`: counters /
  gauges / log-bucketed streaming histograms (bounded memory, mergeable,
  p50/p99 within ~2% of ``np.percentile``).

Components take ``obs=None`` and default to a PRIVATE disabled bundle
(``Observability.off()``) — no module-global registry, so parallel tests
and paired A/B trials never share state.  A disabled bundle keeps metrics
live (they are O(1) and replace the old grow-forever lists) but turns the
trace recorder into one-integer-compare no-ops; the tracing-overhead gate
in ``benchmarks/bench_serving_pipeline.py`` measures exactly this
off-vs-``sample_rate=1.0`` pair.
"""
from __future__ import annotations

import time

from .harvest import HarvestRing, load_npz
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .quality import QualityMonitor, recall_proxy, shadow_sampled
from .slo import (BurnRule, SLOTracker, default_rules, health_snapshot,
                  write_health)
from .trace import TraceRecorder, check_well_nested

__all__ = [
    "BurnRule", "Counter", "Gauge", "HarvestRing", "Histogram",
    "MetricsRegistry", "Observability", "QualityMonitor", "SLOTracker",
    "TraceRecorder", "check_well_nested", "default_rules",
    "health_snapshot", "load_npz", "recall_proxy", "shadow_sampled",
    "write_health",
]


class Observability:
    """Trace recorder + metrics registry, shared by one serving stack."""

    def __init__(self, sample_rate: float = 1.0, *, enabled: bool = True,
                 max_events_per_thread: int = 1 << 15,
                 clock=time.perf_counter):
        self.trace = TraceRecorder(
            sample_rate, enabled=enabled,
            max_events_per_thread=max_events_per_thread, clock=clock)
        self.metrics = MetricsRegistry()

    @classmethod
    def off(cls) -> "Observability":
        """Metrics-only bundle: tracing disabled (mint() == 0 for every
        request), metrics live.  The default for every component."""
        return cls(enabled=False)

    @property
    def tracing(self) -> bool:
        return self.trace.enabled

    def mint(self) -> int:
        return self.trace.mint()
