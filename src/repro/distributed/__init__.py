from .collectives import bucketed_psum, compressed_psum, compressed_psum_tree
from .fabric import FabricStats, ShardNode, ShardReply, ShardTask, ShardedFabric
from .fault import (
    FailoverPlan,
    FaultEvent,
    FaultInjector,
    HeartbeatMonitor,
    ownership_mask,
    plan_failover,
)
from . import sharding
