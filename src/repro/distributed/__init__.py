from .collectives import bucketed_psum, compressed_psum, compressed_psum_tree
from .fault import FailoverPlan, HeartbeatMonitor, ownership_mask, plan_failover
from . import sharding
