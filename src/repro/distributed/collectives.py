"""Distributed-optimization collectives.

* ``int8 compressed all-reduce with error feedback`` — gradient compression
  for the data-parallel axes.  Each participant quantizes its shard of the
  gradient to int8 with a per-tensor scale, psums the int8 payload (16x fewer
  bytes on the wire than f32 at 512 chips... 4x per tensor, and the scale is
  one scalar), dequantizes, and accumulates the quantization residual into an
  error-feedback buffer added back next step (Karimireddy et al.-style EF,
  keeps SGD/Adam convergence).
* ``bucketed_psum`` — fuses many small tensors into one flat collective
  (latency amortization at 1000+ nodes; one collective per step instead of
  one per parameter).

Both are shard_map-safe (pure jax.lax collectives).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    x: jax.Array,
    axis_name,
    error: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """int8 all-reduce mean with error feedback.

    Returns (mean-reduced x, new error buffer).  Call inside shard_map with
    ``axis_name`` bound.  When ``error`` is None a zero buffer is used.
    """
    if error is None:
        error = jnp.zeros_like(x)
    x_ef = x + error
    q, scale = quantize_int8(x_ef)
    deq_local = dequantize_int8(q, scale)
    new_error = x_ef - deq_local                 # residual kept locally
    # reduce in int32 to avoid int8 overflow across >127 participants
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)   # participants may differ
    n = jax.lax.psum(jnp.ones((), x.dtype), axis_name)
    # each participant contributed q_i * scale_i; approximate with mean scale
    mean_scale = scale_sum / n
    out = summed.astype(jnp.float32) * mean_scale / n
    return out.astype(x.dtype), new_error


def compressed_psum_tree(grads, axis_name, errors=None):
    """Tree-mapped compressed psum. errors pytree matches grads (or None)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if errors is None:
        err_leaves = [None] * len(leaves)
    else:
        err_leaves = treedef.flatten_up_to(errors)
    outs, new_errs = [], []
    for g, e in zip(leaves, err_leaves):
        o, ne = compressed_psum(g, axis_name, e)
        outs.append(o)
        new_errs.append(ne)
    return treedef.unflatten(outs), treedef.unflatten(new_errs)


def bucketed_psum(grads, axis_name, bucket_bytes: int = 64 << 20):
    """Fuse small leaves into flat buckets before psum (collective fusion).

    One psum per bucket instead of per leaf — the latency-bound small-tensor
    regime at scale.  Mean reduction.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    flats, shapes, dtypes = [], [], []
    for g in leaves:
        shapes.append(g.shape)
        dtypes.append(g.dtype)
        flats.append(g.astype(jnp.float32).reshape(-1))
    buckets, cur, cur_bytes = [], [], 0
    for f in flats:
        cur.append(f)
        cur_bytes += f.size * 4
        if cur_bytes >= bucket_bytes:
            buckets.append(jnp.concatenate(cur))
            cur, cur_bytes = [], 0
    if cur:
        buckets.append(jnp.concatenate(cur))
    reduced = [jax.lax.psum(b, axis_name) / n for b in buckets]
    flat_all = jnp.concatenate(reduced) if len(reduced) > 1 else reduced[0]
    outs, off = [], 0
    for shape, dt in zip(shapes, dtypes):
        size = 1
        for s in shape:
            size *= s
        outs.append(flat_all[off : off + size].reshape(shape).astype(dt))
        off += size
    return treedef.unflatten(outs)
