"""Fault-tolerance runtime: heartbeats, straggler policy, serving failover.

At 1000+-node scale the failure model is: (a) a serving shard stops answering
(host/die failure), (b) a shard answers slowly (straggler), (c) a training
worker dies mid-step (handled by checkpoint/restart in launch/train.py).

* ``HeartbeatMonitor`` — logical-clock heartbeat table; a shard missing
  ``miss_threshold`` consecutive beats is marked failed, one marked slow for
  ``slow_factor``x median latency is a straggler.
* ``FailoverPlan`` — given failed shards and the ReplicaMap, compute the probe
  re-routing (clusters whose primary died scan a replica) and the irrecoverable
  set.  The sharded search engine consumes the resulting per-shard ownership
  mask; no resharding of the posting tensor is needed for R-1 failures.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.storage.layout import ReplicaMap


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault against a serving fabric, relative to arm time.

    ``shard == -1`` defers the victim choice to fire time: a seeded draw
    among the shards still alive, so a schedule stays valid even if an
    earlier event already killed the shard a fixed id would have named."""
    at_s: float
    kind: str                  # "kill" | "stall" | "corrupt"
    shard: int = -1            # -1 = seeded choice among live shards at fire
    duration_s: float = 0.0    # stall/corrupt window length
    stall_s: float = 0.0       # per-task delay injected while stalled
    silent: bool = False       # kill only: no CQ flush — the shard just goes
                               # quiet, and detection must come from missed
                               # heartbeats instead of dead-letter replies
    fired: bool = False


class FaultInjector:
    """Seeded, replayable fault schedule for the sharded serving fabric.

    The injector is passive: the fabric's poller calls :meth:`poll` on its
    reply-pump path, and any event whose fire time has passed is applied to
    the fabric's shard node (kill / stall window / corrupt window).  All
    randomness (victim choice for ``shard=-1`` events) comes from one seeded
    generator, so a drill replays the identical fault sequence from
    (schedule, seed) — the property the kill-a-shard bench gates on.
    """

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(np.random.SeedSequence([seed, 23]))
        # lint: bounded-by(drill schedule, fixed when the test configures it)
        self.events: list[FaultEvent] = []
        # lint: bounded-by(at most one entry per scheduled event)
        self.log: list[tuple[float, str, int]] = []   # (rel time, kind, shard)
        self._t0: Optional[float] = None

    # -- schedule ----------------------------------------------------------
    def kill(self, at_s: float, shard: int = -1,
             silent: bool = False) -> "FaultInjector":
        self.events.append(FaultEvent(at_s, "kill", shard, silent=silent))
        return self

    def stall(self, at_s: float, shard: int = -1, duration_s: float = 1.0,
              stall_s: float = 0.25) -> "FaultInjector":
        self.events.append(FaultEvent(at_s, "stall", shard,
                                      duration_s=duration_s, stall_s=stall_s))
        return self

    def corrupt(self, at_s: float, shard: int = -1,
                duration_s: float = 0.5) -> "FaultInjector":
        self.events.append(FaultEvent(at_s, "corrupt", shard,
                                      duration_s=duration_s))
        return self

    # -- runtime -----------------------------------------------------------
    def arm(self, t0: float) -> None:
        """Pin the schedule's zero time (defaults to the first poll)."""
        self._t0 = t0

    def pick_victim(self, alive: Sequence[int]) -> int:
        """Seeded victim draw for ``shard=-1`` events (exposed so tests can
        assert schedule determinism without a live fabric)."""
        alive = sorted(alive)
        if not alive:
            return -1
        return int(alive[int(self.rng.integers(0, len(alive)))])

    def poll(self, now: float, fabric) -> list[tuple[str, int]]:
        """Fire every due event against ``fabric`` (anything exposing
        ``alive_shards()`` and ``inject(event, shard)``).  Returns the
        (kind, shard) pairs fired this call."""
        if self._t0 is None:
            self._t0 = now
        el = now - self._t0
        fired = []
        for ev in sorted(self.events, key=lambda e: e.at_s):
            if ev.fired or el < ev.at_s:
                continue
            ev.fired = True
            shard = ev.shard if ev.shard >= 0 \
                else self.pick_victim(fabric.alive_shards())
            if shard < 0:
                continue
            fabric.inject(ev, shard)
            self.log.append((el, ev.kind, shard))
            fired.append((ev.kind, shard))
        return fired


@dataclasses.dataclass
class HeartbeatMonitor:
    n_nodes: int
    miss_threshold: int = 3
    slow_factor: float = 3.0

    def __post_init__(self):
        self.last_beat = np.zeros(self.n_nodes, dtype=np.int64)
        self.latency_ema = np.ones(self.n_nodes, dtype=np.float64)
        self.clock = 0

    def beat(self, node: int, latency: float = 1.0) -> None:
        self.last_beat[node] = self.clock
        self.latency_ema[node] = 0.8 * self.latency_ema[node] + 0.2 * latency

    def tick(self) -> None:
        self.clock += 1

    def failed(self) -> np.ndarray:
        return np.nonzero(self.clock - self.last_beat >= self.miss_threshold)[0]

    def stragglers(self) -> np.ndarray:
        alive = np.setdiff1d(np.arange(self.n_nodes), self.failed())
        if alive.size == 0:
            return alive
        med = np.median(self.latency_ema[alive])
        return alive[self.latency_ema[alive] > self.slow_factor * med]


@dataclasses.dataclass
class FailoverPlan:
    owner: np.ndarray          # (C,) serving shard per cluster after failover
    lost: np.ndarray           # clusters with no live replica
    moved: np.ndarray          # clusters whose owner changed

    @property
    def n_lost(self) -> int:
        return int(self.lost.size)


def plan_failover(
    replica_map: ReplicaMap, failed_shards: Sequence[int]
) -> FailoverPlan:
    primary = replica_map.replicas[:, 0].copy()
    fm = replica_map.failover(failed_shards)
    owner = fm.replicas[:, 0]
    lost = fm.lost_clusters()
    moved = np.nonzero((owner != primary) & (owner >= 0))[0]
    return FailoverPlan(owner=owner, lost=lost, moved=moved)


def ownership_mask(owner: np.ndarray, n_shards: int) -> np.ndarray:
    """(S, C) bool — shard s scans cluster c.  Consumed by the sharded search
    engine in place of the static striping when a failover plan is active."""
    mask = np.zeros((n_shards, owner.shape[0]), dtype=bool)
    valid = owner >= 0
    mask[owner[valid], np.nonzero(valid)[0]] = True
    return mask
