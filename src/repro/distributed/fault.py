"""Fault-tolerance runtime: heartbeats, straggler policy, serving failover.

At 1000+-node scale the failure model is: (a) a serving shard stops answering
(host/die failure), (b) a shard answers slowly (straggler), (c) a training
worker dies mid-step (handled by checkpoint/restart in launch/train.py).

* ``HeartbeatMonitor`` — logical-clock heartbeat table; a shard missing
  ``miss_threshold`` consecutive beats is marked failed, one marked slow for
  ``slow_factor``x median latency is a straggler.
* ``FailoverPlan`` — given failed shards and the ReplicaMap, compute the probe
  re-routing (clusters whose primary died scan a replica) and the irrecoverable
  set.  The sharded search engine consumes the resulting per-shard ownership
  mask; no resharding of the posting tensor is needed for R-1 failures.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.storage.layout import ReplicaMap


@dataclasses.dataclass
class HeartbeatMonitor:
    n_nodes: int
    miss_threshold: int = 3
    slow_factor: float = 3.0

    def __post_init__(self):
        self.last_beat = np.zeros(self.n_nodes, dtype=np.int64)
        self.latency_ema = np.ones(self.n_nodes, dtype=np.float64)
        self.clock = 0

    def beat(self, node: int, latency: float = 1.0) -> None:
        self.last_beat[node] = self.clock
        self.latency_ema[node] = 0.8 * self.latency_ema[node] + 0.2 * latency

    def tick(self) -> None:
        self.clock += 1

    def failed(self) -> np.ndarray:
        return np.nonzero(self.clock - self.last_beat >= self.miss_threshold)[0]

    def stragglers(self) -> np.ndarray:
        alive = np.setdiff1d(np.arange(self.n_nodes), self.failed())
        if alive.size == 0:
            return alive
        med = np.median(self.latency_ema[alive])
        return alive[self.latency_ema[alive] > self.slow_factor * med]


@dataclasses.dataclass
class FailoverPlan:
    owner: np.ndarray          # (C,) serving shard per cluster after failover
    lost: np.ndarray           # clusters with no live replica
    moved: np.ndarray          # clusters whose owner changed

    @property
    def n_lost(self) -> int:
        return int(self.lost.size)


def plan_failover(
    replica_map: ReplicaMap, failed_shards: Sequence[int]
) -> FailoverPlan:
    primary = replica_map.replicas[:, 0].copy()
    fm = replica_map.failover(failed_shards)
    owner = fm.replicas[:, 0]
    lost = fm.lost_clusters()
    moved = np.nonzero((owner != primary) & (owner >= 0))[0]
    return FailoverPlan(owner=owner, lost=lost, moved=moved)


def ownership_mask(owner: np.ndarray, n_shards: int) -> np.ndarray:
    """(S, C) bool — shard s scans cluster c.  Consumed by the sharded search
    engine in place of the static striping when a failover plan is active."""
    mask = np.zeros((n_shards, owner.shape[0]), dtype=bool)
    valid = owner >= 0
    mask[owner[valid], np.nonzero(valid)[0]] = True
    return mask
