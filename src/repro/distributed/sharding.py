"""Sharding rules per architecture family.

Single source of truth for how every model family maps onto the production
mesh — consumed by launch/dryrun.py, launch/train.py, launch/serve.py.

Mesh axes (launch/mesh.py): single pod ``(data=16, model=16)``; multi-pod
``(pod=2, data=16, model=16)``.  ``pod`` composes with ``data`` as an outer
batch axis everywhere (gradient reduction crosses pods once per step).

Conventions (PartitionSpec leaves name mesh axes):
* LM train:  batch over (pod, data); Megatron TP over ``model`` — attention
  heads and d_ff columns sharded, row-parallel second matmuls, vocab sharded
  on the embedding/unembedding.  MoE experts sharded over ``model`` (EP).
* LM decode: batch over (pod, data); KV heads over ``model`` when divisible,
  else split-KV (sequence) decode.
* GNN:       edges/nodes over (pod, data) [graph partition], hidden dim of the
  big MLPs over ``model``.
* RecSys:    embedding tables row-sharded over ``model`` (the paper-adjacent
  hot path: lookup = all-to-all-ish gather); batch over (pod, data).
* ANNS:      queries over (pod, data); posting clusters over ``model``;
  centroids + LLSP replicated.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_spec(mesh: Mesh, *trailing) -> P:
    """Batch-sharded leading dim, e.g. tokens (B, S) -> P(('pod','data'), None)."""
    return P(batch_axes(mesh), *trailing)


def replicated() -> P:
    return P()


# ---------------------------------------------------------------------------
# LM transformer parameter/activation specs
# ---------------------------------------------------------------------------
def lm_param_specs(params_tree, mesh: Mesh):
    """Megatron-style TP rules applied by leaf path name.

    * ``wq/wk/wv``  (D, H, Dh)    -> shard head dim over model
    * ``wo``        (H, Dh, D)    -> shard head dim over model (row-parallel)
    * ``w_gate/w_up`` (D, F)      -> shard F over model (col-parallel)
    * ``w_down``    (F, D)        -> shard F over model (row-parallel)
    * MoE expert variants carry a leading E dim -> experts over model (EP)
    * ``embed``     (V, D)        -> shard V over model
    * norms/scalars               -> replicated
    """

    def spec_for(path: str, x) -> P:
        nd = x.ndim
        if "moe" in path and nd >= 3:
            return P("model", *([None] * (nd - 1)))          # EP
        if any(k in path for k in ("wq", "wk", "wv")):
            return P(None, "model", None)
        if "wo" in path:
            return P("model", None, None)
        if any(k in path for k in ("w_gate", "w_up")):
            return P(None, "model")
        if "w_down" in path:
            return P("model", None)
        if "embed" in path:
            return P("model", None)
        if "router" in path:
            return P()                                        # tiny
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for path, leaf in flat:
        name = "/".join(str(p) for p in path).lower()
        specs.append(spec_for(name, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def lm_kv_cache_spec(mesh: Mesh, kv_heads: int, *, seq_split: bool = False) -> P:
    """KV cache (B, S, Hkv, Dh): heads over model if divisible, else sequence
    split (the split-KV decode path for long_500k / small-kv archs)."""
    tp = mesh.shape["model"]
    if not seq_split and kv_heads % tp == 0:
        return P(batch_axes(mesh), None, "model", None)
    return P(batch_axes(mesh), "model", None, None)


# ---------------------------------------------------------------------------
# ANNS / recsys / gnn specs
# ---------------------------------------------------------------------------
def anns_specs(mesh: Mesh) -> dict:
    return {
        "centroids": P(),
        "postings": P("model", None, None),
        "posting_ids": P("model", None),
        "llsp": P(),
        "queries": data_spec(mesh, None),
        "topk": data_spec(mesh),
    }


def recsys_table_spec() -> P:
    return P("model", None)          # rows over model — EmbeddingBag hot path


def gnn_specs(mesh: Mesh) -> dict:
    return {
        "edges": data_spec(mesh, None),
        "node_feats": P(None, "model"),
        "hidden": P(None, "model"),
    }


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
