"""Sharded, replicated serving fabric — the multi-machine Helmsman tier.

The paper's production deployment spreads one logical index over ~40
machines and keeps serving through machine loss.  This module is that
fabric, scaled down to S simulated shard engines in one process:

* the posting tier is partitioned by **centroid ownership**
  (``storage.layout.plan_striping``): shard s owns the clusters striped to
  it, plus replica copies of hot clusters (``make_replica_map``, R=2);
* the router (:class:`ShardedFabric`) speaks the engine's
  ``plan / prefetch / dispatch / harvest`` stage protocol, so the PR 2
  :class:`~repro.runtime.engine.ServeEngine` drives it unchanged: ``plan``
  is the PR 2 centroid+LLSP planner, ``prefetch`` fans the micro-batch's
  probed-cluster union out to owner shards over per-shard SQ/CQ
  :class:`~repro.runtime.engine.QueuePair` s, ``harvest`` collects per-shard
  candidate top-m sets and merges them with the permutation-invariant
  ``merge_candidate_topk`` (Fig. 2a's frontend merge);
* each :class:`ShardNode` is a worker thread scanning ONLY its local
  posting subset with per-cluster-block numpy arithmetic — the same block
  produces bit-identical distances no matter which shard hosts it, which is
  what makes S=1 vs S=8 results *bit-equal* (the property test's claim);
* robustness is live, not latent: shards heartbeat into the seed
  :class:`~repro.distributed.fault.HeartbeatMonitor`; a dead shard
  (dead-letter CQ replies on a flushed kill, missed beats on a silent one)
  triggers ``plan_failover`` + ``ownership_mask`` re-routing, its in-flight
  tasks are **requeued** to surviving replicas, and its posting tier is
  retired through a per-shard PR 4 :class:`~repro.lifecycle.version.Epoch`
  (released only after its last outstanding task resolves);
* hot-shard load uses power-of-two-choices routing across live replicas,
  stragglers get deadline-aware hedged re-dispatch, flaky shards get
  checksum-verified replies with a bounded per-task retry budget, and a
  cluster with no live replica degrades the touching queries to a
  ``partial`` response instead of erroring the batch.

Everything stochastic (fault schedules, victim choice) is seeded through
:class:`~repro.distributed.fault.FaultInjector`, so the kill-a-shard drill
in ``benchmarks/bench_fabric.py`` is replayable bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Optional

import numpy as np
import jax.numpy as jnp

from repro.core.distance import merge_candidate_topk
from repro.core.search import SearchConfig, _auto_ncand
from repro.distributed.fault import (
    FaultEvent, HeartbeatMonitor, ownership_mask, plan_failover,
)
from repro.lifecycle.version import Epoch
from repro.obs import Observability
from repro.runtime.engine import QueuePair
from repro.runtime.pipeline import (
    BatchResult, PrefetchPipeline, StageTimes, max_id_replicas,
)
from repro.storage.host_tier import TieredPostings
from repro.storage.layout import make_replica_map, plan_striping


@dataclasses.dataclass
class ShardTask:
    """One shard-scoped scan command (the SQ entry of the shard's queue
    pair).  ``cids`` are GLOBAL cluster ids this shard must scan for this
    micro-batch; ``probe`` is the per-query membership mask over them."""
    task_id: int
    shard: int
    queries: np.ndarray            # (bp, D) float32 — shared, not copied
    q2: np.ndarray                 # (bp, 1) float32 — precomputed ||q||^2
    cids: np.ndarray               # (U_s,) int64 global cluster ids
    probe: np.ndarray              # (bp, U_s) bool
    m: int                         # per-query candidate slots to return
    attempt: int = 0
    trace_ids: tuple = ()          # sampled request ids riding this task
    kind: str = "dispatch"         # "dispatch" | "requeue" | "hedge"


@dataclasses.dataclass
class ShardReply:
    """CQ entry from a shard.  status: "ok" | "dead".  ``checksum`` is the
    crc32 of the candidate payload computed BEFORE any in-transit
    corruption — the router re-hashes on receipt and retries a mismatch."""
    task_id: int
    shard: int
    status: str
    cand_d: Optional[np.ndarray] = None    # (bp, m) float32
    cand_i: Optional[np.ndarray] = None    # (bp, m) int32
    checksum: int = 0
    service_s: float = 0.0


def _payload_crc(cand_d: np.ndarray, cand_i: np.ndarray) -> int:
    return zlib.crc32(cand_i.tobytes(), zlib.crc32(cand_d.tobytes()))


class ShardNode:
    """One simulated shard engine: a worker thread draining its SQ.

    The scan is pure numpy, per cluster block: for each owned cluster the
    distances are ``||q||^2 - 2 q @ block.T + ||block||^2`` over the (L, D)
    block — identical inputs give identical bits regardless of which shard
    (or how many shards) the block lives on, so the cross-shard merge is
    bit-equal to the single-shard scan.  No jax from worker threads: the
    matmuls release the GIL, and S workers on one host time-share cleanly
    without a per-shard compile cache.
    """

    def __init__(self, shard: int, postings: np.ndarray,
                 posting_ids: np.ndarray, owned: np.ndarray, fabric,
                 sq_depth: int = 256):
        self.shard = shard
        self.fabric = fabric
        self.owned = owned                           # (n_local,) global cids
        self.local_of = np.full(postings.shape[0], -1, np.int64)
        self.local_of[owned] = np.arange(owned.size)
        # tier-wrapped local subset: the per-shard Epoch releases exactly
        # this payload when the shard retires (PR 4 safe-retire machinery)
        self.tier = TieredPostings(
            np.ascontiguousarray(postings[owned]),
            np.ascontiguousarray(posting_ids[owned]),
            epoch=shard)
        self.qp = QueuePair(sq_depth=sq_depth)
        self.killed = False
        self.flush_on_kill = True
        self.stall_until = 0.0
        self.stall_s = 0.0
        self.corrupt_until = 0.0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        assert self._thread is None
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name=f"shard-{self.shard}", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def kill(self, flush: bool = True) -> None:
        """Die mid-traffic.  ``flush`` drains the SQ into dead-letter CQ
        replies (the NVMe abort path — the router requeues them at once);
        a silent kill just stops beating and lets the heartbeat monitor
        find the body."""
        self.flush_on_kill = flush
        self.killed = True
        self._stop.set()
        if flush:
            dead = [ShardReply(t.task_id, self.shard, "dead")
                    for t in self.qp.pop_submissions()]
            if dead:
                self.qp.complete(dead)
                self.fabric._reply_event.set()

    # -- worker ------------------------------------------------------------
    def _loop(self) -> None:
        clock = self.fabric.clock
        while not self._stop.is_set():
            tasks = self.qp.pop_submissions()
            if not tasks:
                if not self.killed:
                    self.fabric._beat(self.shard)
                self.qp.wait_submissions(timeout=self.fabric.idle_beat_s)
                continue
            for task in tasks:
                if self.killed:
                    if self.flush_on_kill:
                        self.qp.complete(
                            [ShardReply(task.task_id, self.shard, "dead")])
                        self.fabric._reply_event.set()
                    continue
                now = clock()
                if now < self.stall_until:
                    # straggle, but keep the heart beating with the inflated
                    # latency: a slow shard is a straggler (hedge target),
                    # not a corpse (failover target)
                    end = min(self.stall_until, now + self.stall_s)
                    while clock() < end and not self._stop.is_set():
                        self.fabric._beat(self.shard, latency=self.stall_s)
                        time.sleep(0.005)
                t0 = clock()
                cand_d, cand_i = self.scan(task)
                t1 = clock()
                service = t1 - t0
                obs = self.fabric.obs
                if obs.tracing and task.trace_ids:
                    # worker-side scan span: sequential per shard thread, so
                    # an "X" event on the shard's track is safe to nest
                    obs.trace.span(
                        "scan", t0, t1, trace_id=task.trace_ids[0],
                        track=f"shard-{self.shard}",
                        args={"task_id": task.task_id, "kind": task.kind,
                              "clusters": int(task.cids.size),
                              "trace_ids": list(task.trace_ids[:32])})
                crc = _payload_crc(cand_d, cand_i)
                if clock() < self.corrupt_until:
                    # bit flips in transit: payload mutates AFTER the
                    # checksum was taken, so the router's re-hash catches it
                    cand_i = np.where(cand_i >= 0, cand_i ^ 0x55, cand_i)
                if self.killed and not self.flush_on_kill:
                    continue               # died mid-scan, silently
                self.qp.complete([ShardReply(
                    task.task_id, self.shard, "ok", cand_d, cand_i,
                    checksum=crc, service_s=service)])
                self.fabric._beat(self.shard, latency=service)
                self.fabric._note_service(self.shard, service)
                self.fabric._reply_event.set()

    # -- the scan itself ---------------------------------------------------
    def scan(self, task: ShardTask) -> tuple[np.ndarray, np.ndarray]:
        """Per-cluster-block scan -> per-query top-m candidate (d, id) sets.

        Blocks are visited in ascending global-cluster order and reduced
        with the identical (bp, L) expression everywhere, so the candidate
        VALUES are layout-independent; only the top-m cut varies, and m is
        sized (k2 * dup_bound) so the global top-k distinct ids always
        survive the per-shard cut (same bound as the pipeline's oracle)."""
        postings, pids = self.tier.postings, self.tier.posting_ids
        if postings is None:
            raise RuntimeError(f"scan on retired shard {self.shard}")
        bp = task.queries.shape[0]
        l = postings.shape[1]
        cols = []
        ids_cols = []
        for j, cid in enumerate(task.cids):
            loc = self.local_of[cid]
            block = postings[loc]                        # (L, D)
            ids = pids[loc]                              # (L,)
            n2 = np.einsum("ld,ld->l", block, block)
            d = task.q2 - 2.0 * (task.queries @ block.T) + n2[None, :]
            dead = ~task.probe[:, j : j + 1] | (ids < 0)[None, :]
            cols.append(np.where(dead, np.inf, np.maximum(d, 0.0)))
            ids_cols.append(ids)
        if not cols:
            return (np.full((bp, task.m), np.inf, np.float32),
                    np.full((bp, task.m), -1, np.int32))
        d = np.concatenate(cols, axis=1).astype(np.float32, copy=False)
        flat_ids = np.concatenate(ids_cols).astype(np.int32, copy=False)
        n = d.shape[1]
        m = min(task.m, n)
        if m < n:
            part = np.argpartition(d, m - 1, axis=1)[:, :m]
            pd = np.take_along_axis(d, part, axis=1)
        else:
            part = np.broadcast_to(np.arange(n), (bp, n))
            pd = d
        order = np.argsort(pd, axis=1, kind="stable")
        cand_d = np.take_along_axis(pd, order, axis=1)
        cand_i = flat_ids[np.take_along_axis(part, order, axis=1)]
        cand_i = np.where(np.isinf(cand_d), -1, cand_i)
        if m < task.m:                                   # tiny shard: pad
            padw = task.m - m
            cand_d = np.pad(cand_d, ((0, 0), (0, padw)),
                            constant_values=np.inf)
            cand_i = np.pad(cand_i, ((0, 0), (0, padw)), constant_values=-1)
        return np.ascontiguousarray(cand_d), np.ascontiguousarray(cand_i)


@dataclasses.dataclass
class _TaskRecord:
    """Router-side bookkeeping for one outstanding ShardTask."""
    task: ShardTask
    state: "_FabricBatch"
    sent_at: float
    hedged: bool = False


class _FabricBatch:
    """Harvest-side state of one micro-batch in the fabric."""

    def __init__(self, plan, queries: np.ndarray, q2: np.ndarray,
                 wanted: np.ndarray, probe_u: np.ndarray,
                 deadline: Optional[float]):
        self.plan = plan
        self.queries = queries
        self.q2 = q2
        self.wanted = wanted                 # (U,) union cluster ids
        self.probe_u = probe_u               # (bp, U) bool
        self.deadline = deadline
        self.pending: set = set(int(c) for c in wanted)
        self.lost: set = set()
        # lint: bounded-by(per-request accumulator, one entry per shard)
        self.cand: list = []                 # [(cand_d, cand_i)]
        self.dispatched_at = 0.0

    def resolve(self, cids, lost: bool = False) -> list:
        """Mark clusters resolved; returns the ones that were still
        pending (late duplicate replies resolve nothing)."""
        fresh = [int(c) for c in cids if int(c) in self.pending]
        for c in fresh:
            self.pending.discard(c)
            if lost:
                self.lost.add(c)
        return fresh

    @property
    def complete(self) -> bool:
        return not self.pending

    def partial_rows(self) -> np.ndarray:
        """(bp,) bool — queries whose probe set touched a lost cluster."""
        if not self.lost:
            return np.zeros(self.probe_u.shape[0], bool)
        cols = np.isin(self.wanted, np.fromiter(self.lost, np.int64,
                                                len(self.lost)))
        return self.probe_u[:, cols].any(axis=1)


@dataclasses.dataclass
class FabricStats:
    tasks: int = 0
    replies: int = 0
    dead_replies: int = 0
    hedges: int = 0
    retries: int = 0
    checksum_failures: int = 0
    requeued_tasks: int = 0
    timeouts: int = 0
    partial_queries: int = 0
    # lint: bounded-by(one entry per shard; _declare_failed de-dups)
    failovers: list = dataclasses.field(default_factory=list)
    # per-shard accumulators (measured on the worker, summed by the router)
    busy_s: Optional[np.ndarray] = None      # (S,) scan seconds per shard
    tasks_per_shard: Optional[np.ndarray] = None

    def init(self, n_shards: int) -> None:
        self.busy_s = np.zeros(n_shards)
        self.tasks_per_shard = np.zeros(n_shards, np.int64)


class ShardedFabric:
    """S-shard serving fabric behind the engine's stage protocol.

    ``plan`` (and ``route``) run on the PR 2 planner — one centroid+LLSP
    pass for the whole batch, no per-shard replanning.  ``prefetch`` is the
    fan-out: the batch's probed-cluster union is deduped once, each union
    cluster is assigned to ONE live shard by power-of-two-choices over its
    replicas, and one ShardTask per owner shard is submitted to that
    shard's SQ (epoch-ref'd).  ``harvest`` pumps every shard's CQ (replies
    for ANY in-flight batch route through the outstanding table, so deep
    engine windows work), verifies checksums, drives the heartbeat /
    failover / hedge / retry machinery, and merges the surviving candidate
    sets with ``merge_candidate_topk``.
    """

    accepts_deadline = True

    def __init__(self, index, llsp_params, cfg: SearchConfig, *,
                 n_shards: int = 4, n_replicas: int = 2,
                 hot_clusters: Optional[np.ndarray] = None,
                 pad_batch: int = 16, clock=time.monotonic,
                 hedge_after_s: float = 0.08, retry_budget: int = 3,
                 harvest_timeout_s: float = 5.0, tick_s: float = 0.05,
                 miss_threshold: int = 3, idle_beat_s: float = 0.01,
                 injector=None, name: str = "fabric",
                 obs: Optional[Observability] = None):
        self.index = index
        self.cfg = cfg
        self.clock = clock
        self.name = name
        self.obs = obs if obs is not None else Observability.off()
        m = self.obs.metrics
        self._m_requeued = m.counter("fabric.requeued")   # by cause
        self._m_hedges = m.counter("fabric.hedges")
        self._m_retries = m.counter("fabric.retries")     # by cause
        self._m_timeouts = m.counter("fabric.timeouts")
        self._m_partial = m.counter("fabric.partial_queries")  # by reason
        self._m_failovers = m.counter("fabric.failovers")
        self._g_qdepth = m.gauge("fabric.shard_queue_depth")
        self._g_out = m.gauge("fabric.shard_outstanding")
        self._h_task = m.histogram("fabric.task_service_s")
        self.n_shards = int(n_shards)
        self.hedge_after_s = hedge_after_s
        self.retry_budget = int(retry_budget)
        self.harvest_timeout_s = harvest_timeout_s
        self.tick_s = tick_s
        self.idle_beat_s = idle_beat_s
        self.injector = injector
        # planner: the PR 2 pipeline in plan/route-only duty (tier-less, so
        # it is never dispatched — the shards scan, the planner routes)
        self.planner = PrefetchPipeline(index, llsp_params, cfg, tier=None,
                                        pad_batch=pad_batch)
        postings = np.ascontiguousarray(np.asarray(index.postings,
                                                   np.float32))
        posting_ids = np.ascontiguousarray(np.asarray(index.posting_ids,
                                                      np.int32))
        n_clusters = postings.shape[0]
        self.striping = plan_striping(n_clusters, self.n_shards)
        self.rmap0 = make_replica_map(n_clusters, self.n_shards,
                                      self.striping,
                                      hot_clusters=hot_clusters,
                                      n_replicas=n_replicas)
        self.live_replicas = self.rmap0.replicas.copy()
        self.owner = self.live_replicas[:, 0].copy()
        self.owner_mask = ownership_mask(self.owner, self.n_shards)
        self.failed: set = set()
        self.lost: set = set()
        self.hb = HeartbeatMonitor(self.n_shards,
                                   miss_threshold=miss_threshold)
        self._hb_lock = threading.Lock()
        self._svc_lock = threading.Lock()
        self._last_tick = clock()
        self._reply_event = threading.Event()
        self.stats = FabricStats()
        self.stats.init(self.n_shards)
        # lint: bounded-by(one node/epoch per shard, fixed at deploy)
        self.nodes = []
        # lint: bounded-by(one node/epoch per shard, fixed at deploy)
        self.epochs = []
        for s in range(self.n_shards):
            owned = np.nonzero((self.rmap0.replicas == s).any(axis=1))[0]
            node = ShardNode(s, postings, posting_ids, owned, self)
            self.nodes.append(node)
            self.epochs.append(Epoch(f"{name}/shard{s}", s, node,
                                     clock=clock))
        self._outstanding: dict[int, _TaskRecord] = {}
        self._out_per_shard = np.zeros(self.n_shards, np.int64)
        self._task_ids = iter(range(1, 1 << 62))
        k2 = cfg.n_cand or _auto_ncand(cfg.k)
        self.dup_bound = max_id_replicas(posting_ids)
        self.cand_m = k2 * self.dup_bound
        self.cand_bucket = 256
        self._started = False

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> None:
        if self._started:
            return
        for node in self.nodes:
            node.start()
        with self._hb_lock:
            for s in range(self.n_shards):
                self.hb.beat(s)
        self._started = True

    def stop(self) -> None:
        for node in self.nodes:
            node.stop()
        self._started = False

    def alive_shards(self) -> list[int]:
        return [s for s in range(self.n_shards)
                if s not in self.failed and not self.nodes[s].killed]

    # -- worker-side callbacks (thread-safe) -------------------------------
    def _beat(self, shard: int, latency: float = 0.001) -> None:
        with self._hb_lock:
            self.hb.beat(shard, latency=latency)

    def _note_service(self, shard: int, service_s: float) -> None:
        with self._svc_lock:
            self.stats.busy_s[shard] += service_s
            self.stats.tasks_per_shard[shard] += 1

    # -- fault injection (FaultInjector.poll target) -----------------------
    def inject(self, ev: FaultEvent, shard: int) -> None:
        node = self.nodes[shard]
        now = self.clock()
        if ev.kind == "kill":
            node.kill(flush=not ev.silent)
        elif ev.kind == "stall":
            node.stall_until = now + ev.duration_s
            node.stall_s = max(ev.stall_s, 1e-3)
        elif ev.kind == "corrupt":
            node.corrupt_until = now + ev.duration_s
        else:
            raise ValueError(f"unknown fault kind {ev.kind!r}")

    # -- stage protocol ----------------------------------------------------
    @property
    def pad_batch(self) -> int:
        return self.planner.pad_batch

    def route(self, queries, topk):
        return self.planner.route(queries, topk)

    def plan(self, queries, topk, nprobe_cap=None, routed=None,
             deadline: Optional[float] = None):
        plan = self.planner.plan(queries, topk, nprobe_cap=nprobe_cap,
                                 routed=routed)
        plan.deadline = deadline           # carried to harvest (hedging &
        return plan                        # give-up are deadline-aware)

    def _p2c_assign(self, wanted: np.ndarray
                    ) -> tuple[dict[int, list[int]], list[int]]:
        """Assign each union cluster to one live shard: power-of-two-choices
        over its live replicas by instantaneous load (SQ depth + outstanding
        tasks), ties to the lower shard id.  Returns ({shard: [cid]},
        [lost cid])."""
        depths = np.array([self.nodes[s].qp.sq_len() for s
                           in range(self.n_shards)])
        load = depths + self._out_per_shard
        for s in range(self.n_shards):
            # the instantaneous load signal p2c routes on, surfaced as
            # per-shard gauges (the "is shard 3's SQ the p99?" question)
            self._g_qdepth.set(int(depths[s]), f"shard{s}")
            self._g_out.set(int(self._out_per_shard[s]), f"shard{s}")
        by_shard: dict[int, list[int]] = {}
        lost: list[int] = []
        for c in wanted:
            reps = [int(r) for r in self.live_replicas[c] if r >= 0
                    and r not in self.failed]
            if not reps:
                lost.append(int(c))
                continue
            best = min(reps[:2], key=lambda s: (load[s], s))
            by_shard.setdefault(best, []).append(int(c))
            load[best] += 1
        return by_shard, lost

    def _submit(self, state: _FabricBatch, shard: int, cids: list[int],
                attempt: int = 0, kind: str = "dispatch") -> None:
        cols = np.searchsorted(state.wanted, np.asarray(cids, np.int64))
        task = ShardTask(
            task_id=next(self._task_ids), shard=shard,
            queries=state.queries, q2=state.q2,
            cids=np.asarray(cids, np.int64),
            probe=np.ascontiguousarray(state.probe_u[:, cols]),
            m=self.cand_m, attempt=attempt,
            trace_ids=getattr(state.plan, "trace_ids", ()), kind=kind)
        self.epochs[shard].acquire()
        sent = self.clock()
        self._outstanding[task.task_id] = _TaskRecord(task, state,
                                                      sent_at=sent)
        self._out_per_shard[shard] += 1
        self.stats.tasks += 1
        if self.obs.tracing and task.trace_ids:
            # task LIFETIME (submit -> resolve): tasks overlap on a shard's
            # track while queued, so async "b"/"e" — closed by the single
            # drop point, _drop_outstanding
            self.obs.trace.abegin(
                "task", f"task-{task.task_id}", t=sent,
                trace_id=task.trace_ids[0], track=f"shard-{shard}",
                args={"kind": kind, "attempt": attempt,
                      "clusters": len(cids),
                      "trace_ids": list(task.trace_ids[:32])})
            # flow arrow request -> shard task: the "s" endpoint binds near
            # the request's async span on the requests track, the "f"
            # endpoint lands on the shard task it fanned out to — Perfetto
            # draws the arrow, check_well_nested verifies the pairing
            fid = f"flow-task-{task.task_id}"
            self.obs.trace.flow_start(
                "fanout", fid, t=sent, trace_id=task.trace_ids[0],
                track="requests", args={"shard": shard, "kind": kind})
            self.obs.trace.flow_finish(
                "fanout", fid, t=sent, trace_id=task.trace_ids[0],
                track=f"shard-{shard}")
        if not self.nodes[shard].qp.submit(task, block=False):
            # shard SQ full — treat as an instant dead-letter and requeue
            self._drop_outstanding(task.task_id)
            self._reroute(state, cids, attempt + 1, cause="sq_full")

    def prefetch(self, plan) -> _FabricBatch:
        """Fan-out: dedupe the batch's probed-cluster union, assign owners,
        submit one ShardTask per owner shard."""
        t = plan.times
        t.gather_start = self.clock()
        if self.injector is not None:
            self.injector.poll(self.clock(), self)
        queries = np.ascontiguousarray(np.asarray(plan.queries_dev,
                                                  np.float32))
        q2 = np.einsum("bd,bd->b", queries, queries)[:, None]
        live = plan.pmask & (plan.cids >= 0)
        wanted = np.unique(plan.cids[live]).astype(np.int64)
        # (bp, U) probe-membership: columns follow sorted union order
        bp, p = plan.cids.shape
        probe_u = np.zeros((bp, wanted.size), bool)
        if wanted.size:
            cols = np.searchsorted(wanted, plan.cids[live])
            rows = np.nonzero(live)[0]
            probe_u[rows, cols] = True
        state = _FabricBatch(plan, queries, q2, wanted, probe_u,
                             getattr(plan, "deadline", None))
        by_shard, lost = self._p2c_assign(wanted)
        state.resolve(lost, lost=True)
        for shard, cids in sorted(by_shard.items()):
            self._submit(state, shard, cids)
        t.gather_end = self.clock()
        t.stream_end = t.gather_end
        t.clusters_requested = int(live.sum())
        t.union_clusters = int(wanted.size)
        return state

    def dispatch(self, state: _FabricBatch) -> _FabricBatch:
        state.plan.times.scan_dispatch = self.clock()
        state.dispatched_at = state.plan.times.scan_dispatch
        return state

    # -- failure machinery -------------------------------------------------
    def _drop_outstanding(self, task_id: int) -> Optional[_TaskRecord]:
        rec = self._outstanding.pop(task_id, None)
        if rec is not None:
            self.epochs[rec.task.shard].release()
            self._out_per_shard[rec.task.shard] -= 1
            if self.obs.tracing and rec.task.trace_ids:
                self.obs.trace.aend("task", f"task-{task_id}",
                                    track=f"shard-{rec.task.shard}")
        return rec

    def _reroute(self, state: _FabricBatch, cids, attempt: int,
                 cause: str = "requeue") -> None:
        """Re-dispatch unresolved clusters under the current live replica
        map; clusters past the retry budget (or with no live replica) are
        lost -> the touching queries degrade to partial.  ``cause`` labels
        the requeue counter ("sq_full" | "dead_reply" | "checksum" |
        "failover")."""
        todo = [c for c in cids if c in state.pending]
        if not todo:
            return
        if attempt > self.retry_budget:
            state.resolve(todo, lost=True)
            return
        by_shard, lost = self._p2c_assign(np.asarray(todo, np.int64))
        state.resolve(lost, lost=True)
        for shard, group in sorted(by_shard.items()):
            self._submit(state, shard, group, attempt=attempt,
                         kind="requeue")
            self.stats.requeued_tasks += 1
            self._m_requeued.inc(1, cause)

    def _declare_failed(self, shard: int) -> None:
        """Shard is dead: recompute the failover plan from the seed
        machinery, retire its epoch, and requeue everything it still owed."""
        if shard in self.failed:
            return
        self.failed.add(shard)
        fo = plan_failover(self.rmap0, sorted(self.failed))
        self.owner = fo.owner
        self.owner_mask = ownership_mask(fo.owner, self.n_shards)
        self.live_replicas = self.rmap0.failover(sorted(self.failed)).replicas
        self.lost = set(int(c) for c in fo.lost)
        self.stats.failovers.append({
            "t": self.clock(), "shard": shard,
            "moved": int(fo.moved.size), "lost": int(fo.n_lost)})
        self._m_failovers.inc(1, f"shard{shard}")
        if self.obs.tracing:
            self.obs.trace.instant(
                "failover", track="router",
                args={"shard": shard, "moved": int(fo.moved.size),
                      "lost": int(fo.n_lost)})
        self.epochs[shard].retire()
        orphans = [tid for tid, rec in self._outstanding.items()
                   if rec.task.shard == shard]
        for tid in orphans:
            rec = self._drop_outstanding(tid)
            self._reroute(rec.state, rec.task.cids.tolist(),
                          rec.task.attempt + 1, cause="failover")

    def _maybe_tick(self) -> None:
        """Advance the heartbeat logical clock at tick_s cadence; shards
        past miss_threshold ticks without a beat are declared failed."""
        now = self.clock()
        if now - self._last_tick < self.tick_s:
            return
        with self._hb_lock:
            # one tick per cadence check, never a catch-up burst: a long gap
            # between harvest calls (jit warmup, idle engine) must not burn
            # miss_threshold ticks at once and fail every healthy shard
            self.hb.tick()
            self._last_tick = now
            newly = [int(s) for s in self.hb.failed()
                     if s not in self.failed]
        for s in newly:
            self._declare_failed(s)

    def _pump_replies(self) -> int:
        """Drain every shard CQ; route replies through the outstanding
        table to their batch state.  Returns replies consumed."""
        n = 0
        for node in self.nodes:
            for reply in node.qp.poll():
                n += 1
                rec = self._drop_outstanding(reply.task_id)
                if rec is None:
                    continue               # hedge-resolved or abandoned
                self.stats.replies += 1
                if reply.status == "dead":
                    self.stats.dead_replies += 1
                    self._m_retries.inc(1, "dead_reply")
                    self._declare_failed(reply.shard)
                    self._reroute(rec.state, rec.task.cids.tolist(),
                                  rec.task.attempt + 1, cause="dead_reply")
                    continue
                if _payload_crc(reply.cand_d, reply.cand_i) != reply.checksum:
                    self.stats.checksum_failures += 1
                    self.stats.retries += 1
                    self._m_retries.inc(1, "checksum")
                    if self.obs.tracing and rec.task.trace_ids:
                        self.obs.trace.instant(
                            "checksum_retry", track="router",
                            trace_id=rec.task.trace_ids[0],
                            args={"shard": reply.shard,
                                  "task_id": reply.task_id})
                    self._reroute(rec.state, rec.task.cids.tolist(),
                                  rec.task.attempt + 1, cause="checksum")
                    continue
                self._h_task.observe(reply.service_s)
                fresh = rec.state.resolve(rec.task.cids.tolist())
                if fresh:
                    rec.state.cand.append((reply.cand_d, reply.cand_i))
        return n

    def _hedge_due(self, state: _FabricBatch) -> None:
        """Deadline-aware hedged re-dispatch: an outstanding task older than
        the hedge threshold (or whose batch deadline is at risk) gets its
        unresolved clusters duplicated onto alternate live replicas; the
        first reply to land resolves the clusters, the loser is ignored."""
        now = self.clock()
        thresh = self.hedge_after_s
        if state.deadline is not None:
            thresh = min(thresh, max((state.deadline - now) * 0.5, 0.01))
        for tid, rec in list(self._outstanding.items()):
            if rec.state is not state or rec.hedged:
                continue
            if now - rec.sent_at < thresh:
                continue
            todo = [c for c in rec.task.cids.tolist() if c in state.pending]
            if not todo:
                continue
            by_shard: dict[int, list[int]] = {}
            for c in todo:
                alts = [int(r) for r in self.live_replicas[c]
                        if r >= 0 and r != rec.task.shard
                        and r not in self.failed]
                if alts:
                    by_shard.setdefault(alts[0], []).append(c)
            if not by_shard:
                continue
            rec.hedged = True
            if self.obs.tracing and rec.task.trace_ids:
                self.obs.trace.instant(
                    "hedge", track="router",
                    trace_id=rec.task.trace_ids[0],
                    args={"slow_shard": rec.task.shard,
                          "task_id": tid,
                          "age_ms": round((now - rec.sent_at) * 1e3, 3)})
            for shard, group in sorted(by_shard.items()):
                self._submit(state, shard, group,
                             attempt=rec.task.attempt, kind="hedge")
                self.stats.hedges += 1
                self._m_hedges.inc(1, f"shard{shard}")

    def harvest(self, state: _FabricBatch) -> BatchResult:
        """Collect this batch's replies (pumping every in-flight batch's),
        drive failure detection, merge, and stamp partial rows."""
        t = state.plan.times
        give_up = state.dispatched_at + self.harvest_timeout_s
        if state.deadline is not None:
            give_up = max(give_up, state.deadline)
        timed_out = False
        while not state.complete:
            if self.injector is not None:
                self.injector.poll(self.clock(), self)
            got = self._pump_replies()
            self._maybe_tick()
            if state.complete:
                break
            if self.clock() >= give_up:
                # bound the wait: whatever is still unresolved is lost and
                # the touching queries degrade to partial — a zero-drop
                # fabric never hangs a batch on a black-holed shard
                self.stats.timeouts += 1
                self._m_timeouts.inc()
                timed_out = True
                if self.obs.tracing:
                    self.obs.trace.instant(
                        "give_up", track="router",
                        args={"unresolved": len(state.pending)})
                state.resolve(list(state.pending), lost=True)
                break
            self._hedge_due(state)
            if not got:
                self._reply_event.wait(timeout=0.002)
                self._reply_event.clear()
        tids = getattr(state.plan, "trace_ids", ())
        m0 = self.clock() if (self.obs.tracing and tids) else 0.0
        ids, dists = self._merge(state)
        t.scan_done = self.clock()
        if m0:
            # harvest runs sequentially on the poller thread, so merges on
            # the router track never overlap — an "X" span is safe
            self.obs.trace.span(
                "merge", m0, t.scan_done, trace_id=tids[0], track="router",
                args={"shard_sets": len(state.cand),
                      "trace_ids": list(tids[:32])})
        b = t.size
        partial = state.partial_rows()[:b].copy()
        partial_reason = "timeout" if timed_out else "no_replica"
        n_partial = int(partial.sum())
        self.stats.partial_queries += n_partial
        if n_partial:
            self._m_partial.inc(n_partial, partial_reason)
        return BatchResult(
            ids=ids[:b], dists=dists[:b],
            nprobe=state.plan.nprobe[:b].copy(), times=t,
            partial=partial, partial_reason=partial_reason,
            quality=self._coverage(state, b),
            shards=self._primary_shards(state, b))

    def _coverage(self, state: _FabricBatch, b: int) -> np.ndarray:
        """(b,) per-query COVERAGE proxy: the rank-weighted fraction of
        this query's probed clusters a live replica actually scanned —
        1.0 on complete rows, < 1.0 exactly on the partial rows whose
        recall is at risk.  Probe rank j carries weight ``1/(1+j)``: the
        router orders ``plan.cids`` by expected yield (nearest centroid
        first — the cluster most of the true neighbors live in), so losing
        a query's rank-0 probe costs far more recall than losing its
        rank-15 probe, and the proxy must say so.  Under round-robin
        striping an unweighted count cannot separate a dead shard's home
        queries (they lose rank 0) from bystanders (they lose ~1/S of the
        tail) — every query loses the same 1/S of its probes.  This is
        the fabric's stand-in for the pipeline's rerank-agreement proxy
        (the shards return exact f32 distances, so agreement would be
        trivially 1.0)."""
        cids = np.asarray(state.plan.cids[:b], np.int64)
        valid = cids >= 0
        w = 1.0 / (1.0 + np.arange(cids.shape[1], dtype=np.float32))
        tot = (valid * w).sum(axis=1)
        lost_w = np.zeros(b, np.float32)
        if state.lost:
            lost = np.isin(cids, np.fromiter(
                state.lost, np.int64, len(state.lost))) & valid
            lost_w = (lost * w).sum(axis=1).astype(np.float32)
        cov = 1.0 - lost_w / np.maximum(tot, 1e-9)
        return cov.astype(np.float32)

    def _primary_shards(self, state: _FabricBatch, b: int) -> np.ndarray:
        """(b,) primary shard of each query's nearest probed cluster —
        the label the quality monitor buckets per-shard proxy histograms
        by (the kill drill's 'did the victim's queries dip?' view)."""
        c0 = np.asarray(state.plan.cids[:b, 0], np.int64)
        return self.striping.shard_of(np.maximum(c0, 0)).astype(np.int32)

    def _merge(self, state: _FabricBatch) -> tuple[np.ndarray, np.ndarray]:
        """Cross-shard merge: concatenate every shard's candidate set and
        run the permutation-invariant ``merge_candidate_topk`` — dedup by
        id, ascending, (inf, -1) invalid slots.  Width is bucketed so the
        jit program count stays bounded under varying shard fan-outs."""
        bp = state.queries.shape[0]
        k = self.cfg.k
        if not state.cand:
            return (np.full((bp, k), -1, np.int32),
                    np.full((bp, k), np.inf, np.float32))
        cd = np.concatenate([c[0] for c in state.cand], axis=1)
        ci = np.concatenate([c[1] for c in state.cand], axis=1)
        n = cd.shape[1]
        width = -(-max(n, k) // self.cand_bucket) * self.cand_bucket
        if width != n:
            cd = np.pad(cd, ((0, 0), (0, width - n)),
                        constant_values=np.inf)
            ci = np.pad(ci, ((0, 0), (0, width - n)), constant_values=-1)
        vals, out_ids = merge_candidate_topk(jnp.asarray(cd),
                                             jnp.asarray(ci), k)
        return np.asarray(out_ids), np.asarray(vals)

    # -- synchronous / helper paths ---------------------------------------
    def scan_sync(self, queries, topk) -> BatchResult:
        """Thread-free end-to-end scan: fan out by PRIMARY owner, scan each
        shard's slice inline, merge.  The property tests' deterministic
        path (no p2c load dependence, no worker scheduling)."""
        plan = self.plan(queries, topk)
        t = plan.times
        qs = np.ascontiguousarray(np.asarray(plan.queries_dev, np.float32))
        q2 = np.einsum("bd,bd->b", qs, qs)[:, None]
        live = plan.pmask & (plan.cids >= 0)
        wanted = np.unique(plan.cids[live]).astype(np.int64)
        bp = qs.shape[0]
        probe_u = np.zeros((bp, wanted.size), bool)
        if wanted.size:
            cols = np.searchsorted(wanted, plan.cids[live])
            probe_u[np.nonzero(live)[0], cols] = True
        state = _FabricBatch(plan, qs, q2, wanted, probe_u, None)
        for s in range(self.n_shards):
            cids = [int(c) for c in wanted if self.owner[c] == s]
            if not cids:
                continue
            cols = np.searchsorted(wanted, np.asarray(cids, np.int64))
            task = ShardTask(0, s, qs, q2, np.asarray(cids, np.int64),
                             np.ascontiguousarray(probe_u[:, cols]),
                             m=self.cand_m)
            state.cand.append(self.nodes[s].scan(task))
            state.resolve(cids)
        state.resolve(list(state.pending), lost=True)
        ids, dists = self._merge(state)
        t.scan_dispatch = t.gather_start = t.gather_end = t.stream_end \
            = t.plan_end
        t.scan_done = self.clock()
        b = t.size
        return BatchResult(ids=ids[:b], dists=dists[:b],
                           nprobe=plan.nprobe[:b].copy(), times=t,
                           partial=state.partial_rows()[:b].copy())

    def query_shards(self, queries) -> np.ndarray:
        """(B,) primary shard of each query's nearest centroid — how the
        drills find a hot shard's query rows."""
        cids, _ = self.planner.route(np.asarray(queries, np.float32),
                                     self.cfg.k)
        return self.striping.shard_of(cids[:, 0].astype(np.int64))

    def warmup(self, batch_sizes=(16, 32)) -> int:
        """Pre-compile the plan and merge programs for the shapes live
        traffic will hit (the shard scans are numpy — nothing to warm)."""
        n = 0
        dim = int(np.asarray(self.index.centroids).shape[1])
        for b in batch_sizes:
            bp = -(-b // self.pad_batch) * self.pad_batch
            q = np.zeros((bp, dim), np.float32)
            self.planner.route(q, self.cfg.k)
            n += 1
        for w in range(1, 1 + self.n_shards):
            width = -(-w * self.cand_m // self.cand_bucket) \
                * self.cand_bucket
            merge_candidate_topk(
                jnp.full((self.pad_batch, width), jnp.inf, jnp.float32),
                jnp.full((self.pad_batch, width), -1, jnp.int32),
                self.cfg.k)
            n += 1
        return n
