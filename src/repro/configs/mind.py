"""mind [recsys] embed_dim=64 n_interests=4 capsule_iters=3 multi-interest
retrieval. [arXiv:1904.08030; unverified].  Item table 2^22 x 64.

``retrieval_cand`` is MIND's native serving mode: 4 interest vectors x 1M
candidates, max-over-interests dot scoring (and the Helmsman IVF path in
examples/train_retrieval.py)."""
from repro.configs import ArchDef, RECSYS_SHAPES
from repro.models.recsys import RecSysConfig

CONFIG = RecSysConfig(
    name="mind", kind="mind", n_sparse=1, embed_dim=64,
    table_rows=1 << 22, seq_len=50, n_interests=4, capsule_iters=3,
)
ARCH = ArchDef("mind", "recsys", CONFIG, dict(RECSYS_SHAPES),
               source="[arXiv:1904.08030; unverified]")
