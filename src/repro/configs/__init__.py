"""Architecture registry: 10 assigned archs + the paper's own Helmsman config.

Each configs/<id>.py exports ``ARCH`` (an ArchDef).  ``get(name)`` /
``all_archs()`` are consumed by launch/dryrun.py, launch/train.py and the
smoke tests.  Cell construction (abstract inputs + step fn + shardings per
(arch x shape x mesh)) lives in launch/cells.py.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    kind: str                  # train | prefill | decode | serve | retrieval
    batch: int
    seq: int = 0               # LM context / recsys history
    extras: tuple = ()         # family-specific ((key, value), ...) pairs

    def get(self, key, default=None):
        for k, v in self.extras:
            if k == key:
                return v
        return default


@dataclasses.dataclass(frozen=True)
class ArchDef:
    name: str
    family: str                # lm | gnn | recsys | anns
    config: Any
    shapes: Dict[str, ShapeDef]
    source: str = ""           # [source; verified-tier] from the assignment
    skip_shapes: tuple = ()    # (shape_name, reason) pairs — recorded, not run


ARCH_NAMES = [
    "gemma3_12b", "phi4_mini", "gemma3_27b", "llama4_scout", "qwen2_moe",
    "graphcast",
    "xdeepfm", "wide_deep", "mind", "din",
    "helmsman",
]


def get(name: str) -> ArchDef:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.ARCH


def all_archs(include_extra: bool = True):
    names = ARCH_NAMES if include_extra else ARCH_NAMES[:-1]
    return [get(n) for n in names]


# shared LM shape set (assignment: seq_len x global_batch)
def lm_shapes(*, sub_quadratic: bool):
    shapes = {
        "train_4k": ShapeDef("train_4k", "train", batch=256, seq=4096),
        "prefill_32k": ShapeDef("prefill_32k", "prefill", batch=32, seq=32768),
        "decode_32k": ShapeDef("decode_32k", "decode", batch=128, seq=32768),
    }
    skips = ()
    if sub_quadratic:
        shapes["long_500k"] = ShapeDef("long_500k", "decode", batch=1, seq=524288)
    else:
        skips = (("long_500k",
                  "pure full-attention decoder: 500k-ctx decode requires "
                  "sub-quadratic attention (spec: skip & note in DESIGN.md)"),)
    return shapes, skips


RECSYS_SHAPES = {
    "train_batch": ShapeDef("train_batch", "train", batch=65536),
    "serve_p99": ShapeDef("serve_p99", "serve", batch=512),
    "serve_bulk": ShapeDef("serve_bulk", "serve", batch=262144),
    "retrieval_cand": ShapeDef(
        "retrieval_cand", "retrieval", batch=1,
        extras=(("n_candidates", 1_000_000),),
    ),
}
