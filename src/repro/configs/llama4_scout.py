"""llama4-scout-17b-a16e [moe] 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 + 1 shared expert. [hf:meta-llama/...; unverified]

~109B total params / ~17B active.  Expert weights FSDP-sharded over `data`
(ZeRO-3) on top of EP over `model`, so params+moments fit 16 GB HBM chips.
"""
import jax.numpy as jnp
from repro.configs import ArchDef, lm_shapes
from repro.models.lm import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
    n_kv=8, d_ff=0, vocab=202048, d_head=128, dtype=jnp.bfloat16, fsdp=True,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                  n_shared=1, d_ff_shared=8192),
)
_shapes, _skips = lm_shapes(sub_quadratic=False)
ARCH = ArchDef("llama4_scout", "lm", CONFIG, _shapes,
               source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
               skip_shapes=_skips)
