"""graphcast [gnn] n_layers=16 d_hidden=512 mesh_refinement=6 aggregator=sum
n_vars=227 — encoder-processor-decoder mesh GNN. [arXiv:2212.12794; unverified]

Shapes (assigned):
  full_graph_sm  cora-scale full batch    (N=2708,  E=10556,  d=1433)
  minibatch_lg   reddit-scale sampled     (N=232965, E=114615892, batch=1024,
                                           fanout 15-10, d=602)
  ogb_products   full-batch large         (N=2449029, E=61859140, d=100)
  molecule       batched small graphs     (N=30, E=64, batch=128, d=32)

Edge arrays are padded to a multiple of 512 with an edge mask (edges shard
over the batch axes); the sampled shape's sizes are the padded subgraph of
the 15-10 fanout sampler in data/synthetic.neighbor_sample.
"""
from repro.configs import ArchDef, ShapeDef
from repro.models.gnn import GNNConfig


def _pad512(e: int) -> int:
    return -(-e // 512) * 512


CONFIG = GNNConfig(name="graphcast", n_layers=16, d_hidden=512,
                   n_vars=227, mesh_refinement=6, aggregator="sum")

SHAPES = {
    "full_graph_sm": ShapeDef(
        "full_graph_sm", "train", batch=1,
        extras=(("n_nodes", 2708), ("n_edges", _pad512(10556)),
                ("d_feat", 1433), ("mode", "full")),
    ),
    "minibatch_lg": ShapeDef(
        "minibatch_lg", "train", batch=1024,
        extras=(("n_nodes", 184320),          # padded sampled frontier
                ("n_edges", 1024 * 15 + 16384 * 10),   # 15360 + 163840
                ("d_feat", 602), ("mode", "sampled")),
    ),
    "ogb_products": ShapeDef(
        "ogb_products", "train", batch=1,
        extras=(("n_nodes", 2449029), ("n_edges", _pad512(61859140)),
                ("d_feat", 100), ("mode", "full")),
    ),
    "molecule": ShapeDef(
        "molecule", "train", batch=128,
        extras=(("n_nodes", 30), ("n_edges", 64), ("d_feat", 32),
                ("mode", "batched")),
    ),
}
ARCH = ArchDef("graphcast", "gnn", CONFIG, SHAPES,
               source="[arXiv:2212.12794; unverified]")
