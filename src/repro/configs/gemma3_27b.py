"""gemma3-27b [dense] 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
5:1 local:global, 128k. [hf:google/gemma-3-1b-pt; unverified]

62 layers = 10 blocks of (5 local + 1 global) + 2 trailing local layers
(matches the HF gemma3 pattern: layer global iff (idx+1) % 6 == 0, pattern
truncated at the end) -> period=6, tail_local=2.  FSDP on d_ff so bf16
params + f32 moments fit 16 GB/chip.
"""
import jax.numpy as jnp
from repro.configs import ArchDef, lm_shapes
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="gemma3-27b", n_layers=62, d_model=5376, n_heads=32, n_kv=16,
    d_ff=21504, vocab=262144, d_head=128, rope_theta=1_000_000.0,
    window=1024, period=6, tail_local=2, dtype=jnp.bfloat16, fsdp=True,
)
_shapes, _skips = lm_shapes(sub_quadratic=True)
ARCH = ArchDef("gemma3_27b", "lm", CONFIG, _shapes,
               source="[hf:google/gemma-3-1b-pt; unverified]",
               skip_shapes=_skips)
