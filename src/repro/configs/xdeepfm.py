"""xdeepfm [recsys] n_sparse=39 embed_dim=10 cin=200-200-200 mlp=400-400.
[arXiv:1803.05170; paper].  Table: 2^24 rows (criteo-scale), row-sharded."""
from repro.configs import ArchDef, RECSYS_SHAPES
from repro.models.recsys import RecSysConfig

CONFIG = RecSysConfig(
    name="xdeepfm", kind="xdeepfm", n_sparse=39, embed_dim=10,
    table_rows=1 << 24, mlp=(400, 400), cin_layers=(200, 200, 200),
)
ARCH = ArchDef("xdeepfm", "recsys", CONFIG, dict(RECSYS_SHAPES),
               source="[arXiv:1803.05170; paper]")
