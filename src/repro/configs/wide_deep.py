"""wide-deep [recsys] n_sparse=40 embed_dim=32 mlp=1024-512-256.
[arXiv:1606.07792; paper].  Table: 2^24 rows, row-sharded."""
from repro.configs import ArchDef, RECSYS_SHAPES
from repro.models.recsys import RecSysConfig

CONFIG = RecSysConfig(
    name="wide-deep", kind="wide_deep", n_sparse=40, embed_dim=32,
    table_rows=1 << 24, mlp=(1024, 512, 256),
)
ARCH = ArchDef("wide_deep", "recsys", CONFIG, dict(RECSYS_SHAPES),
               source="[arXiv:1606.07792; paper]")
