"""din [recsys] embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
target-attention ranker. [arXiv:1706.06978; paper].  Item table 2^22 x 18."""
from repro.configs import ArchDef, RECSYS_SHAPES
from repro.models.recsys import RecSysConfig

CONFIG = RecSysConfig(
    name="din", kind="din", n_sparse=4, embed_dim=18,
    table_rows=1 << 22, mlp=(200, 80), attn_mlp=(80, 40), seq_len=100,
)
ARCH = ArchDef("din", "recsys", CONFIG, dict(RECSYS_SHAPES),
               source="[arXiv:1706.06978; paper]")
