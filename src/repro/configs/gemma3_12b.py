"""gemma3-12b [dense] 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144
5:1 local:global sliding window, 128k context. [hf:google/gemma-3-1b-pt; unverified]"""
import jax.numpy as jnp
from repro.configs import ArchDef, lm_shapes
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16, n_kv=8,
    d_ff=15360, vocab=262144, d_head=256, rope_theta=1_000_000.0,
    window=1024, period=6, dtype=jnp.bfloat16,
)
_shapes, _skips = lm_shapes(sub_quadratic=True)  # 5:1 sliding window
ARCH = ArchDef("gemma3_12b", "lm", CONFIG, _shapes,
               source="[hf:google/gemma-3-1b-pt; unverified]",
               skip_shapes=_skips)
