"""Helmsman — the paper's own serving config (extra arch beyond the 40 cells).

SIFT100M-scale clustered index: C=2^20 clusters x L=128 slots x D=128 dims
(f32 posting payload = 64 GiB, striped over the 16 `model` shards = 4 GiB
per chip; centroids 512 MiB replicated = the in-DRAM tier; the DRAM:SSD =
1:20 split of §5.1 maps to centroid-bytes : posting-bytes = 1:128/replica~4).

Shapes:
  serve_online  B=4096 queries, nprobe<=256 (search/ads SLA traffic)
  serve_bulk    B=65536 (offline scoring)
  build_step    one distributed k-means Lloyd iteration over 16M vectors
"""
import dataclasses
from repro.configs import ArchDef, ShapeDef


@dataclasses.dataclass(frozen=True)
class HelmsmanConfig:
    name: str = "helmsman"
    n_clusters: int = 1 << 20
    cluster_len: int = 128
    dim: int = 128
    nprobe_max: int = 256
    k: int = 100


CONFIG = HelmsmanConfig()
SHAPES = {
    "serve_online": ShapeDef("serve_online", "anns_serve", batch=4096),
    "serve_bulk": ShapeDef("serve_bulk", "anns_serve", batch=65536),
    "build_step": ShapeDef(
        "build_step", "anns_build", batch=1 << 24,
        extras=(("k_coarse", 4096),),
    ),
}
ARCH = ArchDef("helmsman", "anns", CONFIG, SHAPES,
               source="[this paper; §5.1 setup]")
