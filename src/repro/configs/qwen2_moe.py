"""qwen2-moe-a2.7b [moe] 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 + 4 shared. [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Experts padded 60 -> 64 for even EP over model=16 (router masks the pads)."""
import jax.numpy as jnp
from repro.configs import ArchDef, lm_shapes
from repro.models.lm import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="qwen2-moe-a2.7b", n_layers=24, d_model=2048, n_heads=16, n_kv=16,
    d_ff=0, vocab=151936, d_head=128, dtype=jnp.bfloat16,
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408,
                  n_shared=4, d_ff_shared=1408, e_pad=64),
)
_shapes, _skips = lm_shapes(sub_quadratic=False)
ARCH = ArchDef("qwen2_moe", "lm", CONFIG, _shapes,
               source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]", skip_shapes=_skips)
