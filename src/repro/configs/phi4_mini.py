"""phi4-mini-3.8b [dense] 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
import jax.numpy as jnp
from repro.configs import ArchDef, lm_shapes
from repro.models.lm import LMConfig

CONFIG = LMConfig(
    name="phi4-mini-3.8b", n_layers=32, d_model=3072, n_heads=24, n_kv=8,
    d_ff=8192, vocab=200064, d_head=128, dtype=jnp.bfloat16,
)
_shapes, _skips = lm_shapes(sub_quadratic=False)  # pure full attention
ARCH = ArchDef("phi4_mini", "lm", CONFIG, _shapes,
               source="[arXiv:2412.08905; hf]", skip_shapes=_skips)
