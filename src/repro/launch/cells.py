"""Cell builders: (arch x shape x mesh) -> (step_fn, abstract args, shardings).

A "cell" is one dry-run unit: the jitted step function for that architecture
and input shape, with explicit in/out shardings on the production mesh, plus
abstract (ShapeDtypeStruct) arguments so nothing is ever allocated.

MODEL_FLOPS conventions (for the §Roofline useful-compute ratio):
  train    6 * N(_active) * tokens
  prefill  2 * N(_active) * tokens
  decode   2 * N(_active) * batch          (one token per sequence)
  gnn      (see _gnn_model_flops) x3 for train
  recsys   per-arch analytic estimate x3 for train
  anns     2 * B * D * (C_scanned + nprobe*L) distance MACs->flops

Pallas kernels are NOT used in the dry-run path (interpret-mode grids would
be unrolled on the CPU backend); the jnp reference path has identical
flops/bytes, and the kernels are validated against it in tests/.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchDef, ShapeDef
from repro.optim import adamw


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    abstract_args: tuple
    in_specs: tuple
    out_specs: Any               # pytree of PartitionSpec or None
    model_flops: float
    donate: tuple = ()
    note: str = ""


def batch_axes(mesh: Mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def _bspec(mesh: Mesh, batch: int, *trailing) -> P:
    """Batch sharding that degrades to replication when batch < dp factors."""
    if batch % dp_size(mesh) == 0:
        return P(batch_axes(mesh), *trailing)
    if batch % mesh.shape["data"] == 0:
        return P("data", *trailing)
    return P(None, *trailing)


def f32_like(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), tree
    )


def opt_abstract(params_abs) -> adamw.AdamWState:
    return adamw.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=f32_like(params_abs),
        nu=f32_like(params_abs),
    )


def zero1_specs(specs, shapes, mesh: Mesh):
    """Optimizer-moment sharding: param spec + shard the first free dim over
    `data` when divisible (ZeRO-1).  Skipped when `data` already appears
    (FSDP weights)."""
    dsize = mesh.shape["data"]

    def one(spec: P, s) -> P:
        parts = tuple(spec) + (None,) * (len(s.shape) - len(tuple(spec)))
        flat = []
        for p_ in parts:
            if p_ is None:
                flat.append(None)
            elif isinstance(p_, tuple):
                flat.extend(p_)
            else:
                flat.append(p_)
        if "data" in flat:
            return spec
        for i, p_ in enumerate(parts):
            if p_ is None and s.shape[i] % dsize == 0 and s.shape[i] >= dsize:
                return P(*parts[:i], "data", *parts[i + 1:])
        return spec

    return jax.tree.map(one, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def opt_specs(param_specs_tree, params_abs, mesh: Mesh) -> adamw.AdamWState:
    z = zero1_specs(param_specs_tree, params_abs, mesh)
    return adamw.AdamWState(step=P(), mu=z, nu=z)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_cell(arch: ArchDef, shape: ShapeDef, mesh: Mesh) -> Cell:
    from repro.models import lm as lm_mod
    from repro.models.lm import transformer as tf

    cfg = arch.config
    tp = mesh.shape["model"]
    p_abs = tf.param_shapes(cfg)
    p_specs = tf.param_specs(cfg, tp=tp)
    b, s = shape.batch, shape.seq
    if cfg.pure_dp and b % (mesh.shape["data"] * tp) == 0:
        tokens_spec = P(("data", "model"), None)   # batch over BOTH axes
    else:
        tokens_spec = _bspec(mesh, b, None)

    if shape.kind == "train":
        o_abs = opt_abstract(p_abs)
        o_specs = opt_specs(p_specs, p_abs, mesh)
        tokens = jax.ShapeDtypeStruct((b, s + 1), jnp.int32)
        step = tf.make_train_step(cfg, mesh=mesh)
        mf = 6.0 * cfg.n_active_params * b * s
        return Cell(arch.name, shape.name, step,
                    (p_abs, o_abs, tokens),
                    (p_specs, o_specs, tokens_spec),
                    (p_specs, o_specs, None), mf,
                    donate=(0, 1))
    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)

        def step(params, tokens):
            return tf.prefill_step(params, tokens, cfg, mesh)

        mf = 2.0 * cfg.n_active_params * b * s
        return Cell(arch.name, shape.name, step, (p_abs, tokens),
                    (p_specs, tokens_spec), None, mf)
    if shape.kind == "decode":
        cache_abs = tf.cache_shapes(cfg, b, s)
        c_specs = tf.cache_specs(cfg, mesh)
        # batch dim of the cache follows the token batch sharding
        if b % dp_size(mesh) != 0:
            c_specs = jax.tree.map(
                lambda sp: P(*[None if (isinstance(x, tuple) or x in ("pod", "data")) else x
                               for x in tuple(sp)]),
                c_specs, is_leaf=lambda x: isinstance(x, P))
        token = jax.ShapeDtypeStruct((b,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)

        def step(params, cache, token, pos):
            return tf.decode_step(params, cache, token, pos, cfg, mesh)

        mf = 2.0 * cfg.n_active_params * b
        return Cell(arch.name, shape.name, step,
                    (p_abs, cache_abs, token, pos),
                    (p_specs, c_specs, _bspec(mesh, b), P()),
                    (None, c_specs), mf, donate=(1,))
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------
def _gnn_model_flops(cfg, n_nodes, n_edges, d_feat, train=True) -> float:
    dh = cfg.d_hidden
    per_layer = 6 * dh * dh * n_edges + 6 * dh * dh * n_nodes
    enc = 2 * (d_feat * dh + dh * dh) * n_nodes
    dec = 2 * (dh * dh + dh * cfg.n_vars) * n_nodes
    f = cfg.n_layers * per_layer + enc + dec
    return (3.0 if train else 1.0) * f


def _gnn_cell(arch: ArchDef, shape: ShapeDef, mesh: Mesh) -> Cell:
    from repro.models import gnn as gnn_mod
    from repro.models.gnn import graphcast as gc

    cfg = arch.config
    n, e = shape.get("n_nodes"), shape.get("n_edges")
    d = shape.get("d_feat")
    mode = shape.get("mode")
    p_abs = gc.param_shapes(cfg, d)
    p_specs = gc.param_specs(cfg)
    o_abs = opt_abstract(p_abs)
    o_specs = opt_specs(p_specs, p_abs, mesh)
    ba = batch_axes(mesh)

    if mode == "batched":
        bsz = shape.batch
        bs = _bspec(mesh, bsz)
        batch_abs = {
            "node_feats": jax.ShapeDtypeStruct((bsz, n, d), jnp.float32),
            "src": jax.ShapeDtypeStruct((bsz, e), jnp.int32),
            "dst": jax.ShapeDtypeStruct((bsz, e), jnp.int32),
            "edge_mask": jax.ShapeDtypeStruct((bsz, e), jnp.bool_),
            "targets": jax.ShapeDtypeStruct((bsz, n, cfg.n_vars), jnp.float32),
        }
        b_specs = {
            "node_feats": _bspec(mesh, bsz, None, None),
            "src": _bspec(mesh, bsz, None),
            "dst": _bspec(mesh, bsz, None),
            "edge_mask": _bspec(mesh, bsz, None),
            "targets": _bspec(mesh, bsz, None, None),
        }
        step = gc.make_train_step(cfg, batched=True)
        mf = _gnn_model_flops(cfg, n * bsz, e * bsz, d)
    else:
        batch_abs = {
            "node_feats": jax.ShapeDtypeStruct((n, d), jnp.float32),
            "src": jax.ShapeDtypeStruct((e,), jnp.int32),
            "dst": jax.ShapeDtypeStruct((e,), jnp.int32),
            "edge_mask": jax.ShapeDtypeStruct((e,), jnp.bool_),
            "targets": jax.ShapeDtypeStruct((n, cfg.n_vars), jnp.float32),
            "node_mask": jax.ShapeDtypeStruct((n,), jnp.bool_),
        }
        b_specs = {
            "node_feats": P(None, None),      # hidden dim shards via params
            "src": P(ba), "dst": P(ba), "edge_mask": P(ba),
            "targets": P(None, None),
            "node_mask": P(None),
        }
        use_mesh = cfg.sharded_mp or cfg.row_dp
        step = gc.make_train_step(cfg, batched=False,
                                  mesh=mesh if use_mesh else None)
        if cfg.row_dp:
            # row-DP contract: node rows divide the flat mesh; pad N up
            n_flat = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
            n = -(-n // n_flat) * n_flat
            batch_abs["node_feats"] = jax.ShapeDtypeStruct((n, d), jnp.float32)
            batch_abs["targets"] = jax.ShapeDtypeStruct((n, cfg.n_vars), jnp.float32)
            batch_abs["node_mask"] = jax.ShapeDtypeStruct((n,), jnp.bool_)
            ba_flat = tuple(mesh.axis_names)
            b_specs["node_feats"] = P(ba_flat, None)
            b_specs["targets"] = P(ba_flat, None)
            b_specs["node_mask"] = P(ba_flat)
            b_specs["src"] = P(ba_flat)
            b_specs["dst"] = P(ba_flat)
            b_specs["edge_mask"] = P(ba_flat)
            # edges must divide the flat mesh too
            e_flat = -(-e // n_flat) * n_flat
            for kk in ("src", "dst"):
                batch_abs[kk] = jax.ShapeDtypeStruct((e_flat,), jnp.int32)
            batch_abs["edge_mask"] = jax.ShapeDtypeStruct((e_flat,), jnp.bool_)
        mf = _gnn_model_flops(cfg, n, e, d)
    return Cell(arch.name, shape.name, step,
                (p_abs, o_abs, batch_abs),
                (p_specs, o_specs, b_specs),
                (p_specs, o_specs, None), mf, donate=(0, 1))


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------
def _recsys_model_flops(cfg, batch: int) -> float:
    d = cfg.embed_dim
    f = cfg.n_sparse
    fl = 0.0
    if cfg.kind == "xdeepfm":
        prev = f
        for hk in cfg.cin_layers:
            fl += 2 * prev * f * hk * d + prev * f * d
            prev = hk
        dims = (f * d,) + tuple(cfg.mlp) + (1,)
        fl += sum(2 * a * b_ for a, b_ in zip(dims[:-1], dims[1:]))
    elif cfg.kind == "wide_deep":
        dims = (f * d,) + tuple(cfg.mlp) + (1,)
        fl += sum(2 * a * b_ for a, b_ in zip(dims[:-1], dims[1:]))
    elif cfg.kind == "din":
        s = cfg.seq_len
        adims = (4 * d,) + tuple(cfg.attn_mlp) + (1,)
        fl += s * sum(2 * a * b_ for a, b_ in zip(adims[:-1], adims[1:]))
        mdims = ((cfg.n_sparse + 2) * d,) + tuple(cfg.mlp) + (1,)
        fl += sum(2 * a * b_ for a, b_ in zip(mdims[:-1], mdims[1:]))
    elif cfg.kind == "mind":
        s, i = cfg.seq_len, cfg.n_interests
        fl += 2 * s * d * d                       # bilinear map
        fl += cfg.capsule_iters * (4 * i * s * d)  # routing iterations
        fl += 2 * d * d + 2 * i * d               # label attention
    return float(fl * batch)


def _recsys_batch_abs(cfg, b: int, mesh: Mesh) -> tuple[dict, dict]:
    abs_ = {
        "sparse_ids": jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b,), jnp.float32),
    }
    specs = {
        "sparse_ids": _bspec(mesh, b, None),
        "labels": _bspec(mesh, b),
    }
    if cfg.seq_len:
        abs_["hist_ids"] = jax.ShapeDtypeStruct((b, cfg.seq_len), jnp.int32)
        abs_["hist_len"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        specs["hist_ids"] = _bspec(mesh, b, None)
        specs["hist_len"] = _bspec(mesh, b)
    return abs_, specs


def _recsys_cell(arch: ArchDef, shape: ShapeDef, mesh: Mesh) -> Cell:
    from repro.models import recsys as rs
    from repro.models.recsys import models as rm

    cfg = arch.config
    ba = batch_axes(mesh)
    p_abs = rm.param_shapes(cfg)
    p_specs = rm.param_specs(cfg)

    if shape.kind == "train":
        b = shape.batch
        o_abs = opt_abstract(p_abs)
        o_specs = opt_specs(p_specs, p_abs, mesh)
        batch_abs, b_specs = _recsys_batch_abs(cfg, b, mesh)
        step = rm.make_train_step(cfg, mesh=mesh, batch_axes=ba)
        mf = 3.0 * _recsys_model_flops(cfg, b)
        return Cell(arch.name, shape.name, step,
                    (p_abs, o_abs, batch_abs),
                    (p_specs, o_specs, b_specs),
                    (p_specs, o_specs, None), mf, donate=(0, 1))
    if shape.kind == "serve":
        b = shape.batch
        batch_abs, b_specs = _recsys_batch_abs(cfg, b, mesh)
        batch_abs.pop("labels"); b_specs.pop("labels")

        def step(params, batch):
            return jax.nn.sigmoid(rm.forward(params, batch, cfg, mesh, ba))

        mf = _recsys_model_flops(cfg, b)
        return Cell(arch.name, shape.name, step, (p_abs, batch_abs),
                    (p_specs, b_specs), None, mf)
    if shape.kind == "retrieval":
        nc = shape.get("n_candidates")
        d = cfg.embed_dim
        cand = jax.ShapeDtypeStruct((nc, d), jnp.float32)
        cand_spec = P("model", None)
        if cfg.kind == "mind":
            hist = jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32)
            hlen = jax.ShapeDtypeStruct((1,), jnp.int32)

            def step(params, hist_ids, hist_len, cand):
                from repro.models.recsys.models import capsule_routing
                from repro.models.recsys.embedding import embedding_lookup_sharded
                # single-user tower: batch replicated (batch=1 < data axis)
                hvec = embedding_lookup_sharded(params["table"], hist_ids, mesh, ())
                hmask = jnp.arange(cfg.seq_len)[None, :] < hist_len[:, None]
                interests = capsule_routing(hvec, hmask, params["bilinear"], cfg)
                return rm.retrieval_scores(interests, cand, k=100)

            mf = 2.0 * nc * d * cfg.n_interests + _recsys_model_flops(cfg, 1)
            return Cell(arch.name, shape.name, step,
                        (p_abs, hist, hlen, cand),
                        (p_specs, P(None, None), P(None), cand_spec),
                        None, mf,
                        note="1 user x 1M candidates, batched dot + top-k")
        # ranking archs: bulk-score the 1M candidates through the model
        b = nc
        batch_abs, b_specs = _recsys_batch_abs(cfg, b, mesh)
        batch_abs.pop("labels"); b_specs.pop("labels")

        def step(params, batch):
            return jax.nn.sigmoid(rm.forward(params, batch, cfg, mesh, ba))

        mf = _recsys_model_flops(cfg, b)
        return Cell(arch.name, shape.name, step, (p_abs, batch_abs),
                    (p_specs, b_specs), None, mf,
                    note="1 user x 1M candidates scored as a bulk batch")
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# ANNS (Helmsman) cells
# ---------------------------------------------------------------------------
def _anns_cell(arch: ArchDef, shape: ShapeDef, mesh: Mesh) -> Cell:
    from repro.core.search import SearchConfig, make_sharded_serve
    from repro.core.gbdt import GBDTParams
    from repro.core.llsp import LLSPParams

    hc = arch.config
    ba = batch_axes(mesh)
    tp = mesh.shape["model"]

    if shape.kind == "anns_serve":
        b = shape.batch
        scfg = SearchConfig(k=hc.k, nprobe_max=hc.nprobe_max,
                            pruning="llsp", use_kernel=False)
        C, L, D = hc.n_clusters, hc.cluster_len, hc.dim
        cents = jax.ShapeDtypeStruct((C, D), jnp.float32)
        posts = jax.ShapeDtypeStruct((C, L, D), jnp.float32)
        pids = jax.ShapeDtypeStruct((C, L), jnp.int32)
        n_levels, T, nodes = 4, 64, 63
        gb = lambda lead: GBDTParams(
            feature=jax.ShapeDtypeStruct(lead + (T, nodes), jnp.int32),
            threshold=jax.ShapeDtypeStruct(lead + (T, nodes), jnp.float32),
            value=jax.ShapeDtypeStruct(lead + (T, nodes), jnp.float32),
            base=jax.ShapeDtypeStruct(lead, jnp.float32),
            lr=jax.ShapeDtypeStruct(lead, jnp.float32),
        )
        llsp = LLSPParams(
            router=gb(()),
            pruners=gb((n_levels,)),
            levels=jax.ShapeDtypeStruct((n_levels,), jnp.int32),
        )
        queries = jax.ShapeDtypeStruct((b, D), jnp.float32)
        topk = jax.ShapeDtypeStruct((b,), jnp.int32)
        fn = make_sharded_serve(mesh, scfg, batch_axes=ba, shard_axis="model")
        llsp_spec = jax.tree.map(lambda _: P(), llsp)
        # distance flops: centroid scan (B x C x D per model shard, replicated
        # in the baseline) + posting scan (B x nprobe x L x D)
        mf = 2.0 * b * D * (C + hc.nprobe_max * L)
        return Cell(arch.name, shape.name, fn,
                    (cents, posts, pids, llsp, queries, topk),
                    (P(), P("model"), P("model"), llsp_spec,
                     _bspec(mesh, b, None), _bspec(mesh, b)),
                    None, mf,
                    note="paper's serving path: LLSP + sharded posting scan + k-merge")
    if shape.kind == "anns_build":
        n = shape.batch
        k = shape.get("k_coarse")
        D = hc.dim
        x = jax.ShapeDtypeStruct((n, D), jnp.float32)
        cents = jax.ShapeDtypeStruct((k, D), jnp.float32)

        def step(x, cents):
            from repro.build.kmeans import kmeans_sharded_step
            return kmeans_sharded_step(mesh, x, cents, k)

        mf = 2.0 * n * k * D
        return Cell(arch.name, shape.name, step, (x, cents),
                    (_bspec(mesh, n, None), P(None, None)), P(None, None), mf,
                    note="one distributed Lloyd iteration (stage-1 build)")
    raise ValueError(shape.kind)


BUILDERS = {
    "lm": _lm_cell,
    "gnn": _gnn_cell,
    "recsys": _recsys_cell,
    "anns": _anns_cell,
}

# beyond-baseline per-arch optimizations (§Perf hillclimbs):
#   * pad_heads_to   — heads shard over TP=16, killing the O(S^2) score psum
#                      that Dh-sharding forces (phi4: 24->32, llama4: 40->48)
#   * seq_parallel   — Megatron-SP activation sharding between blocks
#   * shard_centroids + bf16 postings — Helmsman serving memory/compute
OPT_OVERRIDES = {
    # head padding: big win wherever scores are O(S^2) (train/prefill);
    # slightly NEGATIVE at decode (Tq=1, no score psum) -> decode stays base
    ("phi4_mini", "prefill"): dict(pad_heads_to=32),
    ("phi4_mini", "train"): dict(pad_heads_to=32, seq_parallel=True),
    ("llama4_scout", "prefill"): dict(pad_heads_to=48),
    ("llama4_scout", "train"): dict(pad_heads_to=48, seq_parallel=True),
    ("gemma3_12b", "train"): dict(pure_dp=True),
    ("gemma3_27b", "train"): dict(seq_parallel=True),
    ("qwen2_moe", "train"): dict(seq_parallel=True),
}


def optimize_arch(arch: ArchDef, shape_name: str) -> ArchDef:
    if arch.family == "gnn":
        mode = arch.shapes[shape_name].get("mode")
        if mode == "full":   # full-graph cells: row-DP + dst-sorted edges
            cfg = dataclasses.replace(arch.config, row_dp=True)
            return dataclasses.replace(arch, config=cfg)
        return arch
    if arch.family != "lm":
        return arch
    kind = arch.shapes[shape_name].kind
    ov = OPT_OVERRIDES.get((arch.name, kind),
                           OPT_OVERRIDES.get((arch.name, "*")))
    if ov:
        cfg = dataclasses.replace(arch.config, **ov)
        return dataclasses.replace(arch, config=cfg)
    return arch


def build_cell(arch: ArchDef, shape_name: str, mesh: Mesh,
               variant: str = "base") -> Cell:
    if variant == "opt":
        arch = optimize_arch(arch, shape_name)
    shape = arch.shapes[shape_name]
    cell = BUILDERS[arch.family](arch, shape, mesh)
    if variant == "opt" and arch.family == "anns" and shape.kind == "anns_serve":
        cell = _anns_cell_opt(arch, shape, mesh)
    return cell


def _anns_cell_opt(arch: ArchDef, shape: ShapeDef, mesh: Mesh) -> Cell:
    """Optimized Helmsman serving it.3: sharded centroid scan + int8
    RESIDUAL postings (4x fewer scan bytes, <1% recall cost — validated in
    tests/test_quantize.py)."""
    from repro.core.search import SearchConfig, make_sharded_serve_quantized
    base = _anns_cell(arch, shape, mesh)
    hc = arch.config
    ba = batch_axes(mesh)
    scfg = SearchConfig(k=hc.k, nprobe_max=hc.nprobe_max, pruning="llsp",
                        use_kernel=False, shard_centroids=True)
    fn = make_sharded_serve_quantized(mesh, scfg, batch_axes=ba,
                                      shard_axis="model")
    C, L, D = hc.n_clusters, hc.cluster_len, hc.dim
    cents, _posts, pids, llsp, queries, topk = base.abstract_args
    args = (
        cents,
        jax.ShapeDtypeStruct((C, L, D), jnp.int8),      # q8 residuals
        jax.ShapeDtypeStruct((C, 1, 1), jnp.float32),   # per-cluster scale
        jax.ShapeDtypeStruct((C, L), jnp.float32),      # precomputed norms
        pids, llsp, queries, topk,
    )
    specs = (P("model"), P("model"), P("model"), P("model"), P("model"),
             base.in_specs[3], base.in_specs[4], base.in_specs[5])
    return dataclasses.replace(
        base, fn=fn, abstract_args=args, in_specs=specs,
        note=base.note + " [opt: sharded centroid scan + int8 residual postings]")
