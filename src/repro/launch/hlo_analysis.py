"""Trip-count-aware cost analysis over post-SPMD HLO text.

``compiled.cost_analysis()`` (XLA HloCostAnalysis) counts every while-loop
body ONCE, so any scan-over-layers model under-reports flops/bytes by the
trip count, and collectives inside loops are similarly invisible to a flat
text scan.  This module parses the compiled HLO text into its computation
graph, extracts while-loop trip counts (from the scan-style `compare(iter,
constant(N)), direction=LT` condition), and accumulates per-computation
costs multiplied down the call graph:

  flops  — dot ops: 2 * prod(result dims) * prod(contracted lhs dims)
           (+1 flop/element for non-fused elementwise at top level; matmul
           dominates every model here)
  bytes  — per op: result + operand buffer bytes, skipping pure plumbing
           (parameter/tuple/get-tuple-element/bitcast/constant) and skipping
           the INSIDE of kLoop/kInput/kOutput fusions (their call site
           already accounts the fused buffers once) — a proxy for HBM
           traffic of the scheduled module
  coll   — per collective kind: result bytes x a per-chip traffic factor
           (all-gather 1x, all-reduce 2x (ring), reduce-scatter 1x payload,
           all-to-all 1x, collective-permute 1x), again multiplied by loop
           trip counts

Calibrated against analytically-known cells in tests/test_dryrun_analysis.py
(scan vs unrolled variants agree within a few percent).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLL_FACTOR = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\-.~]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_OP_RE = re.compile(
    # result type is either a tuple "(...)" (may contain /*index=N*/ comments)
    # or a plain "dtype[shape]{layout}"
    r"^\s*(?:ROOT\s+)?%?([\w\-.~]+)\s*=\s*((?:\([^()]*\))|(?:[\w\[\],{}]+))\s*"
    r"([\w\-]+)\((.*)$"
)
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\-.~]+)")
_COND_RE = re.compile(r"condition=%?([\w\-.~]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_SKIP_BYTES_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "custom-call",
}
_PLUMBING = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
             "after-all", "partition-id", "replica-id"}


def _type_bytes_and_elems(type_str: str) -> Tuple[int, int]:
    """Bytes + element count of an HLO type string (tuples summed)."""
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        if not dims:
            n = 1
        total_b += n * _DTYPE_BYTES[dt]
        total_e += n
    if total_b == 0 and type_str.strip().startswith(("f", "s", "u", "pred", "bf")):
        m = re.match(r"([a-z]\w*)\[\]", type_str.strip().lstrip("("))
        if m and m.group(1) in _DTYPE_BYTES:
            total_b = _DTYPE_BYTES[m.group(1)]
            total_e = 1
    return total_b, total_e


@dataclasses.dataclass
class OpInfo:
    name: str
    result_type: str
    opcode: str
    args: str


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_ops: Dict[str, int] = dataclasses.field(default_factory=dict)
    # (callee, flops_mult, bytes_mult) edges
    edges: List[Tuple[str, float, float]] = dataclasses.field(default_factory=list)
    max_const: int = 0     # largest integer constant (trip-count source)


def _dot_flops(op: OpInfo, symtab: Dict[str, str]) -> float:
    _, res_elems = _type_bytes_and_elems(op.result_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.args)
    operands = re.findall(r"%([\w\-.~]+)", op.args.split("),")[0] + ")")
    if not operands:
        return 0.0
    lhs_type = symtab.get(operands[0], "")
    shapes = _SHAPE_RE.findall(lhs_type)
    if not shapes:
        return 2.0 * res_elems
    dims = [int(d) for d in shapes[0][1].split(",") if d]
    k = 1
    if m and m.group(1):
        for ci in m.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * res_elems * k


def parse_computations(hlo: str) -> Tuple[Dict[str, CompCost], Optional[str]]:
    comps: Dict[str, CompCost] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    cur_cost: Optional[CompCost] = None
    symtab: Dict[str, str] = {}
    fused = False

    for line in hlo.splitlines():
        mstart = _COMP_START.match(line)
        if mstart and "=" not in line.split("(")[0]:
            cur = mstart.group(2)
            cur_cost = comps.setdefault(cur, CompCost())
            if mstart.group(1):
                entry = cur
            symtab = {}
            fused = cur.startswith(("fused_", "wrapped_")) or ".fused" in cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mop = _OP_RE.match(line)
        if not mop:
            continue
        name, rtype, opcode, args = mop.groups()
        symtab[name] = rtype
        c = cur_cost

        for mc in _CONST_RE.finditer(line):
            c.max_const = max(c.max_const, int(mc.group(1)))

        if opcode == "dot" or opcode == "convolution":
            c.flops += _dot_flops(OpInfo(name, rtype, opcode, args), symtab)
        elif opcode not in _PLUMBING and not fused:
            # crude elementwise estimate: 1 flop per result element
            _, elems = _type_bytes_and_elems(rtype)
            c.flops += elems

        # bytes: result + operands, top-level ops only (fusion internals are
        # accounted at their call sites)
        if opcode not in _SKIP_BYTES_OPS and not fused:
            b, _ = _type_bytes_and_elems(rtype)
            arg_head = args.split("), ")[0]
            for on in re.findall(r"%([\w\-.~]+)", arg_head):
                ob, _ = _type_bytes_and_elems(symtab.get(on, ""))
                b += ob
            c.bytes += b

        # collectives (sync or -start; -done carries no shape transfer)
        for kind in _COLL_FACTOR:
            if opcode == kind or opcode == kind + "-start":
                rb, _ = _type_bytes_and_elems(rtype)
                c.coll[kind] = c.coll.get(kind, 0.0) + rb * _COLL_FACTOR[kind]
                c.coll_ops[kind] = c.coll_ops.get(kind, 0) + 1

        # call edges
        if opcode == "while":
            body = _CALLS_RE.search(line)
            cond = _COND_RE.search(line)
            c.edges.append(("__WHILE__:" + (body.group(1) if body else ""),
                            0.0, 0.0))
            if cond:
                c.edges.append(("__COND__:" + cond.group(1), 0.0, 0.0))
        elif opcode == "conditional":
            mb = _BRANCHES_RE.search(line)
            if mb:
                for b_ in mb.group(1).split(","):
                    c.edges.append((b_.strip().lstrip("%"), 1.0, 1.0))
        else:
            mcalls = _CALLS_RE.search(line)
            if mcalls and opcode in ("fusion", "call", "map", "reduce",
                                     "reduce-window", "sort", "scatter",
                                     "select-and-scatter", "all-reduce",
                                     "all-reduce-start", "reduce-scatter"):
                # fusion bodies: flops inside count once; bytes already
                # counted at the call site
                bytes_mult = 0.0
                c.edges.append((mcalls.group(1), 1.0, bytes_mult))
    return comps, entry


@dataclasses.dataclass
class Totals:
    flops: float
    bytes: float
    coll: Dict[str, float]
    coll_ops: Dict[str, int]
    n_while: int

    @property
    def coll_total(self) -> float:
        return float(sum(self.coll.values()))


def analyze(hlo: str) -> Totals:
    comps, entry = parse_computations(hlo)
    if entry is None:
        entry = next(iter(comps)) if comps else None
    memo: Dict[Tuple[str, float, float], Tuple[float, float, dict, dict, int]] = {}

    def visit(name: str, fm: float, bm: float, depth: int = 0):
        if name not in comps or depth > 64:
            return 0.0, 0.0, {}, {}, 0
        key = (name, fm, bm)
        if key in memo:
            return memo[key]
        c = comps[name]
        fl = c.flops * fm
        by = c.bytes * bm
        coll = {k: v * fm for k, v in c.coll.items()}
        coll_ops = {k: int(v * max(fm, 1)) for k, v in c.coll_ops.items()}
        n_while = 0
        for callee, efm, ebm in c.edges:
            if callee.startswith("__WHILE__:"):
                body = callee.split(":", 1)[1]
                trip = _trip_count(comps, c, body)
                n_while += 1
                sf, sb, sc, so, sw = visit(body, fm * trip, bm * trip, depth + 1)
            elif callee.startswith("__COND__:"):
                cond = callee.split(":", 1)[1]
                sf, sb, sc, so, sw = visit(cond, fm, bm, depth + 1)
            else:
                sf, sb, sc, so, sw = visit(callee, fm * efm, bm * ebm, depth + 1)
                sf = sf if efm else 0.0
            fl += sf
            by += sb
            for k, v in sc.items():
                coll[k] = coll.get(k, 0.0) + v
            for k, v in so.items():
                coll_ops[k] = coll_ops.get(k, 0) + v
            n_while += sw
        out = (fl, by, coll, coll_ops, n_while)
        memo[key] = out
        return out

    fl, by, coll, coll_ops, n_while = visit(entry, 1.0, 1.0) if entry else (0, 0, {}, {}, 0)
    for k in _COLL_FACTOR:
        coll.setdefault(k, 0.0)
        coll_ops.setdefault(k, 0)
    return Totals(flops=fl, bytes=by, coll=coll, coll_ops=coll_ops,
                  n_while=n_while)


def _trip_count(comps: Dict[str, CompCost], caller: CompCost, body: str) -> int:
    """Trip count of a while loop: the comparison constant in its condition.

    The condition computation is the edge recorded right after the body edge;
    we look it up by scanning caller edges.  Fallback: 1."""
    take_next = False
    for callee, _, _ in caller.edges:
        if callee == "__WHILE__:" + body:
            take_next = True
            continue
        if take_next and callee.startswith("__COND__:"):
            cond = callee.split(":", 1)[1]
            cc = comps.get(cond)
            if cc is not None:
                tc = cc.max_const
                # condition body may nest the compare in a wrapped fusion
                if tc == 0:
                    for sub, _, _ in cc.edges:
                        sc = comps.get(sub)
                        if sc is not None:
                            tc = max(tc, sc.max_const)
                return max(tc, 1)
            return 1
    return 1
