"""Serving launcher — the online half of Fig. 8 as a runnable node daemon.

PR 2 made this a thin driver over the async serving runtime
(``repro.runtime``): index deployment and node health stay here, but the
traffic loop is the runtime's SQ/CQ queue-pair engine — arrivals from a
seeded multi-tenant Poisson trace are submitted one query at a time, the
dynamic batcher coalesces them per index with deadline-aware admission
control, and the prefetch pipeline overlaps each batch's host gather +
device stream with the previous batch's fused-topk scan.

Responsibilities (container-scale versions of the production node):
  * index deployment: build or load indexes, allocate their cluster extents
    from the node's ChunkArena (multi-index hosting, §4.2), publish
    IndexMeta, wrap the postings in a streamed host tier + pipeline;
  * traffic: open-loop Poisson tenants through the ServeEngine (§4.1);
  * health: heartbeat table per logical shard, straggler detection, replica
    failover on shard failure (§6.2);
  * freshness: a mid-run rebuild + atomic ``swap_pipeline`` (the paper's
    daily/hourly rebuild flow) while the engine keeps serving.

The scan path is the PR 1 fused-topk data path: the Pallas kernel on TPU,
interpret-mode on CPU (``--no-kernel`` switches to the fast packed-domain
jnp oracle instead — same candidates, same recall).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --indexes 2 --duration 8
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import os
import tempfile
import time

import numpy as np
import jax.numpy as jnp

from repro.build.pipeline import BuildConfig, build_index
from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.llsp import LLSPConfig
from repro.core.search import SearchConfig
from repro.data import PAPER_DATASETS, make_queries, make_vectors
from repro.distributed import (
    FaultInjector,
    HeartbeatMonitor,
    ShardedFabric,
    plan_failover,
)
from repro.lifecycle import VersionManager
from repro.obs import (
    HarvestRing,
    Observability,
    QualityMonitor,
    SLOTracker,
    default_rules,
    health_snapshot,
    write_health,
)
from repro.runtime import (
    BatchPolicy,
    DynamicBatcher,
    PrefetchPipeline,
    RerankConfig,
    ServeEngine,
    TenantSpec,
    make_quantized_pipeline,
    multi_tenant_trace,
)
from repro.runtime.pipeline import _vectors_from_postings
from repro.storage import ChunkArena, IndexMeta, TieredPostings, \
    make_replica_map, plan_striping


@dataclasses.dataclass
class Deployment:
    name: str
    index: object
    llsp: object
    spec: object
    meta: IndexMeta
    striping: object
    replica_map: object
    pipeline: PrefetchPipeline
    queries: np.ndarray          # probe pool for recall spot checks
    true10: np.ndarray


def deploy(arena: ChunkArena, name: str, spec, workdir: str,
           n_shards: int, scfg: SearchConfig, tier: str = "q8",
           rerank: RerankConfig | None = None,
           with_rerank: bool = True) -> Deployment:
    x = make_vectors(spec)
    q, topk = make_queries(spec, 256)
    topk = np.minimum(topk, 50).astype(np.int32)
    cfg = BuildConfig(max_cluster_size=96, cluster_len=128,
                      coarse_per_task=5000, n_workers=2,
                      llsp=LLSPConfig(levels=(8, 16), n_ratio_features=8))
    index, llsp, report = build_index(x, cfg, workdir, queries=q,
                                      query_topk=topk)
    cluster_bytes = index.cluster_len * index.dim * 4
    extents = arena.allocate_index(name, index.n_clusters, cluster_bytes)
    striping = plan_striping(index.n_clusters, n_shards, extents)
    hot = np.arange(index.n_clusters)[::3]
    rmap = make_replica_map(index.n_clusters, n_shards, striping,
                            hot_clusters=hot, n_replicas=2)
    meta = IndexMeta(name=name, n_clusters=index.n_clusters,
                     cluster_len=index.cluster_len, dim=index.dim,
                     dtype="int8" if tier == "q8" else "float32",
                     extents=extents)
    meta.save(os.path.join(workdir, f"{name}.meta.json"))
    if tier == "q8":
        # quantized serving default: q8 hot tier + mmap flash tier (f32
        # corpus, arena-accounted) + adaptive f32 re-rank at harvest
        pipeline = make_quantized_pipeline(
            index, llsp, scfg, arena=arena, name=name, vectors=x,
            flash_path=os.path.join(workdir, f"{name}.flash.f32"),
            rerank=rerank, with_flash=with_rerank)
    else:
        hot_tier = TieredPostings(np.asarray(index.postings),
                                  np.asarray(index.posting_ids))
        # dup_bound auto-derives from the build's realized replication, so a
        # rebuilt index with a different max_replicas can never outrun the
        # oracle's pre-selection (the ROADMAP dup_bound=8 hazard)
        pipeline = PrefetchPipeline(index, llsp, scfg, tier=hot_tier)
    _, t10 = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    hot_note = ""
    if tier == "q8":
        f32_bytes = np.asarray(index.postings).nbytes \
            + np.asarray(index.posting_ids).nbytes
        fl = (f" + flash {pipeline.flash.nbytes >> 20} MiB"
              if pipeline.flash is not None else ", rerank off")
        hot_note = (f", hot {pipeline.tier.nbytes() >> 20} MiB "
                    f"({pipeline.tier.nbytes() / f32_bytes:.2f}x f32)" + fl)
    print(f"[deploy] {name}: {index.n_clusters} clusters, "
          f"{len({e.device for e in extents})} devices, "
          f"arena free {arena.free_bytes >> 20} MiB, "
          f"build overlap {report.shard_overlap:.2f} "
          f"({len(report.shard_stamps)} shards), "
          f"dup_bound {pipeline.dup_bound}, tier={pipeline.tier_kind}"
          + hot_note)
    return Deployment(name, index, llsp, spec, meta, striping, rmap,
                      pipeline, q, np.asarray(t10))


def undeploy(arena: ChunkArena, dep: Deployment) -> None:
    if dep.pipeline.flash is not None:
        dep.pipeline.flash.release()   # mmap file + its arena chunks
    arena.release_index(dep.name)
    print(f"[undeploy] {dep.name}: chunks recycled "
          f"(arena free {arena.free_bytes >> 20} MiB)")


def probe_recall(engine: ServeEngine, dep: Deployment,
                 lat: list[float], tenant: str, n: int = 64) -> float:
    """Submit known queries THROUGH the engine and score the completions —
    the health check exercises the exact serving path, not a side door.
    Non-probe completions drained along the way keep feeding ``lat``."""
    want = {}
    for i in range(n):
        rid = engine.submit(dep.queries[i], 10, index=tenant, block=True)
        if rid >= 0:
            want[rid] = i
    deadline = time.monotonic() + 60.0
    got: dict[int, np.ndarray] = {}
    while len(got) < len(want) and time.monotonic() < deadline:
        for c in engine.qp.poll():
            if c.req_id in want:
                if c.ids is not None:
                    got[c.req_id] = c.ids
                else:
                    want.pop(c.req_id)
            elif c.status != "shed":
                lat.append(c.latency)
        time.sleep(0.01)
    if not got:
        return float("nan")
    rows = [want[r] for r in got]
    ids = np.stack([got[r] for r in got])
    return recall_at_k(ids[:, :10], dep.true10[rows])


def make_obs(args) -> Observability:
    """One telemetry bundle per serve run: metrics are always live (they
    are the bounded-memory latency accounting), tracing turns on iff
    ``--trace-out`` was given, at ``--sample-rate``."""
    return Observability(args.sample_rate, enabled=bool(args.trace_out))


def finish_obs(obs: Observability, args) -> None:
    """End-of-run telemetry flush: metrics summary + Perfetto export."""
    if args.metrics_every > 0:
        for line in obs.metrics.render():
            print(f"[metrics] {line}")
    if args.trace_out:
        doc = obs.trace.export(args.trace_out)
        print(f"[trace] {len(doc['traceEvents'])} events -> "
              f"{args.trace_out} "
              f"(ring-dropped {doc['otherData']['dropped_events']}); "
              f"open in https://ui.perfetto.dev")


def make_quality_stack(args, obs: Observability, vectors=None):
    """Quality-observability bundle for one serve run: the per-query
    recall-proxy monitor (+ shadow audit lane when ``vectors`` is given),
    the structured harvest ring, and the burn-rate SLO tracker with the
    default serving rules.  ``--no-quality`` returns (None, None, None)
    — the A/B baseline the overhead bench measures against."""
    if args.no_quality:
        return None, None, None
    harvest = HarvestRing()
    quality = QualityMonitor(
        obs.metrics, vectors=vectors, shadow_rate=args.shadow_rate,
        harvest=harvest, trace=obs.trace if obs.tracing else None)
    slo = SLOTracker(metrics=obs.metrics,
                     trace=obs.trace if obs.tracing else None)
    # short drills need short windows: scale the multi-window pair to the
    # trace duration (capped at the workbook's 1m/5m defaults)
    fast = min(60.0, max(args.duration / 4.0, 1.0))
    slow = min(300.0, max(args.duration, 4.0))
    default_rules(slo, obs.metrics, quality=quality,
                  fast_s=fast, slow_s=slow)
    return quality, harvest, slo


def emit_health(args, quality, harvest, slo, registry) -> None:
    """Tick the SLO state machine and (when ``--health-out`` is set)
    atomically rewrite the health snapshot JSON an operator polls."""
    if slo is None:
        return
    slo.tick()
    if args.health_out:
        write_health(args.health_out, health_snapshot(
            slo=slo, quality=quality, registry=registry,
            extra={"harvest": {"records": len(harvest),
                               "appended": harvest.appended,
                               "dropped": harvest.dropped}}))


def finish_quality(args, quality, harvest, slo, registry) -> None:
    """End-of-run quality flush: drain the shadow-audit lane, write the
    final health snapshot, persist the harvest shard, print the rollup."""
    if quality is None:
        return
    quality.drain()
    emit_health(args, quality, harvest, slo, registry)
    if args.harvest_out:
        harvest.flush_npz(args.harvest_out)
        print(f"[quality] harvest shard: {len(harvest)} records -> "
              f"{args.harvest_out} (lifetime {harvest.appended}, "
              f"ring-dropped {harvest.dropped})")
    s = quality.summary()
    firing = [n for n, st in slo.snapshot().items()
              if st["state"] == "firing"]
    print(f"[quality] {s['queries']:.0f} queries, proxy p50="
          f"{s['proxy']['p50']:.3f} low_frac={s['low_frac']:.4f}, "
          f"audits done={s['audits_done']:.0f} "
          f"dropped={s['audits_dropped']:.0f}, "
          f"calib p99={s['calibration_err']['p99']:.4f}, "
          f"alerts firing={firing or 'none'}")
    quality.close()


FABRIC_TIER_ERROR = (
    "--tier q8 is not supported in fabric mode (--shards > 0): the fabric "
    "shards f32 postings and has no quantized tier; drop --tier q8 (fabric "
    "serves f32) or use the single-node pipeline (--shards 0)")


def run_fabric(args) -> None:
    """Fabric drill mode (``--shards > 0``): one index served behind the
    sharded, replicated fabric; optional seeded kill mid-trace.

    Rejects an explicit ``--tier q8`` outright: silently overriding the
    operator's tier choice made a drill look like a quantized-serving
    test when it never was (PR 8 follow-up)."""
    if getattr(args, "tier", None) == "q8":
        raise ValueError(FABRIC_TIER_ERROR)
    scfg = SearchConfig(k=10, nprobe_max=16, pruning="llsp", n_ratio=8,
                        use_kernel=not args.no_kernel, fused_topk=True)
    arena = ChunkArena(n_devices=12, device_bytes=1 << 30,
                       chunk_bytes=1 << 20)
    deadline_s = args.deadline_ms * 1e-3 or None
    name = list(PAPER_DATASETS)[0]
    with tempfile.TemporaryDirectory() as root:
        spec = dataclasses.replace(PAPER_DATASETS[name], n=args.n, dim=32)
        dep = deploy(arena, name, spec, os.path.join(root, name),
                     args.shards, scfg, tier="f32")
        inj = None
        if args.kill_shard_at > 0:
            inj = FaultInjector(seed=0).kill(args.kill_shard_at)
        hot = (np.arange(dep.index.n_clusters) if args.replicas > 1
               else None)
        obs = make_obs(args)
        fab = ShardedFabric(dep.index, dep.llsp, scfg,
                            n_shards=args.shards,
                            n_replicas=args.replicas, hot_clusters=hot,
                            injector=inj, hedge_after_s=0.05, tick_s=0.02,
                            obs=obs)
        fab.warmup()
        fab.start()
        # fabric quality: the coverage proxy rides every BatchResult; the
        # shadow audit lane brute-forces against the reconstructed corpus
        quality, harvest, slo = make_quality_stack(
            args, obs, vectors=_vectors_from_postings(dep.index))
        engine = ServeEngine(
            {name: fab},
            DynamicBatcher(BatchPolicy(max_batch=args.batch,
                                       max_wait_s=0.05), [name]),
            depth=args.depth, obs=obs, quality=quality)
        engine.start()
        trace = multi_tenant_trace(
            [TenantSpec(name, args.rate, topk_lo=10, topk_hi=50,
                        deadline_s=deadline_s, n_queries=256)],
            args.duration)
        print(f"[fabric] {args.shards} shards x R={args.replicas}, "
              f"replaying {len(trace)} arrivals over {args.duration:.0f}s"
              + (f", kill drill at t={args.kill_shard_at:.1f}s"
                 if inj is not None else ""))
        t0 = time.monotonic()
        if inj is not None:
            inj.arm(t0)
        # bounded recent window (heartbeat means only); the full-run
        # percentiles come from the engine's streaming latency histogram
        lat: collections.deque = collections.deque(maxlen=2048)
        next_metrics = args.metrics_every or float("inf")
        next_health = args.health_every or float("inf")
        try:
            for arr in trace:
                lag = t0 + arr.t - time.monotonic()
                if lag > 0:
                    time.sleep(lag)
                engine.submit(dep.queries[arr.qrow], arr.topk, index=name,
                              deadline_s=arr.deadline_s)
                if time.monotonic() - t0 >= next_metrics:
                    next_metrics += args.metrics_every
                    for line in obs.metrics.render():
                        print(f"[metrics] {line}")
                if time.monotonic() - t0 >= next_health:
                    next_health += args.health_every
                    emit_health(args, quality, harvest, slo, obs.metrics)
            r = probe_recall(engine, dep, lat, name)
        finally:
            engine.stop(drain=True)
            fab.stop()
        engine.qp.poll()
        st, fs = engine.stats, fab.stats
        wall = time.monotonic() - t0
        pct = obs.metrics.histogram("engine.latency_s").summary_ms()
        print(f"[fabric] {st.completed} completions in {wall:.1f}s "
              f"({(st.completed - st.shed) / wall:.0f} q/s), "
              f"p50={pct['p50_ms']:.0f}ms p99={pct['p99_ms']:.0f}ms, "
              f"shed={st.shed} partial={st.partial} failed={st.failed}")
        for f in fs.failovers:
            print(f"[fault] shard {f['shard']} failed over: "
                  f"{f['moved']} clusters moved to replicas, "
                  f"{f['lost']} lost")
        if inj is not None:
            print(f"[fault] injector log: "
                  f"{[(round(t, 2), k, s) for t, k, s in inj.log]}, "
                  f"dead_replies={fs.dead_replies} "
                  f"requeued={fs.requeued_tasks} hedges={fs.hedges}")
        print(f"[fabric] busy_s per shard: "
              f"{[round(b, 3) for b in fs.busy_s.tolist()]}, tasks "
              f"{fs.tasks_per_shard.tolist()}")
        print(f"[health] {name}: recall@10={r:.3f} through the engine, "
              f"dropped={st.submitted - st.rejected - st.completed}")
        finish_quality(args, quality, harvest, slo, obs.metrics)
        finish_obs(obs, args)
        undeploy(arena, dep)
        arena.validate()


FABRIC_RUNBOOK = """\
operator runbook — quantized tier + flash re-rank (single-node default):

  The first pass serves from the int8-residual hot tier (~0.3x the f32
  posting bytes resident in host DRAM); the f32 vectors live in a
  mmap-backed flash file and only the ~2k fused-topk candidates per query
  are read back and exact-rescored at harvest.  Re-ranking walks the
  candidates in rounds and stops once the exact top-k is stable
  (FusionANNS-style adaptive stop); the flash reads run on their own
  submission lane so batch i's re-rank I/O overlaps batch i+1's scan —
  verified from the stage stamps, see rerank_overlap_efficiency.

  --tier q8|f32       first-pass payload (default q8).  f32 restores the
                      all-resident PR 2 pipeline (A/B baseline; also what
                      benchmarks/bench_cost.py prices as the DRAM-heavy
                      row of the $/QPS table)
  --no-rerank         serve raw q8 distances (recall drops <1% on the
                      bench corpora; use to isolate re-rank cost)
  --rerank-round N    candidates exact-scored per re-rank round (64)
  --rerank-stable N   stop after N consecutive rounds leave the exact
                      top-k unchanged (1)

  reading the output:
    [deploy] ... tier=q8, hot X MiB (0.31x f32) + flash Y MiB
        the cost-model split: hot = DRAM-resident bytes, flash = SSD
    [metrics] engine.rerank_rounds / rerank_cands / rerank_io_s
        adaptive-stop behaviour under live traffic; rerank_stop counts
        stable vs exhausted walks
    --trace-out lanes gain a "rerank" span per batch; its overlap with
        the NEXT batch's scan span is the cost-thesis I/O overlap

  rebuilds inherit the tier: --rebuild under --tier q8 quantizes the new
  epoch's shards before the swap (RebuildReport.tier == "q8").

operator runbook — sharded fabric mode (--shards > 0):

  Serve one index behind the sharded, replicated fabric instead of the
  single-node pipeline.  Probed clusters fan out to owner shards by
  power-of-two-choices over live replicas; shard death is detected by
  dead-letter CQ replies or missed heartbeats, failover reroutes probes
  to replicas, stragglers are hedged, and clusters with no live replica
  degrade the touching responses to status="partial" — never a dropped
  query.

  --shards S          number of simulated shards (worker threads)
  --replicas R        copies per cluster: R=2 survives any single shard
                      death with zero loss; R=1 degrades to partial
  --kill-shard-at T   chaos drill: at T seconds a seeded FaultInjector
                      kills one live shard (victim drawn from a seeded
                      generator, so the drill replays exactly); watch
                      the [fault] lines for the failover plan and the
                      final [health] recall probe for parity

  drills:
    # zero-drop kill drill: 8 shards, R=2, shard dies mid-trace
    serve --shards 8 --replicas 2 --kill-shard-at 4 --duration 8
    # same but unreplicated: expect partial responses, not drops
    serve --shards 8 --replicas 1 --kill-shard-at 4 --duration 8

  --rebuild and --fail-shard belong to the single-node mode and are
  rejected when --shards is set (fabric epoch swap is future work).

operator runbook — observability (both modes):

  Metrics are always on: bounded-memory streaming histograms/counters/
  gauges replace the old grow-forever latency lists; --metrics-every N
  prints the full registry every N seconds (per-shard queue depth and
  outstanding gauges, shed/degrade/partial/hedge/requeue counters
  labeled by reason, latency and task-service histograms).

  Tracing turns on when --trace-out is given: every request admitted
  under --sample-rate carries a trace_id from submit through batcher,
  plan, fabric fan-out (per-shard tasks incl. requeues and hedges),
  and merge, and the run exports one Chrome/Perfetto trace_event JSON
  at exit.  Overhead at --sample-rate 1.0 is gated <= 5% q/s by
  benchmarks/bench_serving_pipeline.py.

  capture a failover flamegraph:
    # kill a shard mid-trace and trace every request
    serve --shards 8 --replicas 2 --kill-shard-at 4 --duration 8 \\
          --trace-out /tmp/drill.json --metrics-every 2
    # then open https://ui.perfetto.dev and drag /tmp/drill.json in:
    #   "requests" track  — request lifetimes + done:<status> terminals;
    #                       flow arrows link each request to the shard
    #                       tasks it fanned out to
    #   "shard-N" tracks  — task lifetimes (kind=dispatch/requeue/hedge)
    #                       and worker scan spans; the killed shard's
    #                       tasks reappear on survivors as kind=requeue
    #   "router" track    — failover/hedge/give_up instants, merge spans
    #   "batch-N" lanes   — plan/gather/stream/scan stage spans
    #   "lifecycle" track — rebuild snapshot/build/swap spans, per-shard
    #                       stage-2 stream lifetimes, epoch_swap instant
    #   "slo" track       — alert_fire:<rule> / alert_clear:<rule>
    #                       burn-rate transitions

operator runbook — quality observability (both modes):

  Latency telemetry answers "where did this query spend its time?";
  the quality layer answers "is recall degrading RIGHT NOW, and
  where?".  On by default; --no-quality is the A/B-baseline off switch
  (the overhead bench gates the on/off q/s ratio >= 0.95).

  per-query recall proxy (free, every query):
    single-node q8: overlap between the pre-rerank quantized top-k and
    the post-rerank exact top-k (rerank agreement).  fabric: coverage —
    the fraction of the query's probed clusters a live replica actually
    scanned (< 1.0 exactly on partial rows).  Streamed into
    quality.recall_proxy histograms labeled by route, nprobe bucket,
    degrade status, and (fabric) per shard — a kill drill shows the
    victim shard's histogram dip while survivors hold.

  shadow audit lane (--shadow-rate, default 0.01):
    a deterministic Knuth-hash sample of queries is brute-force
    rescored against the live corpus on a single background lane —
    measured true recall (quality.recall_true) plus per-audit
    |proxy - true| calibration error (quality.calibration_err).
    Submission never blocks serving: the lane is bounded and overflow
    audits are dropped + counted.  Multi-index nodes disable the lane
    (one corpus per auditor); proxies stay on.

  burn-rate SLO alerts (Google SRE multi-window):
    rules deadline/partial/failed/shed/quality fire when the windowed
    bad-event rate burns the error budget at >= 2x on BOTH a fast and
    a slow window, and clear with hysteresis at <= 1x — one transition
    per excursion, no flap storms.  Transitions land on the "slo"
    trace track and in the slo.alerts counter.

  --health-out F      atomically rewrite the health snapshot JSON at F
                      every --health-every seconds (default 1.0): alert
                      states + burn rates, quality rollup, drift
                      summary, harvest depth, full metrics registry —
                      the one document an operator (or the CI drill
                      gate) polls
  --harvest-out F     write the bounded per-query harvest ring (trace
                      id, route, probed clusters, shed/degrade
                      decision, latency, rerank rounds, recall proxy)
                      as a compressed npz shard at exit — the replay
                      substrate for offline policy training

  drills:
    # quality-observed kill drill: watch the victim's proxy dip and
    # the partial burn-rate alert fire, then clear
    serve --shards 8 --replicas 1 --kill-shard-at 4 --duration 8 \\
          --health-out /tmp/health.json --harvest-out /tmp/harvest.npz
    # calibrate the proxy: 10 pct shadow audits, then read
    # quality.calibration_err out of the final health snapshot
    serve --indexes 1 --duration 8 --shadow-rate 0.1 \\
          --health-out /tmp/health.json

operator runbook — concurrency & determinism invariants:

  The serving path is one poller thread crossing several locks; the
  rules that keep it deadlock-free, bounded-memory, and replayable are
  enforced by the static analysis gate and its runtime lock-order
  checker:

    PYTHONPATH=src python -m repro.analysis.lint src tests

  Rule catalog, motivating incidents (including the PR 9
  callback-under-lock deadlock), and the waiver syntax are documented
  in docs/invariants.md.
"""


def main() -> None:
    ap = argparse.ArgumentParser(
        epilog=FABRIC_RUNBOOK,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--indexes", type=int, default=2)
    ap.add_argument("--duration", type=float, default=8.0,
                    help="seconds of traffic")
    ap.add_argument("--rate", type=float, default=30.0,
                    help="total offered qps across tenants")
    ap.add_argument("--batch", type=int, default=32,
                    help="batcher max micro-batch")
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline (0 = best-effort)")
    ap.add_argument("--depth", type=int, default=2,
                    help="in-flight batch window (1 = PR 2 double buffer)")
    ap.add_argument("--grouping", choices=("locality", "fifo"),
                    default="locality",
                    help="micro-batch formation: probe-overlap grouping "
                         "or arrival order")
    ap.add_argument("--rebuild", action="store_true",
                    help="rebuild + swap index 0 mid-run (freshness flow)")
    ap.add_argument("--fail-shard", type=int, default=-1,
                    help="simulate this shard failing mid-run")
    ap.add_argument("--no-kernel", action="store_true",
                    help="packed-domain jnp oracle instead of the Pallas "
                         "kernel (interpret-mode on CPU)")
    ap.add_argument("--tier", choices=("q8", "f32"), default=None,
                    help="first-pass posting payload: int8-residual hot "
                         "tier + flash f32 re-rank (single-node default) "
                         "or the all-f32-resident baseline (see runbook). "
                         "Fabric mode (--shards > 0) serves f32 and "
                         "REJECTS an explicit q8")
    ap.add_argument("--no-rerank", action="store_true",
                    help="q8 tier only: skip the flash-tier exact re-rank "
                         "and serve raw quantized distances")
    ap.add_argument("--rerank-round", type=int, default=64,
                    help="candidates exact-scored per re-rank round")
    ap.add_argument("--rerank-stable", type=int, default=1,
                    help="stop re-ranking after this many consecutive "
                         "rounds leave the top-k unchanged")
    ap.add_argument("--shards", type=int, default=0,
                    help="serve through the sharded fabric with this many "
                         "shards (0 = single-node pipeline; see runbook "
                         "below)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="fabric mode: replicas per cluster (R>=2 for "
                         "zero-loss failover)")
    ap.add_argument("--kill-shard-at", type=float, default=0.0,
                    help="fabric mode: kill a seeded-random live shard at "
                         "this many seconds into the trace (0 = no drill)")
    ap.add_argument("--trace-out", type=str, default="",
                    help="write a Chrome/Perfetto trace_event JSON here at "
                         "exit (enables tracing; see observability runbook)")
    ap.add_argument("--sample-rate", type=float, default=1.0,
                    help="fraction of requests traced when --trace-out is "
                         "set (deterministic per-id sampling)")
    ap.add_argument("--metrics-every", type=float, default=0.0,
                    help="print the metrics registry every N seconds "
                         "(0 = only the end-of-run summary lines)")
    ap.add_argument("--health-out", type=str, default="",
                    help="atomically (re)write the health snapshot JSON "
                         "here — alert states + burn rates, quality "
                         "rollup, full metrics (see quality runbook)")
    ap.add_argument("--health-every", type=float, default=0.0,
                    help="SLO tick + health snapshot cadence in seconds "
                         "(defaults to 1.0 when --health-out is set)")
    ap.add_argument("--shadow-rate", type=float, default=0.01,
                    help="fraction of queries shadow-audited against the "
                         "live corpus (deterministic per-id sampling; "
                         "0 disables the audit lane)")
    ap.add_argument("--no-quality", action="store_true",
                    help="disable the quality-observability layer "
                         "entirely: no recall proxies, shadow audits, "
                         "burn-rate alerts, or harvest records (the "
                         "overhead A/B baseline)")
    ap.add_argument("--harvest-out", type=str, default="",
                    help="write the per-query harvest ring as a "
                         "compressed npz shard here at exit")
    args = ap.parse_args()
    if args.health_out and args.health_every <= 0:
        args.health_every = 1.0

    if args.shards > 0:
        if args.rebuild:
            ap.error("--rebuild needs the single-node pipeline; the fabric "
                     "has no epoch-swap path yet (drop --shards)")
        if args.fail_shard >= 0:
            ap.error("--fail-shard is the single-node heartbeat simulation; "
                     "in fabric mode use --kill-shard-at for a live kill")
        run_fabric(args)
        return

    if args.tier is None:
        args.tier = "q8"               # quantized single-node default
    n_shards = 8
    arena = ChunkArena(n_devices=12, device_bytes=1 << 30, chunk_bytes=1 << 20)
    hb = HeartbeatMonitor(n_shards)
    scfg = SearchConfig(k=10, nprobe_max=16, pruning="llsp", n_ratio=8,
                        use_kernel=not args.no_kernel, fused_topk=True)
    names = list(PAPER_DATASETS)[: args.indexes]
    deadline_s = args.deadline_ms * 1e-3 or None
    rerank = RerankConfig(round_size=args.rerank_round,
                          stable_rounds=args.rerank_stable)
    deps: dict[str, Deployment] = {}
    tiers_seen: list = []          # every deployed tier, incl. swapped-out
    with tempfile.TemporaryDirectory() as root:
        for name in names:
            spec = dataclasses.replace(PAPER_DATASETS[name], n=args.n, dim=32)
            deps[name] = deploy(arena, name, spec,
                                os.path.join(root, name), n_shards, scfg,
                                tier=args.tier, rerank=rerank,
                                with_rerank=not args.no_rerank)
            tiers_seen.append(deps[name].pipeline.tier)

        policy = BatchPolicy(max_batch=args.batch, max_wait_s=0.05,
                             shed="degrade", degrade_nprobe=8,
                             grouping=args.grouping)
        batcher = DynamicBatcher(policy, names)
        obs = make_obs(args)
        # shadow audits need one ground-truth corpus: with co-resident
        # indexes the proxy/SLO streams stay on but the audit lane is off
        audit_vecs = (_vectors_from_postings(deps[names[0]].index)
                      if len(names) == 1 else None)
        quality, harvest, slo = make_quality_stack(args, obs,
                                                   vectors=audit_vecs)
        engine = ServeEngine({n: d.pipeline for n, d in deps.items()},
                             batcher, depth=args.depth, obs=obs,
                             quality=quality)
        # epoch-tagged versions (lifecycle runtime): every batch routes to
        # the current epoch at formation and carries it to harvest, so the
        # mid-run rebuild below swaps atomically — in-flight batches finish
        # on the old epoch, which retires only after its last harvest
        vm = VersionManager()
        for name in names:
            vm.deploy(name, deps[name].pipeline)
        vm.bind(engine)
        # compile off-clock: the batcher can release any partial size up to
        # max_batch, and the pipeline pads each to its own pad_batch
        # multiple — warm exactly that padded-shape set
        pb = deps[names[0]].pipeline.pad_batch
        top = -(-policy.max_batch // pb) * pb
        warm_sizes = tuple(range(pb, top + 1, pb))
        for d in deps.values():
            d.pipeline.warmup(batch_sizes=warm_sizes)
        engine.start()

        trace = multi_tenant_trace(
            [TenantSpec(n, args.rate / len(names), topk_lo=10, topk_hi=50,
                        deadline_s=deadline_s, n_queries=256)
             for n in names],
            args.duration)
        print(f"[serve] replaying {len(trace)} arrivals over "
              f"{args.duration:.0f}s ({args.rate:.0f} qps offered, "
              f"kernel={'pallas' if scfg.use_kernel else 'oracle'})")
        t0 = time.monotonic()
        next_report = 1.0
        next_metrics = args.metrics_every or float("inf")
        next_health = args.health_every or float("inf")
        n_ticks = 0
        # bounded recent window (heartbeat means only); percentiles come
        # from the engine's streaming latency histogram, not a raw list
        lat: collections.deque = collections.deque(maxlen=64)
        lat_hist = obs.metrics.histogram("engine.latency_s")
        failed: list[int] = []
        did_fail = did_rebuild = False
        for arr in trace:
            lag = t0 + arr.t - time.monotonic()
            if lag > 0:
                time.sleep(lag)
            dep = deps[arr.index]
            engine.submit(dep.queries[arr.qrow], arr.topk, index=arr.index,
                          deadline_s=arr.deadline_s)
            el = time.monotonic() - t0
            if el >= next_report:
                # heartbeat ticks every 1s (the monitor needs a few ticks
                # after a failure to cross its miss threshold); stats print
                # every other tick
                while next_report <= el:
                    next_report += 1.0
                n_ticks += 1
                comps = engine.qp.poll()
                lat += [c.latency for c in comps if c.status != "shed"]
                hb.tick()
                mean_lat = float(np.mean(lat)) if lat else 0.0
                for s in range(n_shards):
                    if s not in failed:
                        hb.beat(s, latency=mean_lat)
                st = engine.stats
                if n_ticks % 2 == 0:
                    print(f"[serve] t={el:4.1f}s completed={st.completed} "
                          f"batches={st.batches} shed={st.shed} "
                          f"degraded={st.degraded} "
                          f"p50={lat_hist.summary_ms()['p50_ms']:.0f}ms")
            if el >= next_metrics:
                next_metrics += args.metrics_every
                for line in obs.metrics.render():
                    print(f"[metrics] {line}")
            if el >= next_health:
                next_health += args.health_every
                emit_health(args, quality, harvest, slo, obs.metrics)
            if (not did_fail and args.fail_shard >= 0
                    and el > args.duration / 2):
                did_fail = True
                dep0 = deps[names[0]]
                owners = set(dep0.replica_map.replicas[:, 0].tolist())
                shard = (args.fail_shard if args.fail_shard in owners
                         else int(dep0.replica_map.replicas[0, 0]))
                failed.append(shard)
                plan = plan_failover(dep0.replica_map, failed)
                print(f"[fault] shard {shard} down: "
                      f"{len(plan.moved)} clusters on replicas, "
                      f"{plan.n_lost} lost pending re-replication; "
                      f"heartbeat reports failed={hb.failed().tolist()}")
            if not did_rebuild and args.rebuild and el > 2 * args.duration / 3:
                did_rebuild = True
                name_r = names[0]
                old = deps[name_r]
                spec = dataclasses.replace(old.spec, seed=old.spec.seed + 1)
                # the rebuild inherits the serving tier: a q8 deployment
                # re-quantizes the fresh epoch's shards before the swap
                fresh = deploy(arena, name_r + "_r1", spec,
                               os.path.join(root, f"{name_r}_r1"),
                               n_shards, scfg, tier=args.tier, rerank=rerank,
                               with_rerank=not args.no_rerank)
                tiers_seen.append(fresh.pipeline.tier)
                fresh.pipeline.warmup(batch_sizes=warm_sizes)
                old_ep, new_ep = vm.swap(name_r, fresh.pipeline)
                # reclaim the old extents ONLY after the old epoch's last
                # in-flight batch harvests — freeing early is exactly the
                # use-after-free the epoch protocol exists to prevent
                retired = old_ep.finalized.wait(timeout=30.0)
                if retired:
                    undeploy(arena, old)
                else:
                    print(f"[swap] WARNING: epoch {old_ep.eid} still has "
                          f"{old_ep.inflight} batch(es) in flight; leaking "
                          f"its extents instead of freeing under a live scan")
                deps[name_r] = fresh
                print(f"[swap] {name_r} epoch {old_ep.eid} -> {new_ep.eid}: "
                      f"{old_ep.record.batches} batches finished on the old "
                      f"epoch, retired={retired} (engine kept serving)")

        for name, dep in deps.items():
            r = probe_recall(engine, dep, lat, name)
            print(f"[health] {name}: recall@10={r:.3f} (through the engine)")
        engine.stop(drain=True)
        engine.qp.poll()
        st = engine.stats
        pct = lat_hist.summary_ms()
        wall = time.monotonic() - t0
        print(f"[done] {st.completed} completions in {wall:.1f}s "
              f"({(st.completed - st.shed) / wall:.0f} q/s), "
              f"p50={pct['p50_ms']:.0f}ms p99={pct['p99_ms']:.0f}ms, "
              f"shed={st.shed} degraded={st.degraded} "
              f"rejected={st.rejected}")
        bs = batcher.stats
        # released tiers keep their stats (release drops only the payload),
        # so a retired epoch's pre-swap gather traffic still counts here
        union_mib = sum(t.stats.union_bytes_streamed
                        for t in tiers_seen if t is not None) / 2**20
        print(f"[batcher] grouping={args.grouping} depth={args.depth}: "
              f"{bs.batches} batches ({bs.locality_batches} locality-"
              f"formed, {bs.aged_seeds} aged seeds), "
              f"max queue wait {bs.max_queue_wait_s * 1e3:.1f}ms "
              f"(bound {policy.max_wait_s * 1e3:.0f}ms), "
              f"gather union {union_mib:.1f} MiB")
        if failed:
            # live shards keep beating through shutdown so the monitor can
            # cross its miss threshold on the silent one
            for _ in range(3):
                hb.tick()
                for s in range(n_shards):
                    if s not in failed:
                        hb.beat(s, latency=1e-3)
            print(f"[health] heartbeat-detected failures at shutdown: "
                  f"{hb.failed().tolist()} (injected: {failed})")
        finish_quality(args, quality, harvest, slo, obs.metrics)
        finish_obs(obs, args)
        for dep in deps.values():
            undeploy(arena, dep)
        arena.validate()


if __name__ == "__main__":
    main()
