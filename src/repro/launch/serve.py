"""Serving launcher — the online half of Fig. 8 as a runnable node daemon.

Responsibilities (container-scale versions of the production node):
  * index deployment: build or load indexes, allocate their cluster extents
    from the node's ChunkArena (multi-index hosting, §4.2), publish
    IndexMeta;
  * traffic loop: batched queries through the leveled LLSP engine;
  * health: heartbeat table per logical shard, straggler detection, replica
    failover on shard failure (§6.2);
  * freshness: `--rebuild-every N` swaps in a freshly built index between
    batches (the paper's daily/hourly rebuild flow) atomically.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --indexes 2 --batches 30
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.build.pipeline import BuildConfig, build_index
from repro.core.distance import recall_at_k
from repro.core.ivf import brute_force_topk
from repro.core.llsp import LLSPConfig
from repro.core.search import SearchConfig, serve_leveled
from repro.data import PAPER_DATASETS, make_queries, make_vectors
from repro.distributed import HeartbeatMonitor, ownership_mask, plan_failover
from repro.storage import ChunkArena, IndexMeta, make_replica_map, plan_striping


@dataclasses.dataclass
class Deployment:
    name: str
    index: object
    llsp: object
    spec: object
    meta: IndexMeta
    striping: object
    replica_map: object


def deploy(arena: ChunkArena, name: str, spec, workdir: str,
           n_shards: int) -> Deployment:
    x = make_vectors(spec)
    q, topk = make_queries(spec, 256)
    topk = np.minimum(topk, 50).astype(np.int32)
    cfg = BuildConfig(max_cluster_size=96, cluster_len=128,
                      coarse_per_task=5000, n_workers=2,
                      llsp=LLSPConfig(levels=(8, 16, 32, 64)))
    index, llsp, report = build_index(x, cfg, workdir, queries=q,
                                      query_topk=topk)
    cluster_bytes = index.cluster_len * index.dim * 4
    extents = arena.allocate_index(name, index.n_clusters, cluster_bytes)
    striping = plan_striping(index.n_clusters, n_shards, extents)
    hot = np.arange(index.n_clusters)[::3]
    rmap = make_replica_map(index.n_clusters, n_shards, striping,
                            hot_clusters=hot, n_replicas=2)
    meta = IndexMeta(name=name, n_clusters=index.n_clusters,
                     cluster_len=index.cluster_len, dim=index.dim,
                     dtype="float32", extents=extents)
    meta.save(os.path.join(workdir, f"{name}.meta.json"))
    print(f"[deploy] {name}: {index.n_clusters} clusters, "
          f"{len({e.device for e in extents})} devices, "
          f"arena free {arena.free_bytes >> 20} MiB")
    return Deployment(name, index, llsp, spec, meta, striping, rmap)


def undeploy(arena: ChunkArena, dep: Deployment) -> None:
    arena.release_index(dep.name)
    print(f"[undeploy] {dep.name}: chunks recycled "
          f"(arena free {arena.free_bytes >> 20} MiB)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--indexes", type=int, default=2)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--rebuild-every", type=int, default=8)
    ap.add_argument("--fail-shard", type=int, default=-1,
                    help="simulate this shard failing mid-run")
    args = ap.parse_args()

    n_shards = 8
    arena = ChunkArena(n_devices=12, device_bytes=1 << 30, chunk_bytes=1 << 20)
    hb = HeartbeatMonitor(n_shards)
    names = list(PAPER_DATASETS)[: args.indexes]
    deps = {}
    with tempfile.TemporaryDirectory() as root:
        for name in names:
            spec = dataclasses.replace(PAPER_DATASETS[name], n=args.n, dim=32)
            deps[name] = deploy(arena, name, spec,
                                os.path.join(root, name), n_shards)

        scfg = SearchConfig(k=10, nprobe_max=64, pruning="llsp", n_ratio=16,
                            use_kernel=False)
        failed: list = []
        for b in range(args.batches):
            name = names[b % len(names)]
            dep = deps[name]
            q, topk = make_queries(dep.spec, args.batch, seed=10_000 + b)
            topk = np.minimum(topk, 50).astype(np.int32)
            t0 = time.perf_counter()
            out = serve_leveled(dep.index, dep.llsp, q, topk, scfg)
            dt = time.perf_counter() - t0
            hb.tick()
            for s in range(n_shards):
                if s not in failed:
                    hb.beat(s, latency=dt / args.batch)
            if b % 5 == 0:
                x = make_vectors(dep.spec)
                _, ti = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
                r = recall_at_k(out["ids"], np.asarray(ti))
                print(f"[serve] b{b:03d} {name:8s} {args.batch/dt:7.0f} q/s "
                      f"recall={r:.3f} probes={out['nprobe'].mean():.1f}")
            if b == args.batches // 2 and args.fail_shard >= 0:
                # fail a shard that actually owns clusters of THIS index
                owners = set(dep.replica_map.replicas[:, 0].tolist())
                shard = (args.fail_shard if args.fail_shard in owners
                         else int(dep.replica_map.replicas[0, 0]))
                failed.append(shard)
                plan = plan_failover(dep.replica_map, failed)
                mask = ownership_mask(plan.owner, n_shards)
                print(f"[fault] shard {shard} down: "
                      f"{len(plan.moved)} clusters on replicas, "
                      f"{plan.n_lost} lost pending re-replication; "
                      f"heartbeat reports failed={hb.failed().tolist()}")
            if args.rebuild_every and b > 0 and b % args.rebuild_every == 0:
                # freshness: rebuild + atomic swap (paper's daily rebuild)
                name_r = names[0]
                old = deps[name_r]
                undeploy(arena, old)
                spec = dataclasses.replace(old.spec, seed=old.spec.seed + b)
                deps[name_r] = deploy(
                    arena, name_r, spec,
                    os.path.join(root, f"{name_r}_r{b}"), n_shards)
                print(f"[swap] {name_r} rebuilt and swapped in")
        if failed:
            print(f"[health] heartbeat-detected failures at shutdown: "
                  f"{hb.failed().tolist()} (injected: {failed})")
        for dep in deps.values():
            undeploy(arena, dep)
        arena.validate()
    print("[done]")


if __name__ == "__main__":
    main()
