import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import: jax locks the
#   device count at first init, and the production meshes need 512
#   placeholder devices (16x16 single pod, 2x16x16 multi-pod).

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape x mesh) cell:

    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...)\
            .lower(*input_specs(arch))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

plus a collective-bytes pass over the post-SPMD HLO (cost_analysis does not
expose collective traffic).  Results stream to one JSON per cell under
``results/dryrun/`` so the sweep is resumable; benchmarks/roofline.py builds
the §Roofline table from those files.

Usage:
  python -m repro.launch.dryrun --arch gemma3_12b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import json
import time
import traceback

# deliberate: jax imports AFTER the XLA_FLAGS line above
import jax                                            # noqa: E402
import numpy as np                                    # noqa: E402

from repro.configs import all_archs, get              # noqa: E402
from repro.launch.cells import build_cell             # noqa: E402
from repro.launch.mesh import make_production_mesh    # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# TPU v5e hardware constants (per spec)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             out_dir: str, force: bool = False, save_hlo: bool = False,
             variant: str = "base") -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch_name}.{shape_name}.{mesh_kind}" + (
        "" if variant == "base" else f".{variant}")
    out_path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            cached = json.load(f)
        if cached.get("ok"):        # failed cells re-run on the next sweep
            return cached

    arch = get(arch_name)
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
           "variant": variant, "ok": False}
    t0 = time.perf_counter()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        n_chips = int(np.prod(list(mesh.shape.values())))
        cell = build_cell(arch, shape_name, mesh, variant=variant)
        from jax.sharding import NamedSharding

        def to_sharding(spec_tree, abs_tree):
            return jax.tree.map(
                lambda sp, _: NamedSharding(mesh, sp), spec_tree, abs_tree,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            )

        in_sh = tuple(
            to_sharding(sp, ab) for sp, ab in zip(cell.in_specs, cell.abstract_args)
        )
        out_sh = None
        if cell.out_specs is not None:
            out_sh = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp), cell.out_specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
            )
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*cell.abstract_args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            try:
                hlo = compiled.as_text()
            except Exception:
                hlo = lowered.as_text()
            from repro.launch.hlo_analysis import analyze
            totals = analyze(hlo)
            coll = {"bytes": totals.coll, "ops": totals.coll_ops,
                    "total": totals.coll_total}
            if save_hlo:
                with open(os.path.join(out_dir, tag + ".hlo"), "w") as f:
                    f.write(hlo)
            hlo_len = len(hlo)
            del hlo

        # trip-count-corrected per-device totals (launch/hlo_analysis.py);
        # raw cost_analysis() kept for reference (counts loop bodies once)
        flops = totals.flops
        bytes_acc = totals.bytes
        raw_flops = float(cost.get("flops", 0.0)) if cost else 0.0
        raw_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
        mem_rec = {}
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    mem_rec[k] = int(v)
        # cost_analysis() of the SPMD-partitioned module reports PER-DEVICE
        # numbers (calibrated against the analytically-known k-means cell:
        # HLO flops == global/16 under data-axis-only sharding), so the
        # roofline terms divide by per-chip peaks only.
        compute_s = flops / PEAK_FLOPS
        memory_s = bytes_acc / HBM_BW
        coll_s = coll["total"] / ICI_BW
        model_flops = cell.model_flops
        rec.update({
            "ok": True,
            "n_chips": n_chips,
            "flops": flops,
            "bytes_accessed": bytes_acc,
            "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes},
            "n_while_loops": totals.n_while,
            "collectives": coll,
            "memory_analysis": mem_rec,
            "bytes_per_device": {
                k: v // n_chips for k, v in mem_rec.items()
                if k.endswith("_in_bytes")
            },
            "roofline": {
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": coll_s,
                "dominant": max(
                    [("compute", compute_s), ("memory", memory_s),
                     ("collective", coll_s)], key=lambda kv: kv[1])[0],
            },
            "model_flops": model_flops,
            # global useful flops vs global compiled flops (per-device x chips)
            "useful_ratio": (model_flops / (flops * n_chips)) if flops else None,
            "note": cell.note,
            "hlo_chars": hlo_len,
            "seconds": {"lower": t_lower, "compile": t_compile},
        })
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["seconds"] = {"total": time.perf_counter() - t0}
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK " if rec["ok"] else "FAIL"
    print(f"[{status}] {tag}  "
          + (f"flops={rec['flops']:.3g} coll={rec['collectives']['total']:.3g} "
             f"dom={rec['roofline']['dominant']} "
             f"compile={rec['seconds']['compile']:.1f}s"
             if rec["ok"] else rec.get("error", "")), flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in all_archs():
            for shape in arch.shapes:
                for m in meshes:
                    cells.append((arch.name, shape, m))
            for sname, reason in arch.skip_shapes:
                for m in meshes:
                    tag = f"{arch.name}.{sname}.{m}"
                    path = os.path.join(args.out, tag + ".json")
                    os.makedirs(args.out, exist_ok=True)
                    with open(path, "w") as f:
                        json.dump({"arch": arch.name, "shape": sname,
                                   "mesh": m, "ok": None,
                                   "skipped": reason}, f, indent=1)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for m in meshes:
            cells.append((args.arch, args.shape, m))

    n_ok = n_fail = 0
    for arch_name, shape, m in cells:
        rec = run_cell(arch_name, shape, m, args.out, force=args.force,
                       save_hlo=args.save_hlo, variant=args.variant)
        if rec.get("ok"):
            n_ok += 1
        elif rec.get("ok") is False:
            n_fail += 1
    print(f"done: {n_ok} ok, {n_fail} failed")


if __name__ == "__main__":
    main()
