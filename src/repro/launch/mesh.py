"""Production mesh factory.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; ``pod`` is an outer batch
axis (gradient reduction / serving batch split crosses pods).

A FUNCTION, not a module constant, so importing never touches jax device
state (the dry run sets XLA_FLAGS before the first jax call; tests and
benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real local device(s) — smoke tests of sharded code
    paths (shard_map logic) on CPU."""
    n = len(jax.devices())
    data = min(data, n)
    return jax.make_mesh((data, max(1, n // data)), ("data", "model"))
